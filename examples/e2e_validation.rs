//! Table 2 reproduction — the end-to-end validation driver.
//!
//! Simulates the paper's PD-disaggregated deployment (Qwen2-7B, 8xA800,
//! 1:1 prefill:decode) across the four Table-2 workloads and compares
//! **predicted** throughput (Frontier: learned PJRT predictor +
//! conservative engine overheads) against **profiled** throughput (the
//! real-system stand-in: analytical oracle + calibrated vLLM-like engine
//! overheads — see DESIGN.md §Substitutions). The paper reports a
//! consistent 19.0-23.2% relative error band with trends preserved;
//! this driver asserts the same *shape*: every row within a modest
//! band, ordering identical, predicted below profiled.
//!
//! Also exercises the full three-layer stack on a Poisson trace and
//! reports latency percentiles. Results land in
//! `target/bench_results/table2.csv` and EXPERIMENTS.md.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_validation
//! ```

use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::model::ModelConfig;
use frontier::predictor::PredictorKind;
use frontier::report::{csv, markdown_table};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

/// The paper's Table-2 grid: (batch size, avg input, output).
const TABLE2: [(u32, u32, u32); 4] = [(4, 32, 1024), (8, 128, 256), (16, 256, 128), (32, 32, 128)];

fn workload(bs: u32, avg_in: u32, out: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Uniform { lo: (avg_in / 2).max(1), hi: avg_in + avg_in / 2 },
        output: LenDist::Fixed(out),
        // enough waves to reach steady state at the target concurrency
        n_requests: bs * 6,
        seed: 0x7AB1E2,
        classes: vec![],
        trace: None,
    }
}

fn config(bs: u32, avg_in: u32, out: u32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4)
        .with_workload(workload(bs, avg_in, out));
    // Table 2's "batch size" is the serving concurrency: cap each decode
    // replica so the global in-flight count matches
    cfg.policy.budget.max_batch = ((bs + 3) / 4).max(1) as usize;
    cfg
}

fn main() -> anyhow::Result<()> {
    println!("== Table 2: PD-disaggregated Qwen2-7B, 8 GPUs (4 prefill : 4 decode) ==\n");
    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    let mut errors = Vec::new();
    let mut pairs = Vec::new();
    for (bs, avg_in, out) in TABLE2 {
        // predicted: Frontier with the learned predictor (PJRT artifacts)
        let predicted = frontier::run_experiment(
            &config(bs, avg_in, out)
                .with_predictor(PredictorKind::Learned)
                .with_overhead(OverheadConfig::predicted()),
        )?;
        // profiled: the physical-system stand-in (oracle operator times +
        // calibrated real-engine overheads)
        let profiled = frontier::run_experiment(
            &config(bs, avg_in, out)
                .with_predictor(PredictorKind::Oracle)
                .with_overhead(OverheadConfig::profiled_real()),
        )?;
        let p = predicted.tokens_per_sec_per_gpu();
        let t = profiled.tokens_per_sec_per_gpu();
        let err = (p - t).abs() / t;
        errors.push(err);
        pairs.push((p, t));
        rows.push(vec![
            bs.to_string(),
            avg_in.to_string(),
            out.to_string(),
            format!("{t:.3}"),
            format!("{p:.3}"),
            format!("{:.1}%", err * 100.0),
        ]);
        csv_rows.push(vec![
            bs.to_string(),
            avg_in.to_string(),
            out.to_string(),
            format!("{t:.4}"),
            format!("{p:.4}"),
            format!("{err:.4}"),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["Batch", "Avg Input", "Output", "Profiled tok/s/GPU", "Predicted tok/s/GPU", "Rel err"],
            &rows
        )
    );
    frontier::bench_util::write_results(
        "table2.csv",
        &csv(&["batch", "avg_input", "output", "profiled", "predicted", "rel_err"], &csv_rows),
    );

    // the paper's claims, as assertions
    let profiled_order: Vec<usize> = {
        let mut idx: Vec<usize> = (0..pairs.len()).collect();
        idx.sort_by(|&a, &b| pairs[a].1.partial_cmp(&pairs[b].1).unwrap());
        idx
    };
    let predicted_order: Vec<usize> = {
        let mut idx: Vec<usize> = (0..pairs.len()).collect();
        idx.sort_by(|&a, &b| pairs[a].0.partial_cmp(&pairs[b].0).unwrap());
        idx
    };
    assert_eq!(
        profiled_order, predicted_order,
        "throughput trend across configurations must be captured"
    );
    let max_err = errors.iter().cloned().fold(0.0, f64::max);
    let min_err = errors.iter().cloned().fold(1.0, f64::min);
    println!(
        "relative error band: {:.1}% .. {:.1}% (paper: 19.0% .. 23.2%)",
        min_err * 100.0,
        max_err * 100.0
    );
    assert!(max_err < 0.35, "error band blew past the paper's ballpark: {max_err:.3}");

    // full-stack latency study on a live trace
    println!("\n== End-to-end Poisson trace through the full stack ==\n");
    let cfg = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4)
        .with_workload(WorkloadSpec::poisson(10.0, 300, 512, 128))
        .with_predictor(PredictorKind::Learned);
    let r = frontier::run_experiment(&cfg)?;
    println!("{}", r.summary());
    println!(
        "\nTTFT p50/p90/p99: {:.0}/{:.0}/{:.0} ms | TBT p50/p99: {:.1}/{:.1} ms",
        r.metrics.ttft.quantile(50.0) * 1e3,
        r.metrics.ttft.quantile(90.0) * 1e3,
        r.metrics.ttft.quantile(99.0) * 1e3,
        r.metrics.tbt.quantile(50.0) * 1e3,
        r.metrics.tbt.quantile(99.0) * 1e3,
    );
    println!("\nTable 2 validation complete.");
    Ok(())
}
