//! AF disaggregation + MoE: micro-batch pipelining and expert stragglers.
//!
//! Reproduces the qualitative claims of §3.3: (a) the ping-pong pipeline
//! hides transfer/compute gaps as micro-batches increase, and (b) token
//! load imbalance creates straggler effects that balance-oblivious
//! simulation misses.
//!
//! ```bash
//! cargo run --release --example af_moe
//! ```

use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::model::ModelConfig;
use frontier::moe::RoutingPolicy;
use frontier::report::markdown_table;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn workload() -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Uniform { lo: 128, hi: 1024 },
        output: LenDist::Fixed(64),
        n_requests: 48,
        seed: 7,
        classes: vec![],
        trace: None,
    }
}

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::mixtral_8x7b();
    println!("== AF decode pool: micro-batch (ping-pong) sweep, {} ==\n", model.name);
    let mut rows = Vec::new();
    for m in [1u32, 2, 4, 8] {
        // prefill tier at tp=2: Mixtral's 92 GB of weights need 2 GPUs
        let cfg = ExperimentConfig::af(model.clone(), 2, 4, 4, m)
            .with_parallelism(frontier::parallelism::Parallelism::tp(2))
            .with_workload(workload())
            .with_overhead(OverheadConfig::zero());
        let r = frontier::run_experiment(&cfg)?;
        rows.push(vec![
            m.to_string(),
            format!("{:.2}", r.sim_duration),
            format!("{:.1}", r.tokens_per_sec_per_gpu()),
            format!(
                "{:.1}",
                r.metrics.tbt.quantile(50.0) * 1e3
            ),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["micro-batches", "makespan (s)", "tok/s/gpu", "TBT p50 (ms)"], &rows)
    );

    println!("\n== MoE routing skew: straggler effects under EP=8 ==\n");
    let mut rows = Vec::new();
    for (name, routing) in [
        ("balanced", RoutingPolicy::Balanced),
        ("uniform", RoutingPolicy::UniformRandom),
        ("skewed a=0.5", RoutingPolicy::Skewed { alpha: 0.5 }),
        ("skewed a=0.05", RoutingPolicy::Skewed { alpha: 0.05 }),
    ] {
        let run = |straggler: bool| -> anyhow::Result<f64> {
            let mut cfg = ExperimentConfig::colocated(model.clone(), 1)
                .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 8))
                .with_workload(workload())
                .with_overhead(OverheadConfig::zero());
            cfg.policy.moe_routing = routing;
            cfg.policy.straggler_max = straggler;
            Ok(frontier::run_experiment(&cfg)?.sim_duration)
        };
        let with = run(true)?;
        let without = run(false)?;
        rows.push(vec![
            name.to_string(),
            format!("{with:.2}"),
            format!("{without:.2}"),
            format!("{:+.1}%", (with / without - 1.0) * 100.0),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["routing", "max-sync (s)", "mean-sync (s)", "straggler cost"],
            &rows
        )
    );
    println!(
        "\nThe `max` synchronization barrier (§3.3) prices the slowest EP rank;\n\
         under skewed routing the gap versus balance-oblivious `mean` widens —\n\
         exactly the fidelity gap Frontier's MoE micro-workflow closes."
    );
    Ok(())
}
