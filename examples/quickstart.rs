//! Quickstart: simulate a co-located Qwen2-7B deployment in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use frontier::config::ExperimentConfig;
use frontier::model::ModelConfig;
use frontier::predictor::PredictorKind;
use frontier::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    // 4 single-GPU replicas of Qwen2-7B, Poisson arrivals at 6 req/s
    let cfg = ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 4)
        .with_workload(WorkloadSpec::poisson(6.0, 200, 512, 128))
        .with_predictor(PredictorKind::Oracle);

    let report = frontier::run_experiment(&cfg)?;
    println!("{}", report.summary());

    // the same deployment under PD disaggregation (2 prefill : 2 decode)
    let pd = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 2, 2)
        .with_workload(WorkloadSpec::poisson(6.0, 200, 512, 128));
    let pd_report = frontier::run_experiment(&pd)?;
    println!("\n{}", pd_report.summary());

    println!(
        "\nPD vs co-located on 4 GPUs: {:.1} vs {:.1} tok/s/gpu, \
         p99 TBT {:.1} vs {:.1} ms",
        pd_report.tokens_per_sec_per_gpu(),
        report.tokens_per_sec_per_gpu(),
        pd_report.metrics.tbt.quantile(99.0) * 1e3,
        report.metrics.tbt.quantile(99.0) * 1e3,
    );
    Ok(())
}
