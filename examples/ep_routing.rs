//! Expert-parallel placement x routing-skew sweep (cross-cluster MoE),
//! plus a static-vs-migrating sweep under drifting popularity.
//!
//! The paper's headline MoE scenario: an AF-disaggregated decode pool
//! whose FFN/expert tier spans two clusters. Sweeps expert placement
//! (contiguous, strided, replicated-hot) against routing skew
//! (balanced -> heavily skewed) and reports end-to-end step economics:
//! makespan, cross-cluster byte fraction, EP rank imbalance, and the
//! dispatch bubbles the ping-pong pipeline could not hide. The final
//! section pits `--migration off` against `--migration threshold` on a
//! drifting-popularity workload and emits a CSV (migration overhead vs.
//! recovered imbalance) — see README "Expert migration" for how to
//! read it.
//!
//! ```bash
//! cargo run --release --example ep_routing
//! ```

use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::hardware::LinkSpec;
use frontier::model::ModelConfig;
use frontier::moe::{
    EpSpec, EpTopology, ExpertPlacement, PlacementPolicy, RoutingPolicy,
};
use frontier::parallelism::Parallelism;
use frontier::report::markdown_table;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn workload() -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Uniform { lo: 128, hi: 512 },
        output: LenDist::Fixed(32),
        n_requests: 32,
        seed: 13,
    }
}

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::mixtral_8x7b();
    let placements = [
        PlacementPolicy::Contiguous,
        PlacementPolicy::Strided,
        PlacementPolicy::ReplicatedHot { hot: 2 },
    ];
    let routings = [
        ("balanced", RoutingPolicy::Balanced),
        ("uniform", RoutingPolicy::UniformRandom),
        ("skewed a=0.1", RoutingPolicy::Skewed { alpha: 0.1 }),
    ];

    println!(
        "== layer-level EP all-to-all: placement x skew ({}, EP=8 over 2 clusters) ==\n",
        model.name
    );
    let moe = model.moe.clone().expect("moe model");
    let bpt = model.d_model as f64 * model.dtype_bytes as f64;
    let mut rows = Vec::new();
    for placement in placements {
        for (rname, routing) in routings {
            let mut rng = frontier::core::Pcg64::new(17);
            let loads =
                frontier::moe::assign_tokens(routing, 256, moe.n_experts, moe.top_k, &mut rng);
            let spec = EpSpec::flat(
                ExpertPlacement::build(
                    placement,
                    moe.n_experts,
                    EpTopology::new(8, 2),
                    Some(&loads),
                ),
                LinkSpec::nvlink_a800(),
                LinkSpec::cross_cluster(),
            );
            let disp = spec.a2a_time(&spec.placement.dispatch_matrix(&loads, bpt));
            let imb = frontier::moe::rank_imbalance(&spec.placement.rank_totals(&loads));
            rows.push(vec![
                placement.name().to_string(),
                rname.to_string(),
                format!("{:.1}", disp.secs * 1e6),
                format!("{:.1}%", disp.cross_bytes / disp.total_bytes * 100.0),
                format!("{imb:.2}"),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &["placement", "routing", "dispatch (us)", "cross bytes", "rank imbalance"],
            &rows
        )
    );

    println!("\n== end-to-end AF decode: placement x routing (2-cluster expert tier) ==\n");
    let mut rows = Vec::new();
    for placement in placements {
        for (rname, routing) in routings {
            let cfg = ExperimentConfig::af(model.clone(), 2, 4, 8, 2)
                .with_parallelism(frontier::parallelism::Parallelism::tp(2))
                .with_workload(workload())
                .with_overhead(OverheadConfig::zero())
                .with_ep_clusters(2, LinkSpec::cross_cluster())
                .with_ep_placement(placement)
                .with_moe_routing(routing);
            let r = frontier::run_experiment(&cfg)?;
            let m = &r.metrics;
            rows.push(vec![
                placement.name().to_string(),
                rname.to_string(),
                format!("{:.2}", r.sim_duration),
                format!("{:.1}", r.tokens_per_sec_per_gpu()),
                format!("{:.1}%", m.ep_cross_frac() * 100.0),
                format!("{:.2}", m.ep_imbalance_mean()),
                format!("{:.2}", m.dispatch_bubble_s),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &[
                "placement",
                "routing",
                "makespan (s)",
                "tok/s/gpu",
                "cross bytes",
                "imbalance",
                "bubble (s)"
            ],
            &rows
        )
    );

    println!("\n== cluster span: same deployment, EP domain in 1 vs 2 clusters ==\n");
    let mut rows = Vec::new();
    for clusters in [1u32, 2] {
        let cfg = ExperimentConfig::af(model.clone(), 2, 4, 8, 2)
            .with_parallelism(frontier::parallelism::Parallelism::tp(2))
            .with_workload(workload())
            .with_overhead(OverheadConfig::zero())
            .with_ep_clusters(clusters, LinkSpec::cross_cluster());
        let r = frontier::run_experiment(&cfg)?;
        rows.push(vec![
            clusters.to_string(),
            format!("{:.2}", r.sim_duration),
            format!("{:.1}%", r.metrics.ep_cross_frac() * 100.0),
            format!("{:.2}", r.metrics.dispatch_bubble_s),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["clusters", "makespan (s)", "cross bytes", "bubble (s)"], &rows)
    );
    println!(
        "\nCross-cluster EP pays the trunk on every dispatch/combine; skewed\n\
         routing serializes on the hot expert's ingress NIC. Replicating the\n\
         hottest experts onto each cluster trades memory for both effects —\n\
         the placement axis the closed-form all-to-all cannot see."
    );

    println!("\n== drifting popularity: static vs migrating placement (CSV) ==\n");
    // One co-located tiny-moe replica, 4 EP ranks, popularity jumping
    // to a new hot set every `period` routing draws: the faster the
    // drift, the more often migration pays its weight-move bill (each
    // adopted move copies the expert's weights for every layer).
    // Columns: `overhead_stall_s` / `migrated_mb` are what migration
    // costs, `recovered_imbalance` is what it buys back (mean EP rank
    // imbalance of static minus migrating at equal config).
    println!(
        "drift_period,migration,sim_s,tok_s_gpu,imb_mean,migrations,\
         migrated_mb,overhead_stall_s,recovered_imbalance"
    );
    for period in [12u64, 24, 48] {
        let base = |migrate: bool| {
            let mut cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
                .with_parallelism(Parallelism::new(1, 1, 4))
                .with_workload(WorkloadSpec::table2(128, 64, 64))
                .with_overhead(OverheadConfig::zero())
                .with_moe_routing(RoutingPolicy::Drifting { alpha: 0.1, period });
            if migrate {
                cfg = cfg.with_migration(1.1, 8);
            }
            cfg
        };
        let stat = frontier::run_experiment(&base(false))?;
        let mig = frontier::run_experiment(&base(true))?;
        for (label, r) in [("off", &stat), ("threshold", &mig)] {
            let recovered = if label == "threshold" {
                stat.metrics.ep_imbalance_mean() - r.metrics.ep_imbalance_mean()
            } else {
                0.0
            };
            println!(
                "{},{},{:.4},{:.2},{:.3},{},{:.1},{:.5},{:.3}",
                period,
                label,
                r.sim_duration,
                r.tokens_per_sec_per_gpu(),
                r.metrics.ep_imbalance_mean(),
                r.metrics.migrations,
                r.metrics.migrated_bytes / 1e6,
                r.metrics.migration_stall_s,
                recovered,
            );
        }
    }
    println!(
        "\nRead it as a trade: `overhead_stall_s` (and the moved megabytes)\n\
         is the price of following the hot set; `recovered_imbalance` is the\n\
         rank-imbalance the migrating run wins back, which shows up as the\n\
         sim_s / tok_s_gpu gap at equal configuration. Fast drift (small\n\
         period) migrates more and can spend more on weight moves than the\n\
         rebalance recovers; expert size scales the bill — a mixtral-class\n\
         expert costs ~28x a tiny-moe expert per move."
    );
    Ok(())
}
