//! Expert-parallel placement x routing-skew sweep (cross-cluster MoE),
//! plus a static-vs-migrating sweep under drifting popularity.
//!
//! The paper's headline MoE scenario: an AF-disaggregated decode pool
//! whose FFN/expert tier spans two clusters. Sweeps expert placement
//! (contiguous, strided, replicated-hot) against routing skew
//! (balanced -> heavily skewed) and reports end-to-end step economics:
//! makespan, cross-cluster byte fraction, EP rank imbalance, and the
//! dispatch bubbles the ping-pong pipeline could not hide. The final
//! section pits `--migration off` against `--migration threshold` on a
//! drifting-popularity workload and emits a CSV (migration overhead vs.
//! recovered imbalance) — see README "Expert migration" for how to
//! read it.
//!
//! Every end-to-end grid here is a thin front-end over the parallel
//! sweep engine (`frontier::sweep`): axes over CLI flags, fanned across
//! worker threads, results collected in deterministic grid order.
//!
//! ```bash
//! cargo run --release --example ep_routing
//! ```

use frontier::config::cli::FlagMap;
use frontier::hardware::LinkSpec;
use frontier::metrics::SimReport;
use frontier::model::ModelConfig;
use frontier::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy, RoutingPolicy};
use frontier::report::markdown_table;
use frontier::sweep::{Axis, SweepRunner, SweepSpec};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn workload() -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Uniform { lo: 128, hi: 512 },
        output: LenDist::Fixed(32),
        n_requests: 32,
        seed: 13,
        classes: vec![],
        trace: None,
    }
}

/// Base flags of the AF deployment every end-to-end grid shares: 2
/// prefill replicas feeding a 4-attn / 8-ffn decode pool, tp=2, zero
/// engine overhead (the custom length distribution rides a post-hook).
fn af_base() -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "mixtral-8x7b");
    f.set("mode", "af");
    f.set("prefill", "2");
    f.set("attn-gpus", "4");
    f.set("ffn-gpus", "8");
    f.set("micro-batches", "2");
    f.set("tp", "2");
    f.set("overhead", "zero");
    f
}

fn report_of(pr: &frontier::sweep::PointResult) -> anyhow::Result<&SimReport> {
    pr.outcome
        .as_ref()
        .map_err(|e| anyhow::anyhow!("point {:?} failed: {e}", pr.point.label))
}

fn main() -> anyhow::Result<()> {
    let model = ModelConfig::mixtral_8x7b();
    let placements = ["contiguous", "strided", "replicated:2"];
    let routings = ["balanced", "uniform", "skewed:0.1"];

    println!(
        "== layer-level EP all-to-all: placement x skew ({}, EP=8 over 2 clusters) ==\n",
        model.name
    );
    let moe = model.moe.clone().expect("moe model");
    let bpt = model.d_model as f64 * model.dtype_bytes as f64;
    let mut rows = Vec::new();
    for pname in placements {
        let placement = PlacementPolicy::parse(pname).expect("placement");
        for routing in routings {
            let policy = RoutingPolicy::parse(routing).expect("routing");
            let mut rng = frontier::core::Pcg64::new(17);
            let loads =
                frontier::moe::assign_tokens(policy, 256, moe.n_experts, moe.top_k, &mut rng);
            let spec = EpSpec::flat(
                ExpertPlacement::build(
                    placement,
                    moe.n_experts,
                    EpTopology::new(8, 2),
                    Some(&loads),
                ),
                LinkSpec::nvlink_a800(),
                LinkSpec::cross_cluster(),
            );
            let disp = spec.a2a_time(&spec.placement.dispatch_matrix(&loads, bpt));
            let imb = frontier::moe::rank_imbalance(&spec.placement.rank_totals(&loads));
            rows.push(vec![
                placement.name().to_string(),
                routing.to_string(),
                format!("{:.1}", disp.secs * 1e6),
                format!("{:.1}%", disp.cross_bytes / disp.total_bytes * 100.0),
                format!("{imb:.2}"),
            ]);
        }
    }
    println!(
        "{}",
        markdown_table(
            &["placement", "routing", "dispatch (us)", "cross bytes", "rank imbalance"],
            &rows
        )
    );

    println!("\n== end-to-end AF decode: placement x routing (2-cluster expert tier) ==\n");
    let mut base = af_base();
    base.set("ep-clusters", "2");
    let spec = SweepSpec::new(base)
        .with_axes(vec![
            Axis::new("ep-placement", placements.iter().map(|s| s.to_string()).collect())?,
            Axis::new("routing", routings.iter().map(|s| s.to_string()).collect())?,
        ])
        .with_post(Box::new(|cfg| cfg.workload = workload()));
    let result = SweepRunner::default().run(&spec)?;
    let mut rows = Vec::new();
    for pr in &result.points {
        let r = report_of(pr)?;
        let m = &r.metrics;
        rows.push(vec![
            pr.point.assigns[0].1.clone(),
            pr.point.assigns[1].1.clone(),
            format!("{:.2}", r.sim_duration),
            format!("{:.1}", r.tokens_per_sec_per_gpu()),
            format!("{:.1}%", m.ep_cross_frac() * 100.0),
            format!("{:.2}", m.ep_imbalance_mean()),
            format!("{:.2}", m.dispatch_bubble_s),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &[
                "placement",
                "routing",
                "makespan (s)",
                "tok/s/gpu",
                "cross bytes",
                "imbalance",
                "bubble (s)"
            ],
            &rows
        )
    );

    println!("\n== cluster span: same deployment, EP domain in 1 vs 2 clusters ==\n");
    let spec = SweepSpec::new(af_base())
        .with_axes(vec![Axis::new("ep-clusters", vec!["1".into(), "2".into()])?])
        .with_post(Box::new(|cfg| cfg.workload = workload()));
    let result = SweepRunner::default().run(&spec)?;
    let mut rows = Vec::new();
    for pr in &result.points {
        let r = report_of(pr)?;
        rows.push(vec![
            pr.point.assigns[0].1.clone(),
            format!("{:.2}", r.sim_duration),
            format!("{:.1}%", r.metrics.ep_cross_frac() * 100.0),
            format!("{:.2}", r.metrics.dispatch_bubble_s),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["clusters", "makespan (s)", "cross bytes", "bubble (s)"], &rows)
    );
    println!(
        "\nCross-cluster EP pays the trunk on every dispatch/combine; skewed\n\
         routing serializes on the hot expert's ingress NIC. Replicating the\n\
         hottest experts onto each cluster trades memory for both effects —\n\
         the placement axis the closed-form all-to-all cannot see."
    );

    println!("\n== drifting popularity: static vs migrating placement (CSV) ==\n");
    // One co-located tiny-moe replica, 4 EP ranks, popularity jumping
    // to a new hot set every `period` routing draws: the faster the
    // drift, the more often migration pays its weight-move bill (each
    // adopted move copies the expert's weights for every layer).
    // Columns: `overhead_stall_s` / `migrated_mb` are what migration
    // costs, `recovered_imbalance` is what it buys back (mean EP rank
    // imbalance of static minus migrating at equal config).
    let mut base = FlagMap::new();
    base.set("model", "tiny-moe");
    base.set("replicas", "1");
    base.set("ep", "4");
    base.set("requests", "128");
    base.set("input", "64");
    base.set("output", "64");
    base.set("overhead", "zero");
    base.set("migration-threshold", "1.1");
    base.set("load-window", "8");
    let spec = SweepSpec::new(base).with_axes(vec![
        Axis::new(
            "routing",
            vec!["drift:0.1:12".into(), "drift:0.1:24".into(), "drift:0.1:48".into()],
        )?,
        Axis::new("migration", vec!["off".into(), "threshold".into()])?,
    ]);
    let result = SweepRunner::default().run(&spec)?;
    println!(
        "drift_period,migration,sim_s,tok_s_gpu,imb_mean,migrations,\
         migrated_mb,overhead_stall_s,recovered_imbalance"
    );
    // grid order is (period slowest, migration fastest): chunk into
    // (static, migrating) pairs at equal drift period
    for pair in result.points.chunks(2) {
        let stat = report_of(&pair[0])?;
        let mig = report_of(&pair[1])?;
        let period = pair[0].point.assigns[0].1.rsplit(':').next().unwrap_or("?");
        for (label, r) in [("off", stat), ("threshold", mig)] {
            let recovered = if label == "threshold" {
                stat.metrics.ep_imbalance_mean() - r.metrics.ep_imbalance_mean()
            } else {
                0.0
            };
            println!(
                "{},{},{:.4},{:.2},{:.3},{},{:.1},{:.5},{:.3}",
                period,
                label,
                r.sim_duration,
                r.tokens_per_sec_per_gpu(),
                r.metrics.ep_imbalance_mean(),
                r.metrics.migrations,
                r.metrics.migrated_bytes / 1e6,
                r.metrics.migration_stall_s,
                recovered,
            );
        }
    }
    println!(
        "\nRead it as a trade: `overhead_stall_s` (and the moved megabytes)\n\
         is the price of following the hot set; `recovered_imbalance` is the\n\
         rank-imbalance the migrating run wins back, which shows up as the\n\
         sim_s / tok_s_gpu gap at equal configuration. Fast drift (small\n\
         period) migrates more and can spend more on weight moves than the\n\
         rebalance recovers; expert size scales the bill — a mixtral-class\n\
         expert costs ~28x a tiny-moe expert per move."
    );
    Ok(())
}
