//! Figure 2 reproduction: CDF of relative error in simulated operator
//! runtime under dynamic workloads.
//!
//! Frontier (the learned predictor, via the AOT PJRT artifacts) vs the
//! Vidur proxy-length baseline on Attention; Frontier alone on
//! GroupedGEMM (unsupported by Vidur, Table 1). Ground truth is the
//! analytical kernel oracle. Writes CSV series to
//! `target/bench_results/` and prints an ASCII CDF.
//!
//! ```bash
//! make artifacts && cargo run --release --example fig2_cdf
//! ```

use frontier::core::Pcg64;
use frontier::operators::opgen;
use frontier::predictor::{
    ExecutionPredictor, LearnedPredictor, OraclePredictor, RooflinePredictor, VidurPredictor,
};
use frontier::report::{ascii_cdf, cdf_summary, csv};
use frontier::runtime::PredictorRuntime;

const N_CASES: usize = 1000;

fn rel_errors(
    pred: &mut dyn ExecutionPredictor,
    truth: &mut OraclePredictor,
    ops: &[frontier::operators::OpWorkload],
) -> Vec<f64> {
    ops.iter()
        .map(|op| {
            let p = pred.predict(op);
            let t = truth.predict(op);
            (p - t).abs() / t
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    let dir = PredictorRuntime::default_dir();
    let mut learned = LearnedPredictor::load_exact(&dir)
        .map_err(|e| anyhow::anyhow!("run `make artifacts` first: {e}"))?;
    let mut vidur = VidurPredictor::a800();
    let mut roofline = RooflinePredictor::a800();
    let mut truth = OraclePredictor::a800();

    // held-out workloads (seed differs from training)
    let mut rng = Pcg64::new(0xF16_2);
    let attn_ops: Vec<_> = (0..N_CASES).map(|_| opgen::attn_workload(&mut rng)).collect();
    let gg_ops: Vec<_> =
        (0..N_CASES).map(|_| opgen::grouped_gemm_workload(&mut rng)).collect();

    println!("== Figure 2(a): Attention operator, {N_CASES} dynamic workloads ==\n");
    let frontier_err = rel_errors(&mut learned, &mut truth, &attn_ops);
    let vidur_err = rel_errors(&mut vidur, &mut truth, &attn_ops);
    let roofline_err = rel_errors(&mut roofline, &mut truth, &attn_ops);
    println!("{}", cdf_summary(&frontier_err, "Frontier"));
    println!("{}", cdf_summary(&vidur_err, "Vidur   "));
    println!("{}", cdf_summary(&roofline_err, "Roofline"));
    println!(
        "\n{}",
        ascii_cdf(
            &[
                ("Frontier", frontier_err.clone()),
                ("Vidur", vidur_err.clone()),
                ("Roofline", roofline_err.clone()),
            ],
            64,
            16,
            0.6,
        )
    );

    println!("== Figure 2(b): GroupedGEMM operator (Vidur: unsupported) ==\n");
    let gg_err = rel_errors(&mut learned, &mut truth, &gg_ops);
    println!("{}", cdf_summary(&gg_err, "Frontier"));
    println!(
        "\n{}",
        ascii_cdf(&[("Frontier", gg_err.clone())], 64, 16, 0.2)
    );

    // paper's headline fidelity claims
    let attn_under_10 = frontier::metrics::frac_below(&frontier_err, 0.10);
    let gg_under_6 = frontier::metrics::frac_below(&gg_err, 0.06);
    println!("Frontier attention: {:.1}% of cases under 10% error (paper: >94%)", attn_under_10 * 100.0);
    println!("Frontier GroupedGEMM: {:.1}% of cases under 6% error (paper: >95%)", gg_under_6 * 100.0);

    // CSV series for external plotting
    let mut rows = Vec::new();
    for (i, op) in attn_ops.iter().enumerate() {
        rows.push(vec![
            i.to_string(),
            op.class().to_string(),
            format!("{:.6}", frontier_err[i]),
            format!("{:.6}", vidur_err[i]),
            format!("{:.6}", roofline_err[i]),
        ]);
    }
    frontier::bench_util::write_results(
        "fig2_attention.csv",
        &csv(&["case", "kind", "frontier", "vidur", "roofline"], &rows),
    );
    let gg_rows: Vec<Vec<String>> = gg_err
        .iter()
        .enumerate()
        .map(|(i, e)| vec![i.to_string(), format!("{e:.6}")])
        .collect();
    frontier::bench_util::write_results(
        "fig2_grouped_gemm.csv",
        &csv(&["case", "frontier"], &gg_rows),
    );
    Ok(())
}
