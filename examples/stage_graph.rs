//! Stage-graph deployments: PD+AF hybrid, heterogeneous-GPU PD, and
//! multi-decode-pool fan-out — the shapes the flat mode enum could not
//! express, each a few lines of graph config.
//!
//! ```bash
//! cargo run --release --example stage_graph
//! ```

use frontier::cluster::StageKind;
use frontier::config::{ExperimentConfig, StageConfig, StageGraphConfig};
use frontier::hardware::GpuSpec;
use frontier::model::ModelConfig;
use frontier::parallelism::Parallelism;
use frontier::report::markdown_table;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn workload(n: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Uniform { lo: 128, hi: 512 },
        output: LenDist::Fixed(32),
        n_requests: n,
        seed: 13,
        classes: vec![],
        trace: None,
    }
}

fn stage_rows(r: &frontier::metrics::SimReport) -> Vec<Vec<String>> {
    r.stages
        .iter()
        .map(|st| {
            vec![
                st.name.clone(),
                st.kind.clone(),
                format!("{}x ({} gpus, {})", st.replicas, st.gpus, st.gpu_name),
                st.iterations.to_string(),
                st.tokens.to_string(),
                format!("{:.1}%", st.busy_frac * 100.0),
            ]
        })
        .collect()
}

fn print_run(title: &str, r: &frontier::metrics::SimReport) {
    println!("\n== {title} ==");
    println!(
        "  {:.2}s simulated | {:.1} tok/s/gpu | TTFT p99 {:.0} ms | TBT p99 {:.2} ms",
        r.sim_duration,
        r.tokens_per_sec_per_gpu(),
        r.metrics.ttft.quantile(99.0) * 1e3,
        r.metrics.tbt.quantile(99.0) * 1e3,
    );
    println!(
        "{}",
        markdown_table(
            &["stage", "kind", "pool", "iters", "tokens", "busy"],
            &stage_rows(r)
        )
    );
}

fn main() -> anyhow::Result<()> {
    // 1. PD+AF hybrid: a prefill pool feeding an attention/FFN decode
    //    pair whose expert tier spans two clusters (the paper's
    //    cross-cluster MoE scenario, now composed from graph pieces).
    let moe = ModelConfig::mixtral_8x7b();
    let mut hybrid = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 2)
            .named("prefill")
            .with_parallelism(Parallelism::tp(2)),
        StageConfig::af_stage(4, 8, 2).named("af-decode"),
    ]);
    hybrid.stages[1].ep_clusters = Some(2);
    let cfg = ExperimentConfig::from_stages(moe.clone(), hybrid).with_workload(workload(32));
    print_run("PD+AF hybrid (Mixtral, EP over 2 clusters)", &frontier::run_experiment(&cfg)?);

    // 2. Heterogeneous PD: big-HBM H200s prefill, cheap A800s decode —
    //    compared against the same GPU count of homogeneous A800s.
    let dense = ModelConfig::qwen2_7b();
    let hetero = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 2).named("prefill").on_gpu(GpuSpec::h200()),
        StageConfig::new(StageKind::Decode, 2).named("decode").on_gpu(GpuSpec::a800()),
    ]);
    let cfg_het =
        ExperimentConfig::from_stages(dense.clone(), hetero).with_workload(workload(48));
    let r_het = frontier::run_experiment(&cfg_het)?;
    print_run("heterogeneous PD (H200 prefill -> A800 decode)", &r_het);
    let cfg_homo = ExperimentConfig::pd(dense.clone(), 2, 2).with_workload(workload(48));
    let r_homo = frontier::run_experiment(&cfg_homo)?;
    println!(
        "  vs homogeneous A800 PD: {:.2}s simulated, TTFT p99 {:.0} ms",
        r_homo.sim_duration,
        r_homo.metrics.ttft.quantile(99.0) * 1e3
    );

    // 3. Multi-decode fan-out: one prefill pool feeding two decode
    //    pools on different hardware; the controller routes each
    //    handoff to the pool with the most free KV memory.
    let fan = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 2).named("prefill"),
        StageConfig::new(StageKind::Decode, 2).named("decode-h100").on_gpu(GpuSpec::h100()),
        StageConfig::new(StageKind::Decode, 2).named("decode-a800"),
    ]);
    let cfg_fan = ExperimentConfig::from_stages(dense, fan).with_workload(workload(64));
    print_run("multi-decode fan-out (H100 + A800 pools)", &frontier::run_experiment(&cfg_fan)?);

    println!(
        "\nEvery deployment above is one stage graph walked by the same\n\
         controller; the CLI forms are `--stages \"prefill:2,tp=2;af,attn=4,ffn=8,micro=2,epc=2\"`,\n\
         `--stages \"prefill:2@h200;decode:2@a800\"`, and\n\
         `--stages \"prefill:2;decode:2@h100;decode:2@a800\"`."
    );
    Ok(())
}
