//! Cluster dynamics study: a seeded decode outage at the traffic-day
//! peak, with and without an autoscaler replacing the lost capacity.
//!
//! Reproduces the headline scenario of the fault-injection axis: a
//! traffic day runs over a PD deployment, 10% of the decode pool dies
//! right at the diurnal peak, and the report answers (a) how much SLO
//! damage the displaced requests absorb (their KV is gone, so they pay
//! a full re-prefill), (b) how long the fleet takes to recover the SLO
//! (windowed attainment from the built-in time series), and (c) what
//! changes when an autoscaler is allowed to provision replacements.
//! A whole-pool outage follows, where the autoscaler's dead-pool
//! replacement path makes the difference stark. The faulted schedule
//! is part of the scenario seed, so every row is byte-identical for
//! any `--sim-threads` — checked at the end.
//!
//! ```bash
//! cargo run --release --example cluster_dynamics
//! ```

use frontier::cluster::dynamics::{AutoscaleSpec, FaultSpec, ScalePolicy};
use frontier::config::ExperimentConfig;
use frontier::metrics::{SimReport, SloSpec, TsBucket};
use frontier::model::ModelConfig;
use frontier::report::markdown_table;
use frontier::workload::WorkloadSpec;

const RATE: f64 = 30.0; // mean req/s over the day
const N_REQUESTS: u32 = 1200; // one day = N/RATE = 40 s period
const PEAK_S: f64 = 10.0; // diurnal sin peaks at period/4
const MTTR_S: f64 = 30.0;

fn base() -> ExperimentConfig {
    ExperimentConfig::pd(ModelConfig::tiny(), 3, 10)
        .with_workload(WorkloadSpec::traffic_day(RATE, N_REQUESTS))
        .with_slo(SloSpec { ttft_s: Some(2.0), tbt_s: Some(0.05), e2e_s: None })
        .with_seed(42)
}

fn autoscale() -> AutoscaleSpec {
    let mut a = AutoscaleSpec::new(ScalePolicy::Reactive, 8, 12);
    a.interval_s = 1.0;
    a.provision_s = 5.0;
    a.warmup_s = 1.0;
    a.up_queue = 0.5;
    a.down_queue = 0.1;
    a
}

/// Windowed time-to-SLO-recovery: seconds from the fault until the
/// per-bucket SLO attainment climbs back over 95% after its first
/// post-fault dip (0 when attainment never dipped; inf when it never
/// comes back).
fn slo_recovery_s(rep: &SimReport, fault_t: f64) -> f64 {
    let ts = &rep.metrics.timeseries;
    let healthy =
        |b: &TsBucket| b.completions == 0 || b.slo_ok as f64 >= 0.95 * b.completions as f64;
    let start = (fault_t / ts.bucket_s) as usize;
    let mut dipped = false;
    for (i, b) in ts.buckets.iter().enumerate().skip(start) {
        if !dipped && !healthy(b) {
            dipped = true;
        } else if dipped && healthy(b) {
            return i as f64 * ts.bucket_s - fault_t;
        }
    }
    if dipped {
        f64::INFINITY
    } else {
        0.0
    }
}

fn row(label: &str, fault_t: f64, rep: &SimReport) -> Vec<String> {
    let m = &rep.metrics;
    let rec = slo_recovery_s(rep, fault_t);
    vec![
        label.to_string(),
        format!("{:.3}%", rep.availability() * 100.0),
        m.fault_requeues.to_string(),
        format!("{:.1}", m.ttr.quantile(50.0)),
        if rec.is_finite() { format!("{rec:.0}") } else { "never".into() },
        format!("{}/{}", m.fault_affected_slo_miss, m.fault_affected_completed),
        format!("{}", m.scale_up_events + m.scale_down_events),
        format!("{:.2}", rep.goodput()),
    ]
}

const HEADERS: [&str; 8] = [
    "scenario",
    "availability",
    "requeues",
    "TTR p50 (s)",
    "SLO recovery (s)",
    "SLO miss (affected)",
    "scale events",
    "goodput (req/s)",
];

fn main() -> anyhow::Result<()> {
    println!("== Traffic day, 10% decode loss at the peak (t = {PEAK_S} s) ==\n");
    // stage 1 is the decode pool (10 replicas); losing replica 0 at the
    // peak is the 10% loss, repaired MTTR seconds later
    let ten_pct = FaultSpec::parse(&format!(
        "list:down@{PEAK_S}:1.0;up@{}:1.0",
        PEAK_S + MTTR_S
    ))?;
    let baseline = frontier::run_experiment(&base())?;
    let faulted = frontier::run_experiment(&base().with_faults(ten_pct.clone()))?;
    let scaled = frontier::run_experiment(
        &base().with_faults(ten_pct.clone()).with_autoscale(autoscale()),
    )?;
    let rows = vec![
        row("no fault", PEAK_S, &baseline),
        row("10% loss", PEAK_S, &faulted),
        row("10% loss + autoscale", PEAK_S, &scaled),
    ];
    println!("{}", markdown_table(&HEADERS, &rows));

    println!("\n== Whole decode pool outage (dead-pool replacement) ==\n");
    // every decode replica dies at the peak: without an autoscaler the
    // fleet can only wait out the repair; with one, the dead-pool check
    // provisions replacements after one control interval
    let pool = FaultSpec::parse(&format!(
        "list:down@{PEAK_S}:1;up@{}:1",
        PEAK_S + MTTR_S
    ))?;
    let faulted = frontier::run_experiment(&base().with_faults(pool.clone()))?;
    let scaled = frontier::run_experiment(
        &base().with_faults(pool.clone()).with_autoscale(autoscale()),
    )?;
    let rows = vec![
        row("pool outage", PEAK_S, &faulted),
        row("pool outage + autoscale", PEAK_S, &scaled),
    ];
    println!("{}", markdown_table(&HEADERS, &rows));

    // determinism: the faulted, autoscaled day renders byte-identical
    // reports for any engine thread count
    let cfg = base().with_faults(pool).with_autoscale(autoscale());
    let serial = frontier::run_experiment(&cfg.clone().with_sim_threads(1))?
        .to_json_deterministic()
        .to_string_pretty();
    for threads in [2u32, 4] {
        let par = frontier::run_experiment(&cfg.clone().with_sim_threads(threads))?
            .to_json_deterministic()
            .to_string_pretty();
        assert_eq!(serial, par, "report diverged at sim-threads={threads}");
    }
    println!("\nDeterminism: faulted + autoscaled report is byte-identical for");
    println!("sim-threads 1/2/4 ({} bytes of JSON).", serial.len());
    Ok(())
}
