//! PD disaggregation study: ratio sweep + backpressure dynamics.
//!
//! Explores the rate-matching question DistServe poses: how should a
//! fixed GPU budget split between prefill and decode stages, and what
//! happens when the decode stage's KV memory runs short (the §3.3
//! backpressure workflow)?
//!
//! ```bash
//! cargo run --release --example pd_disagg
//! ```

use frontier::config::{ExperimentConfig, PolicyConfig};
use frontier::metrics::SloSpec;
use frontier::model::ModelConfig;
use frontier::report::markdown_table;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn workload(n: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Poisson { rate: 8.0 },
        input: LenDist::LogNormal { mean: 768.0, sigma: 0.7 },
        output: LenDist::LogNormal { mean: 128.0, sigma: 0.4 },
        n_requests: n,
        seed: 42,
        classes: vec![],
        trace: None,
    }
}

fn main() -> anyhow::Result<()> {
    let total_gpus = 8u32;
    println!("== PD ratio sweep: Qwen2-7B, {total_gpus} GPUs, 8 req/s ==\n");
    let mut rows = Vec::new();
    for prefill in 1..total_gpus {
        let decode = total_gpus - prefill;
        let cfg = ExperimentConfig::pd(ModelConfig::qwen2_7b(), prefill, decode)
            .with_workload(workload(160))
            // goodput = completions meeting TTFT <= 1 s and TBT <= 100 ms
            .with_slo(SloSpec { ttft_s: Some(1.0), tbt_s: Some(0.1), e2e_s: None });
        let r = frontier::run_experiment(&cfg)?;
        rows.push(vec![
            format!("{prefill}:{decode}"),
            format!("{:.1}", r.tokens_per_sec_per_gpu()),
            format!("{:.0}", r.metrics.ttft.quantile(99.0) * 1e3),
            format!("{:.1}", r.metrics.tbt.quantile(99.0) * 1e3),
            format!("{:.2}", r.goodput()),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["P:D", "tok/s/gpu", "TTFT p99 (ms)", "TBT p99 (ms)", "goodput (req/s)"],
            &rows
        )
    );

    println!("\n== Decode memory backpressure: shrinking the KV pool ==\n");
    let mut rows = Vec::new();
    for reserve in [0.10, 0.80, 0.95, 0.99] {
        let mut cfg = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4)
            .with_workload(workload(120));
        cfg.policy = PolicyConfig { kv_reserve_frac: reserve, ..PolicyConfig::default() };
        let r = frontier::run_experiment(&cfg)?;
        rows.push(vec![
            format!("{:.0}%", (1.0 - reserve) * 100.0),
            format!("{:.1}", r.tokens_per_sec_per_gpu()),
            format!("{:.0}", r.metrics.ttft.quantile(99.0) * 1e3),
            format!("{}", r.metrics.kv_transfers),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["KV pool", "tok/s/gpu", "TTFT p99 (ms)", "kv transfers"],
            &rows
        )
    );
    println!(
        "\nWith a starved KV pool the controller holds PREFILL_COMPLETE requests\n\
         until decode memory frees (pull-based transfers) — throughput degrades\n\
         gracefully instead of OOMing, and TTFT tail absorbs the queueing."
    );
    Ok(())
}
