//! Link fault study: a 60% WAN brownout at the traffic-day peak, on a
//! MoE deployment whose expert tier spans two clusters.
//!
//! Reproduces the headline scenario of the link-fault axis: a traffic
//! day runs over a PD deployment whose decode pool is an EP domain
//! stretched across the WAN trunk, and right at the diurnal peak the
//! trunk browns out to 40% of nominal bandwidth for 20 seconds. Every
//! expert dispatch/combine in that window prices through the degraded
//! trunk, so token latency climbs exactly when load does. The report
//! answers (a) how much SLO damage the brownout inflicts, (b) how long
//! the fleet takes to recover the SLO (windowed attainment from the
//! built-in time series), and (c) whether threshold-triggered expert
//! migration — whose re-placement traffic must itself cross the
//! degraded trunk — claws any of it back. The fabric-epoch plan is
//! part of the scenario seed, so every row is byte-identical for any
//! `--sim-threads` — checked at the end.
//!
//! ```bash
//! cargo run --release --example link_faults
//! ```

use frontier::cluster::dynamics::LinkFaultSpec;
use frontier::cluster::StageKind;
use frontier::config::{ExperimentConfig, StageConfig, StageGraphConfig};
use frontier::metrics::{SimReport, SloSpec, TsBucket};
use frontier::model::ModelConfig;
use frontier::parallelism::Parallelism;
use frontier::report::markdown_table;
use frontier::workload::WorkloadSpec;

const RATE: f64 = 30.0; // mean req/s over the day
const N_REQUESTS: u32 = 1200; // one day = N/RATE = 40 s period
const PEAK_S: f64 = 10.0; // diurnal sin peaks at period/4
const BROWNOUT_S: f64 = 20.0;
const BW_FRAC: f64 = 0.4; // 60% brownout: 40% of nominal kept

fn base() -> ExperimentConfig {
    // prefill feeds an EP-parallel decode pool whose 4 expert ranks
    // are split across two clusters: dispatch/combine ride the WAN
    let mut graph = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 2),
        StageConfig::new(StageKind::Decode, 2).with_parallelism(Parallelism::new(1, 1, 4)),
    ]);
    graph.stages[1].ep_clusters = Some(2);
    ExperimentConfig::from_stages(ModelConfig::tiny_moe(), graph)
        .with_workload(WorkloadSpec::traffic_day(RATE, N_REQUESTS))
        .with_slo(SloSpec { ttft_s: Some(2.0), tbt_s: Some(0.05), e2e_s: None })
        .with_seed(42)
}

fn brownout() -> LinkFaultSpec {
    LinkFaultSpec::parse(&format!(
        "list:degrade@{PEAK_S}:wan:{BW_FRAC};up@{}:wan",
        PEAK_S + BROWNOUT_S
    ))
    .expect("static schedule")
}

/// Windowed time-to-SLO-recovery: seconds from the brownout until the
/// per-bucket SLO attainment climbs back over 95% after its first
/// post-fault dip (0 when attainment never dipped; inf when it never
/// comes back).
fn slo_recovery_s(rep: &SimReport, fault_t: f64) -> f64 {
    let ts = &rep.metrics.timeseries;
    let healthy =
        |b: &TsBucket| b.completions == 0 || b.slo_ok as f64 >= 0.95 * b.completions as f64;
    let start = (fault_t / ts.bucket_s) as usize;
    let mut dipped = false;
    for (i, b) in ts.buckets.iter().enumerate().skip(start) {
        if !dipped && !healthy(b) {
            dipped = true;
        } else if dipped && healthy(b) {
            return i as f64 * ts.bucket_s - fault_t;
        }
    }
    if dipped {
        f64::INFINITY
    } else {
        0.0
    }
}

fn row(label: &str, rep: &SimReport) -> Vec<String> {
    let m = &rep.metrics;
    let rec = slo_recovery_s(rep, PEAK_S);
    vec![
        label.to_string(),
        format!("{:.1}", m.link_degraded_s[2]),
        format!("{:.1}", m.tbt.quantile(99.0) * 1e3),
        format!("{:.0}", m.ttft.quantile(99.0) * 1e3),
        if rec.is_finite() { format!("{rec:.0}") } else { "never".into() },
        format!("{:.1}%", rep.slo_attainment() * 100.0),
        m.migrations.to_string(),
        format!("{:.2}", rep.goodput()),
    ]
}

const HEADERS: [&str; 8] = [
    "scenario",
    "wan degraded (s)",
    "TBT p99 (ms)",
    "TTFT p99 (ms)",
    "SLO recovery (s)",
    "SLO attainment",
    "migrations",
    "goodput (req/s)",
];

fn main() -> anyhow::Result<()> {
    println!(
        "== Traffic day, {}% WAN brownout at the peak (t = {PEAK_S} s, {BROWNOUT_S} s) ==\n",
        ((1.0 - BW_FRAC) * 100.0) as u32
    );
    let baseline = frontier::run_experiment(&base())?;
    let browned = frontier::run_experiment(&base().with_link_faults(brownout()))?;
    let migrating = frontier::run_experiment(
        &base().with_link_faults(brownout()).with_migration(0.05, 64),
    )?;
    let rows = vec![
        row("no fault", &baseline),
        row("brownout", &browned),
        row("brownout + migration", &migrating),
    ];
    println!("{}", markdown_table(&HEADERS, &rows));
    println!(
        "\nThe brownout prices every EP dispatch/combine through the degraded\n\
         trunk for {BROWNOUT_S} s at the diurnal peak; expert migration pays the\n\
         same degraded trunk for its re-placement traffic."
    );

    // determinism: the link-faulted, migrating day renders
    // byte-identical reports for any engine thread count (fabric
    // epochs clamp every sync window to one capacity regime)
    let cfg = base().with_link_faults(brownout()).with_migration(0.05, 64);
    let serial = frontier::run_experiment(&cfg.clone().with_sim_threads(1))?
        .to_json_deterministic()
        .to_string_pretty();
    for threads in [2u32, 4] {
        let par = frontier::run_experiment(&cfg.clone().with_sim_threads(threads))?
            .to_json_deterministic()
            .to_string_pretty();
        assert_eq!(serial, par, "report diverged at sim-threads={threads}");
    }
    println!("\nDeterminism: link-faulted report is byte-identical for");
    println!("sim-threads 1/2/4 ({} bytes of JSON).", serial.len());
    Ok(())
}
