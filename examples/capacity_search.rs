//! Capacity search: the paper's motivating use case.
//!
//! §1: finding the optimal serving configuration for a dense model on a
//! 16-GPU co-located cluster cost ~18,000 GPU-hours (~$93k) of
//! trial-and-error. Frontier explores the same configuration space in
//! simulation: deployment mode x parallelism x batch cap, extracting
//! the throughput/latency Pareto frontier in seconds.
//!
//! The space is derived (replica counts follow from the tp degree), so
//! it runs as an *explicit point list* through the parallel sweep
//! engine — all configurations fan across worker threads, and a point
//! that fails validation reports its error without aborting the search.
//!
//! ```bash
//! cargo run --release --example capacity_search
//! ```

use frontier::config::cli::FlagMap;
use frontier::metrics::pareto_frontier;
use frontier::report::markdown_table;
use frontier::sweep::{PointSpec, SweepRunner, SweepSpec};

fn main() -> anyhow::Result<()> {
    let gpus = 16u32;
    let mut base = FlagMap::new();
    base.set("model", "qwen2-72b");
    base.set("rate", "3.0");
    base.set("requests", "120");
    base.set("input", "1024");
    base.set("output", "256");
    println!("== Capacity search: qwen2-72b on {gpus} GPUs ==\n");

    // configuration space: mode x tensor-parallel degree x batch cap,
    // with replica counts derived from the tp degree
    let mut points = Vec::new();
    for tp in [2u32, 4, 8] {
        let replicas = gpus / tp;
        for mode in ["colocated", "pd"] {
            if mode == "pd" && replicas / 2 == 0 {
                continue;
            }
            for max_batch in [8u32, 32, 128] {
                let mut assigns = vec![("tp".to_string(), tp.to_string())];
                if mode == "pd" {
                    let prefill = replicas / 2;
                    assigns.push((
                        "pd-ratio".into(),
                        format!("{prefill}:{}", replicas - prefill),
                    ));
                } else {
                    assigns.push(("mode".into(), "colocated".into()));
                    assigns.push(("replicas".into(), replicas.to_string()));
                }
                assigns.push(("max-batch".into(), max_batch.to_string()));
                points.push(
                    PointSpec::new(assigns).with_label(format!("{mode} tp{tp} b{max_batch}")),
                );
            }
        }
    }

    let result = SweepRunner::default().run(&SweepSpec::new(base).with_points(points))?;

    let mut pareto_points = Vec::new();
    let mut rows = Vec::new();
    for pr in &result.points {
        let label = pr.point.label.clone();
        match &pr.outcome {
            Ok(r) => {
                let thr = r.tokens_per_sec_per_gpu();
                let lat = r.metrics.tbt.quantile(99.0) * 1e3;
                rows.push(vec![
                    label.clone(),
                    format!("{thr:.1}"),
                    format!("{lat:.1}"),
                    format!("{:.0}", r.metrics.ttft.quantile(99.0) * 1e3),
                ]);
                pareto_points.push((thr, lat, label));
            }
            Err(e) => {
                rows.push(vec![label, format!("error: {e}"), "-".into(), "-".into()]);
            }
        }
    }
    println!(
        "{}",
        markdown_table(&["config", "tok/s/gpu", "TBT p99 (ms)", "TTFT p99 (ms)"], &rows)
    );

    println!("\n== Pareto frontier (maximize throughput, minimize TBT p99) ==\n");
    let front = pareto_frontier(&pareto_points);
    let rows: Vec<Vec<String>> = front
        .iter()
        .map(|(thr, lat, label)| {
            vec![label.clone(), format!("{thr:.1}"), format!("{lat:.1}")]
        })
        .collect();
    println!("{}", markdown_table(&["config", "tok/s/gpu", "TBT p99 (ms)"], &rows));
    println!(
        "\n{} configurations explored in simulation; the paper quotes ~18,000\n\
         GPU-hours (>$93k) to do this on hardware for one 72B/16-GPU setting.",
        pareto_points.len()
    );
    Ok(())
}
