//! Capacity search: the paper's motivating use case.
//!
//! §1: finding the optimal serving configuration for a dense model on a
//! 16-GPU co-located cluster cost ~18,000 GPU-hours (~$93k) of
//! trial-and-error. Frontier explores the same configuration space in
//! simulation: deployment mode x parallelism x batch cap, extracting
//! the throughput/latency Pareto frontier in seconds.
//!
//! ```bash
//! cargo run --release --example capacity_search
//! ```

use frontier::config::{DeploymentMode, ExperimentConfig};
use frontier::metrics::{pareto_frontier, percentile};
use frontier::model::ModelConfig;
use frontier::parallelism::Parallelism;
use frontier::report::markdown_table;
use frontier::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let gpus = 16u32;
    let model = ModelConfig::qwen2_72b();
    let workload = WorkloadSpec::poisson(3.0, 120, 1024, 256);
    println!("== Capacity search: {} on {gpus} GPUs ==\n", model.name);

    let mut points = Vec::new();
    let mut rows = Vec::new();
    // configuration space: mode x tensor-parallel degree x batch cap
    for tp in [2u32, 4, 8] {
        let replicas = gpus / tp;
        for (mode_name, mode) in [
            ("colocated", DeploymentMode::Colocated { replicas }),
            (
                "pd",
                DeploymentMode::PdDisagg {
                    prefill_replicas: replicas / 2,
                    decode_replicas: replicas - replicas / 2,
                },
            ),
        ] {
            if matches!(mode, DeploymentMode::PdDisagg { prefill_replicas, .. } if prefill_replicas == 0)
            {
                continue;
            }
            for max_batch in [8usize, 32, 128] {
                let mut cfg = ExperimentConfig::colocated(model.clone(), replicas)
                    .with_workload(workload.clone())
                    .with_parallelism(Parallelism::tp(tp));
                cfg.mode = mode.clone();
                cfg.policy.budget.max_batch = max_batch;
                let label = format!("{mode_name} tp{tp} b{max_batch}");
                match frontier::run_experiment(&cfg) {
                    Ok(r) => {
                        let thr = r.tokens_per_sec_per_gpu();
                        let lat = percentile(&r.metrics.tbt, 99.0) * 1e3;
                        rows.push(vec![
                            label.clone(),
                            format!("{thr:.1}"),
                            format!("{lat:.1}"),
                            format!("{:.0}", percentile(&r.metrics.ttft, 99.0) * 1e3),
                        ]);
                        points.push((thr, lat, label));
                    }
                    Err(e) => {
                        rows.push(vec![label, format!("error: {e}"), "-".into(), "-".into()]);
                    }
                }
            }
        }
    }
    println!(
        "{}",
        markdown_table(&["config", "tok/s/gpu", "TBT p99 (ms)", "TTFT p99 (ms)"], &rows)
    );

    println!("\n== Pareto frontier (maximize throughput, minimize TBT p99) ==\n");
    let front = pareto_frontier(&points);
    let rows: Vec<Vec<String>> = front
        .iter()
        .map(|(thr, lat, label)| {
            vec![label.clone(), format!("{thr:.1}"), format!("{lat:.1}")]
        })
        .collect();
    println!("{}", markdown_table(&["config", "tok/s/gpu", "TBT p99 (ms)"], &rows));
    println!(
        "\n{} configurations explored in simulation; the paper quotes ~18,000\n\
         GPU-hours (>$93k) to do this on hardware for one 72B/16-GPU setting.",
        points.len()
    );
    Ok(())
}
