//! Capacity search: the paper's motivating use case, as a real
//! optimizer.
//!
//! §1: finding the optimal serving configuration for one model on a
//! 16-GPU cluster cost ~18,000 GPU-hours (~$93k) of trial-and-error.
//! Earlier revisions of this example brute-forced a small grid; it now
//! drives the `search` autotuner over a 240-point MoE deployment space
//! (PD ratio x EP cluster span x capacity factor x migration policy x
//! migration threshold) under the diurnal traffic-day workload, and
//! lets the three pruning layers do the work:
//!
//! * successive halving simulates most of the grid only at a short
//!   horizon, promoting the top quarter per rung;
//! * config-hash dedup collapses the `migration-threshold` axis
//!   wherever `migration=off` makes it inert;
//! * Pareto pruning drops (cost, goodput, p99)-dominated regions
//!   between rungs.
//!
//! The search trajectory (rung populations, prune counts, dedup hits)
//! prints alongside the final ranking — the same document `frontier
//! search` emits.
//!
//! ```bash
//! cargo run --release --example capacity_search
//! ```

use frontier::config::cli::FlagMap;
use frontier::report::search::search_markdown;
use frontier::search::{Objective, SearchRunner, SearchSpec};
use frontier::sweep::{Axis, SweepSpec};

fn main() -> anyhow::Result<()> {
    let mut base = FlagMap::new();
    base.set("model", "mixtral-8x7b");
    base.set("ep", "2");
    base.set("workload", "day:6.0");
    base.set("requests", "192");
    base.set("slo-ttft", "2000");
    base.set("slo-tbt", "200");
    base.set("seed", "7");

    let axes = vec![
        Axis::new("pd-ratio", vec!["1:3".into(), "2:2".into(), "3:1".into()])?,
        Axis::new("ep-clusters", vec!["1".into(), "2".into()])?,
        Axis::new(
            "capacity-factor",
            vec!["1.0".into(), "1.25".into(), "1.5".into(), "2.0".into()],
        )?,
        Axis::new("migration", vec!["off".into(), "threshold".into()])?,
        Axis::new(
            "migration-threshold",
            vec![
                "1.05".into(),
                "1.1".into(),
                "1.2".into(),
                "1.3".into(),
                "1.4".into(),
            ],
        )?,
    ];
    let spec = SearchSpec {
        sweep: SweepSpec::new(base).with_axes(axes),
        objective: Objective::Cost,
        rungs: 3,
        promote_frac: 0.25,
    };

    println!("== Capacity search: mixtral-8x7b traffic day, 240-point deployment grid ==\n");
    let result = SearchRunner::default().run(&spec)?;
    print!("{}", search_markdown(&result));

    println!(
        "\n{} of {} grid points simulated ({} dedup hits); the paper quotes\n\
         ~18,000 GPU-hours (>$93k) to explore one such space on hardware.",
        result.searched_points(),
        result.grid_points,
        result.dedup_hits(),
    );
    if let Some(best) = result.ranked.first() {
        println!(
            "best by {}: {} at {:.2} GPU-s/1k tokens (goodput {:.2} req/s, TBT p99 {:.1} ms)",
            result.objective.name(),
            best.point.label,
            best.metrics.cost_gpu_s_per_1k,
            best.metrics.goodput_rps,
            best.metrics.tbt_p99_ms,
        );
    }
    Ok(())
}
