//! Bench: the autotuner over a 1000-point deployment grid — the scale
//! target of ROADMAP item 3 (a 1k–10k-point search completing on
//! CI-class hardware).
//!
//! Grid: pd-ratio(5) x ep-clusters(2) x capacity-factor(5) x
//! migration(off|threshold) x migration-threshold(10) = 1000 points of
//! tiny-moe under the diurnal traffic-day workload, searched with 3
//! successive-halving rungs at `--promote-frac 0.25` on the cost
//! objective. The bench exists to pin the *work avoided*:
//!
//! * `search_points_ratio` — unique simulations / grid size, a gated
//!   ceiling (brute force is 1.0; halving + Pareto pruning + dedup must
//!   keep it well below);
//! * `search_dedup_hits` — a gated floor: the 10-value
//!   `migration-threshold` axis is inert under `migration=off`, so
//!   hash-dedup must collapse 9 of its 10 values for half the grid on
//!   the first rung;
//! * the trajectory lands in the merged report (rung populations,
//!   prune counts, dedup hits) and the search completes with zero
//!   point errors.
//!
//! Emits `target/bench_results/BENCH_search.json`; the blessed copy at
//! the repo root arms the CI perf gate (`BENCH_BASELINE`). The ratio
//! and dedup metrics gate unconditionally; wall-clock only against a
//! calibrated baseline.

use frontier::bench_util::{
    gate_against_baseline, quick, section, write_results, BaselineCheck,
};
use frontier::config::cli::FlagMap;
use frontier::config::json::Json;
use frontier::search::{Objective, SearchRunner, SearchSpec};
use frontier::sweep::{Axis, SweepSpec};

fn main() {
    // quick mode shortens the horizon ladder, not the grid: the pruning
    // ratios being gated are horizon-independent
    let full: u32 = if quick() { 64 } else { 256 };
    let mut json: Vec<(&'static str, Json)> = Vec::new();
    let calibrated = std::env::var_os("BENCH_CALIBRATED").is_some_and(|v| v == "1");
    json.push(("calibrated", Json::Bool(calibrated)));
    json.push(("quick", Json::Bool(quick())));

    let mut base = FlagMap::new();
    base.set("model", "tiny-moe");
    base.set("replicas", "1");
    base.set("ep", "2");
    base.set("workload", "day:40.0");
    base.set("requests", full.to_string());
    base.set("seed", "3");
    let axes = vec![
        Axis::new(
            "pd-ratio",
            vec!["1:3".into(), "2:2".into(), "3:1".into(), "1:2".into(), "2:1".into()],
        )
        .unwrap(),
        Axis::new("ep-clusters", vec!["1".into(), "2".into()]).unwrap(),
        Axis::new(
            "capacity-factor",
            vec!["1.0".into(), "1.1".into(), "1.25".into(), "1.5".into(), "2.0".into()],
        )
        .unwrap(),
        Axis::new("migration", vec!["off".into(), "threshold".into()]).unwrap(),
        Axis::new(
            "migration-threshold",
            vec![
                "1.05".into(),
                "1.1".into(),
                "1.15".into(),
                "1.2".into(),
                "1.25".into(),
                "1.3".into(),
                "1.35".into(),
                "1.4".into(),
                "1.45".into(),
                "1.5".into(),
            ],
        )
        .unwrap(),
    ];
    let spec = SearchSpec {
        sweep: SweepSpec::new(base).with_axes(axes),
        objective: Objective::Cost,
        rungs: 3,
        promote_frac: 0.25,
    };

    section(&format!("search: 1000-point grid, 3 rungs, full horizon {full} requests"));
    let t0 = std::time::Instant::now();
    let result = SearchRunner::default().run(&spec).unwrap();
    let wall = t0.elapsed().as_secs_f64();

    let searched = result.searched_points();
    let ratio = searched as f64 / result.grid_points as f64;
    let dedup = result.dedup_hits();
    for t in &result.trajectory {
        println!(
            "rung {} @ {:>4} req: population {:>4} | simulated {:>4} | dedup {:>4} \
             | pruned {:>3} | promoted {:>3}",
            t.rung, t.requests, t.population, t.simulated, t.dedup_hits, t.pruned, t.promoted
        );
    }
    println!(
        "searched {searched}/{} points (ratio {ratio:.3}) | {dedup} dedup hits | {wall:.2}s",
        result.grid_points
    );
    if let Some(best) = result.ranked.first() {
        println!("best: {} at {:.3} GPU-s/1k tokens", best.point.label, best.score);
    }

    // the acceptance bar: strictly cheaper than brute force, dedup
    // doing real work, a clean grid, and the trajectory in the report
    assert_eq!(result.grid_points, 1000, "grid drifted");
    assert!(searched < result.grid_points, "search did not beat brute force");
    assert!(dedup > 0, "config-hash dedup found nothing on an inert-axis grid");
    assert!(result.errors.is_empty(), "grid points failed: {:?}", result.errors.first());
    assert_eq!(result.trajectory.len(), 3);
    assert!(!result.ranked.is_empty());

    json.push(("search_grid_points", Json::Num(result.grid_points as f64)));
    json.push(("search_points_ratio", Json::Num(ratio)));
    json.push(("search_dedup_hits", Json::Num(dedup as f64)));
    json.push(("search_wall_s", Json::Num(wall)));

    let current = Json::obj(json);
    write_results("BENCH_search.json", &current.to_string_pretty());

    gate_against_baseline(
        &current,
        &[
            // scale drift alarm: the ratio gate is meaningless if the
            // bench silently runs a different grid
            BaselineCheck {
                key: "search_grid_points",
                higher_is_better: false,
                tol: 0.0,
                needs_calibration: false,
                two_sided: true,
            },
            // the tentpole's ceiling: fraction of the grid simulated
            BaselineCheck {
                key: "search_points_ratio",
                higher_is_better: false,
                tol: 0.0,
                needs_calibration: false,
                two_sided: false,
            },
            // the dedup floor
            BaselineCheck {
                key: "search_dedup_hits",
                higher_is_better: true,
                tol: 0.0,
                needs_calibration: false,
                two_sided: false,
            },
            // wall-clock: calibrated baselines only
            BaselineCheck {
                key: "search_wall_s",
                higher_is_better: false,
                tol: 0.5,
                needs_calibration: true,
                two_sided: false,
            },
        ],
    );
}
