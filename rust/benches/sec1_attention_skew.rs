//! Bench S1 (paper §1 anecdote): one FlashAttention operation on a
//! 72-request batch with skewed lengths. The paper reports Vidur at
//! 0.151 ms against a measured 0.340 ms (>55% error); this bench
//! reproduces the *shape* — Vidur severely underestimates, Frontier
//! lands close to ground truth.

use frontier::bench_util::section;
use frontier::operators::OpWorkload;
use frontier::predictor::{
    ExecutionPredictor, LearnedPredictor, OraclePredictor, RooflinePredictor, VidurPredictor,
};
use frontier::report::markdown_table;
use frontier::runtime::PredictorRuntime;

fn main() {
    // 72 decode requests: 71 short, one very long context — the regime
    // where the runtime is straggler-dominated and a mean-length proxy
    // collapses
    let mut ctx = vec![200u32; 71];
    ctx.push(32768);
    assert_eq!(ctx.len(), 72);
    let op = OpWorkload::Attention {
        is_prefill: false,
        q_lens: vec![1; 72],
        ctx_lens: ctx,
        n_heads: 28,
        n_kv_heads: 4,
        head_dim: 128,
    };

    let mut truth = OraclePredictor::a800();
    let t = truth.predict(&op);
    section("§1 anecdote: skewed 72-request decode attention batch");
    let mut rows = vec![vec![
        "ground truth (oracle)".to_string(),
        format!("{:.3}", t * 1e3),
        "-".to_string(),
    ]];
    let mut add = |name: &str, pred: &mut dyn ExecutionPredictor| {
        let p = pred.predict(&op);
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", p * 1e3),
            format!("{:+.1}%", (p / t - 1.0) * 100.0),
        ]);
        p
    };
    let v = add("vidur (sqrt proxy)", &mut VidurPredictor::a800());
    add("roofline", &mut RooflinePredictor::a800());
    let f = match LearnedPredictor::load_exact(&PredictorRuntime::default_dir()) {
        Ok(mut l) => Some(add("frontier (learned)", &mut l)),
        Err(e) => {
            println!("(learned predictor unavailable: {e})");
            None
        }
    };
    println!("{}", markdown_table(&["model", "predicted (ms)", "error"], &rows));
    println!(
        "paper: vidur 0.151 ms vs measured 0.340 ms (-55.6%); here vidur is {:+.1}%",
        (v / t - 1.0) * 100.0
    );
    assert!(v < 0.7 * t, "vidur must severely underestimate the skewed batch");
    if let Some(f) = f {
        assert!(
            (f - t).abs() / t < 0.15,
            "frontier must stay close to ground truth on the same batch"
        );
    }

    // the homogeneous control: both models fine
    section("control: homogeneous 72-request batch (same total kv)");
    let total: u64 = 71 * 200 + 32768;
    let hom = OpWorkload::Attention {
        is_prefill: false,
        q_lens: vec![1; 72],
        ctx_lens: vec![(total / 72) as u32; 72],
        n_heads: 28,
        n_kv_heads: 4,
        head_dim: 128,
    };
    let t_hom = truth.predict(&hom);
    let v_hom = VidurPredictor::a800().predict(&hom);
    println!(
        "oracle {:.3} ms | vidur {:.3} ms ({:+.1}%) — proxy models are fine when \
         batches are homogeneous; heterogeneity is what breaks them",
        t_hom * 1e3,
        v_hom * 1e3,
        (v_hom / t_hom - 1.0) * 100.0
    );
}
