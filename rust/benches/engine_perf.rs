//! Bench P1: simulator performance — events/second, sim-time/host-time
//! ratio, and predictor cache effectiveness. This is the §Perf target
//! surface for the L3 optimization pass (EXPERIMENTS.md §Perf).
//!
//! Emits `target/bench_results/BENCH_engine_perf.json` (blessed copy at
//! the repo root); with `BENCH_BASELINE` set it becomes the CI perf
//! gate: deterministic event/iteration counts are compared exactly-ish
//! (they only move when simulation *logic* changes — a deliberate
//! re-pin), wall-clock events/sec only against a calibrated baseline.

use frontier::bench_util::{
    bench, gate_against_baseline, quick, section, write_results, BaselineCheck,
};
use frontier::config::cli::FlagMap;
use frontier::config::json::Json;
use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::core::{EventQueue, SimTime};
use frontier::model::ModelConfig;
use frontier::predictor::PredictorKind;
use frontier::report::sweep::sweep_json;
use frontier::sweep::{Axis, SweepRunner, SweepSpec};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn big_workload(n: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Poisson { rate: 40.0 },
        input: LenDist::LogNormal { mean: 512.0, sigma: 0.7 },
        output: LenDist::LogNormal { mean: 96.0, sigma: 0.4 },
        n_requests: n,
        seed: 1,
        classes: vec![],
        trace: None,
    }
}

fn main() {
    // quick mode shrinks the workloads ~4x; the deterministic counts in
    // the JSON change with it, so the gate pins the quick-mode numbers
    let scale = if quick() { 4 } else { 1 };
    let mut json: Vec<(&'static str, Json)> = Vec::new();
    let calibrated = std::env::var_os("BENCH_CALIBRATED").is_some_and(|v| v == "1");
    json.push(("calibrated", Json::Bool(calibrated)));
    json.push(("quick", Json::Bool(quick())));

    section("raw event queue throughput");
    let q_events = 100_000u64 / scale as u64;
    let r_queue = bench("schedule+pop event queue", || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..q_events {
            q.schedule_at(SimTime(i * 7 % 1_000_000), i);
        }
        while q.pop().is_some() {}
    });
    json.push((
        "queue_events_per_s",
        Json::Num(q_events as f64 / r_queue.mean.as_secs_f64().max(1e-12)),
    ));

    section("end-to-end simulation throughput (oracle predictor)");
    for (name, key_evps, key_events, key_iters, cfg) in [
        (
            "colocated qwen2-7b x4",
            "colocated_events_per_s",
            "colocated_events",
            "colocated_iterations",
            ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 4)
                .with_workload(big_workload(400 / scale)),
        ),
        (
            "pd 4:4 qwen2-7b",
            "pd_events_per_s",
            "pd_events",
            "pd_iterations",
            ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4)
                .with_workload(big_workload(400 / scale)),
        ),
        (
            "colocated mixtral ep8",
            "moe_ep8_events_per_s",
            "moe_ep8_events",
            "moe_ep8_iterations",
            ExperimentConfig::colocated(ModelConfig::mixtral_8x7b(), 1)
                .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 8))
                .with_workload(big_workload(200 / scale)),
        ),
    ] {
        let r = frontier::run_experiment(&cfg).unwrap();
        println!(
            "{name}: {} events in {:.3}s host = {:.0} ev/s | sim/host = {:.0}x | {} iters",
            r.events_processed,
            r.host_duration,
            r.events_per_sec(),
            r.speedup(),
            r.metrics.iterations,
        );
        let b = bench(&format!("simulate: {name}"), || {
            std::hint::black_box(frontier::run_experiment(&cfg).unwrap().sim_duration);
        });
        json.push((
            key_evps,
            Json::Num(r.events_processed as f64 / b.mean.as_secs_f64().max(1e-12)),
        ));
        json.push((key_events, Json::Num(r.events_processed as f64)));
        json.push((key_iters, Json::Num(r.metrics.iterations as f64)));
    }

    section("predictor cost inside the loop");
    let cfg = ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 2)
        .with_workload(big_workload(150 / scale.min(2)));
    bench("full sim, oracle predictor", || {
        std::hint::black_box(frontier::run_experiment(&cfg).unwrap().sim_duration);
    });
    let cfg_v = cfg.clone().with_predictor(PredictorKind::Vidur);
    bench("full sim, vidur predictor", || {
        std::hint::black_box(frontier::run_experiment(&cfg_v).unwrap().sim_duration);
    });
    if frontier::runtime::PredictorRuntime::default_dir().join("manifest.json").exists() {
        let cfg_l = cfg.clone().with_predictor(PredictorKind::Learned);
        let t0 = std::time::Instant::now();
        let cold = frontier::run_experiment(&cfg_l).unwrap();
        println!(
            "full sim, learned predictor COLD: {:?} ({} PJRT launches, incl. ~100ms artifact compile)",
            t0.elapsed(),
            cold.metrics.predictor_evals
        );
        bench("full sim, learned predictor WARM (shared cache)", || {
            std::hint::black_box(frontier::run_experiment(&cfg_l).unwrap().sim_duration);
        });
    }

    section("zero-overhead config (engine floor)");
    let fast = ExperimentConfig::colocated(ModelConfig::tiny(), 8)
        .with_workload(big_workload(1000 / scale))
        .with_overhead(OverheadConfig::zero());
    let r = frontier::run_experiment(&fast).unwrap();
    println!(
        "tiny x8, {} reqs: {:.0} ev/s, {} events",
        1000 / scale,
        r.events_per_sec(),
        r.events_processed
    );
    json.push(("floor_events_per_s", Json::Num(r.events_per_sec())));

    section("parallel sweep scaling (16-point grid, SweepRunner)");
    // a fixed 16-point seed grid over a mid-size colocated deployment:
    // heavy enough per point that thread spawn cost is noise, small
    // enough that quick mode stays CI-friendly
    let mut sweep_base = FlagMap::new();
    sweep_base.set("model", "qwen2-7b");
    sweep_base.set("replicas", "2");
    sweep_base.set("requests", if quick() { "48" } else { "192" });
    sweep_base.set("input", "256");
    sweep_base.set("output", "64");
    let seeds: Vec<String> = (1..=16u64).map(|s| s.to_string()).collect();
    let grid_points = seeds.len();
    let sweep_spec =
        SweepSpec::new(sweep_base).with_axes(vec![Axis::new("seed", seeds).expect("seed axis")]);
    // determinism first: the merged JSON must not depend on thread count,
    // or the timing comparison below compares different work
    let r1 = SweepRunner::with_threads(1).run(&sweep_spec).unwrap();
    let r4 = SweepRunner::with_threads(4).run(&sweep_spec).unwrap();
    assert!(r1.points.iter().all(|p| p.outcome.is_ok()), "grid points must run clean");
    assert_eq!(
        sweep_json(&r1).to_string_pretty(),
        sweep_json(&r4).to_string_pretty(),
        "merged sweep report must be byte-identical across thread counts"
    );
    let serial = bench("sweep 16 points, 1 thread", || {
        std::hint::black_box(SweepRunner::with_threads(1).run(&sweep_spec).unwrap().points.len());
    });
    let par4 = bench("sweep 16 points, 4 threads", || {
        std::hint::black_box(SweepRunner::with_threads(4).run(&sweep_spec).unwrap().points.len());
    });
    let sweep_speedup = serial.mean.as_secs_f64() / par4.mean.as_secs_f64().max(1e-12);
    println!("sweep scaling: {sweep_speedup:.2}x with 4 threads");
    json.push(("sweep_grid_points", Json::Num(grid_points as f64)));
    json.push(("sweep_serial_s", Json::Num(serial.mean.as_secs_f64())));
    json.push(("sweep_4t_s", Json::Num(par4.mean.as_secs_f64())));
    json.push(("sweep_speedup_4t", Json::Num(sweep_speedup)));

    section("single-run parallel engine (--sim-threads, 5-shard graph)");
    // one prefill pool fanning out to four cross-cluster decode pools:
    // five stage shards, decode work spread across four of them — the
    // shape the windowed engine is built for. Long fixed-input prefills
    // keep the sync window wide (the cheapest kv edge sizes it).
    let mk_single = |threads: u32| {
        let mut f = FlagMap::new();
        f.set("model", "qwen2-7b");
        f.set(
            "stages",
            "prefill:4;decode:2,cluster=1;decode:2,cluster=1;decode:2,cluster=1;decode:2,cluster=1",
        );
        f.set("edges", "0>1,0>2,0>3,0>4");
        f.set("requests", if quick() { "160" } else { "600" });
        f.set("input", "512");
        f.set("output", "64");
        f.set("sim-threads", threads.to_string());
        frontier::config::cli::build_config(&f).unwrap()
    };
    // determinism first: the 4-thread run must be byte-identical to the
    // serial run, or the timing below compares different simulations
    let rep1 = frontier::run_experiment(&mk_single(1)).unwrap();
    let rep4 = frontier::run_experiment(&mk_single(4)).unwrap();
    assert_eq!(
        rep1.to_json_deterministic().to_string_pretty(),
        rep4.to_json_deterministic().to_string_pretty(),
        "single-run report must be byte-identical across sim-thread counts"
    );
    let cfg1 = mk_single(1);
    let single_serial = bench("single run, sim-threads 1", || {
        std::hint::black_box(frontier::run_experiment(&cfg1).unwrap().sim_duration);
    });
    let cfg4 = mk_single(4);
    let single_4t = bench("single run, sim-threads 4", || {
        std::hint::black_box(frontier::run_experiment(&cfg4).unwrap().sim_duration);
    });
    let single_speedup =
        single_serial.mean.as_secs_f64() / single_4t.mean.as_secs_f64().max(1e-12);
    println!("single-run scaling: {single_speedup:.2}x with 4 engine threads");
    json.push(("single_run_serial_s", Json::Num(single_serial.mean.as_secs_f64())));
    json.push(("single_run_4t_s", Json::Num(single_4t.mean.as_secs_f64())));
    json.push(("single_run_speedup_4t", Json::Num(single_speedup)));

    let current = Json::obj(json);
    write_results("BENCH_engine_perf.json", &current.to_string_pretty());

    // CI perf gate: wall-clock throughput only against a calibrated
    // baseline (20% band). The deterministic event counts double as a
    // drift alarm with a tight band — they move only when simulation
    // logic changes, which is a deliberate baseline re-pin.
    gate_against_baseline(
        &current,
        &[
            BaselineCheck {
                key: "colocated_events_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "pd_events_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "moe_ep8_events_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "queue_events_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "moe_ep8_events",
                higher_is_better: false,
                tol: 0.01,
                needs_calibration: false,
                two_sided: true,
            },
            BaselineCheck {
                key: "moe_ep8_iterations",
                higher_is_better: false,
                tol: 0.01,
                needs_calibration: false,
                two_sided: true,
            },
            // sweep-engine scaling: the 4-thread/serial wall-clock
            // *ratio* is hardware-class-stable on the >= 4-core CI
            // runners, so it gates unconditionally — baseline 2.5 with
            // a 20% band enforces the >= 2.0x floor
            BaselineCheck {
                key: "sweep_speedup_4t",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: false,
                two_sided: false,
            },
            BaselineCheck {
                key: "sweep_grid_points",
                higher_is_better: false,
                tol: 0.0,
                needs_calibration: false,
                two_sided: true,
            },
            // single-run engine scaling: like sweep_speedup_4t this is a
            // wall-clock *ratio*, stable across hardware classes, so it
            // gates unconditionally — baseline 2.25 with the 20% band
            // enforces the >= 1.8x floor on the 5-shard graph
            BaselineCheck {
                key: "single_run_speedup_4t",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: false,
                two_sided: false,
            },
        ],
    );
}
