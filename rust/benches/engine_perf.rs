//! Bench P1: simulator performance — events/second, sim-time/host-time
//! ratio, and predictor cache effectiveness. This is the §Perf target
//! surface for the L3 optimization pass (EXPERIMENTS.md §Perf).

use frontier::bench_util::{bench, section};
use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::core::{EventQueue, SimTime};
use frontier::model::ModelConfig;
use frontier::predictor::PredictorKind;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn big_workload(n: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Poisson { rate: 40.0 },
        input: LenDist::LogNormal { mean: 512.0, sigma: 0.7 },
        output: LenDist::LogNormal { mean: 96.0, sigma: 0.4 },
        n_requests: n,
        seed: 1,
    }
}

fn main() {
    section("raw event queue throughput");
    bench("schedule+pop 100k events", || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..100_000u64 {
            q.schedule_at(SimTime(i * 7 % 1_000_000), i);
        }
        while q.pop().is_some() {}
    });

    section("end-to-end simulation throughput (oracle predictor)");
    for (name, cfg) in [
        (
            "colocated qwen2-7b x4, 400 reqs",
            ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 4)
                .with_workload(big_workload(400)),
        ),
        (
            "pd 4:4 qwen2-7b, 400 reqs",
            ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4).with_workload(big_workload(400)),
        ),
        (
            "colocated mixtral ep8, 200 reqs",
            ExperimentConfig::colocated(ModelConfig::mixtral_8x7b(), 1)
                .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 8))
                .with_workload(big_workload(200)),
        ),
    ] {
        let r = frontier::run_experiment(&cfg).unwrap();
        println!(
            "{name}: {} events in {:.3}s host = {:.0} ev/s | sim/host = {:.0}x | {} iters",
            r.events_processed,
            r.host_duration,
            r.events_per_sec(),
            r.speedup(),
            r.metrics.iterations,
        );
        bench(&format!("simulate: {name}"), || {
            std::hint::black_box(frontier::run_experiment(&cfg).unwrap().sim_duration);
        });
    }

    section("predictor cost inside the loop");
    let cfg = ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 2)
        .with_workload(big_workload(150));
    bench("full sim, oracle predictor", || {
        std::hint::black_box(frontier::run_experiment(&cfg).unwrap().sim_duration);
    });
    let cfg_v = cfg.clone().with_predictor(PredictorKind::Vidur);
    bench("full sim, vidur predictor", || {
        std::hint::black_box(frontier::run_experiment(&cfg_v).unwrap().sim_duration);
    });
    if frontier::runtime::PredictorRuntime::default_dir().join("manifest.json").exists() {
        let cfg_l = cfg.clone().with_predictor(PredictorKind::Learned);
        let t0 = std::time::Instant::now();
        let cold = frontier::run_experiment(&cfg_l).unwrap();
        println!(
            "full sim, learned predictor COLD: {:?} ({} PJRT launches, incl. ~100ms artifact compile)",
            t0.elapsed(),
            cold.metrics.predictor_evals
        );
        bench("full sim, learned predictor WARM (shared cache)", || {
            std::hint::black_box(frontier::run_experiment(&cfg_l).unwrap().sim_duration);
        });
    }

    section("zero-overhead config (engine floor)");
    let fast = ExperimentConfig::colocated(ModelConfig::tiny(), 8)
        .with_workload(big_workload(1000))
        .with_overhead(OverheadConfig::zero());
    let r = frontier::run_experiment(&fast).unwrap();
    println!(
        "tiny x8, 1000 reqs: {:.0} ev/s, {} events",
        r.events_per_sec(),
        r.events_processed
    );
}
