//! Routing-sampler throughput — the tentpole measurement of the
//! O(1) alias-table overhaul.
//!
//! Three samplers over the same popularity model:
//!
//! * **oracle** — the frozen linear-scan reference
//!   (`moe::assign_tokens_oracle`): O(tokens·k·E) per draw, one weight
//!   copy per token. This was the production sampler before this
//!   change.
//! * **alias** — per-token top-k through the cached Walker alias table
//!   (`RoutingFidelity::Token`): O(1) per pick.
//! * **aggregate** — O(E) binomial-split multinomial per draw
//!   (`RoutingFidelity::Aggregate`): the huge-batch scale mode.
//!
//! Emits `target/bench_results/BENCH_routing.json` (blessed copy lives
//! at the repo root) and, when `BENCH_BASELINE` is set, fails on >
//! tolerance regressions vs the committed baseline — the CI perf gate.
//!
//! ```bash
//! cargo bench --bench routing
//! BENCH_QUICK=1 BENCH_BASELINE=BENCH_routing.json cargo bench --bench routing
//! ```

use frontier::bench_util::{
    bench, gate_against_baseline, quick, section, write_results, BaselineCheck,
};
use frontier::config::json::Json;
use frontier::core::Pcg64;
use frontier::moe::{
    assign_tokens_into, assign_tokens_oracle, PopularityCache, RoutingFidelity, RoutingPolicy,
};

/// Per-expert share vectors of `draws` draws with each sampler, for the
/// distribution smoke check (the statistically rigorous equivalence
/// pins live in rust/tests/routing_dist.rs).
fn shares(
    fidelity: Option<RoutingFidelity>,
    policy: RoutingPolicy,
    tokens: u32,
    e: u32,
    k: u32,
    draws: u64,
) -> Vec<f64> {
    let mut rng = Pcg64::new(999);
    let mut cache = PopularityCache::default();
    let mut loads = Vec::new();
    let mut totals = vec![0u64; e as usize];
    for d in 0..draws {
        match fidelity {
            None => {
                let (l, _) = assign_tokens_oracle(policy, tokens, e, k, None, d, &mut rng);
                for (t, &x) in totals.iter_mut().zip(&l) {
                    *t += u64::from(x);
                }
            }
            Some(f) => {
                assign_tokens_into(
                    policy, f, tokens, e, k, None, d, &mut cache, &mut rng, &mut loads,
                );
                for (t, &x) in totals.iter_mut().zip(&loads) {
                    *t += u64::from(x);
                }
            }
        }
    }
    let sum: u64 = totals.iter().sum();
    totals.iter().map(|&t| t as f64 / sum.max(1) as f64).collect()
}

fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f64::max)
}

fn main() {
    // the acceptance configuration: E=128 experts, top_k=4 (the
    // MegaScale-Infer disaggregated-EP regime), skewed popularity
    let e = 128u32;
    let k = 4u32;
    let tokens = 512u32;
    let policy = RoutingPolicy::Skewed { alpha: 0.1 };
    let draws = if quick() { 8u64 } else { 32 };

    section("distribution smoke (shares vs the oracle sampler)");
    // fixed draw count in both modes: the smoke stats are deterministic
    // (fixed seed), so the gate compares identical numbers
    let smoke_draws = 200;
    let s_oracle = shares(None, policy, tokens, e, k, smoke_draws);
    let s_alias = shares(Some(RoutingFidelity::Token), policy, tokens, e, k, smoke_draws);
    let s_agg = shares(Some(RoutingFidelity::Aggregate), policy, tokens, e, k, smoke_draws);
    let err_alias = max_abs_diff(&s_oracle, &s_alias);
    let err_agg = max_abs_diff(&s_oracle, &s_agg);
    println!("max |share - oracle share|: alias {err_alias:.4}, aggregate {err_agg:.4}");
    assert!(err_alias < 0.02, "alias sampler drifted from the oracle: {err_alias}");
    assert!(err_agg < 0.03, "aggregate sampler drifted from the oracle: {err_agg}");

    section(&format!("token-draw throughput, E={e} top_k={k} tokens={tokens}"));
    let mut rng = Pcg64::new(1);
    let t_oracle = bench("oracle linear scan", || {
        for d in 0..draws {
            let (l, _) = assign_tokens_oracle(policy, tokens, e, k, None, d, &mut rng);
            std::hint::black_box(l.len());
        }
    });
    let mut rng = Pcg64::new(1);
    let mut cache = PopularityCache::default();
    let mut loads = Vec::new();
    let t_alias = bench("alias table (token fidelity)", || {
        for d in 0..draws {
            assign_tokens_into(
                policy,
                RoutingFidelity::Token,
                tokens,
                e,
                k,
                None,
                d,
                &mut cache,
                &mut rng,
                &mut loads,
            );
            std::hint::black_box(loads.len());
        }
    });
    let thr = |r: &frontier::bench_util::BenchResult| {
        draws as f64 * tokens as f64 / r.mean.as_secs_f64().max(1e-12)
    };
    let alias_speedup = thr(&t_alias) / thr(&t_oracle);
    println!(
        "tokens drawn/s: oracle {:.3e}, alias {:.3e}  (speedup {alias_speedup:.1}x)",
        thr(&t_oracle),
        thr(&t_alias)
    );
    assert!(
        alias_speedup >= 5.0,
        "acceptance floor: alias must be >=5x the oracle at E=128/top_k=4, got {alias_speedup:.2}x"
    );

    // the aggregate mode targets huge batches, where even the alias
    // sampler's per-token loop is the bottleneck
    let big_tokens = 4096u32;
    let big_draws = if quick() { 2u64 } else { 4 };
    section(&format!("aggregate mode, E={e} top_k={k} tokens={big_tokens}"));
    let mut rng = Pcg64::new(1);
    let t_oracle_big = bench("oracle linear scan (big batch)", || {
        for d in 0..big_draws {
            let (l, _) = assign_tokens_oracle(policy, big_tokens, e, k, None, d, &mut rng);
            std::hint::black_box(l.len());
        }
    });
    let mut rng = Pcg64::new(1);
    let t_agg = bench("aggregate counts (O(E) per draw)", || {
        for d in 0..big_draws {
            assign_tokens_into(
                policy,
                RoutingFidelity::Aggregate,
                big_tokens,
                e,
                k,
                None,
                d,
                &mut cache,
                &mut rng,
                &mut loads,
            );
            std::hint::black_box(loads.len());
        }
    });
    let thr_big = |r: &frontier::bench_util::BenchResult| {
        big_draws as f64 * big_tokens as f64 / r.mean.as_secs_f64().max(1e-12)
    };
    let aggregate_speedup = thr_big(&t_agg) / thr_big(&t_oracle_big);
    println!(
        "tokens drawn/s: oracle {:.3e}, aggregate {:.3e}  (speedup {aggregate_speedup:.1}x)",
        thr_big(&t_oracle_big),
        thr_big(&t_agg)
    );
    assert!(aggregate_speedup >= 5.0, "aggregate must also clear 5x, got {aggregate_speedup:.2}x");

    let calibrated = std::env::var_os("BENCH_CALIBRATED").is_some_and(|v| v == "1");
    let current = Json::obj(vec![
        ("calibrated", Json::Bool(calibrated)),
        ("experts", Json::Num(e as f64)),
        ("top_k", Json::Num(k as f64)),
        ("tokens", Json::Num(tokens as f64)),
        ("aggregate_tokens", Json::Num(big_tokens as f64)),
        ("oracle_tokens_per_s", Json::Num(thr(&t_oracle))),
        ("alias_tokens_per_s", Json::Num(thr(&t_alias))),
        ("aggregate_tokens_per_s", Json::Num(thr_big(&t_agg))),
        ("alias_speedup", Json::Num(alias_speedup)),
        ("aggregate_speedup", Json::Num(aggregate_speedup)),
        ("max_share_err_alias", Json::Num(err_alias)),
        ("max_share_err_aggregate", Json::Num(err_agg)),
    ]);
    write_results("BENCH_routing.json", &current.to_string_pretty());

    // CI perf gate: ratio metrics always, absolute throughput only
    // against a calibrated baseline
    gate_against_baseline(
        &current,
        &[
            BaselineCheck {
                key: "alias_speedup",
                higher_is_better: true,
                tol: 0.35,
                needs_calibration: false,
                two_sided: false,
            },
            BaselineCheck {
                key: "aggregate_speedup",
                higher_is_better: true,
                tol: 0.35,
                needs_calibration: false,
                two_sided: false,
            },
            BaselineCheck {
                key: "max_share_err_alias",
                higher_is_better: false,
                tol: 0.5,
                needs_calibration: false,
                two_sided: false,
            },
            BaselineCheck {
                key: "max_share_err_aggregate",
                higher_is_better: false,
                tol: 0.5,
                needs_calibration: false,
                two_sided: false,
            },
            BaselineCheck {
                key: "alias_tokens_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "aggregate_tokens_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
        ],
    );
}
