//! Bench F2 (paper Figure 2): operator-runtime prediction error CDFs
//! under dynamic workloads, plus prediction-throughput timings.
//!
//! Regenerates both Fig. 2 panels: Attention (Frontier vs Vidur vs
//! Roofline) and GroupedGEMM (Frontier; unsupported by Vidur).

use frontier::bench_util::{bench, section, write_results};
use frontier::core::Pcg64;
use frontier::metrics::frac_below;
use frontier::operators::opgen;
use frontier::predictor::{
    ExecutionPredictor, LearnedPredictor, OraclePredictor, RooflinePredictor, VidurPredictor,
};
use frontier::report::{cdf_summary, csv};
use frontier::runtime::PredictorRuntime;

fn errors(
    pred: &mut dyn ExecutionPredictor,
    truth: &mut OraclePredictor,
    ops: &[frontier::operators::OpWorkload],
) -> Vec<f64> {
    ops.iter()
        .map(|op| {
            let p = pred.predict(op);
            let t = truth.predict(op);
            (p - t).abs() / t
        })
        .collect()
}

fn main() {
    let n = 600;
    let mut rng = Pcg64::new(0xF16_2);
    let attn_ops: Vec<_> = (0..n).map(|_| opgen::attn_workload(&mut rng)).collect();
    let gg_ops: Vec<_> = (0..n).map(|_| opgen::grouped_gemm_workload(&mut rng)).collect();
    let mut truth = OraclePredictor::a800();
    let mut vidur = VidurPredictor::a800();
    let mut roofline = RooflinePredictor::a800();

    section("Figure 2(a): Attention relative-error CDF");
    let learned = LearnedPredictor::load_exact(&PredictorRuntime::default_dir());
    match learned {
        Ok(mut learned) => {
            let fe = errors(&mut learned, &mut truth, &attn_ops);
            let ve = errors(&mut vidur, &mut truth, &attn_ops);
            let re = errors(&mut roofline, &mut truth, &attn_ops);
            println!("{}", cdf_summary(&fe, "Frontier"));
            println!("{}", cdf_summary(&ve, "Vidur   "));
            println!("{}", cdf_summary(&re, "Roofline"));
            println!(
                "frontier <10%: {:.1}% of cases (paper: >94%) | vidur <10%: {:.1}%",
                frac_below(&fe, 0.10) * 100.0,
                frac_below(&ve, 0.10) * 100.0
            );

            section("Figure 2(b): GroupedGEMM relative-error CDF");
            let ge = errors(&mut learned, &mut truth, &gg_ops);
            println!("{}", cdf_summary(&ge, "Frontier"));
            println!(
                "frontier <6%: {:.1}% of cases (paper: >95%)",
                frac_below(&ge, 0.06) * 100.0
            );
            let rows: Vec<Vec<String>> = (0..n)
                .map(|i| {
                    vec![
                        format!("{:.6}", fe[i]),
                        format!("{:.6}", ve[i]),
                        format!("{:.6}", ge[i]),
                    ]
                })
                .collect();
            write_results(
                "bench_fig2.csv",
                &csv(&["frontier_attn", "vidur_attn", "frontier_gg"], &rows),
            );

            section("prediction throughput (the simulator's hot path)");
            let op = &attn_ops[0];
            bench("oracle predict (1 op)", || {
                std::hint::black_box(truth.predict(op));
            });
            bench("learned predict, cache hit", || {
                std::hint::black_box(learned.predict(op));
            });
            let mut i = 0usize;
            bench("learned predict, cache miss (PJRT exec)", || {
                i += 1;
                std::hint::black_box(learned.predict(&attn_ops[i % attn_ops.len()]));
            });
        }
        Err(e) => {
            println!("learned predictor unavailable ({e}); run `make artifacts`.");
            println!("falling back to vidur/roofline only");
            let ve = errors(&mut vidur, &mut truth, &attn_ops);
            println!("{}", cdf_summary(&ve, "Vidur"));
        }
    }
}
