//! EP scratch-network measurement (ROADMAP "Scratch EP network").
//!
//! `EpSpec::a2a_time` used to build a fresh `EpNetwork` (2n `Link`s + a
//! `Fabric` map) and two n^2 byte matrices on *every* routing draw —
//! millions of small allocations on long MoE runs. The CostModel now
//! carries a reusable scratch buffer. This bench counts heap
//! allocations per draw on both paths with a counting global allocator
//! and emits the drop as `target/bench_results/BENCH_ep_scratch.json`.
//!
//! ```bash
//! cargo bench --bench ep_scratch
//! ```

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use frontier::bench_util::{bench, section, write_results};
use frontier::config::json::Json;
use frontier::core::{Pcg64, SimTime};
use frontier::hardware::LinkSpec;
use frontier::moe::{
    assign_tokens, EpSpec, EpTopology, ExpertPlacement, PlacementPolicy, RoutingPolicy,
};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn main() {
    let n_ranks = 16u32;
    let n_experts = 64u32;
    let spec = EpSpec::flat(
        ExpertPlacement::build(
            PlacementPolicy::Contiguous,
            n_experts,
            EpTopology::new(n_ranks, 2),
            None,
        ),
        LinkSpec::nvlink_a800(),
        LinkSpec::cross_cluster(),
    );
    let bpt = 4096.0 * 2.0;
    // pre-draw the routing assignments so both paths price identical
    // matrices and the measured region contains only the a2a pricing
    let mut rng = Pcg64::new(42);
    let draws: Vec<Vec<u32>> = (0..256)
        .map(|_| {
            assign_tokens(RoutingPolicy::Skewed { alpha: 0.1 }, 512, n_experts, 4, &mut rng)
        })
        .collect();

    // fresh path: network + two matrices allocated per draw (the old
    // EpSpec::a2a_time behaviour)
    let fresh_pass = |out: &mut f64| {
        for loads in &draws {
            let mat = spec.placement.dispatch_matrix(loads, bpt);
            let mat_t = spec.placement.transposed(&mat);
            *out += spec.a2a_time(&mat).secs + spec.a2a_time(&mat_t).secs;
        }
    };
    // scratch path: one network + two buffers reused across draws (what
    // CostModel::moe_ffn_ep does internally)
    let mut net = spec.make_network();
    let mut mat: Vec<f64> = Vec::new();
    let mut mat_t: Vec<f64> = Vec::new();
    // warm the buffers (first fill sizes them; trunks appear lazily)
    spec.placement.dispatch_matrix_into(&draws[0], bpt, &mut mat);
    spec.placement.transpose_into(&mat, &mut mat_t);
    net.reset();
    net.all_to_all(SimTime::ZERO, &mat);
    net.reset();
    net.all_to_all(SimTime::ZERO, &mat_t);

    // sanity: both paths must price identically
    {
        let fresh = spec.a2a_time(&spec.placement.dispatch_matrix(&draws[1], bpt));
        spec.placement.dispatch_matrix_into(&draws[1], bpt, &mut mat);
        net.reset();
        let reused = net.all_to_all(SimTime::ZERO, &mat).1;
        assert_eq!(fresh, reused, "scratch path must price like a fresh network");
    }

    section("EP a2a pricing: fresh network per draw vs reusable scratch");
    let mut sink = 0.0f64;
    let a0 = allocs();
    fresh_pass(&mut sink);
    let fresh_allocs = allocs() - a0;

    let mut scratch_pass = |out: &mut f64| {
        for loads in &draws {
            spec.placement.dispatch_matrix_into(loads, bpt, &mut mat);
            spec.placement.transpose_into(&mat, &mut mat_t);
            net.reset();
            *out += net.all_to_all(SimTime::ZERO, &mat).1.secs;
            net.reset();
            *out += net.all_to_all(SimTime::ZERO, &mat_t).1.secs;
        }
    };
    let a1 = allocs();
    scratch_pass(&mut sink);
    let scratch_allocs = allocs() - a1;

    let per_draw_fresh = fresh_allocs as f64 / draws.len() as f64;
    let per_draw_scratch = scratch_allocs as f64 / draws.len() as f64;
    println!(
        "allocations/draw: fresh {per_draw_fresh:.1} -> scratch {per_draw_scratch:.1} \
         ({fresh_allocs} vs {scratch_allocs} over {} draws)",
        draws.len()
    );
    assert!(
        scratch_allocs * 10 < fresh_allocs,
        "scratch path must cut allocations by >10x: {scratch_allocs} vs {fresh_allocs}"
    );

    let t_fresh = bench("fresh network per draw", || {
        let mut s = 0.0;
        fresh_pass(&mut s);
        std::hint::black_box(s);
    });
    let t_scratch = bench("reusable scratch", || {
        let mut s = 0.0;
        scratch_pass(&mut s);
        std::hint::black_box(s);
    });
    std::hint::black_box(sink);

    let json = Json::obj(vec![
        ("ranks", Json::Num(n_ranks as f64)),
        ("experts", Json::Num(n_experts as f64)),
        ("draws", Json::Num(draws.len() as f64)),
        ("fresh_allocs_per_draw", Json::Num(per_draw_fresh)),
        ("scratch_allocs_per_draw", Json::Num(per_draw_scratch)),
        (
            "alloc_reduction_factor",
            Json::Num(fresh_allocs.max(1) as f64 / scratch_allocs.max(1) as f64),
        ),
        ("fresh_mean_s", Json::Num(t_fresh.mean.as_secs_f64())),
        ("scratch_mean_s", Json::Num(t_scratch.mean.as_secs_f64())),
        (
            "speedup",
            Json::Num(t_fresh.mean.as_secs_f64() / t_scratch.mean.as_secs_f64().max(1e-12)),
        ),
    ]);
    write_results("BENCH_ep_scratch.json", &json.to_string_pretty());
}
