//! Bench: one full simulated traffic day at scale — the open-loop
//! stress target of the workload/metrics subsystem.
//!
//! Drives the 4-class diurnal `traffic_day` mix (chat / RAG / agentic /
//! batch) through a colocated deployment at 1e6 requests (50k in
//! `BENCH_QUICK=1` mode) and reports simulated-events/sec, plus the
//! properties the run exists to pin:
//!
//! * the day completes — every request is accounted for
//!   (completed + rejected == offered);
//! * collector memory stays O(1) in request count (t-digest centroids
//!   and time-series buckets bounded, no raw sample vectors);
//! * admission stays cheap at pathological queue depths (the SJF
//!   full-queue drain+sort this PR removed made deep waiting queues
//!   quadratic).
//!
//! Emits `target/bench_results/BENCH_longrun.json`; the blessed copy at
//! the repo root arms the CI perf gate (`BENCH_BASELINE`). Wall-clock
//! metrics gate only against a calibrated baseline; the request count
//! is a two-sided drift alarm.

use std::collections::VecDeque;

use frontier::bench_util::{
    bench, gate_against_baseline, quick, section, write_results, BaselineCheck,
};
use frontier::config::json::Json;
use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::core::SimTime;
use frontier::metrics::{SloSpec, TS_MAX_BUCKETS};
use frontier::model::ModelConfig;
use frontier::scheduler::{admit, BatchPolicy, IterBudget, QueuedReq};
use frontier::workload::WorkloadSpec;

fn main() {
    // ~500 simulated seconds of traffic day regardless of scale: the
    // offered rate tracks the request count so both modes exercise the
    // same concurrency regime
    let n: u32 = if quick() { 50_000 } else { 1_000_000 };
    let rate = n as f64 / 500.0;
    let mut json: Vec<(&'static str, Json)> = Vec::new();
    let calibrated = std::env::var_os("BENCH_CALIBRATED").is_some_and(|v| v == "1");
    json.push(("calibrated", Json::Bool(calibrated)));
    json.push(("quick", Json::Bool(quick())));

    section(&format!("traffic day: {n} requests at {rate:.0} req/s offered"));
    let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 8)
        .with_workload(WorkloadSpec::traffic_day(rate, n))
        .with_overhead(OverheadConfig::zero())
        .with_slo(SloSpec { ttft_s: Some(2.0), tbt_s: Some(0.1), e2e_s: None });
    // one timed run — at this scale a single pass is the measurement
    let r = frontier::run_experiment(&cfg).unwrap();
    println!(
        "{} events in {:.2}s host = {:.0} ev/s | {} iterations | sim {:.1}s",
        r.events_processed,
        r.host_duration,
        r.events_per_sec(),
        r.metrics.iterations,
        r.sim_duration,
    );
    println!(
        "completed {} / rejected {} | goodput {:.1} req/s | SLO attainment {:.1}%",
        r.metrics.completed_requests,
        r.metrics.rejected_requests,
        r.goodput(),
        r.slo_attainment() * 100.0,
    );

    // the day must complete: every offered request accounted for
    assert_eq!(
        r.metrics.completed_requests + r.metrics.rejected_requests,
        n as u64,
        "requests lost by the simulation"
    );
    assert!(r.metrics.completed_requests > 0, "nothing completed");
    // collector memory is O(1) in n: bounded digests, bounded
    // time-series, and no raw sample retention
    for (name, d) in [
        ("ttft", &r.metrics.ttft),
        ("tbt", &r.metrics.tbt),
        ("e2e", &r.metrics.e2e),
        ("norm_latency", &r.metrics.norm_latency),
    ] {
        assert!(
            d.centroids() + d.buffered() <= 1024,
            "{name} digest grew unbounded: {} centroids + {} buffered",
            d.centroids(),
            d.buffered()
        );
    }
    assert!(r.metrics.timeseries.buckets.len() <= TS_MAX_BUCKETS);
    assert!(r.metrics.raw.is_none(), "raw samples must be off by default");

    json.push(("longrun_requests", Json::Num(n as f64)));
    json.push(("longrun_completed", Json::Num(r.metrics.completed_requests as f64)));
    json.push(("longrun_rejected", Json::Num(r.metrics.rejected_requests as f64)));
    json.push(("longrun_events", Json::Num(r.events_processed as f64)));
    json.push(("longrun_iterations", Json::Num(r.metrics.iterations as f64)));
    json.push(("longrun_events_per_s", Json::Num(r.events_per_sec())));
    json.push(("longrun_sim_s", Json::Num(r.sim_duration)));
    json.push(("longrun_goodput_rps", Json::Num(r.goodput())));

    section("admission at pathological queue depth");
    let deep = 50_000usize;
    let make_queue = || -> VecDeque<QueuedReq> {
        (0..deep)
            .map(|i| QueuedReq {
                id: i as u64,
                tokens_needed: ((i * 37) % 997) as u32 + 1,
                blocks_needed: 1,
                arrival: SimTime::from_secs_f64(i as f64 * 1e-3),
            })
            .collect()
    };
    let budget = IterBudget { max_batch: 256, ..IterBudget::default() };
    // a full batch means admission is impossible: the call must return
    // without touching the queue (the old SJF path drained and
    // re-sorted all 50k entries here, every iteration)
    let mut q = make_queue();
    let blocked = bench("admit: blocked, 50k-deep queue", || {
        let out = admit(BatchPolicy::Sjf, &mut q, budget.max_batch, &budget, u64::MAX);
        assert!(out.is_empty());
    });
    assert_eq!(q.len(), deep, "blocked admission must leave the queue intact");
    let sjf = bench("admit: SJF picks 256 of 50k", || {
        let mut q = make_queue();
        let out = admit(BatchPolicy::Sjf, &mut q, 0, &budget, u64::MAX);
        std::hint::black_box(out.len());
    });
    json.push(("admit_blocked_mean_s", Json::Num(blocked.mean.as_secs_f64())));
    json.push(("admit_sjf_deep_mean_s", Json::Num(sjf.mean.as_secs_f64())));

    let current = Json::obj(json);
    write_results("BENCH_longrun.json", &current.to_string_pretty());

    gate_against_baseline(
        &current,
        &[
            // scale drift alarm: the gate is meaningless if the bench
            // silently runs a different day
            BaselineCheck {
                key: "longrun_requests",
                higher_is_better: false,
                tol: 0.0,
                needs_calibration: false,
                two_sided: true,
            },
            // deterministic counts: pinned once the baseline carries
            // them (skipped with a notice until then)
            BaselineCheck {
                key: "longrun_events",
                higher_is_better: false,
                tol: 0.0,
                needs_calibration: false,
                two_sided: true,
            },
            BaselineCheck {
                key: "longrun_completed",
                higher_is_better: false,
                tol: 0.0,
                needs_calibration: false,
                two_sided: true,
            },
            // wall-clock: calibrated baselines only
            BaselineCheck {
                key: "longrun_events_per_s",
                higher_is_better: true,
                tol: 0.2,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "admit_blocked_mean_s",
                higher_is_better: false,
                tol: 0.5,
                needs_calibration: true,
                two_sided: false,
            },
            BaselineCheck {
                key: "admit_sjf_deep_mean_s",
                higher_is_better: false,
                tol: 0.5,
                needs_calibration: true,
                two_sided: false,
            },
        ],
    );
}
