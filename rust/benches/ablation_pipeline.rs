//! Bench A3 (§3.3 AF): micro-batch ping-pong pipeline ablation.
//!
//! Sweeps the number of micro-batches m per decode step and reports
//! both the pure dependency-graph step time (token latency) and the
//! end-to-end serving numbers, demonstrating the latency-hiding the
//! event-graph executor captures (MegaScale-Infer / Step-3).

use frontier::bench_util::{bench, section, write_results};
use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::model::ModelConfig;
use frontier::report::{csv, markdown_table};
use frontier::workflows::af::{af_step, attn_utilization, AfStep};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn main() {
    section("dependency-graph step time vs micro-batch count (fixed total work)");
    let layers = 32;
    let total_attn = 3.2e-3; // attention-side work per layer-step, all micros
    let total_ffn = 3.2e-3;
    let xfer = 30e-6;
    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    for m in [1usize, 2, 3, 4, 6, 8, 12, 16] {
        let step = AfStep::uniform(
            layers,
            m,
            total_attn / m as f64 / layers as f64,
            total_ffn / m as f64 / layers as f64,
            xfer,
        );
        let (t, busy) = af_step(&step);
        let util = busy[0] / t;
        rows.push(vec![
            m.to_string(),
            format!("{:.2}", t * 1e3),
            format!("{:.0}%", util * 100.0),
            format!("{:.2}", (busy[2] / t) * 100.0),
        ]);
        csv_rows.push(vec![m.to_string(), format!("{:.5}", t * 1e3), format!("{util:.4}")]);
    }
    println!(
        "{}",
        markdown_table(
            &["micro-batches", "step time (ms)", "attn-pool busy", "a2f link busy %"],
            &rows
        )
    );
    write_results("ablation_pipeline.csv", &csv(&["m", "step_ms", "attn_util"], &csv_rows));
    println!(
        "m=1 serializes attn -> transfer -> ffn -> transfer; m>=2 overlaps the\n\
         two pools (ping-pong) until transfer overhead dominates at large m.\n"
    );

    section("end-to-end AF serving across m (Mixtral-8x7B, 4+4 GPUs)");
    let mut rows = Vec::new();
    for m in [1u32, 2, 4, 8] {
        // prefill tier needs tp=2: Mixtral's 92 GB of weights do not fit
        // a single 80 GB GPU
        let cfg = ExperimentConfig::af(ModelConfig::mixtral_8x7b(), 2, 4, 4, m)
            .with_parallelism(frontier::parallelism::Parallelism::tp(2))
            .with_workload(WorkloadSpec {
                arrival: Arrival::Batch,
                input: LenDist::Uniform { lo: 128, hi: 512 },
                output: LenDist::Fixed(32),
                n_requests: 32,
                seed: 9,
                classes: vec![],
                trace: None,
            })
            .with_overhead(OverheadConfig::zero());
        let r = frontier::run_experiment(&cfg).unwrap();
        rows.push(vec![
            m.to_string(),
            format!("{:.2}", r.sim_duration),
            format!("{:.1}", r.tokens_per_sec_per_gpu()),
        ]);
    }
    println!("{}", markdown_table(&["m", "makespan (s)", "tok/s/gpu"], &rows));
    println!(
        "at this decode batch the FFN side is weight-bound (re-reads all\n\
         expert weights per micro-batch), so fixed costs multiply with m and\n\
         serial m=1 wins — the quantitative trade-off MegaScale-Infer's\n\
         operating point (very large global batches, step-level sweep above)\n\
         flips the other way. Frontier prices both regimes."
    );

    section("executor cost (host time per simulated step)");
    let step = AfStep::uniform(61, 4, 25e-6, 25e-6, 10e-6);
    bench("af_step 61 layers x 4 micros", || {
        std::hint::black_box(af_step(&step));
    });
    let _ = attn_utilization(&step);
}
