//! Bench A2 (§3.3 PD): backpressure ablation.
//!
//! Sweeps decode-stage KV memory and shows the controller's pull-based
//! transfer discipline: with backpressure ON (Frontier's model),
//! transfers wait for memory-availability signals and the system
//! degrades gracefully; with the consumer's memory unconstrained
//! (backpressure ablated), the decode stage overcommits and the
//! simulated throughput is optimistic fiction.

use frontier::bench_util::{section, write_results};
use frontier::config::{ExperimentConfig, PolicyConfig};
use frontier::model::ModelConfig;
use frontier::report::{csv, markdown_table};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn workload() -> WorkloadSpec {
    // heavy enough that a starved decode pool is the bottleneck: long
    // contexts (big KV footprints) and long decodes at a high offered rate
    WorkloadSpec {
        arrival: Arrival::Poisson { rate: 25.0 },
        input: LenDist::LogNormal { mean: 2048.0, sigma: 0.8 },
        output: LenDist::Fixed(256),
        n_requests: 150,
        seed: 77,
        classes: vec![],
        trace: None,
    }
}

fn main() {
    section("decode KV pool sweep: backpressure in action (PD 4:4, Qwen2-7B)");
    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    for reserve in [0.10, 0.90, 0.99, 0.995, 0.998] {
        let mut cfg =
            ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4).with_workload(workload());
        cfg.policy = PolicyConfig { kv_reserve_frac: reserve, ..PolicyConfig::default() };
        let r = frontier::run_experiment(&cfg).expect("backpressure must not deadlock");
        let pool_frac = 1.0 - reserve;
        rows.push(vec![
            format!("{:.1}%", pool_frac * 100.0),
            format!("{:.2}", r.tokens_per_sec_per_gpu()),
            format!("{:.0}", r.metrics.ttft.quantile(50.0) * 1e3),
            format!("{:.0}", r.metrics.ttft.quantile(99.0) * 1e3),
            format!("{:.1}", r.metrics.tbt.quantile(99.0) * 1e3),
            format!("{}", r.metrics.completed_requests),
        ]);
        csv_rows.push(vec![
            format!("{pool_frac:.3}"),
            format!("{:.4}", r.tokens_per_sec_per_gpu()),
            format!("{:.4}", r.metrics.ttft.quantile(99.0)),
            format!("{:.4}", r.metrics.tbt.quantile(99.0)),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["KV pool", "tok/s/gpu", "TTFT p50 (ms)", "TTFT p99 (ms)", "TBT p99 (ms)", "done"],
            &rows
        )
    );
    write_results(
        "ablation_backpressure.csv",
        &csv(&["pool_frac", "tok_s_gpu", "ttft_p99_s", "tbt_p99_s"], &csv_rows),
    );
    println!(
        "\nshrinking the consumer pool moves the cost into TTFT (requests queue\n\
         at PREFILL_COMPLETE awaiting transfer slots) while decode TBT stays\n\
         flat — the producer/consumer rate-match the paper models in §3.3.\n"
    );

    section("ablation: what an unconstrained-consumer simulator would claim");
    // backpressure ablated = decode pool effectively infinite
    let mut free = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4).with_workload(workload());
    free.policy = PolicyConfig { kv_reserve_frac: 0.0, ..PolicyConfig::default() };
    let free_r = frontier::run_experiment(&free).unwrap();
    let mut tight = free.clone();
    tight.policy.kv_reserve_frac = 0.995;
    let tight_r = frontier::run_experiment(&tight).unwrap();
    println!(
        "unconstrained consumer: {:.2} tok/s/gpu, TTFT p99 {:.0} ms\n\
         real 0.5% pool       : {:.2} tok/s/gpu, TTFT p99 {:.0} ms\n\
         a simulator without memory-availability signaling reports the first\n\
         number for the second system — {:.1}x optimistic on throughput.",
        free_r.tokens_per_sec_per_gpu(),
        free_r.metrics.ttft.quantile(99.0) * 1e3,
        tight_r.tokens_per_sec_per_gpu(),
        tight_r.metrics.ttft.quantile(99.0) * 1e3,
        free_r.tokens_per_sec_per_gpu() / tight_r.tokens_per_sec_per_gpu()
    );
}
