//! Bench T2 (paper Table 2): end-to-end PD-disaggregated throughput,
//! predicted vs profiled, across the four batch/length configurations.

use frontier::bench_util::{bench, section, write_results};
use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::model::ModelConfig;
use frontier::predictor::PredictorKind;
use frontier::report::{csv, markdown_table};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

const TABLE2: [(u32, u32, u32); 4] = [(4, 32, 1024), (8, 128, 256), (16, 256, 128), (32, 32, 128)];

fn config(bs: u32, avg_in: u32, out: u32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 4, 4).with_workload(
        WorkloadSpec {
            arrival: Arrival::Batch,
            input: LenDist::Uniform { lo: (avg_in / 2).max(1), hi: avg_in + avg_in / 2 },
            output: LenDist::Fixed(out),
            n_requests: bs * 6,
            seed: 0x7AB1E2,
            classes: vec![],
            trace: None,
        },
    );
    cfg.policy.budget.max_batch = ((bs + 3) / 4).max(1) as usize;
    cfg
}

fn main() {
    section("Table 2: predicted vs profiled throughput (tokens/s/GPU)");
    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    for (bs, avg_in, out) in TABLE2 {
        let predicted = frontier::run_experiment(
            &config(bs, avg_in, out)
                .with_predictor(PredictorKind::Learned)
                .with_overhead(OverheadConfig::predicted()),
        );
        let profiled = frontier::run_experiment(
            &config(bs, avg_in, out)
                .with_predictor(PredictorKind::Oracle)
                .with_overhead(OverheadConfig::profiled_real()),
        )
        .expect("profiled run");
        let t = profiled.tokens_per_sec_per_gpu();
        match predicted {
            Ok(predicted) => {
                let p = predicted.tokens_per_sec_per_gpu();
                let err = (p - t).abs() / t * 100.0;
                rows.push(vec![
                    bs.to_string(),
                    avg_in.to_string(),
                    out.to_string(),
                    format!("{t:.3}"),
                    format!("{p:.3}"),
                    format!("{err:.1}%"),
                ]);
                csv_rows.push(vec![
                    bs.to_string(),
                    format!("{t:.4}"),
                    format!("{p:.4}"),
                    format!("{:.4}", err / 100.0),
                ]);
            }
            Err(e) => rows.push(vec![
                bs.to_string(),
                avg_in.to_string(),
                out.to_string(),
                format!("{t:.3}"),
                format!("unavailable: {e}"),
                "-".into(),
            ]),
        }
    }
    println!(
        "{}",
        markdown_table(
            &["Batch", "Avg In", "Out", "Profiled", "Predicted", "Rel err"],
            &rows
        )
    );
    write_results(
        "bench_table2.csv",
        &csv(&["batch", "profiled", "predicted", "rel_err"], &csv_rows),
    );

    section("simulation cost per Table-2 row (host time)");
    for (bs, avg_in, out) in [(4u32, 32u32, 1024u32), (32, 32, 128)] {
        bench(&format!("simulate bs={bs} in={avg_in} out={out} (oracle)"), || {
            let r = frontier::run_experiment(
                &config(bs, avg_in, out).with_overhead(OverheadConfig::profiled_real()),
            )
            .unwrap();
            std::hint::black_box(r.sim_duration);
        });
    }
}
