//! Bench A1 (§3.3 MoE): straggler synchronization ablation.
//!
//! The ExecutionPredictor models the MoE barrier as `max` over per-rank
//! expert task times. This ablation sweeps routing skew (Dirichlet
//! concentration alpha) and compares `max` against the
//! balance-oblivious `mean`, at both the layer level and end-to-end.

use frontier::bench_util::{section, write_results};
use frontier::config::{ExperimentConfig, OverheadConfig};
use frontier::core::Pcg64;
use frontier::hardware::LinkSpec;
use frontier::model::ModelConfig;
use frontier::moe::{balance_metrics, RoutingPolicy};
use frontier::parallelism::Parallelism;
use frontier::predictor::OraclePredictor;
use frontier::report::{csv, markdown_table};
use frontier::workflows::{CostCtx, CostModel};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn main() {
    let model = ModelConfig::mixtral_8x7b();
    let alphas = [20.0, 5.0, 1.0, 0.3, 0.1, 0.05];

    section("MoE layer time: max-sync vs mean-sync across routing skew (EP=8)");
    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    for &alpha in &alphas {
        let layer_time = |straggler: bool, seed: u64| {
            let mut cm = CostModel::new(
                model.clone(),
                Parallelism::new(1, 1, 8),
                LinkSpec::nvlink_a800(),
            );
            cm.overhead = OverheadConfig::zero();
            cm.moe_routing = RoutingPolicy::Skewed { alpha };
            cm.straggler_max = straggler;
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(seed);
            // average over several routing draws
            let mut acc = 0.0;
            for _ in 0..20 {
                let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
                acc += cm.ffn_block_time(&mut ctx, 256);
            }
            acc / 20.0
        };
        let t_max = layer_time(true, 1);
        let t_mean = layer_time(false, 1);
        // measure the imbalance this alpha produces
        let mut rng = Pcg64::new(2);
        let loads =
            frontier::moe::assign_tokens(RoutingPolicy::Skewed { alpha }, 256, 8, 2, &mut rng);
        let imb = balance_metrics(&loads).imbalance;
        rows.push(vec![
            format!("{alpha}"),
            format!("{:.2}", imb),
            format!("{:.1}", t_max * 1e6),
            format!("{:.1}", t_mean * 1e6),
            format!("{:+.1}%", (t_max / t_mean - 1.0) * 100.0),
        ]);
        csv_rows.push(vec![
            format!("{alpha}"),
            format!("{imb:.4}"),
            format!("{:.2}", t_max * 1e6),
            format!("{:.2}", t_mean * 1e6),
        ]);
    }
    println!(
        "{}",
        markdown_table(
            &["alpha", "imbalance", "max-sync (us)", "mean-sync (us)", "straggler cost"],
            &rows
        )
    );
    write_results(
        "ablation_straggler.csv",
        &csv(&["alpha", "imbalance", "max_us", "mean_us"], &csv_rows),
    );

    section("end-to-end: skewed routing, straggler modeling on/off");
    let mut rows = Vec::new();
    for straggler in [true, false] {
        let mut cfg = ExperimentConfig::colocated(model.clone(), 1)
            .with_parallelism(Parallelism::new(1, 1, 8))
            .with_workload(WorkloadSpec {
                arrival: Arrival::Batch,
                input: LenDist::Uniform { lo: 128, hi: 512 },
                output: LenDist::Fixed(64),
                n_requests: 64,
                seed: 5,
                classes: vec![],
                trace: None,
            });
        cfg.policy.moe_routing = RoutingPolicy::Skewed { alpha: 0.1 };
        cfg.policy.straggler_max = straggler;
        let r = frontier::run_experiment(&cfg).unwrap();
        rows.push(vec![
            if straggler { "max (Frontier)" } else { "mean (oblivious)" }.to_string(),
            format!("{:.2}", r.sim_duration),
            format!("{:.2}", r.tokens_per_sec_per_gpu()),
        ]);
    }
    println!(
        "{}",
        markdown_table(&["sync model", "makespan (s)", "tok/s/gpu"], &rows)
    );
    println!(
        "\nbalance-oblivious simulation overestimates MoE serving capacity; the\n\
         gap is the straggler effect the paper's micro-workflow captures."
    );
}
