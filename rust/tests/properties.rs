//! Property-based tests on coordinator and substrate invariants
//! (hand-rolled harness; see `frontier::proptest_util`).

use std::collections::VecDeque;

use frontier::config::{ExperimentConfig, PolicyConfig};
use frontier::core::Pcg64;
use frontier::memory::BlockManager;
use frontier::model::ModelConfig;
use frontier::moe::{
    assign_tokens, assign_tokens_at, assign_tokens_cached, assign_tokens_capped,
    assign_tokens_into, plan_migration, rank_imbalance, EpTopology, ExpertPlacement,
    PlacementPolicy, PopularityCache, RoutingFidelity, RoutingPolicy,
};
use frontier::proptest_util::run_prop;
use frontier::scheduler::{admit, BatchPolicy, IterBudget, QueuedReq};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

#[test]
fn prop_block_manager_never_overcommits() {
    run_prop("block manager conservation", 200, |g| {
        let total = g.u64(1, 500);
        let mut bm = BlockManager::with_blocks(total);
        let mut live: Vec<u64> = Vec::new();
        for step in 0..60 {
            if g.bool() || live.is_empty() {
                let want = g.u64(1, 64);
                let id = step as u64;
                if bm.allocate(id, want).is_ok() {
                    live.push(id);
                }
            } else {
                let idx = g.u64(0, live.len() as u64 - 1) as usize;
                let id = live.swap_remove(idx);
                bm.free_request(id);
            }
            assert!(bm.used_blocks() + bm.free_blocks() == total);
            assert!(bm.free_blocks() <= total);
        }
        for id in live {
            bm.free_request(id);
        }
        assert_eq!(bm.free_blocks(), total, "all memory returns to the pool");
    });
}

#[test]
fn prop_admission_respects_all_budgets() {
    run_prop("admission budgets", 200, |g| {
        let mut waiting: VecDeque<QueuedReq> = (0..g.u32(1, 40))
            .map(|i| QueuedReq {
                id: i as u64,
                tokens_needed: g.u32(0, 4096),
                blocks_needed: g.u64(0, 64),
                arrival: frontier::core::SimTime::ZERO,
            })
            .collect();
        let before: Vec<u64> = waiting.iter().map(|q| q.id).collect();
        let budget = IterBudget {
            max_batch: g.u32(1, 32) as usize,
            max_prefill_tokens: g.u32(0, 8192),
        };
        let running = g.u32(0, 8) as usize;
        let free = g.u64(0, 256);
        let policy = *g.pick(&[BatchPolicy::Fcfs, BatchPolicy::Sjf]);
        let admitted = admit(policy, &mut waiting, running, &budget, free);
        // batch cap
        assert!(running + admitted.len() <= budget.max_batch.max(running));
        // memory cap
        let blocks: u64 = admitted.iter().map(|q| q.blocks_needed).sum();
        assert!(blocks <= free, "admitted {blocks} blocks with only {free} free");
        // conservation: admitted + still-waiting == original set
        let mut all: Vec<u64> = admitted
            .iter()
            .map(|q| q.id)
            .chain(waiting.iter().map(|q| q.id))
            .collect();
        all.sort_unstable();
        let mut want = before.clone();
        want.sort_unstable();
        assert_eq!(all, want, "requests must never be lost or duplicated");
    });
}

#[test]
fn prop_moe_routing_conserves_tokens() {
    run_prop("moe token conservation", 150, |g| {
        let mut rng = Pcg64::new(g.seed * 77 + 1);
        let tokens = g.u32(0, 2048);
        let e = g.u32(1, 64);
        let k = g.u32(1, 8);
        let policy = *g.pick(&[
            RoutingPolicy::Balanced,
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.1 },
            RoutingPolicy::Skewed { alpha: 5.0 },
        ]);
        let loads = assign_tokens(policy, tokens, e, k, &mut rng);
        assert_eq!(loads.len(), e as usize);
        let eff_k = k.min(e);
        assert_eq!(
            loads.iter().map(|&x| x as u64).sum::<u64>(),
            tokens as u64 * eff_k as u64
        );
        // top-k without replacement: no expert receives more than `tokens`
        assert!(loads.iter().all(|&l| l <= tokens));
    });
}

#[test]
fn prop_production_samplers_conserve_for_every_policy_and_fidelity() {
    // the alias-table and aggregate samplers share the oracle's hard
    // invariants: exact slot conservation (routed + dropped ==
    // tokens * k), per-token distinctness (no expert exceeds the token
    // count), capacity caps respected, and zero drops whenever the cap
    // has headroom — for every policy, fidelity, and draw index
    run_prop("production sampler conservation", 200, |g| {
        let tokens = g.u32(0, 1024);
        let e = g.u32(1, 64);
        let k = g.u32(1, 8);
        let cap = if g.bool() { Some(g.u32(1, 2048)) } else { None };
        let policy = *g.pick(&[
            RoutingPolicy::Balanced,
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.05 },
            RoutingPolicy::Skewed { alpha: 2.0 },
            RoutingPolicy::Drifting { alpha: 0.1, period: 5 },
        ]);
        let fidelity = *g.pick(&[RoutingFidelity::Token, RoutingFidelity::Aggregate]);
        let draw = g.u64(0, 1000);
        let mut cache = PopularityCache::default();
        let mut loads = Vec::new();
        let dropped = assign_tokens_into(
            policy,
            fidelity,
            tokens,
            e,
            k,
            cap,
            draw,
            &mut cache,
            &mut Pcg64::new(g.seed * 17 + 3),
            &mut loads,
        );
        let eff_k = k.min(e) as u64;
        assert_eq!(loads.len(), e as usize);
        assert_eq!(
            loads.iter().map(|&x| u64::from(x)).sum::<u64>() + dropped,
            tokens as u64 * eff_k,
            "{policy:?} {fidelity:?}: slots lost or invented"
        );
        assert!(
            loads.iter().all(|&l| l <= tokens),
            "{policy:?} {fidelity:?}: distinctness violated"
        );
        if let Some(c) = cap {
            assert!(loads.iter().all(|&l| l <= c), "{policy:?} {fidelity:?}: cap violated");
            if c >= tokens {
                assert_eq!(dropped, 0, "{policy:?} {fidelity:?}: cap with headroom dropped");
            }
        } else {
            assert_eq!(dropped, 0, "{policy:?} {fidelity:?}: uncapped must not drop");
        }
    });
}

#[test]
fn prop_capacity_cap_conserves_and_never_drops_with_headroom() {
    // 1) capacity >= the uncapped max expert load => zero drops and a
    //    bit-identical assignment; 2) any cap conserves token-slots
    //    (routed + dropped == tokens * k) and respects the cap exactly
    run_prop("capacity factor", 150, |g| {
        let tokens = g.u32(0, 1024);
        let e = g.u32(1, 32);
        let k = g.u32(1, 4);
        let policy = *g.pick(&[
            RoutingPolicy::Balanced,
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.05 },
        ]);
        let seed = g.seed * 31 + 7;
        let uncapped = assign_tokens(policy, tokens, e, k, &mut Pcg64::new(seed));
        let max_load = uncapped.iter().copied().max().unwrap_or(0);
        // headroom: capping at the observed max changes nothing
        let (same, dropped) = assign_tokens_capped(
            policy,
            tokens,
            e,
            k,
            Some(max_load.max(1)),
            &mut Pcg64::new(seed),
        );
        assert_eq!(same, uncapped, "cap >= max load must be a no-op");
        assert_eq!(dropped, 0, "cap >= max load must not drop");
        // a tight cap conserves token-slots exactly
        let cap = g.u32(1, max_load.max(1));
        let (capped, d) =
            assign_tokens_capped(policy, tokens, e, k, Some(cap), &mut Pcg64::new(seed));
        assert!(capped.iter().all(|&l| l <= cap), "cap violated: {capped:?} cap {cap}");
        let eff_k = k.min(e) as u64;
        assert_eq!(
            capped.iter().map(|&x| x as u64).sum::<u64>() + d,
            tokens as u64 * eff_k,
            "token-slots lost or invented"
        );
    });
}

#[test]
fn prop_ep_placement_is_a_partition() {
    // non-replicated policies: every expert lives on exactly one rank,
    // every host rank is valid, and the per-rank blocks are balanced
    run_prop("ep placement partition", 200, |g| {
        let ranks = g.u32(1, 16);
        let experts = g.u32(1, 96);
        let clusters = g.u32(1, 8);
        let topo = EpTopology::new(ranks, clusters);
        let policy = *g.pick(&[PlacementPolicy::Contiguous, PlacementPolicy::Strided]);
        let p = ExpertPlacement::build(policy, experts, topo, None);
        assert_eq!(p.expert_ranks.len(), experts as usize);
        let mut per_rank = vec![0u32; ranks as usize];
        for hosts in &p.expert_ranks {
            assert_eq!(hosts.len(), 1, "{policy:?} must not replicate");
            assert!(hosts[0] < ranks, "host {} out of range", hosts[0]);
            per_rank[hosts[0] as usize] += 1;
        }
        assert_eq!(per_rank.iter().sum::<u32>(), experts, "experts lost or duplicated");
        let max = per_rank.iter().max().unwrap();
        let min = per_rank.iter().min().unwrap();
        assert!(max - min <= 1, "unbalanced blocks: {per_rank:?}");
    });
}

#[test]
fn prop_ep_dispatch_bytes_conserve_routed_tokens() {
    // the (src, dst) dispatch matrix totals exactly the routed-token
    // bytes, rank loads conserve tokens exactly, and the combine phase
    // mirrors the dispatch — for every placement policy
    run_prop("ep dispatch conservation", 200, |g| {
        let mut rng = Pcg64::new(g.seed * 77 + 5);
        let ranks = g.u32(1, 12);
        let experts = g.u32(1, 48);
        let clusters = g.u32(1, 6);
        let topo = EpTopology::new(ranks, clusters);
        let policy = *g.pick(&[
            PlacementPolicy::Contiguous,
            PlacementPolicy::Strided,
            PlacementPolicy::ReplicatedHot { hot: 3 },
        ]);
        let tokens = g.u32(0, 1024);
        let k = g.u32(1, 4);
        let loads = assign_tokens(RoutingPolicy::UniformRandom, tokens, experts, k, &mut rng);
        let p = ExpertPlacement::build(policy, experts, topo, Some(&loads));
        let bpt = g.f64(1.0, 8192.0);
        let routed: u64 = loads.iter().map(|&x| x as u64).sum();
        let want = routed as f64 * bpt;
        let dispatch: f64 = p.dispatch_matrix(&loads, bpt).iter().sum();
        let combine: f64 = p.combine_matrix(&loads, bpt).iter().sum();
        let tol = 1e-9 * want.max(1.0);
        assert!((dispatch - want).abs() < tol, "dispatch {dispatch} vs {want}");
        assert!((combine - want).abs() < tol, "combine {combine} vs {want}");
        // token conservation is exact (integer largest-remainder split)
        assert_eq!(p.rank_totals(&loads).iter().sum::<u64>(), routed);
    });
}

#[test]
fn prop_draw_clock_and_cache_are_inert_for_non_drifting_policies() {
    // the draw-clock plumbing added for drifting popularity is the only
    // mechanism by which this PR could have perturbed pre-existing RNG
    // streams: pin that for every non-drifting policy, ANY draw index
    // (and a reused popularity cache, warm from any other policy) is
    // bit-identical to the plain capped assignment
    run_prop("draw clock inert", 150, |g| {
        let tokens = g.u32(0, 512);
        let e = g.u32(1, 32);
        let k = g.u32(1, 4);
        let cap = if g.bool() { Some(g.u32(1, 64)) } else { None };
        let policy = *g.pick(&[
            RoutingPolicy::Balanced,
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.1 },
            RoutingPolicy::Skewed { alpha: 2.0 },
        ]);
        let seed = g.seed * 13 + 3;
        let draw = g.u64(0, u64::MAX / 2);
        let plain = assign_tokens_capped(policy, tokens, e, k, cap, &mut Pcg64::new(seed));
        let at = assign_tokens_at(policy, tokens, e, k, cap, draw, &mut Pcg64::new(seed));
        assert_eq!(plain, at, "{policy:?} at draw {draw}");
        // a warm cache (possibly keyed to a different policy) must be
        // transparently refreshed, never change results
        let mut cache = PopularityCache::default();
        let warm = *g.pick(&[
            RoutingPolicy::Skewed { alpha: 0.5 },
            RoutingPolicy::Drifting { alpha: 0.3, period: 7 },
            policy,
        ]);
        assign_tokens_cached(warm, 16, e, k, None, 3, &mut cache, &mut Pcg64::new(1));
        let cached = assign_tokens_cached(
            policy,
            tokens,
            e,
            k,
            cap,
            draw,
            &mut cache,
            &mut Pcg64::new(seed),
        );
        assert_eq!(plain, cached, "warm cache must be transparent");
        // and the cache is equally transparent for drifting popularity
        let drift = RoutingPolicy::Drifting { alpha: 0.1, period: 5 };
        let fresh = assign_tokens_at(drift, tokens, e, k, cap, draw, &mut Pcg64::new(seed));
        let reused = assign_tokens_cached(
            drift,
            tokens,
            e,
            k,
            cap,
            draw,
            &mut cache,
            &mut Pcg64::new(seed),
        );
        assert_eq!(fresh, reused);
    });
}

#[test]
fn prop_migration_plan_never_worsens_predicted_imbalance() {
    // planner soundness: whenever a plan is emitted it must (1) predict
    // a strict, threshold-clearing improvement, (2) actually move
    // something, (3) keep the placement valid (every expert hosted on
    // in-range ranks, home-expert slots capped at ceil(E/N)), and
    // (4) be a fixed point — re-planning right after adoption proposes
    // nothing, so stationary load can never thrash
    run_prop("migration plan soundness", 200, |g| {
        let ranks = g.u32(2, 12);
        let experts = g.u32(1, 64);
        let clusters = g.u32(1, 4);
        let topo = EpTopology::new(ranks, clusters);
        let policy = *g.pick(&[
            PlacementPolicy::Contiguous,
            PlacementPolicy::Strided,
            PlacementPolicy::ReplicatedHot { hot: 2 },
        ]);
        let current = ExpertPlacement::build(policy, experts, topo, None);
        let est: Vec<u32> = (0..experts).map(|_| g.u32(0, 1000)).collect();
        let threshold = g.f64(1.0, 2.0);
        let Some(plan) = plan_migration(&current, policy, &est, threshold) else { return };
        assert!(
            plan.post_imbalance < plan.pre_imbalance,
            "plan must predict improvement: {} -> {}",
            plan.pre_imbalance,
            plan.post_imbalance
        );
        assert!(plan.pre_imbalance > threshold * plan.post_imbalance);
        assert!(!plan.moves.is_empty());
        // placement validity + expert-slot cap on home ranks
        assert_eq!(plan.placement.expert_ranks.len(), experts as usize);
        let cap = (experts as usize).div_ceil(ranks as usize);
        let mut homes = vec![0usize; ranks as usize];
        for hosts in &plan.placement.expert_ranks {
            assert!(!hosts.is_empty());
            assert!(hosts.iter().all(|&h| h < ranks));
            homes[hosts[0] as usize] += 1;
        }
        assert!(homes.iter().all(|&c| c <= cap), "slot cap violated: {homes:?}");
        // moves are consistent with the diff
        for m in &plan.moves {
            assert_ne!(m.from, m.to);
            assert_eq!(current.expert_ranks[m.expert as usize][0], m.from);
            assert!(plan.placement.expert_ranks[m.expert as usize].contains(&m.to));
        }
        // token conservation through the new placement
        let routed: u64 = est.iter().map(|&x| x as u64).sum();
        assert_eq!(plan.placement.rank_totals(&est).iter().sum::<u64>(), routed);
        // stability under stationary load
        assert!(
            plan_migration(&plan.placement, policy, &est, threshold).is_none(),
            "adopted placement must be a fixed point"
        );
    });
}

#[test]
fn prop_balanced_contiguous_has_zero_cross_rank_variance() {
    // when the routed-token total divides the expert count and experts
    // divide across ranks, Balanced routing + Contiguous placement puts
    // exactly the same load on every rank
    run_prop("balanced contiguous zero variance", 150, |g| {
        let ranks = g.u32(1, 8);
        let per_rank = g.u32(1, 8);
        let experts = ranks * per_rank;
        let tokens = experts * g.u32(1, 32);
        let k = g.u32(1, 4).min(experts);
        let mut rng = Pcg64::new(g.seed);
        let loads = assign_tokens(RoutingPolicy::Balanced, tokens, experts, k, &mut rng);
        let topo = EpTopology::new(ranks, g.u32(1, ranks));
        let p = ExpertPlacement::build(PlacementPolicy::Contiguous, experts, topo, None);
        let totals = p.rank_totals(&loads);
        assert!(
            totals.windows(2).all(|w| w[0] == w[1]),
            "seed {}: uneven rank loads {totals:?}",
            g.seed
        );
        if tokens > 0 {
            assert!((rank_imbalance(&totals) - 1.0).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_oracle_times_positive_finite_monotone() {
    run_prop("oracle sanity", 150, |g| {
        let gpu = frontier::hardware::GpuSpec::a800();
        let ctx = g.skewed_lens(64, 32768);
        let h = *g.pick(&[16u32, 28, 32, 64]);
        let hkv = *g.pick(&[4u32, 8, 16]);
        let t = frontier::oracle::attn_decode_time(&ctx, h, hkv.min(h), 128, 2, &gpu);
        assert!(t > 0.0 && t.is_finite());
        // doubling every context cannot make it faster
        let ctx2: Vec<u32> = ctx.iter().map(|&c| c * 2).collect();
        let t2 = frontier::oracle::attn_decode_time(&ctx2, h, hkv.min(h), 128, 2, &gpu);
        assert!(t2 >= t * 0.999, "t={t} t2={t2}");
    });
}

#[test]
fn prop_simulation_conserves_requests_and_tokens() {
    // end-to-end conservation across random small deployments: every
    // admitted request completes, token accounting is exact
    run_prop("request/token conservation", 12, |g| {
        let n = g.u32(4, 24);
        let output = g.u32(1, 24);
        let mode = g.u32(0, 2);
        let w = WorkloadSpec {
            arrival: if g.bool() {
                Arrival::Batch
            } else {
                Arrival::Poisson { rate: 20.0 }
            },
            input: LenDist::Uniform { lo: 16, hi: 512 },
            output: LenDist::Fixed(output),
            n_requests: n,
            seed: g.seed,
            classes: vec![],
            trace: None,
        };
        let model =
            if g.bool() { ModelConfig::tiny() } else { ModelConfig::tiny_moe() };
        let cfg = match mode {
            0 => ExperimentConfig::colocated(model, g.u32(1, 3)),
            1 => ExperimentConfig::pd(model, 1, g.u32(1, 2)),
            _ => ExperimentConfig::af(model, 1, 2, 2, g.u32(1, 4)),
        }
        .with_workload(w)
        .with_seed(g.seed);
        let report = frontier::run_experiment(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, n as u64);
        assert_eq!(report.metrics.output_tokens, n as u64 * output as u64);
        assert_eq!(report.metrics.ttft.count(), n as u64);
        assert_eq!(report.metrics.e2e.count(), n as u64);
        // TTFT <= e2e pairwise is not directly paired here, but means are
        assert!(
            report.metrics.ttft.mean()
                <= report.metrics.e2e.mean() + 1e-12
        );
    });
}

#[test]
fn prop_simulation_deterministic_under_seed() {
    run_prop("determinism", 6, |g| {
        let cfg = ExperimentConfig::pd(ModelConfig::tiny_moe(), 1, 1)
            .with_workload(WorkloadSpec::poisson(10.0, 16, 128, 8).with_seed(g.seed))
            .with_seed(g.seed);
        let a = frontier::run_experiment(&cfg).unwrap();
        let b = frontier::run_experiment(&cfg).unwrap();
        assert_eq!(a.sim_duration, b.sim_duration);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.metrics.ttft, b.metrics.ttft);
    });
}

#[test]
fn prop_memory_pressure_never_loses_requests() {
    // shrink the decode pool arbitrarily: backpressure may slow things
    // down but every request must still complete exactly once
    run_prop("backpressure safety", 8, |g| {
        let mut cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1).with_workload(
            WorkloadSpec {
                arrival: Arrival::Batch,
                input: LenDist::Fixed(g.u32(256, 4096)),
                output: LenDist::Fixed(g.u32(4, 32)),
                n_requests: g.u32(8, 32),
                seed: g.seed,
                classes: vec![],
                trace: None,
            },
        );
        cfg.policy = PolicyConfig {
            kv_reserve_frac: g.f64(0.9, 0.998),
            ..PolicyConfig::default()
        };
        let n = cfg.workload.n_requests as u64;
        let report = frontier::run_experiment(&cfg).unwrap();
        // no deadlock, exact conservation: every request either completes
        // or is rejected by admission control (too big for the decode
        // pool), never stuck in the transfer queue
        assert_eq!(report.metrics.completed_requests + report.metrics.rejected_requests, n);
        if report.metrics.rejected_requests > 0 {
            // rejections only legitimate when a single request exceeds
            // the starved pool's total capacity
            let blocks_per_req = (cfg.workload.input.mean() + cfg.workload.output.mean()) / 16.0;
            let pool = frontier::memory::BlockManager::from_budget(
                80 * (1 << 30),
                frontier::model::ModelConfig::tiny().weight_bytes_per_gpu(1, 1),
                frontier::model::ModelConfig::tiny().kv_bytes_per_token(),
                cfg.policy.kv_reserve_frac,
            );
            assert!(
                blocks_per_req * 0.5 > pool.total_blocks() as f64 * 0.1,
                "rejections at seed {} look spurious: ~{blocks_per_req:.0} blocks/req vs pool {}",
                g.seed,
                pool.total_blocks()
            );
        }
    });
}

#[test]
fn prop_fault_plan_recoveries_follow_failures() {
    // the materialized schedule is the determinism anchor for the
    // dynamics layer: per replica it must strictly alternate
    // failure -> recovery (never a recovery first), stay time-sorted,
    // and end every replica healthy (trailing recovery)
    use frontier::cluster::dynamics::{build_plan, FaultSpec};
    use frontier::core::SimTime;
    run_prop("fault plan ordering", 100, |g| {
        let spec = FaultSpec::Mttf {
            mttf_s: g.f64(1.0, 100.0),
            mttr_s: g.f64(0.5, 30.0),
        };
        let shape: Vec<u32> = (0..g.u32(1, 3)).map(|_| g.u32(1, 4)).collect();
        let plan = build_plan(Some(&spec), None, None, &shape, g.seed, g.f64(10.0, 500.0));
        assert!(plan.faults.windows(2).all(|w| w[0].at <= w[1].at), "schedule sorted");
        for (s, &n) in shape.iter().enumerate() {
            for r in 0..n as usize {
                let evs: Vec<_> = plan
                    .faults
                    .iter()
                    .filter(|f| f.stage == s && f.replica == r)
                    .collect();
                let mut t = SimTime::ZERO;
                for (i, f) in evs.iter().enumerate() {
                    assert_eq!(f.up, i % 2 == 1, "recovery must follow its failure");
                    assert!(f.at > t, "per-replica times strictly increase");
                    t = f.at;
                }
                assert_eq!(evs.len() % 2, 0, "no replica ends the run down");
            }
            let last_up = plan
                .faults
                .iter()
                .filter(|f| f.stage == s && f.up)
                .map(|f| f.at)
                .max()
                .unwrap_or(SimTime::ZERO);
            assert_eq!(plan.revive_after[s], last_up, "revive_after covers the last recovery");
        }
        // same inputs, same plan; different seed, different plan
        let again = build_plan(Some(&spec), None, None, &shape, g.seed, 500.0);
        let other = build_plan(Some(&spec), None, None, &shape, g.seed ^ 1, 500.0);
        if !plan.faults.is_empty() {
            assert_ne!(again.faults, other.faults, "seed must matter");
        }
    });
}

#[test]
fn prop_faulted_simulation_conserves_requests() {
    // failures displace and may reject requests, but nothing vanishes
    // and nothing completes twice — for random deployments, workloads,
    // and fault schedules
    use frontier::cluster::dynamics::FaultSpec;
    run_prop("fault conservation", 8, |g| {
        let n = g.u32(8, 24);
        let w = WorkloadSpec {
            arrival: Arrival::Poisson { rate: 30.0 },
            input: LenDist::Uniform { lo: 16, hi: 128 },
            output: LenDist::Fixed(g.u32(2, 12)),
            n_requests: n,
            seed: g.seed,
            classes: vec![],
            trace: None,
        };
        let spec = FaultSpec::Mttf {
            mttf_s: g.f64(2.0, 10.0),
            mttr_s: g.f64(0.5, 3.0),
        };
        let base = if g.bool() {
            ExperimentConfig::pd(ModelConfig::tiny(), 2, 2)
        } else {
            ExperimentConfig::colocated(ModelConfig::tiny(), 2)
        };
        let cfg = base.with_workload(w).with_seed(g.seed).with_faults(spec);
        let rep = frontier::run_experiment(&cfg).unwrap();
        let m = &rep.metrics;
        assert_eq!(
            m.completed_requests + m.rejected_requests,
            n as u64,
            "conservation across failures"
        );
        assert!(m.fault_recoveries <= m.faults, "a recovery needs a failure");
        assert!((0.0..=1.0).contains(&rep.availability()));
        assert!(m.fault_affected_slo_miss <= m.fault_affected_completed);
        // deterministic under the same seed even with faults
        let again = frontier::run_experiment(&cfg).unwrap();
        assert_eq!(rep.metrics.ttft, again.metrics.ttft);
        assert_eq!(rep.sim_duration, again.sim_duration);
    });
}

#[test]
fn prop_link_faulted_simulation_conserves_requests() {
    // link brownouts and partitions reroute, stall, or reject KV
    // transfers, but nothing vanishes and nothing completes twice —
    // for random workloads and link schedules, on the tier the KV
    // handoff actually rides
    use frontier::cluster::dynamics::{LinkFaultEvent, LinkFaultKind, LinkFaultSpec, LinkTarget};
    use frontier::config::{StageConfig, StageGraphConfig};
    use frontier::cluster::StageKind;
    use frontier::network::Tier;
    run_prop("link fault conservation", 8, |g| {
        let n = g.u32(8, 24);
        let w = WorkloadSpec {
            arrival: Arrival::Poisson { rate: 30.0 },
            input: LenDist::Uniform { lo: 16, hi: 128 },
            output: LenDist::Fixed(g.u32(2, 12)),
            n_requests: n,
            seed: g.seed,
            classes: vec![],
            trace: None,
        };
        // prefill -> cross-cluster decode: the handoff crosses the WAN
        let graph = StageGraphConfig::new(vec![
            StageConfig::new(StageKind::Prefill, 2),
            StageConfig::new(StageKind::Decode, 2).in_cluster(1),
        ]);
        let spec = if g.bool() {
            LinkFaultSpec::Mttf {
                mttf_s: g.f64(1.0, 5.0),
                mttr_s: g.f64(0.5, 2.0),
                bw_frac: if g.bool() { Some(g.f64(0.1, 0.9)) } else { None },
            }
        } else {
            // outage window over the WAN tier; half the draws never heal
            // (transfers must reject as backpressure, not stall the run)
            let down_at = g.f64(0.0, 2.0);
            let mut evs = vec![LinkFaultEvent {
                t_s: down_at,
                target: LinkTarget::Tier(Tier::CrossCluster),
                kind: LinkFaultKind::Down,
            }];
            if g.bool() {
                evs.push(LinkFaultEvent {
                    t_s: down_at + g.f64(0.5, 3.0),
                    target: LinkTarget::Tier(Tier::CrossCluster),
                    kind: LinkFaultKind::Up,
                });
            }
            LinkFaultSpec::List(evs)
        };
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 1)
            .with_stages(graph)
            .with_workload(w)
            .with_seed(g.seed)
            .with_link_faults(spec);
        let rep = frontier::run_experiment(&cfg).unwrap();
        let m = &rep.metrics;
        assert_eq!(
            m.completed_requests + m.rejected_requests,
            n as u64,
            "conservation across link faults"
        );
        assert!(m.link_recoveries <= m.link_faults, "a recovery needs a fault");
        assert!(m.link_affected_slo_miss <= m.link_affected_completed);
        assert!(m.link_degraded_s.iter().all(|&s| s >= 0.0));
        // deterministic under the same seed even with link faults
        let again = frontier::run_experiment(&cfg).unwrap();
        assert_eq!(rep.metrics.ttft, again.metrics.ttft);
        assert_eq!(rep.sim_duration, again.sim_duration);
    });
}

#[test]
fn prop_autoscaled_simulation_stays_in_band() {
    // the control loop acts at most once per tick per pool and never
    // loses requests, for random policies, cadences, and loads
    use frontier::cluster::dynamics::{AutoscaleSpec, ScalePolicy};
    run_prop("autoscale bounds", 8, |g| {
        let n = g.u32(8, 32);
        let policy = *g.pick(&[ScalePolicy::Reactive, ScalePolicy::Predictive]);
        let mut auto = AutoscaleSpec::new(policy, 1, g.u32(2, 5));
        auto.interval_s = g.f64(0.2, 2.0);
        auto.provision_s = g.f64(0.2, 2.0);
        auto.warmup_s = g.f64(0.0, 1.0);
        let w = WorkloadSpec {
            arrival: Arrival::Poisson { rate: g.f64(20.0, 120.0) },
            input: LenDist::Uniform { lo: 16, hi: 128 },
            output: LenDist::Fixed(g.u32(2, 12)),
            n_requests: n,
            seed: g.seed,
            classes: vec![],
            trace: None,
        };
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 2)
            .with_workload(w)
            .with_seed(g.seed)
            .with_autoscale(auto);
        let rep = frontier::run_experiment(&cfg).unwrap();
        let m = &rep.metrics;
        assert_eq!(m.completed_requests + m.rejected_requests, n as u64);
        assert!(m.scale_ticks > 0, "the loop must have run");
        // one grow decision per tick per pool, one drain per tick per
        // pool — the loop can never act more often than it evaluates
        assert!(m.scale_up_events <= m.scale_ticks);
        assert!(m.scale_down_events <= m.scale_ticks);
        // the report presents the deployed shape, not headroom slots
        assert_eq!(rep.stages[1].replicas, 2);
    });
}
