//! End-to-end coverage of the open-loop workload engine and the
//! streaming SLO metrics path: a multi-class traffic day runs through
//! the real coordinator, per-class stats and SLO goodput land in the
//! report, trace round-trips reproduce the run, and the collector's
//! memory stays bounded.

use frontier::config::ExperimentConfig;
use frontier::metrics::SloSpec;
use frontier::model::ModelConfig;
use frontier::workload::{trace_to_text, WorkloadSpec};

fn day_cfg(n: u32) -> ExperimentConfig {
    ExperimentConfig::colocated(ModelConfig::tiny(), 2)
        .with_workload(WorkloadSpec::traffic_day(40.0, n))
        .with_slo(SloSpec { ttft_s: Some(2.0), tbt_s: Some(0.2), e2e_s: None })
}

#[test]
fn traffic_day_completes_with_streaming_metrics() {
    let n = 400u32;
    let r = frontier::run_experiment(&day_cfg(n)).unwrap();
    let m = &r.metrics;
    assert_eq!(
        m.completed_requests + m.rejected_requests,
        n as u64,
        "every offered request must be accounted for"
    );
    assert!(m.completed_requests > 0);
    // the 4 classes all saw traffic and were tracked separately
    assert_eq!(m.per_class.len(), 4);
    assert_eq!(m.class_names, ["chat", "rag", "agentic", "batch"]);
    assert!(m.per_class.iter().all(|c| c.completed > 0), "all classes complete requests");
    let per_class_total: u64 = m.per_class.iter().map(|c| c.completed).sum();
    assert_eq!(per_class_total, m.completed_requests);
    // SLO accounting is consistent
    assert!(m.slo_ok <= m.completed_requests);
    assert!(r.slo_attainment() <= 1.0);
    assert!(r.goodput() <= r.requests_per_sec() + 1e-9);
    // streaming collector: raw vectors off, digests and time series
    // bounded regardless of n
    assert!(m.raw.is_none());
    assert!(m.ttft.centroids() + m.ttft.buffered() <= 1024);
    assert!(m.timeseries.buckets.len() > 1, "an open-loop day spans multiple buckets");
    assert!(m.timeseries.buckets.len() <= frontier::metrics::TS_MAX_BUCKETS);
    // the JSON projection carries the new sections
    let j = r.to_json();
    assert!(j.get("goodput_rps").is_some());
    assert!(j.get("slo_attainment").is_some());
    let classes = j.req("classes").unwrap().as_arr().unwrap();
    assert_eq!(classes.len(), 4);
    assert_eq!(classes[0].req("name").unwrap().as_str().unwrap(), "chat");
    assert!(j.get("timeseries").is_some());
}

#[test]
fn tighter_slos_monotonically_reduce_goodput() {
    let mut loose = day_cfg(200);
    loose.slo = SloSpec { ttft_s: Some(1e6), tbt_s: Some(1e6), e2e_s: None };
    let mut tight = day_cfg(200);
    tight.slo = SloSpec { ttft_s: Some(1e-6), tbt_s: Some(1e-6), e2e_s: None };
    let r_loose = frontier::run_experiment(&loose).unwrap();
    let r_tight = frontier::run_experiment(&tight).unwrap();
    // identical simulations (SLOs are observational, never control)
    assert_eq!(r_loose.sim_duration, r_tight.sim_duration);
    assert_eq!(r_loose.events_processed, r_tight.events_processed);
    assert_eq!(r_loose.metrics.completed_requests, r_tight.metrics.completed_requests);
    // attainment orders correctly: everything meets the loose SLO,
    // (essentially) nothing the impossible one
    assert_eq!(r_loose.metrics.slo_ok, r_loose.metrics.completed_requests);
    assert!(r_tight.metrics.slo_ok < r_loose.metrics.slo_ok);
    assert!(r_tight.goodput() < r_loose.goodput());
}

#[test]
fn trace_round_trip_reproduces_the_run() {
    let cfg = day_cfg(150);
    let trace = cfg.workload.materialize().unwrap();
    let path = std::env::temp_dir().join("frontier_workload_slo_roundtrip.trace");
    std::fs::write(&path, trace_to_text(&trace)).unwrap();

    let direct = frontier::run_experiment(&cfg).unwrap();
    let mut replay_cfg = cfg.clone();
    replay_cfg.workload = WorkloadSpec::from_trace(path.clone());
    let replayed = frontier::run_experiment(&replay_cfg).unwrap();
    std::fs::remove_file(&path).ok();

    // the text format rounds arrivals to 1us, so metrics match to that
    // tolerance rather than bit-exactly
    assert_eq!(direct.metrics.completed_requests, replayed.metrics.completed_requests);
    assert_eq!(direct.metrics.output_tokens, replayed.metrics.output_tokens);
    assert_eq!(direct.metrics.prefill_tokens, replayed.metrics.prefill_tokens);
    assert!((direct.sim_duration - replayed.sim_duration).abs() < 1e-3);
    // classes survive the round trip: per-class completion counts agree
    assert_eq!(replayed.metrics.per_class.len(), direct.metrics.per_class.len());
    for (a, b) in direct.metrics.per_class.iter().zip(&replayed.metrics.per_class) {
        assert_eq!(a.completed, b.completed);
    }
}

#[test]
fn corrupt_traces_fail_at_config_time_not_mid_run() {
    let dir = std::env::temp_dir();
    for (name, body) in [
        ("frontier_bad_trace_unsorted.trace", "0.5 10 10 0\n0.1 10 10 0\n"),
        ("frontier_bad_trace_negative.trace", "-1.0 10 10 0\n"),
        ("frontier_bad_trace_nan.trace", "nan 10 10 0\n"),
        ("frontier_bad_trace_zero_len.trace", "0.0 0 10 0\n"),
        ("frontier_bad_trace_garbage.trace", "hello world\n"),
        ("frontier_bad_trace_empty.trace", "# only a comment\n"),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, body).unwrap();
        let mut cfg = day_cfg(10);
        cfg.workload = WorkloadSpec::from_trace(path.clone());
        let err = frontier::run_experiment(&cfg);
        std::fs::remove_file(&path).ok();
        assert!(err.is_err(), "{name} must be rejected");
    }
    // a missing file is an error too, not an empty run
    let mut cfg = day_cfg(10);
    cfg.workload = WorkloadSpec::from_trace(dir.join("frontier_no_such_trace.trace"));
    assert!(frontier::run_experiment(&cfg).is_err());
}

#[test]
fn single_class_presets_run_and_keep_flat_runs_intact() {
    for preset in ["chat", "rag", "agentic", "batch"] {
        let w = WorkloadSpec::parse_spec(preset, 40).unwrap();
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 2).with_workload(w);
        let r = frontier::run_experiment(&cfg).unwrap();
        assert_eq!(
            r.metrics.completed_requests + r.metrics.rejected_requests,
            40,
            "preset {preset}"
        );
        assert_eq!(r.metrics.per_class.len(), 1, "preset {preset}");
    }
    // legacy flat workloads still produce the same stream: identical
    // runs stay bit-identical run-to-run (guards the RNG plumbing
    // around the new class machinery)
    let flat = ExperimentConfig::colocated(ModelConfig::tiny(), 2)
        .with_workload(WorkloadSpec::poisson(20.0, 64, 128, 32));
    let a = frontier::run_experiment(&flat).unwrap();
    let b = frontier::run_experiment(&flat).unwrap();
    assert_eq!(a.sim_duration, b.sim_duration);
    assert_eq!(a.metrics.ttft, b.metrics.ttft);
}
