//! EP placement & cross-cluster routing integration: the AF decode
//! pool's step times must be data-dependent on routing skew and on the
//! cluster span of the expert tier.

use frontier::config::ExperimentConfig;
use frontier::hardware::LinkSpec;
use frontier::model::ModelConfig;
use frontier::moe::{PlacementPolicy, RoutingPolicy};
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn af_cfg(routing: RoutingPolicy, clusters: u32, placement: PlacementPolicy) -> ExperimentConfig {
    ExperimentConfig::af(ModelConfig::tiny_moe(), 1, 2, 4, 2)
        .with_workload(WorkloadSpec {
            arrival: Arrival::Batch,
            input: LenDist::Fixed(128),
            output: LenDist::Fixed(24),
            n_requests: 24,
            seed: 11,
            classes: vec![],
            trace: None,
        })
        .with_seed(11)
        .with_moe_routing(routing)
        .with_ep_placement(placement)
        .with_ep_clusters(clusters, LinkSpec::cross_cluster())
}

#[test]
fn skewed_routing_strictly_increases_af_step_time() {
    let balanced = frontier::run_experiment(&af_cfg(
        RoutingPolicy::Balanced,
        1,
        PlacementPolicy::Contiguous,
    ))
    .unwrap();
    let skewed = frontier::run_experiment(&af_cfg(
        RoutingPolicy::Skewed { alpha: 0.05 },
        1,
        PlacementPolicy::Contiguous,
    ))
    .unwrap();
    assert_eq!(balanced.metrics.completed_requests, 24);
    assert_eq!(skewed.metrics.completed_requests, 24);
    assert!(
        skewed.sim_duration > balanced.sim_duration,
        "skewed {:.4}s must exceed balanced {:.4}s",
        skewed.sim_duration,
        balanced.sim_duration
    );
    // the imbalance metric explains the gap
    let bal_imb = balanced.metrics.ep_imbalance_mean();
    let skew_imb = skewed.metrics.ep_imbalance_mean();
    assert!(skew_imb > bal_imb, "imbalance {skew_imb:.3} vs {bal_imb:.3}");
}

#[test]
fn cross_cluster_placement_costs_at_least_intra() {
    // identical seed + workload => identical routing draws; only the
    // cluster span of the EP domain differs
    let intra = frontier::run_experiment(&af_cfg(
        RoutingPolicy::UniformRandom,
        1,
        PlacementPolicy::Contiguous,
    ))
    .unwrap();
    let cross = frontier::run_experiment(&af_cfg(
        RoutingPolicy::UniformRandom,
        2,
        PlacementPolicy::Contiguous,
    ))
    .unwrap();
    assert!(
        cross.sim_duration >= intra.sim_duration,
        "cross-cluster {:.4}s must not beat intra-cluster {:.4}s",
        cross.sim_duration,
        intra.sim_duration
    );
    assert_eq!(intra.metrics.ep_cross_frac(), 0.0);
    assert!(cross.metrics.ep_cross_frac() > 0.0);
}

#[test]
fn placement_policy_changes_traffic_shape() {
    // with 2 clusters and skewed routing, strided placement spreads the
    // hot experts differently from contiguous; both must complete the
    // workload and report EP traffic
    let contiguous = frontier::run_experiment(&af_cfg(
        RoutingPolicy::Skewed { alpha: 0.1 },
        2,
        PlacementPolicy::Contiguous,
    ))
    .unwrap();
    let strided = frontier::run_experiment(&af_cfg(
        RoutingPolicy::Skewed { alpha: 0.1 },
        2,
        PlacementPolicy::Strided,
    ))
    .unwrap();
    let replicated = frontier::run_experiment(&af_cfg(
        RoutingPolicy::Skewed { alpha: 0.1 },
        2,
        PlacementPolicy::ReplicatedHot { hot: 2 },
    ))
    .unwrap();
    for r in [&contiguous, &strided, &replicated] {
        assert_eq!(r.metrics.completed_requests, 24);
        assert!(r.metrics.ep_bytes > 0.0);
    }
    // identical routing draws (same seed): placement alone must move the
    // simulated economics — at least one of time / cross-fraction shifts
    let moved = (contiguous.sim_duration - strided.sim_duration).abs() > 1e-9
        || (contiguous.metrics.ep_cross_frac() - strided.metrics.ep_cross_frac()).abs() > 1e-9;
    assert!(moved, "contiguous and strided placements are indistinguishable");
}

#[test]
fn colocated_moe_reports_ep_traffic() {
    // the EP path also engages on co-located replicas with ep > 1
    let mut cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
        .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 4))
        .with_workload(WorkloadSpec::table2(8, 64, 8));
    cfg.ep_clusters = 2;
    let r = frontier::run_experiment(&cfg).unwrap();
    assert_eq!(r.metrics.completed_requests, 8);
    assert!(r.metrics.ep_bytes > 0.0);
    assert!(r.metrics.ep_cross_frac() > 0.0);
    assert!(r.metrics.op_time.contains_key("ep_dispatch"));
}
