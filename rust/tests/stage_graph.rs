//! Stage-graph integration: heterogeneous multi-stage deployments
//! (PD+AF hybrid, heterogeneous-GPU PD, fan-out) and the oracle parity
//! pin — a 1-stage graph must bit-reproduce the legacy co-located path.

use frontier::cluster::StageKind;
use frontier::config::{
    ExperimentConfig, FlowKind, StageConfig, StageEdge, StageGraphConfig,
};
use frontier::hardware::GpuSpec;
use frontier::model::ModelConfig;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn fixed_workload(n: u32, input: u32, output: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Fixed(input),
        output: LenDist::Fixed(output),
        n_requests: n,
        seed: 7,
        classes: vec![],
        trace: None,
    }
}

#[test]
fn one_stage_graph_bit_reproduces_colocated() {
    // the acceptance-criterion parity pin: an explicit 1-stage unified
    // graph must give bit-identical results to the legacy mode enum
    for model in [ModelConfig::tiny(), ModelConfig::tiny_moe()] {
        let legacy = ExperimentConfig::colocated(model.clone(), 2)
            .with_workload(WorkloadSpec::table2(24, 64, 16));
        let graph = ExperimentConfig::from_stages(
            model,
            StageGraphConfig::new(vec![StageConfig::new(StageKind::Unified, 2)]),
        )
        .with_workload(WorkloadSpec::table2(24, 64, 16));
        let a = frontier::run_experiment(&legacy).unwrap();
        let b = frontier::run_experiment(&graph).unwrap();
        assert_eq!(a.sim_duration, b.sim_duration, "sim duration must be bit-identical");
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.metrics.output_tokens, b.metrics.output_tokens);
        assert_eq!(a.metrics.ttft, b.metrics.ttft);
        assert_eq!(a.metrics.tbt, b.metrics.tbt);
        assert_eq!(a.metrics.e2e, b.metrics.e2e);
    }
}

#[test]
fn two_stage_graph_bit_reproduces_legacy_pd() {
    let w = fixed_workload(24, 128, 16);
    let legacy = ExperimentConfig::pd(ModelConfig::tiny(), 1, 2).with_workload(w.clone());
    let graph = ExperimentConfig::from_stages(
        ModelConfig::tiny(),
        StageGraphConfig::new(vec![
            StageConfig::new(StageKind::Prefill, 1),
            StageConfig::new(StageKind::Decode, 2),
        ]),
    )
    .with_workload(w);
    let a = frontier::run_experiment(&legacy).unwrap();
    let b = frontier::run_experiment(&graph).unwrap();
    assert_eq!(a.sim_duration, b.sim_duration);
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.metrics.kv_transfers, b.metrics.kv_transfers);
    assert_eq!(a.metrics.ttft, b.metrics.ttft);
}

#[test]
fn pd_af_hybrid_end_to_end() {
    // prefill pool feeding an attention/FFN decode pair with a
    // cross-cluster expert tier — the PD+AF hybrid the flat mode enum
    // could not express
    let mut graph = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 2).named("prefill"),
        StageConfig::af_stage(2, 4, 2).named("af"),
    ]);
    graph.stages[1].ep_clusters = Some(2);
    let n = 24u32;
    let output = 16u32;
    let cfg = ExperimentConfig::from_stages(ModelConfig::tiny_moe(), graph)
        .with_workload(fixed_workload(n, 128, output))
        .with_seed(11);
    let r = frontier::run_experiment(&cfg).unwrap();
    // completion + conservation of tokens
    assert_eq!(r.metrics.completed_requests, n as u64);
    assert_eq!(r.metrics.rejected_requests, 0);
    assert_eq!(r.metrics.output_tokens, n as u64 * output as u64);
    // every request crossed the prefill->af boundary exactly once
    assert_eq!(r.metrics.kv_transfers, n as u64);
    // the AF stage's MoE tier engaged the EP fabric across clusters
    assert!(r.metrics.ep_bytes > 0.0);
    assert!(r.metrics.ep_cross_frac() > 0.0);
    // per-stage metrics in the report
    assert_eq!(r.stages.len(), 2);
    assert_eq!(r.stages[0].kind, "prefill");
    assert_eq!(r.stages[1].kind, "af");
    assert!(r.stages[0].iterations > 0 && r.stages[1].iterations > 0);
    assert!(r.stages[0].tokens > 0 && r.stages[1].tokens > 0);
    assert_eq!(r.mode, "stage-graph");
    // determinism under seed
    let r2 = frontier::run_experiment(&cfg).unwrap();
    assert_eq!(r.sim_duration, r2.sim_duration);
    assert_eq!(r.events_processed, r2.events_processed);
    assert_eq!(r.metrics.ttft, r2.metrics.ttft);
}

#[test]
fn heterogeneous_gpu_pd_end_to_end() {
    let n = 32u32;
    let output = 12u32;
    let mk = |prefill_gpu: GpuSpec| {
        let graph = StageGraphConfig::new(vec![
            StageConfig::new(StageKind::Prefill, 1).on_gpu(prefill_gpu),
            StageConfig::new(StageKind::Decode, 1).on_gpu(GpuSpec::a800()),
        ]);
        ExperimentConfig::from_stages(ModelConfig::qwen2_7b(), graph)
            .with_workload(fixed_workload(n, 1024, output))
    };
    let slow = frontier::run_experiment(&mk(GpuSpec::a800())).unwrap();
    let fast = frontier::run_experiment(&mk(GpuSpec::h100())).unwrap();
    for r in [&slow, &fast] {
        assert_eq!(r.metrics.completed_requests, n as u64);
        assert_eq!(r.metrics.output_tokens, n as u64 * output as u64);
        assert_eq!(r.metrics.kv_transfers, n as u64);
    }
    // the H100 prefill pool is strictly faster silicon: prefill-bound
    // TTFT must improve while the shared A800 decode stage pins TBT
    let slow_ttft = slow.metrics.ttft.mean();
    let fast_ttft = fast.metrics.ttft.mean();
    assert!(
        fast_ttft < slow_ttft,
        "H100 prefill TTFT {fast_ttft:.4}s must beat A800 {slow_ttft:.4}s"
    );
    // determinism under seed
    let again = frontier::run_experiment(&mk(GpuSpec::h100())).unwrap();
    assert_eq!(fast.sim_duration, again.sim_duration);
    assert_eq!(fast.metrics.e2e, again.metrics.e2e);
    // per-stage report names the hardware
    assert_eq!(fast.stages[0].gpu_name, "H100-SXM5-80GB");
    assert_eq!(fast.stages[1].gpu_name, "A800-SXM4-80GB");
}

#[test]
fn multi_decode_fan_out_spreads_handoffs() {
    let n = 32u32;
    let graph = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 2).named("prefill"),
        StageConfig::new(StageKind::Decode, 1).named("d0"),
        StageConfig::new(StageKind::Decode, 1).named("d1"),
    ]);
    // auto-wiring fans the prefill stage out to both decode pools
    let cfg = ExperimentConfig::from_stages(ModelConfig::tiny(), graph)
        .with_workload(fixed_workload(n, 256, 16));
    let r = frontier::run_experiment(&cfg).unwrap();
    assert_eq!(r.metrics.completed_requests, n as u64);
    assert_eq!(r.metrics.kv_transfers, n as u64);
    // most-free-memory dispatch must use both pools
    let d0 = &r.stages[1];
    let d1 = &r.stages[2];
    assert!(
        d0.tokens > 0 && d1.tokens > 0,
        "fan-out must engage both decode pools: {} / {} tokens",
        d0.tokens,
        d1.tokens
    );
}

#[test]
fn per_stage_budget_overrides_apply() {
    // capping the decode stage at batch=1 forces serial decoding there:
    // strictly more decode iterations than the unconstrained run
    let mk = |max_batch: Option<usize>| {
        let mut decode = StageConfig::new(StageKind::Decode, 1);
        if let Some(b) = max_batch {
            decode.budget = Some(frontier::scheduler::IterBudget {
                max_batch: b,
                ..Default::default()
            });
        }
        let graph = StageGraphConfig::new(vec![
            StageConfig::new(StageKind::Prefill, 1),
            decode,
        ]);
        ExperimentConfig::from_stages(ModelConfig::tiny(), graph)
            .with_workload(fixed_workload(8, 64, 8))
    };
    let free = frontier::run_experiment(&mk(None)).unwrap();
    let capped = frontier::run_experiment(&mk(Some(1))).unwrap();
    assert_eq!(capped.metrics.completed_requests, 8);
    assert!(
        capped.metrics.iterations > free.metrics.iterations,
        "batch=1 decode must iterate more: {} vs {}",
        capped.metrics.iterations,
        free.metrics.iterations
    );
    assert!(capped.sim_duration > free.sim_duration);
}

#[test]
fn wan_placed_stages_pay_the_trunk_on_handoff() {
    // same PD shape, but the decode pool lives in another cluster: KV
    // handoff rides the WAN tier instead of NVLink, inflating TTFT-to-
    // first-decode latency while completing the same work
    let mk = |decode_cluster: u32| {
        let graph = StageGraphConfig::new(vec![
            StageConfig::new(StageKind::Prefill, 1),
            StageConfig::new(StageKind::Decode, 1).in_cluster(decode_cluster),
        ]);
        ExperimentConfig::from_stages(ModelConfig::tiny(), graph)
            .with_workload(fixed_workload(16, 2048, 8))
    };
    let local = frontier::run_experiment(&mk(0)).unwrap();
    let remote = frontier::run_experiment(&mk(1)).unwrap();
    assert_eq!(local.metrics.completed_requests, 16);
    assert_eq!(remote.metrics.completed_requests, 16);
    assert_eq!(local.metrics.kv_bytes, remote.metrics.kv_bytes);
    assert!(
        remote.sim_duration > local.sim_duration,
        "WAN handoff {:.4}s must cost more than NVLink {:.4}s",
        remote.sim_duration,
        local.sim_duration
    );
}

#[test]
fn inter_node_stage_placement_sits_between_nvlink_and_wan() {
    let mk = |cluster: u32, node: u32| {
        let graph = StageGraphConfig::new(vec![
            StageConfig::new(StageKind::Prefill, 1),
            StageConfig::new(StageKind::Decode, 1).in_cluster(cluster).on_node(node),
        ]);
        ExperimentConfig::from_stages(ModelConfig::tiny(), graph)
            .with_workload(fixed_workload(12, 4096, 4))
    };
    let nv = frontier::run_experiment(&mk(0, 0)).unwrap().sim_duration;
    let ib = frontier::run_experiment(&mk(0, 1)).unwrap().sim_duration;
    let wan = frontier::run_experiment(&mk(1, 0)).unwrap().sim_duration;
    assert!(nv < ib, "NVLink handoff {nv:.4}s must beat IB {ib:.4}s");
    assert!(ib < wan, "IB handoff {ib:.4}s must beat WAN {wan:.4}s");
}

#[test]
fn capacity_factor_drops_surface_in_reports() {
    let mk = |cf: Option<f64>| {
        let mut cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
            .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 4))
            .with_workload(fixed_workload(16, 128, 8));
        cfg.policy.moe_routing = frontier::moe::RoutingPolicy::Skewed { alpha: 0.05 };
        cfg.policy.capacity_factor = cf;
        cfg
    };
    let capped = frontier::run_experiment(&mk(Some(1.0))).unwrap();
    assert_eq!(capped.metrics.completed_requests, 16);
    assert!(capped.metrics.dropped_tokens > 0, "skewed cf=1.0 must drop");
    let json = capped.to_json();
    assert!(json.req("dropped_tokens").unwrap().as_u64().unwrap() > 0);
    let uncapped = frontier::run_experiment(&mk(None)).unwrap();
    assert_eq!(uncapped.metrics.dropped_tokens, 0);
    // generous headroom: no drops either
    let roomy = frontier::run_experiment(&mk(Some(64.0))).unwrap();
    assert_eq!(roomy.metrics.dropped_tokens, 0);
}

#[test]
fn explicit_edges_and_graph_validation_via_config() {
    // a decode pool with no incoming edge must be rejected up front
    let graph = StageGraphConfig::new(vec![
        StageConfig::new(StageKind::Prefill, 1),
        StageConfig::new(StageKind::Decode, 1),
        StageConfig::new(StageKind::Decode, 1),
    ])
    .with_edges(vec![StageEdge { src: 0, dst: 1, flow: FlowKind::KvHandoff }]);
    let cfg = ExperimentConfig::from_stages(ModelConfig::tiny(), graph)
        .with_workload(fixed_workload(4, 64, 4));
    assert!(cfg.validate().is_err());
    assert!(frontier::coordinator::GlobalController::new(cfg).is_err());
}

#[test]
fn stage_report_json_includes_stages() {
    let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
        .with_workload(fixed_workload(6, 64, 4));
    let r = frontier::run_experiment(&cfg).unwrap();
    let j = r.to_json();
    let stages = j.req("stages").unwrap().as_arr().unwrap();
    assert_eq!(stages.len(), 2);
    assert_eq!(stages[0].req("kind").unwrap().as_str().unwrap(), "prefill");
    assert!(stages[1].req("iterations").unwrap().as_u64().unwrap() > 0);
}
