//! Guard: every target file is registered in `Cargo.toml`.
//!
//! The manifest sets `autotests = false` (and friends), so a test,
//! bench, or example file that is not listed explicitly silently never
//! builds or runs — PR 8 found `rust/tests/parallel_engine.rs` in
//! exactly that state. This test diffs the directory listings against
//! the registered `path = "..."` entries in both directions.

use std::collections::BTreeSet;
use std::fs;
use std::path::Path;

fn manifest() -> String {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    fs::read_to_string(root.join("Cargo.toml")).expect("read Cargo.toml")
}

/// Every `path = "..."` value in the manifest (lib, bin, tests,
/// benches, examples — the distinction doesn't matter for the diff).
fn registered_paths(toml: &str) -> BTreeSet<String> {
    toml.lines()
        .filter_map(|l| {
            let rest = l.trim().strip_prefix("path")?.trim_start().strip_prefix('=')?;
            let rest = rest.trim().strip_prefix('"')?;
            Some(rest.strip_suffix('"')?.to_string())
        })
        .collect()
}

fn rs_files(dir: &str) -> BTreeSet<String> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let mut out = BTreeSet::new();
    for entry in fs::read_dir(root.join(dir)).unwrap_or_else(|e| panic!("read {dir}: {e}")) {
        let entry = entry.expect("dir entry");
        let name = entry.file_name().to_string_lossy().into_owned();
        if entry.path().is_file() && name.ends_with(".rs") {
            out.insert(format!("{dir}/{name}"));
        }
    }
    out
}

#[test]
fn every_target_file_is_registered() {
    let registered = registered_paths(&manifest());
    assert!(
        registered.contains("rust/src/lib.rs") && registered.contains("rust/src/main.rs"),
        "manifest parsing broke: {registered:?}"
    );
    for dir in ["rust/tests", "rust/benches", "examples"] {
        for file in rs_files(dir) {
            assert!(
                registered.contains(&file),
                "{file} exists but is not registered in Cargo.toml — with \
                 autotests/autobenches/autoexamples off it will never build or run; \
                 add a [[test]]/[[bench]]/[[example]] entry"
            );
        }
    }
}

#[test]
fn every_registered_path_exists() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    for path in registered_paths(&manifest()) {
        // vendor/anyhow is a `path = ` dependency entry, not a target
        // file; directories pass the existence check either way
        assert!(root.join(&path).exists(), "Cargo.toml registers {path} but it does not exist");
    }
}
