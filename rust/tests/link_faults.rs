//! Link/fabric fault-injection integration: brownouts, outages, and
//! partitions must compose with the sharded engine without breaking
//! its determinism contract. A link-faulted run renders
//! **byte-identical** deterministic reports for any `--sim-threads`
//! (fabric epochs clamp windows so no window straddles a capacity
//! change); requests are conserved through outages (held transfers are
//! re-dispatched at the epoch that revives their path, unhealable
//! partitions reject as backpressure); and a config without
//! `--link-faults` stays inert — no link metrics appear and nothing
//! about the report changes.

use frontier::config::cli::{build_config, FlagMap};
use frontier::metrics::SimReport;

/// Run the config with an explicit thread count and render the
/// deterministic JSON projection (host-time fields excluded).
fn run_json(mut flags: FlagMap, threads: u32) -> String {
    flags.set("sim-threads", threads.to_string());
    let cfg = build_config(&flags).unwrap();
    frontier::run_experiment(&cfg).unwrap().to_json_deterministic().to_string_pretty()
}

fn run_report(flags: &FlagMap) -> SimReport {
    frontier::run_experiment(&build_config(flags).unwrap()).unwrap()
}

/// Serial vs 2 / 4 / 16 threads: every rendering must match the serial
/// bytes (16 oversubscribes every config under test).
fn assert_thread_invariant(flags: FlagMap) {
    let serial = run_json(flags.clone(), 1);
    for threads in [2u32, 4, 16] {
        assert_eq!(serial, run_json(flags.clone(), threads), "diverged at sim-threads={threads}");
    }
}

fn pd_base(requests: u32) -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("mode", "pd");
    f.set("prefill", "2");
    f.set("decode", "2");
    f.set("requests", requests.to_string());
    f.set("input", "64");
    f.set("output", "16");
    f.set("rate", "40");
    f
}

/// Two clusters: the prefill->decode KV handoff crosses the WAN trunk,
/// so wan-tier faults hit the hot path.
fn cross_cluster_base(requests: u32) -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("stages", "prefill:2;decode:2,cluster=1");
    f.set("edges", "0>1");
    f.set("requests", requests.to_string());
    f.set("input", "64");
    f.set("output", "16");
    f.set("rate", "40");
    f
}

#[test]
fn brownout_is_thread_invariant() {
    // degrade the tier the KV handoff actually rides (same-node pd =>
    // nvlink): every transfer in the brownout window prices slower, and
    // the per-epoch sync window must shrink identically on every
    // thread count
    let mut f = pd_base(48);
    f.set("link-faults", "list:degrade@0.3:nvlink:0.4:0.002;up@2:nvlink");
    assert_thread_invariant(f);
}

#[test]
fn wan_outage_with_recovery_is_thread_invariant() {
    // cross-cluster partition: transfers arriving during the outage are
    // held and re-dispatched at the recovery epoch's boundary
    let mut f = cross_cluster_base(48);
    f.set("link-faults", "list:down@0.3:wan;up@2:wan");
    assert_thread_invariant(f);
}

#[test]
fn mttf_brownouts_are_thread_invariant() {
    // seeded stochastic WAN schedule, brownout flavor: epochs derived
    // from the drawn schedule must be identical on every thread count
    let mut f = cross_cluster_base(48);
    f.set("link-faults", "mttf:3:mttr:1:frac:0.5");
    assert_thread_invariant(f);
}

#[test]
fn combined_replica_and_link_faults_are_thread_invariant() {
    // both dynamics axes at once: replica displacement/requeue riding
    // the same windows as a WAN brownout
    let mut f = cross_cluster_base(48);
    f.set("faults", "mttf:4:mttr:2");
    f.set("link-faults", "list:degrade@0.5:wan:0.3;up@3:wan");
    assert_thread_invariant(f);
}

#[test]
fn day_workload_with_link_faults_is_thread_invariant() {
    // open-loop traffic day (idle gaps spanning epoch boundaries —
    // epochs are applied lazily at the next window start)
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("mode", "pd");
    f.set("prefill", "2");
    f.set("decode", "2");
    f.set("requests", "120");
    f.set("workload", "day");
    f.set("link-faults", "list:degrade@5:nvlink:0.5;up@25:nvlink");
    assert_thread_invariant(f);
}

#[test]
fn same_seed_same_link_schedule_same_report() {
    let mut f = cross_cluster_base(32);
    f.set("link-faults", "mttf:1:mttr:0.5");
    f.set("seed", "7");
    assert_eq!(run_json(f.clone(), 1), run_json(f.clone(), 1));
    // a different seed draws a different link schedule
    let mut g = f.clone();
    g.set("seed", "8");
    assert_ne!(run_json(f, 1), run_json(g, 1));
}

#[test]
fn outage_metrics_are_reported_and_conserve_requests() {
    let mut f = cross_cluster_base(48);
    f.set("link-faults", "list:down@0.3:wan;up@2:wan");
    let rep = run_report(&f);
    let m = &rep.metrics;
    assert_eq!(m.link_faults, 1);
    assert_eq!(m.link_recoveries, 1);
    // the wan tier was degraded for the [0.3, 2) outage span
    assert!(m.link_degraded_s[2] >= 1.0, "wan degraded {}s", m.link_degraded_s[2]);
    assert_eq!(m.link_degraded_s[0], 0.0);
    assert_eq!(m.link_degraded_s[1], 0.0);
    // transfers hit the dead trunk and were held, not dropped
    assert!(m.link_stalled_transfers > 0);
    // conservation across the partition: nothing vanishes
    assert_eq!(m.completed_requests + m.rejected_requests, 48);
    assert_eq!(m.rejected_requests, 0, "healed partition rejects nothing");
    // stalled-but-completed requests are tracked for SLO damage
    assert!(m.link_affected_completed > 0);
    assert!(m.link_affected_completed >= m.link_affected_slo_miss);
}

#[test]
fn unhealed_partition_rejects_as_backpressure() {
    // the trunk never comes back: transfers that would wait forever
    // must reject (conservation, not a stall-bail)
    let mut f = cross_cluster_base(32);
    f.set("link-faults", "list:down@0.3:wan");
    let rep = run_report(&f);
    let m = &rep.metrics;
    assert_eq!(m.completed_requests + m.rejected_requests, 32);
    assert!(m.rejected_requests > 0, "dead-forever path must shed load");
    assert!(m.fault_rejected > 0);
}

#[test]
fn fanout_reroutes_around_dead_trunk() {
    // two decode pools, one across the WAN: when the trunk dies the
    // live local pool absorbs the traffic and reroutes are metered
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("stages", "prefill:2;decode:2;decode:2,cluster=1");
    f.set("edges", "0>1,0>2");
    f.set("requests", "32");
    f.set("input", "64");
    f.set("output", "16");
    f.set("rate", "40");
    f.set("link-faults", "list:down@0.2:wan");
    let rep = run_report(&f);
    let m = &rep.metrics;
    assert!(m.link_rerouted_transfers > 0, "dispatch must route around the dead path");
    assert_eq!(m.completed_requests, 32, "local pool absorbs everything");
    assert_thread_invariant(f);
}

#[test]
fn inert_config_reports_no_link_metrics() {
    // no --link-faults: the JSON projection stays free of link blocks,
    // so pre-PR goldens (and diffs against them) are unchanged — even
    // when replica faults are on
    let mut f = pd_base(32);
    f.set("faults", "list:down@0.4:1.0;up@2:1.0");
    let json = run_report(&f).to_json_deterministic().to_string_pretty();
    assert!(json.contains("\"faults\""));
    assert!(!json.contains("\"link_faults\""), "{json}");
    assert!(!json.contains("\"link_degraded_s\""), "{json}");
    // and a link-faulted run does grow the new block
    let mut g = cross_cluster_base(32);
    g.set("link-faults", "list:down@0.3:wan;up@2:wan");
    let json = run_report(&g).to_json_deterministic().to_string_pretty();
    assert!(json.contains("\"link_faults\""), "{json}");
    assert!(json.contains("\"link_degraded_s\""), "{json}");
}

#[test]
fn irrelevant_link_fault_leaves_results_unchanged() {
    // single-cluster pd never touches the wan tier: a wan brownout
    // creates epochs (and the link block) but every path prices
    // bit-identically and the re-derived window only ever shrinks —
    // results must match the fault-free run exactly. This pins the
    // window-conservativeness argument from the module doc.
    let base = pd_base(32);
    let clean = run_report(&base);
    let mut g = base.clone();
    g.set("link-faults", "list:degrade@0.5:wan:0.3;up@3:wan");
    let faulted = run_report(&g);
    assert_eq!(faulted.metrics.link_faults, 1);
    let (a, b) = (&clean.metrics, &faulted.metrics);
    assert_eq!(a.completed_requests, b.completed_requests);
    assert_eq!(a.output_tokens, b.output_tokens);
    assert_eq!(a.kv_transfers, b.kv_transfers);
    assert_eq!(a.ttft.quantile(99.0), b.ttft.quantile(99.0));
    assert_eq!(a.e2e.quantile(50.0), b.e2e.quantile(50.0));
    assert_eq!(b.link_stalled_transfers, 0);
    assert_eq!(b.link_rerouted_transfers, 0);
}

#[test]
fn malformed_link_schedules_are_rejected_at_config_time() {
    let reject = |spec: &str| {
        let mut f = pd_base(8);
        f.set("link-faults", spec);
        assert!(build_config(&f).is_err(), "accepted {spec:?}");
    };
    // bad grammar
    reject("flaky");
    // bandwidth fraction outside (0, 1]
    reject("list:degrade@1:wan:1.5");
    reject("list:degrade@1:wan:0");
    // negative added latency
    reject("list:degrade@1:wan:0.5:-1");
    // recovery preceding its fault
    reject("list:up@1:wan");
    // duplicate outage of a dead target
    reject("list:down@5:wan;down@6:wan");
    // degrading a dead link (it must come back up first)
    reject("list:down@5:wan;degrade@6:wan:0.5");
    // unsorted times
    reject("list:down@5:wan;up@3:wan");
    // pair endpoints that host no stage
    reject("list:down@3:0.0-1.7");
    // mttf brownout fraction must be a real brownout
    reject("mttf:600:frac:1.0");
    reject("mttf:0");
}
