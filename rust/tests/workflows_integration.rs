//! System-level integration: the coordinator's PD/AF/MoE workflows
//! against analytically computable expectations.

use frontier::config::{ExperimentConfig, OverheadConfig, PolicyConfig};
use frontier::model::ModelConfig;
use frontier::moe::RoutingPolicy;
use frontier::predictor::PredictorKind;
use frontier::workload::{Arrival, LenDist, WorkloadSpec};

fn base_workload(n: u32, input: u32, output: u32) -> WorkloadSpec {
    WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::Fixed(input),
        output: LenDist::Fixed(output),
        n_requests: n,
        seed: 3,
        classes: vec![],
        trace: None,
    }
}

#[test]
fn pd_throughput_bounded_by_decode_stage() {
    // deterministic service: with 1 prefill + 1 decode replica and long
    // outputs, steady-state token rate == decode iteration rate
    let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
        .with_workload(base_workload(8, 64, 64))
        .with_overhead(OverheadConfig::zero());
    let report = frontier::run_experiment(&cfg).unwrap();
    assert_eq!(report.metrics.completed_requests, 8);
    // decode dominates: most iterations are decode-side
    assert!(report.metrics.iterations as f64 > 64.0);
    // sanity on the throughput identity: tokens == n * output
    assert_eq!(report.metrics.output_tokens, 8 * 64);
}

#[test]
fn pd_backpressure_holds_transfers_under_memory_pressure() {
    // Squeeze decode memory so only a few requests fit at once: the
    // controller must serialize transfers, never fail an allocation.
    let mut cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
        .with_workload(base_workload(32, 2048, 32));
    cfg.policy = PolicyConfig { kv_reserve_frac: 0.997, ..PolicyConfig::default() };
    let report = frontier::run_experiment(&cfg).unwrap();
    assert_eq!(report.metrics.completed_requests, 32, "backpressure must not lose requests");
    assert_eq!(report.metrics.kv_transfers, 32);
}

#[test]
fn pd_disaggregation_isolates_decode_from_prefill_bursts() {
    // co-located: a long prefill interleaves with decode iterations and
    // inflates TBT tails; PD isolates them (DistServe's motivation).
    let w = WorkloadSpec {
        arrival: Arrival::Poisson { rate: 3.0 },
        input: LenDist::ZipfMix { lo: 64, hi: 512, long_lo: 6144, long_hi: 8192, frac_long: 0.2 },
        output: LenDist::Fixed(96),
        n_requests: 60,
        seed: 11,
        classes: vec![],
        trace: None,
    };
    let colo = ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 2)
        .with_workload(w.clone());
    let pd = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 1, 1).with_workload(w);
    let colo_r = frontier::run_experiment(&colo).unwrap();
    let pd_r = frontier::run_experiment(&pd).unwrap();
    let colo_tbt = colo_r.metrics.tbt.quantile(99.0);
    let pd_tbt = pd_r.metrics.tbt.quantile(99.0);
    assert!(
        pd_tbt < colo_tbt,
        "PD p99 TBT {pd_tbt:.4}s should beat co-located {colo_tbt:.4}s on the same GPUs"
    );
}

#[test]
fn af_micro_batching_has_an_optimum() {
    // ping-pong pipelining (m=2) overlaps the attn and ffn pools and
    // must not lose to serial execution; but large m multiplies
    // per-kernel fixed costs (weight-bound decode GEMMs do not shrink
    // with batch), so m=8 must show the overhead — the trade-off the
    // paper's event-graph executor exists to quantify
    let run_with_m = |m: u32| {
        let cfg = ExperimentConfig::af(ModelConfig::tiny(), 1, 2, 2, m)
            .with_workload(base_workload(64, 256, 32))
            .with_overhead(OverheadConfig::zero());
        frontier::run_experiment(&cfg).unwrap()
    };
    let m1 = run_with_m(1);
    let m2 = run_with_m(2);
    let m8 = run_with_m(8);
    assert_eq!(m1.metrics.completed_requests, 64);
    assert!(
        m2.sim_duration <= m1.sim_duration * 1.005,
        "m=2 {:.3}s must not lose to serial m=1 {:.3}s",
        m2.sim_duration,
        m1.sim_duration
    );
    assert!(
        m8.sim_duration > m2.sim_duration,
        "m=8 {:.3}s must pay fixed-cost multiplication vs m=2 {:.3}s",
        m8.sim_duration,
        m2.sim_duration
    );
}

#[test]
fn moe_straggler_modeling_slows_skewed_routing() {
    let mk = |straggler: bool, alpha: f64| {
        let mut cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
            .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 4))
            .with_workload(base_workload(16, 64, 32))
            .with_overhead(OverheadConfig::zero());
        cfg.policy.moe_routing = RoutingPolicy::Skewed { alpha };
        cfg.policy.straggler_max = straggler;
        frontier::run_experiment(&cfg).unwrap()
    };
    let with_straggler = mk(true, 0.05);
    let without = mk(false, 0.05);
    assert!(
        with_straggler.sim_duration > without.sim_duration,
        "straggler max {:.4}s must exceed balance-oblivious mean {:.4}s",
        with_straggler.sim_duration,
        without.sim_duration
    );
}

#[test]
fn vidur_predictor_is_systematically_optimistic() {
    // the proxy-length model misses wave quantization and stragglers,
    // so the same deployment simulates consistently *faster* than the
    // oracle-driven ground truth (the fidelity gap of §2.2); errors
    // partially average out end-to-end, which is why operator-level
    // CDFs (Fig. 2) are the sharper lens
    let w = WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::ZipfMix { lo: 32, hi: 256, long_lo: 4096, long_hi: 8192, frac_long: 0.2 },
        output: LenDist::Fixed(96),
        n_requests: 48,
        seed: 5,
        classes: vec![],
        trace: None,
    };
    let cfg = ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 1).with_workload(w);
    let oracle_r = frontier::run_experiment(&cfg.clone()).unwrap();
    let vidur_r =
        frontier::run_experiment(&cfg.with_predictor(PredictorKind::Vidur)).unwrap();
    assert!(
        vidur_r.sim_duration < oracle_r.sim_duration,
        "vidur {:.2}s must be optimistic vs oracle {:.2}s",
        vidur_r.sim_duration,
        oracle_r.sim_duration
    );
    let rel = (vidur_r.sim_duration - oracle_r.sim_duration).abs() / oracle_r.sim_duration;
    assert!(rel > 0.015, "vidur should diverge from ground truth, rel={rel:.3}");
}

#[test]
fn sjf_beats_fcfs_on_mean_ttft_under_skew() {
    let w = WorkloadSpec {
        arrival: Arrival::Batch,
        input: LenDist::ZipfMix { lo: 32, hi: 128, long_lo: 8192, long_hi: 16384, frac_long: 0.1 },
        output: LenDist::Fixed(8),
        n_requests: 40,
        seed: 17,
        classes: vec![],
        trace: None,
    };
    let mut fcfs = ExperimentConfig::colocated(ModelConfig::tiny(), 1).with_workload(w);
    fcfs.policy.budget.max_batch = 4;
    let mut sjf = fcfs.clone();
    sjf.policy.batch = frontier::scheduler::BatchPolicy::Sjf;
    let fcfs_r = frontier::run_experiment(&fcfs).unwrap();
    let sjf_r = frontier::run_experiment(&sjf).unwrap();
    let fcfs_ttft = fcfs_r.metrics.ttft.mean();
    let sjf_ttft = sjf_r.metrics.ttft.mean();
    assert!(
        sjf_ttft < fcfs_ttft,
        "SJF mean TTFT {sjf_ttft:.4}s should beat FCFS {fcfs_ttft:.4}s"
    );
}

#[test]
fn chunked_prefill_caps_tbt_inflation() {
    // small prefill token budget => long prompts cannot monopolize an
    // iteration (Sarathi-style); p99 TBT improves vs unbounded chunks
    let w = WorkloadSpec {
        arrival: Arrival::Poisson { rate: 4.0 },
        input: LenDist::ZipfMix { lo: 64, hi: 256, long_lo: 4096, long_hi: 8192, frac_long: 0.25 },
        output: LenDist::Fixed(64),
        n_requests: 50,
        seed: 23,
        classes: vec![],
        trace: None,
    };
    let mut unbounded = ExperimentConfig::colocated(ModelConfig::qwen2_7b(), 1).with_workload(w);
    unbounded.policy.budget.max_prefill_tokens = u32::MAX;
    let mut chunked = unbounded.clone();
    chunked.policy.budget.max_prefill_tokens = 512;
    let u = frontier::run_experiment(&unbounded).unwrap();
    let c = frontier::run_experiment(&chunked).unwrap();
    let u_tbt = u.metrics.tbt.quantile(99.0);
    let c_tbt = c.metrics.tbt.quantile(99.0);
    assert!(
        c_tbt < u_tbt,
        "chunked p99 TBT {c_tbt:.4}s should beat unbounded {u_tbt:.4}s"
    );
}

#[test]
fn trace_replay_matches_generated_workload() {
    // replaying the materialized trace must reproduce the generated run
    let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 2)
        .with_workload(WorkloadSpec::poisson(12.0, 40, 128, 16));
    let generated = frontier::run_experiment(&cfg).unwrap();
    let trace = cfg.workload.generate();
    let replayed = frontier::coordinator::GlobalController::new(cfg.clone())
        .unwrap()
        .run_with_trace(trace.clone())
        .unwrap();
    assert_eq!(generated.sim_duration, replayed.sim_duration);
    assert_eq!(generated.events_processed, replayed.events_processed);
    // and the JSON file round-trip feeds the same path
    let json = frontier::workload::trace_to_json(&trace);
    let dir = std::env::temp_dir().join("frontier_trace_test.json");
    std::fs::write(&dir, json.to_string_pretty()).unwrap();
    let loaded = frontier::workload::trace_from_file(&dir).unwrap();
    let _ = std::fs::remove_file(&dir);
    let replayed2 = frontier::coordinator::GlobalController::new(cfg)
        .unwrap()
        .run_with_trace(loaded)
        .unwrap();
    // arrival timestamps round-trip through f64 seconds: equal to the ns
    assert_eq!(replayed.metrics.output_tokens, replayed2.metrics.output_tokens);
    assert_eq!(replayed.metrics.completed_requests, replayed2.metrics.completed_requests);
}

#[test]
fn report_json_round_trips() {
    let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 1)
        .with_workload(base_workload(4, 32, 4));
    let report = frontier::run_experiment(&cfg).unwrap();
    let j = report.to_json();
    let parsed = frontier::config::json::Json::parse(&j.to_string_pretty()).unwrap();
    assert_eq!(parsed.req("completed").unwrap().as_u64().unwrap(), 4);
    assert_eq!(parsed.req("mode").unwrap().as_str().unwrap(), "colocated");
}
