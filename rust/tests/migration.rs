//! Dynamic expert migration, end to end: a drifting-popularity workload
//! under `--migration threshold` must beat the static placement on both
//! post-drift rank imbalance and step time, pay for its weight moves
//! (migrated bytes metered, stage stall charged), and — with migration
//! off — bit-reproduce the static-placement simulator.
//!
//! Constants (alpha=0.1, period=24, window=8, threshold=1.1) were
//! chosen so the deterministic popularity epochs are *separable* skew:
//! epoch 0 spreads load over experts {3, 6, 2}, epoch 1 concentrates on
//! expert 2 (unfixable by placement — the planner must NOT churn), and
//! epoch 2 spreads over {2, 3, 7}. LPT re-placement then wins by a wide
//! deterministic margin over load-oblivious contiguous blocks.

use frontier::config::ExperimentConfig;
use frontier::metrics::mean;
use frontier::model::ModelConfig;
use frontier::moe::{MigrationPolicy, RoutingPolicy};
use frontier::parallelism::Parallelism;
use frontier::workload::WorkloadSpec;

/// One co-located MoE replica whose 4 EP ranks see drifting popularity:
/// the scenario the migration control loop exists for. Big decode
/// batches (128 requests) make the per-draw expert loads heavy enough
/// that rank imbalance moves real GroupedGEMM tiles and fabric bytes.
fn drift_cfg() -> ExperimentConfig {
    ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
        .with_parallelism(Parallelism::new(1, 1, 4))
        .with_moe_routing(RoutingPolicy::Drifting { alpha: 0.1, period: 24 })
        .with_workload(WorkloadSpec::table2(128, 64, 64))
        .with_seed(1)
}

#[test]
fn drifting_run_with_migration_beats_static_placement() {
    let off = frontier::run_experiment(&drift_cfg()).unwrap();
    let mig = frontier::run_experiment(&drift_cfg().with_migration(1.1, 8)).unwrap();

    // both runs complete the workload
    assert_eq!(off.metrics.completed_requests, 128);
    assert_eq!(mig.metrics.completed_requests, 128);
    assert_eq!(off.metrics.migrations, 0, "off must never migrate");

    // the migrating run actually migrated, and paid for it
    assert!(mig.metrics.migrations >= 1, "drift must trigger migration");
    assert!(mig.metrics.migrated_bytes > 0.0, "weight moves are metered");
    assert!(mig.metrics.migration_stall_s > 0.0, "weight moves take time");
    assert!(
        mig.metrics.migration_post_imbalance_mean()
            < mig.metrics.migration_pre_imbalance_mean(),
        "adopted plans must predict an improvement"
    );

    // ...and it was worth it: lower realized EP rank imbalance
    assert!(
        mig.metrics.ep_imbalance_mean() < off.metrics.ep_imbalance_mean(),
        "imbalance: migrating {:.3} vs static {:.3}",
        mig.metrics.ep_imbalance_mean(),
        off.metrics.ep_imbalance_mean()
    );
    // ...and lower mean step time despite the migration stalls
    assert_eq!(off.metrics.tbt.count(), mig.metrics.tbt.count());
    assert!(
        mig.metrics.tbt.mean() < off.metrics.tbt.mean(),
        "mean tbt: migrating {:.6} vs static {:.6}",
        mig.metrics.tbt.mean(),
        off.metrics.tbt.mean()
    );
    assert!(
        mig.sim_duration < off.sim_duration,
        "makespan: migrating {:.4} vs static {:.4}",
        mig.sim_duration,
        off.sim_duration
    );
}

#[test]
fn post_flip_step_times_recover() {
    // after the popularity flips, the migrating run's step times come
    // back down while the static placement stays stale: compare the
    // tail (the final popularity epoch) of the two tbt streams. The
    // digests don't keep per-sample order, so this test opts into raw
    // sample retention.
    let off = frontier::run_experiment(&drift_cfg().with_raw_samples()).unwrap();
    let mig =
        frontier::run_experiment(&drift_cfg().with_migration(1.1, 8).with_raw_samples()).unwrap();
    let tail = |r: &frontier::metrics::SimReport| {
        let xs = &r.metrics.raw.as_ref().expect("raw samples kept").tbt;
        let n = xs.len().min(300);
        mean(&xs[xs.len() - n..])
    };
    assert!(
        tail(&mig) < tail(&off),
        "post-flip tbt: migrating {:.6} vs static {:.6}",
        tail(&mig),
        tail(&off)
    );
}

#[test]
fn migration_off_bit_reproduces_static_results() {
    // `--migration off` must be byte-for-byte the static simulator: no
    // estimator attached, no stall, identical event stream. The knob
    // values of the (inert) threshold machinery must not matter either.
    let base = frontier::run_experiment(&drift_cfg()).unwrap();
    let mut tweaked_cfg = drift_cfg();
    tweaked_cfg.policy.migration_threshold = 7.5;
    tweaked_cfg.policy.load_window = 3;
    assert_eq!(tweaked_cfg.policy.migration, MigrationPolicy::Off);
    let tweaked = frontier::run_experiment(&tweaked_cfg).unwrap();
    assert_eq!(base.sim_duration, tweaked.sim_duration);
    assert_eq!(base.events_processed, tweaked.events_processed);
    assert_eq!(base.metrics.tbt, tweaked.metrics.tbt);
    assert_eq!(base.metrics.ttft, tweaked.metrics.ttft);
    assert_eq!(base.metrics.migrations, 0);
    assert_eq!(base.metrics.migration_stall_s, 0.0);
}

#[test]
fn tracking_without_triggering_is_free() {
    // a threshold so high it never fires: the load estimator observes
    // every draw, yet the run is bit-identical to `--migration off` —
    // pins that tracking never perturbs pricing or the RNG stream
    let off = frontier::run_experiment(&drift_cfg()).unwrap();
    let armed = frontier::run_experiment(&drift_cfg().with_migration(1e9, 8)).unwrap();
    assert_eq!(armed.metrics.migrations, 0, "threshold 1e9 must never fire");
    assert_eq!(off.sim_duration, armed.sim_duration);
    assert_eq!(off.events_processed, armed.events_processed);
    assert_eq!(off.metrics.tbt, armed.metrics.tbt);
}

#[test]
fn stationary_skew_migrates_once_and_settles() {
    // under stationary (non-drifting) separable skew the control loop
    // adapts once, then holds: no thrash, and never a worse imbalance
    // than the static placement
    let cfg = || {
        ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
            .with_parallelism(Parallelism::new(1, 1, 4))
            .with_moe_routing(RoutingPolicy::Skewed { alpha: 0.1 })
            .with_workload(WorkloadSpec::table2(128, 64, 64))
            .with_seed(1)
    };
    let off = frontier::run_experiment(&cfg()).unwrap();
    let mig = frontier::run_experiment(&cfg().with_migration(1.1, 8)).unwrap();
    assert!(mig.metrics.migrations >= 1, "separable stationary skew adapts");
    assert!(
        mig.metrics.migrations <= 2,
        "stationary load must not thrash ({} migrations)",
        mig.metrics.migrations
    );
    assert!(mig.metrics.ep_imbalance_mean() < off.metrics.ep_imbalance_mean());
    assert!(mig.metrics.tbt.mean() < off.metrics.tbt.mean());
}

#[test]
fn af_stage_ffn_pool_migrates_too() {
    // the AF decode stage's FFN pool owns the EP domain: the same
    // control loop must engage there (draws advance per layer x micro)
    let cfg = || {
        ExperimentConfig::af(ModelConfig::tiny_moe(), 1, 2, 4, 2)
            .with_moe_routing(RoutingPolicy::Skewed { alpha: 0.1 })
            .with_workload(WorkloadSpec::table2(24, 64, 24))
            .with_seed(7)
    };
    let off = frontier::run_experiment(&cfg()).unwrap();
    let mig = frontier::run_experiment(&cfg().with_migration(1.05, 64)).unwrap();
    assert_eq!(off.metrics.completed_requests, 24);
    assert_eq!(mig.metrics.completed_requests, 24);
    assert!(mig.metrics.migrations >= 1, "AF FFN pool must migrate");
    assert!(mig.metrics.migrated_bytes > 0.0);
    assert!(
        mig.metrics.ep_imbalance_mean() <= off.metrics.ep_imbalance_mean(),
        "imbalance: migrating {:.3} vs static {:.3}",
        mig.metrics.ep_imbalance_mean(),
        off.metrics.ep_imbalance_mean()
    );
}
