//! Cluster-dynamics integration: fault injection and autoscaling must
//! compose with the sharded engine without breaking its determinism
//! contract. A faulted and/or autoscaled run renders **byte-identical**
//! deterministic reports for any `--sim-threads`; the same seed yields
//! the same fault schedule and therefore the same report; and a config
//! with neither axis stays inert (no dynamics metrics appear, nothing
//! about the report changes).

use frontier::config::cli::{build_config, FlagMap};
use frontier::metrics::SimReport;

/// Run the config with an explicit thread count and render the
/// deterministic JSON projection (host-time fields excluded).
fn run_json(mut flags: FlagMap, threads: u32) -> String {
    flags.set("sim-threads", threads.to_string());
    let cfg = build_config(&flags).unwrap();
    frontier::run_experiment(&cfg).unwrap().to_json_deterministic().to_string_pretty()
}

fn run_report(flags: &FlagMap) -> SimReport {
    frontier::run_experiment(&build_config(flags).unwrap()).unwrap()
}

/// Serial vs 2 / 4 / 16 threads: every rendering must match the serial
/// bytes (16 oversubscribes every config under test).
fn assert_thread_invariant(flags: FlagMap) {
    let serial = run_json(flags.clone(), 1);
    for threads in [2u32, 4, 16] {
        assert_eq!(serial, run_json(flags.clone(), threads), "diverged at sim-threads={threads}");
    }
}

fn pd_base(requests: u32) -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("mode", "pd");
    f.set("prefill", "2");
    f.set("decode", "2");
    f.set("requests", requests.to_string());
    f.set("input", "64");
    f.set("output", "16");
    f.set("rate", "40");
    f
}

#[test]
fn mttf_faults_are_thread_invariant() {
    // stochastic schedule dense enough to hit both the entry pool
    // (local requeue) and the decode pool (cross-shard requeue +
    // in-flight transfer displacement)
    let mut f = pd_base(48);
    f.set("faults", "mttf:4:mttr:2");
    assert_thread_invariant(f);
}

#[test]
fn explicit_fault_list_is_thread_invariant() {
    // whole decode pool dies mid-run and recovers: every in-flight
    // request on stage 1 is displaced at once
    let mut f = pd_base(48);
    f.set("faults", "list:down@0.4:1;up@2:1");
    assert_thread_invariant(f);
}

#[test]
fn autoscaled_run_is_thread_invariant() {
    let mut f = pd_base(64);
    f.set("autoscale", "reactive:1:4");
    f.set("scale-interval", "0.5");
    f.set("scale-delay", "1");
    f.set("scale-up", "1.5");
    assert_thread_invariant(f);
}

#[test]
fn faults_plus_autoscale_are_thread_invariant() {
    // the full dynamics stack at once: displacement, retry/backoff,
    // dead-pool replacement, drain-based scale-down
    let mut f = pd_base(48);
    f.set("faults", "mttf:5:mttr:2");
    f.set("autoscale", "predictive:1:4");
    f.set("scale-interval", "0.5");
    f.set("scale-delay", "1");
    assert_thread_invariant(f);
}

#[test]
fn day_workload_with_faults_is_thread_invariant() {
    // open-loop traffic day (idle gaps, class mix) + decode outage
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("mode", "pd");
    f.set("prefill", "2");
    f.set("decode", "2");
    f.set("requests", "120");
    f.set("workload", "day");
    f.set("faults", "list:down@5:1.0;up@25:1.0");
    assert_thread_invariant(f);
}

#[test]
fn same_seed_same_schedule_same_report() {
    let mut f = pd_base(32);
    f.set("faults", "mttf:4:mttr:2");
    f.set("seed", "7");
    assert_eq!(run_json(f.clone(), 1), run_json(f.clone(), 1));
    // a different seed draws a different fault schedule
    let mut g = f.clone();
    g.set("seed", "8");
    assert_ne!(run_json(f, 1), run_json(g, 1));
}

#[test]
fn fault_metrics_are_reported_and_conserve_requests() {
    let mut f = pd_base(48);
    f.set("faults", "list:down@0.4:1;up@2:1;down@3:0.0;up@4:0.0");
    let rep = run_report(&f);
    let m = &rep.metrics;
    // the outage actually happened and was recovered
    assert_eq!(m.faults, 3, "pool event expands to 2 decode replicas + 1 prefill");
    assert_eq!(m.fault_recoveries, 3);
    assert!(m.fault_downtime_s > 0.0);
    assert!(m.ttr.count() == 3 && m.ttr.mean() > 0.0, "time-to-recovery metered");
    assert!(m.fault_requeues > 0, "the dead pool held work when it died");
    // conservation across failures: nothing vanishes, nothing doubles
    assert_eq!(m.completed_requests + m.rejected_requests, 48);
    // availability strictly dips below an immortal fleet's 1.0
    assert!(rep.availability() < 1.0 && rep.availability() > 0.0);
    // displaced-but-completed requests are tracked for SLO damage
    assert!(m.fault_affected_completed > 0);
    assert!(m.fault_affected_completed >= m.fault_affected_slo_miss);
}

#[test]
fn autoscaler_reacts_and_reports_events() {
    // kill the whole decode pool early: the autoscaler's next tick
    // sees zero live capacity and must provision a replacement
    // (emergency grow), which then serves the held KV transfers
    let mut f = pd_base(96);
    f.set("rate", "400");
    f.set("faults", "list:down@0.3:1;up@10:1");
    f.set("autoscale", "reactive:1:4");
    f.set("scale-interval", "0.2");
    f.set("scale-delay", "0.5");
    let rep = run_report(&f);
    assert!(rep.metrics.scale_ticks > 0);
    assert!(rep.metrics.scale_up_events > 0, "a dead pool must trigger a grow");
    assert_eq!(rep.metrics.completed_requests + rep.metrics.rejected_requests, 96);
    // the report still presents the *deployed* shape, not the
    // pre-provisioned headroom slots
    assert_eq!(rep.stages[1].replicas, 2);
}

#[test]
fn slo_scale_signal_is_thread_invariant() {
    let mut f = pd_base(64);
    f.set("rate", "200");
    f.set("slo-ttft", "200");
    f.set("slo-tbt", "50");
    f.set("autoscale", "reactive:1:4");
    f.set("scale-signal", "slo");
    f.set("scale-interval", "0.5");
    f.set("scale-delay", "1");
    assert_thread_invariant(f);
}

#[test]
fn slo_signal_scales_up_under_pressure() {
    // a burst far past the pool's capacity: the per-tick missed-SLO
    // fraction (or a non-empty queue before the first completions)
    // crosses the grow threshold
    let mut f = pd_base(96);
    f.set("rate", "400");
    f.set("slo-ttft", "100");
    f.set("slo-tbt", "20");
    f.set("autoscale", "reactive:1:4");
    f.set("scale-signal", "slo");
    f.set("scale-interval", "0.2");
    f.set("scale-delay", "0.5");
    let rep = run_report(&f);
    assert!(rep.metrics.scale_ticks > 0);
    assert!(rep.metrics.scale_up_events > 0, "missed-SLO fraction must trigger a grow");
    assert_eq!(rep.metrics.completed_requests + rep.metrics.rejected_requests, 96);
}

#[test]
fn inert_config_reports_no_dynamics() {
    let f = pd_base(32);
    let rep = run_report(&f);
    assert_eq!(rep.metrics.faults, 0);
    assert_eq!(rep.metrics.scale_ticks, 0);
    assert_eq!(rep.availability(), 1.0);
    // the JSON projection stays free of dynamics blocks, so pre-PR
    // goldens (and diffs against them) are unchanged
    let json = rep.to_json_deterministic().to_string_pretty();
    assert!(!json.contains("\"faults\""), "{json}");
    assert!(!json.contains("\"autoscale\""), "{json}");
    // and a faulted run does grow the new block
    let mut g = pd_base(32);
    g.set("faults", "list:down@0.4:1.0;up@2:1.0");
    let json = run_report(&g).to_json_deterministic().to_string_pretty();
    assert!(json.contains("\"faults\""), "{json}");
    assert!(json.contains("\"availability\""), "{json}");
}

#[test]
fn malformed_dynamics_flags_are_rejected_at_config_time() {
    // bad grammar
    let mut f = pd_base(8);
    f.set("faults", "sometimes");
    assert!(build_config(&f).is_err());
    // schedule that targets a stage the graph does not have
    let mut f = pd_base(8);
    f.set("faults", "list:down@1:9");
    assert!(build_config(&f).is_err());
    // recovery preceding its failure
    let mut f = pd_base(8);
    f.set("faults", "list:up@1:1.0");
    assert!(build_config(&f).is_err());
    // autoscale band excluding the initial pool size
    let mut f = pd_base(8);
    f.set("autoscale", "reactive:3:4");
    assert!(build_config(&f).is_err());
    // orphan tuning subflag
    let mut f = pd_base(8);
    f.set("scale-interval", "5");
    assert!(build_config(&f).is_err());
}
