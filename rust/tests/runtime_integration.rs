//! PJRT runtime integration: the AOT predictor artifacts must load,
//! agree with the Python-side golden predictions, and track the oracle.
//!
//! These tests exercise the whole L1->L2->L3 chain: Pallas kernels
//! lowered through JAX to HLO text, compiled by the Rust PJRT client,
//! queried by the learned predictor with Rust-extracted features.

use frontier::config::json::Json;
use frontier::operators::OpWorkload;
use frontier::predictor::{ExecutionPredictor, LearnedPredictor, OraclePredictor};
use frontier::runtime::PredictorRuntime;

fn artifacts_ready() -> bool {
    PredictorRuntime::default_dir().join("manifest.json").exists()
}

#[test]
fn artifacts_load_and_match_python_golden() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = PredictorRuntime::default_dir();
    let rt = PredictorRuntime::load(&dir).expect("artifacts load");
    assert_eq!(rt.attn.n_features, 16);
    assert_eq!(rt.grouped_gemm.n_features, 12);
    assert_eq!(rt.gemm.n_features, 6);
    let golden =
        Json::parse(&std::fs::read_to_string(dir.join("predictor_golden.json")).unwrap())
            .unwrap();
    for (name, exe) in
        [("attn", &rt.attn), ("grouped_gemm", &rt.grouped_gemm), ("gemm", &rt.gemm)]
    {
        let g = golden.req(name).unwrap();
        let feats: Vec<Vec<f64>> = g
            .req("features")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| r.as_f64_vec().unwrap())
            .collect();
        let want = g.req("pred_us").unwrap().as_f64_vec().unwrap();
        let got = exe.predict_us(&feats).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            let rel = (a - b).abs() / b.max(1e-9);
            assert!(rel < 1e-3, "{name}[{i}]: rust {a} vs python {b} (rel {rel:.2e})");
        }
    }
}

#[test]
fn learned_predictor_tracks_oracle() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut learned = LearnedPredictor::load(&PredictorRuntime::default_dir()).unwrap();
    let mut truth = OraclePredictor::a800();
    // representative in-distribution workloads
    let ops = vec![
        OpWorkload::Gemm { m: 512, n: 4096, k: 4096 },
        OpWorkload::Gemm { m: 17, n: 18944, k: 3584 },
        OpWorkload::Attention {
            is_prefill: false,
            q_lens: vec![1; 48],
            ctx_lens: (0..48).map(|i| 200 + i * 317).collect(),
            n_heads: 28,
            n_kv_heads: 4,
            head_dim: 128,
        },
        OpWorkload::Attention {
            is_prefill: true,
            q_lens: vec![512, 128, 2048, 64],
            ctx_lens: vec![0, 0, 0, 0],
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
        },
        OpWorkload::GroupedGemm {
            tokens_per_expert: vec![11, 250, 3, 99, 512, 0, 47, 70],
            n: 4096,
            k: 2048,
        },
    ];
    for op in &ops {
        let p = learned.predict(op);
        let t = truth.predict(op);
        let rel = (p - t).abs() / t;
        assert!(
            rel < 0.25,
            "{}: learned {p:.3e}s vs oracle {t:.3e}s (rel {rel:.3})",
            op.class()
        );
    }
}

#[test]
fn learned_predictor_caches_repeated_queries() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut learned = LearnedPredictor::load(&PredictorRuntime::default_dir()).unwrap();
    let op = OpWorkload::Gemm { m: 64, n: 1024, k: 1024 };
    let a = learned.predict(&op);
    let evals_after_first = learned.evals();
    for _ in 0..10 {
        assert_eq!(learned.predict(&op), a);
    }
    assert_eq!(learned.evals(), evals_after_first, "repeats must hit the cache");
    let (hits, _) = learned.cache_stats();
    assert_eq!(hits, 10);
}

#[test]
fn learned_predictor_comm_ops_use_alpha_beta() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut learned = LearnedPredictor::load(&PredictorRuntime::default_dir()).unwrap();
    let mut truth = OraclePredictor::a800();
    let op = OpWorkload::AllReduce { bytes: 3.2e8, n_ranks: 8 };
    assert_eq!(learned.predict(&op), truth.predict(&op));
}

#[test]
fn full_simulation_with_learned_predictor() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use frontier::config::ExperimentConfig;
    use frontier::model::ModelConfig;
    use frontier::predictor::PredictorKind;
    use frontier::workload::WorkloadSpec;

    let cfg = ExperimentConfig::pd(ModelConfig::qwen2_7b(), 1, 1)
        .with_workload(WorkloadSpec::table2(12, 64, 16))
        .with_predictor(PredictorKind::Learned);
    let report = frontier::run_experiment(&cfg).expect("learned-predictor sim");
    assert_eq!(report.metrics.completed_requests, 12);
    assert_eq!(report.predictor, "learned");

    // oracle-driven run of the same config must land close (the fidelity
    // claim at system level)
    let cfg2 = cfg.with_predictor(PredictorKind::Oracle);
    let truth = frontier::run_experiment(&cfg2).unwrap();
    let rel = (report.sim_duration - truth.sim_duration).abs() / truth.sim_duration;
    assert!(
        rel < 0.15,
        "e2e learned {} vs oracle {} (rel {rel:.3})",
        report.sim_duration,
        truth.sim_duration
    );
}
