//! Parallel-engine integration: the sharded event loop behind
//! `--sim-threads` must produce **byte-identical** deterministic
//! reports to the serial run — for every deployment shape the graph
//! layer can express, and for any thread count (including
//! oversubscribed: more threads than shards). The engine's determinism
//! contract is structural (shards share no mutable state during the
//! parallel phase; the barrier merge order is thread-count-invariant),
//! and these tests pin it end to end through the JSON projection.

use frontier::config::cli::{build_config, FlagMap};

/// Run the config with an explicit thread count and render the
/// deterministic JSON projection (host-time fields excluded).
fn run_json(mut flags: FlagMap, threads: u32) -> String {
    flags.set("sim-threads", threads.to_string());
    let cfg = build_config(&flags).unwrap();
    frontier::run_experiment(&cfg).unwrap().to_json_deterministic().to_string_pretty()
}

/// Serial vs 2 / 4 / 16 threads: every rendering must match the serial
/// bytes (16 oversubscribes every config under test).
fn assert_thread_invariant(flags: FlagMap) {
    let serial = run_json(flags.clone(), 1);
    for threads in [2u32, 4, 16] {
        assert_eq!(serial, run_json(flags.clone(), threads), "diverged at sim-threads={threads}");
    }
}

fn base(model: &str, requests: u32, input: u32, output: u32) -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", model);
    f.set("requests", requests.to_string());
    f.set("input", input.to_string());
    f.set("output", output.to_string());
    f
}

#[test]
fn colocated_is_thread_invariant() {
    // single shard: the engine takes the serial drain path at any
    // thread count, so this pins the fast path's equivalence
    let mut f = base("tiny", 32, 64, 16);
    f.set("replicas", "2");
    assert_thread_invariant(f);
}

#[test]
fn pd_is_thread_invariant() {
    let mut f = base("tiny", 24, 64, 16);
    f.set("mode", "pd");
    f.set("prefill", "2");
    f.set("decode", "2");
    assert_thread_invariant(f);
}

#[test]
fn fanout_graph_is_thread_invariant() {
    // >= 4 stages, one decode pool in another cluster (the kv edge
    // into it crosses the WAN trunk, so the sync window is set by the
    // cheapest edge while dispatch still serializes the expensive one)
    let mut f = base("tiny", 32, 64, 16);
    f.set("stages", "prefill:2;decode:1;decode:1;decode:1,cluster=1");
    f.set("edges", "0>1,0>2,0>3");
    assert_thread_invariant(f);
}

#[test]
fn af_ep_graph_is_thread_invariant() {
    // prefill pool feeding an attention/FFN decode pair whose FFN pool
    // is an EP domain: batched EP pricing + cross-shard handoff
    let mut f = base("tiny-moe", 12, 32, 8);
    f.set("mode", "af");
    f.set("prefill", "1");
    f.set("attn-gpus", "2");
    f.set("ffn-gpus", "2");
    f.set("micro-batches", "2");
    assert_thread_invariant(f);
}

#[test]
fn migration_enabled_pd_is_thread_invariant() {
    // expert migration runs inside the parallel phase (stage-internal
    // EP fabric) — per-shard RNG streams must still be deterministic
    let mut f = base("tiny-moe", 24, 48, 12);
    f.set("mode", "pd");
    f.set("prefill", "1");
    f.set("decode", "1");
    f.set("ep", "4");
    f.set("migration", "threshold");
    f.set("load-window", "16");
    assert_thread_invariant(f);
}

#[test]
fn day_workload_pd_is_thread_invariant() {
    // open-loop traffic-day trace over the PD boundary: arrival-driven
    // windows (idle gaps between bursts) must merge identically
    let mut f = base("tiny", 160, 48, 8);
    f.set("workload", "day");
    f.set("mode", "pd");
    f.set("prefill", "2");
    f.set("decode", "2");
    assert_thread_invariant(f);
}

#[test]
fn sim_threads_lowering_round_trips() {
    let mut f = base("tiny", 8, 32, 8);
    f.set("sim-threads", "4");
    assert_eq!(build_config(&f).unwrap().sim_threads, 4);
    // default stays serial
    assert_eq!(build_config(&base("tiny", 8, 32, 8)).unwrap().sim_threads, 1);
}
