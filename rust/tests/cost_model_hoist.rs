//! Pin the per-iteration hot path against CostModel reconstruction:
//! every stage's cost models (including the AF pair's attn/ffn models)
//! are built once at controller construction; pricing iterations must
//! never clone the model config or rebuild a cost model.
//!
//! Lives in its own integration binary so the global construction
//! counter is not perturbed by concurrently running tests.

use std::sync::atomic::Ordering;

use frontier::config::ExperimentConfig;
use frontier::model::ModelConfig;
use frontier::workflows::cost::COST_MODELS_BUILT;
use frontier::workload::WorkloadSpec;

#[test]
fn no_cost_models_built_during_simulation() {
    // AF + MoE is the path that used to rebuild attn/ffn cost models
    // (and clone the model) every decode iteration
    let scenarios = vec![
        ExperimentConfig::af(ModelConfig::tiny_moe(), 1, 2, 4, 2)
            .with_workload(WorkloadSpec::table2(16, 128, 16)),
        ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
            .with_workload(WorkloadSpec::table2(16, 128, 16)),
        ExperimentConfig::colocated(ModelConfig::tiny_moe(), 2)
            .with_parallelism(frontier::parallelism::Parallelism::new(1, 1, 4))
            .with_workload(WorkloadSpec::table2(16, 128, 16)),
    ];
    for cfg in scenarios {
        let controller = frontier::coordinator::GlobalController::new(cfg.clone()).unwrap();
        let trace = cfg.workload.generate();
        let before = COST_MODELS_BUILT.load(Ordering::SeqCst);
        let report = controller.run_with_trace(trace).unwrap();
        let after = COST_MODELS_BUILT.load(Ordering::SeqCst);
        assert_eq!(report.metrics.completed_requests, 16);
        assert!(report.metrics.iterations > 0);
        assert_eq!(
            after - before,
            0,
            "{}: {} cost models built during the run (hot path must reuse \
             construction-time models)",
            cfg.mode_name(),
            after - before
        );
    }
}
