//! Flat-allocation pin for the pricing hot path: after warmup, a
//! steady-state iteration-pricing draw must perform **zero** plan/op
//! vector allocations — the scratch buffers (`PlanScratch`,
//! `EpScratch`, the popularity cache's alias table and sampling
//! scratch) absorb everything. The counting global allocator makes the
//! regression impossible to reintroduce silently (the
//! `COST_MODELS_BUILT` pattern, one level deeper).
//!
//! Lives in its own integration binary so the global counter only sees
//! this test's allocations; all scenarios run inside one `#[test]` so
//! the default multi-threaded harness cannot interleave others.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use frontier::config::OverheadConfig;
use frontier::core::Pcg64;
use frontier::hardware::LinkSpec;
use frontier::model::ModelConfig;
use frontier::moe::{
    EpSpec, EpTopology, ExpertPlacement, PlacementPolicy, RoutingFidelity, RoutingPolicy,
};
use frontier::parallelism::Parallelism;
use frontier::predictor::OraclePredictor;
use frontier::workflows::{BatchShape, CostCtx, CostModel};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn decode_shape(n: usize, ctx: u32) -> BatchShape {
    BatchShape { prefill: vec![], decode_ctx: vec![ctx; n], lm_head_rows: n as u32 }
}

/// Warm `iters` times, then assert the next `iters` calls allocate
/// exactly zero times.
fn assert_flat(name: &str, mut step: impl FnMut()) {
    for _ in 0..8 {
        step();
    }
    let before = allocs();
    for _ in 0..32 {
        step();
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "{name}: {} allocations across 32 steady-state draws (hot path must be \
         allocation-free)",
        after - before
    );
}

fn moe_cm(fidelity: RoutingFidelity, with_ep: bool) -> CostModel {
    let mut cm = CostModel::new(
        ModelConfig::tiny_moe(),
        Parallelism::new(1, 1, 4),
        LinkSpec::nvlink_a800(),
    );
    cm.overhead = OverheadConfig::zero();
    cm.moe_routing = RoutingPolicy::Skewed { alpha: 0.1 };
    cm.routing_fidelity = fidelity;
    if with_ep {
        cm.ep = Some(EpSpec::flat(
            ExpertPlacement::build(
                PlacementPolicy::Contiguous,
                8,
                EpTopology::new(4, 2),
                None,
            ),
            LinkSpec::nvlink_a800(),
            LinkSpec::cross_cluster(),
        ));
    }
    cm
}

#[test]
fn steady_state_pricing_is_allocation_free() {
    let mut pred = OraclePredictor::a800();
    let mut rng = Pcg64::new(7);
    let shape = decode_shape(48, 512);

    // 1) EP placement path (the §3.3 micro-workflow through the fabric)
    let cm = moe_cm(RoutingFidelity::Token, true);
    assert_flat("moe_ffn_ep (token fidelity)", || {
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        let s = cm.moe_ffn_ep(&mut ctx, 128).unwrap();
        std::hint::black_box(s.ffn_secs);
    });

    // 2) EP path at aggregate fidelity (binomial-split sampler)
    let cm = moe_cm(RoutingFidelity::Aggregate, true);
    assert_flat("moe_ffn_ep (aggregate fidelity)", || {
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        let s = cm.moe_ffn_ep(&mut ctx, 128).unwrap();
        std::hint::black_box(s.ffn_secs);
    });

    // 3) full iteration on the closed-form plan path (MoE, par.ep > 1,
    //    no EpSpec): attention ops + gate + A2A + per-rank GroupedGemms
    let cm = moe_cm(RoutingFidelity::Token, false);
    assert_flat("iteration_time (MoE plan path)", || {
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        std::hint::black_box(cm.iteration_time(&mut ctx, &shape));
    });

    // 4) full iteration on the EP path (attention + EP FFN + LM head)
    let cm = moe_cm(RoutingFidelity::Token, true);
    assert_flat("iteration_time (EP path)", || {
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        std::hint::black_box(cm.iteration_time(&mut ctx, &shape));
    });

    // 5) dense model for completeness (no MoE machinery at all)
    let mut cm = CostModel::new(
        ModelConfig::tiny(),
        Parallelism::default(),
        LinkSpec::nvlink_a800(),
    );
    cm.overhead = OverheadConfig::zero();
    assert_flat("iteration_time (dense)", || {
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        std::hint::black_box(cm.iteration_time(&mut ctx, &shape));
    });

    // 6) mixed prefill + decode batches on a stable shape
    let cm = moe_cm(RoutingFidelity::Token, false);
    let mixed = BatchShape {
        prefill: vec![(128, 0), (64, 256)],
        decode_ctx: vec![300; 16],
        lm_head_rows: 17,
    };
    assert_flat("iteration_time (mixed batch)", || {
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        std::hint::black_box(cm.iteration_time(&mut ctx, &mixed));
    });
}
