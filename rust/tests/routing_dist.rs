//! Distributional equivalence of the production routing samplers
//! against the frozen linear-scan oracle (`moe::assign_tokens_oracle`).
//!
//! The alias-table token sampler draws from *exactly* the oracle's
//! distribution (rejection targets the same renormalized
//! without-replacement conditional), so its stats must match to
//! sampling noise; the aggregate sampler is a population-level
//! approximation and gets looser (but still tight) tolerance bands.
//! Tolerances carry >= 3x margin over values measured with an
//! independent Python port of all three samplers.

use frontier::core::Pcg64;
use frontier::moe::{
    assign_tokens_into, assign_tokens_oracle, expert_popularity_phase, PopularityCache,
    RoutingFidelity, RoutingPolicy,
};

/// Per-expert slot totals and mean per-draw imbalance over `draws`
/// independent draws (draw index passed through, so drifting policies
/// cross epoch boundaries exactly like production).
fn collect(
    fidelity: Option<RoutingFidelity>,
    policy: RoutingPolicy,
    tokens: u32,
    e: u32,
    k: u32,
    draws: u64,
    seed: u64,
) -> (Vec<u64>, f64) {
    let mut rng = Pcg64::new(seed);
    let mut cache = PopularityCache::default();
    let mut loads = Vec::new();
    let mut totals = vec![0u64; e as usize];
    let mut imb = 0.0;
    for d in 0..draws {
        match fidelity {
            None => {
                let (l, _) = assign_tokens_oracle(policy, tokens, e, k, None, d, &mut rng);
                loads.clear();
                loads.extend_from_slice(&l);
            }
            Some(f) => {
                assign_tokens_into(
                    policy, f, tokens, e, k, None, d, &mut cache, &mut rng, &mut loads,
                );
            }
        }
        for (t, &x) in totals.iter_mut().zip(&loads) {
            *t += u64::from(x);
        }
        let mean = loads.iter().map(|&x| f64::from(x)).sum::<f64>() / e as f64;
        if mean > 0.0 {
            imb += f64::from(*loads.iter().max().unwrap()) / mean;
        }
    }
    (totals, imb / draws as f64)
}

fn shares(totals: &[u64]) -> Vec<f64> {
    let s: u64 = totals.iter().sum();
    totals.iter().map(|&t| t as f64 / s.max(1) as f64).collect()
}

fn max_share_diff(a: &[u64], b: &[u64]) -> f64 {
    shares(a)
        .iter()
        .zip(shares(b))
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Two-sample Pearson statistic over equal-total count vectors: under
/// identical distributions it concentrates around `E - 1` (per-token
/// without-replacement correlation only shrinks it).
fn chi2_pair(a: &[u64], b: &[u64]) -> f64 {
    a.iter()
        .zip(b)
        .filter(|(&x, &y)| x + y > 0)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d / (x + y) as f64
        })
        .sum()
}

const POLICIES: [RoutingPolicy; 4] = [
    RoutingPolicy::UniformRandom,
    RoutingPolicy::Skewed { alpha: 0.05 },
    RoutingPolicy::Skewed { alpha: 0.5 },
    RoutingPolicy::Drifting { alpha: 0.1, period: 7 },
];

fn equivalence_config(e: u32, k: u32, tokens: u32, draws: u64) {
    for policy in POLICIES {
        let (to, imb_o) = collect(None, policy, tokens, e, k, draws, 11);
        let (ta, imb_a) =
            collect(Some(RoutingFidelity::Token), policy, tokens, e, k, draws, 22);
        let (tg, imb_g) =
            collect(Some(RoutingFidelity::Aggregate), policy, tokens, e, k, draws, 33);
        // both samplers conserve every slot
        let slots = draws * tokens as u64 * k.min(e) as u64;
        assert_eq!(to.iter().sum::<u64>(), slots, "{policy:?}");
        assert_eq!(ta.iter().sum::<u64>(), slots, "{policy:?}");
        assert_eq!(tg.iter().sum::<u64>(), slots, "{policy:?}");
        // alias sampler: identical distribution, sampling noise only
        // (Python-port measured maxima: share 0.0033, imb rel 0.008,
        // chi2 128 at e=128)
        let sd = max_share_diff(&to, &ta);
        assert!(sd < 0.02, "{policy:?} e={e}: alias share diff {sd}");
        let ir = (imb_a - imb_o).abs() / imb_o;
        assert!(ir < 0.05, "{policy:?} e={e}: alias imbalance rel err {ir}");
        let x2 = chi2_pair(&to, &ta);
        let bound = 3.0 * (e - 1) as f64 + 30.0;
        assert!(x2 < bound, "{policy:?} e={e}: chi2 {x2} vs bound {bound}");
        // aggregate sampler: approximation band (measured maxima:
        // share 0.046, imb rel 0.113)
        let sd = max_share_diff(&to, &tg);
        assert!(sd < 0.10, "{policy:?} e={e}: aggregate share diff {sd}");
        let ir = (imb_g - imb_o).abs() / imb_o;
        assert!(ir < 0.25, "{policy:?} e={e}: aggregate imbalance rel err {ir}");
    }
}

#[test]
fn alias_and_aggregate_match_oracle_small() {
    equivalence_config(8, 2, 256, 300);
}

#[test]
fn alias_and_aggregate_match_oracle_large() {
    // the acceptance regime: E=128 experts, top_k=4
    equivalence_config(128, 4, 256, 80);
}

#[test]
fn drifting_epoch_boundaries_shift_every_sampler_together() {
    // heavy skew: within each popularity epoch, every sampler's busiest
    // expert must be one of the truly-popular ones for *that* epoch
    let policy = RoutingPolicy::Drifting { alpha: 0.05, period: 10 };
    for (name, fidelity, seed) in [
        ("oracle", None, 11u64),
        ("alias", Some(RoutingFidelity::Token), 22),
        ("aggregate", Some(RoutingFidelity::Aggregate), 33),
    ] {
        let mut rng = Pcg64::new(seed);
        let mut cache = PopularityCache::default();
        let mut loads = Vec::new();
        for epoch in 0..4u64 {
            let w = expert_popularity_phase(0.05, 8, epoch);
            let wmax = w.iter().cloned().fold(0.0, f64::max);
            let mut totals = [0u64; 8];
            for d in epoch * 10..(epoch + 1) * 10 {
                match fidelity {
                    None => {
                        let (l, _) =
                            assign_tokens_oracle(policy, 256, 8, 2, None, d, &mut rng);
                        loads.clear();
                        loads.extend_from_slice(&l);
                    }
                    Some(f) => {
                        assign_tokens_into(
                            policy, f, 256, 8, 2, None, d, &mut cache, &mut rng, &mut loads,
                        );
                    }
                }
                for (t, &x) in totals.iter_mut().zip(&loads) {
                    *t += u64::from(x);
                }
            }
            let hot =
                totals.iter().enumerate().max_by_key(|&(_, &t)| t).unwrap().0;
            assert!(
                w[hot] >= 0.5 * wmax,
                "{name} epoch {epoch}: busiest expert {hot} has weight {} vs max {wmax}",
                w[hot]
            );
        }
    }
}

#[test]
fn production_samplers_are_deterministic_and_draw_indexed() {
    for fidelity in [RoutingFidelity::Token, RoutingFidelity::Aggregate] {
        let run = || {
            let mut rng = Pcg64::new(7);
            let mut cache = PopularityCache::default();
            let mut loads = Vec::new();
            let mut all = Vec::new();
            for d in 0..20u64 {
                assign_tokens_into(
                    RoutingPolicy::Drifting { alpha: 0.1, period: 6 },
                    fidelity,
                    64,
                    8,
                    2,
                    None,
                    d,
                    &mut cache,
                    &mut rng,
                    &mut loads,
                );
                all.extend_from_slice(&loads);
            }
            all
        };
        assert_eq!(run(), run(), "{fidelity:?} must be seed-deterministic");
        // inside epoch 0, drifting is bit-identical to skewed (the
        // drift/skew epoch-0 equivalence carries over to both samplers)
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        let mut ca = PopularityCache::default();
        let mut cb = PopularityCache::default();
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        for d in 0..6u64 {
            assign_tokens_into(
                RoutingPolicy::Drifting { alpha: 0.1, period: 6 },
                fidelity,
                64,
                8,
                2,
                None,
                d,
                &mut ca,
                &mut a,
                &mut la,
            );
            assign_tokens_into(
                RoutingPolicy::Skewed { alpha: 0.1 },
                fidelity,
                64,
                8,
                2,
                None,
                d,
                &mut cb,
                &mut b,
                &mut lb,
            );
            assert_eq!(la, lb, "{fidelity:?} draw {d}");
        }
    }
}

#[test]
fn capacity_semantics_agree_across_samplers() {
    // a tight cap: every sampler respects it and conserves
    // routed + dropped == tokens * k
    let cap = frontier::moe::expert_capacity(512, 8, 2, 1.0);
    let policy = RoutingPolicy::Skewed { alpha: 0.05 };
    let mut results = Vec::new();
    for (name, fidelity) in [
        ("oracle", None),
        ("alias", Some(RoutingFidelity::Token)),
        ("aggregate", Some(RoutingFidelity::Aggregate)),
    ] {
        let mut rng = Pcg64::new(41);
        let (loads, dropped) = match fidelity {
            None => assign_tokens_oracle(policy, 512, 8, 2, Some(cap), 0, &mut rng),
            Some(f) => {
                let mut cache = PopularityCache::default();
                let mut loads = Vec::new();
                let d = assign_tokens_into(
                    policy,
                    f,
                    512,
                    8,
                    2,
                    Some(cap),
                    0,
                    &mut cache,
                    &mut rng,
                    &mut loads,
                );
                (loads, d)
            }
        };
        assert!(loads.iter().all(|&l| l <= cap), "{name}: cap violated");
        assert!(dropped > 0, "{name}: heavy skew under cf=1.0 must drop");
        assert_eq!(
            loads.iter().map(|&x| u64::from(x)).sum::<u64>() + dropped,
            1024,
            "{name}: slots lost"
        );
        results.push((name, dropped));
    }
    // drop volume is driven by the (shared) popularity skew: all three
    // land in the same ballpark
    let (lo, hi) = results
        .iter()
        .fold((u64::MAX, 0), |(lo, hi), &(_, d)| (lo.min(d), hi.max(d)));
    assert!(
        (hi - lo) as f64 / hi as f64 <= 0.5,
        "drop volumes diverge: {results:?}"
    );
}
