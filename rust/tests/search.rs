//! Autotuner integration tests: the three ISSUE-10 properties —
//! (a) Pareto pruning never discards a non-dominated point,
//! (b) dedup-enabled searches find byte-identically what dedup-disabled
//!     ones find,
//! (c) a killed (`max_sims`) then `--resume`d search reproduces the
//!     uninterrupted merged md/CSV/JSON byte-for-byte, for 1 and 4
//!     threads —
//! plus thread-invariance of the merged report, manifest clobber
//! protection, and the trajectory/dedup accounting on an inert-axis
//! grid.

use std::fs;
use std::path::PathBuf;

use frontier::config::cli::FlagMap;
use frontier::proptest_util::{run_prop, Gen};
use frontier::report::search::{search_csv, search_json, search_markdown};
use frontier::search::{pareto_kept, MetricPoint, Objective, SearchResult, SearchRunner, SearchSpec};
use frontier::sweep::{Axis, SweepSpec};

/// Cheap dense base (mirrors `rust/tests/sweep.rs`).
fn tiny_base() -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("replicas", "2");
    f.set("requests", "24");
    f.set("input", "32");
    f.set("output", "16");
    f
}

/// Cheap MoE base with a 2-rank EP domain: the grid where
/// `migration-threshold` is inert (migration defaults to off), so
/// config-hash dedup has real work to do.
fn moe_base() -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny-moe");
    f.set("replicas", "1");
    f.set("ep", "2");
    f.set("requests", "16");
    f.set("input", "32");
    f.set("output", "8");
    f
}

fn axis(name: &str, values: &[&str]) -> Axis {
    Axis::new(name, values.iter().map(|s| s.to_string()).collect()).unwrap()
}

fn dense_spec() -> SearchSpec {
    SearchSpec {
        sweep: SweepSpec::new(tiny_base())
            .with_axes(vec![axis("seed", &["1", "2", "3", "4"]), axis("input", &["16", "32"])]),
        objective: Objective::Cost,
        rungs: 2,
        promote_frac: 0.5,
    }
}

fn moe_spec() -> SearchSpec {
    SearchSpec {
        sweep: SweepSpec::new(moe_base()).with_axes(vec![
            axis("capacity-factor", &["1.0", "1.5"]),
            axis("migration-threshold", &["1.1", "1.2", "1.3"]),
        ]),
        objective: Objective::Cost,
        rungs: 2,
        promote_frac: 0.5,
    }
}

/// All three merged renderings, concatenated — the byte-identity
/// currency of these tests.
fn rendered(r: &SearchResult) -> String {
    format!(
        "{}\n===\n{}\n===\n{}",
        search_markdown(r),
        search_csv(r),
        search_json(r).to_string_pretty()
    )
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("frontier_search_{name}_{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

// ---- property (a): Pareto pruning never discards a non-dominated point

#[test]
fn prop_pareto_never_discards_non_dominated() {
    run_prop("pareto keeps every non-dominated point", 200, |g: &mut Gen| {
        // discrete coordinate sets provoke the tie cases (equal points,
        // equal on two of three axes) that a continuous draw never hits
        let vals = [1.0, 2.0, 3.0];
        let n = g.u32(1, 12) as usize;
        let pts: Vec<MetricPoint> = (0..n)
            .map(|_| MetricPoint {
                cost_gpu_s_per_1k: *g.pick(&vals),
                goodput_rps: *g.pick(&vals),
                tbt_p99_ms: *g.pick(&vals),
            })
            .collect();
        let kept = pareto_kept(&pts);
        assert_eq!(kept.len(), pts.len());
        let dominates = |a: &MetricPoint, b: &MetricPoint| {
            a.cost_gpu_s_per_1k <= b.cost_gpu_s_per_1k
                && a.goodput_rps >= b.goodput_rps
                && a.tbt_p99_ms <= b.tbt_p99_ms
                && (a.cost_gpu_s_per_1k < b.cost_gpu_s_per_1k
                    || a.goodput_rps > b.goodput_rps
                    || a.tbt_p99_ms < b.tbt_p99_ms)
        };
        for (i, b) in pts.iter().enumerate() {
            let dominated = pts.iter().any(|a| dominates(a, b));
            assert_eq!(
                kept[i], !dominated,
                "point {i} ({b:?}) kept={} but dominated={dominated} in {pts:?}",
                kept[i]
            );
        }
        // at least one point always survives a non-empty set
        assert!(kept.iter().any(|&k| k), "{pts:?}");
    });
}

// ---- property (b): dedup changes the work, never the findings

#[test]
fn dedup_on_and_off_find_byte_identical_results() {
    let spec = moe_spec();
    let on = SearchRunner::with_threads(2).run(&spec).unwrap();
    let off = SearchRunner { dedup: false, ..SearchRunner::with_threads(2) }.run(&spec).unwrap();
    // dedup shows up only in the work accounting...
    assert!(on.dedup_hits() > 0, "inert migration-threshold axis must dedup");
    assert_eq!(off.dedup_hits(), 0);
    assert!(on.searched_points() < off.searched_points());
    // ...never in what was found: ranking and errors byte-identical
    assert_eq!(search_csv(&on), search_csv(&off));
    let (jon, joff) = (search_json(&on), search_json(&off));
    assert_eq!(
        jon.req("ranked").unwrap().to_string_pretty(),
        joff.req("ranked").unwrap().to_string_pretty(),
        "dedup changed the embedded reports or ranking"
    );
    assert_eq!(
        jon.req("errors").unwrap().to_string_pretty(),
        joff.req("errors").unwrap().to_string_pretty()
    );
}

// ---- property (c): kill + resume is byte-identical to uninterrupted

#[test]
fn killed_then_resumed_search_is_byte_identical() {
    let spec = moe_spec();
    for threads in [1usize, 4] {
        let uninterrupted = SearchRunner::with_threads(threads).run(&spec).unwrap();
        let want = rendered(&uninterrupted);
        let dir = tmp(&format!("resume_{threads}t"));
        // kill after 1 fresh simulation (rung 0 alone needs 2 uniques)
        let killed = SearchRunner {
            manifest_dir: Some(dir.clone()),
            max_sims: Some(1),
            ..SearchRunner::with_threads(threads)
        }
        .run(&spec);
        let msg = killed.unwrap_err().to_string();
        assert!(msg.contains("--resume"), "budget error must point at resume: {msg}");
        // resume: finishes the grid, report byte-identical
        let resumed = SearchRunner {
            manifest_dir: Some(dir.clone()),
            resume: true,
            ..SearchRunner::with_threads(threads)
        }
        .run(&spec)
        .unwrap();
        assert_eq!(
            rendered(&resumed),
            want,
            "resumed report diverged from uninterrupted ({threads} threads)"
        );
        // and a second resume (everything cached) is *still* identical
        let dir2 = tmp(&format!("resume2_{threads}t"));
        fs::create_dir_all(&dir2).unwrap();
        fs::rename(dir.join("manifest.jsonl"), dir2.join("manifest.jsonl")).unwrap();
        fs::rename(dir.join("points"), dir2.join("points")).unwrap();
        let warm = SearchRunner {
            manifest_dir: Some(dir2.clone()),
            resume: true,
            ..SearchRunner::with_threads(threads)
        }
        .run(&spec)
        .unwrap();
        assert_eq!(rendered(&warm), want, "fully-cached resume diverged");
        fs::remove_dir_all(&dir).unwrap();
        fs::remove_dir_all(&dir2).unwrap();
    }
}

// ---- thread invariance and determinism

#[test]
fn merged_report_is_byte_identical_across_thread_counts() {
    let spec = dense_spec();
    let r1 = SearchRunner::with_threads(1).run(&spec).unwrap();
    let r4 = SearchRunner::with_threads(4).run(&spec).unwrap();
    let r9 = SearchRunner::with_threads(9).run(&spec).unwrap();
    let rd = SearchRunner::default().run(&spec).unwrap();
    let want = rendered(&r1);
    assert_eq!(want, rendered(&r4));
    assert_eq!(want, rendered(&r9), "oversubscribed");
    assert_eq!(want, rendered(&rd), "all-cores default");
}

// ---- trajectory, halving, and dedup accounting

#[test]
fn trajectory_reflects_halving_pruning_and_dedup() {
    let spec = moe_spec();
    let r = SearchRunner::with_threads(2).run(&spec).unwrap();
    assert_eq!(r.grid_points, 6);
    assert_eq!(r.full_requests, 16);
    assert_eq!(r.trajectory.len(), 2);
    let (r0, r1) = (&r.trajectory[0], &r.trajectory[1]);
    // rung 0 at the quartered horizon (16/4), rung 1 at the full one
    assert_eq!(r0.requests, 4);
    assert_eq!(r1.requests, 16);
    assert_eq!(r0.population, 6);
    // migration-threshold is inert: 2 unique configs, 4 dedup hits
    assert_eq!(r0.simulated, 2);
    assert_eq!(r0.dedup_hits, 4);
    assert_eq!(r0.errors, 0);
    // halving: at most half (of the Pareto pool) promoted, >= 1
    assert!(r1.population >= 1 && r1.population <= 3);
    assert_eq!(r1.population, r0.promoted);
    assert_eq!(r.ranked.len(), r1.promoted);
    // ranking is sorted by the objective
    for w in r.ranked.windows(2) {
        assert!(w[0].score <= w[1].score);
    }
    // final-rung pareto flags exist and mark at least the best point
    assert!(r.ranked.iter().any(|p| p.pareto));
    // summary line surfaces the accounting
    let md = search_markdown(&r);
    assert!(md.contains("## Trajectory") && md.contains("## Ranking"), "{md}");
    assert!(md.contains(&format!("dedup_hits={}", r.dedup_hits())), "{md}");
}

#[test]
fn single_rung_search_is_a_ranked_full_horizon_pass() {
    let mut spec = dense_spec();
    spec.rungs = 1;
    let r = SearchRunner::with_threads(2).run(&spec).unwrap();
    assert_eq!(r.trajectory.len(), 1);
    assert_eq!(r.trajectory[0].requests, 24, "one rung = the full horizon");
    assert_eq!(r.ranked.len(), 8, "nothing pruned before a final ranking");
}

// ---- errors are isolated and identifiable

#[test]
fn point_errors_carry_rung_and_written_flags() {
    // tiny-moe has 8 experts: ep=3 cannot shard them, ep=2 can
    let mut base = moe_base();
    base.remove("ep");
    let spec = SearchSpec {
        sweep: SweepSpec::new(base).with_axes(vec![axis("ep", &["3", "2"])]),
        objective: Objective::Cost,
        rungs: 2,
        promote_frac: 1.0,
    };
    let r = SearchRunner::with_threads(2).run(&spec).unwrap();
    assert_eq!(r.errors.len(), 1, "the bad point errors once, at its first rung");
    assert_eq!(r.errors[0].rung, 0);
    assert_eq!(r.errors[0].point.written, vec![("ep".to_string(), "3".to_string())]);
    assert_eq!(r.ranked.len(), 1, "the good point survives to the ranking");
    let j = search_json(&r);
    let errs = j.req("errors").unwrap().as_arr().unwrap();
    assert_eq!(errs[0].req("written").unwrap().req("ep").unwrap().as_str().unwrap(), "3");
    let md = search_markdown(&r);
    assert!(md.contains("## Errors"), "{md}");
}

// ---- manifest safety at the runner level

#[test]
fn manifest_requires_resume_to_reuse_and_dedup_to_exist() {
    let spec = dense_spec();
    let dir = tmp("clobber");
    SearchRunner { manifest_dir: Some(dir.clone()), ..SearchRunner::with_threads(1) }
        .run(&spec)
        .unwrap();
    // a second run into the same directory must refuse without --resume
    let err = SearchRunner { manifest_dir: Some(dir.clone()), ..SearchRunner::with_threads(1) }
        .run(&spec)
        .unwrap_err()
        .to_string();
    assert!(err.contains("--resume"), "{err}");
    // manifest entries are hash-keyed: dedup=false cannot honor them
    let err = SearchRunner {
        manifest_dir: Some(dir.clone()),
        resume: true,
        dedup: false,
        ..SearchRunner::with_threads(1)
    }
    .run(&spec)
    .unwrap_err()
    .to_string();
    assert!(err.contains("dedup"), "{err}");
    fs::remove_dir_all(&dir).unwrap();
}
