//! Sweep-engine integration tests: merged reports byte-identical across
//! thread counts, stable grid ordering, single-point parity with the
//! `frontier simulate` config lowering, and per-point error isolation.

use frontier::config::cli::{build_config, FlagMap};
use frontier::config::DeploymentMode;
use frontier::report::sweep::{sweep_csv, sweep_json, sweep_markdown};
use frontier::sweep::{Axis, PointSpec, SweepRunner, SweepSpec};

/// Cheap dense base: 2 tiny replicas, small batch workload.
fn tiny_base() -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny");
    f.set("replicas", "2");
    f.set("requests", "24");
    f.set("input", "32");
    f.set("output", "16");
    f
}

/// Cheap MoE base: one tiny-moe replica with a 2-rank EP domain.
fn moe_base() -> FlagMap {
    let mut f = FlagMap::new();
    f.set("model", "tiny-moe");
    f.set("replicas", "1");
    f.set("ep", "2");
    f.set("requests", "16");
    f.set("input", "32");
    f.set("output", "8");
    f
}

fn seed_axis(values: &[&str]) -> Axis {
    Axis::new("seed", values.iter().map(|s| s.to_string()).collect()).unwrap()
}

#[test]
fn multithreaded_sweep_is_byte_identical_to_serial() {
    let spec = SweepSpec::new(tiny_base()).with_axes(vec![
        seed_axis(&["1", "2", "3"]),
        Axis::new("requests", vec!["8".into(), "16".into()]).unwrap(),
    ]);
    let r1 = SweepRunner::with_threads(1).run(&spec).unwrap();
    let r4 = SweepRunner::with_threads(4).run(&spec).unwrap();
    assert_eq!(
        sweep_json(&r1).to_string_pretty(),
        sweep_json(&r4).to_string_pretty(),
        "merged JSON must not depend on thread count"
    );
    assert_eq!(sweep_csv(&r1), sweep_csv(&r4));
    assert_eq!(sweep_markdown(&r1), sweep_markdown(&r4));
    // oversubscribed runner (more threads than points) and the
    // all-cores default resolve to the same bytes too
    let r9 = SweepRunner::with_threads(9).run(&spec).unwrap();
    assert_eq!(sweep_json(&r1).to_string_pretty(), sweep_json(&r9).to_string_pretty());
    let rd = SweepRunner::default().run(&spec).unwrap();
    assert_eq!(sweep_json(&r1).to_string_pretty(), sweep_json(&rd).to_string_pretty());
}

#[test]
fn grid_ordering_is_stable_and_row_major() {
    let spec = SweepSpec::new(tiny_base()).with_axes(vec![
        seed_axis(&["1", "2"]),
        Axis::new("requests", vec!["8".into(), "12".into(), "16".into()]).unwrap(),
    ]);
    let pts = spec.points().unwrap();
    let labels: Vec<&str> = pts.iter().map(|p| p.label.as_str()).collect();
    assert_eq!(
        labels,
        [
            "seed=1 requests=8",
            "seed=1 requests=12",
            "seed=1 requests=16",
            "seed=2 requests=8",
            "seed=2 requests=12",
            "seed=2 requests=16",
        ]
    );
    // the runner's output preserves exactly this order
    let run = SweepRunner::with_threads(3).run(&spec).unwrap();
    let got: Vec<&str> = run.points.iter().map(|p| p.point.label.as_str()).collect();
    assert_eq!(got, labels);
    assert!(run.points.iter().enumerate().all(|(i, p)| p.point.index == i));
}

#[test]
fn single_point_sweep_bit_reproduces_simulate_lowering() {
    // a one-value axis through the sweep engine must price exactly what
    // `frontier simulate` prices for the same flags
    let mut flags = moe_base();
    flags.set("routing", "skewed:0.3");
    let spec = SweepSpec::new(flags.clone())
        .with_axes(vec![Axis::new("capacity-factor", vec!["1.25".into()]).unwrap()]);
    let swept = SweepRunner::with_threads(2).run(&spec).unwrap();
    assert_eq!(swept.points.len(), 1);
    let from_sweep = swept.points[0].outcome.as_ref().unwrap().to_json_deterministic();

    flags.set("capacity-factor", "1.25");
    let direct = frontier::run_experiment(&build_config(&flags).unwrap()).unwrap();
    assert_eq!(
        direct.to_json_deterministic().to_string_pretty(),
        from_sweep.to_string_pretty(),
        "sweep lowering diverged from the simulate lowering"
    );
}

#[test]
fn pd_ratio_axis_owns_the_deployment_shape() {
    let mut base = tiny_base();
    base.set("stages", "prefill:1;decode:1"); // the axis must clear this
    let spec = SweepSpec::new(base)
        .with_axes(vec![Axis::new("pd-ratio", vec!["1:3".into(), "2:2".into()]).unwrap()]);
    let pts = spec.points().unwrap();
    let cfg0 = spec.point_config(&pts[0]).unwrap();
    assert!(cfg0.stages.is_none(), "pd-ratio takes over an explicit stage graph");
    assert_eq!(
        cfg0.mode,
        DeploymentMode::PdDisagg { prefill_replicas: 1, decode_replicas: 3 }
    );
    let cfg1 = spec.point_config(&pts[1]).unwrap();
    assert_eq!(
        cfg1.mode,
        DeploymentMode::PdDisagg { prefill_replicas: 2, decode_replicas: 2 }
    );
}

#[test]
fn per_point_errors_do_not_abort_the_sweep() {
    // tiny-moe has 8 experts: ep=3 cannot shard them, ep=2 can
    let mut base = moe_base();
    base.remove("ep");
    let spec = SweepSpec::new(base)
        .with_axes(vec![Axis::new("ep", vec!["3".into(), "2".into()]).unwrap()]);
    let r = SweepRunner::with_threads(2).run(&spec).unwrap();
    assert_eq!(r.points.len(), 2);
    assert!(r.points[0].outcome.is_err(), "8 experts cannot shard over ep=3");
    assert!(r.points[1].outcome.is_ok(), "the good point still ran");
    let csv = sweep_csv(&r);
    assert!(csv.contains("error"), "{csv}");
    let cols = csv.lines().next().unwrap().matches(',').count();
    assert!(
        csv.lines().all(|l| l.matches(',').count() == cols),
        "error rows keep the CSV rectangular: {csv}"
    );
    // JSON carries the error string in place of the report
    let j = sweep_json(&r);
    let pts = j.req("points").unwrap().as_arr().unwrap();
    assert!(pts[0].get("error").is_some() && pts[0].get("report").is_none());
    assert!(pts[1].get("report").is_some() && pts[1].get("error").is_none());
}

#[test]
fn explicit_points_run_with_labels() {
    let spec = SweepSpec::new(tiny_base()).with_points(vec![
        PointSpec::parse("seed=3,requests=8").unwrap().with_label("small"),
        PointSpec::parse("seed=4").unwrap(),
    ]);
    let r = SweepRunner::with_threads(2).run(&spec).unwrap();
    assert!(r.axes.is_empty());
    assert_eq!(r.points[0].point.label, "small");
    assert_eq!(r.points[1].point.label, "seed=4");
    assert!(r.points.iter().all(|p| p.outcome.is_ok()));
    let md = sweep_markdown(&r);
    assert!(md.contains("point") && md.contains("small"), "{md}");
}

#[test]
fn sweep_json_reports_are_deterministic_projections() {
    let spec = SweepSpec::new(tiny_base()).with_axes(vec![seed_axis(&["5"])]);
    let r = SweepRunner::with_threads(1).run(&spec).unwrap();
    let j = sweep_json(&r);
    let rep = j.req("points").unwrap().as_arr().unwrap()[0].req("report").unwrap();
    assert!(rep.get("host_duration_s").is_none(), "host time must not leak into sweep output");
    assert!(rep.get("sim_duration_s").is_some());
}
