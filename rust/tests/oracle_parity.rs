//! Golden-vector parity: the Rust oracle and feature extractor must
//! match the Python implementations that trained the predictors.
//!
//! `make artifacts` writes `artifacts/oracle_golden.json` from the
//! Python side; these tests replay every case through the Rust mirror.
//! Skipped (cleanly) when artifacts are absent.

use frontier::config::json::Json;
use frontier::hardware::{GpuSpec, LinkSpec};
use frontier::operators::features;
use frontier::oracle;

const REL_TOL: f64 = 1e-9;

fn golden() -> Option<Json> {
    let path = frontier::runtime::PredictorRuntime::default_dir().join("oracle_golden.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("golden parses"))
}

fn assert_close(got: f64, want: f64, what: &str) {
    let denom = want.abs().max(1e-12);
    let rel = (got - want).abs() / denom;
    assert!(rel < REL_TOL, "{what}: got {got}, want {want} (rel {rel:.2e})");
}

#[test]
fn attn_times_and_features_match_python() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let gpu = GpuSpec::a800();
    let cases = g.req("attn").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 50);
    for (i, c) in cases.iter().enumerate() {
        let q: Vec<u32> = c.req("q_lens").unwrap().as_u32_vec().unwrap();
        let ctx: Vec<u32> = c.req("ctx_lens").unwrap().as_u32_vec().unwrap();
        let h = c.req("n_heads").unwrap().as_u64().unwrap() as u32;
        let hkv = c.req("n_kv_heads").unwrap().as_u64().unwrap() as u32;
        let d = c.req("head_dim").unwrap().as_u64().unwrap() as u32;
        let is_prefill = c.req("is_prefill").unwrap().as_bool().unwrap();
        let want_us = c.req("time_us").unwrap().as_f64().unwrap();
        let got = if is_prefill {
            oracle::attn_prefill_time(&q, &ctx, h, hkv, d, 2, &gpu)
        } else {
            oracle::attn_decode_time(&ctx, h, hkv, d, 2, &gpu)
        };
        assert_close(got * 1e6, want_us, &format!("attn[{i}] time"));
        let want_f = c.req("features").unwrap().as_f64_vec().unwrap();
        let got_f = features::attn_features(is_prefill, &q, &ctx, h, hkv, d, &gpu);
        assert_eq!(got_f.len(), want_f.len(), "attn[{i}] feature count");
        for (j, (a, b)) in got_f.iter().zip(&want_f).enumerate() {
            assert_close(*a, *b, &format!("attn[{i}] feature {j}"));
        }
    }
}

#[test]
fn grouped_gemm_matches_python() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let gpu = GpuSpec::a800();
    for (i, c) in g.req("grouped_gemm").unwrap().as_arr().unwrap().iter().enumerate() {
        let loads = c.req("tokens_per_expert").unwrap().as_u32_vec().unwrap();
        let n = c.req("n").unwrap().as_u64().unwrap();
        let k = c.req("k").unwrap().as_u64().unwrap();
        let want_us = c.req("time_us").unwrap().as_f64().unwrap();
        let got = oracle::grouped_gemm_time(&loads, n, k, 2, &gpu);
        assert_close(got * 1e6, want_us, &format!("gg[{i}] time"));
        let want_f = c.req("features").unwrap().as_f64_vec().unwrap();
        let got_f = features::grouped_gemm_features(&loads, n, k, &gpu);
        for (j, (a, b)) in got_f.iter().zip(&want_f).enumerate() {
            assert_close(*a, *b, &format!("gg[{i}] feature {j}"));
        }
    }
}

#[test]
fn gemm_matches_python() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let gpu = GpuSpec::a800();
    for (i, c) in g.req("gemm").unwrap().as_arr().unwrap().iter().enumerate() {
        let m = c.req("m").unwrap().as_u64().unwrap();
        let n = c.req("n").unwrap().as_u64().unwrap();
        let k = c.req("k").unwrap().as_u64().unwrap();
        let want_us = c.req("time_us").unwrap().as_f64().unwrap();
        let got = oracle::gemm_time(m, n, k, 2, &gpu);
        assert_close(got * 1e6, want_us, &format!("gemm[{i}] m={m} n={n} k={k}"));
        let want_f = c.req("features").unwrap().as_f64_vec().unwrap();
        let got_f = features::gemm_features(m, n, k, &gpu);
        for (j, (a, b)) in got_f.iter().zip(&want_f).enumerate() {
            assert_close(*a, *b, &format!("gemm[{i}] feature {j}"));
        }
    }
}

#[test]
fn ep_fabric_all2all_reduces_to_closed_form_uncontended() {
    // Not golden-gated: the FIFO-contended EP fabric must reduce to the
    // analytical `oracle::all2all_time` in the uncontended case — a
    // uniform byte matrix over a single cluster, where each of the n
    // ranks holds `per_rank` bytes and sends 1/n of it to every peer.
    // This keeps the golden collective vectors honest for the EP path.
    use frontier::core::SimTime;
    use frontier::moe::{EpNetwork, EpTopology};

    for spec in [LinkSpec::nvlink_a800(), LinkSpec::infiniband_ndr()] {
        for n in [2u32, 4, 8, 16] {
            let topo = EpTopology::new(n, 1);
            let mut net = EpNetwork::new(topo, spec, spec);
            let per_rank = 4.0e6;
            let mat = vec![per_rank / n as f64; (n * n) as usize];
            let (finish, phase) = net.all_to_all(SimTime::ZERO, &mat);
            let want = oracle::all2all_time(per_rank, n, &spec);
            let got = finish.as_secs_f64();
            let rel = (got - want).abs() / want;
            assert!(rel < 1e-6, "n={n}: fabric {got} vs closed form {want} (rel {rel:.2e})");
            assert_eq!(phase.cross_bytes, 0.0, "single cluster must have no cross bytes");
            assert!((phase.total_bytes - per_rank * n as f64).abs() < 1e-6 * per_rank);
        }
    }
}

#[test]
fn collectives_match_python() {
    let Some(g) = golden() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let link = LinkSpec::nvlink_a800();
    for (i, c) in g.req("collective").unwrap().as_arr().unwrap().iter().enumerate() {
        let bytes = c.req("bytes").unwrap().as_f64().unwrap();
        let n = c.req("n_ranks").unwrap().as_u64().unwrap() as u32;
        assert_close(
            oracle::allreduce_time(bytes, n, &link) * 1e6,
            c.req("allreduce_us").unwrap().as_f64().unwrap(),
            &format!("allreduce[{i}]"),
        );
        assert_close(
            oracle::all2all_time(bytes, n, &link) * 1e6,
            c.req("all2all_us").unwrap().as_f64().unwrap(),
            &format!("all2all[{i}]"),
        );
        assert_close(
            oracle::p2p_time(bytes, &link) * 1e6,
            c.req("p2p_us").unwrap().as_f64().unwrap(),
            &format!("p2p[{i}]"),
        );
    }
}
