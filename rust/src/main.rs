//! `frontier` CLI: run simulations, sweeps, and validation from the
//! command line (hand-rolled arg parsing; no clap in this offline build).

use anyhow::{anyhow, bail, Result};

use frontier::baseline::ReplicaCentricSim;
use frontier::config::{DeploymentMode, ExperimentConfig, OverheadConfig};
use frontier::model::ModelConfig;
use frontier::predictor::PredictorKind;
use frontier::workload::WorkloadSpec;

const USAGE: &str = "\
frontier — simulator for next-generation LLM inference systems

USAGE:
  frontier simulate [OPTIONS]     run one simulation and print the report
  frontier sweep-pd [OPTIONS]     sweep prefill:decode ratios at fixed GPUs
  frontier baseline [OPTIONS]     run the replica-centric (Vidur-style) baseline
  frontier validate               check AOT artifacts load and predict
  frontier info                   list models, predictors, modes

OPTIONS (simulate / sweep-pd / baseline):
  --model <qwen2-7b|qwen2-72b|mixtral-8x7b|deepseek-v3-lite|tiny|tiny-moe>
  --mode <colocated|pd|af>         deployment (default colocated)
  --stages <DSL>                   explicit stage graph, overrides --mode:
                                   stages `kind[:replicas][@gpu][,key=val...]`
                                   joined by `;`. kinds: unified|prefill|decode|af;
                                   gpus: a800|a100|h100|h200; keys: tp pp ep attn
                                   ffn micro batch ptok cluster node epc name.
                                   e.g. \"prefill:2@h200,tp=2;af,attn=4,ffn=4,micro=2\"
  --stages-json <file.json>        stage graph from JSON (same schema)
  --edges <spec>                   kv edges as \"0>1,0>2\" (default: auto-wire)
  --gpu <a800|a100|h100|h200>      default GPU for stages without @gpu (default a800)
  --replicas <N>                   colocated replicas (default 4)
  --prefill <N> --decode <N>       PD cluster sizes (default 4/4)
  --attn-gpus <N> --ffn-gpus <N>   AF pool sizes (default 4/4)
  --micro-batches <M>              AF micro-batches (default 2)
  --tp <N> --pp <N> --ep <N>       per-replica parallelism (default 1/1/1)
  --routing <balanced|uniform|skewed:ALPHA|drift:ALPHA:PERIOD>  MoE routing (default uniform)
  --routing-fidelity <token|aggregate> routing-draw sampler: per-token alias
                                   draws, or O(E) aggregate counts for
                                   huge-batch scale runs (default token)
  --drift <N>                      popularity epoch length in routing draws; upgrades
                                   skewed routing to drifting popularity (default off)
  --ep-placement <contiguous|strided|replicated:K>  expert placement (default contiguous)
  --ep-clusters <N>                EP ranks span N clusters (default 1)
  --migration <off|threshold>      dynamic expert migration (default off)
  --migration-threshold <F>        migrate when current/rebalanced predicted
                                   imbalance ratio exceeds F >= 1 (default 1.25)
  --load-window <N>                expert-load EWMA window, routing draws (default 64)
  --capacity-factor <F>            MoE per-expert token cap (GShard drops; default off)
  --cross-bw <GBps>                cross-cluster WAN bandwidth (default 12.5)
  --inter-bw <GBps>                inter-node IB bandwidth (default 50)
  --ranks-per-node <N>             EP ranks per node (default: cluster = one node)
  --ingress-scale <F>              ingress/egress NIC bandwidth ratio (default 1.0)
  --predictor <oracle|learned|vidur|roofline>   (default oracle)
  --requests <N>                   workload size (default 256)
  --input <N> --output <N>         token lengths (default 128/128)
  --rate <R>                       Poisson arrivals at R req/s (default: batch)
  --trace <file.json>              replay a trace file instead of generating
  --profiled                       use the real-system overhead preset
  --seed <S>                       RNG seed (default 1)
  --json                           emit the report as JSON
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    cmd: String,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Result<Args> {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut flags = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("unexpected argument {a:?}"))?
                .to_string();
            // boolean flags
            if matches!(key.as_str(), "json" | "profiled") {
                flags.insert(key, "true".into());
                continue;
            }
            let val = it.next().ok_or_else(|| anyhow!("--{key} needs a value"))?;
            flags.insert(key, val);
        }
        Ok(Args { cmd, flags })
    }

    fn get(&self, k: &str) -> Option<&str> {
        self.flags.get(k).map(|s| s.as_str())
    }

    fn num<T: std::str::FromStr>(&self, k: &str, default: T) -> Result<T> {
        match self.get(k) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad value for --{k}: {v:?}")),
        }
    }

    fn has(&self, k: &str) -> bool {
        self.flags.contains_key(k)
    }
}

fn model_by_name(name: &str) -> Result<ModelConfig> {
    Ok(match name {
        "qwen2-7b" => ModelConfig::qwen2_7b(),
        "qwen2-72b" => ModelConfig::qwen2_72b(),
        "mixtral-8x7b" => ModelConfig::mixtral_8x7b(),
        "deepseek-v3-lite" => ModelConfig::deepseek_v3_lite(),
        "tiny" => ModelConfig::tiny(),
        "tiny-moe" => ModelConfig::tiny_moe(),
        _ => bail!("unknown model {name:?} (see `frontier info`)"),
    })
}

fn build_config(a: &Args) -> Result<ExperimentConfig> {
    let model = model_by_name(a.get("model").unwrap_or("qwen2-7b"))?;
    let mode = a.get("mode").unwrap_or("colocated");
    let mut cfg = match mode {
        "colocated" => ExperimentConfig::colocated(model, a.num("replicas", 4u32)?),
        "pd" => ExperimentConfig::pd(model, a.num("prefill", 4u32)?, a.num("decode", 4u32)?),
        "af" => ExperimentConfig::af(
            model,
            a.num("prefill", 2u32)?,
            a.num("attn-gpus", 4u32)?,
            a.num("ffn-gpus", 4u32)?,
            a.num("micro-batches", 2u32)?,
        ),
        _ => bail!("unknown mode {mode:?}"),
    };
    cfg.parallel = frontier::parallelism::Parallelism::new(
        a.num("tp", 1u32)?,
        a.num("pp", 1u32)?,
        a.num("ep", 1u32)?,
    );
    if let Some(g) = a.get("gpu") {
        cfg.gpu = frontier::hardware::GpuSpec::by_name(g)
            .ok_or_else(|| anyhow!("unknown gpu {g:?} (a800|a100|h100|h200)"))?;
    }
    // explicit stage graph (DSL or JSON) overrides the mode-level shape
    match (a.get("stages"), a.get("stages-json")) {
        (Some(_), Some(_)) => bail!("--stages and --stages-json are mutually exclusive"),
        (Some(dsl), None) => {
            cfg = cfg.with_stages(frontier::config::StageGraphConfig::parse_cli(
                dsl,
                a.get("edges"),
            )?);
        }
        (None, Some(path)) => {
            let text = std::fs::read_to_string(path)?;
            let json = frontier::config::json::Json::parse(&text)?;
            cfg = cfg.with_stages(frontier::config::StageGraphConfig::from_json(&json)?);
        }
        (None, None) => {
            if a.has("edges") {
                bail!("--edges requires --stages");
            }
        }
    }
    let requests = a.num("requests", 256u32)?;
    let input = a.num("input", 128u32)?;
    let output = a.num("output", 128u32)?;
    cfg.workload = match a.get("rate") {
        Some(r) => WorkloadSpec::poisson(
            r.parse().map_err(|_| anyhow!("bad --rate"))?,
            requests,
            input,
            output,
        ),
        None => WorkloadSpec::table2(requests, input, output),
    };
    if let Some(r) = a.get("routing") {
        cfg.policy.moe_routing = frontier::moe::RoutingPolicy::parse(r).ok_or_else(|| {
            anyhow!("unknown routing {r:?} (balanced|uniform|skewed:ALPHA|drift:ALPHA:PERIOD)")
        })?;
    }
    let drift = a.num("drift", 0u64)?;
    if drift > 0 {
        cfg.policy.moe_routing = match cfg.policy.moe_routing {
            frontier::moe::RoutingPolicy::Skewed { alpha } => {
                frontier::moe::RoutingPolicy::Drifting { alpha, period: drift }
            }
            frontier::moe::RoutingPolicy::Drifting { alpha, .. } => {
                frontier::moe::RoutingPolicy::Drifting { alpha, period: drift }
            }
            _ => bail!("--drift requires skewed routing (--routing skewed:ALPHA)"),
        };
    }
    if let Some(f) = a.get("routing-fidelity") {
        cfg.policy.routing_fidelity = frontier::moe::RoutingFidelity::parse(f)
            .ok_or_else(|| anyhow!("unknown routing fidelity {f:?} (token|aggregate)"))?;
    }
    if let Some(m) = a.get("migration") {
        cfg.policy.migration = frontier::moe::MigrationPolicy::parse(m)
            .ok_or_else(|| anyhow!("unknown migration policy {m:?} (off|threshold)"))?;
    }
    cfg.policy.migration_threshold = a.num("migration-threshold", 1.25f64)?;
    cfg.policy.load_window = a.num("load-window", 64u32)?;
    if let Some(p) = a.get("ep-placement") {
        cfg.policy.ep_placement = frontier::moe::PlacementPolicy::parse(p).ok_or_else(|| {
            anyhow!("unknown placement {p:?} (contiguous|strided|replicated:K)")
        })?;
    }
    cfg.ep_clusters = a.num("ep-clusters", 1u32)?;
    if let Some(bw) = a.get("cross-bw") {
        let gbps: f64 = bw.parse().map_err(|_| anyhow!("bad value for --cross-bw: {bw:?}"))?;
        cfg.cross_link.bandwidth = gbps * 1e9;
    }
    if let Some(bw) = a.get("inter-bw") {
        let gbps: f64 = bw.parse().map_err(|_| anyhow!("bad value for --inter-bw: {bw:?}"))?;
        cfg.inter_node_link.bandwidth = gbps * 1e9;
    }
    cfg.ranks_per_node = a.num("ranks-per-node", 0u32)?;
    cfg.nic_ingress_scale = a.num("ingress-scale", 1.0f64)?;
    if let Some(cf) = a.get("capacity-factor") {
        cfg.policy.capacity_factor = Some(
            cf.parse().map_err(|_| anyhow!("bad value for --capacity-factor: {cf:?}"))?,
        );
    }
    if let Some(p) = a.get("predictor") {
        cfg.predictor =
            PredictorKind::parse(p).ok_or_else(|| anyhow!("unknown predictor {p:?}"))?;
    }
    if a.has("profiled") {
        cfg.overhead = OverheadConfig::profiled_real();
    }
    cfg.seed = a.num("seed", 1u64)?;
    Ok(cfg)
}

fn run() -> Result<()> {
    let args = Args::parse()?;
    match args.cmd.as_str() {
        "simulate" => {
            let cfg = build_config(&args)?;
            let report = match args.get("trace") {
                Some(path) => {
                    let trace =
                        frontier::workload::trace_from_file(std::path::Path::new(path))?;
                    frontier::coordinator::GlobalController::new(cfg)?.run_with_trace(trace)?
                }
                None => frontier::run_experiment(&cfg)?,
            };
            if args.has("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                println!("{}", report.summary());
            }
        }
        "baseline" => {
            let cfg = build_config(&args)?;
            let report = ReplicaCentricSim::new(cfg).simulate()?;
            if args.has("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                println!("{}", report.summary());
            }
        }
        "sweep-pd" => {
            let total: u32 = args.num("gpus", 8u32)?;
            let cfg0 = build_config(&args)?;
            println!("PD ratio sweep over {total} GPUs ({})", cfg0.model.name);
            let mut rows = Vec::new();
            for p in 1..total {
                let d = total - p;
                let mut cfg = cfg0.clone();
                // the sweep owns the deployment shape
                cfg.stages = None;
                cfg.mode = DeploymentMode::PdDisagg {
                    prefill_replicas: p,
                    decode_replicas: d,
                };
                let report = frontier::run_experiment(&cfg)?;
                rows.push(vec![
                    format!("{p}:{d}"),
                    format!("{:.2}", report.tokens_per_sec_per_gpu()),
                    format!(
                        "{:.1}",
                        frontier::metrics::percentile(&report.metrics.ttft, 99.0) * 1e3
                    ),
                    format!(
                        "{:.2}",
                        frontier::metrics::percentile(&report.metrics.tbt, 99.0) * 1e3
                    ),
                ]);
            }
            println!(
                "{}",
                frontier::report::markdown_table(
                    &["P:D", "tok/s/gpu", "TTFT p99 (ms)", "TBT p99 (ms)"],
                    &rows
                )
            );
        }
        "validate" => {
            let dir = frontier::runtime::PredictorRuntime::default_dir();
            println!("loading artifacts from {dir:?}");
            let rt = frontier::runtime::PredictorRuntime::load(&dir)?;
            println!(
                "attn predictor: batch={} features={} val_mape={:.4}",
                rt.attn.batch, rt.attn.n_features, rt.attn.val_mape
            );
            println!(
                "grouped_gemm predictor: batch={} features={} val_mape={:.4}",
                rt.grouped_gemm.batch, rt.grouped_gemm.n_features, rt.grouped_gemm.val_mape
            );
            println!(
                "gemm predictor: batch={} features={} val_mape={:.4}",
                rt.gemm.batch, rt.gemm.n_features, rt.gemm.val_mape
            );
            // golden check against python predictions
            let golden_path = dir.join("predictor_golden.json");
            let text = std::fs::read_to_string(&golden_path)?;
            let golden = frontier::config::json::Json::parse(&text)?;
            for (name, exe) in [
                ("attn", &rt.attn),
                ("grouped_gemm", &rt.grouped_gemm),
                ("gemm", &rt.gemm),
            ] {
                let g = golden.req(name)?;
                let feats: Vec<Vec<f64>> = g
                    .req("features")?
                    .as_arr()?
                    .iter()
                    .map(|r| r.as_f64_vec())
                    .collect::<Result<_>>()?;
                let want = g.req("pred_us")?.as_f64_vec()?;
                let got = exe.predict_us(&feats)?;
                for (a, b) in got.iter().zip(&want) {
                    let rel = (a - b).abs() / b.max(1e-9);
                    if rel > 1e-3 {
                        bail!("{name}: runtime {a} != python {b} (rel {rel:.2e})");
                    }
                }
                println!("{name}: {} golden predictions match python", want.len());
            }
            println!("artifacts OK");
        }
        "info" => {
            println!("models: qwen2-7b qwen2-72b mixtral-8x7b deepseek-v3-lite tiny tiny-moe");
            println!("modes: colocated pd af (or --stages for arbitrary stage graphs)");
            println!("gpus: a800 a100 h100 h200");
            println!("predictors: oracle learned vidur roofline");
            println!(
                "stage DSL example: --stages \"prefill:2@h200,tp=2;af,attn=4,ffn=4,micro=2\""
            );
            for name in ["qwen2-7b", "mixtral-8x7b", "deepseek-v3-lite"] {
                let m = model_by_name(name)?;
                println!(
                    "  {name}: {} layers, d={}, {}B params, kv {} B/token{}",
                    m.n_layers,
                    m.d_model,
                    m.param_count() / 1_000_000_000,
                    m.kv_bytes_per_token(),
                    if m.is_moe() { " [MoE]" } else { "" }
                );
            }
        }
        _ => {
            print!("{USAGE}");
        }
    }
    Ok(())
}
