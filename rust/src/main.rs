//! `frontier` CLI: run simulations, design-space sweeps, and validation
//! from the command line (hand-rolled arg parsing; no clap in this
//! offline build). The flag grammar and config lowering live in
//! `frontier::config::cli`; the parallel sweep engine in
//! `frontier::sweep` — this file is only the front-end.

use anyhow::{bail, Result};

use frontier::baseline::ReplicaCentricSim;
use frontier::config::cli::{
    build_config, model_by_name, reject_unknown_flags, Args, FlagMap, DEFAULT_MODEL,
    DRIVER_FLAGS, SEARCH_FLAGS,
};
use frontier::report::search::{search_csv, search_json, search_markdown};
use frontier::report::sweep::{sweep_csv, sweep_json, sweep_markdown};
use frontier::search::{Objective, SearchResult, SearchRunner, SearchSpec};
use frontier::sweep::{Axis, PointSpec, SweepResult, SweepRunner, SweepSpec};

const USAGE: &str = "\
frontier — simulator for next-generation LLM inference systems

USAGE:
  frontier simulate [OPTIONS]     run one simulation and print the report
  frontier sweep [OPTIONS]        parallel design-space sweep over a config grid
  frontier sweep-pd [OPTIONS]     sweep prefill:decode ratios at fixed GPUs
  frontier search [OPTIONS]       autotune: successive-halving search over a grid
  frontier baseline [OPTIONS]     run the replica-centric (Vidur-style) baseline
  frontier validate               check AOT artifacts load and predict
  frontier info                   list models, predictors, modes

Flags accept both `--key value` and `--key=value`; passing the same flag
twice is an error (sweep the value with `frontier sweep --axis` instead),
and flags the subcommand does not read are rejected, not ignored.

OPTIONS (simulate / sweep / sweep-pd / baseline):
  --model <qwen2-7b|qwen2-72b|mixtral-8x7b|deepseek-v3-lite|tiny|tiny-moe>
  --mode <colocated|pd|af>         deployment (default colocated)
  --stages <DSL>                   explicit stage graph, overrides --mode:
                                   stages `kind[:replicas][@gpu][,key=val...]`
                                   joined by `;`. kinds: unified|prefill|decode|af;
                                   gpus: a800|a100|h100|h200; keys: tp pp ep attn
                                   ffn micro batch ptok cluster node epc name.
                                   e.g. \"prefill:2@h200,tp=2;af,attn=4,ffn=4,micro=2\"
  --stages-json <file.json>        stage graph from JSON (same schema)
  --edges <spec>                   kv edges as \"0>1,0>2\" (default: auto-wire)
  --gpu <a800|a100|h100|h200>      default GPU for stages without @gpu (default a800)
  --replicas <N>                   colocated replicas (default 4)
  --prefill <N> --decode <N>       PD cluster sizes (default 4/4)
  --attn-gpus <N> --ffn-gpus <N>   AF pool sizes (default 4/4)
  --micro-batches <M>              AF micro-batches (default 2)
  --tp <N> --pp <N> --ep <N>       per-replica parallelism (default 1/1/1)
  --routing <balanced|uniform|skewed:ALPHA|drift:ALPHA:PERIOD>  MoE routing (default uniform)
  --routing-fidelity <token|aggregate> routing-draw sampler: per-token alias
                                   draws, or O(E) aggregate counts for
                                   huge-batch scale runs (default token)
  --drift <N>                      popularity epoch length in routing draws; upgrades
                                   skewed routing to drifting popularity (default off)
  --ep-placement <contiguous|strided|replicated:K>  expert placement (default contiguous)
  --ep-clusters <N>                EP ranks span N clusters (default 1)
  --migration <off|threshold>      dynamic expert migration (default off)
  --migration-threshold <F>        migrate when current/rebalanced predicted
                                   imbalance ratio exceeds F >= 1 (default 1.25)
  --load-window <N>                expert-load EWMA window, routing draws (default 64)
  --capacity-factor <F>            MoE per-expert token cap (GShard drops; default off)
  --cross-bw <GBps>                cross-cluster WAN bandwidth (default 12.5)
  --inter-bw <GBps>                inter-node IB bandwidth (default 50)
  --ranks-per-node <N>             EP ranks per node (default: cluster = one node)
  --ingress-scale <F>              ingress/egress NIC bandwidth ratio (default 1.0)
  --predictor <oracle|learned|vidur|roofline>   (default oracle)
  --max-batch <N>                  per-iteration batch-size cap (default 256)
  --overhead <predicted|profiled|zero>  engine-overhead preset (default predicted)
  --requests <N>                   workload size (default 256)
  --input <N> --output <N>         token lengths (default 128/128)
  --rate <R>                       Poisson arrivals at R req/s (default: batch)
  --workload <SPEC>                named workload mix, sweepable as an axis:
                                   day[:RATE] (diurnal 4-class traffic day),
                                   chat[:RATE] | rag[:RATE] | agentic[:RATE] |
                                   batch[:RATE] single-class presets, or
                                   trace:<file> to replay a recorded trace
                                   (conflicts with --rate/--input/--output)
  --slo-ttft <MS> --slo-tbt <MS>   per-request SLO thresholds (milliseconds);
                                   judged at completion, reported as goodput
                                   and attainment
  --slo-e2e <S>                    end-to-end latency SLO (seconds)
  --trace <file.json>              replay a trace file instead of generating
                                   (simulate only; rejected by sweeps)
  --profiled                       use the real-system overhead preset
                                   (alias; conflicts with --overhead)
  --sim-threads <N>                engine threads for one run (default 1;
                                   report is bit-identical for any N)
  --faults <SPEC>                  fault-injection schedule, sweepable:
                                   mttf:MTTF[:mttr:MTTR] (seeded exponential
                                   per-replica failures, seconds),
                                   list:down@T:S[.R];up@T:S[.R];... or
                                   file:<sched.json> (explicit events; no .R
                                   targets the whole pool)
  --link-faults <SPEC>             link/fabric fault schedule, sweepable:
                                   mttf:MTTF[:mttr:MTTR][:frac:F] (seeded
                                   WAN-trunk outages, or brownouts to F of
                                   nominal bandwidth), list:EV;EV;... or
                                   file:<sched.json> with EV = down@T:TGT |
                                   degrade@T:TGT:FRAC[:ALPHA] | up@T:TGT and
                                   TGT = nvlink|ib|wan|trunk|C.N-C.N
  --autoscale <POLICY:MIN:MAX>     autoscale decode-capable pools between MIN
                                   and MAX replicas; POLICY is reactive or
                                   predictive (queue-trend extrapolation)
  --scale-signal <SIG>             autoscale signal: queue (depth per replica,
                                   default) or slo (windowed missed-SLO
                                   fraction; needs --slo-* thresholds)
  --scale-interval <S>             autoscaler control-loop period (default 10)
  --scale-delay <S>                replica provisioning delay (default 30)
  --scale-warmup <S>               new-replica first-iteration warmup stall
                                   (default 2)
  --scale-up <Q> --scale-down <Q>  scale thresholds in signal units: queue
                                   depth per replica (defaults 4 / 0.5) or
                                   missed-SLO fraction under --scale-signal
                                   slo (defaults 0.05 / 0.005)
  --seed <S>                       RNG seed (default 1)
  --json                           emit the report as JSON

OPTIONS (sweep):
  --axis <name=v1,v2,...>          sweep axis (repeatable; axes form a cartesian
                                   grid, first axis varies slowest). names:
                                   pd-ratio (values P:D, takes over the
                                   deployment shape), any value flag above
                                   (capacity-factor, ep-clusters,
                                   migration-threshold, seed, ...), or
                                   flag:<name> to bypass flag-name validation.
                                   comma-valued flags (stages, edges) cannot
                                   ride this grammar; sweep them via the API
  --point <k=v[,k2=v2...]>         explicit grid point (repeatable, instead of
                                   --axis; same key grammar as axis names)
  --threads <N>                    worker threads (default: all cores; the
                                   merged report is bit-identical for any N)
  --format <md|csv|json>           merged report format (default md; --json is
                                   shorthand for --format json)

OPTIONS (sweep-pd):
  --gpus <N>                       total GPUs split prefill:decode, sweeping
                                   P:D from 1:N-1 to N-1:1 (default 8)
  --threads <N>                    worker threads (default: all cores)
  --format <md|csv|json>           merged report format (default md)

OPTIONS (search):
  --axis / --point / --threads / --format    as for sweep
  --objective <cost|goodput|p99>   ranking objective (default cost): GPU-seconds
                                   per 1k tokens, SLO goodput, or TBT p99
  --rungs <N>                      successive-halving rungs, 1..=10 (default 3):
                                   rung r simulates at requests/4^(R-1-r)
                                   (floored at 4); only the final rung pays the
                                   full --requests horizon
  --promote-frac <F>               fraction of non-dominated survivors promoted
                                   per rung, in (0,1] (default 0.25; at least
                                   one point always advances)
  --manifest <DIR>                 persist per-point reports and an append-only
                                   manifest.jsonl incrementally, for resume
  --resume                         continue a killed run from --manifest DIR;
                                   the merged report is byte-identical to an
                                   uninterrupted run
  --max-sims <N>                   stop after N fresh simulations; with
                                   --manifest this is a resumable checkpoint
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Non-sweep subcommands must not silently ignore sweep-driver flags —
/// `frontier simulate --axis seed=1,2` runs ONE simulation, and the
/// user deserves an error, not a quietly un-swept report. (`--json` is
/// shared by every subcommand, and `--trace` is simulate's own flag, so
/// both stay allowed here.)
fn reject_sweep_flags(args: &Args) -> Result<()> {
    for k in DRIVER_FLAGS {
        if !matches!(*k, "json" | "trace") && args.flags.has(k) {
            let hint = if *k == "gpus" {
                "sweep-pd"
            } else if SEARCH_FLAGS.contains(k) {
                "search"
            } else {
                "sweep"
            };
            bail!("--{k} only applies to the sweep subcommands (did you mean `frontier {hint}`?)");
        }
    }
    Ok(())
}

/// The sweep drivers would otherwise *strip* the autotuner knobs (they
/// are [`DRIVER_FLAGS`]) — `frontier sweep --rungs 3` must error, not
/// quietly run the full grid.
fn reject_search_flags(args: &Args, cmd: &str) -> Result<()> {
    for k in SEARCH_FLAGS {
        if args.flags.has(k) {
            bail!("--{k} only applies to `frontier search` (not `frontier {cmd}`)");
        }
    }
    Ok(())
}

/// The base experiment configuration shared by all grid points: the
/// sweep command line minus every driver-level flag.
fn sweep_base_flags(args: &Args) -> Result<FlagMap> {
    if args.flags.has("trace") {
        // the sweep path builds synthetic workloads from flags; a trace
        // base flag would be silently ignored, not replayed
        bail!("--trace is not supported by sweeps (trace replay is simulate-only)");
    }
    let mut base = args.flags.clone();
    for k in DRIVER_FLAGS {
        base.remove(k);
    }
    Ok(base)
}

/// Merged-report output format of the sweep subcommands.
#[derive(Clone, Copy, PartialEq)]
enum SweepFormat {
    Md,
    Csv,
    Json,
}

/// Resolve and validate the output format *before* the grid runs, so a
/// `--format` typo fails in milliseconds instead of after the sweep.
fn sweep_format(args: &Args) -> Result<SweepFormat> {
    let format = match (args.flags.truthy("json"), args.flags.get("format")) {
        (true, Some(f)) if f != "json" => {
            bail!("--json and --format {f:?} are mutually exclusive")
        }
        (true, _) => "json",
        (false, f) => f.unwrap_or("md"),
    };
    match format {
        "md" | "markdown" => Ok(SweepFormat::Md),
        "csv" => Ok(SweepFormat::Csv),
        "json" => Ok(SweepFormat::Json),
        f => bail!("unknown sweep format {f:?} (md|csv|json)"),
    }
}

fn print_sweep(format: SweepFormat, result: &SweepResult) -> Result<()> {
    match format {
        SweepFormat::Md => print!("{}", sweep_markdown(result)),
        SweepFormat::Csv => print!("{}", sweep_csv(result)),
        SweepFormat::Json => println!("{}", sweep_json(result).to_string_pretty()),
    }
    // per-point errors are isolated in the report rows, but the process
    // must still signal them (CI smoke, scripts) — after printing
    let failed = result.points.iter().filter(|p| p.outcome.is_err()).count();
    if failed > 0 {
        bail!("{failed}/{} grid points failed (see the error rows above)", result.points.len());
    }
    Ok(())
}

fn run_sweep(args: &Args) -> Result<()> {
    if args.flags.has("gpus") {
        bail!("--gpus belongs to sweep-pd; use an explicit pd-ratio axis with `frontier sweep`");
    }
    reject_search_flags(args, "sweep")?;
    // the full driver set passes here: the driver flags sweep itself
    // does not read (--gpus above, --trace in sweep_base_flags) get
    // tailored rejections instead of the generic unknown-flag error
    reject_unknown_flags(&args.flags, DRIVER_FLAGS)?;
    let axes: Vec<Axis> =
        args.flags.get_all("axis").iter().map(|s| Axis::parse(s)).collect::<Result<_>>()?;
    let points: Vec<PointSpec> =
        args.flags.get_all("point").iter().map(|s| PointSpec::parse(s)).collect::<Result<_>>()?;
    let spec = match (axes.is_empty(), points.is_empty()) {
        (false, false) => bail!("--axis and --point are mutually exclusive"),
        (true, true) => bail!("sweep needs at least one --axis or --point"),
        (false, true) => SweepSpec::new(sweep_base_flags(args)?).with_axes(axes),
        (true, false) => SweepSpec::new(sweep_base_flags(args)?).with_points(points),
    };
    let format = sweep_format(args)?;
    let runner = SweepRunner::with_threads(args.flags.num("threads", 0usize)?);
    print_sweep(format, &runner.run(&spec)?)
}

fn run_sweep_pd(args: &Args) -> Result<()> {
    if args.flags.has("axis") || args.flags.has("point") {
        bail!("sweep-pd owns its pd-ratio grid; use `frontier sweep --axis ...` to compose axes");
    }
    reject_search_flags(args, "sweep-pd")?;
    reject_unknown_flags(&args.flags, DRIVER_FLAGS)?;
    let format = sweep_format(args)?;
    let total: u32 = args.flags.num("gpus", 8u32)?;
    if total < 2 {
        bail!("--gpus must be >= 2 to split prefill:decode");
    }
    let model = model_by_name(args.flags.get("model").unwrap_or(DEFAULT_MODEL))?;
    if format == SweepFormat::Md {
        // human header; kept out of the csv/json machine formats
        println!("PD ratio sweep over {total} GPUs ({})", model.name);
    }
    let ratios: Vec<String> = (1..total).map(|p| format!("{p}:{}", total - p)).collect();
    let spec =
        SweepSpec::new(sweep_base_flags(args)?).with_axes(vec![Axis::new("pd-ratio", ratios)?]);
    let runner = SweepRunner::with_threads(args.flags.num("threads", 0usize)?);
    print_sweep(format, &runner.run(&spec)?)
}

fn print_search(format: SweepFormat, result: &SearchResult) -> Result<()> {
    match format {
        SweepFormat::Md => print!("{}", search_markdown(result)),
        SweepFormat::Csv => print!("{}", search_csv(result)),
        SweepFormat::Json => println!("{}", search_json(result).to_string_pretty()),
    }
    // same contract as print_sweep: errors are isolated in the report
    // but the process still signals them
    if !result.errors.is_empty() {
        bail!(
            "{}/{} grid points failed (see the error rows above)",
            result.errors.len(),
            result.grid_points
        );
    }
    Ok(())
}

fn run_search(args: &Args) -> Result<()> {
    if args.flags.has("gpus") {
        bail!("--gpus belongs to sweep-pd; give search an explicit pd-ratio axis instead");
    }
    reject_unknown_flags(&args.flags, DRIVER_FLAGS)?;
    let axes: Vec<Axis> =
        args.flags.get_all("axis").iter().map(|s| Axis::parse(s)).collect::<Result<_>>()?;
    let points: Vec<PointSpec> =
        args.flags.get_all("point").iter().map(|s| PointSpec::parse(s)).collect::<Result<_>>()?;
    let sweep = match (axes.is_empty(), points.is_empty()) {
        (false, false) => bail!("--axis and --point are mutually exclusive"),
        (true, true) => bail!("search needs at least one --axis or --point"),
        (false, true) => SweepSpec::new(sweep_base_flags(args)?).with_axes(axes),
        (true, false) => SweepSpec::new(sweep_base_flags(args)?).with_points(points),
    };
    let spec = SearchSpec {
        sweep,
        objective: Objective::parse(args.flags.get("objective").unwrap_or("cost"))?,
        rungs: args.flags.num("rungs", 3u32)?,
        promote_frac: args.flags.num("promote-frac", 0.25f64)?,
    };
    let format = sweep_format(args)?;
    let runner = SearchRunner {
        threads: args.flags.num("threads", 0usize)?,
        manifest_dir: args.flags.get("manifest").map(std::path::PathBuf::from),
        resume: args.flags.truthy("resume"),
        max_sims: match args.flags.get("max-sims") {
            // 0usize default is never read: the flag is present
            Some(_) => Some(args.flags.num("max-sims", 0usize)?),
            None => None,
        },
        ..SearchRunner::default()
    };
    print_search(format, &runner.run(&spec)?)
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.cmd.as_str() {
        "simulate" => {
            reject_sweep_flags(&args)?;
            reject_unknown_flags(&args.flags, &["trace"])?;
            let cfg = build_config(&args.flags)?;
            let report = match args.flags.get("trace") {
                Some(path) => {
                    let trace =
                        frontier::workload::trace_from_file(std::path::Path::new(path))?;
                    frontier::coordinator::GlobalController::new(cfg)?.run_with_trace(trace)?
                }
                None => frontier::run_experiment(&cfg)?,
            };
            if args.flags.truthy("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                println!("{}", report.summary());
            }
        }
        "baseline" => {
            reject_sweep_flags(&args)?;
            reject_unknown_flags(&args.flags, &[])?;
            let cfg = build_config(&args.flags)?;
            let report = ReplicaCentricSim::new(cfg).simulate()?;
            if args.flags.truthy("json") {
                println!("{}", report.to_json().to_string_pretty());
            } else {
                println!("{}", report.summary());
            }
        }
        "sweep" => run_sweep(&args)?,
        "sweep-pd" => run_sweep_pd(&args)?,
        "search" => run_search(&args)?,
        "validate" => {
            if let Some(k) = args.flags.keys().next() {
                bail!("validate takes no flags (got --{k})");
            }
            let dir = frontier::runtime::PredictorRuntime::default_dir();
            println!("loading artifacts from {dir:?}");
            let rt = frontier::runtime::PredictorRuntime::load(&dir)?;
            println!(
                "attn predictor: batch={} features={} val_mape={:.4}",
                rt.attn.batch, rt.attn.n_features, rt.attn.val_mape
            );
            println!(
                "grouped_gemm predictor: batch={} features={} val_mape={:.4}",
                rt.grouped_gemm.batch, rt.grouped_gemm.n_features, rt.grouped_gemm.val_mape
            );
            println!(
                "gemm predictor: batch={} features={} val_mape={:.4}",
                rt.gemm.batch, rt.gemm.n_features, rt.gemm.val_mape
            );
            // golden check against python predictions
            let golden_path = dir.join("predictor_golden.json");
            let text = std::fs::read_to_string(&golden_path)?;
            let golden = frontier::config::json::Json::parse(&text)?;
            for (name, exe) in [
                ("attn", &rt.attn),
                ("grouped_gemm", &rt.grouped_gemm),
                ("gemm", &rt.gemm),
            ] {
                let g = golden.req(name)?;
                let feats: Vec<Vec<f64>> = g
                    .req("features")?
                    .as_arr()?
                    .iter()
                    .map(|r| r.as_f64_vec())
                    .collect::<Result<_>>()?;
                let want = g.req("pred_us")?.as_f64_vec()?;
                let got = exe.predict_us(&feats)?;
                for (a, b) in got.iter().zip(&want) {
                    let rel = (a - b).abs() / b.max(1e-9);
                    if rel > 1e-3 {
                        bail!("{name}: runtime {a} != python {b} (rel {rel:.2e})");
                    }
                }
                println!("{name}: {} golden predictions match python", want.len());
            }
            println!("artifacts OK");
        }
        "info" => {
            if let Some(k) = args.flags.keys().next() {
                bail!("info takes no flags (got --{k})");
            }
            println!("models: qwen2-7b qwen2-72b mixtral-8x7b deepseek-v3-lite tiny tiny-moe");
            println!("modes: colocated pd af (or --stages for arbitrary stage graphs)");
            println!("gpus: a800 a100 h100 h200");
            println!("predictors: oracle learned vidur roofline");
            println!(
                "stage DSL example: --stages \"prefill:2@h200,tp=2;af,attn=4,ffn=4,micro=2\""
            );
            println!(
                "sweep example: frontier sweep --model mixtral-8x7b --replicas 1 --ep 8 \
                 --axis capacity-factor=1.0,1.25,1.5 --axis ep-clusters=1,2"
            );
            for name in ["qwen2-7b", "mixtral-8x7b", "deepseek-v3-lite"] {
                let m = model_by_name(name)?;
                println!(
                    "  {name}: {} layers, d={}, {}B params, kv {} B/token{}",
                    m.n_layers,
                    m.d_model,
                    m.param_count() / 1_000_000_000,
                    m.kv_bytes_per_token(),
                    if m.is_moe() { " [MoE]" } else { "" }
                );
            }
        }
        _ => {
            print!("{USAGE}");
        }
    }
    Ok(())
}
