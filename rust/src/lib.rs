//! # Frontier — simulating the next generation of LLM inference systems
//!
//! A high-fidelity, event-driven simulator for disaggregated (prefill/decode
//! and attention/FFN) and Mixture-of-Experts LLM serving, reproducing
//! *"Frontier: Simulating the Next Generation of LLM Inference Systems"*.
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the simulator: [`coordinator::GlobalController`]
//!   orchestrating [`cluster::ClusterWorker`]s over the event engine in
//!   [`core`], with pluggable [`scheduler`] policies, a paged KV
//!   [`memory`] manager, and a [`network`] transfer model.
//! * **L2/L1 (python, build time)** — the learned operator-runtime
//!   predictors (JAX MLP over Pallas kernels), AOT-lowered to HLO text in
//!   `artifacts/` and executed from [`runtime`] via PJRT. Python never
//!   runs on the simulation path.

pub mod baseline;
pub mod bench_util;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod core;
pub mod hardware;
pub mod memory;
pub mod metrics;
pub mod model;
pub mod moe;
pub mod network;
pub mod operators;
pub mod oracle;
pub mod parallelism;
pub mod predictor;
pub mod proptest_util;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod search;
pub mod sweep;
pub mod workflows;
pub mod workload;

pub mod prelude {
    //! Everything a typical driver needs.
    pub use crate::config::{
        DeploymentMode, ExperimentConfig, OverheadConfig, PolicyConfig, StageConfig,
        StageGraphConfig,
    };
    pub use crate::coordinator::GlobalController;
    pub use crate::core::{SimTime, US};
    pub use crate::hardware::GpuSpec;
    pub use crate::metrics::SimReport;
    pub use crate::model::{ModelConfig, MoeConfig};
    pub use crate::parallelism::Parallelism;
    pub use crate::predictor::{ExecutionPredictor, PredictorKind};
    pub use crate::search::{Objective, SearchRunner, SearchSpec};
    pub use crate::sweep::{Axis, SweepRunner, SweepSpec};
    pub use crate::workload::WorkloadSpec;
}

use anyhow::Result;

/// Run a complete experiment from a config: build the deployment, drive the
/// workload through the [`coordinator::GlobalController`], and collect a
/// [`metrics::SimReport`].
pub fn run_experiment(cfg: &config::ExperimentConfig) -> Result<metrics::SimReport> {
    coordinator::run(cfg)
}
