//! The GlobalController: stateful orchestrator of the stage graph
//! (§3.1, generalized to heterogeneous multi-stage deployments).
//!
//! The controller executes a [`crate::config::StageGraphConfig`]: a
//! directed graph of stages (pools of replicas, each with its own GPU
//! model, parallelism plan, scheduler budget, and cost model) joined by
//! typed edges. Requests arrive at entry stages, walk kv edges on
//! prefill completion, and decode to completion in decode-capable
//! pools. The legacy modes are 1- and 2-stage instances of the same
//! machinery:
//!
//! * **Co-located** — one unified stage, continuous batching.
//! * **PD** — producer/consumer with system-level backpressure: the
//!   controller queues `PREFILL_COMPLETE` requests and initiates
//!   `KV_CACHE_TRANSFER` only when a downstream pool signals memory
//!   availability (§3.3 PD steps 1-3). With several decode pools (the
//!   fan-out deployment) the controller picks the pool with the most
//!   free memory.
//! * **AF** — a decode stage that is an attention/FFN pair whose step
//!   time comes from the event-dependency-graph executor
//!   ([`crate::workflows::af`]); its attn/ffn cost models are built
//!   once at construction, never per iteration.
//!
//! Stage-to-stage KV handoff rides the 3-tier hierarchical fabric
//! ([`crate::network::HierFabric`]): stages sharing a node exchange
//! over NVLink, stages on different nodes over IB, stages in different
//! clusters over the WAN trunk.
//!
//! With `--migration threshold` the controller also runs the **expert
//! migration control loop** (ROADMAP "expert migration" /
//! "load-aware replication"): every stage with an EP domain carries a
//! windowed online load estimator fed by its routing draws; between
//! iterations the controller re-plans the expert placement when the
//! tracked load diverges from the placement's assumption, charges the
//! weight moves through the EP fabric, and stalls the stage's replicas
//! for the transfer makespan ([`crate::moe::migration`]).

use std::cell::RefCell;
use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::cluster::{ClusterWorker, ReplicaWorker, StageKind};
use crate::config::{ExperimentConfig, StageGraphConfig};
use crate::core::{EventQueue, Pcg64, SimTime};
use crate::memory::{blocks_for_tokens, BlockManager};
use crate::metrics::{MetricsCollector, ReqTimestamps, SimReport, StageReport};
use crate::moe::{
    self, EpFabric, EpSpec, EpTopology, ExpertPlacement, LoadEstimator, MigrationPolicy,
};
use crate::network::{HierFabric, NetLoc};
use crate::predictor::{self, ExecutionPredictor};
use crate::scheduler::{self, IterBudget, QueuedReq};
use crate::workflows::af::{af_step, AfStep};
use crate::workflows::{BatchShape, CostCtx, CostModel};
use crate::workload::RequestSpec;

/// Request lifecycle states (§3.3's stateful workflow).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    Queued,
    Prefilling,
    PrefillComplete,
    Transferring,
    Decoding,
    Done,
    Rejected,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub spec: RequestSpec,
    pub state: ReqState,
    /// Prefill tokens completed so far (chunked prefill).
    pub prefill_progress: u32,
    /// Output tokens generated so far.
    pub decoded: u32,
    pub ts: ReqTimestamps,
    pub last_token: SimTime,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(u64),
    IterEnd { s: usize, r: usize },
    KvDone { rid: u64, s: usize, r: usize },
}

/// Prebuilt AF executor state: the attention- and FFN-pool cost models
/// are constructed once here — the per-iteration hot path only draws
/// routing and prices (no model clones, pinned by
/// [`crate::workflows::cost::COST_MODELS_BUILT`] in the tests).
struct AfRuntime {
    micro_batches: u32,
    attn_cost: CostModel,
    ffn_cost: CostModel,
}

/// One stage of the graph at runtime: the replica pool plus everything
/// needed to price its iterations.
struct StageRuntime {
    name: String,
    cw: ClusterWorker,
    /// Per-stage pricing (stage GPU, parallelism, EP placement).
    cost: CostModel,
    /// Per-stage operator-runtime predictor (stage GPU).
    pred: Box<dyn ExecutionPredictor>,
    budget: IterBudget,
    /// Total GPUs backing the stage (reports).
    gpus: u32,
    gpu_name: String,
    /// Coordinate in the hierarchical fabric.
    loc: NetLoc,
    af: Option<AfRuntime>,
    /// Estimator draw count at the last migration check (the control
    /// loop re-plans at most once per load window).
    mig_last_draws: u64,
}

impl StageRuntime {
    /// The cost model owning this stage's EP domain: the AF stage's
    /// FFN pool, else the stage-level model. Every migration-loop
    /// access (tracker attach, estimator read, placement rewrite) goes
    /// through this pair so they can never diverge.
    fn ep_cost(&self) -> &CostModel {
        match self.af.as_ref() {
            Some(afr) => &afr.ffn_cost,
            None => &self.cost,
        }
    }

    fn ep_cost_mut(&mut self) -> &mut CostModel {
        match self.af.as_mut() {
            Some(afr) => &mut afr.ffn_cost,
            None => &mut self.cost,
        }
    }
}

pub struct GlobalController {
    cfg: ExperimentConfig,
    graph: StageGraphConfig,
    queue: EventQueue<Ev>,
    reqs: Vec<Request>,
    stages: Vec<StageRuntime>,
    /// Entry stages (prefill-capable, no incoming kv edge).
    entry: Vec<usize>,
    /// Round-robin cursor for entry routing.
    entry_rr: usize,
    /// KV-handoff successors per stage (resolved adjacency).
    kv_out: Vec<Vec<usize>>,
    /// Contended 3-tier fabric for stage-to-stage KV handoff.
    fabric: HierFabric,
    rng: Pcg64,
    metrics: MetricsCollector,
    /// PREFILL_COMPLETE requests awaiting a KV transfer slot, with the
    /// stage that produced them.
    pending_transfers: VecDeque<(u64, usize)>,
    /// Iteration start times per (stage, replica) for busy accounting.
    iter_started: Vec<Vec<SimTime>>,
    /// Pending migration stall per (stage, replica), seconds: expert
    /// weight-transfer time charged to the replica's next iteration.
    pending_stall: Vec<Vec<f64>>,
    /// Arrival-routing scratch, reused across requests: open-loop runs
    /// see millions of arrivals and these used to be three fresh
    /// allocations each.
    scratch_slots: Vec<(usize, usize, u64)>,
    scratch_loads: Vec<usize>,
    scratch_free: Vec<u64>,
}

/// Convenience: build + run.
pub fn run(cfg: &ExperimentConfig) -> Result<SimReport> {
    GlobalController::new(cfg.clone())?.run()
}

impl GlobalController {
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let graph = cfg.stage_graph();
        let model = &cfg.model;
        // EP fabric: legacy flat intra+cross unless node granularity is
        // engaged (`ranks_per_node > 0`). The NIC ingress-asymmetry knob
        // applies smoothly in both modes — it must not flip the fabric
        // model, only scale ingress bandwidth.
        let ep_fabric = if cfg.ranks_per_node == 0 {
            EpFabric {
                ingress_scale: cfg.nic_ingress_scale,
                ..EpFabric::flat(cfg.link, cfg.cross_link)
            }
        } else {
            EpFabric::hierarchical(cfg.hier_spec(), cfg.ranks_per_node, cfg.nic_ingress_scale)
        };
        // EP placement over `ranks` expert ranks spanning `clusters`
        // clusters. The replicated-hot policy targets the experts a
        // deterministic warmup routing draw marks hottest — with the
        // stable skewed-popularity model this is the run's actual hot
        // set (see `moe::expert_popularity`).
        let make_ep = |ranks: u32, clusters: u32| -> Option<EpSpec> {
            let moe = model.moe.as_ref()?;
            if ranks <= 1 {
                return None;
            }
            let mut warmup = Pcg64::new(cfg.seed ^ 0x9E37_79B9);
            let hint = moe::assign_tokens(
                cfg.policy.moe_routing,
                4096,
                moe.n_experts,
                moe.top_k,
                &mut warmup,
            );
            Some(EpSpec {
                placement: ExpertPlacement::build(
                    cfg.policy.ep_placement,
                    moe.n_experts,
                    EpTopology::new(ranks, clusters),
                    Some(&hint),
                ),
                fabric: ep_fabric,
            })
        };
        let base_cost = |par: crate::parallelism::Parallelism| -> CostModel {
            let mut cost = CostModel::new(model.clone(), par, cfg.link);
            cost.moe_routing = cfg.policy.moe_routing;
            cost.routing_fidelity = cfg.policy.routing_fidelity;
            cost.straggler_max = cfg.policy.straggler_max;
            cost.overhead = cfg.overhead;
            cost.capacity_factor = cfg.policy.capacity_factor;
            cost
        };
        let mut stages = Vec::with_capacity(graph.stages.len());
        for st in &graph.stages {
            let gpu = st.gpu.clone().unwrap_or_else(|| cfg.gpu.clone());
            let par = st.parallel.unwrap_or(cfg.parallel);
            let budget = st.budget.unwrap_or(cfg.policy.budget);
            let ep_clusters = st.ep_clusters.unwrap_or(cfg.ep_clusters);
            let gpus_per_replica = par.gpus_per_replica();
            let (cw, gpus, af) = match st.af {
                Some(afp) => {
                    // KV lives on the attention side of the AF pair;
                    // roughly half the weights (attention stack) sit
                    // with it.
                    let af_mem = BlockManager::from_budget(
                        gpu.hbm_capacity * afp.attn_gpus as u64,
                        model.param_count() * model.dtype_bytes as u64 / 2,
                        model.kv_bytes_per_token(),
                        cfg.policy.kv_reserve_frac,
                    );
                    let group_gpus = afp.attn_gpus + afp.ffn_gpus;
                    let cw = ClusterWorker::new(st.kind, st.replicas, group_gpus, af_mem);
                    // attention pool: TP across its GPUs; FFN pool: EP
                    // for MoE (or TP for dense)
                    let attn_par = crate::parallelism::Parallelism::tp(
                        afp.attn_gpus.min(model.n_kv_heads).max(1),
                    );
                    let ffn_par = if model.is_moe() {
                        crate::parallelism::Parallelism::new(1, 1, afp.ffn_gpus.max(1))
                    } else {
                        crate::parallelism::Parallelism::tp(afp.ffn_gpus.max(1))
                    };
                    let mut attn_cost = base_cost(attn_par);
                    attn_cost.overhead = crate::config::OverheadConfig::zero();
                    let mut ffn_cost = base_cost(ffn_par);
                    ffn_cost.overhead = crate::config::OverheadConfig::zero();
                    // the FFN pool is the EP domain: a2f/f2a hops become
                    // the EP dispatch/combine phases
                    ffn_cost.ep = make_ep(afp.ffn_gpus, ep_clusters);
                    let af = AfRuntime {
                        micro_batches: afp.micro_batches,
                        attn_cost,
                        ffn_cost,
                    };
                    (cw, st.replicas * group_gpus, Some(af))
                }
                None => {
                    let mem = BlockManager::from_budget(
                        gpu.hbm_capacity * gpus_per_replica as u64,
                        model.weight_bytes_per_gpu(par.tp, par.ep) * gpus_per_replica as u64,
                        model.kv_bytes_per_token(),
                        cfg.policy.kv_reserve_frac,
                    );
                    let cw = ClusterWorker::new(st.kind, st.replicas, gpus_per_replica, mem);
                    (cw, st.replicas * gpus_per_replica, None)
                }
            };
            let mut cost = base_cost(par);
            // replica-level EP ranks (co-located / PD stages)
            if af.is_none() {
                cost.ep = make_ep(par.ep, ep_clusters);
            }
            let pred = predictor::build_for(
                cfg.predictor,
                gpu.clone(),
                cfg.link,
                cfg.artifacts_dir.as_deref(),
            )?;
            stages.push(StageRuntime {
                name: st.name.clone(),
                cw,
                cost,
                pred,
                budget,
                gpus,
                gpu_name: gpu.name.to_string(),
                loc: NetLoc::new(st.cluster, st.node),
                af,
                mig_last_draws: 0,
            });
            // expert-migration control loop: attach the online load
            // estimator to the cost model owning the stage's EP domain.
            // Static runs carry no tracker at all, keeping them
            // bit-identical to the pre-migration simulator.
            if cfg.policy.migration == MigrationPolicy::Threshold {
                if let Some(moe) = model.moe.as_ref() {
                    let tracked = stages.last_mut().expect("just pushed").ep_cost_mut();
                    if tracked.ep.is_some() {
                        tracked.load_tracker = Some(RefCell::new(LoadEstimator::new(
                            moe.n_experts,
                            cfg.policy.load_window,
                        )));
                    }
                }
            }
        }
        let entry = graph.entry_stages();
        let kv_out: Vec<Vec<usize>> = (0..graph.stages.len()).map(|s| graph.kv_out(s)).collect();
        let iter_started: Vec<Vec<SimTime>> = stages
            .iter()
            .map(|st| vec![SimTime::ZERO; st.cw.replicas.len()])
            .collect();
        let pending_stall = stages.iter().map(|st| vec![0.0f64; st.cw.replicas.len()]).collect();
        let mut metrics = MetricsCollector::default();
        metrics.slo = cfg.slo;
        metrics.class_names = cfg.workload.class_names();
        if cfg.keep_raw_samples {
            metrics.raw = Some(Box::default());
        }
        Ok(GlobalController {
            graph,
            queue: EventQueue::new(),
            reqs: Vec::new(),
            stages,
            entry,
            entry_rr: 0,
            kv_out,
            fabric: HierFabric::new(cfg.hier_spec()),
            rng: Pcg64::new(cfg.seed),
            metrics,
            pending_transfers: VecDeque::new(),
            iter_started,
            pending_stall,
            scratch_slots: Vec::new(),
            scratch_loads: Vec::new(),
            scratch_free: Vec::new(),
            cfg,
        })
    }

    /// Execute the configured workload to completion (loading and
    /// validating the trace file first when the workload replays one).
    pub fn run(self) -> Result<SimReport> {
        let trace = self.cfg.workload.materialize()?;
        self.run_with_trace(trace)
    }

    /// Execute an explicit request trace (trace replay) to completion.
    pub fn run_with_trace(mut self, trace: Vec<RequestSpec>) -> Result<SimReport> {
        let host_start = std::time::Instant::now();
        for spec in trace {
            let rid = self.reqs.len() as u64;
            self.reqs.push(Request {
                ts: ReqTimestamps { arrival: spec.arrival, ..Default::default() },
                spec,
                state: ReqState::Queued,
                prefill_progress: 0,
                decoded: 0,
                last_token: SimTime::ZERO,
            });
            self.queue.schedule_at(self.reqs[rid as usize].spec.arrival, Ev::Arrival(rid));
        }
        while let Some(ev) = self.queue.pop() {
            match ev.kind {
                Ev::Arrival(rid) => self.on_arrival(rid),
                Ev::IterEnd { s, r } => self.on_iter_end(s, r),
                Ev::KvDone { rid, s, r } => self.on_kv_done(rid, s, r),
            }
        }
        let unfinished = self
            .reqs
            .iter()
            .filter(|r| !matches!(r.state, ReqState::Done | ReqState::Rejected))
            .count();
        if unfinished > 0 {
            bail!("simulation stalled with {unfinished} unfinished requests");
        }
        self.metrics.predictor_evals = self.stages.iter().map(|st| st.pred.evals()).sum();
        let horizon = self.queue.now();
        let stage_reports: Vec<StageReport> = self
            .stages
            .iter()
            .map(|st| StageReport {
                name: st.name.clone(),
                kind: st.cw.kind.name().to_string(),
                replicas: st.cw.replicas.len() as u32,
                gpus: st.gpus,
                gpu_name: st.gpu_name.clone(),
                iterations: st.cw.replicas.iter().map(|r| r.iterations).sum(),
                tokens: st.cw.replicas.iter().map(|r| r.tokens_processed).sum(),
                busy_frac: st.cw.busy_fraction(horizon),
                peak_mem_frac: st.cw.peak_mem_frac(),
            })
            .collect();
        // sum over the already-resolved runtime stages (cfg.n_gpus()
        // would re-lower and re-clone the whole graph)
        let n_gpus = self.stages.iter().map(|st| st.gpus).sum();
        Ok(SimReport {
            mode: self.cfg.mode_name().to_string(),
            predictor: self.stages[0].pred.name().to_string(),
            sim_duration: self.queue.now().as_secs_f64(),
            host_duration: host_start.elapsed().as_secs_f64(),
            events_processed: self.queue.processed(),
            n_gpus,
            metrics: self.metrics,
            stages: stage_reports,
        })
    }

    // -- event handlers ----------------------------------------------------

    /// Whether a request needing `full_blocks` for its lifetime could
    /// ever be handed downstream from entry stage `s` (admission
    /// control: a request that fits nowhere downstream would deadlock
    /// the PREFILL_COMPLETE queue).
    fn fits_downstream(&self, s: usize, full_blocks: u64) -> bool {
        let dsts = &self.kv_out[s];
        dsts.is_empty()
            || dsts.iter().any(|&d| {
                self.stages[d]
                    .cw
                    .replicas
                    .iter()
                    .any(|rep| full_blocks <= rep.mem.total_blocks())
            })
    }

    fn on_arrival(&mut self, rid: u64) {
        self.metrics.record_arrival(self.queue.now().as_secs_f64());
        let (input_len, output_len) = {
            let rq = &self.reqs[rid as usize];
            (rq.spec.input_len, rq.spec.output_len)
        };
        let full_blocks = blocks_for_tokens(input_len + output_len);
        // collect admissible (stage, replica) slots across entry stages
        // into reused scratch vectors (this path runs per arrival)
        let mut slots = std::mem::take(&mut self.scratch_slots);
        let mut loads = std::mem::take(&mut self.scratch_loads);
        let mut free = std::mem::take(&mut self.scratch_free);
        slots.clear();
        loads.clear();
        free.clear();
        for &s in &self.entry {
            let blocks_needed = match self.stages[s].cw.kind {
                // co-located replicas hold KV for the whole lifetime
                StageKind::Unified => full_blocks,
                // prefill stage holds KV only until handoff
                _ => blocks_for_tokens(input_len),
            };
            let fits_frontend = self.stages[s]
                .cw
                .replicas
                .iter()
                .any(|rep| blocks_needed <= rep.mem.total_blocks());
            let fits_down = output_len <= 1 || self.fits_downstream(s, full_blocks);
            if !fits_frontend || !fits_down {
                continue;
            }
            for (r, rep) in self.stages[s].cw.replicas.iter().enumerate() {
                slots.push((s, r, blocks_needed));
                loads.push(rep.load());
                free.push(rep.mem.free_blocks());
            }
        }
        let choice = if slots.is_empty() {
            None
        } else {
            let mut rr = self.entry_rr;
            let i = scheduler::route(self.cfg.policy.route, &loads, &free, &mut rr);
            self.entry_rr = rr;
            Some(slots[i])
        };
        self.scratch_slots = slots;
        self.scratch_loads = loads;
        self.scratch_free = free;
        let Some((s, r, blocks_needed)) = choice else {
            self.reqs[rid as usize].state = ReqState::Rejected;
            self.metrics.rejected_requests += 1;
            return;
        };
        let q = QueuedReq {
            id: rid,
            tokens_needed: input_len,
            blocks_needed,
            arrival: self.queue.now(),
        };
        self.stages[s].cw.replicas[r].waiting.push_back(q);
        self.try_start_iteration(s, r);
    }

    fn on_iter_end(&mut self, s: usize, r: usize) {
        let now = self.queue.now();
        let kind = self.stages[s].cw.kind;
        {
            let started = self.iter_started[s][r];
            let repl = &mut self.stages[s].cw.replicas[r];
            repl.busy = false;
            repl.iterations += 1;
            repl.busy_ns += (now - started).0;
        }
        self.metrics.iterations += 1;

        // take the batch vectors instead of cloning them: this handler
        // runs once per iteration, and a 1e6-request day runs tens of
        // millions of iterations
        let running: Vec<u64> = std::mem::take(&mut self.stages[s].cw.replicas[r].running);
        let chunks: Vec<u32> = std::mem::take(&mut self.stages[s].cw.replicas[r].iter_chunks);
        let mut finished: Vec<u64> = Vec::new();
        let mut to_transfer: Vec<u64> = Vec::new();

        for (i, &rid) in running.iter().enumerate() {
            let chunk = chunks.get(i).copied().unwrap_or(0);
            let (input_len, output_len) = {
                let rq = &self.reqs[rid as usize];
                (rq.spec.input_len, rq.spec.output_len)
            };
            if chunk > 0 {
                // prefill progress
                let rq = &mut self.reqs[rid as usize];
                rq.prefill_progress += chunk;
                self.metrics.prefill_tokens += chunk as u64;
                self.stages[s].cw.replicas[r].tokens_processed += chunk as u64;
                if rq.prefill_progress >= input_len {
                    // prefill iteration emits the first output token
                    rq.ts.prefill_done = Some(now);
                    rq.ts.first_token = Some(now);
                    rq.last_token = now;
                    rq.decoded = 1;
                    self.metrics.output_tokens += 1;
                    let class = rq.spec.class;
                    let ttft = (now - rq.ts.arrival).as_secs_f64();
                    self.metrics.record_ttft(class, ttft, now.as_secs_f64());
                    let rq = &mut self.reqs[rid as usize];
                    if rq.decoded >= output_len {
                        finished.push(rid);
                    } else if kind == StageKind::Prefill {
                        rq.state = ReqState::PrefillComplete;
                        to_transfer.push(rid);
                    } else {
                        rq.state = ReqState::Decoding;
                    }
                }
            } else {
                // decode step: one token
                let rq = &mut self.reqs[rid as usize];
                rq.decoded += 1;
                self.metrics.output_tokens += 1;
                let class = rq.spec.class;
                let tbt = (now - rq.last_token).as_secs_f64();
                self.metrics.record_tbt(class, tbt, now.as_secs_f64());
                let rq = &mut self.reqs[rid as usize];
                rq.last_token = now;
                self.stages[s].cw.replicas[r].tokens_processed += 1;
                if rq.decoded >= output_len {
                    finished.push(rid);
                }
            }
        }

        // retire finished requests
        if !finished.is_empty() {
            for &rid in &finished {
                let rq = &mut self.reqs[rid as usize];
                rq.state = ReqState::Done;
                rq.ts.done = Some(now);
                let e2e = (now - rq.ts.arrival).as_secs_f64();
                let ttft = rq.ts.first_token.map_or(e2e, |ft| (ft - rq.ts.arrival).as_secs_f64());
                // mean inter-token gap over the request (SLO judgment)
                let tbt_mean = match (rq.ts.first_token, rq.decoded) {
                    (Some(ft), d) if d > 1 => (now - ft).as_secs_f64() / (d - 1) as f64,
                    _ => 0.0,
                };
                let (class, output_len) = (rq.spec.class, rq.spec.output_len);
                self.metrics.record_completion(
                    class,
                    ttft,
                    tbt_mean,
                    e2e,
                    output_len,
                    now.as_secs_f64(),
                );
                self.stages[s].cw.replicas[r].mem.free_request(rid);
            }
        }
        // hand prefill-complete requests to the controller's transfer queue
        for &rid in &to_transfer {
            self.stages[s].cw.replicas[r].mem.free_request(rid);
            self.pending_transfers.push_back((rid, s));
        }
        // give the batch vector back (minus retired ids), reusing its
        // allocation for the next iteration
        {
            let repl = &mut self.stages[s].cw.replicas[r];
            debug_assert!(repl.running.is_empty());
            repl.running = running;
            if !finished.is_empty() || !to_transfer.is_empty() {
                repl.running
                    .retain(|rid| !finished.contains(rid) && !to_transfer.contains(rid));
            }
        }
        if !to_transfer.is_empty() || !finished.is_empty() {
            // memory availability changed: the downstream ClusterScheduler
            // signals the controller (PD backpressure step 2/3)
            self.try_dispatch_transfers();
        }
        // between iterations: the expert-migration control loop may
        // re-place experts (and stall this stage) before the next batch
        self.maybe_migrate(s);
        self.try_start_iteration(s, r);
    }

    /// Expert-migration control loop, run between iterations of stage
    /// `s`: once per load window, compare the tracked per-expert loads
    /// against the current placement; when the predicted rank imbalance
    /// clears the threshold, adopt the rebalanced placement, charge the
    /// expert weight moves through the EP fabric, and stall every
    /// replica of the stage for the transfer makespan.
    fn maybe_migrate(&mut self, s: usize) {
        if self.cfg.policy.migration != MigrationPolicy::Threshold {
            return;
        }
        let window = self.cfg.policy.load_window.max(1) as u64;
        let threshold = self.cfg.policy.migration_threshold;
        let placement_policy = self.cfg.policy.ep_placement;
        let last = self.stages[s].mig_last_draws;
        // read phase: estimator snapshot + weight footprint. The one
        // placement stands for every resident layer's FFN, so a move
        // copies the expert's weights for ALL of the stage's layers.
        let (draws, est, expert_bytes) = {
            let cost = self.stages[s].ep_cost();
            let Some(tracker) = cost.load_tracker.as_ref() else { return };
            let tracker = tracker.borrow();
            if tracker.draws() < last + window {
                return;
            }
            let layers = (cost.model.n_layers / cost.par.pp.max(1)).max(1) as f64;
            let per_expert = cost.model.expert_weight_bytes(cost.par.tp) * layers;
            (tracker.draws(), tracker.snapshot(), per_expert)
        };
        self.stages[s].mig_last_draws = draws;
        // plan + adopt phase
        let (phase, pre, post) = {
            let cost = self.stages[s].ep_cost_mut();
            let Some(eps) = cost.ep.as_mut() else { return };
            let plan = moe::plan_migration(&eps.placement, placement_policy, &est, threshold);
            let Some(plan) = plan else { return };
            let phase = moe::charge_migration(eps, &plan, expert_bytes);
            let moe::MigrationPlan { placement, pre_imbalance, post_imbalance, .. } = plan;
            eps.placement = placement;
            (phase, pre_imbalance, post_imbalance)
        };
        // every replica of the pool holds its own copy of the expert
        // weights, so a placement rewrite moves the plan's bytes once
        // per replica (replicas copy in parallel — each pays the same
        // makespan, which is why the stall below is also per replica)
        let replicas = self.stages[s].cw.replicas.len() as f64;
        self.metrics.record_migration(
            phase.total_bytes * replicas,
            phase.cross_bytes * replicas,
            pre,
            post,
        );
        for stall in &mut self.pending_stall[s] {
            *stall += phase.secs;
        }
    }

    fn on_kv_done(&mut self, rid: u64, s: usize, r: usize) {
        let rq = &mut self.reqs[rid as usize];
        rq.state = ReqState::Decoding;
        let q = QueuedReq {
            id: rid,
            tokens_needed: 0,
            blocks_needed: 0, // reserved at dispatch time
            arrival: self.queue.now(),
        };
        self.stages[s].cw.replicas[r].waiting.push_back(q);
        self.try_start_iteration(s, r);
    }

    // -- coordination ------------------------------------------------------

    /// PD backpressure: initiate KV transfers only into replicas with
    /// free memory, FIFO over the PREFILL_COMPLETE queue. With several
    /// downstream pools (fan-out) the pool with the most free memory
    /// wins. FIFO is enforced *per destination set*: a held request
    /// blocks later requests that could route to any of its candidate
    /// pools (no overtaking within a pipeline), but requests bound for
    /// disjoint pools — independent prefill->decode pipelines in the
    /// same graph — dispatch freely past it.
    fn try_dispatch_transfers(&mut self) {
        let now = self.queue.now();
        let mut held: VecDeque<(u64, usize)> = VecDeque::new();
        // destinations an earlier held request may still claim
        let mut blocked: Vec<bool> = vec![false; self.stages.len()];
        while let Some((rid, src)) = self.pending_transfers.pop_front() {
            let (input_len, output_len) = {
                let rq = &self.reqs[rid as usize];
                (rq.spec.input_len, rq.spec.output_len)
            };
            let blocks = blocks_for_tokens(input_len + output_len);
            let dsts = self.kv_out[src].clone();
            // defensive: a request no replica could EVER hold must not
            // clog the queue (admission control should prevent this)
            if dsts.iter().all(|&d| {
                self.stages[d]
                    .cw
                    .replicas
                    .iter()
                    .all(|rep| blocks > rep.mem.total_blocks())
            }) {
                self.reqs[rid as usize].state = ReqState::Rejected;
                self.metrics.rejected_requests += 1;
                continue;
            }
            let hold = |blocked: &mut Vec<bool>, held: &mut VecDeque<(u64, usize)>| {
                for &d in &dsts {
                    blocked[d] = true;
                }
                held.push_back((rid, src));
            };
            // FIFO per pipeline: an earlier held request owns these pools
            if dsts.iter().any(|&d| blocked[d]) {
                hold(&mut blocked, &mut held);
                continue;
            }
            // choose the (stage, replica) with the most free memory that fits
            let mut best: Option<(usize, usize, u64)> = None;
            for &d in &dsts {
                for (r, rep) in self.stages[d].cw.replicas.iter().enumerate() {
                    let fr = rep.mem.free_blocks();
                    let better = match best {
                        None => true,
                        Some((_, _, b)) => fr > b,
                    };
                    if fr >= blocks && better {
                        best = Some((d, r, fr));
                    }
                }
            }
            let Some((d, r, _)) = best else {
                // backpressure: no consumer memory in this pipeline
                hold(&mut blocked, &mut held);
                continue;
            };
            self.stages[d].cw.replicas[r]
                .mem
                .allocate(rid, blocks)
                .expect("reserved blocks must fit");
            let bytes =
                input_len as f64 * self.stages[src].cost.model.kv_bytes_per_token() as f64;
            // the handoff rides the hierarchical fabric between the two
            // stages' coordinates (NVLink / IB / WAN by placement)
            let (src_loc, dst_loc) = (self.stages[src].loc, self.stages[d].loc);
            let delivery = self.fabric.transfer(now, src_loc, dst_loc, bytes);
            self.metrics.kv_transfers += 1;
            self.metrics.kv_bytes += bytes;
            self.reqs[rid as usize].state = ReqState::Transferring;
            self.queue.schedule_at(delivery, Ev::KvDone { rid, s: d, r });
        }
        self.pending_transfers = held;
    }

    /// Form and launch the next iteration on a replica if it is idle and
    /// has work.
    fn try_start_iteration(&mut self, s: usize, r: usize) {
        let kind = self.stages[s].cw.kind;
        let budget = self.stages[s].budget;
        let policy = self.cfg.policy.batch;
        {
            let repl = &mut self.stages[s].cw.replicas[r];
            if repl.busy || !repl.has_work() {
                return;
            }
            // admissions (reserving memory)
            let free = repl.mem.free_blocks();
            let admitted =
                scheduler::admit(policy, &mut repl.waiting, repl.running.len(), &budget, free);
            for q in &admitted {
                if q.blocks_needed > 0 {
                    repl.mem.allocate(q.id, q.blocks_needed).expect("admit checked memory");
                }
                repl.running.push(q.id);
            }
            for q in &admitted {
                let rq = &mut self.reqs[q.id as usize];
                if rq.state == ReqState::Queued {
                    rq.state = ReqState::Prefilling;
                }
            }
        }
        // build the batch shape (reading the running set in place — the
        // pre-digest code cloned it every iteration)
        if self.stages[s].cw.replicas[r].running.is_empty() {
            return;
        }
        let mut shape = BatchShape::default();
        let mut chunks = std::mem::take(&mut self.stages[s].cw.replicas[r].iter_chunks);
        chunks.clear();
        let mut token_budget = budget.max_prefill_tokens;
        for &rid in &self.stages[s].cw.replicas[r].running {
            let rq = &self.reqs[rid as usize];
            if rq.prefill_progress < rq.spec.input_len {
                let remaining = rq.spec.input_len - rq.prefill_progress;
                let chunk = remaining.min(token_budget);
                if chunk > 0 {
                    shape.prefill.push((chunk, rq.prefill_progress));
                    token_budget -= chunk;
                    if rq.prefill_progress + chunk >= rq.spec.input_len {
                        shape.lm_head_rows += 1; // emits first token
                    }
                }
                chunks.push(chunk);
            } else {
                shape.decode_ctx.push(rq.spec.input_len + rq.decoded);
                shape.lm_head_rows += 1;
                chunks.push(0);
            }
        }
        if shape.is_empty() {
            return;
        }
        let dt = if kind == StageKind::AfDecode {
            self.af_iteration_time(s, &shape)
        } else {
            let st = &mut self.stages[s];
            let mut ctx = CostCtx {
                pred: st.pred.as_mut(),
                rng: &mut self.rng,
                metrics: Some(&mut self.metrics),
            };
            st.cost.iteration_time(&mut ctx, &shape)
        };
        debug_assert!(dt > 0.0);
        // pending expert-migration stall: the replica's EP ranks were
        // busy receiving weights, so its next iteration starts late.
        // Metered here — at the moment the delay is actually paid — so
        // a migration adopted after the final iteration reports none.
        let stall = std::mem::take(&mut self.pending_stall[s][r]);
        self.metrics.migration_stall_s += stall;
        let repl = &mut self.stages[s].cw.replicas[r];
        repl.busy = true;
        repl.iter_chunks = chunks;
        self.iter_started[s][r] = self.queue.now();
        self.queue.schedule_in(SimTime::from_secs_f64(dt + stall), Ev::IterEnd { s, r });
    }

    /// AF decode step: partition the batch into micro-batches and run
    /// the dependency-graph executor. On the MoE path every
    /// `(layer, micro)` cell is data-dependent: a fresh routing draw
    /// sets the per-rank expert loads (stragglers) *and* the
    /// dispatch/combine transfer times through the EP fabric. The
    /// attn/ffn cost models were built once at controller construction.
    fn af_iteration_time(&mut self, s: usize, shape: &BatchShape) -> f64 {
        let st = &mut self.stages[s];
        let afr = st.af.as_ref().expect("af runtime on AF stage");
        let m = (afr.micro_batches as usize).max(1).min(shape.decode_ctx.len().max(1));
        let attn_cost = &afr.attn_cost;
        let ffn_cost = &afr.ffn_cost;
        let model = &attn_cost.model;
        let ep_active = ffn_cost.ep.is_some();

        // round-robin partition of decode sequences
        let mut micro_ctx: Vec<Vec<u32>> = vec![Vec::new(); m];
        for (i, &ctx) in shape.decode_ctx.iter().enumerate() {
            micro_ctx[i % m].push(ctx);
        }
        // prefill chunks (if the AF pool also prefills) ride micro 0
        let micro0_prefill = shape.prefill.clone();

        let layers = model.n_layers as usize;
        let d_bytes = model.d_model as f64 * model.dtype_bytes as f64;
        let mut attn_time = vec![vec![0.0f64; m]; layers];
        let mut ffn_time = vec![vec![0.0f64; m]; layers];
        let mut a2f_time = vec![vec![0.0f64; m]; layers];
        let mut f2a_time = vec![vec![0.0f64; m]; layers];
        for (k, ctxs) in micro_ctx.iter().enumerate() {
            let micro_shape = BatchShape {
                prefill: if k == 0 { micro0_prefill.clone() } else { vec![] },
                decode_ctx: ctxs.clone(),
                lm_head_rows: 0,
            };
            let micro_tokens = micro_shape.total_tokens() as u64;
            if micro_shape.is_empty() {
                continue;
            }
            let t_attn = {
                let mut ctx = CostCtx {
                    pred: st.pred.as_mut(),
                    rng: &mut self.rng,
                    metrics: Some(&mut self.metrics),
                };
                attn_cost.attn_block_time(&mut ctx, &micro_shape)
            };
            // dense fallback: point-to-point hop sized by this micro-batch
            let xfer = crate::oracle::p2p_time(micro_tokens as f64 * d_bytes, &attn_cost.link);
            for l in 0..layers {
                attn_time[l][k] = t_attn;
                let mut ctx = CostCtx {
                    pred: st.pred.as_mut(),
                    rng: &mut self.rng,
                    metrics: Some(&mut self.metrics),
                };
                if ep_active {
                    // fresh routing per layer: data-dependent stragglers
                    // and skew-dependent dispatch/combine
                    let sample = ffn_cost
                        .moe_ffn_ep(&mut ctx, micro_tokens)
                        .expect("ep spec attached and micro-batch non-empty");
                    ffn_time[l][k] = sample.ffn_secs;
                    a2f_time[l][k] = sample.dispatch_secs;
                    f2a_time[l][k] = sample.combine_secs;
                } else {
                    // fresh routing per layer: data-dependent straggler noise
                    ffn_time[l][k] = ffn_cost.ffn_block_time(&mut ctx, micro_tokens);
                    a2f_time[l][k] = xfer;
                    f2a_time[l][k] = xfer;
                }
            }
        }
        let step = AfStep { attn_time, ffn_time, a2f_time, f2a_time };
        let (t_graph, busy) = af_step(&step);
        if ep_active {
            // FFN-pool idle time inside the step: dispatch bubbles the
            // ping-pong pipeline failed to hide
            self.metrics.dispatch_bubble_s += (t_graph - busy[1]).max(0.0);
        }
        let lm_head = {
            let mut ctx = CostCtx {
                pred: st.pred.as_mut(),
                rng: &mut self.rng,
                metrics: Some(&mut self.metrics),
            };
            attn_cost.lm_head_time(&mut ctx, shape.lm_head_rows as u64)
        };
        let o = &st.cost.overhead;
        o.sched_overhead_s + layers as f64 * o.launch_gap_s + o.op_scale * (t_graph + lm_head)
    }

    // -- accessors for tests/tools ------------------------------------------

    /// The resolved stage graph this controller executes.
    pub fn stage_graph(&self) -> &StageGraphConfig {
        &self.graph
    }

    pub fn n_stages(&self) -> usize {
        self.stages.len()
    }

    /// The replica pool of stage `s`.
    pub fn stage(&self, s: usize) -> &ClusterWorker {
        &self.stages[s].cw
    }

    pub fn pending_transfer_count(&self) -> usize {
        self.pending_transfers.len()
    }

    pub fn replica(&self, s: usize, r: usize) -> &ReplicaWorker {
        &self.stages[s].cw.replicas[r]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::predictor::PredictorKind;
    use crate::workload::WorkloadSpec;

    fn tiny_cfg(mode_requests: u32) -> ExperimentConfig {
        ExperimentConfig::colocated(ModelConfig::tiny(), 2)
            .with_workload(WorkloadSpec::table2(mode_requests, 64, 16))
            .with_predictor(PredictorKind::Oracle)
    }

    #[test]
    fn colocated_completes_all_requests() {
        let report = run(&tiny_cfg(32)).unwrap();
        assert_eq!(report.metrics.completed_requests, 32);
        assert_eq!(report.metrics.rejected_requests, 0);
        assert_eq!(report.metrics.output_tokens, 32 * 16);
        assert!(report.sim_duration > 0.0);
        assert!(report.metrics.ttft.count() == 32);
        // the 1-stage graph reports itself
        assert_eq!(report.stages.len(), 1);
        assert_eq!(report.stages[0].kind, "unified");
        assert!(report.stages[0].iterations > 0);
    }

    #[test]
    fn pd_completes_all_requests_with_transfers() {
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
            .with_workload(WorkloadSpec::table2(24, 64, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 24);
        // every multi-token request crosses the PD boundary once
        assert_eq!(report.metrics.kv_transfers, 24);
        assert!(report.metrics.kv_bytes > 0.0);
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.stages[0].kind, "prefill");
        assert_eq!(report.stages[1].kind, "decode");
    }

    #[test]
    fn af_mode_runs() {
        let cfg = ExperimentConfig::af(ModelConfig::tiny(), 1, 2, 2, 2)
            .with_workload(WorkloadSpec::table2(8, 32, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
    }

    #[test]
    fn deterministic_under_seed() {
        let a = run(&tiny_cfg(16)).unwrap();
        let b = run(&tiny_cfg(16)).unwrap();
        assert_eq!(a.sim_duration, b.sim_duration);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.metrics.output_tokens, b.metrics.output_tokens);
    }

    #[test]
    fn single_token_outputs_skip_transfer() {
        let mut w = WorkloadSpec::table2(8, 64, 1);
        w.output = crate::workload::LenDist::Fixed(1);
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1).with_workload(w);
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
        assert_eq!(report.metrics.kv_transfers, 0); // done at prefill
    }

    #[test]
    fn oversized_request_rejected() {
        let mut w = WorkloadSpec::table2(4, 64, 8);
        w.input = crate::workload::LenDist::Fixed(100_000_000);
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 1).with_workload(w);
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.rejected_requests, 4);
        assert_eq!(report.metrics.completed_requests, 0);
    }

    #[test]
    fn ttft_precedes_e2e() {
        let report = run(&tiny_cfg(16)).unwrap();
        assert!(report.metrics.ttft.mean() < report.metrics.e2e.mean());
    }

    #[test]
    fn moe_model_runs_colocated() {
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
            .with_parallelism(crate::parallelism::Parallelism::new(1, 1, 2))
            .with_workload(WorkloadSpec::table2(8, 32, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
        assert!(report.metrics.op_time.contains_key("grouped_gemm"));
    }

    #[test]
    fn controller_exposes_stage_pools() {
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 2, 1)
            .with_workload(WorkloadSpec::table2(4, 32, 4));
        let gc = GlobalController::new(cfg).unwrap();
        assert_eq!(gc.n_stages(), 2);
        assert_eq!(gc.stage(0).kind, StageKind::Prefill);
        assert_eq!(gc.stage(0).replicas.len(), 2);
        assert_eq!(gc.stage(1).kind, StageKind::Decode);
        assert_eq!(gc.pending_transfer_count(), 0);
        assert!(!gc.replica(1, 0).busy);
        assert_eq!(gc.stage_graph().kv_out(0), vec![1]);
    }
}
