//! The GlobalController: stateful orchestrator of the stage graph
//! (§3.1, generalized to heterogeneous multi-stage deployments).
//!
//! The controller executes a [`crate::config::StageGraphConfig`]: a
//! directed graph of stages (pools of replicas, each with its own GPU
//! model, parallelism plan, scheduler budget, and cost model) joined by
//! typed edges. Requests arrive at entry stages, walk kv edges on
//! prefill completion, and decode to completion in decode-capable
//! pools. The legacy modes are 1- and 2-stage instances of the same
//! machinery:
//!
//! * **Co-located** — one unified stage, continuous batching.
//! * **PD** — producer/consumer with system-level backpressure: the
//!   controller queues `PREFILL_COMPLETE` requests and initiates
//!   `KV_CACHE_TRANSFER` only when a downstream pool signals memory
//!   availability (§3.3 PD steps 1-3). With several decode pools (the
//!   fan-out deployment) the controller picks the pool with the most
//!   free memory.
//! * **AF** — a decode stage that is an attention/FFN pair whose step
//!   time comes from the event-dependency-graph executor
//!   ([`crate::workflows::af`]); its attn/ffn cost models are built
//!   once at construction, never per iteration.
//!
//! Stage-to-stage KV handoff rides the 3-tier hierarchical fabric
//! ([`crate::network::HierFabric`]): stages sharing a node exchange
//! over NVLink, stages on different nodes over IB, stages in different
//! clusters over the WAN trunk.
//!
//! With `--migration threshold` the controller also runs the **expert
//! migration control loop** (ROADMAP "expert migration" /
//! "load-aware replication"): every stage with an EP domain carries a
//! windowed online load estimator fed by its routing draws; between
//! iterations the controller re-plans the expert placement when the
//! tracked load diverges from the placement's assumption, charges the
//! weight moves through the EP fabric, and stalls the stage's replicas
//! for the transfer makespan ([`crate::moe::migration`]).
//!
//! # Parallel engine (`--sim-threads`)
//!
//! A single run is sharded across **stage shards**: all entry stages
//! (which share arrival routing) form shard 0, and every
//! KV-destination stage gets its own shard. The only cross-shard
//! couplings are KV handoffs (strictly entry → destination: only
//! `Prefill`-kind stages produce `PREFILL_COMPLETE`, and a prefill
//! stage can never be a KV destination), the shared handoff fabric,
//! and the controller-level transfer queue. The run proceeds in
//! conservative time windows:
//!
//! 1. **Parallel phase** — each shard drains its own event queue up to
//!    the window horizon `T_end = T + Δ` (`T` = earliest pending event
//!    across shards, `Δ` = the sync window), touching only shard-local
//!    state and appending cross-shard effects ([`PbRec`]) to a commit
//!    list.
//! 2. **Barrier phase** — one thread merges the commit lists in
//!    deterministic `(time, shard, position)` order and applies them:
//!    transfer-queue pushes, fabric charging, and KV dispatch into
//!    destination shards (which replays destination-side frees through
//!    a window free-ledger so a dispatch at time `t` never sees memory
//!    freed later in the same window).
//!
//! `Δ` is derived from the minimum possible KV-handoff latency over
//! every kv edge (smallest trace payload at the edge's path bandwidth,
//! plus the path latency), so no event produced inside a window can
//! require cross-shard delivery inside that same window. Expert a2a /
//! migration traffic is stage-internal (it rides the stage's own EP
//! fabric, not the inter-stage fabric) and therefore never constrains
//! `Δ`. Because shards share no mutable state during the parallel
//! phase and the barrier merge order is thread-count-invariant, the
//! report is **bit-identical for any `--sim-threads` value**;
//! single-shard graphs (co-located pools) skip windowing entirely and
//! drain serially, exactly like the pre-sharding engine.
//!
//! # Cluster dynamics (`--faults` / `--autoscale`)
//!
//! With a [`crate::cluster::dynamics`] spec the fleet stops being
//! immortal and statically sized. The whole schedule is materialized
//! as a [`crate::cluster::dynamics::DynPlan`] *before* the event loop
//! and pre-scheduled into each owning shard's queue, so the parallel
//! engine never coordinates across shards to decide *when* a replica
//! dies — only the damage routes cross-shard, through the existing
//! commit records: a failed KV-destination replica requeues its
//! displaced requests via [`PbKind::Requeue`] (applied at the barrier
//! no earlier than one sync window later), its freed KV rides
//! [`PbKind::Free`], and recoveries/scale-ups re-run transfer dispatch
//! via [`PbKind::Trigger`]. Displaced requests keep their arrival
//! timestamps and pay a full re-prefill of input + decoded context
//! (KV is gone), so fault latency damage lands in TTFT/E2E/SLO
//! metrics. Replica incarnation counters ([`ReplicaWorker::gen`])
//! stamp `IterEnd`/`KvDone` events; stale ones are dropped. Runs
//! without dynamics schedule nothing and stay byte-identical to the
//! pre-dynamics engine.
//!
//! # Fabric epochs (`--link-faults`)
//!
//! Link/fabric faults change the *capacity* the sync window is derived
//! from, so they get their own determinism mechanism: the plan folds
//! the link schedule into **fabric epochs**
//! ([`crate::cluster::dynamics::LinkEpoch`]) — intervals of
//! piecewise-constant [`crate::network::FabricState`]. Per epoch the
//! engine re-derives a conservative window `Δ_e` from the *degraded*
//! path model (minimum over live kv edges only — dead paths are never
//! dispatched onto), and every window's horizon is clamped to the next
//! epoch boundary, so no window ever straddles a capacity change:
//! every dispatch inside a window prices against exactly one fabric
//! state, for any `--sim-threads`. Degradation only slows links
//! (`bw_frac <= 1`, `alpha_add_s >= 0`), so `Δ_e` stays a valid lower
//! bound within its epoch; recovery — the dangerous direction — takes
//! effect only at an epoch boundary, where `Δ` is re-derived. KV
//! transfers whose every candidate path is down are held (re-dispatched
//! at the next epoch boundary) or rejected as backpressure when no
//! future epoch revives a path; the EP cross-cluster trunk's health is
//! pushed into each stage's cost model at epoch application so MoE
//! dispatch/combine and expert migrations price through the degraded
//! trunk. Runs without `--link-faults` build no epochs and skip every
//! branch here.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Barrier, Mutex};

use anyhow::{bail, Result};

use crate::cluster::{dynamics, ClusterWorker, ReplicaWorker, StageKind};
use crate::config::{ExperimentConfig, StageGraphConfig};
use crate::core::{EventQueue, Pcg64, SimTime};
use crate::memory::{blocks_for_tokens, BlockManager};
use crate::metrics::{MetricsCollector, ReqTimestamps, SimReport, StageReport};
use crate::moe::{
    self, EpFabric, EpSpec, EpTopology, ExpertPlacement, LoadEstimator, MigrationPolicy,
};
use crate::network::{FabricState, HierFabric, NetLoc};
use crate::predictor::{self, ExecutionPredictor, PredictorKind};
use crate::scheduler::{self, IterBudget, QueuedReq};
use crate::workflows::af::{af_step, AfStep};
use crate::workflows::{BatchShape, CostCtx, CostModel, MoeEpSample};
use crate::workload::RequestSpec;

/// Request lifecycle states (§3.3's stateful workflow).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    Queued,
    Prefilling,
    PrefillComplete,
    Transferring,
    Decoding,
    Done,
    Rejected,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub spec: RequestSpec,
    pub state: ReqState,
    /// Prefill tokens completed so far (chunked prefill).
    pub prefill_progress: u32,
    /// Prefill completion target: `input_len` normally, `input_len +
    /// decoded` after a fault displaced the request (the lost KV
    /// context — prompt plus tokens decoded so far — must be
    /// recomputed before decoding resumes).
    pub prefill_target: u32,
    /// Output tokens generated so far.
    pub decoded: u32,
    pub ts: ReqTimestamps,
    pub last_token: SimTime,
    /// Fault-displacement routing attempts consumed (bounded by
    /// [`dynamics::MAX_RETRIES`]).
    pub retries: u8,
    /// Displaced by at least one fault — feeds the per-fault SLO
    /// damage meter on completion.
    pub affected: bool,
    /// KV transfer rerouted around (or stalled on) a dead fabric path
    /// — feeds the link-fault SLO damage meter on completion.
    pub link_affected: bool,
}

/// Shard-local events. Stage indices are **shard-local** — the shard
/// resolves them against its own `stages` vector without touching any
/// shared map on the hot path.
#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(u64),
    /// `gen` stamps the replica incarnation that scheduled the event;
    /// a fault bumps the replica's counter, so stale iterations from a
    /// lost incarnation drop themselves (always 0 without `--faults`).
    IterEnd { s: usize, r: usize, gen: u32 },
    KvDone { rid: u64, s: usize, r: usize, gen: u32 },
    /// Pre-scheduled fault transition from the [`dynamics::DynPlan`].
    Fault { s: usize, r: usize, up: bool },
    /// A fault-displaced request re-entering the entry router
    /// (shard 0 only).
    Retry(u64),
    /// Pre-scheduled autoscaler evaluation for one governed stage.
    ScaleTick { s: usize },
    /// A scale-up decision's provisioning delay elapsed: the replica
    /// joins the pool.
    ScaleUp { s: usize, r: usize },
}

/// A `Box<dyn ExecutionPredictor>` asserted to be `Send`.
///
/// [`ExecutionPredictor`] has no `Send` supertrait because the learned
/// predictor holds thread-affine PJRT state (`Rc` + thread-locals).
/// The engine enforces the invariant at runtime instead: when
/// `cfg.predictor` is [`PredictorKind::Learned`] the resolved thread
/// count is forced to 1 and no worker threads are spawned, so shards
/// (and the predictors inside them) never leave the constructing
/// thread. Every other predictor is plain `Send` data.
struct SendPredictor(Box<dyn ExecutionPredictor>);

// SAFETY: see the type-level invariant — a shard only crosses threads
// when the wrapped predictor is one of the analytical (plain-data)
// predictors; the learned predictor pins the run to one thread.
unsafe impl Send for SendPredictor {}

impl Deref for SendPredictor {
    type Target = Box<dyn ExecutionPredictor>;
    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

impl DerefMut for SendPredictor {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.0
    }
}

/// Prebuilt AF executor state: the attention- and FFN-pool cost models
/// are constructed once here — the per-iteration hot path only draws
/// routing and prices (no model clones, pinned by
/// [`crate::workflows::cost::COST_MODELS_BUILT`] in the tests).
struct AfRuntime {
    micro_batches: u32,
    attn_cost: CostModel,
    ffn_cost: CostModel,
}

/// One stage of the graph at runtime: the replica pool plus everything
/// needed to price its iterations.
struct StageRuntime {
    name: String,
    cw: ClusterWorker,
    /// Per-stage pricing (stage GPU, parallelism, EP placement).
    cost: CostModel,
    /// Per-stage operator-runtime predictor (stage GPU).
    pred: SendPredictor,
    budget: IterBudget,
    /// Total GPUs backing the stage (reports).
    gpus: u32,
    gpu_name: String,
    /// Coordinate in the hierarchical fabric.
    loc: NetLoc,
    af: Option<AfRuntime>,
    /// Estimator draw count at the last migration check (the control
    /// loop re-plans at most once per load window).
    mig_last_draws: u64,
    /// Scale signal at the previous autoscaler tick (the predictive
    /// policy's trend term; queue depth or SLO miss fraction).
    q_prev: f64,
    /// Shard-local completion count at the previous autoscaler tick
    /// (the `--scale-signal slo` window delta).
    prev_completed: u64,
    /// Shard-local SLO-met count at the previous autoscaler tick.
    prev_slo_ok: u64,
}

impl StageRuntime {
    /// The cost model owning this stage's EP domain: the AF stage's
    /// FFN pool, else the stage-level model. Every migration-loop
    /// access (tracker attach, estimator read, placement rewrite) goes
    /// through this pair so they can never diverge.
    fn ep_cost(&self) -> &CostModel {
        match self.af.as_ref() {
            Some(afr) => &afr.ffn_cost,
            None => &self.cost,
        }
    }

    fn ep_cost_mut(&mut self) -> &mut CostModel {
        match self.af.as_mut() {
            Some(afr) => &mut afr.ffn_cost,
            None => &mut self.cost,
        }
    }
}

/// A cross-shard effect recorded during the parallel phase and applied
/// serially — in deterministic merged order — at the window barrier.
/// Per event the emission order is frees, then transfers, then one
/// trigger, mirroring the serial handler.
enum PbKind {
    /// KV blocks freed on a KV-destination stage (request retired).
    /// Feeds the window free-ledger: a dispatch at an earlier merged
    /// timestamp must not see memory freed after it.
    Free { gstage: usize, replica: usize, blocks: u64 },
    /// A `PREFILL_COMPLETE` request leaving its source shard for the
    /// controller-level transfer queue, carried by value.
    Xfer { rid: u64, src: usize, req: Box<Request> },
    /// Memory availability changed: re-run transfer dispatch (PD
    /// backpressure steps 2/3).
    Trigger,
    /// A fault-displaced request leaving its failed destination shard
    /// for shard 0's entry router. The barrier inserts it into
    /// shard 0's store and schedules its `Retry` one recovery delay
    /// later — at least one sync window, so the cross-shard effect
    /// always lands in a future window.
    Requeue { rid: u64, req: Box<Request> },
}

/// One commit record: what happened, stamped with when.
struct PbRec {
    time: SimTime,
    kind: PbKind,
}

/// A `PREFILL_COMPLETE` request awaiting a KV transfer slot, owned by
/// the controller between its source and destination shards.
struct PendingXfer {
    rid: u64,
    /// Global index of the stage that produced it.
    src: usize,
    req: Box<Request>,
}

/// Request ownership per shard. The entry shard owns every request
/// from arrival (ids are dense `0..trace_len`); destination shards
/// hold only the requests currently resident in them (sparse), moved
/// in by value at dispatch and dropped on completion.
enum ReqStore {
    Dense(Vec<Option<Request>>),
    Sparse(HashMap<u64, Request>),
}

impl ReqStore {
    fn get(&self, rid: u64) -> &Request {
        match self {
            ReqStore::Dense(v) => v[rid as usize].as_ref().expect("live request"),
            ReqStore::Sparse(m) => m.get(&rid).expect("live request"),
        }
    }

    fn get_mut(&mut self, rid: u64) -> &mut Request {
        match self {
            ReqStore::Dense(v) => v[rid as usize].as_mut().expect("live request"),
            ReqStore::Sparse(m) => m.get_mut(&rid).expect("live request"),
        }
    }

    fn insert(&mut self, rid: u64, req: Request) {
        match self {
            ReqStore::Dense(v) => {
                let i = rid as usize;
                if v.len() <= i {
                    v.resize_with(i + 1, || None);
                }
                v[i] = Some(req);
            }
            ReqStore::Sparse(m) => {
                m.insert(rid, req);
            }
        }
    }

    fn remove(&mut self, rid: u64) -> Request {
        match self {
            ReqStore::Dense(v) => v[rid as usize].take().expect("live request"),
            ReqStore::Sparse(m) => m.remove(&rid).expect("live request"),
        }
    }
}

/// Read-only run context shared by every shard (and worker thread).
struct RunCtx {
    cfg: ExperimentConfig,
    /// Global stage index -> (shard, shard-local index).
    stage_shard: Vec<(usize, usize)>,
    /// KV-handoff successors per global stage (resolved adjacency).
    kv_out: Vec<Vec<usize>>,
    /// Per-stage max replica block capacity: admission checks compare
    /// against this cache instead of re-scanning every replica's pool
    /// per arrival (capacity is fixed at construction — replicas of a
    /// stage are built identically and never resized).
    stage_max_blocks: Vec<u64>,
    /// Global stages that receive KV handoffs (their frees feed the
    /// window free-ledger).
    is_kv_dst: Vec<bool>,
    /// Fabric coordinate per global stage.
    stage_locs: Vec<NetLoc>,
    /// Flat offset of `(global stage, replica 0)` in the free-ledger.
    free_off: Vec<usize>,
    /// Total replica slots in the free-ledger.
    free_slots: usize,
    kv_bytes_per_token: u64,
    /// Whether any kv edge exists at all (gates barrier triggers — a
    /// graph without handoffs never needs the dispatch path).
    has_transfers: bool,
    /// Whether any cluster dynamics (`--faults` / `--autoscale`) are
    /// configured. Gates the health-aware routing branches; `false`
    /// keeps every hot path byte-identical to the pre-dynamics engine.
    dyn_on: bool,
    /// Cross-shard requeue latency after a fault: the failure
    /// detection backoff widened to at least one sync window (set per
    /// run, once the window is known).
    recover_delay: SimTime,
    /// Per global stage: time of its last *scheduled* fault recovery —
    /// before it a dead pool is worth retrying into, after it a dead
    /// pool stays dead.
    revive_after: Vec<SimTime>,
    /// Per global stage: whether the autoscaler governs it
    /// (decode-capable stages only).
    governed: Vec<bool>,
    /// Per global stage: configured initial replica count. Autoscaled
    /// pools pre-provision `max_replicas` slots, but reports, GPU
    /// counts, and fault targeting all use the configured size.
    init_replicas: Vec<u32>,
    /// Whether a link-fault schedule is configured. Gates every
    /// fabric-epoch branch; `false` leaves the engine byte-identical
    /// to the pre-link-fault build.
    link_on: bool,
    /// Fabric epochs from the plan (non-empty iff `link_on`;
    /// `epochs[0]` starts at t=0).
    epochs: Vec<dynamics::LinkEpoch>,
    /// Per-epoch conservative sync window, re-derived from each
    /// epoch's degraded path model (parallel to `epochs`).
    epoch_delta: Vec<SimTime>,
}

/// One shard of the parallel engine: a group of stages advanced by one
/// worker during the parallel phase. Everything a handler mutates
/// lives here — shards share no state until the window barrier.
struct Shard {
    queue: EventQueue<Ev>,
    stages: Vec<StageRuntime>,
    /// Shard-local -> global stage index.
    gstage: Vec<usize>,
    /// Shard-local indices of entry stages (non-empty only on shard 0).
    entry: Vec<usize>,
    /// Round-robin cursor for entry routing.
    entry_rr: usize,
    store: ReqStore,
    rng: Pcg64,
    metrics: MetricsCollector,
    /// Iteration start times per (local stage, replica).
    iter_started: Vec<Vec<SimTime>>,
    /// Pending migration stall per (local stage, replica), seconds.
    pending_stall: Vec<Vec<f64>>,
    /// Arrival-routing scratch, reused across requests.
    scratch_slots: Vec<(usize, usize, u64)>,
    scratch_loads: Vec<usize>,
    scratch_free: Vec<u64>,
    /// Reusable batched-EP pricing output (AF path).
    ep_samples: Vec<MoeEpSample>,
    /// Cross-shard effects of the current window, time-ordered.
    commits: Vec<PbRec>,
    /// Fabric epoch last applied to this shard's cost models
    /// (`usize::MAX` = none yet; untouched without `--link-faults`).
    cur_epoch: usize,
}

pub struct GlobalController {
    ctx: RunCtx,
    graph: StageGraphConfig,
    shards: Vec<Shard>,
    /// Contended 3-tier fabric for stage-to-stage KV handoff. Charged
    /// only at the window barrier (serially, in merged time order).
    fabric: HierFabric,
    /// PREFILL_COMPLETE requests awaiting a KV transfer slot.
    pending_transfers: VecDeque<PendingXfer>,
}

/// Convenience: build + run.
pub fn run(cfg: &ExperimentConfig) -> Result<SimReport> {
    GlobalController::new(cfg.clone())?.run()
}

impl GlobalController {
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let graph = cfg.stage_graph();
        let model = &cfg.model;
        // EP fabric: legacy flat intra+cross unless node granularity is
        // engaged (`ranks_per_node > 0`). The NIC ingress-asymmetry knob
        // applies smoothly in both modes — it must not flip the fabric
        // model, only scale ingress bandwidth.
        let ep_fabric = if cfg.ranks_per_node == 0 {
            EpFabric {
                ingress_scale: cfg.nic_ingress_scale,
                ..EpFabric::flat(cfg.link, cfg.cross_link)
            }
        } else {
            EpFabric::hierarchical(cfg.hier_spec(), cfg.ranks_per_node, cfg.nic_ingress_scale)
        };
        // EP placement over `ranks` expert ranks spanning `clusters`
        // clusters. The replicated-hot policy targets the experts a
        // deterministic warmup routing draw marks hottest — with the
        // stable skewed-popularity model this is the run's actual hot
        // set (see `moe::expert_popularity`).
        let make_ep = |ranks: u32, clusters: u32| -> Option<EpSpec> {
            let moe = model.moe.as_ref()?;
            if ranks <= 1 {
                return None;
            }
            let mut warmup = Pcg64::new(cfg.seed ^ 0x9E37_79B9);
            let hint = moe::assign_tokens(
                cfg.policy.moe_routing,
                4096,
                moe.n_experts,
                moe.top_k,
                &mut warmup,
            );
            Some(EpSpec {
                placement: ExpertPlacement::build(
                    cfg.policy.ep_placement,
                    moe.n_experts,
                    EpTopology::new(ranks, clusters),
                    Some(&hint),
                ),
                fabric: ep_fabric,
            })
        };
        let base_cost = |par: crate::parallelism::Parallelism| -> CostModel {
            let mut cost = CostModel::new(model.clone(), par, cfg.link);
            cost.moe_routing = cfg.policy.moe_routing;
            cost.routing_fidelity = cfg.policy.routing_fidelity;
            cost.straggler_max = cfg.policy.straggler_max;
            cost.overhead = cfg.overhead;
            cost.capacity_factor = cfg.policy.capacity_factor;
            cost
        };
        let mut runtimes = Vec::with_capacity(graph.stages.len());
        for st in &graph.stages {
            let gpu = st.gpu.clone().unwrap_or_else(|| cfg.gpu.clone());
            let par = st.parallel.unwrap_or(cfg.parallel);
            let budget = st.budget.unwrap_or(cfg.policy.budget);
            let ep_clusters = st.ep_clusters.unwrap_or(cfg.ep_clusters);
            let gpus_per_replica = par.gpus_per_replica();
            // autoscaled pools pre-provision `max_replicas` slots so
            // every shape derived from the pool (free-ledger offsets,
            // capacity caches, stall vectors) is fixed at
            // construction; the extra slots start down and cost
            // nothing until a scale-up brings them up. GPU counts and
            // reports keep the configured initial size.
            let n_slots = match cfg.autoscale.as_ref() {
                Some(a) if st.kind != StageKind::Prefill => a.max_replicas.max(st.replicas),
                _ => st.replicas,
            };
            let (mut cw, gpus, af) = match st.af {
                Some(afp) => {
                    // KV lives on the attention side of the AF pair;
                    // roughly half the weights (attention stack) sit
                    // with it.
                    let af_mem = BlockManager::from_budget(
                        gpu.hbm_capacity * afp.attn_gpus as u64,
                        model.param_count() * model.dtype_bytes as u64 / 2,
                        model.kv_bytes_per_token(),
                        cfg.policy.kv_reserve_frac,
                    );
                    let group_gpus = afp.attn_gpus + afp.ffn_gpus;
                    let cw = ClusterWorker::new(st.kind, n_slots, group_gpus, af_mem);
                    // attention pool: TP across its GPUs; FFN pool: EP
                    // for MoE (or TP for dense)
                    let attn_par = crate::parallelism::Parallelism::tp(
                        afp.attn_gpus.min(model.n_kv_heads).max(1),
                    );
                    let ffn_par = if model.is_moe() {
                        crate::parallelism::Parallelism::new(1, 1, afp.ffn_gpus.max(1))
                    } else {
                        crate::parallelism::Parallelism::tp(afp.ffn_gpus.max(1))
                    };
                    let mut attn_cost = base_cost(attn_par);
                    attn_cost.overhead = crate::config::OverheadConfig::zero();
                    let mut ffn_cost = base_cost(ffn_par);
                    ffn_cost.overhead = crate::config::OverheadConfig::zero();
                    // the FFN pool is the EP domain: a2f/f2a hops become
                    // the EP dispatch/combine phases
                    ffn_cost.ep = make_ep(afp.ffn_gpus, ep_clusters);
                    let af = AfRuntime {
                        micro_batches: afp.micro_batches,
                        attn_cost,
                        ffn_cost,
                    };
                    (cw, st.replicas * group_gpus, Some(af))
                }
                None => {
                    let mem = BlockManager::from_budget(
                        gpu.hbm_capacity * gpus_per_replica as u64,
                        model.weight_bytes_per_gpu(par.tp, par.ep) * gpus_per_replica as u64,
                        model.kv_bytes_per_token(),
                        cfg.policy.kv_reserve_frac,
                    );
                    let cw = ClusterWorker::new(st.kind, n_slots, gpus_per_replica, mem);
                    (cw, st.replicas * gpus_per_replica, None)
                }
            };
            for rep in cw.replicas.iter_mut().skip(st.replicas as usize) {
                rep.up = false; // autoscale headroom: not yet provisioned
            }
            let mut cost = base_cost(par);
            // replica-level EP ranks (co-located / PD stages)
            if af.is_none() {
                cost.ep = make_ep(par.ep, ep_clusters);
            }
            let pred = predictor::build_for(
                cfg.predictor,
                gpu.clone(),
                cfg.link,
                cfg.artifacts_dir.as_deref(),
            )?;
            runtimes.push(StageRuntime {
                name: st.name.clone(),
                cw,
                cost,
                pred: SendPredictor(pred),
                budget,
                gpus,
                gpu_name: gpu.name.to_string(),
                loc: NetLoc::new(st.cluster, st.node),
                af,
                mig_last_draws: 0,
                q_prev: 0.0,
                prev_completed: 0,
                prev_slo_ok: 0,
            });
            // expert-migration control loop: attach the online load
            // estimator to the cost model owning the stage's EP domain.
            // Static runs carry no tracker at all, keeping them
            // bit-identical to the pre-migration simulator.
            if cfg.policy.migration == MigrationPolicy::Threshold {
                if let Some(moe) = model.moe.as_ref() {
                    let tracked = runtimes.last_mut().expect("just pushed").ep_cost_mut();
                    if tracked.ep.is_some() {
                        tracked.load_tracker = Some(RefCell::new(LoadEstimator::new(
                            moe.n_experts,
                            cfg.policy.load_window,
                        )));
                    }
                }
            }
        }
        let n = graph.stages.len();
        let entry_g = graph.entry_stages();
        let kv_out: Vec<Vec<usize>> = (0..n).map(|s| graph.kv_out(s)).collect();
        let mut is_entry = vec![false; n];
        for &s in &entry_g {
            is_entry[s] = true;
        }
        let mut is_kv_dst = vec![false; n];
        for dsts in &kv_out {
            for &d in dsts {
                is_kv_dst[d] = true;
            }
        }
        // shard partition: the entry stages share arrival routing, so
        // they ride shard 0 together; every other stage (always a KV
        // destination — a non-entry stage is only reachable over a kv
        // edge) advances independently in its own shard
        let mut shard_stages: Vec<Vec<usize>> = vec![entry_g];
        for (s, entry) in is_entry.iter().enumerate() {
            if !entry {
                shard_stages.push(vec![s]);
            }
        }
        let mut stage_shard = vec![(0usize, 0usize); n];
        for (si, list) in shard_stages.iter().enumerate() {
            for (li, &g) in list.iter().enumerate() {
                stage_shard[g] = (si, li);
            }
        }
        let stage_max_blocks: Vec<u64> = runtimes
            .iter()
            .map(|st| st.cw.replicas.iter().map(|rep| rep.mem.total_blocks()).max().unwrap_or(0))
            .collect();
        let stage_locs: Vec<NetLoc> = runtimes.iter().map(|st| st.loc).collect();
        let mut free_off = Vec::with_capacity(n);
        let mut free_slots = 0usize;
        for st in &runtimes {
            free_off.push(free_slots);
            free_slots += st.cw.replicas.len();
        }
        let has_transfers = kv_out.iter().any(|d| !d.is_empty());
        let governed = ExperimentConfig::autoscale_governs(&graph);
        let init_replicas: Vec<u32> = graph.stages.iter().map(|st| st.replicas).collect();
        let dyn_on =
            cfg.faults.is_some() || cfg.autoscale.is_some() || cfg.link_faults.is_some();
        // distribute the stage runtimes into their shards
        let mut slots: Vec<Option<StageRuntime>> = runtimes.into_iter().map(Some).collect();
        let shards: Vec<Shard> = shard_stages
            .iter()
            .enumerate()
            .map(|(si, list)| {
                let stages: Vec<StageRuntime> = list
                    .iter()
                    .map(|&g| slots[g].take().expect("each stage lives in exactly one shard"))
                    .collect();
                let mut metrics = MetricsCollector::default();
                metrics.slo = cfg.slo;
                metrics.class_names = cfg.workload.class_names();
                if cfg.keep_raw_samples {
                    metrics.raw = Some(Box::default());
                }
                let iter_started = stages
                    .iter()
                    .map(|st| vec![SimTime::ZERO; st.cw.replicas.len()])
                    .collect();
                let pending_stall =
                    stages.iter().map(|st| vec![0.0f64; st.cw.replicas.len()]).collect();
                Shard {
                    queue: EventQueue::new(),
                    gstage: list.clone(),
                    entry: if si == 0 { (0..stages.len()).collect() } else { Vec::new() },
                    entry_rr: 0,
                    store: if si == 0 {
                        ReqStore::Dense(Vec::new())
                    } else {
                        ReqStore::Sparse(HashMap::new())
                    },
                    // disjoint deterministic RNG streams: shard 0 keeps
                    // the legacy stream (single-shard graphs stay
                    // bit-identical to the pre-sharding engine)
                    rng: if si == 0 {
                        Pcg64::new(cfg.seed)
                    } else {
                        Pcg64::new(cfg.seed ^ 0xD1B5_4A32_D192_ED03u64.wrapping_mul(si as u64))
                    },
                    metrics,
                    iter_started,
                    pending_stall,
                    scratch_slots: Vec::new(),
                    scratch_loads: Vec::new(),
                    scratch_free: Vec::new(),
                    ep_samples: Vec::new(),
                    commits: Vec::new(),
                    cur_epoch: usize::MAX,
                    stages,
                }
            })
            .collect();
        Ok(GlobalController {
            graph,
            shards,
            fabric: HierFabric::new(cfg.hier_spec()),
            pending_transfers: VecDeque::new(),
            ctx: RunCtx {
                stage_shard,
                kv_out,
                stage_max_blocks,
                is_kv_dst,
                stage_locs,
                free_off,
                free_slots,
                kv_bytes_per_token: cfg.model.kv_bytes_per_token(),
                has_transfers,
                dyn_on,
                recover_delay: SimTime::ZERO,
                revive_after: vec![SimTime::ZERO; n],
                governed,
                init_replicas,
                link_on: cfg.link_faults.is_some(),
                epochs: Vec::new(),
                epoch_delta: Vec::new(),
                cfg,
            },
        })
    }

    /// Execute the configured workload to completion (loading and
    /// validating the trace file first when the workload replays one).
    pub fn run(self) -> Result<SimReport> {
        let trace = self.ctx.cfg.workload.materialize()?;
        self.run_with_trace(trace)
    }

    /// Conservative cross-shard synchronization horizon: the smallest
    /// possible KV-handoff latency over every kv edge — the wire time
    /// of the smallest trace payload at the edge path's bandwidth plus
    /// the path latency, exactly the lower bound of
    /// [`crate::network::Link::transfer`]'s charge. A handoff
    /// dispatched at `t` is delivered no earlier than `t + Δ`, so
    /// events inside a `[T, T + Δ)` window never need cross-shard
    /// visibility within it. Floored at one tick so a window always
    /// covers its opening timestamp.
    fn sync_window(&self, trace: &[RequestSpec]) -> SimTime {
        self.sync_window_for(self.min_kv_bytes(trace), None)
            .unwrap_or(SimTime(1))
            .max(SimTime(1))
    }

    /// Smallest KV handoff payload the trace can produce, bytes.
    fn min_kv_bytes(&self, trace: &[RequestSpec]) -> f64 {
        let min_input = trace.iter().map(|t| t.input_len).min().unwrap_or(1).max(1);
        min_input as f64 * self.ctx.kv_bytes_per_token as f64
    }

    /// The conservative window under one fabric state: minimum over
    /// *live* kv edges of the (degraded) lower-bound handoff latency.
    /// Dead edges are excluded — the dispatcher never sends a transfer
    /// onto a down path, so they cannot constrain the window. `None`
    /// when no live edge exists (no kv edges at all, or every path is
    /// down in this epoch — nothing dispatches, so any window is
    /// conservative); `state == None` prices the healthy fabric.
    fn sync_window_for(&self, min_bytes: f64, state: Option<&FabricState>) -> Option<SimTime> {
        let spec = self.fabric.spec();
        let mut delta: Option<SimTime> = None;
        for (src, dsts) in self.ctx.kv_out.iter().enumerate() {
            for &d in dsts {
                let (sl, dl) = (self.ctx.stage_locs[src], self.ctx.stage_locs[d]);
                let path = match state {
                    Some(fs) => match fs.degraded_path(spec, sl, dl) {
                        Some(p) => p,
                        None => continue,
                    },
                    None => spec.path(sl, dl),
                };
                let edge = SimTime::from_secs_f64(min_bytes / path.bandwidth)
                    + SimTime::from_secs_f64(path.alpha);
                delta = Some(match delta {
                    None => edge,
                    Some(cur) => cur.min(edge),
                });
            }
        }
        delta
    }

    /// Execute an explicit request trace (trace replay) to completion.
    pub fn run_with_trace(mut self, trace: Vec<RequestSpec>) -> Result<SimReport> {
        let host_start = std::time::Instant::now();
        let trace_len = trace.len() as u64;
        let delta = self.sync_window(&trace);
        let min_bytes = self.min_kv_bytes(&trace);
        let last_arrival_s =
            trace.iter().map(|t| t.arrival.as_secs_f64()).fold(0.0f64, f64::max);
        {
            let s0 = &mut self.shards[0];
            if let ReqStore::Dense(v) = &mut s0.store {
                v.reserve(trace.len());
            }
            for (rid, spec) in trace.into_iter().enumerate() {
                let rid = rid as u64;
                let arrival = spec.arrival;
                s0.store.insert(
                    rid,
                    Request {
                        ts: ReqTimestamps { arrival, ..Default::default() },
                        prefill_target: spec.input_len,
                        spec,
                        state: ReqState::Queued,
                        prefill_progress: 0,
                        decoded: 0,
                        last_token: SimTime::ZERO,
                        retries: 0,
                        affected: false,
                        link_affected: false,
                    },
                );
                s0.queue.schedule_at(arrival, Ev::Arrival(rid));
            }
        }
        // cluster dynamics: materialize the seeded plan — a pure
        // function of (spec, shape, seed, horizon), independent of
        // thread count — and pre-schedule every transition into the
        // queue of the shard that owns its stage. Runs without
        // --faults/--autoscale build no plan and schedule nothing.
        let (mut link_fault_n, mut link_recovery_n) = (0u64, 0u64);
        if self.ctx.dyn_on {
            self.ctx.recover_delay =
                SimTime::from_secs_f64(dynamics::RECOVER_BACKOFF_S).max(delta);
            let plan = dynamics::build_plan(
                self.ctx.cfg.faults.as_ref(),
                self.ctx.cfg.link_faults.as_ref(),
                self.ctx.cfg.autoscale.as_ref(),
                &self.ctx.init_replicas,
                self.ctx.cfg.seed,
                last_arrival_s + dynamics::PLAN_SLACK_S,
            );
            self.ctx.revive_after = plan.revive_after.clone();
            // fabric epochs: re-derive the conservative window per
            // epoch from its degraded path model. An epoch with no
            // live kv edge dispatches nothing, so the healthy Δ
            // stands in (any value is conservative there).
            if self.ctx.link_on {
                let deltas: Vec<SimTime> = plan
                    .epochs
                    .iter()
                    .map(|ep| {
                        self.sync_window_for(min_bytes, Some(&ep.state))
                            .unwrap_or(delta)
                            .max(SimTime(1))
                    })
                    .collect();
                // a cross-shard requeue must land in a future window
                // under the *widest* epoch's Δ
                self.ctx.recover_delay =
                    deltas.iter().copied().fold(self.ctx.recover_delay, SimTime::max);
                self.ctx.epoch_delta = deltas;
                self.ctx.epochs = plan.epochs.clone();
                for e in &plan.link_events {
                    if e.health.healthy() {
                        link_recovery_n += 1;
                    } else {
                        link_fault_n += 1;
                    }
                }
            }
            for f in &plan.faults {
                let (si, li) = self.ctx.stage_shard[f.stage];
                self.shards[si]
                    .queue
                    .schedule_at(f.at, Ev::Fault { s: li, r: f.replica, up: f.up });
            }
            for (gs, &gov) in self.ctx.governed.iter().enumerate() {
                if !gov {
                    continue;
                }
                let (si, li) = self.ctx.stage_shard[gs];
                for &t in &plan.ticks {
                    self.shards[si].queue.schedule_at(t, Ev::ScaleTick { s: li });
                }
            }
        }
        let GlobalController { ctx, graph: _, mut shards, mut fabric, mut pending_transfers } =
            self;
        // resolved worker count: never more threads than shards, and
        // the learned predictor's thread-affine PJRT state pins the run
        // to the constructing thread
        let mut nthreads = (ctx.cfg.sim_threads as usize).clamp(1, shards.len());
        if ctx.cfg.predictor == PredictorKind::Learned {
            nthreads = 1;
        }
        if shards.len() == 1 {
            Self::drain_single(&mut shards[0], &ctx, &mut fabric, &mut pending_transfers);
        } else {
            let mut future_frees = vec![0u64; ctx.free_slots];
            let cells: Vec<Mutex<Shard>> = shards.into_iter().map(Mutex::new).collect();
            Self::run_windows(
                &cells,
                &ctx,
                &mut fabric,
                &mut pending_transfers,
                &mut future_frees,
                delta,
                nthreads,
            );
            shards = cells
                .into_iter()
                .map(|m| m.into_inner().expect("no shard worker panicked"))
                .collect();
        }
        let horizon = shards.iter().map(|sh| sh.queue.now()).max().unwrap_or(SimTime::ZERO);
        // merge shard-local metrics in fixed shard order (deterministic
        // regardless of how many threads advanced the shards)
        let mut metrics = std::mem::take(&mut shards[0].metrics);
        for sh in shards.iter().skip(1) {
            metrics.merge(&sh.metrics);
        }
        // outages still open at the horizon never saw their recovery:
        // charge the partial downtime (fixed stage order, deterministic)
        for sh in &shards {
            for st in &sh.stages {
                for rep in &st.cw.replicas {
                    if let Some(since) = rep.down_since {
                        metrics.fault_downtime_s += (horizon - since).as_secs_f64();
                    }
                }
            }
        }
        // link-fault meters that are pure functions of the plan:
        // stamped once on the merged collector, identical for any
        // thread count
        if link_fault_n > 0 || link_recovery_n > 0 {
            metrics.link_faults = link_fault_n;
            metrics.link_recoveries = link_recovery_n;
            metrics.link_degraded_s =
                dynamics::degraded_seconds(&ctx.epochs, horizon.as_secs_f64());
        }
        let finished = metrics.completed_requests + metrics.rejected_requests;
        if finished < trace_len {
            let unfinished = trace_len - finished;
            bail!("simulation stalled with {unfinished} unfinished requests");
        }
        metrics.predictor_evals = shards
            .iter()
            .flat_map(|sh| sh.stages.iter())
            .map(|st| st.pred.evals())
            .sum();
        let events_processed: u64 = shards.iter().map(|sh| sh.queue.processed()).sum();
        let stage_reports: Vec<StageReport> = ctx
            .stage_shard
            .iter()
            .enumerate()
            .map(|(g, &(si, li))| {
                let st = &shards[si].stages[li];
                // autoscaled pools report against the configured
                // initial size (pre-provisioned headroom slots are an
                // engine artifact, not deployed capacity)
                let init = ctx.init_replicas[g];
                StageReport {
                    name: st.name.clone(),
                    kind: st.cw.kind.name().to_string(),
                    replicas: init,
                    gpus: st.gpus,
                    gpu_name: st.gpu_name.clone(),
                    iterations: st.cw.replicas.iter().map(|r| r.iterations).sum(),
                    tokens: st.cw.replicas.iter().map(|r| r.tokens_processed).sum(),
                    busy_frac: st.cw.busy_fraction_n(horizon, init as usize),
                    peak_mem_frac: st.cw.peak_mem_frac(),
                }
            })
            .collect();
        // sum over the already-resolved runtime stages (cfg.n_gpus()
        // would re-lower and re-clone the whole graph)
        let n_gpus = stage_reports.iter().map(|st| st.gpus).sum();
        let (p_si, p_li) = ctx.stage_shard[0];
        Ok(SimReport {
            mode: ctx.cfg.mode_name().to_string(),
            predictor: shards[p_si].stages[p_li].pred.name().to_string(),
            sim_duration: horizon.as_secs_f64(),
            host_duration: host_start.elapsed().as_secs_f64(),
            events_processed,
            n_gpus,
            metrics,
            stages: stage_reports,
        })
    }

    // -- engine loops -------------------------------------------------------

    /// Single-shard fast path: no cross-shard edges exist, so the run
    /// is a plain serial drain with commits applied inline after every
    /// event — observationally identical to the pre-sharding engine.
    fn drain_single(
        shard: &mut Shard,
        ctx: &RunCtx,
        fabric: &mut HierFabric,
        pending: &mut VecDeque<PendingXfer>,
    ) {
        // single-shard graphs have no KV destinations, so the ledger
        // stays all-zero (frees are always live here)
        let future_frees = vec![0u64; ctx.free_slots];
        while let Some(t) = shard.queue.peek_time() {
            // fabric epochs: install the state covering this event's
            // time before handling it (single-shard graphs have no kv
            // handoffs, so this only moves EP trunk pricing)
            if ctx.link_on {
                let ei = dynamics::epoch_index(&ctx.epochs, t);
                if ei != shard.cur_epoch {
                    fabric.set_state(ctx.epochs[ei].state.clone());
                    shard.apply_epoch(ctx, ei);
                }
            }
            let ev = shard.queue.pop().expect("peeked");
            shard.handle(ctx, ev.kind);
            if shard.commits.is_empty() {
                continue;
            }
            let now = shard.queue.now();
            let recs = std::mem::take(&mut shard.commits);
            for rec in recs {
                match rec.kind {
                    PbKind::Free { .. } => {}
                    PbKind::Xfer { rid, src, req } => {
                        pending.push_back(PendingXfer { rid, src, req });
                    }
                    // unreachable in practice: a single-shard graph
                    // handles its own (entry-stage) faults locally —
                    // kept for uniformity with the windowed engine
                    PbKind::Requeue { rid, req } => {
                        shard.store.insert(rid, *req);
                        shard.queue.schedule_at(now + ctx.recover_delay, Ev::Retry(rid));
                    }
                    PbKind::Trigger => {
                        let mut view = [&mut *shard];
                        Self::dispatch_transfers(
                            &mut view,
                            ctx,
                            fabric,
                            pending,
                            &future_frees,
                            now,
                        );
                    }
                }
            }
        }
    }

    /// The windowed multi-shard loop. Every window: compute the
    /// horizon, advance every shard up to it (on `nthreads` threads —
    /// shards are pulled off a shared counter), then apply the window's
    /// commits serially at the barrier. The same code path serves
    /// `nthreads == 1` (no workers spawn; the barriers are trivial), so
    /// serial and parallel runs execute the identical algorithm.
    fn run_windows(
        cells: &[Mutex<Shard>],
        ctx: &RunCtx,
        fabric: &mut HierFabric,
        pending: &mut VecDeque<PendingXfer>,
        future_frees: &mut [u64],
        delta: SimTime,
        nthreads: usize,
    ) {
        let n_shards = cells.len();
        let barrier_a = Barrier::new(nthreads);
        let barrier_b = Barrier::new(nthreads);
        let t_end_bits = AtomicU64::new(0);
        let epoch_bits = AtomicUsize::new(0);
        let done = AtomicBool::new(false);
        let panicked = AtomicBool::new(false);
        let next_shard = AtomicUsize::new(0);
        // one parallel-phase turn: pull shard indices until none remain
        let advance_all = |t_end: SimTime| {
            let res = catch_unwind(AssertUnwindSafe(|| {
                let ei = epoch_bits.load(Ordering::Acquire);
                loop {
                    let i = next_shard.fetch_add(1, Ordering::Relaxed);
                    if i >= n_shards {
                        break;
                    }
                    let mut sh = cells[i].lock().expect("shard lock");
                    if ctx.link_on {
                        // windows never straddle an epoch boundary, so
                        // one state covers the whole parallel phase
                        sh.apply_epoch(ctx, ei);
                    }
                    sh.advance(ctx, t_end);
                }
            }));
            if res.is_err() {
                panicked.store(true, Ordering::Release);
            }
        };
        std::thread::scope(|scope| {
            for _ in 1..nthreads {
                scope.spawn(|| loop {
                    barrier_a.wait();
                    if done.load(Ordering::Acquire) {
                        return;
                    }
                    advance_all(SimTime(t_end_bits.load(Ordering::Acquire)));
                    barrier_b.wait();
                });
            }
            let mut cur_epoch = usize::MAX;
            loop {
                // workers are parked at barrier_a here: the main thread
                // owns every shard (uncontended locks)
                let t = cells
                    .iter()
                    .filter_map(|c| c.lock().expect("shard lock").queue.peek_time())
                    .min();
                let t = match t {
                    Some(t) => t,
                    // every queue is idle but transfers are held for a
                    // scheduled path recovery (holds are only taken when
                    // a future epoch revives a path): step to the next
                    // epoch boundary so its re-dispatch can run — epochs
                    // are not queue events, so nothing else would wake
                    // the loop
                    None if ctx.link_on && !pending.is_empty() => {
                        let ni = if cur_epoch == usize::MAX { 0 } else { cur_epoch + 1 };
                        match ctx.epochs.get(ni) {
                            Some(ep) => ep.start,
                            None => break,
                        }
                    }
                    None => break,
                };
                let t_end = if ctx.link_on {
                    // fabric epochs: the window runs at this epoch's Δ
                    // and is clamped to the next epoch boundary, so no
                    // window straddles a capacity change
                    let ei = dynamics::epoch_index(&ctx.epochs, t);
                    if ei != cur_epoch {
                        cur_epoch = ei;
                        fabric.set_state(ctx.epochs[ei].state.clone());
                        epoch_bits.store(ei, Ordering::Release);
                        // a recovered path fires no Trigger of its own:
                        // re-run dispatch for transfers stalled on a
                        // path that just came back (all shards are
                        // parked, frees are live — ledger stays zero)
                        if !pending.is_empty() {
                            let mut guards: Vec<_> = cells
                                .iter()
                                .map(|c| c.lock().expect("shard lock"))
                                .collect();
                            future_frees.fill(0);
                            Self::dispatch_transfers(
                                &mut guards,
                                ctx,
                                fabric,
                                pending,
                                future_frees,
                                t,
                            );
                        }
                    }
                    let mut te = t + ctx.epoch_delta[ei];
                    if let Some(next) = ctx.epochs.get(ei + 1) {
                        te = te.min(next.start);
                    }
                    te
                } else {
                    t + delta
                };
                t_end_bits.store(t_end.0, Ordering::Release);
                next_shard.store(0, Ordering::Release);
                barrier_a.wait();
                advance_all(t_end);
                barrier_b.wait();
                if panicked.load(Ordering::Acquire) {
                    done.store(true, Ordering::Release);
                    barrier_a.wait();
                    panic!("engine shard worker panicked during the parallel phase");
                }
                Self::window_barrier(cells, ctx, fabric, pending, future_frees);
            }
            done.store(true, Ordering::Release);
            barrier_a.wait();
        });
    }

    /// Apply one window's cross-shard commits: merge the per-shard
    /// commit lists by `(time, shard, position)` — thread-count
    /// invariant — and replay them against the free-ledger.
    fn window_barrier(
        cells: &[Mutex<Shard>],
        ctx: &RunCtx,
        fabric: &mut HierFabric,
        pending: &mut VecDeque<PendingXfer>,
        future_frees: &mut [u64],
    ) {
        let mut guards: Vec<_> = cells.iter().map(|c| c.lock().expect("shard lock")).collect();
        if guards.iter().all(|g| g.commits.is_empty()) {
            return;
        }
        // ledger: every KV-destination free in this window, by replica
        // slot. Frees were applied live during the parallel phase, so
        // "free blocks at merged time t" = live free minus the frees
        // not yet replayed past t.
        future_frees.fill(0);
        for g in guards.iter() {
            for rec in &g.commits {
                if let PbKind::Free { gstage, replica, blocks } = rec.kind {
                    future_frees[ctx.free_off[gstage] + replica] += blocks;
                }
            }
        }
        let lists: Vec<Vec<PbRec>> =
            guards.iter_mut().map(|g| std::mem::take(&mut g.commits)).collect();
        let mut iters: Vec<_> = lists.into_iter().map(|l| l.into_iter().peekable()).collect();
        loop {
            // earliest-time commit; ties resolve to the lowest shard
            // index, then list order — fully deterministic
            let mut best: Option<(SimTime, usize)> = None;
            for (i, it) in iters.iter_mut().enumerate() {
                if let Some(rec) = it.peek() {
                    let earlier = match best {
                        None => true,
                        Some((bt, _)) => rec.time < bt,
                    };
                    if earlier {
                        best = Some((rec.time, i));
                    }
                }
            }
            let Some((time, i)) = best else { break };
            let rec = iters[i].next().expect("peeked");
            match rec.kind {
                PbKind::Free { gstage, replica, blocks } => {
                    let slot = ctx.free_off[gstage] + replica;
                    future_frees[slot] = future_frees[slot].saturating_sub(blocks);
                }
                PbKind::Xfer { rid, src, req } => {
                    pending.push_back(PendingXfer { rid, src, req });
                }
                // fault displacement crossing back to the entry
                // router: recover_delay >= the sync window, so the
                // Retry always lands in a future window
                PbKind::Requeue { rid, req } => {
                    guards[0].store.insert(rid, *req);
                    guards[0].queue.schedule_at(time + ctx.recover_delay, Ev::Retry(rid));
                }
                PbKind::Trigger => {
                    Self::dispatch_transfers(&mut guards, ctx, fabric, pending, future_frees, time);
                }
            }
        }
    }

    /// PD backpressure: initiate KV transfers only into replicas with
    /// free memory, FIFO over the PREFILL_COMPLETE queue. With several
    /// downstream pools (fan-out) the pool with the most free memory
    /// wins. FIFO is enforced *per destination set*: a held request
    /// blocks later requests that could route to any of its candidate
    /// pools (no overtaking within a pipeline), but requests bound for
    /// disjoint pools — independent prefill->decode pipelines in the
    /// same graph — dispatch freely past it. `future_frees` discounts
    /// destination memory freed later in the window than `now`.
    fn dispatch_transfers<S: DerefMut<Target = Shard>>(
        shards: &mut [S],
        ctx: &RunCtx,
        fabric: &mut HierFabric,
        pending: &mut VecDeque<PendingXfer>,
        future_frees: &[u64],
        now: SimTime,
    ) {
        let mut held: VecDeque<PendingXfer> = VecDeque::new();
        // destinations an earlier held request may still claim
        let mut blocked: Vec<bool> = vec![false; ctx.stage_shard.len()];
        while let Some(mut px) = pending.pop_front() {
            let (input_len, output_len) = (px.req.spec.input_len, px.req.spec.output_len);
            let blocks = blocks_for_tokens(input_len + output_len);
            let dsts = &ctx.kv_out[px.src];
            // defensive: a request no replica could EVER hold must not
            // clog the queue (admission control should prevent this)
            if dsts.iter().all(|&d| blocks > ctx.stage_max_blocks[d]) {
                shards[0].metrics.rejected_requests += 1;
                continue;
            }
            // FIFO per pipeline: an earlier held request owns these pools
            if dsts.iter().any(|&d| blocked[d]) {
                for &d in dsts {
                    blocked[d] = true;
                }
                held.push_back(px);
                continue;
            }
            // choose the (stage, replica) with the most free memory —
            // as of `now`, not end-of-window — that fits
            let mut best: Option<(usize, usize, u64)> = None;
            let mut live_dsts = 0usize;
            for &d in dsts {
                // link-fault routing: a dead fabric path takes no new
                // transfers — they reroute, stall, or reject below
                if ctx.link_on
                    && !fabric.state().path_up(ctx.stage_locs[px.src], ctx.stage_locs[d])
                {
                    continue;
                }
                live_dsts += 1;
                let (ds, dl) = ctx.stage_shard[d];
                for (r, rep) in shards[ds].stages[dl].cw.replicas.iter().enumerate() {
                    // health-aware fan-out: down/draining replicas
                    // take no new KV (vacuously true without
                    // dynamics — every replica is alive)
                    if !rep.alive() {
                        continue;
                    }
                    let fr = rep.mem.free_blocks().saturating_sub(future_frees[ctx.free_off[d] + r]);
                    let better = match best {
                        None => true,
                        Some((_, _, b)) => fr > b,
                    };
                    if fr >= blocks && better {
                        best = Some((d, r, fr));
                    }
                }
            }
            let Some((d, r, _)) = best else {
                // every candidate path is down: hold until a future
                // epoch revives one (re-dispatched at that epoch's
                // boundary — no memory Trigger would come), or reject
                // as backpressure when the partition never heals
                if ctx.link_on && live_dsts == 0 && !dsts.is_empty() {
                    let src_loc = ctx.stage_locs[px.src];
                    let revives = dsts.iter().any(|&d| {
                        ctx.epochs.iter().any(|ep| {
                            ep.start > now && ep.state.path_up(src_loc, ctx.stage_locs[d])
                        })
                    });
                    if !revives {
                        shards[0].metrics.rejected_requests += 1;
                        shards[0].metrics.fault_rejected += 1;
                        continue;
                    }
                    if !px.req.link_affected {
                        px.req.link_affected = true;
                        shards[0].metrics.link_stalled_transfers += 1;
                    }
                    for &dd in dsts {
                        blocked[dd] = true;
                    }
                    held.push_back(px);
                    continue;
                }
                // a hold is only safe when a future Trigger can come:
                // a pipeline whose every pool is dead with no recovery
                // or scale-up in sight would stall the run — reject
                // with backpressure instead
                let dead_forever = ctx.dyn_on
                    && dsts.iter().all(|&d| {
                        let (ds, dl) = ctx.stage_shard[d];
                        shards[ds].stages[dl].cw.replicas.iter().all(|rep| !rep.alive())
                            && now >= ctx.revive_after[d]
                            && !(ctx.cfg.autoscale.is_some() && ctx.governed[d])
                    });
                if dead_forever {
                    shards[0].metrics.rejected_requests += 1;
                    shards[0].metrics.fault_rejected += 1;
                    continue;
                }
                // backpressure: no consumer memory in this pipeline
                for &dd in dsts {
                    blocked[dd] = true;
                }
                held.push_back(px);
                continue;
            };
            if ctx.link_on && live_dsts < dsts.len() {
                // dispatched around at least one dead path
                shards[0].metrics.link_rerouted_transfers += 1;
                px.req.link_affected = true;
            }
            let (ds, dl) = ctx.stage_shard[d];
            shards[ds].stages[dl].cw.replicas[r]
                .mem
                .allocate(px.rid, blocks)
                .expect("reserved blocks must fit");
            let bytes = input_len as f64 * ctx.kv_bytes_per_token as f64;
            // the handoff rides the hierarchical fabric between the two
            // stages' coordinates (NVLink / IB / WAN by placement)
            let delivery = fabric.transfer(now, ctx.stage_locs[px.src], ctx.stage_locs[d], bytes);
            shards[0].metrics.kv_transfers += 1;
            shards[0].metrics.kv_bytes += bytes;
            let mut req = px.req;
            req.state = ReqState::Transferring;
            shards[ds].store.insert(px.rid, *req);
            // stamp the destination incarnation: if the replica fails
            // while the transfer is in flight, the KvDone goes stale
            // (the fault requeues the request via the inbound list)
            let gen = shards[ds].stages[dl].cw.replicas[r].gen;
            shards[ds].stages[dl].cw.replicas[r].inbound.push(px.rid);
            shards[ds].queue.schedule_at(delivery, Ev::KvDone { rid: px.rid, s: dl, r, gen });
        }
        *pending = held;
    }

    // -- accessors for tests/tools ------------------------------------------

    /// The resolved stage graph this controller executes.
    pub fn stage_graph(&self) -> &StageGraphConfig {
        &self.graph
    }

    pub fn n_stages(&self) -> usize {
        self.ctx.stage_shard.len()
    }

    /// The replica pool of stage `s` (global stage index).
    pub fn stage(&self, s: usize) -> &ClusterWorker {
        let (si, li) = self.ctx.stage_shard[s];
        &self.shards[si].stages[li].cw
    }

    pub fn pending_transfer_count(&self) -> usize {
        self.pending_transfers.len()
    }

    pub fn replica(&self, s: usize, r: usize) -> &ReplicaWorker {
        &self.stage(s).replicas[r]
    }
}

impl Shard {
    /// Parallel phase: drain this shard's queue up to (excluding) the
    /// window horizon, touching only shard-local state.
    fn advance(&mut self, ctx: &RunCtx, t_end: SimTime) {
        while let Some(t) = self.queue.peek_time() {
            if t >= t_end {
                break;
            }
            let ev = self.queue.pop().expect("peeked");
            self.handle(ctx, ev.kind);
        }
    }

    /// Install fabric epoch `ei`'s state into this shard's cost
    /// models: the EP cross-cluster trunk's health feeds MoE
    /// dispatch/combine and expert-migration pricing from here on.
    /// No-op when the epoch is unchanged.
    fn apply_epoch(&mut self, ctx: &RunCtx, ei: usize) {
        if ei == self.cur_epoch {
            return;
        }
        self.cur_epoch = ei;
        let trunk = ctx.epochs[ei].state.ep_trunk_health();
        for st in &self.stages {
            st.ep_cost().set_ep_trunk_health(trunk);
        }
    }

    fn handle(&mut self, ctx: &RunCtx, ev: Ev) {
        match ev {
            Ev::Arrival(rid) => self.on_arrival(ctx, rid),
            Ev::IterEnd { s, r, gen } => self.on_iter_end(ctx, s, r, gen),
            Ev::KvDone { rid, s, r, gen } => self.on_kv_done(ctx, rid, s, r, gen),
            Ev::Fault { s, r, up } => self.on_fault(ctx, s, r, up),
            Ev::Retry(rid) => self.on_retry(ctx, rid),
            Ev::ScaleTick { s } => self.on_scale_tick(ctx, s),
            Ev::ScaleUp { s, r } => self.on_scale_up(ctx, s, r),
        }
    }

    // -- event handlers ----------------------------------------------------

    /// Whether a request needing `full_blocks` for its lifetime could
    /// ever be handed downstream from entry stage `gs` (admission
    /// control: a request that fits nowhere downstream would deadlock
    /// the PREFILL_COMPLETE queue). O(stages) via the per-stage
    /// capacity cache.
    fn fits_downstream(ctx: &RunCtx, gs: usize, full_blocks: u64) -> bool {
        let dsts = &ctx.kv_out[gs];
        dsts.is_empty() || dsts.iter().any(|&d| full_blocks <= ctx.stage_max_blocks[d])
    }

    fn on_arrival(&mut self, ctx: &RunCtx, rid: u64) {
        self.metrics.record_arrival(self.queue.now().as_secs_f64());
        let (input_len, output_len) = {
            let rq = self.store.get(rid);
            (rq.spec.input_len, rq.spec.output_len)
        };
        let full_blocks = blocks_for_tokens(input_len + output_len);
        // collect admissible (stage, replica) slots across entry stages
        // into reused scratch vectors (this path runs per arrival)
        let mut slots = std::mem::take(&mut self.scratch_slots);
        let mut loads = std::mem::take(&mut self.scratch_loads);
        let mut free = std::mem::take(&mut self.scratch_free);
        slots.clear();
        loads.clear();
        free.clear();
        let mut fits_any = false;
        for &s in &self.entry {
            let gs = self.gstage[s];
            let blocks_needed = match self.stages[s].cw.kind {
                // co-located replicas hold KV for the whole lifetime
                StageKind::Unified => full_blocks,
                // prefill stage holds KV only until handoff
                _ => blocks_for_tokens(input_len),
            };
            // O(stages) admission: replicas of a stage share capacity,
            // so the cached per-stage max stands in for the old
            // per-replica scan
            let fits_frontend = blocks_needed <= ctx.stage_max_blocks[gs];
            let fits_down = output_len <= 1 || Self::fits_downstream(ctx, gs, full_blocks);
            if !fits_frontend || !fits_down {
                continue;
            }
            fits_any = true;
            for (r, rep) in self.stages[s].cw.replicas.iter().enumerate() {
                // health-aware entry routing (vacuously true without
                // dynamics — every replica is alive)
                if !rep.alive() {
                    continue;
                }
                slots.push((s, r, blocks_needed));
                loads.push(rep.load());
                free.push(rep.mem.free_blocks());
            }
        }
        let choice = if slots.is_empty() {
            None
        } else {
            let mut rr = self.entry_rr;
            let i = scheduler::route(ctx.cfg.policy.route, &loads, &free, &mut rr);
            self.entry_rr = rr;
            Some(slots[i])
        };
        self.scratch_slots = slots;
        self.scratch_loads = loads;
        self.scratch_free = free;
        let Some((s, r, blocks_needed)) = choice else {
            if ctx.dyn_on && fits_any {
                // capacity exists but no healthy replica right now:
                // the fault path decides between backoff and rejection
                self.fault_retry_or_reject(ctx, rid);
            } else {
                self.store.remove(rid);
                self.metrics.rejected_requests += 1;
            }
            return;
        };
        let q = QueuedReq {
            id: rid,
            tokens_needed: input_len,
            blocks_needed,
            arrival: self.queue.now(),
        };
        self.stages[s].cw.replicas[r].waiting.push_back(q);
        self.try_start_iteration(ctx, s, r);
    }

    fn on_iter_end(&mut self, ctx: &RunCtx, s: usize, r: usize, gen: u32) {
        // stale incarnation: the replica failed after this iteration
        // started — its batch was requeued at the fault, the work lost
        if self.stages[s].cw.replicas[r].gen != gen {
            return;
        }
        let now = self.queue.now();
        let gs = self.gstage[s];
        let kind = self.stages[s].cw.kind;
        {
            let started = self.iter_started[s][r];
            let repl = &mut self.stages[s].cw.replicas[r];
            repl.busy = false;
            repl.iterations += 1;
            repl.busy_ns += (now - started).0;
        }
        self.metrics.iterations += 1;

        // take the batch vectors instead of cloning them: this handler
        // runs once per iteration, and a 1e6-request day runs tens of
        // millions of iterations
        let running: Vec<u64> = std::mem::take(&mut self.stages[s].cw.replicas[r].running);
        let chunks: Vec<u32> = std::mem::take(&mut self.stages[s].cw.replicas[r].iter_chunks);
        let mut finished: Vec<u64> = Vec::new();
        let mut to_transfer: Vec<u64> = Vec::new();

        for (i, &rid) in running.iter().enumerate() {
            let chunk = chunks.get(i).copied().unwrap_or(0);
            let (target, output_len) = {
                let rq = self.store.get(rid);
                (rq.prefill_target, rq.spec.output_len)
            };
            if chunk > 0 {
                // prefill progress
                let rq = self.store.get_mut(rid);
                rq.prefill_progress += chunk;
                self.metrics.prefill_tokens += chunk as u64;
                self.stages[s].cw.replicas[r].tokens_processed += chunk as u64;
                let rq = self.store.get(rid);
                if rq.prefill_progress >= target {
                    // prefill iteration emits the first output token —
                    // unless this is a fault-displaced re-prefill: the
                    // restored context's tokens were already counted
                    let rq = self.store.get_mut(rid);
                    rq.ts.prefill_done = Some(now);
                    rq.last_token = now;
                    if rq.ts.first_token.is_none() {
                        rq.ts.first_token = Some(now);
                        rq.decoded = 1;
                        self.metrics.output_tokens += 1;
                        let rq = self.store.get(rid);
                        let class = rq.spec.class;
                        let ttft = (now - rq.ts.arrival).as_secs_f64();
                        self.metrics.record_ttft(class, ttft, now.as_secs_f64());
                    }
                    let rq = self.store.get_mut(rid);
                    if rq.decoded >= output_len {
                        finished.push(rid);
                    } else if kind == StageKind::Prefill {
                        rq.state = ReqState::PrefillComplete;
                        to_transfer.push(rid);
                    } else {
                        rq.state = ReqState::Decoding;
                    }
                }
            } else {
                // decode step: one token
                let rq = self.store.get_mut(rid);
                rq.decoded += 1;
                self.metrics.output_tokens += 1;
                let class = rq.spec.class;
                let tbt = (now - rq.last_token).as_secs_f64();
                self.metrics.record_tbt(class, tbt, now.as_secs_f64());
                let rq = self.store.get_mut(rid);
                rq.last_token = now;
                self.stages[s].cw.replicas[r].tokens_processed += 1;
                if rq.decoded >= output_len {
                    finished.push(rid);
                }
            }
        }

        // retire finished requests
        if !finished.is_empty() {
            for &rid in &finished {
                let rq = self.store.get_mut(rid);
                rq.state = ReqState::Done;
                rq.ts.done = Some(now);
                let e2e = (now - rq.ts.arrival).as_secs_f64();
                let ttft = rq.ts.first_token.map_or(e2e, |ft| (ft - rq.ts.arrival).as_secs_f64());
                // mean inter-token gap over the request (SLO judgment)
                let tbt_mean = match (rq.ts.first_token, rq.decoded) {
                    (Some(ft), d) if d > 1 => (now - ft).as_secs_f64() / (d - 1) as f64,
                    _ => 0.0,
                };
                let (class, output_len, affected, link_affected) =
                    (rq.spec.class, rq.spec.output_len, rq.affected, rq.link_affected);
                self.metrics.record_completion(
                    class,
                    ttft,
                    tbt_mean,
                    e2e,
                    output_len,
                    now.as_secs_f64(),
                );
                if affected {
                    // per-fault SLO damage: did the displaced request
                    // still make its objectives?
                    let ok = self.metrics.slo.met(ttft, tbt_mean, e2e);
                    self.metrics.record_affected_completion(ok);
                }
                if link_affected {
                    // per-link-fault SLO damage: did the rerouted or
                    // stalled request still make its objectives?
                    let ok = self.metrics.slo.met(ttft, tbt_mean, e2e);
                    self.metrics.record_link_affected_completion(ok);
                }
                let freed = self.stages[s].cw.replicas[r].mem.free_request(rid);
                // KV-destination frees feed the barrier free-ledger so
                // dispatch ordering stays time-consistent
                if ctx.is_kv_dst[gs] {
                    self.commits.push(PbRec {
                        time: now,
                        kind: PbKind::Free { gstage: gs, replica: r, blocks: freed },
                    });
                }
                self.store.remove(rid);
            }
        }
        // hand prefill-complete requests to the controller's transfer
        // queue (by value — they leave this shard entirely)
        for &rid in &to_transfer {
            self.stages[s].cw.replicas[r].mem.free_request(rid);
            let req = self.store.remove(rid);
            self.commits.push(PbRec {
                time: now,
                kind: PbKind::Xfer { rid, src: gs, req: Box::new(req) },
            });
        }
        // give the batch vector back (minus retired ids), reusing its
        // allocation for the next iteration
        {
            let repl = &mut self.stages[s].cw.replicas[r];
            debug_assert!(repl.running.is_empty());
            repl.running = running;
            if !finished.is_empty() || !to_transfer.is_empty() {
                repl.running
                    .retain(|rid| !finished.contains(rid) && !to_transfer.contains(rid));
            }
        }
        // memory availability changed: the downstream ClusterScheduler
        // signals the controller (PD backpressure step 2/3). Transfers
        // always need a dispatch pass; bare completions only matter
        // when the graph has handoffs at all.
        if !to_transfer.is_empty() || (!finished.is_empty() && ctx.has_transfers) {
            self.commits.push(PbRec { time: now, kind: PbKind::Trigger });
        }
        // between iterations: the expert-migration control loop may
        // re-place experts (and stall this stage) before the next batch
        self.maybe_migrate(ctx, s);
        self.try_start_iteration(ctx, s, r);
    }

    /// Expert-migration control loop, run between iterations of stage
    /// `s`: once per load window, compare the tracked per-expert loads
    /// against the current placement; when the predicted rank imbalance
    /// clears the threshold, adopt the rebalanced placement, charge the
    /// expert weight moves through the EP fabric, and stall every
    /// replica of the stage for the transfer makespan. Entirely
    /// stage-internal (the EP fabric belongs to the stage), so it runs
    /// in the parallel phase and never constrains the sync window.
    fn maybe_migrate(&mut self, ctx: &RunCtx, s: usize) {
        if ctx.cfg.policy.migration != MigrationPolicy::Threshold {
            return;
        }
        let window = ctx.cfg.policy.load_window.max(1) as u64;
        let threshold = ctx.cfg.policy.migration_threshold;
        let placement_policy = ctx.cfg.policy.ep_placement;
        let last = self.stages[s].mig_last_draws;
        // read phase: estimator snapshot + weight footprint. The one
        // placement stands for every resident layer's FFN, so a move
        // copies the expert's weights for ALL of the stage's layers.
        let (draws, est, expert_bytes) = {
            let cost = self.stages[s].ep_cost();
            let Some(tracker) = cost.load_tracker.as_ref() else { return };
            let tracker = tracker.borrow();
            if tracker.draws() < last + window {
                return;
            }
            let layers = (cost.model.n_layers / cost.par.pp.max(1)).max(1) as f64;
            let per_expert = cost.model.expert_weight_bytes(cost.par.tp) * layers;
            (tracker.draws(), tracker.snapshot(), per_expert)
        };
        self.stages[s].mig_last_draws = draws;
        // the current fabric epoch's trunk health: expert weight moves
        // crossing clusters pay the degraded WAN trunk (HEALTHY — and
        // bit-identical to the undegraded charge — without link faults)
        let trunk = self.stages[s].ep_cost().ep_trunk_health();
        // plan + adopt phase
        let (phase, pre, post) = {
            let cost = self.stages[s].ep_cost_mut();
            let Some(eps) = cost.ep.as_mut() else { return };
            let plan = moe::plan_migration(&eps.placement, placement_policy, &est, threshold);
            let Some(plan) = plan else { return };
            let phase = moe::charge_migration_degraded(eps, &plan, expert_bytes, trunk);
            let moe::MigrationPlan { placement, pre_imbalance, post_imbalance, .. } = plan;
            eps.placement = placement;
            (phase, pre_imbalance, post_imbalance)
        };
        // every replica of the pool holds its own copy of the expert
        // weights, so a placement rewrite moves the plan's bytes once
        // per replica (replicas copy in parallel — each pays the same
        // makespan, which is why the stall below is also per replica)
        let replicas = self.stages[s].cw.replicas.len() as f64;
        self.metrics.record_migration(
            phase.total_bytes * replicas,
            phase.cross_bytes * replicas,
            pre,
            post,
        );
        for stall in &mut self.pending_stall[s] {
            *stall += phase.secs;
        }
    }

    fn on_kv_done(&mut self, ctx: &RunCtx, rid: u64, s: usize, r: usize, gen: u32) {
        {
            let repl = &mut self.stages[s].cw.replicas[r];
            // stale incarnation: the replica failed while this
            // transfer was in flight — the KV is gone and the fault
            // already requeued the request through the inbound list
            if repl.gen != gen {
                return;
            }
            repl.inbound.retain(|&x| x != rid);
        }
        let rq = self.store.get_mut(rid);
        rq.state = ReqState::Decoding;
        let q = QueuedReq {
            id: rid,
            tokens_needed: 0,
            blocks_needed: 0, // reserved at dispatch time
            arrival: self.queue.now(),
        };
        self.stages[s].cw.replicas[r].waiting.push_back(q);
        self.try_start_iteration(ctx, s, r);
    }

    // -- cluster dynamics handlers ------------------------------------------

    /// Reset a fault-displaced request for re-admission: its KV is
    /// gone, so it must re-prefill the original input *plus* every
    /// token it had already decoded (the recovered context). Arrival
    /// timestamps survive, so the fault's latency damage lands in the
    /// request's TTFT/E2E/SLO numbers.
    fn reset_for_requeue(req: &mut Request) {
        req.state = ReqState::Queued;
        req.prefill_progress = 0;
        req.prefill_target = req.spec.input_len + req.decoded;
        req.affected = true;
    }

    fn on_fault(&mut self, ctx: &RunCtx, s: usize, r: usize, up: bool) {
        if up {
            self.on_fault_up(ctx, s, r);
        } else {
            self.on_fault_down(ctx, s, r);
        }
    }

    /// A replica dies: bump its incarnation (in-flight `IterEnd` /
    /// `KvDone` events go stale), drop its KV, and requeue every
    /// request it held — running batch, waiting queue, and inbound
    /// transfers alike — back through the entry router.
    fn on_fault_down(&mut self, ctx: &RunCtx, s: usize, r: usize) {
        let now = self.queue.now();
        let gs = self.gstage[s];
        let mut rids: Vec<u64> = Vec::new();
        let mut freed_total = 0u64;
        {
            let repl = &mut self.stages[s].cw.replicas[r];
            if !repl.up {
                // already down (e.g. an overlapping explicit schedule)
                return;
            }
            repl.up = false;
            repl.draining = false;
            repl.provisioning = false;
            repl.busy = false;
            repl.gen = repl.gen.wrapping_add(1);
            repl.down_since = Some(now);
            rids.extend(repl.running.drain(..));
            rids.extend(repl.waiting.drain(..).map(|q| q.id));
            rids.extend(repl.inbound.drain(..));
            repl.iter_chunks.clear();
            for &rid in &rids {
                // free_request returns 0 for ids with no allocation
                // (waiting requests reserve at iteration start)
                freed_total += repl.mem.free_request(rid);
            }
        }
        self.metrics.record_fault();
        if ctx.is_kv_dst[gs] && freed_total > 0 {
            self.commits.push(PbRec {
                time: now,
                kind: PbKind::Free { gstage: gs, replica: r, blocks: freed_total },
            });
        }
        // entry stages live on shard 0, so a local requeue can schedule
        // the Retry directly; a KV-destination shard must route the
        // request back through the barrier (cross-shard commit), which
        // lands it no earlier than one sync window later
        let local = !self.entry.is_empty();
        let backoff = SimTime::from_secs_f64(dynamics::RECOVER_BACKOFF_S);
        for rid in rids {
            self.metrics.fault_requeues += 1;
            if local {
                Self::reset_for_requeue(self.store.get_mut(rid));
                self.queue.schedule_at(now + backoff, Ev::Retry(rid));
            } else {
                let mut req = self.store.remove(rid);
                Self::reset_for_requeue(&mut req);
                self.commits.push(PbRec {
                    time: now,
                    kind: PbKind::Requeue { rid, req: Box::new(req) },
                });
            }
        }
    }

    /// A failed replica comes back: empty (its KV died with it), so it
    /// just reports for duty and the transfer dispatcher is re-run.
    fn on_fault_up(&mut self, ctx: &RunCtx, s: usize, r: usize) {
        let now = self.queue.now();
        let gs = self.gstage[s];
        let repl = &mut self.stages[s].cw.replicas[r];
        let Some(since) = repl.down_since else {
            return;
        };
        repl.up = true;
        repl.down_since = None;
        self.metrics.record_fault_recovery((now - since).as_secs_f64());
        if ctx.is_kv_dst[gs] {
            // held transfers may now have a destination again
            self.commits.push(PbRec { time: now, kind: PbKind::Trigger });
        }
    }

    /// A fault-displaced request re-enters the entry router (shard 0
    /// only). Mirrors [`Shard::on_arrival`] except the admission size
    /// is the (possibly larger) re-prefill target and the arrival is
    /// not re-counted.
    fn on_retry(&mut self, ctx: &RunCtx, rid: u64) {
        let (target, output_len) = {
            let rq = self.store.get(rid);
            (rq.prefill_target, rq.spec.output_len)
        };
        let full_blocks = blocks_for_tokens(target + output_len);
        let mut slots = std::mem::take(&mut self.scratch_slots);
        let mut loads = std::mem::take(&mut self.scratch_loads);
        let mut free = std::mem::take(&mut self.scratch_free);
        slots.clear();
        loads.clear();
        free.clear();
        for &s in &self.entry {
            let gs = self.gstage[s];
            let blocks_needed = match self.stages[s].cw.kind {
                StageKind::Unified => full_blocks,
                _ => blocks_for_tokens(target),
            };
            let fits_frontend = blocks_needed <= ctx.stage_max_blocks[gs];
            let fits_down = output_len <= 1 || Self::fits_downstream(ctx, gs, full_blocks);
            if !fits_frontend || !fits_down {
                continue;
            }
            for (r, rep) in self.stages[s].cw.replicas.iter().enumerate() {
                if !rep.alive() {
                    continue;
                }
                slots.push((s, r, blocks_needed));
                loads.push(rep.load());
                free.push(rep.mem.free_blocks());
            }
        }
        let choice = if slots.is_empty() {
            None
        } else {
            let mut rr = self.entry_rr;
            let i = scheduler::route(ctx.cfg.policy.route, &loads, &free, &mut rr);
            self.entry_rr = rr;
            Some(slots[i])
        };
        self.scratch_slots = slots;
        self.scratch_loads = loads;
        self.scratch_free = free;
        let Some((s, r, blocks_needed)) = choice else {
            self.fault_retry_or_reject(ctx, rid);
            return;
        };
        let q = QueuedReq {
            id: rid,
            tokens_needed: target,
            blocks_needed,
            arrival: self.queue.now(),
        };
        self.stages[s].cw.replicas[r].waiting.push_back(q);
        self.try_start_iteration(ctx, s, r);
    }

    /// No healthy entry replica right now: back off and retry if
    /// recovery (a planned fault-up or an autoscale grow) is in sight,
    /// else reject with backpressure. Bounded by
    /// [`dynamics::MAX_RETRIES`] so a dead-forever pool can't loop.
    fn fault_retry_or_reject(&mut self, ctx: &RunCtx, rid: u64) {
        let now = self.queue.now();
        let mut revive: Option<SimTime> = None;
        let mut scalable = false;
        for &s in &self.entry {
            let gs = self.gstage[s];
            if ctx.revive_after[gs] > now {
                let ra = ctx.revive_after[gs];
                revive = Some(revive.map_or(ra, |cur| cur.min(ra)));
            }
            if ctx.cfg.autoscale.is_some() && ctx.governed[gs] {
                scalable = true;
            }
        }
        if self.store.get(rid).retries < dynamics::MAX_RETRIES && (revive.is_some() || scalable)
        {
            self.store.get_mut(rid).retries += 1;
            self.metrics.fault_retries += 1;
            // adaptive backoff: sleep until recovery is actually
            // possible instead of busy-polling a dead pool
            let mut at = now + SimTime::from_secs_f64(dynamics::RETRY_BACKOFF_S);
            if let Some(rv) = revive {
                at = at.max(rv);
            } else if let Some(a) = ctx.cfg.autoscale.as_ref() {
                at = at.max(now + SimTime::from_secs_f64(a.interval_s + a.provision_s));
            }
            self.queue.schedule_at(at, Ev::Retry(rid));
        } else {
            self.store.remove(rid);
            self.metrics.rejected_requests += 1;
            self.metrics.fault_rejected += 1;
        }
    }

    /// Autoscaler control-loop tick for a governed stage: read the
    /// queue-depth signal, grow (with provisioning delay + warmup
    /// stall) or shrink (drain, never kill). Ticks are pre-scheduled
    /// from the [`dynamics::DynPlan`], so their times are identical
    /// for any `--sim-threads`.
    fn on_scale_tick(&mut self, ctx: &RunCtx, s: usize) {
        let Some(a) = ctx.cfg.autoscale.as_ref() else { return };
        let now = self.queue.now();
        self.metrics.scale_ticks += 1;
        // retire drains that ran dry: the replica served out its work
        // and can now leave the pool
        for rep in self.stages[s].cw.replicas.iter_mut() {
            if rep.draining && !rep.busy && !rep.has_work() && rep.inbound.is_empty() {
                rep.draining = false;
                rep.up = false;
                self.metrics.scale_down_events += 1;
            }
        }
        let (mut waiting, mut alive, mut provisioning) = (0usize, 0usize, 0usize);
        for rep in &self.stages[s].cw.replicas {
            if rep.provisioning {
                provisioning += 1;
            }
            if rep.alive() {
                alive += 1;
                waiting += rep.waiting.len();
            }
        }
        let raw = match a.signal {
            dynamics::ScaleSignal::Queue => waiting as f64 / alive.max(1) as f64,
            // SLO-attainment signal: fraction of completions since the
            // last tick that missed a set SLO, from this shard's
            // streaming counters. No completions in the window: a
            // backed-up pool reads full miss, an idle one reads clean.
            dynamics::ScaleSignal::Slo => {
                let (done, ok) = (self.metrics.completed_requests, self.metrics.slo_ok);
                let st = &mut self.stages[s];
                let (dc, dok) = (done - st.prev_completed, ok - st.prev_slo_ok);
                st.prev_completed = done;
                st.prev_slo_ok = ok;
                if dc == 0 {
                    if waiting > 0 {
                        1.0
                    } else {
                        0.0
                    }
                } else {
                    (dc - dok) as f64 / dc as f64
                }
            }
        };
        let signal = match a.policy {
            dynamics::ScalePolicy::Reactive => raw,
            // first-order trend extrapolation: act on where the signal
            // will be next tick, not where it is
            dynamics::ScalePolicy::Predictive => raw + (raw - self.stages[s].q_prev),
        };
        self.stages[s].q_prev = raw;
        // emergency replacement: a pool at zero live capacity reads a
        // zero queue signal (nothing can enqueue on it), so it would
        // never grow and held transfers would stall the run forever
        let dead_pool = alive == 0 && provisioning == 0;
        if (signal > a.up_queue || dead_pool) && alive + provisioning < a.max_replicas as usize {
            // grow into the first free pre-provisioned slot (never one
            // that is faulted, draining, or already provisioning)
            let slot = self.stages[s].cw.replicas.iter().position(|rep| {
                !rep.up && !rep.provisioning && rep.down_since.is_none() && !rep.draining
            });
            if let Some(r) = slot {
                self.stages[s].cw.replicas[r].provisioning = true;
                self.queue
                    .schedule_at(now + SimTime::from_secs_f64(a.provision_s), Ev::ScaleUp { s, r });
            }
        } else if signal < a.down_queue && alive > a.min_replicas as usize {
            // shrink the least-loaded live replica by draining it; it
            // leaves once its queue runs dry (checked next tick)
            let mut best: Option<(usize, usize)> = None;
            for (r, rep) in self.stages[s].cw.replicas.iter().enumerate() {
                if !rep.alive() {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((_, l)) => rep.load() < l,
                };
                if better {
                    best = Some((r, rep.load()));
                }
            }
            if let Some((r, _)) = best {
                self.stages[s].cw.replicas[r].draining = true;
            }
        }
    }

    /// Provisioning finished: the new replica joins the pool, paying
    /// its warmup as a pending stall on its first iteration.
    fn on_scale_up(&mut self, ctx: &RunCtx, s: usize, r: usize) {
        let Some(a) = ctx.cfg.autoscale.as_ref() else { return };
        let gs = self.gstage[s];
        let repl = &mut self.stages[s].cw.replicas[r];
        if !repl.provisioning {
            // a fault hit the slot mid-provision; the grow is lost
            return;
        }
        repl.provisioning = false;
        repl.up = true;
        repl.draining = false;
        self.pending_stall[s][r] += a.warmup_s;
        self.metrics.scale_up_events += 1;
        if ctx.is_kv_dst[gs] {
            // held transfers may now have a destination
            self.commits.push(PbRec { time: self.queue.now(), kind: PbKind::Trigger });
        }
    }

    /// Form and launch the next iteration on a replica if it is idle and
    /// has work.
    fn try_start_iteration(&mut self, ctx: &RunCtx, s: usize, r: usize) {
        let kind = self.stages[s].cw.kind;
        let budget = self.stages[s].budget;
        let policy = ctx.cfg.policy.batch;
        let now = self.queue.now();
        let admitted = {
            let repl = &mut self.stages[s].cw.replicas[r];
            if !repl.up || repl.busy || !repl.has_work() {
                return;
            }
            // admissions (reserving memory)
            let free = repl.mem.free_blocks();
            let admitted =
                scheduler::admit(policy, &mut repl.waiting, repl.running.len(), &budget, free);
            for q in &admitted {
                if q.blocks_needed > 0 {
                    repl.mem.allocate(q.id, q.blocks_needed).expect("admit checked memory");
                }
                repl.running.push(q.id);
            }
            admitted
        };
        for q in &admitted {
            let rq = self.store.get_mut(q.id);
            if rq.state == ReqState::Queued {
                rq.state = ReqState::Prefilling;
            }
            // per-class admission-queue wait: entry queueing and
            // decode-side KV-done queueing both count as admissions
            let class = rq.spec.class;
            self.metrics.record_queue_wait(class, (now - q.arrival).as_secs_f64());
        }
        // build the batch shape (reading the running set in place — the
        // pre-digest code cloned it every iteration)
        if self.stages[s].cw.replicas[r].running.is_empty() {
            return;
        }
        let mut shape = BatchShape::default();
        let mut chunks = std::mem::take(&mut self.stages[s].cw.replicas[r].iter_chunks);
        chunks.clear();
        let mut token_budget = budget.max_prefill_tokens;
        for &rid in &self.stages[s].cw.replicas[r].running {
            let rq = self.store.get(rid);
            // prefill_target == input_len except for fault-displaced
            // requests re-computing their lost context
            if rq.prefill_progress < rq.prefill_target {
                let remaining = rq.prefill_target - rq.prefill_progress;
                let chunk = remaining.min(token_budget);
                if chunk > 0 {
                    shape.prefill.push((chunk, rq.prefill_progress));
                    token_budget -= chunk;
                    if rq.prefill_progress + chunk >= rq.prefill_target {
                        shape.lm_head_rows += 1; // emits first token
                    }
                }
                chunks.push(chunk);
            } else {
                shape.decode_ctx.push(rq.spec.input_len + rq.decoded);
                shape.lm_head_rows += 1;
                chunks.push(0);
            }
        }
        if shape.is_empty() {
            return;
        }
        let dt = if kind == StageKind::AfDecode {
            self.af_iteration_time(s, &shape)
        } else {
            let st = &mut self.stages[s];
            let mut cctx = CostCtx {
                pred: st.pred.as_mut(),
                rng: &mut self.rng,
                metrics: Some(&mut self.metrics),
            };
            st.cost.iteration_time(&mut cctx, &shape)
        };
        debug_assert!(dt > 0.0);
        // pending expert-migration stall: the replica's EP ranks were
        // busy receiving weights, so its next iteration starts late.
        // Metered here — at the moment the delay is actually paid — so
        // a migration adopted after the final iteration reports none.
        let stall = std::mem::take(&mut self.pending_stall[s][r]);
        self.metrics.migration_stall_s += stall;
        let repl = &mut self.stages[s].cw.replicas[r];
        repl.busy = true;
        repl.iter_chunks = chunks;
        let gen = repl.gen;
        self.iter_started[s][r] = now;
        self.queue.schedule_in(SimTime::from_secs_f64(dt + stall), Ev::IterEnd { s, r, gen });
    }

    /// AF decode step: partition the batch into micro-batches and run
    /// the dependency-graph executor. On the MoE path every
    /// `(layer, micro)` cell is data-dependent: a fresh routing draw
    /// sets the per-rank expert loads (stragglers) *and* the
    /// dispatch/combine transfer times through the EP fabric — priced
    /// in one batched pass per micro ([`CostModel::moe_ffn_ep_batch`]:
    /// `n_layers` draws, draw-invariant ops priced once). The attn/ffn
    /// cost models were built once at controller construction.
    fn af_iteration_time(&mut self, s: usize, shape: &BatchShape) -> f64 {
        let Shard { stages, rng, metrics, ep_samples, .. } = self;
        let st = &mut stages[s];
        let afr = st.af.as_ref().expect("af runtime on AF stage");
        let m = (afr.micro_batches as usize).max(1).min(shape.decode_ctx.len().max(1));
        let attn_cost = &afr.attn_cost;
        let ffn_cost = &afr.ffn_cost;
        let model = &attn_cost.model;
        let ep_active = ffn_cost.ep.is_some();

        // round-robin partition of decode sequences
        let mut micro_ctx: Vec<Vec<u32>> = vec![Vec::new(); m];
        for (i, &ctx) in shape.decode_ctx.iter().enumerate() {
            micro_ctx[i % m].push(ctx);
        }
        // prefill chunks (if the AF pool also prefills) ride micro 0
        let micro0_prefill = shape.prefill.clone();

        let layers = model.n_layers as usize;
        let d_bytes = model.d_model as f64 * model.dtype_bytes as f64;
        let mut attn_time = vec![vec![0.0f64; m]; layers];
        let mut ffn_time = vec![vec![0.0f64; m]; layers];
        let mut a2f_time = vec![vec![0.0f64; m]; layers];
        let mut f2a_time = vec![vec![0.0f64; m]; layers];
        for (k, ctxs) in micro_ctx.iter().enumerate() {
            let micro_shape = BatchShape {
                prefill: if k == 0 { micro0_prefill.clone() } else { vec![] },
                decode_ctx: ctxs.clone(),
                lm_head_rows: 0,
            };
            let micro_tokens = micro_shape.total_tokens() as u64;
            if micro_shape.is_empty() {
                continue;
            }
            let t_attn = {
                let mut cctx = CostCtx {
                    pred: st.pred.as_mut(),
                    rng: &mut *rng,
                    metrics: Some(&mut *metrics),
                };
                attn_cost.attn_block_time(&mut cctx, &micro_shape)
            };
            for row in attn_time.iter_mut() {
                row[k] = t_attn;
            }
            // dense fallback: point-to-point hop sized by this micro-batch
            let xfer = crate::oracle::p2p_time(micro_tokens as f64 * d_bytes, &attn_cost.link);
            if ep_active {
                // one batched pricing pass: `layers` fresh routing
                // draws, bit-identical to per-layer calls
                let mut cctx = CostCtx {
                    pred: st.pred.as_mut(),
                    rng: &mut *rng,
                    metrics: Some(&mut *metrics),
                };
                ffn_cost
                    .moe_ffn_ep_batch(&mut cctx, micro_tokens, layers, ep_samples)
                    .expect("ep spec attached and micro-batch non-empty");
                for l in 0..layers {
                    let sample = ep_samples[l];
                    ffn_time[l][k] = sample.ffn_secs;
                    a2f_time[l][k] = sample.dispatch_secs;
                    f2a_time[l][k] = sample.combine_secs;
                }
            } else {
                for l in 0..layers {
                    // fresh routing per layer: data-dependent straggler noise
                    let mut cctx = CostCtx {
                        pred: st.pred.as_mut(),
                        rng: &mut *rng,
                        metrics: Some(&mut *metrics),
                    };
                    ffn_time[l][k] = ffn_cost.ffn_block_time(&mut cctx, micro_tokens);
                    a2f_time[l][k] = xfer;
                    f2a_time[l][k] = xfer;
                }
            }
        }
        let step = AfStep { attn_time, ffn_time, a2f_time, f2a_time };
        let (t_graph, busy) = af_step(&step);
        if ep_active {
            // FFN-pool idle time inside the step: dispatch bubbles the
            // ping-pong pipeline failed to hide
            metrics.dispatch_bubble_s += (t_graph - busy[1]).max(0.0);
        }
        let lm_head = {
            let mut cctx = CostCtx {
                pred: st.pred.as_mut(),
                rng: &mut *rng,
                metrics: Some(&mut *metrics),
            };
            attn_cost.lm_head_time(&mut cctx, shape.lm_head_rows as u64)
        };
        let o = &st.cost.overhead;
        o.sched_overhead_s + layers as f64 * o.launch_gap_s + o.op_scale * (t_graph + lm_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::predictor::PredictorKind;
    use crate::workload::WorkloadSpec;

    fn tiny_cfg(mode_requests: u32) -> ExperimentConfig {
        ExperimentConfig::colocated(ModelConfig::tiny(), 2)
            .with_workload(WorkloadSpec::table2(mode_requests, 64, 16))
            .with_predictor(PredictorKind::Oracle)
    }

    #[test]
    fn colocated_completes_all_requests() {
        let report = run(&tiny_cfg(32)).unwrap();
        assert_eq!(report.metrics.completed_requests, 32);
        assert_eq!(report.metrics.rejected_requests, 0);
        assert_eq!(report.metrics.output_tokens, 32 * 16);
        assert!(report.sim_duration > 0.0);
        assert!(report.metrics.ttft.count() == 32);
        // the 1-stage graph reports itself
        assert_eq!(report.stages.len(), 1);
        assert_eq!(report.stages[0].kind, "unified");
        assert!(report.stages[0].iterations > 0);
    }

    #[test]
    fn pd_completes_all_requests_with_transfers() {
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
            .with_workload(WorkloadSpec::table2(24, 64, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 24);
        // every multi-token request crosses the PD boundary once
        assert_eq!(report.metrics.kv_transfers, 24);
        assert!(report.metrics.kv_bytes > 0.0);
        assert_eq!(report.stages.len(), 2);
        assert_eq!(report.stages[0].kind, "prefill");
        assert_eq!(report.stages[1].kind, "decode");
    }

    #[test]
    fn af_mode_runs() {
        let cfg = ExperimentConfig::af(ModelConfig::tiny(), 1, 2, 2, 2)
            .with_workload(WorkloadSpec::table2(8, 32, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
    }

    #[test]
    fn deterministic_under_seed() {
        let a = run(&tiny_cfg(16)).unwrap();
        let b = run(&tiny_cfg(16)).unwrap();
        assert_eq!(a.sim_duration, b.sim_duration);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.metrics.output_tokens, b.metrics.output_tokens);
    }

    #[test]
    fn single_token_outputs_skip_transfer() {
        let mut w = WorkloadSpec::table2(8, 64, 1);
        w.output = crate::workload::LenDist::Fixed(1);
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1).with_workload(w);
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
        assert_eq!(report.metrics.kv_transfers, 0); // done at prefill
    }

    #[test]
    fn oversized_request_rejected() {
        let mut w = WorkloadSpec::table2(4, 64, 8);
        w.input = crate::workload::LenDist::Fixed(100_000_000);
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 1).with_workload(w);
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.rejected_requests, 4);
        assert_eq!(report.metrics.completed_requests, 0);
    }

    #[test]
    fn ttft_precedes_e2e() {
        let report = run(&tiny_cfg(16)).unwrap();
        assert!(report.metrics.ttft.mean() < report.metrics.e2e.mean());
    }

    #[test]
    fn moe_model_runs_colocated() {
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
            .with_parallelism(crate::parallelism::Parallelism::new(1, 1, 2))
            .with_workload(WorkloadSpec::table2(8, 32, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
        assert!(report.metrics.op_time.contains_key("grouped_gemm"));
    }

    #[test]
    fn controller_exposes_stage_pools() {
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 2, 1)
            .with_workload(WorkloadSpec::table2(4, 32, 4));
        let gc = GlobalController::new(cfg).unwrap();
        assert_eq!(gc.n_stages(), 2);
        assert_eq!(gc.stage(0).kind, StageKind::Prefill);
        assert_eq!(gc.stage(0).replicas.len(), 2);
        assert_eq!(gc.stage(1).kind, StageKind::Decode);
        assert_eq!(gc.pending_transfer_count(), 0);
        assert!(!gc.replica(1, 0).busy);
        assert_eq!(gc.stage_graph().kv_out(0), vec![1]);
    }

    #[test]
    fn shard_partition_groups_entry_stages() {
        // colocated: one unified entry stage -> one shard
        let gc = GlobalController::new(tiny_cfg(4)).unwrap();
        assert_eq!(gc.shards.len(), 1);
        assert_eq!(gc.shards[0].entry.len(), 1);
        // PD: prefill rides shard 0, the decode destination gets its own
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 2)
            .with_workload(WorkloadSpec::table2(4, 32, 4));
        let gc = GlobalController::new(cfg).unwrap();
        assert_eq!(gc.shards.len(), 2);
        assert_eq!(gc.ctx.stage_shard[0], (0, 0));
        assert_eq!(gc.ctx.stage_shard[1], (1, 0));
        assert!(gc.ctx.is_kv_dst[1] && !gc.ctx.is_kv_dst[0]);
        assert!(gc.ctx.has_transfers);
    }

    #[test]
    fn admission_capacity_cache_matches_pools() {
        // the S1 cache must agree with a fresh scan of every pool —
        // admission consults only the cache (O(stages) per arrival)
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 2, 3)
            .with_workload(WorkloadSpec::table2(4, 32, 4));
        let gc = GlobalController::new(cfg).unwrap();
        for s in 0..gc.n_stages() {
            let expect =
                gc.stage(s).replicas.iter().map(|rep| rep.mem.total_blocks()).max().unwrap();
            assert_eq!(gc.ctx.stage_max_blocks[s], expect, "stage {s}");
            assert!(expect > 0);
        }
    }

    #[test]
    fn sim_threads_is_bit_identical_to_serial() {
        // multi-shard graph: same seed, 1 vs 4 threads (oversubscribed:
        // only 2 shards exist) must produce byte-identical reports
        let mk = |threads: u32| {
            ExperimentConfig::pd(ModelConfig::tiny(), 2, 2)
                .with_workload(WorkloadSpec::table2(24, 64, 8))
                .with_sim_threads(threads)
        };
        let a = run(&mk(1)).unwrap();
        let b = run(&mk(4)).unwrap();
        assert_eq!(
            a.to_json_deterministic().to_string_pretty(),
            b.to_json_deterministic().to_string_pretty()
        );
    }

    #[test]
    fn queue_wait_is_recorded_per_admission() {
        let report = run(&tiny_cfg(16)).unwrap();
        // every admitted request waited in an entry queue at least once
        assert!(report.metrics.queue_wait.count() >= 16);
        assert!(report.metrics.queue_wait.quantile(99.0) >= 0.0);
    }
}
