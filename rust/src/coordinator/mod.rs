//! The GlobalController: stateful orchestrator of inter-stage workflows
//! (§3.1).
//!
//! Owns the event engine, the request lifecycle state machine, and the
//! cluster workers. Mode-specific coordination:
//!
//! * **Co-located** — continuous batching on unified replicas.
//! * **PD** — producer/consumer with system-level backpressure: the
//!   controller queues `PREFILL_COMPLETE` requests and initiates
//!   `KV_CACHE_TRANSFER` only when the decode stage signals memory
//!   availability (§3.3 PD steps 1-3).
//! * **AF** — the decode pool is an attention/FFN pair whose step time
//!   comes from the event-dependency-graph executor
//!   ([`crate::workflows::af`]).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::cluster::{ClusterWorker, ReplicaWorker, StageKind};
use crate::config::{DeploymentMode, ExperimentConfig};
use crate::core::{EventQueue, Pcg64, SimTime};
use crate::memory::{blocks_for_tokens, BlockManager};
use crate::metrics::{MetricsCollector, ReqTimestamps, SimReport};
use crate::moe::{self, EpSpec, EpTopology, ExpertPlacement};
use crate::network::Fabric;
use crate::predictor::{self, ExecutionPredictor};
use crate::scheduler::{self, QueuedReq};
use crate::workflows::af::{af_step, AfStep};
use crate::workflows::{BatchShape, CostCtx, CostModel};
use crate::workload::RequestSpec;

/// Request lifecycle states (§3.3's stateful workflow).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqState {
    Queued,
    Prefilling,
    PrefillComplete,
    Transferring,
    Decoding,
    Done,
    Rejected,
}

#[derive(Clone, Debug)]
pub struct Request {
    pub spec: RequestSpec,
    pub state: ReqState,
    /// Prefill tokens completed so far (chunked prefill).
    pub prefill_progress: u32,
    /// Output tokens generated so far.
    pub decoded: u32,
    pub ts: ReqTimestamps,
    pub last_token: SimTime,
}

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(u64),
    IterEnd { c: usize, r: usize },
    KvDone { rid: u64, c: usize, r: usize },
}

/// AF decode-pool parameters.
#[derive(Clone, Copy, Debug)]
struct AfParams {
    micro_batches: u32,
    attn_gpus: u32,
    ffn_gpus: u32,
}

pub struct GlobalController {
    cfg: ExperimentConfig,
    queue: EventQueue<Ev>,
    reqs: Vec<Request>,
    clusters: Vec<ClusterWorker>,
    fabric: Fabric,
    pred: Box<dyn ExecutionPredictor>,
    rng: Pcg64,
    metrics: MetricsCollector,
    /// PREFILL_COMPLETE requests awaiting a KV transfer slot.
    pending_transfers: VecDeque<u64>,
    cost: CostModel,
    af: Option<AfParams>,
    /// Expert placement for the AF FFN pool (static per run; built once).
    af_ep: Option<EpSpec>,
    /// Iteration start times per (cluster, replica) for busy accounting.
    iter_started: Vec<Vec<SimTime>>,
}

/// Convenience: build + run.
pub fn run(cfg: &ExperimentConfig) -> Result<SimReport> {
    GlobalController::new(cfg.clone())?.run()
}

impl GlobalController {
    pub fn new(cfg: ExperimentConfig) -> Result<Self> {
        cfg.validate()?;
        let pred = predictor::build(cfg.predictor, cfg.artifacts_dir.as_deref())?;
        let model = &cfg.model;
        let par = cfg.parallel;
        let gpus_per_replica = par.gpus_per_replica();
        let replica_mem = || -> BlockManager {
            BlockManager::from_budget(
                cfg.gpu.hbm_capacity * gpus_per_replica as u64,
                model.weight_bytes_per_gpu(par.tp, par.ep) * gpus_per_replica as u64,
                model.kv_bytes_per_token(),
                cfg.policy.kv_reserve_frac,
            )
        };
        let clusters = match cfg.mode {
            DeploymentMode::Colocated { replicas } => vec![ClusterWorker::new(
                StageKind::Unified,
                replicas,
                gpus_per_replica,
                replica_mem(),
            )],
            DeploymentMode::PdDisagg { prefill_replicas, decode_replicas } => vec![
                ClusterWorker::new(
                    StageKind::Prefill,
                    prefill_replicas,
                    gpus_per_replica,
                    replica_mem(),
                ),
                ClusterWorker::new(
                    StageKind::Decode,
                    decode_replicas,
                    gpus_per_replica,
                    replica_mem(),
                ),
            ],
            DeploymentMode::AfDisagg { prefill_replicas, attn_gpus, ffn_gpus, .. } => {
                // KV lives on the attention side of the AF pair; roughly
                // half the weights (attention stack) sit with it.
                let af_mem = BlockManager::from_budget(
                    cfg.gpu.hbm_capacity * attn_gpus as u64,
                    model.param_count() * model.dtype_bytes as u64 / 2,
                    model.kv_bytes_per_token(),
                    cfg.policy.kv_reserve_frac,
                );
                vec![
                    ClusterWorker::new(
                        StageKind::Prefill,
                        prefill_replicas,
                        gpus_per_replica,
                        replica_mem(),
                    ),
                    ClusterWorker::new(StageKind::AfDecode, 1, attn_gpus + ffn_gpus, af_mem),
                ]
            }
        };
        let af = match cfg.mode {
            DeploymentMode::AfDisagg { attn_gpus, ffn_gpus, micro_batches, .. } => {
                Some(AfParams { micro_batches, attn_gpus, ffn_gpus })
            }
            _ => None,
        };
        // EP placement over `ranks` expert ranks spanning `ep_clusters`
        // clusters. The replicated-hot policy targets the experts a
        // deterministic warmup routing draw marks hottest — with the
        // stable skewed-popularity model this is the run's actual hot
        // set (see `moe::expert_popularity`).
        let make_ep = |ranks: u32| -> Option<EpSpec> {
            let moe = model.moe.as_ref()?;
            if ranks <= 1 {
                return None;
            }
            let mut warmup = Pcg64::new(cfg.seed ^ 0x9E37_79B9);
            let hint = moe::assign_tokens(
                cfg.policy.moe_routing,
                4096,
                moe.n_experts,
                moe.top_k,
                &mut warmup,
            );
            Some(EpSpec {
                placement: ExpertPlacement::build(
                    cfg.policy.ep_placement,
                    moe.n_experts,
                    EpTopology::new(ranks, cfg.ep_clusters),
                    Some(&hint),
                ),
                intra: cfg.link,
                cross: cfg.cross_link,
            })
        };
        // AF mode: the FFN pool is the EP domain and the a2f/f2a hops
        // become the EP dispatch/combine phases
        let af_ep = af.and_then(|p| make_ep(p.ffn_gpus));
        let mut cost = CostModel::new(model.clone(), par, cfg.link);
        cost.moe_routing = cfg.policy.moe_routing;
        cost.straggler_max = cfg.policy.straggler_max;
        cost.overhead = cfg.overhead;
        // co-located / PD: replica-level EP ranks
        cost.ep = make_ep(par.ep);
        let iter_started = clusters
            .iter()
            .map(|c| vec![SimTime::ZERO; c.replicas.len()])
            .collect();
        Ok(GlobalController {
            queue: EventQueue::new(),
            reqs: Vec::new(),
            clusters,
            fabric: Fabric::new(cfg.link),
            pred,
            rng: Pcg64::new(cfg.seed),
            metrics: MetricsCollector::default(),
            pending_transfers: VecDeque::new(),
            cost,
            af,
            af_ep,
            iter_started,
            cfg,
        })
    }

    /// Execute the configured workload to completion.
    pub fn run(self) -> Result<SimReport> {
        let trace = self.cfg.workload.generate();
        self.run_with_trace(trace)
    }

    /// Execute an explicit request trace (trace replay) to completion.
    pub fn run_with_trace(mut self, trace: Vec<RequestSpec>) -> Result<SimReport> {
        let host_start = std::time::Instant::now();
        for spec in trace {
            let rid = self.reqs.len() as u64;
            self.reqs.push(Request {
                ts: ReqTimestamps { arrival: spec.arrival, ..Default::default() },
                spec,
                state: ReqState::Queued,
                prefill_progress: 0,
                decoded: 0,
                last_token: SimTime::ZERO,
            });
            self.queue.schedule_at(self.reqs[rid as usize].spec.arrival, Ev::Arrival(rid));
        }
        while let Some(ev) = self.queue.pop() {
            match ev.kind {
                Ev::Arrival(rid) => self.on_arrival(rid),
                Ev::IterEnd { c, r } => self.on_iter_end(c, r),
                Ev::KvDone { rid, c, r } => self.on_kv_done(rid, c, r),
            }
        }
        let unfinished = self
            .reqs
            .iter()
            .filter(|r| !matches!(r.state, ReqState::Done | ReqState::Rejected))
            .count();
        if unfinished > 0 {
            bail!("simulation stalled with {unfinished} unfinished requests");
        }
        self.metrics.predictor_evals = self.pred.evals();
        Ok(SimReport {
            mode: self.cfg.mode.name().to_string(),
            predictor: self.pred.name().to_string(),
            sim_duration: self.queue.now().as_secs_f64(),
            host_duration: host_start.elapsed().as_secs_f64(),
            events_processed: self.queue.processed(),
            n_gpus: self.cfg.n_gpus(),
            metrics: self.metrics,
        })
    }

    // -- event handlers ----------------------------------------------------

    fn on_arrival(&mut self, rid: u64) {
        let req = &self.reqs[rid as usize];
        let target_cluster = 0usize; // Unified or Prefill frontend
        let kind = self.clusters[target_cluster].kind;
        let blocks_needed = match kind {
            // co-located replicas hold KV for the whole lifetime
            StageKind::Unified => blocks_for_tokens(req.spec.input_len + req.spec.output_len),
            // prefill stage holds KV only until handoff
            _ => blocks_for_tokens(req.spec.input_len),
        };
        // admission control: the request must fit its frontend replica's
        // pool AND — for disaggregated modes — the downstream decode pool
        // (otherwise it could never be transferred and would deadlock the
        // controller's PREFILL_COMPLETE queue)
        let fits_frontend =
            blocks_needed <= self.clusters[target_cluster].replicas[0].mem.total_blocks();
        let fits_downstream = self.clusters.len() < 2
            || req.spec.output_len <= 1
            || blocks_for_tokens(req.spec.input_len + req.spec.output_len)
                <= self.clusters[1].replicas[0].mem.total_blocks();
        if !fits_frontend || !fits_downstream {
            self.reqs[rid as usize].state = ReqState::Rejected;
            self.metrics.rejected_requests += 1;
            return;
        }
        let cw = &self.clusters[target_cluster];
        let loads = cw.loads();
        let free = cw.free_blocks();
        let mut rr = cw.rr_cursor;
        let r = scheduler::route(self.cfg.policy.route, &loads, &free, &mut rr);
        self.clusters[target_cluster].rr_cursor = rr;
        let q = QueuedReq {
            id: rid,
            tokens_needed: self.reqs[rid as usize].spec.input_len,
            blocks_needed,
            arrival: self.queue.now(),
        };
        self.clusters[target_cluster].replicas[r].waiting.push_back(q);
        self.try_start_iteration(target_cluster, r);
    }

    fn on_iter_end(&mut self, c: usize, r: usize) {
        let now = self.queue.now();
        let kind = self.clusters[c].kind;
        {
            let started = self.iter_started[c][r];
            let repl = &mut self.clusters[c].replicas[r];
            repl.busy = false;
            repl.iterations += 1;
            repl.busy_ns += (now - started).0;
        }
        self.metrics.iterations += 1;

        let running: Vec<u64> = self.clusters[c].replicas[r].running.clone();
        let chunks: Vec<u32> = self.clusters[c].replicas[r].iter_chunks.clone();
        let mut finished: Vec<u64> = Vec::new();
        let mut to_transfer: Vec<u64> = Vec::new();

        for (i, &rid) in running.iter().enumerate() {
            let chunk = chunks.get(i).copied().unwrap_or(0);
            let (input_len, output_len) = {
                let rq = &self.reqs[rid as usize];
                (rq.spec.input_len, rq.spec.output_len)
            };
            if chunk > 0 {
                // prefill progress
                let rq = &mut self.reqs[rid as usize];
                rq.prefill_progress += chunk;
                self.metrics.prefill_tokens += chunk as u64;
                self.clusters[c].replicas[r].tokens_processed += chunk as u64;
                if rq.prefill_progress >= input_len {
                    // prefill iteration emits the first output token
                    rq.ts.prefill_done = Some(now);
                    rq.ts.first_token = Some(now);
                    rq.last_token = now;
                    rq.decoded = 1;
                    self.metrics.output_tokens += 1;
                    self.metrics.ttft.push((now - rq.ts.arrival).as_secs_f64());
                    if rq.decoded >= output_len {
                        finished.push(rid);
                    } else if kind == StageKind::Prefill {
                        rq.state = ReqState::PrefillComplete;
                        to_transfer.push(rid);
                    } else {
                        rq.state = ReqState::Decoding;
                    }
                }
            } else {
                // decode step: one token
                let rq = &mut self.reqs[rid as usize];
                rq.decoded += 1;
                self.metrics.output_tokens += 1;
                self.metrics.tbt.push((now - rq.last_token).as_secs_f64());
                rq.last_token = now;
                self.clusters[c].replicas[r].tokens_processed += 1;
                if rq.decoded >= output_len {
                    finished.push(rid);
                }
            }
        }

        // retire finished requests
        if !finished.is_empty() {
            for &rid in &finished {
                let rq = &mut self.reqs[rid as usize];
                rq.state = ReqState::Done;
                rq.ts.done = Some(now);
                let e2e = (now - rq.ts.arrival).as_secs_f64();
                self.metrics.e2e.push(e2e);
                self.metrics.norm_latency.push(e2e / rq.spec.output_len.max(1) as f64);
                self.metrics.completed_requests += 1;
                self.clusters[c].replicas[r].mem.free_request(rid);
                self.clusters[c].replicas[r].running.retain(|&x| x != rid);
            }
        }
        // hand prefill-complete requests to the controller's transfer queue
        for &rid in &to_transfer {
            self.clusters[c].replicas[r].mem.free_request(rid);
            self.clusters[c].replicas[r].running.retain(|&x| x != rid);
            self.pending_transfers.push_back(rid);
        }
        if !to_transfer.is_empty() || !finished.is_empty() {
            // memory availability changed: the decode ClusterScheduler
            // signals the controller (PD backpressure step 2/3)
            self.try_dispatch_transfers();
        }
        self.try_start_iteration(c, r);
    }

    fn on_kv_done(&mut self, rid: u64, c: usize, r: usize) {
        let rq = &mut self.reqs[rid as usize];
        rq.state = ReqState::Decoding;
        let q = QueuedReq {
            id: rid,
            tokens_needed: 0,
            blocks_needed: 0, // reserved at dispatch time
            arrival: self.queue.now(),
        };
        self.clusters[c].replicas[r].waiting.push_back(q);
        self.try_start_iteration(c, r);
    }

    // -- coordination ------------------------------------------------------

    /// PD backpressure: initiate KV transfers only into replicas with
    /// free memory, FIFO over the PREFILL_COMPLETE queue.
    fn try_dispatch_transfers(&mut self) {
        if self.clusters.len() < 2 {
            return;
        }
        let dc = 1usize;
        let now = self.queue.now();
        while let Some(&rid) = self.pending_transfers.front() {
            let (input_len, output_len) = {
                let rq = &self.reqs[rid as usize];
                (rq.spec.input_len, rq.spec.output_len)
            };
            let blocks = blocks_for_tokens(input_len + output_len);
            // defensive: a request no replica could EVER hold must not
            // block the queue head (admission control should prevent this)
            if self.clusters[dc]
                .replicas
                .iter()
                .all(|rep| blocks > rep.mem.total_blocks())
            {
                self.pending_transfers.pop_front();
                self.reqs[rid as usize].state = ReqState::Rejected;
                self.metrics.rejected_requests += 1;
                continue;
            }
            // choose the replica with the most free memory that fits
            let candidates = self.clusters[dc].free_blocks();
            let mut best: Option<(usize, u64)> = None;
            for (i, &free) in candidates.iter().enumerate() {
                if free >= blocks && best.map_or(true, |(_, b)| free > b) {
                    best = Some((i, free));
                }
            }
            let Some((r, _)) = best else {
                break; // backpressure: no consumer memory, hold the queue
            };
            self.pending_transfers.pop_front();
            self.clusters[dc].replicas[r]
                .mem
                .allocate(rid, blocks)
                .expect("reserved blocks must fit");
            let bytes = input_len as f64 * self.cost.model.kv_bytes_per_token() as f64;
            // one directed link per cluster pair models the NIC path
            let delivery = self.fabric.transfer(now, 0, dc as u32, bytes);
            self.metrics.kv_transfers += 1;
            self.metrics.kv_bytes += bytes;
            self.reqs[rid as usize].state = ReqState::Transferring;
            self.queue.schedule_at(delivery, Ev::KvDone { rid, c: dc, r });
        }
    }

    /// Form and launch the next iteration on a replica if it is idle and
    /// has work.
    fn try_start_iteration(&mut self, c: usize, r: usize) {
        let kind = self.clusters[c].kind;
        let budget = self.cfg.policy.budget;
        let policy = self.cfg.policy.batch;
        {
            let repl = &mut self.clusters[c].replicas[r];
            if repl.busy || !repl.has_work() {
                return;
            }
            // admissions (reserving memory)
            let free = repl.mem.free_blocks();
            let admitted = scheduler::admit(policy, &mut repl.waiting, repl.running.len(), &budget, free);
            for q in &admitted {
                if q.blocks_needed > 0 {
                    repl.mem.allocate(q.id, q.blocks_needed).expect("admit checked memory");
                }
                repl.running.push(q.id);
            }
            for q in &admitted {
                let rq = &mut self.reqs[q.id as usize];
                if rq.state == ReqState::Queued {
                    rq.state = ReqState::Prefilling;
                }
            }
        }
        // build the batch shape
        let running = self.clusters[c].replicas[r].running.clone();
        if running.is_empty() {
            return;
        }
        let mut shape = BatchShape::default();
        let mut chunks = Vec::with_capacity(running.len());
        let mut token_budget = budget.max_prefill_tokens;
        for &rid in &running {
            let rq = &self.reqs[rid as usize];
            if rq.prefill_progress < rq.spec.input_len {
                let remaining = rq.spec.input_len - rq.prefill_progress;
                let chunk = remaining.min(token_budget);
                if chunk > 0 {
                    shape.prefill.push((chunk, rq.prefill_progress));
                    token_budget -= chunk;
                    if rq.prefill_progress + chunk >= rq.spec.input_len {
                        shape.lm_head_rows += 1; // emits first token
                    }
                }
                chunks.push(chunk);
            } else {
                shape.decode_ctx.push(rq.spec.input_len + rq.decoded);
                shape.lm_head_rows += 1;
                chunks.push(0);
            }
        }
        if shape.is_empty() {
            return;
        }
        let dt = if kind == StageKind::AfDecode {
            self.af_iteration_time(&shape)
        } else {
            let mut ctx = CostCtx {
                pred: self.pred.as_mut(),
                rng: &mut self.rng,
                metrics: Some(&mut self.metrics),
            };
            self.cost.iteration_time(&mut ctx, &shape)
        };
        debug_assert!(dt > 0.0);
        let repl = &mut self.clusters[c].replicas[r];
        repl.busy = true;
        repl.iter_chunks = chunks;
        self.iter_started[c][r] = self.queue.now();
        self.queue.schedule_in(SimTime::from_secs_f64(dt), Ev::IterEnd { c, r });
    }

    /// AF decode step: partition the batch into micro-batches and run
    /// the dependency-graph executor. On the MoE path every
    /// `(layer, micro)` cell is data-dependent: a fresh routing draw
    /// sets the per-rank expert loads (stragglers) *and* the
    /// dispatch/combine transfer times through the EP fabric.
    fn af_iteration_time(&mut self, shape: &BatchShape) -> f64 {
        let af = self.af.expect("af params");
        let m = (af.micro_batches as usize).max(1).min(shape.decode_ctx.len().max(1));
        let model = &self.cost.model;
        // attention pool: TP across its GPUs; FFN pool: EP for MoE
        // (or TP for dense)
        let attn_par = crate::parallelism::Parallelism::tp(
            af.attn_gpus.min(model.n_kv_heads).max(1),
        );
        let ffn_par = if model.is_moe() {
            crate::parallelism::Parallelism::new(1, 1, af.ffn_gpus.max(1))
        } else {
            crate::parallelism::Parallelism::tp(af.ffn_gpus.max(1))
        };
        let mut attn_cost = CostModel::new(model.clone(), attn_par, self.cost.link);
        attn_cost.overhead = crate::config::OverheadConfig::zero();
        let mut ffn_cost = CostModel::new(model.clone(), ffn_par, self.cost.link);
        ffn_cost.overhead = crate::config::OverheadConfig::zero();
        ffn_cost.moe_routing = self.cost.moe_routing;
        ffn_cost.straggler_max = self.cost.straggler_max;
        // EP domain of the AF FFN pool: placement built once at startup
        ffn_cost.ep = self.af_ep.clone();
        let ep_active = ffn_cost.ep.is_some();

        // round-robin partition of decode sequences
        let mut micro_ctx: Vec<Vec<u32>> = vec![Vec::new(); m];
        for (i, &ctx) in shape.decode_ctx.iter().enumerate() {
            micro_ctx[i % m].push(ctx);
        }
        // prefill chunks (if the AF pool also prefills) ride micro 0
        let micro0_prefill = shape.prefill.clone();

        let layers = model.n_layers as usize;
        let d_bytes = model.d_model as f64 * model.dtype_bytes as f64;
        let mut attn_time = vec![vec![0.0f64; m]; layers];
        let mut ffn_time = vec![vec![0.0f64; m]; layers];
        let mut a2f_time = vec![vec![0.0f64; m]; layers];
        let mut f2a_time = vec![vec![0.0f64; m]; layers];
        for (k, ctxs) in micro_ctx.iter().enumerate() {
            let micro_shape = BatchShape {
                prefill: if k == 0 { micro0_prefill.clone() } else { vec![] },
                decode_ctx: ctxs.clone(),
                lm_head_rows: 0,
            };
            let micro_tokens = micro_shape.total_tokens() as u64;
            if micro_shape.is_empty() {
                continue;
            }
            let t_attn = {
                let mut ctx = CostCtx {
                    pred: self.pred.as_mut(),
                    rng: &mut self.rng,
                    metrics: Some(&mut self.metrics),
                };
                attn_cost.attn_block_time(&mut ctx, &micro_shape)
            };
            // dense fallback: point-to-point hop sized by this micro-batch
            let xfer = crate::oracle::p2p_time(micro_tokens as f64 * d_bytes, &self.cost.link);
            for l in 0..layers {
                attn_time[l][k] = t_attn;
                let mut ctx = CostCtx {
                    pred: self.pred.as_mut(),
                    rng: &mut self.rng,
                    metrics: Some(&mut self.metrics),
                };
                if ep_active {
                    // fresh routing per layer: data-dependent stragglers
                    // and skew-dependent dispatch/combine
                    let s = ffn_cost
                        .moe_ffn_ep(&mut ctx, micro_tokens)
                        .expect("ep spec attached and micro-batch non-empty");
                    ffn_time[l][k] = s.ffn_secs;
                    a2f_time[l][k] = s.dispatch_secs;
                    f2a_time[l][k] = s.combine_secs;
                } else {
                    // fresh routing per layer: data-dependent straggler noise
                    ffn_time[l][k] = ffn_cost.ffn_block_time(&mut ctx, micro_tokens);
                    a2f_time[l][k] = xfer;
                    f2a_time[l][k] = xfer;
                }
            }
        }
        let step = AfStep { attn_time, ffn_time, a2f_time, f2a_time };
        let (t_graph, busy) = af_step(&step);
        if ep_active {
            // FFN-pool idle time inside the step: dispatch bubbles the
            // ping-pong pipeline failed to hide
            self.metrics.dispatch_bubble_s += (t_graph - busy[1]).max(0.0);
        }
        let lm_head = {
            let mut ctx = CostCtx {
                pred: self.pred.as_mut(),
                rng: &mut self.rng,
                metrics: Some(&mut self.metrics),
            };
            attn_cost.lm_head_time(&mut ctx, shape.lm_head_rows as u64)
        };
        let o = &self.cost.overhead;
        o.sched_overhead_s + layers as f64 * o.launch_gap_s + o.op_scale * (t_graph + lm_head)
    }

    // -- accessors for tests/tools ------------------------------------------

    pub fn clusters(&self) -> &[ClusterWorker] {
        &self.clusters
    }

    pub fn pending_transfer_count(&self) -> usize {
        self.pending_transfers.len()
    }

    pub fn replica(&self, c: usize, r: usize) -> &ReplicaWorker {
        &self.clusters[c].replicas[r]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::predictor::PredictorKind;
    use crate::workload::WorkloadSpec;

    fn tiny_cfg(mode_requests: u32) -> ExperimentConfig {
        ExperimentConfig::colocated(ModelConfig::tiny(), 2)
            .with_workload(WorkloadSpec::table2(mode_requests, 64, 16))
            .with_predictor(PredictorKind::Oracle)
    }

    #[test]
    fn colocated_completes_all_requests() {
        let report = run(&tiny_cfg(32)).unwrap();
        assert_eq!(report.metrics.completed_requests, 32);
        assert_eq!(report.metrics.rejected_requests, 0);
        assert_eq!(report.metrics.output_tokens, 32 * 16);
        assert!(report.sim_duration > 0.0);
        assert!(report.metrics.ttft.len() == 32);
    }

    #[test]
    fn pd_completes_all_requests_with_transfers() {
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1)
            .with_workload(WorkloadSpec::table2(24, 64, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 24);
        // every multi-token request crosses the PD boundary once
        assert_eq!(report.metrics.kv_transfers, 24);
        assert!(report.metrics.kv_bytes > 0.0);
    }

    #[test]
    fn af_mode_runs() {
        let cfg = ExperimentConfig::af(ModelConfig::tiny(), 1, 2, 2, 2)
            .with_workload(WorkloadSpec::table2(8, 32, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
    }

    #[test]
    fn deterministic_under_seed() {
        let a = run(&tiny_cfg(16)).unwrap();
        let b = run(&tiny_cfg(16)).unwrap();
        assert_eq!(a.sim_duration, b.sim_duration);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.metrics.output_tokens, b.metrics.output_tokens);
    }

    #[test]
    fn single_token_outputs_skip_transfer() {
        let mut w = WorkloadSpec::table2(8, 64, 1);
        w.output = crate::workload::LenDist::Fixed(1);
        let cfg = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1).with_workload(w);
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
        assert_eq!(report.metrics.kv_transfers, 0); // done at prefill
    }

    #[test]
    fn oversized_request_rejected() {
        let mut w = WorkloadSpec::table2(4, 64, 8);
        w.input = crate::workload::LenDist::Fixed(100_000_000);
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 1).with_workload(w);
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.rejected_requests, 4);
        assert_eq!(report.metrics.completed_requests, 0);
    }

    #[test]
    fn ttft_precedes_e2e() {
        let report = run(&tiny_cfg(16)).unwrap();
        let mean_ttft = crate::metrics::mean(&report.metrics.ttft);
        let mean_e2e = crate::metrics::mean(&report.metrics.e2e);
        assert!(mean_ttft < mean_e2e);
    }

    #[test]
    fn moe_model_runs_colocated() {
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny_moe(), 1)
            .with_parallelism(crate::parallelism::Parallelism::new(1, 1, 2))
            .with_workload(WorkloadSpec::table2(8, 32, 8));
        let report = run(&cfg).unwrap();
        assert_eq!(report.metrics.completed_requests, 8);
        assert!(report.metrics.op_time.contains_key("grouped_gemm"));
    }
}
