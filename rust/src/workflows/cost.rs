//! Iteration cost model: decompose a batch into the operator
//! micro-workflow and price it.
//!
//! The ReplicaWorker's ExecutionPredictor (§3.1) "decomposes a logical
//! layer into a data-dependent micro-workflow of events". For an MoE
//! layer that means: gate GEMM -> pluggable routing -> per-EP-rank
//! GroupedGEMM (heterogeneous tasks) -> `max` synchronization barrier ->
//! all-to-all combine. For attention it means pricing the *actual*
//! ragged batch, not a proxy.
//!
//! Pricing is two-phase: the op list for an iteration is collected
//! first and handed to [`crate::predictor::ExecutionPredictor::prefetch`]
//! so the learned predictor can batch its PJRT queries (one executable
//! launch per operator class instead of one per op — the §Perf
//! optimization), then combined respecting the straggler barrier.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::OverheadConfig;
use crate::core::{Pcg64, SimTime};
use crate::hardware::LinkSpec;
use crate::metrics::MetricsCollector;
use crate::model::ModelConfig;
use crate::network::LinkHealth;
use crate::moe::{
    self, rank_imbalance, EpNetwork, EpSpec, LoadEstimator, PopularityCache, RoutingFidelity,
    RoutingPolicy,
};
use crate::operators::OpWorkload;
use crate::parallelism::Parallelism;
use crate::predictor::ExecutionPredictor;

/// Global count of [`CostModel`] constructions. Cost models embed a
/// model clone and (lazily) an EP scratch network, so building one is
/// expensive; the controller builds every stage's models once at
/// construction and the hot path must never construct more. Tests pin
/// this by asserting the counter stays flat across a simulation run.
pub static COST_MODELS_BUILT: AtomicU64 = AtomicU64::new(0);

/// Reusable per-CostModel pricing buffers: the EP network (2n NIC/port
/// links + a trunk map) and the two n^2 dispatch/combine byte matrices.
/// Without reuse every routing draw re-allocates all three — millions of
/// small allocations on long MoE runs (ROADMAP "Scratch EP network").
#[derive(Clone, Debug, Default)]
struct EpScratch {
    net: Option<EpNetwork>,
    mat: Vec<f64>,
    mat_t: Vec<f64>,
}

/// Reusable plan/op scratch (alongside [`EpScratch`]): every vector a
/// pricing draw needs — the routing loads, the placement-mapped rank
/// loads, and the operator lists themselves — refilled in place slot by
/// slot, so steady-state iteration pricing performs zero per-draw
/// plan/op-vector allocations (pinned by the counting-allocator test in
/// `rust/tests/alloc_flat.rs`).
#[derive(Clone, Debug, Default)]
struct PlanScratch {
    /// Per-expert token loads of the current routing draw.
    loads: Vec<u32>,
    /// Placement-mapped per-rank expert loads (EP path).
    rank_loads: Vec<Vec<u32>>,
    /// Per-rank token totals feeding the imbalance metric (EP path).
    rank_totals: Vec<u64>,
    /// The closed-form FFN plan (non-EP path), op slots reused.
    plan: FfnPlan,
    /// EP-path ops shared by every rank (gate, shared expert, TP sync).
    ep_common: Vec<OpWorkload>,
    /// EP-path per-rank GroupedGemm pairs.
    ep_per_rank: Vec<Vec<OpWorkload>>,
    /// Cached `(class, secs)` pricing of the draw-invariant common ops,
    /// replayed into the metric stream once per draw by the batched EP
    /// path so its op accounting stays bit-identical to per-draw calls.
    ep_common_t: Vec<(&'static str, f64)>,
}

/// In-place writer over a reusable `Vec<OpWorkload>`: overwrites the
/// slots left from the previous draw — reusing their heap buffers when
/// the variant matches — and truncates the tail on [`OpsWriter::finish`].
/// Steady-state refills with a stable op sequence allocate nothing.
struct OpsWriter<'a> {
    ops: &'a mut Vec<OpWorkload>,
    n: usize,
}

impl<'a> OpsWriter<'a> {
    fn new(ops: &'a mut Vec<OpWorkload>) -> Self {
        OpsWriter { ops, n: 0 }
    }

    /// Write a heap-less op (Gemm / AllReduce / AllToAll / P2p).
    fn plain(&mut self, op: OpWorkload) {
        if self.n < self.ops.len() {
            self.ops[self.n] = op;
        } else {
            self.ops.push(op);
        }
        self.n += 1;
    }

    /// Write a GroupedGemm, reusing the slot's `tokens_per_expert`
    /// buffer when the slot already holds one.
    fn grouped(&mut self, loads: &[u32], n: u64, k: u64) {
        if self.n < self.ops.len() {
            if let OpWorkload::GroupedGemm { tokens_per_expert, n: sn, k: sk } =
                &mut self.ops[self.n]
            {
                tokens_per_expert.clear();
                tokens_per_expert.extend_from_slice(loads);
                *sn = n;
                *sk = k;
                self.n += 1;
                return;
            }
        }
        self.plain(OpWorkload::GroupedGemm { tokens_per_expert: loads.to_vec(), n, k });
    }

    /// Write an Attention op, reusing the slot's `q_lens`/`ctx_lens`
    /// buffers; `fill` receives them cleared.
    fn attention(
        &mut self,
        is_prefill: bool,
        n_heads: u32,
        n_kv_heads: u32,
        head_dim: u32,
        fill: impl FnOnce(&mut Vec<u32>, &mut Vec<u32>),
    ) {
        if self.n < self.ops.len() {
            if let OpWorkload::Attention {
                is_prefill: p,
                q_lens,
                ctx_lens,
                n_heads: h,
                n_kv_heads: kv,
                head_dim: hd,
            } = &mut self.ops[self.n]
            {
                *p = is_prefill;
                *h = n_heads;
                *kv = n_kv_heads;
                *hd = head_dim;
                q_lens.clear();
                ctx_lens.clear();
                fill(q_lens, ctx_lens);
                self.n += 1;
                return;
            }
        }
        let mut q_lens = Vec::new();
        let mut ctx_lens = Vec::new();
        fill(&mut q_lens, &mut ctx_lens);
        self.plain(OpWorkload::Attention {
            is_prefill,
            q_lens,
            ctx_lens,
            n_heads,
            n_kv_heads,
            head_dim,
        });
    }

    fn finish(self) {
        self.ops.truncate(self.n);
    }
}

/// The shape of one iteration's batch on a replica.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BatchShape {
    /// Prefill chunks: (new tokens this iteration, existing context).
    pub prefill: Vec<(u32, u32)>,
    /// Decode sequences: context length (input + generated so far).
    pub decode_ctx: Vec<u32>,
    /// Rows hitting the LM head (decode seqs + prefills finishing now).
    pub lm_head_rows: u32,
}

impl BatchShape {
    pub fn total_tokens(&self) -> u32 {
        self.prefill.iter().map(|&(t, _)| t).sum::<u32>() + self.decode_ctx.len() as u32
    }

    pub fn is_empty(&self) -> bool {
        self.prefill.is_empty() && self.decode_ctx.is_empty()
    }
}

/// Pricing configuration and per-pool pricing state for one replica
/// pool. Mostly set once at construction, but not immutable: the
/// expert-migration control loop rewrites `ep.placement` between
/// iterations, and the draw clock / popularity cache / load tracker
/// advance with every MoE routing draw (interior mutability, so the
/// pricing entry points stay `&self`).
#[derive(Debug)]
pub struct CostModel {
    pub model: ModelConfig,
    pub par: Parallelism,
    pub link: LinkSpec,
    pub moe_routing: RoutingPolicy,
    /// Sampling fidelity of the routing draw: per-token alias sampling
    /// (default) or O(E) aggregate count sampling for huge-batch scale
    /// runs (`--routing-fidelity`).
    pub routing_fidelity: RoutingFidelity,
    /// `max` over expert tasks (stragglers) vs balance-oblivious `mean`.
    pub straggler_max: bool,
    pub overhead: OverheadConfig,
    /// When set, MoE FFN pricing goes through the expert-parallel
    /// placement: rank loads follow the placement (not contiguous
    /// slicing) and dispatch/combine are charged through the contended
    /// cluster fabric instead of the closed-form all-to-all.
    pub ep: Option<EpSpec>,
    /// GShard-style capacity factor: per-expert token caps at
    /// `ceil(cf * fair_share)`; overflow tokens are dropped (counted in
    /// metrics). `None` = unbounded capacity.
    pub capacity_factor: Option<f64>,
    /// Online per-expert load estimator, fed one observation per MoE
    /// routing draw. `None` (the default) skips tracking entirely —
    /// attached by the coordinator only when expert migration is on, so
    /// the static-placement path stays bit-identical.
    pub load_tracker: Option<RefCell<LoadEstimator>>,
    /// Effective EP cross-cluster trunk health for the current fabric
    /// epoch (set by the engine at epoch boundaries; healthy is exactly
    /// inert). Applied to the scratch network before every EP pricing
    /// draw.
    trunk_health: Cell<LinkHealth>,
    /// Routing draws priced so far (drift-epoch clock for
    /// [`RoutingPolicy::Drifting`]; ignored by every other policy).
    draws: Cell<u64>,
    /// Cached popularity vector + alias table for the current drift
    /// epoch (avoids a Dirichlet + table re-derivation per draw).
    pop_cache: RefCell<PopularityCache>,
    /// Reusable EP pricing buffers (network + byte matrices).
    scratch: RefCell<EpScratch>,
    /// Reusable plan/op buffers (routing loads, rank loads, op slots).
    plan_scratch: RefCell<PlanScratch>,
    /// Reusable attention-op list (q/ctx length buffers reused).
    attn_scratch: RefCell<Vec<OpWorkload>>,
}

/// Cloning a cost model is as expensive as building one (model config
/// + EP scratch network), so it counts against [`COST_MODELS_BUILT`]
/// too — the hot-path regression pin cannot be evaded with `.clone()`.
impl Clone for CostModel {
    fn clone(&self) -> Self {
        COST_MODELS_BUILT.fetch_add(1, Ordering::Relaxed);
        CostModel {
            model: self.model.clone(),
            par: self.par,
            link: self.link,
            moe_routing: self.moe_routing,
            routing_fidelity: self.routing_fidelity,
            straggler_max: self.straggler_max,
            overhead: self.overhead,
            ep: self.ep.clone(),
            capacity_factor: self.capacity_factor,
            load_tracker: self.load_tracker.clone(),
            trunk_health: self.trunk_health.clone(),
            draws: self.draws.clone(),
            pop_cache: RefCell::new(self.pop_cache.borrow().clone()),
            scratch: RefCell::new(self.scratch.borrow().clone()),
            plan_scratch: RefCell::new(self.plan_scratch.borrow().clone()),
            attn_scratch: RefCell::new(self.attn_scratch.borrow().clone()),
        }
    }
}

/// Mutable pricing context: predictor + RNG + metric sink.
pub struct CostCtx<'a> {
    pub pred: &'a mut dyn ExecutionPredictor,
    pub rng: &'a mut Pcg64,
    pub metrics: Option<&'a mut MetricsCollector>,
}

impl<'a> CostCtx<'a> {
    fn price(&mut self, op: &OpWorkload) -> f64 {
        let t = self.pred.predict(op);
        if let Some(m) = self.metrics.as_deref_mut() {
            m.record_op(op.class(), t);
        }
        t
    }

}

/// The FFN sub-layer's op decomposition: ops common to all ranks plus
/// the heterogeneous per-EP-rank task groups (empty for dense).
#[derive(Clone, Debug, Default)]
pub struct FfnPlan {
    /// Ops every rank executes (gate, A2A, shared expert, TP sync).
    pub common: Vec<OpWorkload>,
    /// Heterogeneous per-EP-rank GroupedGemm task groups.
    pub per_rank: Vec<Vec<OpWorkload>>,
    /// Token-slots dropped by the capacity-factor policy in this draw.
    pub dropped: u64,
}

/// One EP-aware MoE FFN pricing draw (see [`CostModel::moe_ffn_ep`]):
/// the components are kept separate so the AF pipeline can schedule
/// dispatch/combine on its transfer resources while co-located pricing
/// just sums them.
#[derive(Clone, Copy, Debug)]
pub struct MoeEpSample {
    /// Expert compute (gate + shared expert + rank barrier), seconds.
    pub ffn_secs: f64,
    /// Token dispatch all-to-all through the fabric, seconds.
    pub dispatch_secs: f64,
    /// Expert-output combine all-to-all, seconds.
    pub combine_secs: f64,
    /// Dispatch + combine byte volume (including rank-local bytes).
    pub total_bytes: f64,
    /// Bytes that crossed a cluster boundary.
    pub cross_bytes: f64,
    /// Max-over-mean EP rank load for this routing draw.
    pub rank_imbalance: f64,
}

impl CostModel {
    pub fn new(model: ModelConfig, par: Parallelism, link: LinkSpec) -> Self {
        COST_MODELS_BUILT.fetch_add(1, Ordering::Relaxed);
        CostModel {
            model,
            par,
            link,
            moe_routing: RoutingPolicy::UniformRandom,
            routing_fidelity: RoutingFidelity::Token,
            straggler_max: true,
            overhead: OverheadConfig::predicted(),
            ep: None,
            capacity_factor: None,
            load_tracker: None,
            trunk_health: Cell::new(LinkHealth::HEALTHY),
            draws: Cell::new(0),
            pop_cache: RefCell::new(PopularityCache::default()),
            scratch: RefCell::new(EpScratch::default()),
            plan_scratch: RefCell::new(PlanScratch::default()),
            attn_scratch: RefCell::new(Vec::new()),
        }
    }

    /// Set the effective EP trunk health for subsequent pricing draws
    /// (fabric epochs: the engine calls this at epoch boundaries;
    /// [`LinkHealth::HEALTHY`] is exactly inert).
    pub fn set_ep_trunk_health(&self, h: LinkHealth) {
        self.trunk_health.set(h);
    }

    /// Current effective EP trunk health.
    pub fn ep_trunk_health(&self) -> LinkHealth {
        self.trunk_health.get()
    }

    /// Per-expert token cap for a routing draw of `tokens` tokens, from
    /// the configured capacity factor.
    fn expert_cap(&self, tokens: u32) -> Option<u32> {
        let moe = self.model.moe.as_ref()?;
        let cf = self.capacity_factor?;
        Some(moe::expert_capacity(tokens, moe.n_experts, moe.top_k, cf))
    }

    /// One MoE routing draw: advance the draw clock (drifting popularity
    /// epochs), sample the capacity-capped token-to-expert assignment
    /// into the caller's reusable `loads` buffer (cleared and refilled),
    /// and feed the observation to the load tracker when one is
    /// attached. Returns the dropped token-slots.
    fn draw_assignment_into(
        &self,
        tokens: u32,
        n_experts: u32,
        top_k: u32,
        rng: &mut Pcg64,
        loads: &mut Vec<u32>,
    ) -> u64 {
        let draw = self.draws.get();
        self.draws.set(draw + 1);
        let dropped = moe::assign_tokens_into(
            self.moe_routing,
            self.routing_fidelity,
            tokens,
            n_experts,
            top_k,
            self.expert_cap(tokens),
            draw,
            &mut self.pop_cache.borrow_mut(),
            rng,
            loads,
        );
        if let Some(tracker) = &self.load_tracker {
            tracker.borrow_mut().observe(loads);
        }
        dropped
    }

    /// Attention sub-layer ops (qkv proj + attention + o proj + TP
    /// all-reduce) for the given batch. Also the attention-side stage of
    /// the AF pipeline.
    pub fn attn_block_ops(&self, shape: &BatchShape) -> Vec<OpWorkload> {
        let mut ops = Vec::new();
        self.attn_block_ops_into(shape, &mut ops);
        ops
    }

    /// Allocation-free variant of [`CostModel::attn_block_ops`]: refills
    /// `ops` in place, reusing the op slots' `q_lens`/`ctx_lens` buffers
    /// — the hot-path form (the old path rebuilt both length vectors on
    /// every iteration of every replica).
    pub fn attn_block_ops_into(&self, shape: &BatchShape, ops: &mut Vec<OpWorkload>) {
        let m = &self.model;
        let tp = self.par.tp.max(1);
        let tokens = shape.total_tokens() as u64;
        let mut w = OpsWriter::new(ops);
        if tokens == 0 {
            w.finish();
            return;
        }
        let heads = (m.n_heads / tp).max(1);
        let kv_heads = (m.n_kv_heads / tp).max(1);
        let qkv_n = (heads as u64 + 2 * kv_heads as u64) * m.head_dim as u64;
        w.plain(OpWorkload::Gemm { m: tokens, n: qkv_n, k: m.d_model as u64 });
        if !shape.prefill.is_empty() {
            w.attention(true, heads, kv_heads, m.head_dim, |q, c| {
                for &(t, ctx) in &shape.prefill {
                    q.push(t);
                    c.push(ctx);
                }
            });
        }
        if !shape.decode_ctx.is_empty() {
            w.attention(false, heads, kv_heads, m.head_dim, |q, c| {
                q.resize(shape.decode_ctx.len(), 1);
                c.extend_from_slice(&shape.decode_ctx);
            });
        }
        w.plain(OpWorkload::Gemm {
            m: tokens,
            n: m.d_model as u64,
            k: heads as u64 * m.head_dim as u64,
        });
        if tp > 1 {
            w.plain(OpWorkload::AllReduce {
                bytes: tokens as f64 * m.d_model as f64 * m.dtype_bytes as f64,
                n_ranks: tp,
            });
        }
        w.finish();
    }

    /// Attention sub-layer time, seconds.
    pub fn attn_block_time(&self, ctx: &mut CostCtx, shape: &BatchShape) -> f64 {
        let mut ops = self.attn_scratch.borrow_mut();
        self.attn_block_ops_into(shape, &mut ops);
        ctx.pred.prefetch(&mut ops.iter());
        ops.iter().map(|op| ctx.price(op)).sum()
    }

    /// FFN sub-layer decomposition for `tokens` tokens. Dense: SwiGLU
    /// GEMMs + TP all-reduce. MoE: the §3.3 micro-workflow with a fresh
    /// routing draw. Allocating convenience form of
    /// [`CostModel::fill_ffn_plan`] (hot paths go through the scratch).
    pub fn ffn_block_plan(&self, tokens: u64, rng: &mut Pcg64) -> FfnPlan {
        let mut plan = FfnPlan::default();
        let mut loads = Vec::new();
        self.fill_ffn_plan(tokens, rng, &mut loads, &mut plan);
        plan
    }

    /// Refill a reusable [`FfnPlan`] in place for `tokens` tokens:
    /// identical decomposition to [`CostModel::ffn_block_plan`] but the
    /// op slots (including every GroupedGemm's `tokens_per_expert`
    /// buffer) and the routing-draw `loads` buffer are reused, so a
    /// steady-state draw allocates nothing.
    fn fill_ffn_plan(
        &self,
        tokens: u64,
        rng: &mut Pcg64,
        loads: &mut Vec<u32>,
        plan: &mut FfnPlan,
    ) {
        plan.dropped = 0;
        if tokens == 0 {
            plan.common.clear();
            plan.per_rank.clear();
            return;
        }
        let m = &self.model;
        let tp = self.par.tp.max(1);
        let d = m.d_model as u64;
        match m.moe.as_ref() {
            None => {
                let ffn = (m.ffn_dim / tp).max(1) as u64;
                let mut w = OpsWriter::new(&mut plan.common);
                w.plain(OpWorkload::Gemm { m: tokens, n: 2 * ffn, k: d });
                w.plain(OpWorkload::Gemm { m: tokens, n: d, k: ffn });
                if tp > 1 {
                    w.plain(OpWorkload::AllReduce {
                        bytes: tokens as f64 * d as f64 * m.dtype_bytes as f64,
                        n_ranks: tp,
                    });
                }
                w.finish();
                plan.per_rank.clear();
            }
            Some(moe_cfg) => {
                let ep = self.par.ep.max(1);
                let mut w = OpsWriter::new(&mut plan.common);
                // (1) gating network GEMM
                w.plain(OpWorkload::Gemm { m: tokens, n: moe_cfg.n_experts as u64, k: d });
                // (2) pluggable routing -> token-to-expert assignment
                // map, capped by the capacity-factor drop policy
                plan.dropped = self.draw_assignment_into(
                    tokens as u32,
                    moe_cfg.n_experts,
                    moe_cfg.top_k,
                    rng,
                    loads,
                );
                // (3)+(5) A2A dispatch / combine across EP ranks, sized
                // by the tokens that actually routed (drops excluded)
                let routed: u64 = loads.iter().map(|&x| x as u64).sum();
                let routed_bytes = routed as f64 * d as f64 * m.dtype_bytes as f64;
                if ep > 1 {
                    w.plain(OpWorkload::AllToAll { bytes: routed_bytes, n_ranks: ep });
                    w.plain(OpWorkload::AllToAll { bytes: routed_bytes, n_ranks: ep });
                }
                // (4) heterogeneous expert computation per rank
                // (contiguous EP sharding of the load vector)
                let expert_ffn = (moe_cfg.expert_ffn_dim / tp).max(1) as u64;
                let n_ranks = ep as usize;
                plan.per_rank.truncate(n_ranks);
                while plan.per_rank.len() < n_ranks {
                    plan.per_rank.push(Vec::new());
                }
                for (r, rank_ops) in plan.per_rank.iter_mut().enumerate() {
                    let rank_loads = self.par.expert_shard(loads, r);
                    let mut rw = OpsWriter::new(rank_ops);
                    rw.grouped(rank_loads, 2 * expert_ffn, d);
                    rw.grouped(rank_loads, d, expert_ffn);
                    rw.finish();
                }
                // shared expert runs dense alongside
                if moe_cfg.shared_expert_dim > 0 {
                    let se = (moe_cfg.shared_expert_dim / tp).max(1) as u64;
                    w.plain(OpWorkload::Gemm { m: tokens, n: 2 * se, k: d });
                    w.plain(OpWorkload::Gemm { m: tokens, n: d, k: se });
                }
                if tp > 1 {
                    w.plain(OpWorkload::AllReduce {
                        bytes: tokens as f64 * d as f64 * m.dtype_bytes as f64,
                        n_ranks: tp,
                    });
                }
                w.finish();
            }
        }
    }

    /// Price an [`FfnPlan`]: common ops summed; per-rank groups combined
    /// under the implicit synchronization barrier — `max` (stragglers,
    /// §3.3) or balance-oblivious `mean` (ablation).
    pub fn price_ffn_plan(&self, ctx: &mut CostCtx, plan: &FfnPlan) -> f64 {
        // prefetch everything in one pass (batched PJRT execution),
        // borrowing straight from the plan — no op clones
        ctx.pred.prefetch(&mut plan.common.iter().chain(plan.per_rank.iter().flatten()));
        self.price_ffn_plan_prefetched(ctx, plan)
    }

    /// [`CostModel::price_ffn_plan`] without the prefetch pass — for
    /// callers that already prefetched the plan's ops as part of a
    /// larger batch (the full-iteration path), so the plan is not
    /// walked twice.
    fn price_ffn_plan_prefetched(&self, ctx: &mut CostCtx, plan: &FfnPlan) -> f64 {
        if plan.dropped > 0 {
            if let Some(mc) = ctx.metrics.as_deref_mut() {
                mc.dropped_tokens += plan.dropped;
            }
        }
        let mut t: f64 = plan.common.iter().map(|op| ctx.price(op)).sum();
        t += self.rank_barrier_iter(
            plan.per_rank.iter().map(|ops| ops.iter().map(|op| ctx.price(op)).sum::<f64>()),
        );
        t
    }

    /// The §3.3 synchronization barrier over per-rank task times: `max`
    /// (stragglers) or balance-oblivious `mean` (ablation). Iterator
    /// form so hot callers never materialize a times vector; shared by
    /// the closed-form plan path and the EP placement path so the two
    /// cannot drift.
    fn rank_barrier_iter(&self, times: impl Iterator<Item = f64>) -> f64 {
        let mut n = 0u32;
        let mut sum = 0.0;
        let mut max = 0.0f64;
        for t in times {
            n += 1;
            sum += t;
            max = max.max(t);
        }
        if n == 0 {
            0.0
        } else if self.straggler_max {
            max
        } else {
            sum / n as f64
        }
    }

    /// FFN sub-layer time for `tokens` tokens, seconds. Also the
    /// FFN-side stage of the AF pipeline. Routes through the EP
    /// placement path when an [`EpSpec`] is attached.
    pub fn ffn_block_time(&self, ctx: &mut CostCtx, tokens: u64) -> f64 {
        if let Some(s) = self.moe_ffn_ep(ctx, tokens) {
            return s.ffn_secs + s.dispatch_secs + s.combine_secs;
        }
        let mut plans = self.plan_scratch.borrow_mut();
        let PlanScratch { loads, plan, .. } = &mut *plans;
        self.fill_ffn_plan(tokens, ctx.rng, loads, plan);
        self.price_ffn_plan(ctx, plan)
    }

    /// EP-aware MoE FFN pricing for one batch of `tokens` tokens: draw a
    /// fresh routing assignment, map it through the expert placement to
    /// heterogeneous per-rank GroupedGEMMs (combined under the
    /// synchronization barrier), and charge dispatch/combine through the
    /// contended intra-/cross-cluster fabric. `None` when not applicable
    /// (dense model, no EP spec attached, single rank, or empty batch) —
    /// callers then fall back to the closed-form plan path.
    pub fn moe_ffn_ep(&self, ctx: &mut CostCtx, tokens: u64) -> Option<MoeEpSample> {
        let eps = self.ep.as_ref()?;
        let moe_cfg = self.model.moe.as_ref()?;
        if tokens == 0 || eps.n_ranks() <= 1 {
            return None;
        }
        let m = &self.model;
        let tp = self.par.tp.max(1);
        let d = m.d_model as u64;
        let mut plans = self.plan_scratch.borrow_mut();
        let PlanScratch { loads, rank_loads, rank_totals, ep_common, ep_per_rank, .. } =
            &mut *plans;
        // ops shared by every rank: gate GEMM, shared expert, TP sync
        let mut w = OpsWriter::new(ep_common);
        w.plain(OpWorkload::Gemm { m: tokens, n: moe_cfg.n_experts as u64, k: d });
        if moe_cfg.shared_expert_dim > 0 {
            let se = (moe_cfg.shared_expert_dim / tp).max(1) as u64;
            w.plain(OpWorkload::Gemm { m: tokens, n: 2 * se, k: d });
            w.plain(OpWorkload::Gemm { m: tokens, n: d, k: se });
        }
        if tp > 1 {
            w.plain(OpWorkload::AllReduce {
                bytes: tokens as f64 * d as f64 * m.dtype_bytes as f64,
                n_ranks: tp,
            });
        }
        w.finish();
        // pluggable routing (capacity-capped) -> placement-aware rank loads
        let dropped = self.draw_assignment_into(
            tokens as u32,
            moe_cfg.n_experts,
            moe_cfg.top_k,
            ctx.rng,
            loads,
        );
        eps.placement.rank_expert_loads_into(loads, rank_loads);
        let expert_ffn = (moe_cfg.expert_ffn_dim / tp).max(1) as u64;
        ep_per_rank.truncate(rank_loads.len());
        while ep_per_rank.len() < rank_loads.len() {
            ep_per_rank.push(Vec::new());
        }
        for (rl, rank_ops) in rank_loads.iter().zip(ep_per_rank.iter_mut()) {
            let mut rw = OpsWriter::new(rank_ops);
            rw.grouped(rl, 2 * expert_ffn, d);
            rw.grouped(rl, d, expert_ffn);
            rw.finish();
        }
        ctx.pred.prefetch(&mut ep_common.iter().chain(ep_per_rank.iter().flatten()));
        let mut ffn_secs: f64 = ep_common.iter().map(|op| ctx.price(op)).sum();
        ffn_secs += self.rank_barrier_iter(
            ep_per_rank.iter().map(|ops| ops.iter().map(|op| ctx.price(op)).sum::<f64>()),
        );
        // data-dependent dispatch/combine through the fabric (combine is
        // the transpose of the dispatch matrix already in hand). The
        // network and both byte matrices live in the per-CostModel
        // scratch buffer: one lazy build, then an O(1) generation-bump
        // reset + refill per draw.
        let bpt = d as f64 * m.dtype_bytes as f64;
        let mut scratch = self.scratch.borrow_mut();
        let EpScratch { net, mat, mat_t } = &mut *scratch;
        if !net.as_ref().is_some_and(|n| n.matches(eps)) {
            *net = Some(eps.make_network());
        }
        let net = net.as_mut().expect("scratch network just built");
        net.set_trunk_health(self.trunk_health.get());
        eps.placement.dispatch_matrix_into(loads, bpt, mat);
        eps.placement.transpose_into(mat, mat_t);
        net.reset();
        let dispatch = net.all_to_all(SimTime::ZERO, mat).1;
        net.reset();
        let combine = net.all_to_all(SimTime::ZERO, mat_t).1;
        rank_totals.clear();
        rank_totals.extend(
            rank_loads.iter().map(|per| per.iter().map(|&x| x as u64).sum::<u64>()),
        );
        let imbalance = rank_imbalance(rank_totals);
        if let Some(mc) = ctx.metrics.as_deref_mut() {
            mc.record_op("ep_dispatch", dispatch.secs);
            mc.record_op("ep_combine", combine.secs);
            mc.record_ep(
                dispatch.total_bytes + combine.total_bytes,
                dispatch.cross_bytes + combine.cross_bytes,
                imbalance,
            );
            mc.dropped_tokens += dropped;
        }
        Some(MoeEpSample {
            ffn_secs,
            dispatch_secs: dispatch.secs,
            combine_secs: combine.secs,
            total_bytes: dispatch.total_bytes + combine.total_bytes,
            cross_bytes: dispatch.cross_bytes + combine.cross_bytes,
            rank_imbalance: imbalance,
        })
    }

    /// Batched form of [`CostModel::moe_ffn_ep`]: `n_draws` routing
    /// draws over the same `tokens`-token batch in one pass (the AF
    /// executor prices one draw per layer per micro-batch, so a single
    /// micro costs `n_layers` draws).
    ///
    /// The draw-invariant work is hoisted out of the loop: the common
    /// op list (gate GEMM, shared expert, TP sync) is built and priced
    /// **once**, its `(class, secs)` pairs cached in scratch and
    /// replayed into the metric stream per draw, and the scratch EP
    /// network is resolved once instead of per call. Everything
    /// data-dependent — the routing draw itself, the per-rank grouped
    /// GEMMs, and the fabric dispatch/combine — still runs per draw in
    /// the sequential order, so the RNG stream, every recorded op time,
    /// and the returned samples are bit-identical to `n_draws`
    /// back-to-back `moe_ffn_ep` calls (pinned by
    /// `ep_batch_pricing_matches_sequential`). Only `predictor_evals`
    /// drops (common ops are predicted once, not `n_draws` times).
    ///
    /// `out` is cleared and refilled (reuse it across calls — the
    /// steady state allocates nothing). Returns `None` exactly when
    /// [`CostModel::moe_ffn_ep`] would.
    pub fn moe_ffn_ep_batch(
        &self,
        ctx: &mut CostCtx,
        tokens: u64,
        n_draws: usize,
        out: &mut Vec<MoeEpSample>,
    ) -> Option<()> {
        let eps = self.ep.as_ref()?;
        let moe_cfg = self.model.moe.as_ref()?;
        if tokens == 0 || eps.n_ranks() <= 1 {
            return None;
        }
        out.clear();
        if n_draws == 0 {
            return Some(());
        }
        let m = &self.model;
        let tp = self.par.tp.max(1);
        let d = m.d_model as u64;
        let mut plans = self.plan_scratch.borrow_mut();
        let PlanScratch { loads, rank_loads, rank_totals, ep_common, ep_per_rank, ep_common_t, .. } =
            &mut *plans;
        // draw-invariant: build + price the common ops once, cache the
        // per-op (class, secs) pairs for metric replay
        let mut w = OpsWriter::new(ep_common);
        w.plain(OpWorkload::Gemm { m: tokens, n: moe_cfg.n_experts as u64, k: d });
        if moe_cfg.shared_expert_dim > 0 {
            let se = (moe_cfg.shared_expert_dim / tp).max(1) as u64;
            w.plain(OpWorkload::Gemm { m: tokens, n: 2 * se, k: d });
            w.plain(OpWorkload::Gemm { m: tokens, n: d, k: se });
        }
        if tp > 1 {
            w.plain(OpWorkload::AllReduce {
                bytes: tokens as f64 * d as f64 * m.dtype_bytes as f64,
                n_ranks: tp,
            });
        }
        w.finish();
        ctx.pred.prefetch(&mut ep_common.iter());
        ep_common_t.clear();
        ep_common_t.extend(ep_common.iter().map(|op| (op.class(), ctx.pred.predict(op))));
        let common_secs: f64 = ep_common_t.iter().map(|&(_, t)| t).sum();
        let expert_ffn = (moe_cfg.expert_ffn_dim / tp).max(1) as u64;
        let bpt = d as f64 * m.dtype_bytes as f64;
        let mut scratch = self.scratch.borrow_mut();
        let EpScratch { net, mat, mat_t } = &mut *scratch;
        if !net.as_ref().is_some_and(|n| n.matches(eps)) {
            *net = Some(eps.make_network());
        }
        let net = net.as_mut().expect("scratch network just built");
        net.set_trunk_health(self.trunk_health.get());
        for _ in 0..n_draws {
            let dropped = self.draw_assignment_into(
                tokens as u32,
                moe_cfg.n_experts,
                moe_cfg.top_k,
                ctx.rng,
                loads,
            );
            eps.placement.rank_expert_loads_into(loads, rank_loads);
            ep_per_rank.truncate(rank_loads.len());
            while ep_per_rank.len() < rank_loads.len() {
                ep_per_rank.push(Vec::new());
            }
            for (rl, rank_ops) in rank_loads.iter().zip(ep_per_rank.iter_mut()) {
                let mut rw = OpsWriter::new(rank_ops);
                rw.grouped(rl, 2 * expert_ffn, d);
                rw.grouped(rl, d, expert_ffn);
                rw.finish();
            }
            ctx.pred.prefetch(&mut ep_per_rank.iter().flatten());
            // replay the cached common-op pricing (op order preserved),
            // then price this draw's rank groups live
            if let Some(mc) = ctx.metrics.as_deref_mut() {
                for &(class, t) in ep_common_t.iter() {
                    mc.record_op(class, t);
                }
            }
            let mut ffn_secs = common_secs;
            ffn_secs += self.rank_barrier_iter(
                ep_per_rank.iter().map(|ops| ops.iter().map(|op| ctx.price(op)).sum::<f64>()),
            );
            eps.placement.dispatch_matrix_into(loads, bpt, mat);
            eps.placement.transpose_into(mat, mat_t);
            net.reset();
            let dispatch = net.all_to_all(SimTime::ZERO, mat).1;
            net.reset();
            let combine = net.all_to_all(SimTime::ZERO, mat_t).1;
            rank_totals.clear();
            rank_totals.extend(
                rank_loads.iter().map(|per| per.iter().map(|&x| x as u64).sum::<u64>()),
            );
            let imbalance = rank_imbalance(rank_totals);
            if let Some(mc) = ctx.metrics.as_deref_mut() {
                mc.record_op("ep_dispatch", dispatch.secs);
                mc.record_op("ep_combine", combine.secs);
                mc.record_ep(
                    dispatch.total_bytes + combine.total_bytes,
                    dispatch.cross_bytes + combine.cross_bytes,
                    imbalance,
                );
                mc.dropped_tokens += dropped;
            }
            out.push(MoeEpSample {
                ffn_secs,
                dispatch_secs: dispatch.secs,
                combine_secs: combine.secs,
                total_bytes: dispatch.total_bytes + combine.total_bytes,
                cross_bytes: dispatch.cross_bytes + combine.cross_bytes,
                rank_imbalance: imbalance,
            });
        }
        Some(())
    }

    /// LM head projection for rows that produce a token this iteration.
    pub fn lm_head_time(&self, ctx: &mut CostCtx, rows: u64) -> f64 {
        if rows == 0 {
            return 0.0;
        }
        let m = &self.model;
        let tp = self.par.tp.max(1);
        ctx.price(&OpWorkload::Gemm {
            m: rows,
            n: (m.vocab_size / tp).max(1) as u64,
            k: m.d_model as u64,
        })
    }

    /// Full iteration time for a co-located / PD replica, seconds:
    /// all layers (attention + FFN) + LM head + engine overheads.
    pub fn iteration_time(&self, ctx: &mut CostCtx, shape: &BatchShape) -> f64 {
        if shape.is_empty() {
            return 0.0;
        }
        let tokens = shape.total_tokens() as u64;
        let mut attn_ops = self.attn_scratch.borrow_mut();
        self.attn_block_ops_into(shape, &mut attn_ops);
        let n_layers = (self.model.n_layers / self.par.pp.max(1)).max(1);
        let per_layer = if self.ep.is_some() && self.model.is_moe() {
            // EP path: the FFN stage prices (and prefetches) itself
            ctx.pred.prefetch(&mut attn_ops.iter());
            let attn: f64 = attn_ops.iter().map(|op| ctx.price(op)).sum();
            let ffn = if let Some(s) = self.moe_ffn_ep(ctx, tokens) {
                // one routing draw stands in for every layer of this
                // iteration (the once-per-iteration pricing convention):
                // scale the EP traffic accounting to the physical byte
                // volume so co-located and AF reports agree
                if let Some(mc) = ctx.metrics.as_deref_mut() {
                    for _ in 1..n_layers {
                        mc.record_ep(s.total_bytes, s.cross_bytes, s.rank_imbalance);
                    }
                }
                s.ffn_secs + s.dispatch_secs + s.combine_secs
            } else {
                let mut plans = self.plan_scratch.borrow_mut();
                let PlanScratch { loads, plan, .. } = &mut *plans;
                self.fill_ffn_plan(tokens, ctx.rng, loads, plan);
                self.price_ffn_plan(ctx, plan)
            };
            attn + ffn
        } else {
            // prefetch the whole iteration's ops up front so the
            // predictor batches its queries — chained borrows straight
            // out of the scratch buffers, no clones
            let mut plans = self.plan_scratch.borrow_mut();
            let PlanScratch { loads, plan, .. } = &mut *plans;
            self.fill_ffn_plan(tokens, ctx.rng, loads, plan);
            ctx.pred.prefetch(
                &mut attn_ops
                    .iter()
                    .chain(plan.common.iter())
                    .chain(plan.per_rank.iter().flatten()),
            );
            let attn: f64 = attn_ops.iter().map(|op| ctx.price(op)).sum();
            attn + self.price_ffn_plan_prefetched(ctx, plan)
        };
        drop(attn_ops);
        let layers = n_layers as f64;
        // pp>1: stages run concurrently; per-iteration latency is one
        // stage's layers (steady-state pipelining)
        let compute = per_layer * layers + self.lm_head_time(ctx, shape.lm_head_rows as u64);
        let o = &self.overhead;
        o.sched_overhead_s + layers * o.launch_gap_s + o.op_scale * compute
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::predictor::OraclePredictor;

    fn ctx_pieces() -> (OraclePredictor, Pcg64) {
        (OraclePredictor::a800(), Pcg64::new(7))
    }

    fn price(model: ModelConfig, par: Parallelism, shape: &BatchShape) -> f64 {
        let (mut pred, mut rng) = ctx_pieces();
        let cm = CostModel {
            overhead: OverheadConfig::zero(),
            ..CostModel::new(model, par, LinkSpec::nvlink_a800())
        };
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        cm.iteration_time(&mut ctx, shape)
    }

    fn decode_shape(n: usize, ctx_len: u32) -> BatchShape {
        BatchShape {
            prefill: vec![],
            decode_ctx: vec![ctx_len; n],
            lm_head_rows: n as u32,
        }
    }

    #[test]
    fn empty_batch_is_free() {
        let s = BatchShape::default();
        assert_eq!(price(ModelConfig::tiny(), Parallelism::default(), &s), 0.0);
    }

    #[test]
    fn decode_iteration_scales_with_batch() {
        let t1 = price(ModelConfig::qwen2_7b(), Parallelism::default(), &decode_shape(1, 512));
        let t32 = price(ModelConfig::qwen2_7b(), Parallelism::default(), &decode_shape(32, 512));
        assert!(t32 > t1);
        // but far sublinear (batching amortizes weights)
        assert!(t32 < 8.0 * t1, "t1={t1} t32={t32}");
    }

    #[test]
    fn prefill_dominates_equal_token_decode() {
        // 512 prefill tokens in one seq vs 1 decode token: prefill costs more
        let p = BatchShape { prefill: vec![(512, 0)], decode_ctx: vec![], lm_head_rows: 1 };
        let d = decode_shape(1, 512);
        let tp = price(ModelConfig::qwen2_7b(), Parallelism::default(), &p);
        let td = price(ModelConfig::qwen2_7b(), Parallelism::default(), &d);
        assert!(tp > 5.0 * td, "prefill {tp} decode {td}");
    }

    #[test]
    fn tp_reduces_iteration_time() {
        let m = ModelConfig::qwen2_72b();
        let s = BatchShape { prefill: vec![(2048, 0)], decode_ctx: vec![], lm_head_rows: 0 };
        let t1 = price(m.clone(), Parallelism::default(), &s);
        let t4 = price(m, Parallelism::tp(4), &s);
        assert!(t4 < t1, "tp4 {t4} vs tp1 {t1}");
    }

    #[test]
    fn moe_straggler_max_costs_more_than_mean() {
        let model = ModelConfig::tiny_moe();
        let (mut pred, mut rng) = ctx_pieces();
        let mut cm = CostModel {
            overhead: OverheadConfig::zero(),
            moe_routing: RoutingPolicy::Skewed { alpha: 0.05 },
            ..CostModel::new(model, Parallelism::new(1, 1, 4), LinkSpec::nvlink_a800())
        };
        let shape = decode_shape(64, 512);
        let mut rng2 = Pcg64::new(7);
        let mut pred2 = OraclePredictor::a800();
        let t_max = cm.iteration_time(
            &mut CostCtx { pred: &mut pred, rng: &mut rng, metrics: None },
            &shape,
        );
        cm.straggler_max = false;
        let t_mean = cm.iteration_time(
            &mut CostCtx { pred: &mut pred2, rng: &mut rng2, metrics: None },
            &shape,
        );
        assert!(t_max > t_mean, "max {t_max} vs mean {t_mean}");
    }

    #[test]
    fn moe_costs_more_than_dense_equivalent() {
        let dense = price(ModelConfig::tiny(), Parallelism::default(), &decode_shape(32, 256));
        let moe = price(ModelConfig::tiny_moe(), Parallelism::default(), &decode_shape(32, 256));
        assert!(moe > dense);
    }

    #[test]
    fn overheads_are_additive() {
        let model = ModelConfig::tiny();
        let shape = decode_shape(4, 128);
        let base = price(model.clone(), Parallelism::default(), &shape);
        let (mut pred, mut rng) = ctx_pieces();
        let cm = CostModel {
            overhead: OverheadConfig { sched_overhead_s: 1e-3, launch_gap_s: 0.0, op_scale: 1.0 },
            ..CostModel::new(model, Parallelism::default(), LinkSpec::nvlink_a800())
        };
        let t = cm.iteration_time(
            &mut CostCtx { pred: &mut pred, rng: &mut rng, metrics: None },
            &shape,
        );
        assert!((t - base - 1e-3).abs() < 1e-9);
    }

    #[test]
    fn metrics_accumulate_op_time() {
        let (mut pred, mut rng) = ctx_pieces();
        let mut mc = MetricsCollector::default();
        let cm = CostModel::new(
            ModelConfig::tiny(),
            Parallelism::default(),
            LinkSpec::nvlink_a800(),
        );
        let shape = decode_shape(8, 128);
        cm.iteration_time(
            &mut CostCtx { pred: &mut pred, rng: &mut rng, metrics: Some(&mut mc) },
            &shape,
        );
        assert!(mc.op_time.contains_key("gemm"));
        assert!(mc.op_time.contains_key("attn_decode"));
    }

    #[test]
    fn ep_spec_routes_ffn_through_placement() {
        use crate::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy};
        let mut cm = CostModel::new(
            ModelConfig::tiny_moe(),
            Parallelism::new(1, 1, 4),
            LinkSpec::nvlink_a800(),
        );
        cm.overhead = OverheadConfig::zero();
        let topo = EpTopology::new(4, 2);
        cm.ep = Some(EpSpec::flat(
            ExpertPlacement::build(PlacementPolicy::Contiguous, 8, topo, None),
            LinkSpec::nvlink_a800(),
            LinkSpec::cross_cluster(),
        ));
        let (mut pred, mut rng) = ctx_pieces();
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        let s = cm.moe_ffn_ep(&mut ctx, 128).expect("ep path applies");
        assert!(s.ffn_secs > 0.0 && s.dispatch_secs > 0.0 && s.combine_secs > 0.0);
        assert!(s.cross_bytes > 0.0 && s.cross_bytes < s.total_bytes);
        assert!(s.rank_imbalance >= 1.0);
        // empty batches and dense models fall back to the legacy path
        assert!(cm.moe_ffn_ep(&mut ctx, 0).is_none());
        let dense = CostModel::new(ModelConfig::tiny(), Parallelism::default(), LinkSpec::nvlink_a800());
        let mut ctx2 = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
        assert!(dense.moe_ffn_ep(&mut ctx2, 128).is_none());
    }

    #[test]
    fn ep_metrics_are_recorded() {
        use crate::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy};
        let mut cm = CostModel::new(
            ModelConfig::tiny_moe(),
            Parallelism::new(1, 1, 4),
            LinkSpec::nvlink_a800(),
        );
        cm.ep = Some(EpSpec::flat(
            ExpertPlacement::build(
                PlacementPolicy::Strided,
                8,
                EpTopology::new(4, 1),
                None,
            ),
            LinkSpec::nvlink_a800(),
            LinkSpec::cross_cluster(),
        ));
        let (mut pred, mut rng) = ctx_pieces();
        let mut mc = MetricsCollector::default();
        let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: Some(&mut mc) };
        let t = cm.ffn_block_time(&mut ctx, 256);
        assert!(t > 0.0);
        assert!(mc.ep_bytes > 0.0);
        assert_eq!(mc.ep_cross_bytes, 0.0); // single cluster
        assert_eq!(mc.ep_draws, 1);
        assert!(mc.op_time.contains_key("ep_dispatch"));
        assert!(mc.op_time.contains_key("ep_combine"));
    }

    #[test]
    fn scratch_reuse_is_deterministic_across_draws() {
        use crate::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy};
        let mk = || {
            let mut cm = CostModel::new(
                ModelConfig::tiny_moe(),
                Parallelism::new(1, 1, 4),
                LinkSpec::nvlink_a800(),
            );
            cm.moe_routing = RoutingPolicy::Skewed { alpha: 0.1 };
            cm.ep = Some(EpSpec::flat(
                ExpertPlacement::build(
                    PlacementPolicy::Contiguous,
                    8,
                    EpTopology::new(4, 2),
                    None,
                ),
                LinkSpec::nvlink_a800(),
                LinkSpec::cross_cluster(),
            ));
            cm
        };
        let cm_warm = mk();
        let cm_cold = mk();
        // warm one model's scratch with throwaway draws on another stream
        {
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(999);
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
            for _ in 0..3 {
                cm_warm.moe_ffn_ep(&mut ctx, 96).unwrap();
            }
        }
        // identical rng streams must now price identically regardless of
        // scratch history (reset() fully re-initializes occupancy)
        let sample = |cm: &CostModel| {
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(7);
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
            (0..4).map(|_| cm.moe_ffn_ep(&mut ctx, 128).unwrap()).collect::<Vec<_>>()
        };
        for (a, b) in sample(&cm_warm).iter().zip(sample(&cm_cold).iter()) {
            assert_eq!(a.ffn_secs, b.ffn_secs);
            assert_eq!(a.dispatch_secs, b.dispatch_secs);
            assert_eq!(a.combine_secs, b.combine_secs);
            assert_eq!(a.total_bytes, b.total_bytes);
            assert_eq!(a.cross_bytes, b.cross_bytes);
        }
    }

    #[test]
    fn ep_batch_pricing_matches_sequential() {
        use crate::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy};
        let mk = || {
            let mut cm = CostModel::new(
                ModelConfig::tiny_moe(),
                Parallelism::new(1, 1, 4),
                LinkSpec::nvlink_a800(),
            );
            cm.moe_routing = RoutingPolicy::Skewed { alpha: 0.1 };
            cm.capacity_factor = Some(1.5);
            cm.ep = Some(EpSpec::flat(
                ExpertPlacement::build(
                    PlacementPolicy::Contiguous,
                    8,
                    EpTopology::new(4, 2),
                    None,
                ),
                LinkSpec::nvlink_a800(),
                LinkSpec::cross_cluster(),
            ));
            cm
        };
        let n_draws = 6;
        // sequential reference: n_draws back-to-back single-draw calls
        let cm_seq = mk();
        let mut pred = OraclePredictor::a800();
        let mut rng = Pcg64::new(11);
        let mut mc_seq = MetricsCollector::default();
        let seq: Vec<MoeEpSample> = {
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: Some(&mut mc_seq) };
            (0..n_draws).map(|_| cm_seq.moe_ffn_ep(&mut ctx, 128).unwrap()).collect()
        };
        // batched: one call, same seed — bit-identical samples + metrics
        let cm_batch = mk();
        let mut pred_b = OraclePredictor::a800();
        let mut rng_b = Pcg64::new(11);
        let mut mc_batch = MetricsCollector::default();
        let mut batch = Vec::new();
        {
            let mut ctx =
                CostCtx { pred: &mut pred_b, rng: &mut rng_b, metrics: Some(&mut mc_batch) };
            cm_batch.moe_ffn_ep_batch(&mut ctx, 128, n_draws, &mut batch).unwrap();
        }
        assert_eq!(batch.len(), n_draws);
        for (a, b) in seq.iter().zip(batch.iter()) {
            assert_eq!(a.ffn_secs, b.ffn_secs);
            assert_eq!(a.dispatch_secs, b.dispatch_secs);
            assert_eq!(a.combine_secs, b.combine_secs);
            assert_eq!(a.total_bytes, b.total_bytes);
            assert_eq!(a.cross_bytes, b.cross_bytes);
            assert_eq!(a.rank_imbalance, b.rank_imbalance);
        }
        assert_eq!(mc_seq.op_time, mc_batch.op_time, "op accounting must not drift");
        assert_eq!(mc_seq.ep_bytes, mc_batch.ep_bytes);
        assert_eq!(mc_seq.ep_cross_bytes, mc_batch.ep_cross_bytes);
        assert_eq!(mc_seq.ep_draws, mc_batch.ep_draws);
        assert_eq!(mc_seq.dropped_tokens, mc_batch.dropped_tokens);
        // rng streams consumed identically: a follow-up draw agrees
        let next_seq = {
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
            cm_seq.moe_ffn_ep(&mut ctx, 96).unwrap()
        };
        let next_batch = {
            let mut ctx = CostCtx { pred: &mut pred_b, rng: &mut rng_b, metrics: None };
            cm_batch.moe_ffn_ep(&mut ctx, 96).unwrap()
        };
        assert_eq!(next_seq.ffn_secs, next_batch.ffn_secs);
        // n_draws == 0 clears the output and is not an error
        let mut empty = vec![batch[0]];
        let mut ctx = CostCtx { pred: &mut pred_b, rng: &mut rng_b, metrics: None };
        cm_batch.moe_ffn_ep_batch(&mut ctx, 128, 0, &mut empty).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn load_tracker_observes_without_perturbing_prices() {
        use crate::moe::{EpSpec, EpTopology, ExpertPlacement, LoadEstimator, PlacementPolicy};
        let mk = |tracked: bool| {
            let mut cm = CostModel::new(
                ModelConfig::tiny_moe(),
                Parallelism::new(1, 1, 4),
                LinkSpec::nvlink_a800(),
            );
            cm.moe_routing = RoutingPolicy::Skewed { alpha: 0.1 };
            cm.ep = Some(EpSpec::flat(
                ExpertPlacement::build(
                    PlacementPolicy::Contiguous,
                    8,
                    EpTopology::new(4, 1),
                    None,
                ),
                LinkSpec::nvlink_a800(),
                LinkSpec::cross_cluster(),
            ));
            if tracked {
                cm.load_tracker = Some(RefCell::new(LoadEstimator::new(8, 8)));
            }
            cm
        };
        let sample = |cm: &CostModel| {
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(21);
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
            (0..6)
                .map(|_| cm.moe_ffn_ep(&mut ctx, 128).unwrap())
                .map(|s| s.ffn_secs + s.dispatch_secs + s.combine_secs)
                .collect::<Vec<f64>>()
        };
        let tracked = mk(true);
        let untracked = mk(false);
        assert_eq!(sample(&tracked), sample(&untracked), "tracking must be free");
        let est = tracked.load_tracker.as_ref().unwrap().borrow();
        assert_eq!(est.draws(), 6, "one observation per routing draw");
        // each draw routes 128 tokens * top_k 2 slots; the EWMA estimate
        // conserves that total
        let total: f64 = est.estimate().iter().sum();
        assert!((total - 256.0).abs() < 1e-6, "estimate total {total}");
    }

    #[test]
    fn drifting_routing_matches_skewed_until_the_first_flip() {
        // draw-for-draw parity inside epoch 0, divergence after
        let mk = |routing: RoutingPolicy| {
            let mut cm = CostModel::new(
                ModelConfig::tiny_moe(),
                Parallelism::new(1, 1, 4),
                LinkSpec::nvlink_a800(),
            );
            cm.moe_routing = routing;
            cm.overhead = OverheadConfig::zero();
            cm
        };
        let drift = mk(RoutingPolicy::Drifting { alpha: 0.1, period: 4 });
        let skew = mk(RoutingPolicy::Skewed { alpha: 0.1 });
        let sample = |cm: &CostModel| {
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(3);
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: None };
            (0..8).map(|_| cm.ffn_block_time(&mut ctx, 64)).collect::<Vec<f64>>()
        };
        let d = sample(&drift);
        let s = sample(&skew);
        assert_eq!(d[..4], s[..4], "epoch 0 must be bit-identical to skewed");
        assert_ne!(d[4..], s[4..], "epoch 1 must redraw popularity");
    }

    #[test]
    fn capacity_factor_drops_are_metered() {
        let run = |cf: Option<f64>, ep: u32| {
            let mut cm = CostModel::new(
                ModelConfig::tiny_moe(),
                Parallelism::new(1, 1, ep),
                LinkSpec::nvlink_a800(),
            );
            cm.moe_routing = RoutingPolicy::Skewed { alpha: 0.05 };
            cm.capacity_factor = cf;
            if ep > 1 {
                use crate::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy};
                cm.ep = Some(EpSpec::flat(
                    ExpertPlacement::build(
                        PlacementPolicy::Contiguous,
                        8,
                        EpTopology::new(ep, 1),
                        None,
                    ),
                    LinkSpec::nvlink_a800(),
                    LinkSpec::cross_cluster(),
                ));
            }
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(5);
            let mut mc = MetricsCollector::default();
            let mut ctx = CostCtx { pred: &mut pred, rng: &mut rng, metrics: Some(&mut mc) };
            let t = cm.ffn_block_time(&mut ctx, 512);
            (t, mc.dropped_tokens)
        };
        // tight cap under heavy skew drops on both the closed-form plan
        // path (ep=1, no EpSpec) and the EP placement path
        let (_, d_plan) = run(Some(1.0), 1);
        assert!(d_plan > 0, "plan path must also meter drops");
        let (t_capped, d_ep) = run(Some(1.0), 4);
        assert!(d_ep > 0, "skewed routing under cf=1.0 must drop");
        let (t_uncapped, d_none) = run(None, 4);
        assert_eq!(d_none, 0);
        // dropping tokens removes expert work: capped is never slower
        assert!(t_capped <= t_uncapped, "{t_capped} vs {t_uncapped}");
    }

    #[test]
    fn aggregate_fidelity_prices_the_same_workflow() {
        use crate::moe::{EpSpec, EpTopology, ExpertPlacement, PlacementPolicy, RoutingFidelity};
        let mk = |fidelity: RoutingFidelity| {
            let mut cm = CostModel::new(
                ModelConfig::tiny_moe(),
                Parallelism::new(1, 1, 4),
                LinkSpec::nvlink_a800(),
            );
            cm.moe_routing = RoutingPolicy::Skewed { alpha: 0.1 };
            cm.routing_fidelity = fidelity;
            cm.ep = Some(EpSpec::flat(
                ExpertPlacement::build(
                    PlacementPolicy::Contiguous,
                    8,
                    EpTopology::new(4, 2),
                    None,
                ),
                LinkSpec::nvlink_a800(),
                LinkSpec::cross_cluster(),
            ));
            cm
        };
        let run = |cm: &CostModel| {
            let mut pred = OraclePredictor::a800();
            let mut rng = Pcg64::new(17);
            let mut mc = MetricsCollector::default();
            let t: f64 = {
                let mut ctx =
                    CostCtx { pred: &mut pred, rng: &mut rng, metrics: Some(&mut mc) };
                (0..4).map(|_| cm.ffn_block_time(&mut ctx, 256)).sum()
            };
            (t, mc)
        };
        let (t_tok, mc_tok) = run(&mk(RoutingFidelity::Token));
        let (t_agg, mc_agg) = run(&mk(RoutingFidelity::Aggregate));
        // both fidelities drive the full EP workflow with conserved
        // traffic: same routed-byte volume (no drops), different streams
        assert!(t_tok > 0.0 && t_agg > 0.0);
        assert_eq!(mc_tok.ep_draws, mc_agg.ep_draws);
        assert!(
            (mc_tok.ep_bytes - mc_agg.ep_bytes).abs() < 1e-6 * mc_tok.ep_bytes,
            "conserved routing => identical byte volume: {} vs {}",
            mc_tok.ep_bytes,
            mc_agg.ep_bytes
        );
        // the two samplers price within the same ballpark (same load
        // distribution up to the aggregate approximation)
        let ratio = t_agg / t_tok;
        assert!((0.5..2.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn ffn_plan_structure() {
        let cm = CostModel::new(
            ModelConfig::tiny_moe(),
            Parallelism::new(1, 1, 4),
            LinkSpec::nvlink_a800(),
        );
        let mut rng = Pcg64::new(1);
        let plan = cm.ffn_block_plan(128, &mut rng);
        assert_eq!(plan.per_rank.len(), 4);
        assert!(plan.per_rank.iter().all(|ops| ops.len() == 2));
        // gate + 2 a2a for ep>1
        assert!(plan.common.len() >= 3);
        // dense has no rank groups
        let cm_d = CostModel::new(
            ModelConfig::tiny(),
            Parallelism::default(),
            LinkSpec::nvlink_a800(),
        );
        let plan_d = cm_d.ffn_block_plan(128, &mut rng);
        assert!(plan_d.per_rank.is_empty());
        assert_eq!(plan_d.common.len(), 2);
    }
}
