//! Workflow simulation: iteration cost decomposition and the
//! disaggregation pipelines.
//!
//! * [`cost`] — decomposes one replica iteration (mixed
//!   prefill/decode batch) into the operator micro-workflow and prices
//!   it through an [`crate::predictor::ExecutionPredictor`], including
//!   the MoE data-dependent sub-workflow of §3.3.
//! * [`af`] — the AF-disaggregation event-dependency-graph executor
//!   (micro-batched ping-pong pipeline).

pub mod af;
pub mod cost;

pub use cost::{BatchShape, CostCtx, CostModel, MoeEpSample};
