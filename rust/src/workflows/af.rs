//! AF disaggregation: the event-dependency-graph executor (§3.3).
//!
//! One decode step = a graph of fine-grained events over `L` layers and
//! `m` micro-batches with four serialized resources: the attention pool,
//! the FFN pool, and the two transfer directions. Dependencies per
//! micro-batch `k`:
//!
//! ```text
//! ATTN(l,k) -> A2F(l,k) -> FFN(l,k) -> F2A(l,k) -> ATTN(l+1,k)
//! ```
//!
//! The executor schedules each event as soon as its dependency has fired
//! *and* its resource is free (FIFO by ready time) — while `A2F(l,k)` is
//! in flight the attention pool picks up `ATTN(l,k+1)`, which is exactly
//! the latency-hiding ping-pong pipeline of MegaScale-Infer/Step-3.
//! Step time = completion of the final `FFN(L-1,m-1)` plus its return
//! transfer.

use crate::core::{EventQueue, SimTime};

/// Durations for one decode step's graph.
///
/// All four stages are per-`(layer, micro)` so data-dependent effects —
/// MoE expert stragglers in `ffn_time`, routing-skew-dependent EP
/// dispatch/combine in `a2f_time`/`f2a_time` — flow straight into the
/// pipeline executor.
#[derive(Clone, Debug)]
pub struct AfStep {
    /// attn_time[l][k]: attention stage of layer l, micro-batch k (sec).
    pub attn_time: Vec<Vec<f64>>,
    /// ffn_time[l][k] (sec).
    pub ffn_time: Vec<Vec<f64>>,
    /// a2f_time[l][k]: attn->ffn activation dispatch (sec).
    pub a2f_time: Vec<Vec<f64>>,
    /// f2a_time[l][k]: ffn->attn combine/return (sec).
    pub f2a_time: Vec<Vec<f64>>,
}

impl AfStep {
    /// Uniform stage times (the common analytical case).
    pub fn uniform(layers: usize, micros: usize, attn: f64, ffn: f64, xfer: f64) -> Self {
        AfStep {
            attn_time: vec![vec![attn; micros]; layers],
            ffn_time: vec![vec![ffn; micros]; layers],
            a2f_time: vec![vec![xfer; micros]; layers],
            f2a_time: vec![vec![xfer; micros]; layers],
        }
    }

    pub fn layers(&self) -> usize {
        self.attn_time.len()
    }

    pub fn micros(&self) -> usize {
        self.attn_time.first().map_or(0, |v| v.len())
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum Stage {
    Attn,
    A2f,
    Ffn,
    F2a,
}

#[derive(Clone, Copy, Debug)]
struct Task {
    stage: Stage,
    layer: usize,
    micro: usize,
}

#[derive(Clone, Copy, Debug)]
enum AfEv {
    /// A task's dependency fired: it joins its resource queue.
    Ready(Task),
    /// A resource finished its current task.
    Done(Task),
}

/// Simulate one AF decode step; returns (step seconds, per-resource busy
/// seconds `[attn, ffn, a2f, f2a]` for bubble accounting).
pub fn af_step(step: &AfStep) -> (f64, [f64; 4]) {
    let layers = step.layers();
    let micros = step.micros();
    if layers == 0 || micros == 0 {
        return (0.0, [0.0; 4]);
    }
    let mut q: EventQueue<AfEv> = EventQueue::new();
    // per-resource FIFO of ready tasks + busy flag
    let mut ready: [std::collections::VecDeque<Task>; 4] = Default::default();
    let mut busy = [false; 4];
    let mut busy_time = [0.0f64; 4];
    let mut last_done = SimTime::ZERO;

    let res_of = |s: Stage| match s {
        Stage::Attn => 0,
        Stage::Ffn => 1,
        Stage::A2f => 2,
        Stage::F2a => 3,
    };
    let dur = |t: &Task| match t.stage {
        Stage::Attn => step.attn_time[t.layer][t.micro],
        Stage::Ffn => step.ffn_time[t.layer][t.micro],
        Stage::A2f => step.a2f_time[t.layer][t.micro],
        Stage::F2a => step.f2a_time[t.layer][t.micro],
    };

    for k in 0..micros {
        q.schedule_at(SimTime::ZERO, AfEv::Ready(Task { stage: Stage::Attn, layer: 0, micro: k }));
    }

    while let Some(ev) = q.pop() {
        match ev.kind {
            AfEv::Ready(t) => {
                ready[res_of(t.stage)].push_back(t);
            }
            AfEv::Done(t) => {
                busy[res_of(t.stage)] = false;
                last_done = q.now();
                // fire the dependent task
                let next = match t.stage {
                    Stage::Attn => Some(Task { stage: Stage::A2f, ..t }),
                    Stage::A2f => Some(Task { stage: Stage::Ffn, ..t }),
                    Stage::Ffn => Some(Task { stage: Stage::F2a, ..t }),
                    Stage::F2a => {
                        if t.layer + 1 < layers {
                            Some(Task { stage: Stage::Attn, layer: t.layer + 1, micro: t.micro })
                        } else {
                            None
                        }
                    }
                };
                if let Some(n) = next {
                    q.schedule_at(q.now(), AfEv::Ready(n));
                }
            }
        }
        // dispatch any free resource with work (after each event so that
        // Ready/Done at the same timestamp coalesce deterministically)
        for r in 0..4 {
            if !busy[r] {
                if let Some(t) = ready[r].pop_front() {
                    busy[r] = true;
                    let d = dur(&t);
                    busy_time[r] += d;
                    q.schedule_in(SimTime::from_secs_f64(d), AfEv::Done(t));
                }
            }
        }
    }
    (last_done.as_secs_f64(), busy_time)
}

/// Pipeline-efficiency summary for a step: fraction of the step the
/// attention pool was busy (1.0 = no bubbles on the critical resource).
pub fn attn_utilization(step: &AfStep) -> f64 {
    let (total, busy) = af_step(step);
    if total <= 0.0 {
        return 0.0;
    }
    busy[0] / total
}

#[cfg(test)]
mod tests {
    use super::*;

    const EPS: f64 = 1e-9;

    #[test]
    fn single_micro_batch_is_serial() {
        // m=1: no overlap possible — strict sum of all stages
        let s = AfStep::uniform(4, 1, 10e-6, 20e-6, 5e-6);
        let (t, _) = af_step(&s);
        let expect = 4.0 * (10e-6 + 5e-6 + 20e-6 + 5e-6);
        assert!((t - expect).abs() < EPS, "{t} vs {expect}");
    }

    #[test]
    fn two_micro_batches_overlap() {
        // balanced ping-pong: attn(k+1) runs while ffn(k) computes
        let serial = AfStep::uniform(8, 1, 20e-6, 20e-6, 2e-6);
        let (t1, _) = af_step(&serial);
        // same total work split into 2 micro-batches of half size
        let pipelined = AfStep::uniform(8, 2, 10e-6, 10e-6, 1e-6);
        let (t2, _) = af_step(&pipelined);
        assert!(t2 < 0.75 * t1, "pipelined {t2} vs serial {t1}");
    }

    #[test]
    fn perfectly_balanced_pipeline_hides_transfers() {
        // with m=2 and attn == ffn >> xfer, both pools stay ~busy:
        // step ~= 2 * L * stage (each pool does 2L stage-units serially)
        let l = 16;
        let stage = 50e-6;
        let s = AfStep::uniform(l, 2, stage, stage, 1e-6);
        let (t, busy) = af_step(&s);
        let lower = 2.0 * l as f64 * stage;
        assert!(t >= lower - EPS);
        assert!(t < lower * 1.1, "bubbles too large: {t} vs {lower}");
        // attention pool utilization near 1
        assert!(busy[0] / t > 0.85, "attn util {}", busy[0] / t);
    }

    #[test]
    fn imbalanced_stages_create_bubbles() {
        let balanced = AfStep::uniform(8, 2, 30e-6, 30e-6, 1e-6);
        // same per-step total (60us) but imbalanced 50/10
        let imbalanced = AfStep::uniform(8, 2, 50e-6, 10e-6, 1e-6);
        let (tb, _) = af_step(&balanced);
        let (ti, _) = af_step(&imbalanced);
        // imbalance does not help; the slow stage serializes
        assert!(ti >= tb - EPS, "imbalanced {ti} vs balanced {tb}");
        assert!(attn_utilization(&imbalanced) > 0.9); // attn is the bottleneck
    }

    #[test]
    fn heterogeneous_micro_batches() {
        // one slow micro-batch (MoE straggler) lengthens the step
        let mut s = AfStep::uniform(4, 4, 10e-6, 10e-6, 1e-6);
        let (t_uniform, _) = af_step(&s);
        s.ffn_time[2][1] = 80e-6;
        let (t_straggler, _) = af_step(&s);
        assert!(t_straggler > t_uniform + 60e-6);
    }

    #[test]
    fn heterogeneous_transfers_lengthen_step() {
        // one slow dispatch (EP routing skew) delays everything behind it
        let base = AfStep::uniform(4, 2, 10e-6, 10e-6, 1e-6);
        let (t0, _) = af_step(&base);
        let mut s = base.clone();
        s.a2f_time[1][0] = 50e-6;
        let (t1, _) = af_step(&s);
        assert!(t1 > t0, "{t1} vs {t0}");
    }

    #[test]
    fn empty_step() {
        let s = AfStep { attn_time: vec![], ffn_time: vec![], a2f_time: vec![], f2a_time: vec![] };
        assert_eq!(af_step(&s).0, 0.0);
    }

    #[test]
    fn more_micro_batches_reduce_latency_until_transfer_bound() {
        // total work fixed; sweep m — the paper's ablation A3 shape
        let l = 8;
        let total_attn = 80e-6;
        let total_ffn = 80e-6;
        let mut prev = f64::INFINITY;
        let mut times = Vec::new();
        for m in [1usize, 2, 4] {
            let s = AfStep::uniform(
                l,
                m,
                total_attn / m as f64,
                total_ffn / m as f64,
                2e-6,
            );
            let (t, _) = af_step(&s);
            times.push(t);
            assert!(t <= prev * 1.01, "m={m}: {t} vs prev {prev}");
            prev = t;
        }
        // m=2 must be a real improvement over m=1
        assert!(times[1] < 0.7 * times[0], "{times:?}");
    }
}
