//! Streaming quantile digest: a merging t-digest with the k1 (arcsine)
//! scale function.
//!
//! Replaces the unbounded `Vec<f64>` sample vectors in
//! [`MetricsCollector`](super::MetricsCollector): memory is O(δ)
//! centroids regardless of how many samples are recorded (≈160
//! centroids at 1e6 samples for δ=256), while tail quantiles stay
//! within a few tenths of a percent of exact. Samples accumulate in a
//! fixed buffer and are merged into the centroid list when it fills;
//! the merge criterion `k(q_right) − k_left ≤ 1` with
//! `k(q) = δ/2π·asin(2q−1)` concentrates resolution at both tails.
//!
//! Deterministic: the digest state is a pure function of the insertion
//! order, so bit-reproducibility tests can compare digests directly
//! (`PartialEq`). The exact sorted-vector computation lives on as the
//! in-tree oracle ([`super::percentile`]) that tolerance tests pin
//! against.

const BUFFER_CAP: usize = 512;

/// Merging t-digest over f64 samples. `Default` uses compression
/// δ = 256 (≤ ~2δ centroids, p99 within ~1% at 1e6 samples).
#[derive(Clone, Debug, PartialEq)]
pub struct Digest {
    compression: f64,
    /// `(mean, weight)` clusters, sorted by mean.
    centroids: Vec<(f64, f64)>,
    buffer: Vec<f64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Digest {
    fn default() -> Self {
        Digest::new(256.0)
    }
}

impl Digest {
    pub fn new(compression: f64) -> Self {
        debug_assert!(compression >= 16.0);
        Digest {
            compression,
            centroids: Vec::new(),
            buffer: Vec::new(),
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample. Amortized O(1); flushes the buffer into the
    /// centroid list every [`BUFFER_CAP`] samples.
    pub fn record(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "digest sample must be finite, got {x}");
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.buffer.push(x);
        if self.buffer.len() >= BUFFER_CAP {
            self.flush();
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean (running sum, not centroid means): summation order
    /// matches summing the raw sample vector, so results are
    /// bit-identical to the pre-digest code.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Current centroid count (memory-bound assertions in tests/benches).
    pub fn centroids(&self) -> usize {
        self.centroids.len()
    }

    /// Samples sitting in the unmerged buffer.
    pub fn buffered(&self) -> usize {
        self.buffer.len()
    }

    /// k1 scale function: δ/2π · asin(2q − 1).
    fn k(&self, q: f64) -> f64 {
        self.compression / (2.0 * std::f64::consts::PI)
            * (2.0 * q - 1.0).clamp(-1.0, 1.0).asin()
    }

    /// Merge the buffer into the centroid list and re-compress.
    fn flush(&mut self) {
        if self.buffer.is_empty() {
            return;
        }
        self.buffer.sort_by(|a, b| a.total_cmp(b));
        // two-pointer merge of the sorted centroids and the sorted
        // buffer (as singletons)
        let mut merged: Vec<(f64, f64)> =
            Vec::with_capacity(self.centroids.len() + self.buffer.len());
        let (cs, buf) = (&self.centroids, &self.buffer);
        let (mut i, mut j) = (0, 0);
        while i < cs.len() || j < buf.len() {
            if j >= buf.len() || (i < cs.len() && cs[i].0 <= buf[j]) {
                merged.push(cs[i]);
                i += 1;
            } else {
                merged.push((buf[j], 1.0));
                j += 1;
            }
        }
        // compress: grow each cluster while it spans ≤ 1 unit of
        // k-space
        let total = self.count as f64;
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(self.centroids.len() + 16);
        let (mut acc_m, mut acc_w) = merged[0];
        let mut w_before = 0.0;
        let mut k_left = self.k(0.0);
        for &(m, w) in &merged[1..] {
            let q_right = (w_before + acc_w + w) / total;
            if self.k(q_right) - k_left <= 1.0 {
                let nw = acc_w + w;
                acc_m += (m - acc_m) * w / nw;
                acc_w = nw;
            } else {
                w_before += acc_w;
                out.push((acc_m, acc_w));
                k_left = self.k(w_before / total);
                acc_m = m;
                acc_w = w;
            }
        }
        out.push((acc_m, acc_w));
        self.centroids = out;
        self.buffer.clear();
    }

    /// Merge another digest into this one (shard-local collectors
    /// folding into the run-level collector). Both sides are flushed
    /// first, then the two sorted centroid lists are merged through the
    /// same k-scale compression as [`Digest::flush`], so the result is
    /// a pure function of `(self, other)` — independent of thread
    /// count or merge timing, which is what the parallel engine's
    /// determinism contract needs.
    pub fn merge(&mut self, other: &Digest) {
        if other.count == 0 {
            return;
        }
        self.flush();
        // flush the other side into centroids without mutating it
        let other_flushed;
        let ocs: &[(f64, f64)] = if other.buffer.is_empty() {
            &other.centroids
        } else {
            let mut d = other.clone();
            d.flush();
            other_flushed = d.centroids;
            &other_flushed
        };
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        // two-pointer merge of the two sorted centroid lists
        let mut merged: Vec<(f64, f64)> =
            Vec::with_capacity(self.centroids.len() + ocs.len());
        let cs = &self.centroids;
        let (mut i, mut j) = (0, 0);
        while i < cs.len() || j < ocs.len() {
            if j >= ocs.len() || (i < cs.len() && cs[i].0 <= ocs[j].0) {
                merged.push(cs[i]);
                i += 1;
            } else {
                merged.push(ocs[j]);
                j += 1;
            }
        }
        // compress with the combined total, same criterion as flush()
        let total = self.count as f64;
        let mut out: Vec<(f64, f64)> = Vec::with_capacity(merged.len().min(1024));
        let (mut acc_m, mut acc_w) = merged[0];
        let mut w_before = 0.0;
        let mut k_left = self.k(0.0);
        for &(m, w) in &merged[1..] {
            let q_right = (w_before + acc_w + w) / total;
            if self.k(q_right) - k_left <= 1.0 {
                let nw = acc_w + w;
                acc_m += (m - acc_m) * w / nw;
                acc_w = nw;
            } else {
                w_before += acc_w;
                out.push((acc_m, acc_w));
                k_left = self.k(w_before / total);
                acc_m = m;
                acc_w = w;
            }
        }
        out.push((acc_m, acc_w));
        self.centroids = out;
    }

    /// Estimate the `p`-th percentile (`p` in 0..=100; out-of-range
    /// values clamp). Empty digest returns 0.0, matching the exact
    /// oracle's convention. `&self`: a buffered digest clones itself to
    /// flush, so report-time reads never mutate collected state.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if !self.buffer.is_empty() {
            let mut d = self.clone();
            d.flush();
            return d.quantile(p);
        }
        let cs = &self.centroids;
        let total = self.count as f64;
        let rank = (p / 100.0).clamp(0.0, 1.0) * total;
        let (m0, w0) = cs[0];
        if rank <= w0 / 2.0 {
            if w0 <= 1.0 {
                return m0; // singleton: exact
            }
            return self.min + (rank / (w0 / 2.0)) * (m0 - self.min);
        }
        let mut w_before = 0.0;
        for win in cs.windows(2) {
            let (mi, wi) = win[0];
            let (mj, wj) = win[1];
            let mid_i = w_before + wi / 2.0;
            let mid_j = w_before + wi + wj / 2.0;
            if rank < mid_j {
                let frac = (rank - mid_i) / (mid_j - mid_i);
                return mi + frac * (mj - mi);
            }
            w_before += wi;
        }
        let (ml, wl) = *cs.last().unwrap();
        let mid = w_before + wl / 2.0;
        let denom = total - mid;
        if denom <= 0.0 {
            return self.max;
        }
        let frac = ((rank - mid) / denom).clamp(0.0, 1.0);
        ml + frac * (self.max - ml)
    }
}

#[cfg(test)]
mod tests {
    use super::super::percentile;
    use super::*;
    use crate::core::Pcg64;

    fn lognormal_stream(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        (0..n).map(|_| rng.lognormal(0.0, 0.8)).collect()
    }

    fn digest_of(xs: &[f64]) -> Digest {
        let mut d = Digest::default();
        for &x in xs {
            d.record(x);
        }
        d
    }

    #[test]
    fn empty_digest_is_zero() {
        let d = Digest::default();
        assert_eq!(d.quantile(50.0), 0.0);
        assert_eq!(d.mean(), 0.0);
        assert_eq!(d.count(), 0);
        assert!(d.is_empty());
    }

    #[test]
    fn small_n_tracks_exact_oracle() {
        // 100 distinct values: every centroid stays a singleton, so the
        // digest is within one rank of exact nearest-rank everywhere
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let d = digest_of(&xs);
        for p in [0.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let exact = percentile(&xs, p);
            let got = d.quantile(p);
            assert!((got - exact).abs() <= 1.0, "p{p}: exact {exact} digest {got}");
        }
        assert_eq!(d.min(), 1.0);
        assert_eq!(d.max(), 100.0);
        assert!((d.mean() - 50.5).abs() < 1e-12);
    }

    #[test]
    fn heavy_tail_within_tolerance_of_oracle() {
        // the digest-vs-oracle pinning test: p50/p90/p99 within 2% on a
        // heavy-tailed stream, p99.9 within 5% (tail centroids are
        // wider)
        let xs = lognormal_stream(50_000, 42);
        let d = digest_of(&xs);
        for (p, tol) in [(50.0, 0.02), (90.0, 0.02), (99.0, 0.02), (99.9, 0.05)] {
            let exact = percentile(&xs, p);
            let got = d.quantile(p);
            let rel = (got - exact).abs() / exact.abs().max(1e-12);
            assert!(rel < tol, "p{p}: exact {exact:.5} digest {got:.5} rel {rel:.4}");
        }
    }

    #[test]
    fn memory_bounded_at_one_million_samples() {
        let mut d = Digest::default();
        let mut rng = Pcg64::new(7);
        for _ in 0..1_000_000u32 {
            d.record(rng.next_f64());
        }
        // the whole point: state is O(compression), not O(n)
        assert!(
            d.centroids() + d.buffered() <= 2 * 256 + BUFFER_CAP,
            "digest grew: {} centroids + {} buffered",
            d.centroids(),
            d.buffered()
        );
        assert!((d.quantile(50.0) - 0.5).abs() < 0.01);
        assert!((d.quantile(99.0) - 0.99).abs() < 0.01);
        assert_eq!(d.count(), 1_000_000);
    }

    #[test]
    fn deterministic_and_comparable() {
        let xs = lognormal_stream(5_000, 9);
        assert_eq!(digest_of(&xs), digest_of(&xs));
        let mut shifted = xs.clone();
        for x in &mut shifted {
            *x *= 1.15;
        }
        // ordering of close streams is preserved at the tail
        assert!(digest_of(&xs).quantile(99.0) < digest_of(&shifted).quantile(99.0));
    }

    #[test]
    fn merge_matches_single_stream_within_tolerance() {
        // shard-split streams merged back together must agree with the
        // single-stream digest on count/sum/min/max exactly and on
        // quantiles within the digest's own tolerance
        let xs = lognormal_stream(40_000, 11);
        let whole = digest_of(&xs);
        let mut merged = Digest::default();
        for chunk in xs.chunks(7_919) {
            let part = digest_of(chunk);
            merged.merge(&part);
        }
        assert_eq!(merged.count(), whole.count());
        assert!((merged.sum() - whole.sum()).abs() < 1e-6 * whole.sum().abs());
        assert_eq!(merged.min(), whole.min());
        assert_eq!(merged.max(), whole.max());
        for p in [50.0, 90.0, 99.0] {
            let exact = percentile(&xs, p);
            let got = merged.quantile(p);
            let rel = (got - exact).abs() / exact.abs().max(1e-12);
            assert!(rel < 0.03, "p{p}: exact {exact:.5} merged {got:.5} rel {rel:.4}");
        }
        // memory stays bounded through repeated merges
        assert!(merged.centroids() <= 2 * 256);
    }

    #[test]
    fn merge_empty_is_identity_and_into_empty_adopts() {
        let xs = lognormal_stream(3_000, 5);
        let d = digest_of(&xs);
        // merging an empty digest changes nothing (bit-exact)
        let mut a = d.clone();
        a.merge(&Digest::default());
        assert_eq!(a, d);
        // merging into an empty digest adopts the other's stats
        let mut e = Digest::default();
        e.merge(&d);
        assert_eq!(e.count(), d.count());
        assert_eq!(e.min(), d.min());
        assert_eq!(e.max(), d.max());
        assert!((e.quantile(50.0) - d.quantile(50.0)).abs() < 1e-9 * d.quantile(50.0).abs().max(1.0));
        // both empty: still empty
        let mut z = Digest::default();
        z.merge(&Digest::default());
        assert!(z.is_empty());
    }

    #[test]
    fn merge_is_deterministic() {
        let xs = lognormal_stream(10_000, 21);
        let halves = xs.split_at(4_321);
        let build = || {
            let mut m = digest_of(halves.0);
            m.merge(&digest_of(halves.1));
            m
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn min_max_anchored_exactly() {
        let xs = lognormal_stream(2_000, 3);
        let d = digest_of(&xs);
        let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!((d.quantile(0.0) - lo).abs() < 1e-12);
        assert!((d.quantile(100.0) - hi).abs() < 1e-12);
    }
}
