//! Metrics: streaming latency digests, SLO goodput, throughput, CDFs,
//! Pareto.
//!
//! Latency streams (`ttft`/`tbt`/`e2e`) are held in O(1)-memory
//! [`Digest`]s, not sample vectors, so a 1e6-request traffic day
//! doesn't hoard gigabytes; the exact sorted-percentile computation
//! survives as the in-tree oracle ([`percentile`]) that digest
//! tolerance tests pin against. SLO satisfaction is judged online at
//! request completion ([`SloSpec`]), per class ([`ClassStats`]), and
//! per coarse time bucket ([`TimeSeries`]).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::json::Json;
use crate::core::SimTime;

mod digest;
pub use digest::Digest;

/// TTFT/TBT/E2E service-level objectives, seconds. `None` = no
/// objective on that axis; a request is SLO-good iff every *set*
/// objective is met.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SloSpec {
    pub ttft_s: Option<f64>,
    pub tbt_s: Option<f64>,
    pub e2e_s: Option<f64>,
}

impl SloSpec {
    /// Is any objective set? (Gates SLO rows in reports.)
    pub fn any(&self) -> bool {
        self.ttft_s.is_some() || self.tbt_s.is_some() || self.e2e_s.is_some()
    }

    /// Judge one completed request: `tbt_s` is compared against the
    /// request's *mean* inter-token gap.
    pub fn met(&self, ttft_s: f64, tbt_mean_s: f64, e2e_s: f64) -> bool {
        self.ttft_s.map_or(true, |t| ttft_s <= t)
            && self.tbt_s.map_or(true, |t| tbt_mean_s <= t)
            && self.e2e_s.map_or(true, |t| e2e_s <= t)
    }

    pub fn validate(&self) -> Result<()> {
        for (name, v) in
            [("ttft", self.ttft_s), ("tbt", self.tbt_s), ("e2e", self.e2e_s)]
        {
            if let Some(v) = v {
                if !v.is_finite() || v <= 0.0 {
                    bail!("SLO {name} threshold must be finite and > 0, got {v}");
                }
            }
        }
        Ok(())
    }
}

/// Per-request-class latency and SLO accounting.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ClassStats {
    pub completed: u64,
    pub slo_ok: u64,
    pub ttft: Digest,
    pub tbt: Digest,
    pub e2e: Digest,
    /// Admission-queue wait (arrival → first admitted iteration and
    /// KV-handoff → first decode admission), seconds.
    pub queue_wait: Digest,
}

impl ClassStats {
    /// Fold another class's stats into this one (shard merge).
    fn merge(&mut self, o: &ClassStats) {
        self.completed += o.completed;
        self.slo_ok += o.slo_ok;
        self.ttft.merge(&o.ttft);
        self.tbt.merge(&o.tbt);
        self.e2e.merge(&o.e2e);
        self.queue_wait.merge(&o.queue_wait);
    }
}

/// Raw per-request sample vectors, opt-in via
/// `ExperimentConfig::keep_raw_samples` (memory grows with request
/// count — for oracle tests and offline analysis only, never the
/// default path).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RawSamples {
    pub ttft: Vec<f64>,
    pub tbt: Vec<f64>,
    pub e2e: Vec<f64>,
}

/// Cap on time-series buckets: when exceeded, adjacent pairs merge and
/// the bucket width doubles, keeping memory O(1) over any run length.
pub const TS_MAX_BUCKETS: usize = 256;

/// One coarse load/latency time bucket.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TsBucket {
    pub arrivals: u64,
    pub completions: u64,
    pub slo_ok: u64,
    pub ttft_sum: f64,
    pub ttft_n: u64,
    pub tbt_sum: f64,
    pub tbt_n: u64,
}

impl TsBucket {
    fn absorb(&mut self, o: &TsBucket) {
        self.arrivals += o.arrivals;
        self.completions += o.completions;
        self.slo_ok += o.slo_ok;
        self.ttft_sum += o.ttft_sum;
        self.ttft_n += o.ttft_n;
        self.tbt_sum += o.tbt_sum;
        self.tbt_n += o.tbt_n;
    }
}

/// Coarse time-series of offered load vs. delivered latency: fixed
/// bucket count (width doubles as the run stretches), so long runs get
/// a day-level curve instead of an unbounded log.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeSeries {
    /// Current bucket width, seconds (starts at 1 s, doubles on
    /// compaction).
    pub bucket_s: f64,
    pub buckets: Vec<TsBucket>,
}

impl Default for TimeSeries {
    fn default() -> Self {
        TimeSeries { bucket_s: 1.0, buckets: Vec::new() }
    }
}

impl TimeSeries {
    fn bucket_mut(&mut self, t_s: f64) -> &mut TsBucket {
        let t_s = t_s.max(0.0);
        let mut i = (t_s / self.bucket_s) as usize;
        while i >= TS_MAX_BUCKETS {
            self.compact();
            i = (t_s / self.bucket_s) as usize;
        }
        if i >= self.buckets.len() {
            self.buckets.resize_with(i + 1, Default::default);
        }
        &mut self.buckets[i]
    }

    fn compact(&mut self) {
        let mut out = Vec::with_capacity((self.buckets.len() + 1) / 2);
        for pair in self.buckets.chunks(2) {
            let mut b = pair[0].clone();
            if let Some(second) = pair.get(1) {
                b.absorb(second);
            }
            out.push(b);
        }
        self.buckets = out;
        self.bucket_s *= 2.0;
    }

    /// Fold another time series into this one. Bucket widths are
    /// powers-of-two multiples of the initial 1 s, so the coarser side
    /// is matched exactly by compacting the finer side, then buckets
    /// absorb index-wise. Deterministic: the result depends only on the
    /// two inputs.
    pub fn merge(&mut self, other: &TimeSeries) {
        if other.buckets.is_empty() {
            return;
        }
        let mut o = other.clone();
        while self.bucket_s < o.bucket_s {
            self.compact();
        }
        while o.bucket_s < self.bucket_s {
            o.compact();
        }
        if self.buckets.len() < o.buckets.len() {
            self.buckets.resize_with(o.buckets.len(), Default::default);
        }
        for (b, ob) in self.buckets.iter_mut().zip(&o.buckets) {
            b.absorb(ob);
        }
        while self.buckets.len() > TS_MAX_BUCKETS {
            self.compact();
        }
    }
}

/// Online collection of per-request and system-level metrics.
#[derive(Default, Clone, Debug)]
pub struct MetricsCollector {
    /// Time-to-first-token stream, seconds.
    pub ttft: Digest,
    /// Time-between-tokens (inter-token latency) stream, seconds.
    pub tbt: Digest,
    /// End-to-end request latency stream, seconds.
    pub e2e: Digest,
    /// Normalized latency (e2e / output tokens), seconds/token.
    pub norm_latency: Digest,
    /// Admission-queue wait stream, seconds: how long a request sat in
    /// a stage's waiting queue before its first iteration there.
    pub queue_wait: Digest,
    /// Active SLO thresholds (judged online at request completion).
    pub slo: SloSpec,
    /// Completed requests meeting every set SLO threshold.
    pub slo_ok: u64,
    /// Per-class breakdown, indexed by `RequestSpec::class`.
    pub per_class: Vec<ClassStats>,
    /// Display names for `per_class` (from the workload's class list;
    /// classes beyond this list render as `class<N>`).
    pub class_names: Vec<String>,
    /// Coarse load-vs-latency curve.
    pub timeseries: TimeSeries,
    /// Opt-in raw sample vectors (oracle tests / offline analysis).
    pub raw: Option<Box<RawSamples>>,
    pub completed_requests: u64,
    pub rejected_requests: u64,
    pub output_tokens: u64,
    pub prefill_tokens: u64,
    pub kv_transfers: u64,
    pub kv_bytes: f64,
    pub iterations: u64,
    /// Underlying predictor evaluations (PJRT launches for the learned
    /// predictor) — the §Perf cache-effectiveness metric.
    pub predictor_evals: u64,
    /// Per-operator-class total simulated seconds.
    pub op_time: BTreeMap<&'static str, f64>,
    /// EP dispatch + combine byte volume routed through the fabric
    /// (including rank-local bytes that never hit the network).
    pub ep_bytes: f64,
    /// EP bytes that crossed a cluster boundary.
    pub ep_cross_bytes: f64,
    /// Running sum of per-routing-draw EP rank-load imbalance (max/mean)
    /// over `ep_draws` draws — O(1) accounting, draws number in the
    /// millions on long MoE runs.
    pub ep_imbalance_sum: f64,
    /// Number of EP routing draws accounted.
    pub ep_draws: u64,
    /// AF decode: FFN-pool idle seconds inside steps — dispatch bubbles
    /// the ping-pong pipeline failed to hide.
    pub dispatch_bubble_s: f64,
    /// Token-slots dropped by the MoE capacity-factor policy (GShard
    /// style overflow drops; 0 without a capacity factor).
    pub dropped_tokens: u64,
    /// Expert migrations adopted (placement re-writes; 0 with
    /// `--migration off`).
    pub migrations: u64,
    /// Expert weight bytes copied between EP ranks by migrations.
    pub migrated_bytes: f64,
    /// Migrated bytes that crossed a cluster boundary (rode the WAN
    /// trunk).
    pub migrated_cross_bytes: f64,
    /// Replica-seconds actually stalled on expert weight moves: each
    /// migration's transfer makespan is charged to every replica of the
    /// stage at its *next* iteration start, and metered here only when
    /// that iteration really runs — a migration adopted on the final
    /// iteration delays nothing and meters nothing.
    pub migration_stall_s: f64,
    /// Sum over migrations of the predicted rank imbalance *before*
    /// re-placement (under the estimated loads); divide by
    /// [`MetricsCollector::migrations`] for the mean.
    pub migration_pre_imb_sum: f64,
    /// Sum over migrations of the predicted rank imbalance *after*
    /// re-placement.
    pub migration_post_imb_sum: f64,
    /// Replica failures applied (fault injection; 0 without
    /// `--faults`).
    pub faults: u64,
    /// Replica recoveries applied.
    pub fault_recoveries: u64,
    /// Requests displaced by a failure and requeued through the
    /// re-prefill / re-route recovery path.
    pub fault_requeues: u64,
    /// Backoff retries by displaced requests that found no healthy
    /// replica on a routing attempt.
    pub fault_retries: u64,
    /// Requests rejected with backpressure because every candidate
    /// pool was down with no recovery or scale-up in sight.
    pub fault_rejected: u64,
    /// Replica-seconds of fault downtime — the availability meter's
    /// numerator (outages still open at the end of the run are charged
    /// up to the horizon).
    pub fault_downtime_s: f64,
    /// Time-to-recovery stream, seconds per repaired outage.
    pub ttr: Digest,
    /// Fault-displaced requests that eventually completed.
    pub fault_affected_completed: u64,
    /// Fault-displaced completions that missed a set SLO — the
    /// per-fault SLO damage meter.
    pub fault_affected_slo_miss: u64,
    /// Autoscaler control-loop evaluations.
    pub scale_ticks: u64,
    /// Replicas brought up by the autoscaler.
    pub scale_up_events: u64,
    /// Replicas drained and retired by the autoscaler.
    pub scale_down_events: u64,
    /// Link/fabric fault transitions applied (outages and partial
    /// degradations; 0 without `--link-faults`). Plan-derived —
    /// stamped once on the merged collector, never per shard.
    pub link_faults: u64,
    /// Link/fabric recoveries applied (transitions back to healthy).
    pub link_recoveries: u64,
    /// Seconds each fabric tier (NVLink / IB / WAN) spent degraded or
    /// down over the run horizon, from the fabric epochs.
    pub link_degraded_s: [f64; 3],
    /// KV transfers dispatched around at least one dead fabric path.
    pub link_rerouted_transfers: u64,
    /// KV transfers held at least once because every candidate path
    /// was down (released by a later epoch's recovery).
    pub link_stalled_transfers: u64,
    /// Link-affected requests (rerouted or stalled en route) that
    /// eventually completed.
    pub link_affected_completed: u64,
    /// Link-affected completions that missed a set SLO — the per-link-
    /// fault SLO damage meter.
    pub link_affected_slo_miss: u64,
}

impl MetricsCollector {
    pub fn record_op(&mut self, class: &'static str, secs: f64) {
        *self.op_time.entry(class).or_insert(0.0) += secs;
    }

    fn class_mut(&mut self, class: u16) -> &mut ClassStats {
        let i = class as usize;
        if i >= self.per_class.len() {
            self.per_class.resize_with(i + 1, Default::default);
        }
        &mut self.per_class[i]
    }

    /// Display name for class `i` in reports.
    pub fn class_name(&self, i: usize) -> String {
        self.class_names.get(i).cloned().unwrap_or_else(|| format!("class{i}"))
    }

    /// Account one request arrival at simulated time `t_s` (load curve).
    pub fn record_arrival(&mut self, t_s: f64) {
        self.timeseries.bucket_mut(t_s).arrivals += 1;
    }

    /// Record a time-to-first-token sample for `class` at simulated
    /// time `t_s`.
    pub fn record_ttft(&mut self, class: u16, v_s: f64, t_s: f64) {
        self.ttft.record(v_s);
        self.class_mut(class).ttft.record(v_s);
        let b = self.timeseries.bucket_mut(t_s);
        b.ttft_sum += v_s;
        b.ttft_n += 1;
        if let Some(raw) = &mut self.raw {
            raw.ttft.push(v_s);
        }
    }

    /// Record an admission-queue wait sample for `class`: seconds
    /// between a request joining a stage's waiting queue and its first
    /// admitted iteration there.
    pub fn record_queue_wait(&mut self, class: u16, v_s: f64) {
        self.queue_wait.record(v_s);
        self.class_mut(class).queue_wait.record(v_s);
    }

    /// Record an inter-token latency sample for `class`.
    pub fn record_tbt(&mut self, class: u16, v_s: f64, t_s: f64) {
        self.tbt.record(v_s);
        self.class_mut(class).tbt.record(v_s);
        let b = self.timeseries.bucket_mut(t_s);
        b.tbt_sum += v_s;
        b.tbt_n += 1;
        if let Some(raw) = &mut self.raw {
            raw.tbt.push(v_s);
        }
    }

    /// Account one completed request: e2e / normalized latency streams,
    /// online SLO judgment (`tbt_mean_s` = mean inter-token gap over
    /// the request), per-class stats, and the completion time bucket.
    pub fn record_completion(
        &mut self,
        class: u16,
        ttft_s: f64,
        tbt_mean_s: f64,
        e2e_s: f64,
        output_len: u32,
        t_s: f64,
    ) {
        self.completed_requests += 1;
        self.e2e.record(e2e_s);
        self.norm_latency.record(e2e_s / output_len.max(1) as f64);
        if let Some(raw) = &mut self.raw {
            raw.e2e.push(e2e_s);
        }
        let ok = self.slo.met(ttft_s, tbt_mean_s, e2e_s);
        if ok {
            self.slo_ok += 1;
        }
        let c = self.class_mut(class);
        c.completed += 1;
        c.e2e.record(e2e_s);
        if ok {
            c.slo_ok += 1;
        }
        let b = self.timeseries.bucket_mut(t_s);
        b.completions += 1;
        if ok {
            b.slo_ok += 1;
        }
    }

    /// Account one EP dispatch/combine draw.
    pub fn record_ep(&mut self, bytes: f64, cross_bytes: f64, imbalance: f64) {
        self.ep_bytes += bytes;
        self.ep_cross_bytes += cross_bytes;
        self.ep_imbalance_sum += imbalance;
        self.ep_draws += 1;
    }

    /// Mean EP rank-load imbalance across routing draws.
    pub fn ep_imbalance_mean(&self) -> f64 {
        if self.ep_draws > 0 {
            self.ep_imbalance_sum / self.ep_draws as f64
        } else {
            0.0
        }
    }

    /// Fraction of EP bytes that crossed a cluster boundary.
    pub fn ep_cross_frac(&self) -> f64 {
        if self.ep_bytes > 0.0 {
            self.ep_cross_bytes / self.ep_bytes
        } else {
            0.0
        }
    }

    /// Account one adopted expert migration: `bytes`/`cross_bytes` of
    /// weights moved and the predicted pre/post rank imbalance of the
    /// re-placement. Stall is metered separately
    /// ([`MetricsCollector::migration_stall_s`]) when a replica
    /// actually pays it.
    pub fn record_migration(
        &mut self,
        bytes: f64,
        cross_bytes: f64,
        pre_imbalance: f64,
        post_imbalance: f64,
    ) {
        self.migrations += 1;
        self.migrated_bytes += bytes;
        self.migrated_cross_bytes += cross_bytes;
        self.migration_pre_imb_sum += pre_imbalance;
        self.migration_post_imb_sum += post_imbalance;
    }

    /// Mean predicted rank imbalance immediately before migrations
    /// (0 when none fired).
    pub fn migration_pre_imbalance_mean(&self) -> f64 {
        if self.migrations > 0 {
            self.migration_pre_imb_sum / self.migrations as f64
        } else {
            0.0
        }
    }

    /// Mean predicted rank imbalance immediately after migrations.
    pub fn migration_post_imbalance_mean(&self) -> f64 {
        if self.migrations > 0 {
            self.migration_post_imb_sum / self.migrations as f64
        } else {
            0.0
        }
    }

    /// Whether any cluster dynamics engaged this run — the reporting
    /// gate: zero-fault / zero-autoscale runs add no fields and stay
    /// byte-identical to a build without the dynamics layer.
    pub fn dynamics_active(&self) -> bool {
        self.faults > 0 || self.scale_ticks > 0
    }

    /// Account one applied replica failure.
    pub fn record_fault(&mut self) {
        self.faults += 1;
    }

    /// Account one applied replica recovery after `downtime_s` out.
    pub fn record_fault_recovery(&mut self, downtime_s: f64) {
        self.fault_recoveries += 1;
        self.fault_downtime_s += downtime_s;
        self.ttr.record(downtime_s);
    }

    /// Account one fault-displaced completion (and whether it missed a
    /// set SLO) — called alongside `record_completion`, never instead
    /// of it.
    pub fn record_affected_completion(&mut self, slo_ok: bool) {
        self.fault_affected_completed += 1;
        if self.slo.any() && !slo_ok {
            self.fault_affected_slo_miss += 1;
        }
    }

    /// Whether link/fabric faults engaged this run. A separate gate
    /// from [`MetricsCollector::dynamics_active`] so `--faults`-only
    /// runs keep their exact pre-link-fault report shape.
    pub fn link_active(&self) -> bool {
        self.link_faults > 0 || self.link_recoveries > 0
    }

    /// Account one link-affected completion (the request's KV transfer
    /// was rerouted around a dead path or stalled on one) and whether
    /// it missed a set SLO — called alongside `record_completion`.
    pub fn record_link_affected_completion(&mut self, slo_ok: bool) {
        self.link_affected_completed += 1;
        if self.slo.any() && !slo_ok {
            self.link_affected_slo_miss += 1;
        }
    }

    /// Fold a shard-local collector into this one. Digests merge
    /// through [`Digest::merge`], the time series through
    /// [`TimeSeries::merge`], raw sample vectors concatenate, and all
    /// counters add. The caller merges shards in a fixed order, so the
    /// result is independent of thread count — the parallel engine's
    /// determinism contract. `slo` and `class_names` are set
    /// identically on every shard at construction and are left as-is.
    pub fn merge(&mut self, other: &MetricsCollector) {
        self.ttft.merge(&other.ttft);
        self.tbt.merge(&other.tbt);
        self.e2e.merge(&other.e2e);
        self.norm_latency.merge(&other.norm_latency);
        self.queue_wait.merge(&other.queue_wait);
        self.slo_ok += other.slo_ok;
        if self.per_class.len() < other.per_class.len() {
            self.per_class.resize_with(other.per_class.len(), Default::default);
        }
        for (c, oc) in self.per_class.iter_mut().zip(&other.per_class) {
            c.merge(oc);
        }
        self.timeseries.merge(&other.timeseries);
        if let (Some(raw), Some(oraw)) = (&mut self.raw, &other.raw) {
            raw.ttft.extend_from_slice(&oraw.ttft);
            raw.tbt.extend_from_slice(&oraw.tbt);
            raw.e2e.extend_from_slice(&oraw.e2e);
        }
        self.completed_requests += other.completed_requests;
        self.rejected_requests += other.rejected_requests;
        self.output_tokens += other.output_tokens;
        self.prefill_tokens += other.prefill_tokens;
        self.kv_transfers += other.kv_transfers;
        self.kv_bytes += other.kv_bytes;
        self.iterations += other.iterations;
        self.predictor_evals += other.predictor_evals;
        for (&class, &secs) in &other.op_time {
            *self.op_time.entry(class).or_insert(0.0) += secs;
        }
        self.ep_bytes += other.ep_bytes;
        self.ep_cross_bytes += other.ep_cross_bytes;
        self.ep_imbalance_sum += other.ep_imbalance_sum;
        self.ep_draws += other.ep_draws;
        self.dispatch_bubble_s += other.dispatch_bubble_s;
        self.dropped_tokens += other.dropped_tokens;
        self.migrations += other.migrations;
        self.migrated_bytes += other.migrated_bytes;
        self.migrated_cross_bytes += other.migrated_cross_bytes;
        self.migration_stall_s += other.migration_stall_s;
        self.migration_pre_imb_sum += other.migration_pre_imb_sum;
        self.migration_post_imb_sum += other.migration_post_imb_sum;
        self.faults += other.faults;
        self.fault_recoveries += other.fault_recoveries;
        self.fault_requeues += other.fault_requeues;
        self.fault_retries += other.fault_retries;
        self.fault_rejected += other.fault_rejected;
        self.fault_downtime_s += other.fault_downtime_s;
        self.ttr.merge(&other.ttr);
        self.fault_affected_completed += other.fault_affected_completed;
        self.fault_affected_slo_miss += other.fault_affected_slo_miss;
        self.scale_ticks += other.scale_ticks;
        self.scale_up_events += other.scale_up_events;
        self.scale_down_events += other.scale_down_events;
        self.link_faults += other.link_faults;
        self.link_recoveries += other.link_recoveries;
        for (a, b) in self.link_degraded_s.iter_mut().zip(&other.link_degraded_s) {
            *a += b;
        }
        self.link_rerouted_transfers += other.link_rerouted_transfers;
        self.link_stalled_transfers += other.link_stalled_transfers;
        self.link_affected_completed += other.link_affected_completed;
        self.link_affected_slo_miss += other.link_affected_slo_miss;
    }
}

/// Exact nearest-rank percentile over unsorted samples: the smallest
/// sample with at least `p`% of the data at or below it
/// (`rank = ⌈(p/100)·n⌉`). This is the in-tree oracle the streaming
/// [`Digest`] is tolerance-tested against. The old
/// `round((p/100)·(n-1))` formula was biased — e.g. p50 of [1,2,3,4]
/// returned 3 instead of 2 — and report call sites paid a
/// sort-a-clone per call; reports now read digests instead.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let n = v.len();
    let rank = ((p / 100.0).clamp(0.0, 1.0) * n as f64).ceil() as usize;
    v[rank.clamp(1, n) - 1]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Empirical CDF: sorted (value, cumulative fraction) pairs.
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.into_iter().enumerate().map(|(i, x)| (x, (i + 1) as f64 / n)).collect()
}

/// Fraction of samples <= threshold.
pub fn frac_below(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x <= threshold).count() as f64 / xs.len() as f64
}

/// Per-stage summary of a stage-graph run (one entry per pool).
#[derive(Clone, Debug, Default)]
pub struct StageReport {
    pub name: String,
    pub kind: String,
    pub replicas: u32,
    /// GPUs backing the whole stage.
    pub gpus: u32,
    pub gpu_name: String,
    pub iterations: u64,
    /// Prefill + decode tokens processed by the stage.
    pub tokens: u64,
    /// Mean fraction of the run the stage's replicas were executing.
    pub busy_frac: f64,
    /// Peak KV-pool utilization across the stage's replicas.
    pub peak_mem_frac: f64,
}

/// Final report of one simulation run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub mode: String,
    pub predictor: String,
    /// Simulated wall-clock span, seconds.
    pub sim_duration: f64,
    /// Host time spent simulating, seconds.
    pub host_duration: f64,
    pub events_processed: u64,
    pub n_gpus: u32,
    pub metrics: MetricsCollector,
    /// Per-stage breakdown (empty for simulators without stage pools).
    pub stages: Vec<StageReport>,
}

impl SimReport {
    /// Output tokens per second per GPU — Table 2's headline metric.
    pub fn tokens_per_sec_per_gpu(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.output_tokens as f64 / self.sim_duration / self.n_gpus as f64
    }

    /// Total output token throughput, tokens/s.
    pub fn throughput(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.output_tokens as f64 / self.sim_duration
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.completed_requests as f64 / self.sim_duration
    }

    /// Goodput: completed requests/s meeting every *set* SLO threshold
    /// (DistServe-style). Satisfaction is judged online at request
    /// completion against [`MetricsCollector::slo`]; with no SLOs set,
    /// every completion counts and goodput equals
    /// [`SimReport::requests_per_sec`].
    pub fn goodput(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.slo_ok as f64 / self.sim_duration
    }

    /// Fraction of completed requests that met every set SLO.
    pub fn slo_attainment(&self) -> f64 {
        if self.metrics.completed_requests == 0 {
            return 0.0;
        }
        self.metrics.slo_ok as f64 / self.metrics.completed_requests as f64
    }

    /// Fleet availability: 1 − fault downtime over total
    /// replica-seconds (configured replica counts × simulated span).
    /// 1.0 for an immortal fleet.
    pub fn availability(&self) -> f64 {
        let slots: u64 = self.stages.iter().map(|s| s.replicas as u64).sum();
        if self.sim_duration <= 0.0 || slots == 0 {
            return 1.0;
        }
        (1.0 - self.metrics.fault_downtime_s / (self.sim_duration * slots as f64)).max(0.0)
    }

    /// Simulation speed: simulated seconds per host second.
    pub fn speedup(&self) -> f64 {
        if self.host_duration <= 0.0 {
            return 0.0;
        }
        self.sim_duration / self.host_duration
    }

    pub fn events_per_sec(&self) -> f64 {
        if self.host_duration <= 0.0 {
            return 0.0;
        }
        self.events_processed as f64 / self.host_duration
    }

    pub fn summary(&self) -> String {
        let m = &self.metrics;
        let mut s = format!(
            "[{} | {}] {:.1}s simulated in {:.2}s host ({:.0}x, {:.0} ev/s)\n\
             requests: {} done, {} rejected | tokens: {} out, {} prefill\n\
             throughput: {:.1} tok/s ({:.2} tok/s/gpu on {} gpus), {:.2} req/s\n\
             TTFT p50/p99: {:.1}/{:.1} ms | TBT p50/p99: {:.2}/{:.2} ms | e2e p50: {:.2} s\n\
             iterations: {} | kv transfers: {} ({:.1} MB)",
            self.mode,
            self.predictor,
            self.sim_duration,
            self.host_duration,
            self.speedup(),
            self.events_per_sec(),
            m.completed_requests,
            m.rejected_requests,
            m.output_tokens,
            m.prefill_tokens,
            self.throughput(),
            self.tokens_per_sec_per_gpu(),
            self.n_gpus,
            self.requests_per_sec(),
            m.ttft.quantile(50.0) * 1e3,
            m.ttft.quantile(99.0) * 1e3,
            m.tbt.quantile(50.0) * 1e3,
            m.tbt.quantile(99.0) * 1e3,
            m.e2e.quantile(50.0),
            m.iterations,
            m.kv_transfers,
            m.kv_bytes / 1e6,
        );
        if m.queue_wait.count() > 0 {
            s.push_str(&format!(
                "\nqueue wait p50/p99: {:.1}/{:.1} ms over {} admissions",
                m.queue_wait.quantile(50.0) * 1e3,
                m.queue_wait.quantile(99.0) * 1e3,
                m.queue_wait.count(),
            ));
        }
        if m.slo.any() {
            s.push_str(&format!(
                "\nSLO{}{}{}: goodput {:.2} req/s, attainment {:.1}% ({}/{})",
                m.slo.ttft_s.map_or(String::new(), |v| format!(" ttft<={:.0}ms", v * 1e3)),
                m.slo.tbt_s.map_or(String::new(), |v| format!(" tbt<={:.0}ms", v * 1e3)),
                m.slo.e2e_s.map_or(String::new(), |v| format!(" e2e<={v:.1}s")),
                self.goodput(),
                self.slo_attainment() * 100.0,
                m.slo_ok,
                m.completed_requests,
            ));
        }
        if m.per_class.len() > 1 {
            for (i, c) in m.per_class.iter().enumerate() {
                s.push_str(&format!(
                    "\nclass {:<8} {:>7} done | qwait p50/p99 {:.1}/{:.1} ms | \
                     ttft p50/p99 {:.1}/{:.1} ms | \
                     tbt p50/p99 {:.2}/{:.2} ms | e2e p50 {:.2} s{}",
                    m.class_name(i),
                    c.completed,
                    c.queue_wait.quantile(50.0) * 1e3,
                    c.queue_wait.quantile(99.0) * 1e3,
                    c.ttft.quantile(50.0) * 1e3,
                    c.ttft.quantile(99.0) * 1e3,
                    c.tbt.quantile(50.0) * 1e3,
                    c.tbt.quantile(99.0) * 1e3,
                    c.e2e.quantile(50.0),
                    if m.slo.any() && c.completed > 0 {
                        format!(
                            " | slo {:.1}%",
                            c.slo_ok as f64 / c.completed as f64 * 100.0
                        )
                    } else {
                        String::new()
                    },
                ));
            }
        }
        if m.timeseries.buckets.len() > 1 {
            s.push_str(&format!(
                "\nload curve: {} buckets x {:.0} s (arrivals/completions/mean-ttft in JSON)",
                m.timeseries.buckets.len(),
                m.timeseries.bucket_s,
            ));
        }
        if m.ep_bytes > 0.0 {
            s.push_str(&format!(
                "\nEP: {:.1} MB dispatched+combined ({:.1}% cross-cluster) | \
                 rank imbalance mean {:.2} | dispatch bubble {:.3} s",
                m.ep_bytes / 1e6,
                m.ep_cross_frac() * 100.0,
                m.ep_imbalance_mean(),
                m.dispatch_bubble_s,
            ));
        }
        if m.dropped_tokens > 0 {
            s.push_str(&format!(
                "\ncapacity policy: {} token-slots dropped",
                m.dropped_tokens
            ));
        }
        if m.migrations > 0 {
            s.push_str(&format!(
                "\nexpert migration: {} migrations, {:.1} MB moved \
                 ({:.1}% cross-cluster), stall {:.4} s, \
                 predicted imbalance {:.2} -> {:.2}",
                m.migrations,
                m.migrated_bytes / 1e6,
                if m.migrated_bytes > 0.0 {
                    m.migrated_cross_bytes / m.migrated_bytes * 100.0
                } else {
                    0.0
                },
                m.migration_stall_s,
                m.migration_pre_imbalance_mean(),
                m.migration_post_imbalance_mean(),
            ));
        }
        if m.dynamics_active() {
            s.push_str(&format!(
                "\nfaults: {} ({} recovered, TTR p50 {:.1} s) | downtime {:.1} replica-s, \
                 availability {:.2}%",
                m.faults,
                m.fault_recoveries,
                m.ttr.quantile(50.0),
                m.fault_downtime_s,
                self.availability() * 100.0,
            ));
            s.push_str(&format!(
                "\nfault damage: {} requeued, {} retries, {} rejected | {} affected \
                 completed ({} SLO misses)",
                m.fault_requeues,
                m.fault_retries,
                m.fault_rejected,
                m.fault_affected_completed,
                m.fault_affected_slo_miss,
            ));
            if m.scale_ticks > 0 {
                s.push_str(&format!(
                    "\nautoscale: {} ticks, {} up / {} down",
                    m.scale_ticks, m.scale_up_events, m.scale_down_events,
                ));
            }
        }
        if m.link_active() {
            s.push_str(&format!(
                "\nlink faults: {} ({} recovered) | degraded s nvlink/ib/wan \
                 {:.1}/{:.1}/{:.1}",
                m.link_faults,
                m.link_recoveries,
                m.link_degraded_s[0],
                m.link_degraded_s[1],
                m.link_degraded_s[2],
            ));
            s.push_str(&format!(
                "\nlink damage: {} transfers rerouted, {} stalled | {} affected \
                 completed ({} SLO misses)",
                m.link_rerouted_transfers,
                m.link_stalled_transfers,
                m.link_affected_completed,
                m.link_affected_slo_miss,
            ));
        }
        for st in &self.stages {
            s.push_str(&format!(
                "\nstage {} [{}] {}x{} on {}: {} iters, {} tokens, busy {:.1}%, peak mem {:.1}%",
                st.name,
                st.kind,
                st.replicas,
                if st.replicas > 0 { st.gpus / st.replicas.max(1) } else { st.gpus },
                st.gpu_name,
                st.iterations,
                st.tokens,
                st.busy_frac * 100.0,
                st.peak_mem_frac * 100.0,
            ));
        }
        s
    }

    /// [`SimReport::to_json`] minus the host-time field
    /// (`host_duration_s`): every remaining value is a pure function of
    /// the experiment config, so the document is byte-identical across
    /// runs, machines, and sweep thread counts. The sweep engine's
    /// merged reports are built from this projection
    /// (`rust/tests/sweep.rs` pins the byte-identity).
    pub fn to_json_deterministic(&self) -> Json {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("host_duration_s");
        }
        j
    }

    pub fn to_json(&self) -> Json {
        let m = &self.metrics;
        let mut fields = vec![
            ("mode", Json::Str(self.mode.clone())),
            ("predictor", Json::Str(self.predictor.clone())),
            ("sim_duration_s", Json::Num(self.sim_duration)),
            ("host_duration_s", Json::Num(self.host_duration)),
            ("events", Json::Num(self.events_processed as f64)),
            ("n_gpus", Json::Num(self.n_gpus as f64)),
            ("completed", Json::Num(m.completed_requests as f64)),
            ("rejected", Json::Num(m.rejected_requests as f64)),
            ("output_tokens", Json::Num(m.output_tokens as f64)),
            ("tokens_per_sec_per_gpu", Json::Num(self.tokens_per_sec_per_gpu())),
            ("ttft_p50_ms", Json::Num(m.ttft.quantile(50.0) * 1e3)),
            ("ttft_p99_ms", Json::Num(m.ttft.quantile(99.0) * 1e3)),
            ("tbt_p50_ms", Json::Num(m.tbt.quantile(50.0) * 1e3)),
            ("tbt_p99_ms", Json::Num(m.tbt.quantile(99.0) * 1e3)),
            ("e2e_p50_s", Json::Num(m.e2e.quantile(50.0))),
            ("qwait_p50_ms", Json::Num(m.queue_wait.quantile(50.0) * 1e3)),
            ("qwait_p99_ms", Json::Num(m.queue_wait.quantile(99.0) * 1e3)),
            ("iterations", Json::Num(m.iterations as f64)),
            ("kv_transfers", Json::Num(m.kv_transfers as f64)),
            ("ep_bytes", Json::Num(m.ep_bytes)),
            ("ep_cross_frac", Json::Num(m.ep_cross_frac())),
            ("ep_imbalance_mean", Json::Num(m.ep_imbalance_mean())),
            ("dispatch_bubble_s", Json::Num(m.dispatch_bubble_s)),
            ("dropped_tokens", Json::Num(m.dropped_tokens as f64)),
            ("migrations", Json::Num(m.migrations as f64)),
            ("migrated_bytes", Json::Num(m.migrated_bytes)),
            ("migrated_cross_bytes", Json::Num(m.migrated_cross_bytes)),
            ("migration_stall_s", Json::Num(m.migration_stall_s)),
            ("migration_pre_imbalance", Json::Num(m.migration_pre_imbalance_mean())),
            ("migration_post_imbalance", Json::Num(m.migration_post_imbalance_mean())),
            (
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|st| {
                            Json::obj(vec![
                                ("name", Json::Str(st.name.clone())),
                                ("kind", Json::Str(st.kind.clone())),
                                ("replicas", Json::Num(st.replicas as f64)),
                                ("gpus", Json::Num(st.gpus as f64)),
                                ("gpu", Json::Str(st.gpu_name.clone())),
                                ("iterations", Json::Num(st.iterations as f64)),
                                ("tokens", Json::Num(st.tokens as f64)),
                                ("busy_frac", Json::Num(st.busy_frac)),
                                ("peak_mem_frac", Json::Num(st.peak_mem_frac)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if m.slo.any() {
            fields.push(("goodput_rps", Json::Num(self.goodput())));
            fields.push(("slo_attainment", Json::Num(self.slo_attainment())));
        }
        if m.dynamics_active() {
            // gated like the SLO block so zero-dynamics runs
            // bit-reproduce pre-dynamics reports
            fields.push(("faults", Json::Num(m.faults as f64)));
            fields.push(("fault_recoveries", Json::Num(m.fault_recoveries as f64)));
            fields.push(("fault_requeues", Json::Num(m.fault_requeues as f64)));
            fields.push(("fault_retries", Json::Num(m.fault_retries as f64)));
            fields.push(("fault_rejected", Json::Num(m.fault_rejected as f64)));
            fields.push(("fault_downtime_s", Json::Num(m.fault_downtime_s)));
            fields.push(("ttr_p50_s", Json::Num(m.ttr.quantile(50.0))));
            fields.push(("ttr_p99_s", Json::Num(m.ttr.quantile(99.0))));
            fields.push(("availability", Json::Num(self.availability())));
            fields.push((
                "fault_affected_completed",
                Json::Num(m.fault_affected_completed as f64),
            ));
            fields.push((
                "fault_affected_slo_miss",
                Json::Num(m.fault_affected_slo_miss as f64),
            ));
            fields.push(("scale_ticks", Json::Num(m.scale_ticks as f64)));
            fields.push(("scale_up_events", Json::Num(m.scale_up_events as f64)));
            fields.push(("scale_down_events", Json::Num(m.scale_down_events as f64)));
        }
        if m.link_active() {
            // separate gate: replica-fault-only runs bit-reproduce
            // their pre-link-fault reports
            fields.push(("link_faults", Json::Num(m.link_faults as f64)));
            fields.push(("link_recoveries", Json::Num(m.link_recoveries as f64)));
            fields.push((
                "link_degraded_s",
                Json::Arr(m.link_degraded_s.iter().map(|&v| Json::Num(v)).collect()),
            ));
            fields.push((
                "link_rerouted_transfers",
                Json::Num(m.link_rerouted_transfers as f64),
            ));
            fields.push((
                "link_stalled_transfers",
                Json::Num(m.link_stalled_transfers as f64),
            ));
            fields.push((
                "link_affected_completed",
                Json::Num(m.link_affected_completed as f64),
            ));
            fields.push((
                "link_affected_slo_miss",
                Json::Num(m.link_affected_slo_miss as f64),
            ));
        }
        if m.per_class.len() > 1 {
            fields.push((
                "classes",
                Json::Arr(
                    m.per_class
                        .iter()
                        .enumerate()
                        .map(|(i, c)| {
                            Json::obj(vec![
                                ("name", Json::Str(m.class_name(i))),
                                ("completed", Json::Num(c.completed as f64)),
                                ("slo_ok", Json::Num(c.slo_ok as f64)),
                                ("ttft_p50_ms", Json::Num(c.ttft.quantile(50.0) * 1e3)),
                                ("ttft_p99_ms", Json::Num(c.ttft.quantile(99.0) * 1e3)),
                                ("tbt_p50_ms", Json::Num(c.tbt.quantile(50.0) * 1e3)),
                                ("tbt_p99_ms", Json::Num(c.tbt.quantile(99.0) * 1e3)),
                                ("e2e_p50_s", Json::Num(c.e2e.quantile(50.0))),
                                (
                                    "qwait_p50_ms",
                                    Json::Num(c.queue_wait.quantile(50.0) * 1e3),
                                ),
                                (
                                    "qwait_p99_ms",
                                    Json::Num(c.queue_wait.quantile(99.0) * 1e3),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if m.timeseries.buckets.len() > 1 {
            let ts = &m.timeseries;
            fields.push((
                "timeseries",
                Json::obj(vec![
                    ("bucket_s", Json::Num(ts.bucket_s)),
                    (
                        "arrivals",
                        Json::Arr(
                            ts.buckets.iter().map(|b| Json::Num(b.arrivals as f64)).collect(),
                        ),
                    ),
                    (
                        "completions",
                        Json::Arr(
                            ts.buckets
                                .iter()
                                .map(|b| Json::Num(b.completions as f64))
                                .collect(),
                        ),
                    ),
                    (
                        "slo_ok",
                        Json::Arr(
                            ts.buckets.iter().map(|b| Json::Num(b.slo_ok as f64)).collect(),
                        ),
                    ),
                    (
                        "mean_ttft_ms",
                        Json::Arr(
                            ts.buckets
                                .iter()
                                .map(|b| {
                                    Json::Num(if b.ttft_n > 0 {
                                        b.ttft_sum / b.ttft_n as f64 * 1e3
                                    } else {
                                        0.0
                                    })
                                })
                                .collect(),
                        ),
                    ),
                    (
                        "mean_tbt_ms",
                        Json::Arr(
                            ts.buckets
                                .iter()
                                .map(|b| {
                                    Json::Num(if b.tbt_n > 0 {
                                        b.tbt_sum / b.tbt_n as f64 * 1e3
                                    } else {
                                        0.0
                                    })
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

/// Extract the Pareto frontier (maximize x=throughput, minimize y=latency)
/// from a set of (throughput, latency, label) points.
pub fn pareto_frontier(points: &[(f64, f64, String)]) -> Vec<(f64, f64, String)> {
    let mut pts: Vec<_> = points.to_vec();
    pts.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut best = f64::INFINITY;
    let mut out = Vec::new();
    for p in pts {
        if p.1 < best {
            best = p.1;
            out.push(p);
        }
    }
    out.reverse();
    out
}

/// Latency timestamps for one request (used by the coordinator).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReqTimestamps {
    pub arrival: SimTime,
    pub prefill_done: Option<SimTime>,
    pub first_token: Option<SimTime>,
    pub done: Option<SimTime>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        // nearest-rank: rank = ceil((p/100)*n), 1-based
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_small_n_bias_regression() {
        // the old round((p/100)*(n-1)) formula returned 3.0 for the
        // median of [1,2,3,4] — 75% of the data at or below the "p50"
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert_eq!(percentile(&xs, 75.0), 3.0);
        assert_eq!(percentile(&xs, 76.0), 4.0);
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        // p99 at n=10 must be the max, not the 9th value
        let ten: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        assert_eq!(percentile(&ten, 99.0), 10.0);
    }

    #[test]
    fn slo_judgment_and_goodput() {
        let mut m = MetricsCollector::default();
        m.slo = SloSpec { ttft_s: Some(0.5), tbt_s: Some(0.05), e2e_s: None };
        assert!(m.slo.any());
        // good request
        m.record_completion(0, 0.2, 0.03, 2.0, 10, 2.0);
        // ttft violation
        m.record_completion(0, 0.9, 0.03, 2.0, 10, 3.0);
        // tbt violation
        m.record_completion(0, 0.2, 0.08, 2.0, 10, 4.0);
        assert_eq!(m.completed_requests, 3);
        assert_eq!(m.slo_ok, 1);
        assert_eq!(m.per_class.len(), 1);
        assert_eq!(m.per_class[0].completed, 3);
        assert_eq!(m.per_class[0].slo_ok, 1);
        let r = SimReport {
            mode: "test".into(),
            predictor: "oracle".into(),
            sim_duration: 10.0,
            host_duration: 1.0,
            events_processed: 1,
            n_gpus: 1,
            metrics: m,
            stages: Vec::new(),
        };
        assert!((r.goodput() - 0.1).abs() < 1e-12);
        assert!((r.slo_attainment() - 1.0 / 3.0).abs() < 1e-12);
        let j = r.to_json();
        assert!(j.get("goodput_rps").is_some());
        assert!(j.get("slo_attainment").is_some());
    }

    #[test]
    fn unset_slo_counts_every_completion() {
        let mut m = MetricsCollector::default();
        assert!(!m.slo.any());
        m.record_completion(0, 99.0, 99.0, 99.0, 1, 0.0);
        assert_eq!(m.slo_ok, 1);
        // and the report omits the SLO keys
        let r = SimReport {
            mode: "t".into(),
            predictor: "o".into(),
            sim_duration: 1.0,
            host_duration: 1.0,
            events_processed: 1,
            n_gpus: 1,
            metrics: m,
            stages: Vec::new(),
        };
        assert!(r.to_json().get("goodput_rps").is_none());
    }

    #[test]
    fn slo_validation_rejects_nonpositive() {
        assert!(SloSpec { ttft_s: Some(0.0), ..Default::default() }.validate().is_err());
        assert!(SloSpec { tbt_s: Some(-1.0), ..Default::default() }.validate().is_err());
        assert!(SloSpec { e2e_s: Some(f64::NAN), ..Default::default() }.validate().is_err());
        assert!(SloSpec { ttft_s: Some(0.2), ..Default::default() }.validate().is_ok());
        assert!(SloSpec::default().validate().is_ok());
    }

    #[test]
    fn timeseries_stays_bounded() {
        let mut m = MetricsCollector::default();
        // a "week" of sparse arrivals: bucket count must stay capped,
        // width doubling instead
        for i in 0..600_000u64 {
            m.record_arrival(i as f64);
        }
        assert!(m.timeseries.buckets.len() <= TS_MAX_BUCKETS);
        assert!(m.timeseries.bucket_s > 1.0);
        let total: u64 = m.timeseries.buckets.iter().map(|b| b.arrivals).sum();
        assert_eq!(total, 600_000, "compaction must not lose counts");
    }

    #[test]
    fn per_class_tracks_separately() {
        let mut m = MetricsCollector::default();
        m.class_names = vec!["chat".into(), "batch".into()];
        m.record_ttft(0, 0.1, 1.0);
        m.record_ttft(1, 9.0, 1.0);
        m.record_completion(0, 0.1, 0.01, 1.0, 8, 2.0);
        m.record_completion(1, 9.0, 0.50, 60.0, 8, 61.0);
        assert_eq!(m.per_class.len(), 2);
        assert_eq!(m.class_name(0), "chat");
        assert_eq!(m.class_name(7), "class7");
        assert!(m.per_class[0].ttft.quantile(50.0) < m.per_class[1].ttft.quantile(50.0));
        let r = SimReport {
            mode: "t".into(),
            predictor: "o".into(),
            sim_duration: 100.0,
            host_duration: 1.0,
            events_processed: 1,
            n_gpus: 1,
            metrics: m,
            stages: Vec::new(),
        };
        let j = r.to_json();
        let classes = j.get("classes").unwrap().as_arr().unwrap();
        assert_eq!(classes.len(), 2);
        assert_eq!(
            classes[0].get("name"),
            Some(&Json::Str("chat".into()))
        );
    }

    #[test]
    fn queue_wait_digest_tracks_per_class() {
        let mut m = MetricsCollector::default();
        m.record_queue_wait(0, 0.010);
        m.record_queue_wait(0, 0.030);
        m.record_queue_wait(1, 1.000);
        assert_eq!(m.queue_wait.count(), 3);
        assert_eq!(m.per_class[0].queue_wait.count(), 2);
        assert_eq!(m.per_class[1].queue_wait.count(), 1);
        assert!(m.per_class[0].queue_wait.quantile(50.0) < m.per_class[1].queue_wait.quantile(50.0));
        let r = SimReport {
            mode: "t".into(),
            predictor: "o".into(),
            sim_duration: 1.0,
            host_duration: 1.0,
            events_processed: 1,
            n_gpus: 1,
            metrics: m,
            stages: Vec::new(),
        };
        let j = r.to_json();
        assert!(j.get("qwait_p50_ms").is_some());
        assert!(j.get("qwait_p99_ms").is_some());
        let classes = j.get("classes").unwrap().as_arr().unwrap();
        assert!(classes[0].get("qwait_p99_ms").is_some());
        assert!(r.summary().contains("queue wait"));
    }

    #[test]
    fn timeseries_merge_aligns_widths_and_preserves_counts() {
        // a fine series (1 s buckets) and a coarse one (compacted):
        // merging must not lose events regardless of which side is finer
        let mut fine_mc = MetricsCollector::default();
        for i in 0..100u64 {
            fine_mc.record_arrival(i as f64);
        }
        let fine = fine_mc.timeseries;
        let mut coarse_mc = MetricsCollector::default();
        for i in 0..600_000u64 {
            coarse_mc.record_arrival(i as f64);
        }
        let coarse = coarse_mc.timeseries;
        assert!(coarse.bucket_s > fine.bucket_s);
        let mut a = fine.clone();
        a.merge(&coarse);
        let mut b = coarse.clone();
        b.merge(&fine);
        for ts in [&a, &b] {
            let total: u64 = ts.buckets.iter().map(|x| x.arrivals).sum();
            assert_eq!(total, 600_100, "merge must not lose counts");
            assert!(ts.buckets.len() <= TS_MAX_BUCKETS);
        }
        assert_eq!(a.bucket_s, b.bucket_s);
        // merging an empty series is a no-op
        let mut c = fine.clone();
        c.merge(&TimeSeries::default());
        assert_eq!(c, fine);
    }

    #[test]
    fn collector_merge_adds_counters_and_digests() {
        let mut a = MetricsCollector::default();
        let mut b = MetricsCollector::default();
        a.record_ttft(0, 0.1, 1.0);
        a.record_completion(0, 0.1, 0.01, 1.0, 8, 2.0);
        a.output_tokens = 100;
        a.record_op("gemm", 1.5);
        b.record_ttft(1, 0.4, 3.0);
        b.record_tbt(1, 0.02, 3.5);
        b.record_completion(1, 0.4, 0.02, 2.0, 8, 4.0);
        b.record_queue_wait(1, 0.25);
        b.output_tokens = 50;
        b.rejected_requests = 2;
        b.record_op("gemm", 0.5);
        b.record_op("a2a", 0.25);
        b.record_ep(100.0, 25.0, 1.5);
        a.merge(&b);
        assert_eq!(a.completed_requests, 2);
        assert_eq!(a.rejected_requests, 2);
        assert_eq!(a.output_tokens, 150);
        assert_eq!(a.ttft.count(), 2);
        assert_eq!(a.queue_wait.count(), 1);
        assert_eq!(a.per_class.len(), 2);
        assert_eq!(a.per_class[1].completed, 1);
        assert_eq!(a.op_time["gemm"], 2.0);
        assert_eq!(a.op_time["a2a"], 0.25);
        assert_eq!(a.ep_draws, 1);
        let arrivals: u64 = a.timeseries.buckets.iter().map(|x| x.arrivals).sum();
        assert_eq!(arrivals, 0);
        // merging an empty collector is a no-op on every count
        let snap = a.clone();
        a.merge(&MetricsCollector::default());
        assert_eq!(a.completed_requests, snap.completed_requests);
        assert_eq!(a.ttft, snap.ttft);
        assert_eq!(a.timeseries, snap.timeseries);
    }

    #[test]
    fn cdf_is_monotone() {
        let xs = vec![3.0, 1.0, 2.0, 2.0];
        let c = cdf(&xs);
        assert_eq!(c.len(), 4);
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 < w[1].1));
        assert_eq!(c.last().unwrap().1, 1.0);
    }

    #[test]
    fn frac_below_works() {
        let xs = vec![0.05, 0.08, 0.2, 0.5];
        assert_eq!(frac_below(&xs, 0.1), 0.5);
    }

    #[test]
    fn pareto_extraction() {
        let pts = vec![
            (10.0, 1.0, "a".to_string()),
            (20.0, 2.0, "b".to_string()),
            (15.0, 3.0, "c".to_string()), // dominated by b
            (30.0, 5.0, "d".to_string()),
        ];
        let front = pareto_frontier(&pts);
        let labels: Vec<&str> = front.iter().map(|p| p.2.as_str()).collect();
        assert_eq!(labels, vec!["a", "b", "d"]);
    }

    #[test]
    fn ep_accounting() {
        let mut m = MetricsCollector::default();
        assert_eq!(m.ep_cross_frac(), 0.0);
        assert_eq!(m.ep_imbalance_mean(), 0.0);
        m.record_ep(100.0, 25.0, 1.5);
        m.record_ep(100.0, 25.0, 2.5);
        assert_eq!(m.ep_bytes, 200.0);
        assert!((m.ep_cross_frac() - 0.25).abs() < 1e-12);
        assert_eq!(m.ep_draws, 2);
        assert!((m.ep_imbalance_mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn migration_accounting() {
        let mut m = MetricsCollector::default();
        assert_eq!(m.migration_pre_imbalance_mean(), 0.0);
        assert_eq!(m.migration_post_imbalance_mean(), 0.0);
        m.record_migration(100.0, 40.0, 2.0, 1.2);
        m.record_migration(100.0, 0.0, 3.0, 1.4);
        assert_eq!(m.migrations, 2);
        assert_eq!(m.migrated_bytes, 200.0);
        assert_eq!(m.migrated_cross_bytes, 40.0);
        assert_eq!(m.migration_stall_s, 0.0, "stall is metered only when paid");
        assert!((m.migration_pre_imbalance_mean() - 2.5).abs() < 1e-12);
        assert!((m.migration_post_imbalance_mean() - 1.3).abs() < 1e-12);
    }

    #[test]
    fn deterministic_json_drops_only_host_time() {
        let r = SimReport {
            mode: "test".into(),
            predictor: "oracle".into(),
            sim_duration: 10.0,
            host_duration: 1.0,
            events_processed: 1000,
            n_gpus: 8,
            metrics: MetricsCollector::default(),
            stages: Vec::new(),
        };
        let full = r.to_json();
        let det = r.to_json_deterministic();
        assert!(full.get("host_duration_s").is_some());
        assert!(det.get("host_duration_s").is_none());
        // everything else is carried over unchanged
        if let (Json::Obj(f), Json::Obj(d)) = (&full, &det) {
            assert_eq!(f.len(), d.len() + 1);
            for (k, v) in d {
                assert_eq!(f.get(k), Some(v));
            }
        } else {
            panic!("reports must serialize to objects");
        }
    }

    #[test]
    fn report_throughput_math() {
        let mut m = MetricsCollector::default();
        m.output_tokens = 8000;
        let r = SimReport {
            mode: "test".into(),
            predictor: "oracle".into(),
            sim_duration: 10.0,
            host_duration: 1.0,
            events_processed: 1000,
            n_gpus: 8,
            metrics: m,
            stages: Vec::new(),
        };
        assert_eq!(r.throughput(), 800.0);
        assert_eq!(r.tokens_per_sec_per_gpu(), 100.0);
        assert_eq!(r.events_per_sec(), 1000.0);
    }
}
