//! Metrics: latency percentiles, throughput, goodput, CDFs, Pareto.

use std::collections::BTreeMap;

use crate::config::json::Json;
use crate::core::SimTime;

/// Online collection of per-request and system-level metrics.
#[derive(Default, Clone, Debug)]
pub struct MetricsCollector {
    /// Time-to-first-token samples, seconds.
    pub ttft: Vec<f64>,
    /// Time-between-tokens (inter-token latency) samples, seconds.
    pub tbt: Vec<f64>,
    /// End-to-end request latency samples, seconds.
    pub e2e: Vec<f64>,
    /// Normalized latency (e2e / output tokens), seconds/token.
    pub norm_latency: Vec<f64>,
    pub completed_requests: u64,
    pub rejected_requests: u64,
    pub output_tokens: u64,
    pub prefill_tokens: u64,
    pub kv_transfers: u64,
    pub kv_bytes: f64,
    pub iterations: u64,
    /// Underlying predictor evaluations (PJRT launches for the learned
    /// predictor) — the §Perf cache-effectiveness metric.
    pub predictor_evals: u64,
    /// Per-operator-class total simulated seconds.
    pub op_time: BTreeMap<&'static str, f64>,
    /// EP dispatch + combine byte volume routed through the fabric
    /// (including rank-local bytes that never hit the network).
    pub ep_bytes: f64,
    /// EP bytes that crossed a cluster boundary.
    pub ep_cross_bytes: f64,
    /// Running sum of per-routing-draw EP rank-load imbalance (max/mean)
    /// over `ep_draws` draws — O(1) accounting, draws number in the
    /// millions on long MoE runs.
    pub ep_imbalance_sum: f64,
    /// Number of EP routing draws accounted.
    pub ep_draws: u64,
    /// AF decode: FFN-pool idle seconds inside steps — dispatch bubbles
    /// the ping-pong pipeline failed to hide.
    pub dispatch_bubble_s: f64,
    /// Token-slots dropped by the MoE capacity-factor policy (GShard
    /// style overflow drops; 0 without a capacity factor).
    pub dropped_tokens: u64,
    /// Expert migrations adopted (placement re-writes; 0 with
    /// `--migration off`).
    pub migrations: u64,
    /// Expert weight bytes copied between EP ranks by migrations.
    pub migrated_bytes: f64,
    /// Migrated bytes that crossed a cluster boundary (rode the WAN
    /// trunk).
    pub migrated_cross_bytes: f64,
    /// Replica-seconds actually stalled on expert weight moves: each
    /// migration's transfer makespan is charged to every replica of the
    /// stage at its *next* iteration start, and metered here only when
    /// that iteration really runs — a migration adopted on the final
    /// iteration delays nothing and meters nothing.
    pub migration_stall_s: f64,
    /// Sum over migrations of the predicted rank imbalance *before*
    /// re-placement (under the estimated loads); divide by
    /// [`MetricsCollector::migrations`] for the mean.
    pub migration_pre_imb_sum: f64,
    /// Sum over migrations of the predicted rank imbalance *after*
    /// re-placement.
    pub migration_post_imb_sum: f64,
}

impl MetricsCollector {
    pub fn record_op(&mut self, class: &'static str, secs: f64) {
        *self.op_time.entry(class).or_insert(0.0) += secs;
    }

    /// Account one EP dispatch/combine draw.
    pub fn record_ep(&mut self, bytes: f64, cross_bytes: f64, imbalance: f64) {
        self.ep_bytes += bytes;
        self.ep_cross_bytes += cross_bytes;
        self.ep_imbalance_sum += imbalance;
        self.ep_draws += 1;
    }

    /// Mean EP rank-load imbalance across routing draws.
    pub fn ep_imbalance_mean(&self) -> f64 {
        if self.ep_draws > 0 {
            self.ep_imbalance_sum / self.ep_draws as f64
        } else {
            0.0
        }
    }

    /// Fraction of EP bytes that crossed a cluster boundary.
    pub fn ep_cross_frac(&self) -> f64 {
        if self.ep_bytes > 0.0 {
            self.ep_cross_bytes / self.ep_bytes
        } else {
            0.0
        }
    }

    /// Account one adopted expert migration: `bytes`/`cross_bytes` of
    /// weights moved and the predicted pre/post rank imbalance of the
    /// re-placement. Stall is metered separately
    /// ([`MetricsCollector::migration_stall_s`]) when a replica
    /// actually pays it.
    pub fn record_migration(
        &mut self,
        bytes: f64,
        cross_bytes: f64,
        pre_imbalance: f64,
        post_imbalance: f64,
    ) {
        self.migrations += 1;
        self.migrated_bytes += bytes;
        self.migrated_cross_bytes += cross_bytes;
        self.migration_pre_imb_sum += pre_imbalance;
        self.migration_post_imb_sum += post_imbalance;
    }

    /// Mean predicted rank imbalance immediately before migrations
    /// (0 when none fired).
    pub fn migration_pre_imbalance_mean(&self) -> f64 {
        if self.migrations > 0 {
            self.migration_pre_imb_sum / self.migrations as f64
        } else {
            0.0
        }
    }

    /// Mean predicted rank imbalance immediately after migrations.
    pub fn migration_post_imbalance_mean(&self) -> f64 {
        if self.migrations > 0 {
            self.migration_post_imb_sum / self.migrations as f64
        } else {
            0.0
        }
    }
}

/// Simple percentile over unsorted samples (nearest-rank).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Empirical CDF: sorted (value, cumulative fraction) pairs.
pub fn cdf(xs: &[f64]) -> Vec<(f64, f64)> {
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len() as f64;
    v.into_iter().enumerate().map(|(i, x)| (x, (i + 1) as f64 / n)).collect()
}

/// Fraction of samples <= threshold.
pub fn frac_below(xs: &[f64], threshold: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&x| x <= threshold).count() as f64 / xs.len() as f64
}

/// Per-stage summary of a stage-graph run (one entry per pool).
#[derive(Clone, Debug, Default)]
pub struct StageReport {
    pub name: String,
    pub kind: String,
    pub replicas: u32,
    /// GPUs backing the whole stage.
    pub gpus: u32,
    pub gpu_name: String,
    pub iterations: u64,
    /// Prefill + decode tokens processed by the stage.
    pub tokens: u64,
    /// Mean fraction of the run the stage's replicas were executing.
    pub busy_frac: f64,
    /// Peak KV-pool utilization across the stage's replicas.
    pub peak_mem_frac: f64,
}

/// Final report of one simulation run.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub mode: String,
    pub predictor: String,
    /// Simulated wall-clock span, seconds.
    pub sim_duration: f64,
    /// Host time spent simulating, seconds.
    pub host_duration: f64,
    pub events_processed: u64,
    pub n_gpus: u32,
    pub metrics: MetricsCollector,
    /// Per-stage breakdown (empty for simulators without stage pools).
    pub stages: Vec<StageReport>,
}

impl SimReport {
    /// Output tokens per second per GPU — Table 2's headline metric.
    pub fn tokens_per_sec_per_gpu(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.output_tokens as f64 / self.sim_duration / self.n_gpus as f64
    }

    /// Total output token throughput, tokens/s.
    pub fn throughput(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.output_tokens as f64 / self.sim_duration
    }

    pub fn requests_per_sec(&self) -> f64 {
        if self.sim_duration <= 0.0 {
            return 0.0;
        }
        self.metrics.completed_requests as f64 / self.sim_duration
    }

    /// Goodput: completed requests/s meeting both SLOs (DistServe-style).
    pub fn goodput(&self, ttft_slo: f64, tbt_slo: f64) -> f64 {
        if self.sim_duration <= 0.0 || self.metrics.ttft.is_empty() {
            return 0.0;
        }
        // joint satisfaction approximated per-request via paired samples
        let ok = self
            .metrics
            .ttft
            .iter()
            .zip(&self.metrics.norm_latency)
            .filter(|(&t, &n)| t <= ttft_slo && n <= tbt_slo)
            .count();
        ok as f64 / self.sim_duration
    }

    /// Simulation speed: simulated seconds per host second.
    pub fn speedup(&self) -> f64 {
        if self.host_duration <= 0.0 {
            return 0.0;
        }
        self.sim_duration / self.host_duration
    }

    pub fn events_per_sec(&self) -> f64 {
        if self.host_duration <= 0.0 {
            return 0.0;
        }
        self.events_processed as f64 / self.host_duration
    }

    pub fn summary(&self) -> String {
        let m = &self.metrics;
        let mut s = format!(
            "[{} | {}] {:.1}s simulated in {:.2}s host ({:.0}x, {:.0} ev/s)\n\
             requests: {} done, {} rejected | tokens: {} out, {} prefill\n\
             throughput: {:.1} tok/s ({:.2} tok/s/gpu on {} gpus), {:.2} req/s\n\
             TTFT p50/p99: {:.1}/{:.1} ms | TBT p50/p99: {:.2}/{:.2} ms | e2e p50: {:.2} s\n\
             iterations: {} | kv transfers: {} ({:.1} MB)",
            self.mode,
            self.predictor,
            self.sim_duration,
            self.host_duration,
            self.speedup(),
            self.events_per_sec(),
            m.completed_requests,
            m.rejected_requests,
            m.output_tokens,
            m.prefill_tokens,
            self.throughput(),
            self.tokens_per_sec_per_gpu(),
            self.n_gpus,
            self.requests_per_sec(),
            percentile(&m.ttft, 50.0) * 1e3,
            percentile(&m.ttft, 99.0) * 1e3,
            percentile(&m.tbt, 50.0) * 1e3,
            percentile(&m.tbt, 99.0) * 1e3,
            percentile(&m.e2e, 50.0),
            m.iterations,
            m.kv_transfers,
            m.kv_bytes / 1e6,
        );
        if m.ep_bytes > 0.0 {
            s.push_str(&format!(
                "\nEP: {:.1} MB dispatched+combined ({:.1}% cross-cluster) | \
                 rank imbalance mean {:.2} | dispatch bubble {:.3} s",
                m.ep_bytes / 1e6,
                m.ep_cross_frac() * 100.0,
                m.ep_imbalance_mean(),
                m.dispatch_bubble_s,
            ));
        }
        if m.dropped_tokens > 0 {
            s.push_str(&format!(
                "\ncapacity policy: {} token-slots dropped",
                m.dropped_tokens
            ));
        }
        if m.migrations > 0 {
            s.push_str(&format!(
                "\nexpert migration: {} migrations, {:.1} MB moved \
                 ({:.1}% cross-cluster), stall {:.4} s, \
                 predicted imbalance {:.2} -> {:.2}",
                m.migrations,
                m.migrated_bytes / 1e6,
                if m.migrated_bytes > 0.0 {
                    m.migrated_cross_bytes / m.migrated_bytes * 100.0
                } else {
                    0.0
                },
                m.migration_stall_s,
                m.migration_pre_imbalance_mean(),
                m.migration_post_imbalance_mean(),
            ));
        }
        for st in &self.stages {
            s.push_str(&format!(
                "\nstage {} [{}] {}x{} on {}: {} iters, {} tokens, busy {:.1}%, peak mem {:.1}%",
                st.name,
                st.kind,
                st.replicas,
                if st.replicas > 0 { st.gpus / st.replicas.max(1) } else { st.gpus },
                st.gpu_name,
                st.iterations,
                st.tokens,
                st.busy_frac * 100.0,
                st.peak_mem_frac * 100.0,
            ));
        }
        s
    }

    /// [`SimReport::to_json`] minus the host-time field
    /// (`host_duration_s`): every remaining value is a pure function of
    /// the experiment config, so the document is byte-identical across
    /// runs, machines, and sweep thread counts. The sweep engine's
    /// merged reports are built from this projection
    /// (`rust/tests/sweep.rs` pins the byte-identity).
    pub fn to_json_deterministic(&self) -> Json {
        let mut j = self.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("host_duration_s");
        }
        j
    }

    pub fn to_json(&self) -> Json {
        let m = &self.metrics;
        Json::obj(vec![
            ("mode", Json::Str(self.mode.clone())),
            ("predictor", Json::Str(self.predictor.clone())),
            ("sim_duration_s", Json::Num(self.sim_duration)),
            ("host_duration_s", Json::Num(self.host_duration)),
            ("events", Json::Num(self.events_processed as f64)),
            ("n_gpus", Json::Num(self.n_gpus as f64)),
            ("completed", Json::Num(m.completed_requests as f64)),
            ("rejected", Json::Num(m.rejected_requests as f64)),
            ("output_tokens", Json::Num(m.output_tokens as f64)),
            ("tokens_per_sec_per_gpu", Json::Num(self.tokens_per_sec_per_gpu())),
            ("ttft_p50_ms", Json::Num(percentile(&m.ttft, 50.0) * 1e3)),
            ("ttft_p99_ms", Json::Num(percentile(&m.ttft, 99.0) * 1e3)),
            ("tbt_p50_ms", Json::Num(percentile(&m.tbt, 50.0) * 1e3)),
            ("tbt_p99_ms", Json::Num(percentile(&m.tbt, 99.0) * 1e3)),
            ("e2e_p50_s", Json::Num(percentile(&m.e2e, 50.0))),
            ("iterations", Json::Num(m.iterations as f64)),
            ("kv_transfers", Json::Num(m.kv_transfers as f64)),
            ("ep_bytes", Json::Num(m.ep_bytes)),
            ("ep_cross_frac", Json::Num(m.ep_cross_frac())),
            ("ep_imbalance_mean", Json::Num(m.ep_imbalance_mean())),
            ("dispatch_bubble_s", Json::Num(m.dispatch_bubble_s)),
            ("dropped_tokens", Json::Num(m.dropped_tokens as f64)),
            ("migrations", Json::Num(m.migrations as f64)),
            ("migrated_bytes", Json::Num(m.migrated_bytes)),
            ("migrated_cross_bytes", Json::Num(m.migrated_cross_bytes)),
            ("migration_stall_s", Json::Num(m.migration_stall_s)),
            ("migration_pre_imbalance", Json::Num(m.migration_pre_imbalance_mean())),
            ("migration_post_imbalance", Json::Num(m.migration_post_imbalance_mean())),
            (
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|st| {
                            Json::obj(vec![
                                ("name", Json::Str(st.name.clone())),
                                ("kind", Json::Str(st.kind.clone())),
                                ("replicas", Json::Num(st.replicas as f64)),
                                ("gpus", Json::Num(st.gpus as f64)),
                                ("gpu", Json::Str(st.gpu_name.clone())),
                                ("iterations", Json::Num(st.iterations as f64)),
                                ("tokens", Json::Num(st.tokens as f64)),
                                ("busy_frac", Json::Num(st.busy_frac)),
                                ("peak_mem_frac", Json::Num(st.peak_mem_frac)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Extract the Pareto frontier (maximize x=throughput, minimize y=latency)
/// from a set of (throughput, latency, label) points.
pub fn pareto_frontier(points: &[(f64, f64, String)]) -> Vec<(f64, f64, String)> {
    let mut pts: Vec<_> = points.to_vec();
    pts.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut best = f64::INFINITY;
    let mut out = Vec::new();
    for p in pts {
        if p.1 < best {
            best = p.1;
            out.push(p);
        }
    }
    out.reverse();
    out
}

/// Latency timestamps for one request (used by the coordinator).
#[derive(Clone, Copy, Debug, Default)]
pub struct ReqTimestamps {
    pub arrival: SimTime,
    pub prefill_done: Option<SimTime>,
    pub first_token: Option<SimTime>,
    pub done: Option<SimTime>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        // nearest-rank with round-half-up: rank(50%) = round(49.5) = 50
        assert_eq!(percentile(&xs, 50.0), 51.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn cdf_is_monotone() {
        let xs = vec![3.0, 1.0, 2.0, 2.0];
        let c = cdf(&xs);
        assert_eq!(c.len(), 4);
        assert!(c.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 < w[1].1));
        assert_eq!(c.last().unwrap().1, 1.0);
    }

    #[test]
    fn frac_below_works() {
        let xs = vec![0.05, 0.08, 0.2, 0.5];
        assert_eq!(frac_below(&xs, 0.1), 0.5);
    }

    #[test]
    fn pareto_extraction() {
        let pts = vec![
            (10.0, 1.0, "a".to_string()),
            (20.0, 2.0, "b".to_string()),
            (15.0, 3.0, "c".to_string()), // dominated by b
            (30.0, 5.0, "d".to_string()),
        ];
        let front = pareto_frontier(&pts);
        let labels: Vec<&str> = front.iter().map(|p| p.2.as_str()).collect();
        assert_eq!(labels, vec!["a", "b", "d"]);
    }

    #[test]
    fn ep_accounting() {
        let mut m = MetricsCollector::default();
        assert_eq!(m.ep_cross_frac(), 0.0);
        assert_eq!(m.ep_imbalance_mean(), 0.0);
        m.record_ep(100.0, 25.0, 1.5);
        m.record_ep(100.0, 25.0, 2.5);
        assert_eq!(m.ep_bytes, 200.0);
        assert!((m.ep_cross_frac() - 0.25).abs() < 1e-12);
        assert_eq!(m.ep_draws, 2);
        assert!((m.ep_imbalance_mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn migration_accounting() {
        let mut m = MetricsCollector::default();
        assert_eq!(m.migration_pre_imbalance_mean(), 0.0);
        assert_eq!(m.migration_post_imbalance_mean(), 0.0);
        m.record_migration(100.0, 40.0, 2.0, 1.2);
        m.record_migration(100.0, 0.0, 3.0, 1.4);
        assert_eq!(m.migrations, 2);
        assert_eq!(m.migrated_bytes, 200.0);
        assert_eq!(m.migrated_cross_bytes, 40.0);
        assert_eq!(m.migration_stall_s, 0.0, "stall is metered only when paid");
        assert!((m.migration_pre_imbalance_mean() - 2.5).abs() < 1e-12);
        assert!((m.migration_post_imbalance_mean() - 1.3).abs() < 1e-12);
    }

    #[test]
    fn deterministic_json_drops_only_host_time() {
        let r = SimReport {
            mode: "test".into(),
            predictor: "oracle".into(),
            sim_duration: 10.0,
            host_duration: 1.0,
            events_processed: 1000,
            n_gpus: 8,
            metrics: MetricsCollector::default(),
            stages: Vec::new(),
        };
        let full = r.to_json();
        let det = r.to_json_deterministic();
        assert!(full.get("host_duration_s").is_some());
        assert!(det.get("host_duration_s").is_none());
        // everything else is carried over unchanged
        if let (Json::Obj(f), Json::Obj(d)) = (&full, &det) {
            assert_eq!(f.len(), d.len() + 1);
            for (k, v) in d {
                assert_eq!(f.get(k), Some(v));
            }
        } else {
            panic!("reports must serialize to objects");
        }
    }

    #[test]
    fn report_throughput_math() {
        let mut m = MetricsCollector::default();
        m.output_tokens = 8000;
        let r = SimReport {
            mode: "test".into(),
            predictor: "oracle".into(),
            sim_duration: 10.0,
            host_duration: 1.0,
            events_processed: 1000,
            n_gpus: 8,
            metrics: m,
            stages: Vec::new(),
        };
        assert_eq!(r.throughput(), 800.0);
        assert_eq!(r.tokens_per_sec_per_gpu(), 100.0);
        assert_eq!(r.events_per_sec(), 1000.0);
    }
}
