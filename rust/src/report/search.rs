//! Merged autotuner reporting: one markdown / CSV / JSON document for
//! a whole [`SearchResult`].
//!
//! Like the sweep renderers ([`super::sweep`]), all three are pure
//! functions of the (deterministic) search result, so output is
//! byte-identical for any `--threads` — and, because the search engine
//! computes its trajectory from logical counts only, byte-identical
//! between an uninterrupted run and a killed-then-`--resume`d one
//! (`rust/tests/search.rs` pins both).

use crate::config::json::Json;
use crate::search::{SearchRanked, SearchResult};

/// Metric columns of the ranking table/CSV, after the rank and axis
/// columns.
pub const SEARCH_METRIC_COLS: &[&str] = &[
    "pareto",
    "cost_gpu_s_per_1k",
    "goodput_rps",
    "tbt_p99_ms",
    "tok_s_gpu",
    "completed",
    "sim_s",
];

/// Columns of the trajectory table.
pub const SEARCH_TRAJECTORY_COLS: &[&str] =
    &["rung", "requests", "population", "errors", "dedup_hits", "simulated", "pruned", "promoted"];

fn axis_headers(result: &SearchResult) -> Vec<String> {
    if result.axes.is_empty() {
        vec!["point".into()]
    } else {
        result
            .axes
            .iter()
            .map(|a| a.strip_prefix("flag:").unwrap_or(a).to_string())
            .collect()
    }
}

fn axis_cells(result: &SearchResult, r: &SearchRanked) -> Vec<String> {
    if result.axes.is_empty() {
        vec![r.point.label.clone()]
    } else {
        r.point.assigns.iter().map(|(_, v)| v.clone()).collect()
    }
}

fn metric_cells(r: &SearchRanked) -> Vec<String> {
    let num = |k: &str| r.report.get(k).and_then(|v| v.as_f64().ok()).unwrap_or(0.0);
    vec![
        if r.pareto { "*".into() } else { "".into() },
        format!("{:.3}", r.metrics.cost_gpu_s_per_1k),
        format!("{:.2}", r.metrics.goodput_rps),
        format!("{:.2}", r.metrics.tbt_p99_ms),
        format!("{:.2}", num("tokens_per_sec_per_gpu")),
        format!("{}", num("completed") as u64),
        format!("{:.3}", num("sim_duration_s")),
    ]
}

fn sanitize(cells: Vec<String>, delim: char, replacement: &str) -> Vec<String> {
    cells.into_iter().map(|c| c.replace(delim, replacement)).collect()
}

fn ranking_table(
    result: &SearchResult,
    delim: char,
    replacement: &str,
    render: fn(&[&str], &[Vec<String>]) -> String,
) -> String {
    let mut headers = vec!["rank".to_string()];
    headers.extend(axis_headers(result));
    headers.extend(SEARCH_METRIC_COLS.iter().map(|s| s.to_string()));
    let headers = sanitize(headers, delim, replacement);
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = result
        .ranked
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let mut row = vec![(i + 1).to_string()];
            row.extend(axis_cells(result, r));
            row.extend(metric_cells(r));
            sanitize(row, delim, replacement)
        })
        .collect();
    render(&hrefs, &rows)
}

fn trajectory_rows(result: &SearchResult) -> Vec<Vec<String>> {
    result
        .trajectory
        .iter()
        .map(|t| {
            vec![
                t.rung.to_string(),
                t.requests.to_string(),
                t.population.to_string(),
                t.errors.to_string(),
                t.dedup_hits.to_string(),
                t.simulated.to_string(),
                t.pruned.to_string(),
                t.promoted.to_string(),
            ]
        })
        .collect()
}

/// Merged search report as markdown: a summary line, the trajectory
/// table, the ranking table, and (if any) an error table. Cells are
/// sanitized `|` → `/` like the sweep renderer.
pub fn search_markdown(result: &SearchResult) -> String {
    let mut out = format!(
        "objective={} grid_points={} searched_points={} dedup_hits={} full_requests={}\n\n",
        result.objective.name(),
        result.grid_points,
        result.searched_points(),
        result.dedup_hits(),
        result.full_requests,
    );
    out.push_str("## Trajectory\n\n");
    out.push_str(&super::markdown_table(SEARCH_TRAJECTORY_COLS, &trajectory_rows(result)));
    out.push_str("\n## Ranking\n\n");
    out.push_str(&ranking_table(result, '|', "/", super::markdown_table));
    if !result.errors.is_empty() {
        out.push_str("\n## Errors\n\n");
        let rows: Vec<Vec<String>> = result
            .errors
            .iter()
            .map(|e| {
                let written = e
                    .point
                    .written
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(" ");
                sanitize(
                    vec![e.point.label.clone(), e.rung.to_string(), written, e.error.clone()],
                    '|',
                    "/",
                )
            })
            .collect();
        out.push_str(&super::markdown_table(&["point", "rung", "written", "error"], &rows));
    }
    out
}

/// Merged search report as CSV (the ranking table only, cells
/// sanitized `,` → `;`).
pub fn search_csv(result: &SearchResult) -> String {
    ranking_table(result, ',', ";", super::csv)
}

/// A metric as JSON, with non-finite sentinels (`inf` cost for a run
/// that generated nothing) mapped to `null` so the document stays
/// parseable.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

/// Merged search report as JSON: grid metadata, the trajectory, the
/// ranked survivors (each embedding its deterministic full-horizon
/// report), and every error with its written flags.
pub fn search_json(result: &SearchResult) -> Json {
    let trajectory = result
        .trajectory
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("rung", Json::Num(t.rung as f64)),
                ("requests", Json::Num(t.requests as f64)),
                ("population", Json::Num(t.population as f64)),
                ("errors", Json::Num(t.errors as f64)),
                ("dedup_hits", Json::Num(t.dedup_hits as f64)),
                ("simulated", Json::Num(t.simulated as f64)),
                ("pruned", Json::Num(t.pruned as f64)),
                ("promoted", Json::Num(t.promoted as f64)),
            ])
        })
        .collect();
    let ranked = result
        .ranked
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let assigns = r
                .point
                .assigns
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect();
            Json::obj(vec![
                ("rank", Json::Num((i + 1) as f64)),
                ("index", Json::Num(r.point.index as f64)),
                ("label", Json::Str(r.point.label.clone())),
                ("assigns", Json::Obj(assigns)),
                // hex: a u64 hash does not survive an f64 round-trip
                ("config_hash", Json::Str(format!("{:016x}", r.hash))),
                ("pareto", Json::Bool(r.pareto)),
                ("score", num_or_null(r.score)),
                ("cost_gpu_s_per_1k", num_or_null(r.metrics.cost_gpu_s_per_1k)),
                ("goodput_rps", num_or_null(r.metrics.goodput_rps)),
                ("tbt_p99_ms", num_or_null(r.metrics.tbt_p99_ms)),
                ("report", r.report.clone()),
            ])
        })
        .collect();
    let errors = result
        .errors
        .iter()
        .map(|e| {
            let written = e
                .point
                .written
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect();
            Json::obj(vec![
                ("index", Json::Num(e.point.index as f64)),
                ("label", Json::Str(e.point.label.clone())),
                ("rung", Json::Num(e.rung as f64)),
                ("error", Json::Str(e.error.clone())),
                ("written", Json::Obj(written)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("objective", Json::Str(result.objective.name().to_string())),
        ("grid_points", Json::Num(result.grid_points as f64)),
        ("full_requests", Json::Num(result.full_requests as f64)),
        ("searched_points", Json::Num(result.searched_points() as f64)),
        ("dedup_hits", Json::Num(result.dedup_hits() as f64)),
        ("axes", Json::Arr(result.axes.iter().map(|a| Json::Str(a.clone())).collect())),
        ("trajectory", Json::Arr(trajectory)),
        ("ranked", Json::Arr(ranked)),
        ("errors", Json::Arr(errors)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::search::{MetricPoint, Objective, RungStat, SearchError, SearchRanked};
    use crate::sweep::SweepPoint;

    fn pt(index: usize, cf: &str) -> SweepPoint {
        SweepPoint {
            index,
            assigns: vec![("capacity-factor".into(), cf.into())],
            label: format!("capacity-factor={cf}"),
            written: vec![("capacity-factor".into(), cf.into())],
        }
    }

    fn fake_result() -> SearchResult {
        let report = Json::obj(vec![
            ("tokens_per_sec_per_gpu", Json::Num(500.0)),
            ("completed", Json::Num(9.0)),
            ("sim_duration_s", Json::Num(3.0)),
            ("tbt_p99_ms", Json::Num(42.0)),
        ]);
        let m = MetricPoint::from_report(&report);
        SearchResult {
            axes: vec!["capacity-factor".into()],
            objective: Objective::Cost,
            grid_points: 4,
            full_requests: 64,
            trajectory: vec![
                RungStat {
                    rung: 0,
                    requests: 16,
                    population: 4,
                    errors: 1,
                    dedup_hits: 1,
                    simulated: 2,
                    pruned: 1,
                    promoted: 1,
                },
                RungStat {
                    rung: 1,
                    requests: 64,
                    population: 1,
                    errors: 0,
                    dedup_hits: 0,
                    simulated: 1,
                    pruned: 0,
                    promoted: 1,
                },
            ],
            ranked: vec![SearchRanked {
                point: pt(2, "1.25"),
                hash: 0xdead_beef,
                report,
                metrics: m,
                score: Objective::Cost.score(&m),
                pareto: true,
            }],
            errors: vec![SearchError {
                point: pt(0, "0.0|bad"),
                rung: 0,
                error: "capacity factor must be positive (got 0|bad)".into(),
            }],
        }
    }

    #[test]
    fn markdown_has_summary_trajectory_ranking_and_errors() {
        let md = search_markdown(&fake_result());
        assert!(md.starts_with("objective=cost grid_points=4 searched_points=3 dedup_hits=1"));
        assert!(md.contains("## Trajectory"));
        assert!(md.contains("## Ranking"));
        assert!(md.contains("## Errors"));
        assert!(md.contains("capacity-factor=0.0/bad"), "pipes sanitized: {md}");
        // every row of every table keeps its table's column count
        for table in md.split("\n\n").filter(|s| s.starts_with('|')) {
            let pipes = table.lines().next().unwrap().matches('|').count();
            assert!(table.lines().all(|l| l.matches('|').count() == pipes), "{table}");
        }
    }

    #[test]
    fn csv_is_ranking_only_and_rectangular() {
        let csv = search_csv(&fake_result());
        assert!(csv.starts_with("rank,capacity-factor,pareto,cost_gpu_s_per_1k"));
        let cols = csv.lines().next().unwrap().matches(',').count();
        assert!(csv.lines().all(|l| l.matches(',').count() == cols), "{csv}");
        assert_eq!(csv.lines().count(), 2, "header + one ranked row");
        assert!(csv.contains("1,1.25,*,2.000,3.00,42.00,500.00,9,3.000"), "{csv}");
    }

    #[test]
    fn json_embeds_trajectory_hash_and_written_flags() {
        let j = search_json(&fake_result());
        assert_eq!(j.req("searched_points").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.req("dedup_hits").unwrap().as_f64().unwrap(), 1.0);
        let traj = j.req("trajectory").unwrap().as_arr().unwrap();
        assert_eq!(traj.len(), 2);
        assert_eq!(traj[0].req("pruned").unwrap().as_f64().unwrap(), 1.0);
        let ranked = j.req("ranked").unwrap().as_arr().unwrap();
        assert_eq!(ranked[0].req("config_hash").unwrap().as_str().unwrap(), "00000000deadbeef");
        assert!(ranked[0].req("pareto").unwrap().as_bool().unwrap());
        assert_eq!(ranked[0].req("rank").unwrap().as_f64().unwrap(), 1.0);
        let errs = j.req("errors").unwrap().as_arr().unwrap();
        assert_eq!(
            errs[0].req("written").unwrap().req("capacity-factor").unwrap().as_str().unwrap(),
            "0.0|bad",
            "JSON keeps raw flag text"
        );
        // the whole document round-trips (no bare inf/nan leaked in)
        let text = j.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), j);
        // a degenerate metric serializes as null, not bare inf
        let mut degenerate = fake_result();
        degenerate.ranked[0].metrics.cost_gpu_s_per_1k = f64::INFINITY;
        degenerate.ranked[0].score = f64::INFINITY;
        let text = search_json(&degenerate).to_string_pretty();
        assert!(Json::parse(&text).is_ok(), "{text}");
        assert!(!text.contains("inf"), "{text}");
    }
}
