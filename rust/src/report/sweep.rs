//! Merged sweep reporting: one markdown / CSV / JSON document for a
//! whole [`SweepResult`] grid.
//!
//! All three renderers are pure functions of the (deterministic) sweep
//! result, so their output is byte-identical regardless of how many
//! threads ran the sweep — `rust/tests/sweep.rs` pins this. The JSON
//! form embeds each point's
//! [`SimReport::to_json_deterministic`](crate::metrics::SimReport::to_json_deterministic)
//! projection (host-time fields excluded).

use crate::config::json::Json;
use crate::sweep::{PointResult, SweepResult};

/// Metric columns of the merged table/CSV, after the axis columns.
pub const SWEEP_METRIC_COLS: &[&str] = &[
    "tok_s_gpu",
    "ttft_p50_ms",
    "ttft_p99_ms",
    "tbt_p50_ms",
    "tbt_p99_ms",
    "e2e_p50_s",
    "qwait_p50_ms",
    "qwait_p99_ms",
    "goodput_rps",
    "sim_s",
    "completed",
    "dropped_tokens",
    "ep_imbalance_mean",
    "migrations",
    "availability",
    "scale_events",
    "link_faults",
    "link_degraded_s",
];

fn metric_cells(r: &PointResult) -> Vec<String> {
    match &r.outcome {
        Ok(rep) => {
            let m = &rep.metrics;
            vec![
                format!("{:.2}", rep.tokens_per_sec_per_gpu()),
                format!("{:.1}", m.ttft.quantile(50.0) * 1e3),
                format!("{:.1}", m.ttft.quantile(99.0) * 1e3),
                format!("{:.2}", m.tbt.quantile(50.0) * 1e3),
                format!("{:.2}", m.tbt.quantile(99.0) * 1e3),
                format!("{:.2}", m.e2e.quantile(50.0)),
                format!("{:.2}", m.queue_wait.quantile(50.0) * 1e3),
                format!("{:.2}", m.queue_wait.quantile(99.0) * 1e3),
                // without SLO flags every completion counts, so this
                // degrades to plain completion throughput
                format!("{:.2}", rep.goodput()),
                format!("{:.3}", rep.sim_duration),
                m.completed_requests.to_string(),
                m.dropped_tokens.to_string(),
                format!("{:.3}", m.ep_imbalance_mean()),
                m.migrations.to_string(),
                // 1.0000 for an immortal fleet — the column only moves
                // when a --faults axis is in play
                format!("{:.4}", rep.availability()),
                (m.scale_up_events + m.scale_down_events).to_string(),
                m.link_faults.to_string(),
                // all three tiers summed: 0.0 without a --link-faults axis
                format!("{:.1}", m.link_degraded_s.iter().sum::<f64>()),
            ]
        }
        Err(e) => {
            // keep error rows rectangular: message in the first metric
            // column, dashes in the rest (renderers sanitize their own
            // delimiter; JSON carries the raw message)
            let mut cells = vec![format!("error: {e}")];
            cells.resize(SWEEP_METRIC_COLS.len(), "-".into());
            cells
        }
    }
}

/// Axis column headers: one per cartesian axis, or a single `point`
/// label column for explicit point lists.
fn axis_headers(result: &SweepResult) -> Vec<String> {
    if result.axes.is_empty() {
        vec!["point".into()]
    } else {
        result
            .axes
            .iter()
            .map(|a| a.strip_prefix("flag:").unwrap_or(a).to_string())
            .collect()
    }
}

fn axis_cells(result: &SweepResult, r: &PointResult) -> Vec<String> {
    if result.axes.is_empty() {
        vec![r.point.label.clone()]
    } else {
        // cartesian assigns are stored in axis order
        r.point.assigns.iter().map(|(_, v)| v.clone()).collect()
    }
}

fn headers(result: &SweepResult) -> Vec<String> {
    let mut h = axis_headers(result);
    h.extend(SWEEP_METRIC_COLS.iter().map(|s| s.to_string()));
    h
}

fn rows(result: &SweepResult) -> Vec<Vec<String>> {
    result
        .points
        .iter()
        .map(|r| {
            let mut row = axis_cells(result, r);
            row.extend(metric_cells(r));
            row
        })
        .collect()
}

/// Shared table pipeline: headers + rows with the renderer's delimiter
/// sanitized out of every cell (error messages quote `(a800|a100|...)`
/// grammars, labels are free-form), so each row keeps the same column
/// count in the rendered output.
fn render_table(
    result: &SweepResult,
    delim: char,
    replacement: &str,
    render: fn(&[&str], &[Vec<String>]) -> String,
) -> String {
    let headers: Vec<String> =
        headers(result).into_iter().map(|h| h.replace(delim, replacement)).collect();
    let hrefs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let rows: Vec<Vec<String>> = rows(result)
        .into_iter()
        .map(|row| row.into_iter().map(|c| c.replace(delim, replacement)).collect())
        .collect();
    render(&hrefs, &rows)
}

/// Merged sweep report as a markdown table (cells sanitized `|` → `/`).
pub fn sweep_markdown(result: &SweepResult) -> String {
    render_table(result, '|', "/", super::markdown_table)
}

/// Merged sweep report as CSV (cells sanitized `,` → `;`).
pub fn sweep_csv(result: &SweepResult) -> String {
    render_table(result, ',', ";", super::csv)
}

/// Merged sweep report as JSON: grid metadata plus each point's
/// deterministic report (or its error).
pub fn sweep_json(result: &SweepResult) -> Json {
    let points = result
        .points
        .iter()
        .map(|r| {
            let assigns = r
                .point
                .assigns
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect();
            let mut fields = vec![
                ("index", Json::Num(r.point.index as f64)),
                ("label", Json::Str(r.point.label.clone())),
                ("assigns", Json::Obj(assigns)),
            ];
            match &r.outcome {
                Ok(rep) => fields.push(("report", rep.to_json_deterministic())),
                Err(e) => {
                    // failed points carry the concrete flags they would
                    // have written, so a single error row in a 10k-grid
                    // is identifiable without re-deriving grid indices
                    let written = r
                        .point
                        .written
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                        .collect();
                    fields.push(("error", Json::Str(e.clone())));
                    fields.push(("written", Json::Obj(written)));
                }
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![
        ("axes", Json::Arr(result.axes.iter().map(|a| Json::Str(a.clone())).collect())),
        ("points", Json::Arr(points)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{MetricsCollector, SimReport};
    use crate::sweep::SweepPoint;

    fn fake_report(tokens: u64) -> SimReport {
        let m = MetricsCollector {
            output_tokens: tokens,
            completed_requests: 3,
            ..Default::default()
        };
        SimReport {
            mode: "test".into(),
            predictor: "oracle".into(),
            sim_duration: 2.0,
            host_duration: 0.5,
            events_processed: 10,
            n_gpus: 2,
            metrics: m,
            stages: Vec::new(),
        }
    }

    fn fake_result() -> SweepResult {
        let ok = PointResult {
            point: SweepPoint {
                index: 0,
                assigns: vec![("capacity-factor".into(), "1.25".into())],
                label: "capacity-factor=1.25".into(),
                written: vec![("capacity-factor".into(), "1.25".into())],
            },
            outcome: Ok(fake_report(400)),
        };
        let err = PointResult {
            point: SweepPoint {
                index: 1,
                assigns: vec![("capacity-factor".into(), "2.0".into())],
                label: "capacity-factor=2.0".into(),
                written: vec![("capacity-factor".into(), "2.0".into())],
            },
            outcome: Err("boom, with a comma (a|b|c)".into()),
        };
        SweepResult { axes: vec!["capacity-factor".into()], points: vec![ok, err] }
    }

    #[test]
    fn tables_are_rectangular_with_errors() {
        let r = fake_result();
        let md = sweep_markdown(&r);
        assert!(md.contains("capacity-factor"));
        assert!(md.contains("error: boom"));
        // pipes in error text and labels are sanitized so every markdown
        // row keeps the same column count
        let pipes = md.lines().next().unwrap().matches('|').count();
        assert!(md.lines().all(|l| l.matches('|').count() == pipes), "{md}");
        let mut piped = fake_result();
        piped.axes.clear();
        piped.points[0].point.label = "tp=2|pd".into();
        let md = sweep_markdown(&piped);
        let pipes = md.lines().next().unwrap().matches('|').count();
        assert!(md.lines().all(|l| l.matches('|').count() == pipes), "{md}");
        assert!(md.contains("tp=2/pd"), "{md}");
        let csv = sweep_csv(&r);
        let cols = csv.lines().next().unwrap().matches(',').count();
        assert!(csv.lines().all(|l| l.matches(',').count() == cols), "{csv}");
        assert!(csv.contains("boom; with a comma"), "commas sanitized: {csv}");
        // header cells are sanitized too (a flag:<name> axis can carry
        // arbitrary characters)
        let mut odd = fake_result();
        odd.axes = vec!["flag:a,b".into()];
        let csv = sweep_csv(&odd);
        let cols = csv.lines().next().unwrap().matches(',').count();
        assert!(csv.lines().all(|l| l.matches(',').count() == cols), "{csv}");
        assert!(csv.starts_with("a;b,"), "header sanitized: {csv}");
    }

    #[test]
    fn json_embeds_deterministic_reports() {
        let j = sweep_json(&fake_result());
        let pts = j.req("points").unwrap().as_arr().unwrap();
        assert_eq!(pts.len(), 2);
        let rep = pts[0].req("report").unwrap();
        assert!(rep.get("host_duration_s").is_none(), "host time excluded");
        assert_eq!(rep.req("tokens_per_sec_per_gpu").unwrap().as_f64().unwrap(), 100.0);
        assert_eq!(
            pts[1].req("error").unwrap().as_str().unwrap(),
            "boom, with a comma (a|b|c)",
            "JSON carries the raw error; only table renderers sanitize"
        );
        assert_eq!(
            pts[1].req("written").unwrap().req("capacity-factor").unwrap().as_str().unwrap(),
            "2.0",
            "error rows carry the flags the point would have written"
        );
        assert!(pts[0].get("written").is_none(), "ok rows embed the report instead");
        assert_eq!(
            pts[0].req("assigns").unwrap().req("capacity-factor").unwrap().as_str().unwrap(),
            "1.25"
        );
    }

    #[test]
    fn explicit_grids_get_a_point_column() {
        let mut r = fake_result();
        r.axes.clear();
        let md = sweep_markdown(&r);
        assert!(md.contains("| point"));
        assert!(md.contains("capacity-factor=1.25"));
    }
}
