//! Table / CSV / CDF renderers used by the benches and examples, plus
//! the merged design-space sweep reports ([`sweep`]) and autotuner
//! search reports ([`search`]).

pub mod search;
pub mod sweep;

use std::fmt::Write as _;

/// Render a markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |out: &mut String, cells: &[String]| {
        out.push('|');
        for (i, c) in cells.iter().enumerate() {
            let _ = write!(out, " {:<w$} |", c, w = widths.get(i).copied().unwrap_or(4));
        }
        out.push('\n');
    };
    fmt_row(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push('|');
    for w in &widths {
        let _ = write!(out, "{:-<w$}|", "", w = w + 2);
    }
    out.push('\n');
    for row in rows {
        fmt_row(&mut out, row);
    }
    out
}

/// Render CSV (no quoting needed for numeric tables).
pub fn csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&headers.join(","));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

/// Summarize a CDF at fixed probe points for terminal display.
pub fn cdf_summary(samples: &[f64], label: &str) -> String {
    use crate::metrics::{frac_below, percentile};
    format!(
        "{label}: p50={:.4} p90={:.4} p99={:.4} | <5%={:.1}% <10%={:.1}% <20%={:.1}%",
        percentile(samples, 50.0),
        percentile(samples, 90.0),
        percentile(samples, 99.0),
        frac_below(samples, 0.05) * 100.0,
        frac_below(samples, 0.10) * 100.0,
        frac_below(samples, 0.20) * 100.0,
    )
}

/// ASCII CDF plot (x = value, y = cumulative fraction), for terminal
/// inspection of Fig. 2-style results.
pub fn ascii_cdf(series: &[(&str, Vec<f64>)], width: usize, height: usize, x_max: f64) -> String {
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x'];
    for (si, (_, xs)) in series.iter().enumerate() {
        if xs.is_empty() {
            continue;
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        for col in 0..width {
            let x = x_max * (col as f64 + 0.5) / width as f64;
            let frac = sorted.iter().take_while(|&&v| v <= x).count() as f64 / n as f64;
            let row = ((1.0 - frac) * (height as f64 - 1.0)).round() as usize;
            grid[row.min(height - 1)][col] = marks[si % marks.len()];
        }
    }
    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let y = 1.0 - i as f64 / (height as f64 - 1.0);
        let _ = writeln!(out, "{y:4.2} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "     +{}", "-".repeat(width));
    let _ = writeln!(out, "      0{:>w$.2}", x_max, w = width - 1);
    for (si, (name, _)) in series.iter().enumerate() {
        let _ = writeln!(out, "      {} = {name}", marks[si % marks.len()]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_alignment() {
        let t = markdown_table(
            &["a", "metric"],
            &[vec!["1".into(), "x".into()], vec!["22".into(), "yy".into()]],
        );
        assert!(t.contains("| a  | metric |"));
        assert!(t.lines().count() == 4);
    }

    #[test]
    fn csv_shape() {
        let c = csv(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(c, "x,y\n1,2\n");
    }

    #[test]
    fn ascii_cdf_renders() {
        let s = ascii_cdf(&[("err", vec![0.1, 0.2, 0.3])], 20, 5, 0.5);
        assert!(s.contains('*'));
        assert!(s.contains("err"));
    }

    #[test]
    fn cdf_summary_contains_percentiles() {
        let s = cdf_summary(&[0.01, 0.02, 0.5], "x");
        assert!(s.contains("p50"));
        assert!(s.contains("<10%"));
    }
}
