//! Transformer model descriptors (dense and MoE) and presets.

/// Mixture-of-Experts configuration for a model's FFN layers.
#[derive(Clone, Debug, PartialEq)]
pub struct MoeConfig {
    /// Total number of routed experts.
    pub n_experts: u32,
    /// Experts activated per token.
    pub top_k: u32,
    /// Hidden dim of each expert FFN.
    pub expert_ffn_dim: u32,
    /// Shared-expert hidden dim (0 = none).
    pub shared_expert_dim: u32,
}

/// Architecture hyperparameters of a served model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub n_layers: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_kv_heads: u32,
    pub head_dim: u32,
    /// Dense FFN hidden dim (gate/up + down, SwiGLU-style).
    pub ffn_dim: u32,
    pub vocab_size: u32,
    /// bf16 by default.
    pub dtype_bytes: u32,
    pub moe: Option<MoeConfig>,
}

impl ModelConfig {
    /// Qwen2-7B-Instruct — the paper's end-to-end evaluation model.
    pub fn qwen2_7b() -> Self {
        ModelConfig {
            name: "Qwen2-7B-Instruct".into(),
            n_layers: 28,
            d_model: 3584,
            n_heads: 28,
            n_kv_heads: 4,
            head_dim: 128,
            ffn_dim: 18944,
            vocab_size: 152064,
            dtype_bytes: 2,
            moe: None,
        }
    }

    /// Qwen2-72B — the dense 72B configuration cited in the paper's intro.
    pub fn qwen2_72b() -> Self {
        ModelConfig {
            name: "Qwen2-72B".into(),
            n_layers: 80,
            d_model: 8192,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_dim: 29568,
            vocab_size: 152064,
            dtype_bytes: 2,
            moe: None,
        }
    }

    /// Mixtral-8x7B — the canonical open MoE.
    pub fn mixtral_8x7b() -> Self {
        ModelConfig {
            name: "Mixtral-8x7B".into(),
            n_layers: 32,
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            head_dim: 128,
            ffn_dim: 14336,
            vocab_size: 32000,
            dtype_bytes: 2,
            moe: Some(MoeConfig {
                n_experts: 8,
                top_k: 2,
                expert_ffn_dim: 14336,
                shared_expert_dim: 0,
            }),
        }
    }

    /// A DeepSeek-V3-flavoured fine-grained MoE (reduced layer count so
    /// laptop-scale simulations stay fast; dims per layer are faithful).
    pub fn deepseek_v3_lite() -> Self {
        ModelConfig {
            name: "DeepSeek-V3-lite".into(),
            n_layers: 16,
            d_model: 7168,
            n_heads: 128,
            n_kv_heads: 128,
            head_dim: 64,
            ffn_dim: 18432,
            vocab_size: 129024,
            dtype_bytes: 2,
            moe: Some(MoeConfig {
                n_experts: 64,
                top_k: 8,
                expert_ffn_dim: 2048,
                shared_expert_dim: 2048,
            }),
        }
    }

    /// A small dense model for fast tests.
    pub fn tiny() -> Self {
        ModelConfig {
            name: "tiny-1B".into(),
            n_layers: 8,
            d_model: 1024,
            n_heads: 16,
            n_kv_heads: 16,
            head_dim: 64,
            ffn_dim: 4096,
            vocab_size: 32000,
            dtype_bytes: 2,
            moe: None,
        }
    }

    /// A tiny MoE for fast tests.
    pub fn tiny_moe() -> Self {
        ModelConfig {
            name: "tiny-moe".into(),
            moe: Some(MoeConfig {
                n_experts: 8,
                top_k: 2,
                expert_ffn_dim: 2048,
                shared_expert_dim: 0,
            }),
            ..Self::tiny()
        }
    }

    pub fn is_moe(&self) -> bool {
        self.moe.is_some()
    }

    /// KV-cache bytes per token (all layers, both K and V).
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.n_layers as u64
            * self.n_kv_heads as u64
            * self.head_dim as u64
            * self.dtype_bytes as u64
    }

    /// Total parameter count (weights only, no embeddings tying).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let attn = d * (self.n_heads as u64 * self.head_dim as u64) * 2
            + d * (self.n_kv_heads as u64 * self.head_dim as u64) * 2;
        let ffn = match &self.moe {
            None => 3 * d * self.ffn_dim as u64,
            Some(m) => {
                let routed = m.n_experts as u64 * 3 * d * m.expert_ffn_dim as u64;
                let shared = 3 * d * m.shared_expert_dim as u64;
                let router = d * m.n_experts as u64;
                routed + shared + router
            }
        };
        self.n_layers as u64 * (attn + ffn) + 2 * d * self.vocab_size as u64
    }

    /// Weight bytes resident per GPU given tensor/expert sharding.
    pub fn weight_bytes_per_gpu(&self, tp: u32, ep: u32) -> u64 {
        let shard = tp.max(1) as u64 * ep.max(1) as u64;
        self.param_count() * self.dtype_bytes as u64 / shard
    }

    /// Weight bytes of ONE routed expert in ONE layer as resident on an
    /// EP rank (gate + up + down projections, so `3 * d_model *
    /// expert_ffn_dim / tp` parameters at `dtype_bytes` each). The
    /// simulator keeps a single expert placement shared by every layer,
    /// so callers charging a placement change (migration) must scale by
    /// the stage's resident layer count. 0 for dense models.
    pub fn expert_weight_bytes(&self, tp: u32) -> f64 {
        match &self.moe {
            None => 0.0,
            Some(m) => {
                let ffn = (m.expert_ffn_dim / tp.max(1)).max(1) as f64;
                3.0 * self.d_model as f64 * ffn * self.dtype_bytes as f64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen2_7b_architecture() {
        let m = ModelConfig::qwen2_7b();
        assert_eq!(m.n_layers, 28);
        assert_eq!(m.d_model, 3584);
        assert_eq!(m.n_heads, 28);
        assert_eq!(m.n_kv_heads, 4);
        // ~7.6B params
        let p = m.param_count();
        assert!(p > 6_000_000_000 && p < 9_000_000_000, "{p}");
    }

    #[test]
    fn kv_bytes_per_token_qwen() {
        let m = ModelConfig::qwen2_7b();
        // 2 * 28 layers * 4 kv heads * 128 dim * 2 bytes = 57344
        assert_eq!(m.kv_bytes_per_token(), 57344);
    }

    #[test]
    fn mixtral_is_moe() {
        let m = ModelConfig::mixtral_8x7b();
        assert!(m.is_moe());
        // ~46B params
        let p = m.param_count();
        assert!(p > 40_000_000_000 && p < 52_000_000_000, "{p}");
    }

    #[test]
    fn expert_weight_bytes_scale() {
        let m = ModelConfig::tiny_moe();
        // 3 projections * d_model * expert_ffn_dim * bf16
        assert_eq!(m.expert_weight_bytes(1), 3.0 * 1024.0 * 2048.0 * 2.0);
        assert_eq!(m.expert_weight_bytes(2), m.expert_weight_bytes(1) / 2.0);
        assert_eq!(ModelConfig::tiny().expert_weight_bytes(1), 0.0);
    }

    #[test]
    fn weight_sharding_divides() {
        let m = ModelConfig::qwen2_7b();
        assert_eq!(m.weight_bytes_per_gpu(2, 1) * 2, m.weight_bytes_per_gpu(1, 1));
    }
}
