//! Parallel design-space sweep engine.
//!
//! Frontier exists to *search* the deployment design space — PD ratios,
//! capacity factors, EP cluster spans, migration thresholds — and with
//! the per-draw hot path allocation-free, the bottleneck moved to the
//! sweeps themselves, which all ran their configurations serially. This
//! module turns a sweep into data plus a runner:
//!
//! * [`Axis`] — one named knob and its value list (`pd-ratio`, any
//!   value-taking CLI flag, or `flag:<name>` to bypass validation);
//! * [`SweepSpec`] — base flags + a [`Grid`] (cartesian axes or an
//!   explicit point list) + an optional programmatic post-hook;
//! * [`SweepRunner`] — fans the grid across scoped worker threads and
//!   collects per-point reports **by grid index**, so the merged output
//!   is byte-identical regardless of thread count (each point's config
//!   carries its own seed, and the learned predictor's memo caches are
//!   thread-local).
//!
//! Merged CSV / markdown / JSON rendering lives in
//! [`crate::report::sweep`]; the `frontier sweep` subcommand, `frontier
//! sweep-pd`, and the `ep_routing` / `capacity_search` examples are thin
//! front-ends over this engine.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use crate::config::cli::{build_config, is_value_flag, FlagMap, DRIVER_FLAGS};
use crate::config::ExperimentConfig;
use crate::metrics::SimReport;

/// One sweep axis: a named knob and the values it takes, in order.
#[derive(Clone, Debug, PartialEq)]
pub struct Axis {
    /// Axis name: `pd-ratio` (composite — one `P:D` value sets the
    /// whole deployment shape), any value-taking CLI flag
    /// (`capacity-factor`, `ep-clusters`, `migration-threshold`,
    /// `seed`, ...), or `flag:<name>` to set an arbitrary flag without
    /// registry validation.
    pub name: String,
    /// The values this axis sweeps, in grid order.
    pub values: Vec<String>,
}

impl Axis {
    /// Build an axis, validating the name against the flag registry and
    /// rejecting empty value lists / empty values.
    pub fn new(name: impl Into<String>, values: Vec<String>) -> Result<Axis> {
        let name = name.into();
        validate_axis_name(&name)?;
        if values.is_empty() {
            bail!("axis {name}: needs at least one value");
        }
        if values.iter().any(String::is_empty) {
            bail!("axis {name}: empty value");
        }
        Ok(Axis { name, values })
    }

    /// Parse the CLI grammar `name=v1,v2,...`.
    pub fn parse(spec: &str) -> Result<Axis> {
        let (name, vals) = spec
            .split_once('=')
            .ok_or_else(|| anyhow!("bad axis {spec:?}: expected name=v1,v2,..."))?;
        comma_grammar_guard(name)?;
        Axis::new(name, vals.split(',').map(str::to_string).collect())
    }
}

/// The comma-split CLI grammars ([`Axis::parse`], [`PointSpec::parse`])
/// would mangle a value that itself contains commas, even behind the
/// `flag:` escape — reject those names up front. [`Axis::new`] /
/// [`PointSpec::new`] take values as a list and stay exempt (via
/// `flag:<name>`).
fn comma_grammar_guard(name: &str) -> Result<()> {
    let bare = name.strip_prefix("flag:").unwrap_or(name);
    if COMMA_VALUED_FLAGS.contains(&bare) {
        bail!(
            "axis {name:?}: {bare} values contain commas, which the comma-split CLI \
             grammar cannot express — build the sweep programmatically with Axis::new \
             (values arrive as a list) behind a flag:{bare} axis"
        );
    }
    Ok(())
}

/// Flags whose values legitimately contain commas (the stage DSL, edge
/// lists) — the `v1,v2,...` axis grammar cannot carry them, so they are
/// rejected as bare axis names instead of silently splitting into a
/// wrong grid. Programmatic sweeps can still vary them through
/// `flag:<name>` axes built with [`Axis::new`], where values arrive as
/// a `Vec` and are never comma-split.
const COMMA_VALUED_FLAGS: &[&str] = &["stages", "stages-json", "edges"];

fn validate_axis_name(name: &str) -> Result<()> {
    // bare comma-valued names are rejected everywhere (the registry
    // check below would otherwise accept them); the single error
    // message lives in comma_grammar_guard, which the comma-split
    // grammars additionally run against flag:-prefixed forms
    if COMMA_VALUED_FLAGS.contains(&name) {
        return comma_grammar_guard(name);
    }
    if name == "pd-ratio" || is_value_flag(name) {
        return Ok(());
    }
    if let Some(f) = name.strip_prefix("flag:") {
        if f.is_empty() {
            bail!("axis flag:<name> needs a flag name");
        }
        if DRIVER_FLAGS.contains(&f) {
            bail!(
                "axis {name:?}: --{f} is a driver-level flag the config lowering never \
                 reads — sweeping it would be silently ignored"
            );
        }
        return Ok(());
    }
    bail!(
        "unknown axis {name:?}: use pd-ratio, a value-taking CLI flag \
         (capacity-factor, ep-clusters, migration-threshold, seed, ...), \
         or flag:<name> to bypass validation"
    )
}

/// The flags an axis name touches: `pd-ratio` writes the deployment
/// shape AND clears the stage-graph overrides, `flag:<name>` strips its
/// prefix, everything else maps to itself. The duplicate-axis guard
/// compares these targets, so aliased axes (`seed` vs `flag:seed`,
/// `prefill` vs `pd-ratio`, a programmatic `flag:stages` vs `pd-ratio`)
/// cannot silently shadow or wipe each other.
fn axis_targets(name: &str) -> Vec<&str> {
    if name == "pd-ratio" {
        vec!["mode", "prefill", "decode", "stages", "stages-json", "edges"]
    } else {
        vec![name.strip_prefix("flag:").unwrap_or(name)]
    }
}

/// Apply one `axis = value` assignment to a flag map. `pd-ratio` is the
/// composite axis: a `P:D` value takes over the deployment shape
/// (clearing any `--stages` override, exactly as the old `sweep-pd`
/// loop did); everything else sets the flag of the same name.
fn apply_assignment(name: &str, value: &str, flags: &mut FlagMap) -> Result<()> {
    if let Some(f) = name.strip_prefix("flag:") {
        validate_axis_name(name)?;
        flags.set(f, value);
        return Ok(());
    }
    if name == "pd-ratio" {
        let (p, d) = value
            .split_once(':')
            .ok_or_else(|| anyhow!("bad pd-ratio {value:?}: expected P:D"))?;
        let p: u32 = p.parse().map_err(|_| anyhow!("bad pd-ratio prefill count {p:?}"))?;
        let d: u32 = d.parse().map_err(|_| anyhow!("bad pd-ratio decode count {d:?}"))?;
        if p == 0 || d == 0 {
            bail!("pd-ratio {value:?}: both sides must be >= 1");
        }
        // the axis owns the deployment shape
        for k in ["stages", "stages-json", "edges"] {
            flags.remove(k);
        }
        flags.set("mode", "pd");
        flags.set("prefill", p.to_string());
        flags.set("decode", d.to_string());
        return Ok(());
    }
    validate_axis_name(name)?;
    flags.set(name, value);
    Ok(())
}

/// One explicit grid point: axis-style assignments (same key grammar as
/// [`Axis`] names) plus an optional display label.
#[derive(Clone, Debug, PartialEq)]
pub struct PointSpec {
    /// Display label; defaults to `k=v k2=v2 ...` when absent.
    pub label: Option<String>,
    /// `(axis name, value)` assignments, applied in order.
    pub assigns: Vec<(String, String)>,
}

impl PointSpec {
    /// A point from raw assignments (label auto-derived).
    pub fn new(assigns: Vec<(String, String)>) -> PointSpec {
        PointSpec { label: None, assigns }
    }

    /// Parse the CLI grammar `k=v[,k2=v2...]`. Keys get the same
    /// up-front typo validation as [`Axis`] names.
    pub fn parse(spec: &str) -> Result<PointSpec> {
        let assigns = spec
            .split(',')
            .map(|kv| {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("bad point assignment {kv:?}: expected key=value"))?;
                if k.is_empty() || v.is_empty() {
                    bail!("bad point assignment {kv:?}: empty key or value");
                }
                comma_grammar_guard(k)?;
                validate_axis_name(k)?;
                Ok((k.to_string(), v.to_string()))
            })
            .collect::<Result<Vec<_>>>()?;
        if assigns.is_empty() {
            bail!("empty point spec");
        }
        Ok(PointSpec::new(assigns))
    }

    /// Attach a display label.
    pub fn with_label(mut self, label: impl Into<String>) -> PointSpec {
        self.label = Some(label.into());
        self
    }
}

/// The sweep grid: a cartesian product of axes, or an explicit list of
/// points (for derived spaces a product cannot express, e.g. replica
/// counts computed from the tp degree).
#[derive(Clone, Debug)]
pub enum Grid {
    /// Cartesian product; the first axis varies slowest.
    Cartesian(Vec<Axis>),
    /// Explicit point list, run in the given order.
    Explicit(Vec<PointSpec>),
}

/// Programmatic hook applied to every materialized config after flag
/// lowering — for knobs the flag layer cannot express (e.g. a custom
/// workload length distribution). Must be thread-safe: the runner calls
/// it from its workers.
pub type PostHook = Box<dyn Fn(&mut ExperimentConfig) + Send + Sync>;

/// A full sweep: base flags, the grid, and an optional post-hook.
pub struct SweepSpec {
    /// Flags shared by every grid point (the `frontier sweep` command
    /// line minus the driver-control flags).
    pub base: FlagMap,
    /// The grid to materialize.
    pub grid: Grid,
    /// Applied to each point's built config before the run.
    pub post: Option<PostHook>,
}

/// One materialized grid point. `index` is the deterministic grid
/// position results are collected by.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepPoint {
    /// Position in grid order (cartesian row-major / explicit list
    /// order).
    pub index: usize,
    /// `(axis name, value)` assignments of this point.
    pub assigns: Vec<(String, String)>,
    /// Display label (`k=v k2=v2 ...` unless overridden).
    pub label: String,
    /// The concrete flags the assignments write, sorted by flag name —
    /// composite axes expanded (`pd-ratio=1:3` becomes `mode=pd
    /// prefill=1 decode=3`), `flag:` prefixes stripped. Error rows and
    /// search manifests carry this so a failed point in a 10k-grid is
    /// identifiable without re-deriving grid indices. Falls back to the
    /// raw assignments when one of them cannot be applied (the error
    /// itself surfaces at lowering time).
    pub written: Vec<(String, String)>,
}

/// The flags a point's assignments actually write (see
/// [`SweepPoint::written`]).
fn written_flags(assigns: &[(String, String)]) -> Vec<(String, String)> {
    let mut flags = FlagMap::new();
    for (name, value) in assigns {
        if apply_assignment(name, value, &mut flags).is_err() {
            return assigns.to_vec();
        }
    }
    flags
        .keys()
        .map(|k| (k.to_string(), flags.get(k).unwrap_or_default().to_string()))
        .collect()
}

impl SweepSpec {
    /// A sweep over `base` with an empty cartesian grid; add axes or
    /// points with [`SweepSpec::with_axes`] / [`SweepSpec::with_points`].
    pub fn new(base: FlagMap) -> SweepSpec {
        SweepSpec { base, grid: Grid::Cartesian(Vec::new()), post: None }
    }

    /// Use a cartesian grid over `axes`.
    pub fn with_axes(mut self, axes: Vec<Axis>) -> SweepSpec {
        self.grid = Grid::Cartesian(axes);
        self
    }

    /// Use an explicit point list.
    pub fn with_points(mut self, points: Vec<PointSpec>) -> SweepSpec {
        self.grid = Grid::Explicit(points);
        self
    }

    /// Install a programmatic post-hook (see [`PostHook`]).
    pub fn with_post(mut self, post: PostHook) -> SweepSpec {
        self.post = Some(post);
        self
    }

    /// Axis names of a cartesian grid (table headers); empty for
    /// explicit point lists.
    pub fn axis_names(&self) -> Vec<String> {
        match &self.grid {
            Grid::Cartesian(axes) => axes.iter().map(|a| a.name.clone()).collect(),
            Grid::Explicit(_) => Vec::new(),
        }
    }

    /// Materialize the grid in deterministic order: cartesian products
    /// are row-major (first axis slowest, last fastest), explicit lists
    /// keep their order.
    pub fn points(&self) -> Result<Vec<SweepPoint>> {
        match &self.grid {
            Grid::Cartesian(axes) => {
                if axes.is_empty() {
                    bail!("empty sweep: add at least one axis or point");
                }
                let mut seen = std::collections::BTreeSet::new();
                for ax in axes {
                    for target in axis_targets(&ax.name) {
                        if !seen.insert(target) {
                            bail!(
                                "axis {:?} writes flag --{target}, which an earlier axis \
                                 already sweeps: later assignments would silently shadow it",
                                ax.name
                            );
                        }
                    }
                }
                let total: usize = axes.iter().map(|a| a.values.len()).product();
                if total == 0 {
                    // only reachable by hand-building an Axis with an
                    // empty values list (the fields are pub); running
                    // nothing must not look like success
                    bail!("empty sweep: an axis has no values");
                }
                let mut pts = Vec::with_capacity(total);
                for index in 0..total {
                    let mut rem = index;
                    let mut assigns = Vec::with_capacity(axes.len());
                    for ax in axes.iter().rev() {
                        assigns.push((ax.name.clone(), ax.values[rem % ax.values.len()].clone()));
                        rem /= ax.values.len();
                    }
                    assigns.reverse();
                    let label = join_assigns(&assigns);
                    let written = written_flags(&assigns);
                    pts.push(SweepPoint { index, assigns, label, written });
                }
                Ok(pts)
            }
            Grid::Explicit(points) => {
                if points.is_empty() {
                    bail!("empty sweep: add at least one axis or point");
                }
                for p in points {
                    let mut seen = std::collections::BTreeSet::new();
                    for (k, _) in &p.assigns {
                        for target in axis_targets(k) {
                            if !seen.insert(target) {
                                bail!(
                                    "point {:?}: key {k:?} writes flag --{target}, which \
                                     an earlier key already set — it would silently \
                                     shadow that assignment",
                                    p.label.as_deref().unwrap_or(&join_assigns(&p.assigns))
                                );
                            }
                        }
                    }
                }
                Ok(points
                    .iter()
                    .enumerate()
                    .map(|(index, p)| SweepPoint {
                        index,
                        assigns: p.assigns.clone(),
                        label: p.label.clone().unwrap_or_else(|| join_assigns(&p.assigns)),
                        written: written_flags(&p.assigns),
                    })
                    .collect())
            }
        }
    }

    /// Lower one grid point onto a runnable config: base flags + the
    /// point's assignments through [`build_config`], then the post-hook.
    /// This is exactly the `frontier simulate` lowering, which is why a
    /// one-point sweep bit-reproduces a plain run (`rust/tests/sweep.rs`).
    pub fn point_config(&self, point: &SweepPoint) -> Result<ExperimentConfig> {
        let mut flags = self.base.clone();
        for (name, value) in &point.assigns {
            apply_assignment(name, value, &mut flags)?;
        }
        let mut cfg = build_config(&flags)?;
        if let Some(post) = &self.post {
            post(&mut cfg);
        }
        Ok(cfg)
    }

    /// Like [`SweepSpec::point_config`], but with the workload size
    /// forced to `requests` before the point's assignments apply — the
    /// search engine lowers every rung of its successive-halving ladder
    /// through this (the driver rejects `requests` axes up front, so an
    /// assignment can never shadow the horizon back).
    pub fn point_config_at_horizon(
        &self,
        point: &SweepPoint,
        requests: u32,
    ) -> Result<ExperimentConfig> {
        let mut flags = self.base.clone();
        flags.set("requests", requests.to_string());
        for (name, value) in &point.assigns {
            apply_assignment(name, value, &mut flags)?;
        }
        let mut cfg = build_config(&flags)?;
        if let Some(post) = &self.post {
            post(&mut cfg);
        }
        Ok(cfg)
    }
}

/// Fan `n` index-addressed jobs across `threads` scoped workers and
/// collect the results **by index**: workers pull the next unclaimed
/// index from a shared counter and write into that index's slot, so the
/// output order is deterministic for any thread count. This is the one
/// fan-out primitive behind both [`SweepRunner`] and the search
/// engine's rung scheduling ([`crate::search`]).
pub(crate) fn fan_out<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every fan-out slot is filled"))
        .collect()
}

/// Debug repr of a config with fields the runtime never reads
/// normalized away, so two configs that *run identically* compare (and
/// hash, see [`config_hash`]) identically:
///
/// * an explicit stage graph makes the legacy `mode` (and with it
///   `--replicas`/`--prefill`/`--decode`) dead, yet those flags still
///   land in the struct;
/// * the parallel engine is bit-identical for any thread count, so
///   `sim_threads` never changes what a point computes;
/// * with migration off, the migration threshold and load window are
///   never read (the load estimator is only attached when migration is
///   on — pinned by `rust/tests/migration.rs`).
///
/// Normalization must be *semantics-preserving for errors too*: a knob
/// is only folded onto its default when the given value passes the same
/// `validate()` checks as the default, so a config that would fail
/// validation keeps a distinct repr and still fails instead of silently
/// reusing a valid twin's report. (This is why `capacity_factor` is
/// never folded for dense models: `validate()` range-checks it
/// regardless of the model.)
///
/// Both the no-op-sweep guard and the search engine's config-hash dedup
/// compare this repr.
pub fn comparable_repr(cfg: &ExperimentConfig) -> String {
    let mut c = cfg.clone();
    if c.stages.is_some() {
        c.mode = crate::config::DeploymentMode::Colocated { replicas: 0 };
    }
    c.sim_threads = 1;
    if c.policy.migration == crate::moe::MigrationPolicy::Off {
        let default = crate::config::PolicyConfig::default();
        // fold only values validate() accepts (finite, >= 1 / nonzero):
        // out-of-range values must keep erroring, not alias a valid run
        if c.policy.migration_threshold.is_finite() && c.policy.migration_threshold >= 1.0 {
            c.policy.migration_threshold = default.migration_threshold;
        }
        if c.policy.load_window >= 1 {
            c.policy.load_window = default.load_window;
        }
    }
    format!("{c:?}")
}

/// FNV-1a (64-bit) over [`comparable_repr`]: configs that run
/// identically hash identically, so the search engine can share one
/// simulation (and one manifest slot) between grid points that differ
/// only in inert flags.
pub fn config_hash(cfg: &ExperimentConfig) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in comparable_repr(cfg).as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn join_assigns(assigns: &[(String, String)]) -> String {
    assigns
        .iter()
        .map(|(k, v)| format!("{k}={v}"))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Result of one grid point: the report, or the error that stopped it
/// (an impossible flag combination, say) — one bad point never aborts
/// the rest of the sweep.
#[derive(Clone, Debug)]
pub struct PointResult {
    /// The grid point this result belongs to.
    pub point: SweepPoint,
    /// The run's report, or the config/run error rendered as text.
    pub outcome: Result<SimReport, String>,
}

/// A completed sweep: points in grid order, regardless of how many
/// threads ran them.
#[derive(Debug)]
pub struct SweepResult {
    /// Axis names of the cartesian grid (empty for explicit lists).
    pub axes: Vec<String>,
    /// Per-point results, ordered by [`SweepPoint::index`].
    pub points: Vec<PointResult>,
}

/// Fans grid points across scoped worker threads. Workers pull the next
/// unclaimed grid index from a shared counter and write the result into
/// that index's slot, so the collected output is ordered by grid index
/// and byte-identical for any thread count.
#[derive(Clone, Copy, Debug, Default)]
pub struct SweepRunner {
    /// Worker threads; `0` (the default) means one per available core.
    pub threads: usize,
}

impl SweepRunner {
    /// A runner with an explicit thread count (`0` = all cores).
    pub fn with_threads(threads: usize) -> SweepRunner {
        SweepRunner { threads }
    }

    fn resolved_threads(&self, points: usize) -> usize {
        let t = match self.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        t.clamp(1, points.max(1))
    }

    /// Run every grid point and collect the reports in grid order.
    /// Deterministic by construction: each point's config carries its
    /// own seed, `run_experiment` shares no mutable state across runs
    /// (the learned predictor's memo caches are thread-local), and
    /// results land in per-index slots.
    pub fn run(&self, spec: &SweepSpec) -> Result<SweepResult> {
        let points = spec.points()?;
        // a grid where EVERY point lowers to the same config is a
        // silent no-op — e.g. a `replicas` axis under a `--stages` base
        // that never reads it, or a `prefill` axis under colocated
        // mode. Lowering is cheap (flag parsing, no simulation), so
        // check before burning the grid; any per-point lowering error
        // skips the check and surfaces normally as an error row.
        if points.len() > 1 {
            let lowered: Vec<_> = points.iter().map(|p| spec.point_config(p)).collect();
            if lowered.iter().all(|c| c.is_ok()) {
                let mut reprs =
                    lowered.iter().map(|c| comparable_repr(c.as_ref().unwrap()));
                let first = reprs.next().unwrap();
                if reprs.all(|r| r == first) {
                    bail!(
                        "sweep is a no-op: every grid point lowers to an identical \
                         config — the swept flags are not read under this base \
                         (e.g. a deployment-shape axis under a --stages override)"
                    );
                }
            }
        }
        let threads = self.resolved_threads(points.len());
        let run_point = |p: &SweepPoint| -> PointResult {
            let outcome = spec
                .point_config(p)
                .map(|mut cfg| {
                    // point-level parallelism already saturates the
                    // cores: don't stack the intra-run engine threads on
                    // top (results are bit-identical either way)
                    if threads > 1 {
                        cfg.sim_threads = 1;
                    }
                    cfg
                })
                .and_then(|cfg| crate::run_experiment(&cfg))
                .map_err(|e| format!("{e:#}"));
            PointResult { point: p.clone(), outcome }
        };
        let results: Vec<PointResult> = fan_out(threads, points.len(), |i| run_point(&points[i]));
        Ok(SweepResult { axes: spec.axis_names(), points: results })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axis_parse_and_validation() {
        let a = Axis::parse("capacity-factor=1.0,1.25").unwrap();
        assert_eq!(a.name, "capacity-factor");
        assert_eq!(a.values, ["1.0".to_string(), "1.25".to_string()]);
        assert!(Axis::parse("pd-ratio=1:1,2:2").is_ok());
        assert!(Axis::parse("flag:whatever=1").is_ok(), "flag: bypasses the registry");
        assert!(Axis::parse("not-a-flag=1").is_err());
        assert!(Axis::parse("no-equals").is_err());
        assert!(Axis::parse("seed=").is_err(), "empty value");
        assert!(Axis::parse("flag:=1").is_err(), "flag: needs a name");
        // driver-level flags are ignored by the config lowering, so the
        // escape hatch must not sweep them either
        assert!(Axis::parse("flag:trace=a.json").is_err());
        assert!(Axis::new("flag:threads", vec!["2".into()]).is_err());
        assert!(Axis::new("seed", Vec::new()).is_err(), "empty value list");
        // comma-valued flags cannot ride the comma-split grammar, even
        // behind the flag: escape — only the list-valued API may carry
        // them
        assert!(Axis::parse("stages=prefill:2,tp=2").is_err());
        assert!(Axis::parse("flag:stages=prefill:2,tp=2").is_err());
        assert!(Axis::new("edges", vec!["0>1".into()]).is_err());
        assert!(Axis::new("flag:stages", vec!["prefill:2,tp=2".into()]).is_ok());
    }

    #[test]
    fn pd_ratio_assignment_takes_the_shape() {
        let mut flags = FlagMap::new();
        flags.set("stages", "prefill:1;decode:1");
        flags.set("edges", "0>1");
        apply_assignment("pd-ratio", "3:5", &mut flags).unwrap();
        assert!(!flags.has("stages") && !flags.has("edges"));
        assert_eq!(flags.get("mode"), Some("pd"));
        assert_eq!(flags.get("prefill"), Some("3"));
        assert_eq!(flags.get("decode"), Some("5"));
        assert!(apply_assignment("pd-ratio", "3", &mut flags).is_err());
        assert!(apply_assignment("pd-ratio", "0:4", &mut flags).is_err());
        assert!(apply_assignment("pd-ratio", "x:4", &mut flags).is_err());
    }

    #[test]
    fn cartesian_points_are_row_major() {
        let spec = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("seed", vec!["1".into(), "2".into()]).unwrap(),
            Axis::new("requests", vec!["8".into(), "16".into(), "32".into()]).unwrap(),
        ]);
        let pts = spec.points().unwrap();
        let labels: Vec<&str> = pts.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(
            labels,
            [
                "seed=1 requests=8",
                "seed=1 requests=16",
                "seed=1 requests=32",
                "seed=2 requests=8",
                "seed=2 requests=16",
                "seed=2 requests=32",
            ]
        );
        assert!(pts.iter().enumerate().all(|(i, p)| p.index == i));
        assert_eq!(spec.axis_names(), ["seed".to_string(), "requests".to_string()]);
    }

    #[test]
    fn empty_grids_are_rejected() {
        assert!(SweepSpec::new(FlagMap::new()).points().is_err());
        assert!(SweepSpec::new(FlagMap::new()).with_points(Vec::new()).points().is_err());
    }

    #[test]
    fn shadowing_grids_are_rejected() {
        // a duplicated axis would silently shadow its earlier twin
        let dup = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("seed", vec!["1".into(), "2".into()]).unwrap(),
            Axis::new("seed", vec!["3".into(), "4".into()]).unwrap(),
        ]);
        assert!(dup.points().is_err());
        // aliases shadow through their written flags: flag:seed == seed,
        // pd-ratio writes mode/prefill/decode
        let dup = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("seed", vec!["1".into()]).unwrap(),
            Axis::new("flag:seed", vec!["9".into()]).unwrap(),
        ]);
        assert!(dup.points().is_err());
        let dup = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("prefill", vec!["2".into()]).unwrap(),
            Axis::new("pd-ratio", vec!["1:7".into()]).unwrap(),
        ]);
        assert!(dup.points().is_err());
        // pd-ratio also CLEARS the stage-graph flags, so a programmatic
        // flag:stages axis composed with it would be silently wiped
        let dup = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("flag:stages", vec!["prefill:1;decode:1".into()]).unwrap(),
            Axis::new("pd-ratio", vec!["1:7".into()]).unwrap(),
        ]);
        assert!(dup.points().is_err());
        // same for duplicate keys inside one explicit point
        let dup = SweepSpec::new(FlagMap::new()).with_points(vec![PointSpec::new(vec![
            ("seed".into(), "1".into()),
            ("seed".into(), "2".into()),
        ])]);
        assert!(dup.points().is_err());
        // distinct flags still compose
        let ok = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("seed", vec!["1".into()]).unwrap(),
            Axis::new("pd-ratio", vec!["1:7".into()]).unwrap(),
        ]);
        assert!(ok.points().is_ok());
    }

    #[test]
    fn point_spec_parse_and_labels() {
        let p = PointSpec::parse("seed=3,max-batch=8").unwrap();
        assert_eq!(
            p.assigns,
            [("seed".to_string(), "3".to_string()), ("max-batch".to_string(), "8".to_string())]
        );
        assert!(PointSpec::parse("seed").is_err());
        assert!(PointSpec::parse("=3").is_err());
        assert!(PointSpec::parse("not-a-flag=3").is_err(), "point keys get axis validation");
        assert!(PointSpec::parse("flag:not-a-flag=3").is_ok());
        assert!(PointSpec::parse("pd-ratio=1:3").is_ok());
        assert!(PointSpec::parse("flag:stages=x").is_err(), "comma-valued even behind flag:");
        let spec = SweepSpec::new(FlagMap::new())
            .with_points(vec![p.with_label("small"), PointSpec::parse("seed=4").unwrap()]);
        let pts = spec.points().unwrap();
        assert_eq!(pts[0].label, "small");
        assert_eq!(pts[1].label, "seed=4");
        assert!(spec.axis_names().is_empty());
    }

    #[test]
    fn no_op_sweeps_are_rejected() {
        // a --stages base makes the legacy shape flags dead: a replicas
        // axis under it lowers every point to the same running config
        let mut base = FlagMap::new();
        base.set("model", "tiny");
        base.set("stages", "prefill:1;decode:1");
        base.set("requests", "8");
        let spec = SweepSpec::new(base.clone())
            .with_axes(vec![Axis::new("replicas", vec!["1".into(), "2".into()]).unwrap()]);
        assert!(SweepRunner::with_threads(1).run(&spec).is_err());
        // a live axis under the same base still runs
        let spec = SweepSpec::new(base)
            .with_axes(vec![Axis::new("seed", vec!["1".into(), "2".into()]).unwrap()]);
        assert!(SweepRunner::with_threads(1).run(&spec).is_ok());
    }

    #[test]
    fn config_hash_folds_inert_knobs_only() {
        let mut base = FlagMap::new();
        base.set("model", "tiny-moe");
        base.set("replicas", "1");
        base.set("ep", "2");
        let cfg = |extra: &[(&str, &str)]| {
            let mut f = base.clone();
            for (k, v) in extra {
                f.set(k, *v);
            }
            build_config(&f).unwrap()
        };
        // with migration off, the threshold/window knobs are never read
        let a = cfg(&[("migration-threshold", "1.1")]);
        let b = cfg(&[("migration-threshold", "1.4"), ("load-window", "32")]);
        assert_eq!(config_hash(&a), config_hash(&b));
        // with migration on they are live and must not fold
        let c = cfg(&[("migration", "threshold"), ("migration-threshold", "1.1")]);
        let d = cfg(&[("migration", "threshold"), ("migration-threshold", "1.4")]);
        assert_ne!(config_hash(&c), config_hash(&d));
        assert_ne!(config_hash(&a), config_hash(&c));
        // an out-of-range value keeps a distinct hash even with
        // migration off: it must keep failing validation, not silently
        // alias a valid twin's report
        let mut bad = cfg(&[]);
        bad.policy.migration_threshold = 0.5;
        assert!(bad.validate().is_err());
        assert_ne!(config_hash(&bad), config_hash(&a));
        // the engine is bit-identical for any sim-thread count
        let mut t = cfg(&[]);
        t.sim_threads = 8;
        assert_eq!(config_hash(&t), config_hash(&cfg(&[])));
    }

    #[test]
    fn written_flags_expand_composite_axes() {
        let spec = SweepSpec::new(FlagMap::new()).with_axes(vec![
            Axis::new("pd-ratio", vec!["1:3".into()]).unwrap(),
            Axis::new("flag:seed", vec!["9".into()]).unwrap(),
        ]);
        let pts = spec.points().unwrap();
        assert_eq!(
            pts[0].written,
            [
                ("decode".to_string(), "3".to_string()),
                ("mode".to_string(), "pd".to_string()),
                ("prefill".to_string(), "1".to_string()),
                ("seed".to_string(), "9".to_string()),
            ],
            "composites expanded, flag: stripped, sorted by flag name"
        );
        // an unappliable assignment falls back to the raw pairs (the
        // error itself surfaces at lowering time as an error row)
        let p = PointSpec::new(vec![("pd-ratio".into(), "bogus".into())]);
        let pts = SweepSpec::new(FlagMap::new()).with_points(vec![p]).points().unwrap();
        assert_eq!(pts[0].written, [("pd-ratio".to_string(), "bogus".to_string())]);
    }

    #[test]
    fn horizon_override_sets_the_workload_size() {
        let mut base = FlagMap::new();
        base.set("model", "tiny");
        base.set("requests", "64");
        let spec = SweepSpec::new(base)
            .with_axes(vec![Axis::new("seed", vec!["2".into()]).unwrap()]);
        let pts = spec.points().unwrap();
        assert_eq!(spec.point_config(&pts[0]).unwrap().workload.n_requests, 64);
        let short = spec.point_config_at_horizon(&pts[0], 8).unwrap();
        assert_eq!(short.workload.n_requests, 8);
        assert_eq!(short.seed, 2, "assignments still apply");
    }

    #[test]
    fn point_config_applies_base_axes_and_post() {
        let mut base = FlagMap::new();
        base.set("model", "tiny");
        base.set("replicas", "2");
        let spec = SweepSpec::new(base)
            .with_axes(vec![Axis::new("seed", vec!["9".into()]).unwrap()])
            .with_post(Box::new(|cfg| cfg.policy.kv_reserve_frac = 0.25));
        let pts = spec.points().unwrap();
        let cfg = spec.point_config(&pts[0]).unwrap();
        assert_eq!(cfg.model.name, "tiny-1B");
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.policy.kv_reserve_frac, 0.25, "post-hook ran last");
    }
}
