//! Operator IR: the workload descriptors the execution predictor consumes.
//!
//! A replica iteration is costed by decomposing the model's layer into
//! these operator workloads (see `workflows::cost`), each of which is
//! priced by an [`crate::predictor::ExecutionPredictor`].

pub mod features;
pub mod opgen;

/// One operator invocation with its full workload characterization.
#[derive(Clone, Debug, PartialEq)]
pub enum OpWorkload {
    /// Dense GEMM `[m,k] @ [k,n]`.
    Gemm { m: u64, n: u64, k: u64 },
    /// Batched attention; `q_lens[i]` new tokens attending to
    /// `ctx_lens[i]` existing positions (decode: `q_lens[i] == 1`).
    Attention {
        is_prefill: bool,
        q_lens: Vec<u32>,
        ctx_lens: Vec<u32>,
        n_heads: u32,
        n_kv_heads: u32,
        head_dim: u32,
    },
    /// MoE expert FFN GroupedGEMM with per-expert token loads.
    GroupedGemm { tokens_per_expert: Vec<u32>, n: u64, k: u64 },
    /// Ring all-reduce across `n_ranks` of `bytes` payload.
    AllReduce { bytes: f64, n_ranks: u32 },
    /// All-to-all (EP dispatch/combine).
    AllToAll { bytes: f64, n_ranks: u32 },
    /// Point-to-point transfer (KV-cache migration, AF activations).
    P2p { bytes: f64 },
}

impl OpWorkload {
    /// Short operator-class name (metrics/report keys).
    pub fn class(&self) -> &'static str {
        match self {
            OpWorkload::Gemm { .. } => "gemm",
            OpWorkload::Attention { is_prefill: true, .. } => "attn_prefill",
            OpWorkload::Attention { is_prefill: false, .. } => "attn_decode",
            OpWorkload::GroupedGemm { .. } => "grouped_gemm",
            OpWorkload::AllReduce { .. } => "allreduce",
            OpWorkload::AllToAll { .. } => "all2all",
            OpWorkload::P2p { .. } => "p2p",
        }
    }

    /// Total FLOPs of the op (roofline baseline + reporting).
    pub fn flops(&self) -> f64 {
        match self {
            OpWorkload::Gemm { m, n, k } => 2.0 * (*m as f64) * (*n as f64) * (*k as f64),
            OpWorkload::Attention { q_lens, ctx_lens, n_heads, head_dim, .. } => {
                let mut fl = 0.0;
                for (&l, &c) in q_lens.iter().zip(ctx_lens) {
                    fl += 4.0 * l as f64 * (c as f64 + l as f64 / 2.0) * *head_dim as f64;
                }
                fl * *n_heads as f64
            }
            OpWorkload::GroupedGemm { tokens_per_expert, n, k } => {
                let total: u64 = tokens_per_expert.iter().map(|&m| m as u64).sum();
                2.0 * total as f64 * (*n as f64) * (*k as f64)
            }
            _ => 0.0,
        }
    }

    /// Bytes moved (for communication ops).
    pub fn comm_bytes(&self) -> f64 {
        match self {
            OpWorkload::AllReduce { bytes, .. }
            | OpWorkload::AllToAll { bytes, .. }
            | OpWorkload::P2p { bytes } => *bytes,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_names() {
        assert_eq!(OpWorkload::Gemm { m: 1, n: 1, k: 1 }.class(), "gemm");
        let a = OpWorkload::Attention {
            is_prefill: false,
            q_lens: vec![1],
            ctx_lens: vec![10],
            n_heads: 4,
            n_kv_heads: 4,
            head_dim: 64,
        };
        assert_eq!(a.class(), "attn_decode");
    }

    #[test]
    fn gemm_flops() {
        let g = OpWorkload::Gemm { m: 10, n: 20, k: 30 };
        assert_eq!(g.flops(), 2.0 * 10.0 * 20.0 * 30.0);
    }

    #[test]
    fn comm_bytes() {
        assert_eq!(OpWorkload::P2p { bytes: 42.0 }.comm_bytes(), 42.0);
        assert_eq!(OpWorkload::Gemm { m: 1, n: 1, k: 1 }.comm_bytes(), 0.0);
    }
}
