//! Random operator-workload generator for predictor evaluation
//! (Fig. 2-style studies). Mirrors the distributions of
//! `python/compile/train.py` but is seeded independently, so Rust-side
//! evaluations are held-out with respect to the training data.

use crate::core::Pcg64;
use crate::operators::OpWorkload;

/// (n_heads, n_kv_heads, head_dim) presets spanning GQA ratios — same
/// list as `train.MODEL_PRESETS`.
pub const MODEL_PRESETS: [(u32, u32, u32); 6] = [
    (28, 4, 128),
    (64, 8, 128),
    (32, 8, 128),
    (16, 16, 64),
    (48, 8, 128),
    (32, 32, 128),
];

/// Mixture of length distributions, from homogeneous to heavily skewed
/// (mirrors `train._sample_lens`, including the single-straggler mode).
pub fn sample_lens(rng: &mut Pcg64, b: usize, lo: u32, hi: u32) -> Vec<u32> {
    match rng.gen_range(0, 5) {
        0 => {
            let v = rng.gen_range(lo as u64, hi as u64) as u32;
            vec![v; b]
        }
        1 => (0..b).map(|_| rng.gen_range(lo as u64, hi as u64) as u32).collect(),
        2 => {
            let mu = (rng.next_f64() * (hi as f64 / 4.0 - lo as f64) + lo as f64 + 1.0).ln();
            (0..b)
                .map(|_| (rng.lognormal(mu, 0.8) as u32).clamp(lo, hi))
                .collect()
        }
        3 => {
            let mut lens: Vec<u32> = (0..b)
                .map(|_| rng.gen_range(lo as u64, ((hi / 16).max(lo + 1)) as u64) as u32)
                .collect();
            let n_long = (b / 16).max(1);
            for _ in 0..n_long {
                let i = rng.gen_range(0, b as u64) as usize;
                lens[i] = rng.gen_range((hi / 2) as u64, hi as u64) as u32;
            }
            lens
        }
        _ => {
            // single straggler: one very long sequence dominates
            let mut lens: Vec<u32> = (0..b)
                .map(|_| rng.gen_range(lo as u64, ((hi / 64).max(lo + 1)) as u64) as u32)
                .collect();
            let i = rng.gen_range(0, b as u64) as usize;
            lens[i] = rng.gen_range((hi / 2) as u64, hi as u64) as u32;
            lens
        }
    }
}

/// Random attention workload (prefill or decode) with skewed batches.
pub fn attn_workload(rng: &mut Pcg64) -> OpWorkload {
    let (h, hkv, d) = MODEL_PRESETS[rng.gen_range(0, MODEL_PRESETS.len() as u64) as usize];
    let b = (rng.next_f64() * (128f64).ln()).exp() as usize + 1;
    let is_prefill = rng.next_f64() < 0.5;
    if is_prefill {
        let q_lens = sample_lens(rng, b, 16, 4096);
        let ctx_lens = if rng.next_f64() < 0.3 {
            sample_lens(rng, b, 1, 2048)
        } else {
            vec![0; b]
        };
        OpWorkload::Attention {
            is_prefill: true,
            q_lens,
            ctx_lens,
            n_heads: h,
            n_kv_heads: hkv,
            head_dim: d,
        }
    } else {
        OpWorkload::Attention {
            is_prefill: false,
            q_lens: vec![1; b],
            ctx_lens: sample_lens(rng, b, 16, 32768),
            n_heads: h,
            n_kv_heads: hkv,
            head_dim: d,
        }
    }
}

/// Random GroupedGEMM workload with a wide imbalance sweep.
pub fn grouped_gemm_workload(rng: &mut Pcg64) -> OpWorkload {
    let e = rng.gen_range(2, 65) as usize;
    let total = (rng.next_f64() * ((16384f64).ln() - (16f64).ln()) + (16f64).ln()).exp() as u32;
    let alpha = (rng.next_f64() * ((20f64).ln() - (0.05f64).ln()) + (0.05f64).ln()).exp();
    let probs = rng.dirichlet_sym(alpha, e);
    // multinomial via repeated weighted draws would be slow; use
    // expected counts with stochastic rounding (same load shapes)
    let mut loads: Vec<u32> = probs
        .iter()
        .map(|&p| {
            let x = p * total as f64;
            let base = x.floor();
            (base + if rng.next_f64() < x - base { 1.0 } else { 0.0 }) as u32
        })
        .collect();
    // fix up the sum to exactly `total`
    let mut diff = total as i64 - loads.iter().map(|&x| x as i64).sum::<i64>();
    while diff != 0 {
        let i = rng.gen_range(0, e as u64) as usize;
        if diff > 0 {
            loads[i] += 1;
            diff -= 1;
        } else if loads[i] > 0 {
            loads[i] -= 1;
            diff += 1;
        }
    }
    let n = (rng.next_f64() * ((32768f64).ln() - (512f64).ln()) + (512f64).ln()).exp() as u64;
    let k = (rng.next_f64() * ((8192f64).ln() - (512f64).ln()) + (512f64).ln()).exp() as u64;
    OpWorkload::GroupedGemm { tokens_per_expert: loads, n, k }
}

/// Random dense GEMM workload.
pub fn gemm_workload(rng: &mut Pcg64) -> OpWorkload {
    let m = (rng.next_f64() * (16384f64).ln()).exp() as u64 + 1;
    let n = (rng.next_f64() * ((32768f64).ln() - (256f64).ln()) + (256f64).ln()).exp() as u64;
    let k = (rng.next_f64() * ((32768f64).ln() - (256f64).ln()) + (256f64).ln()).exp() as u64;
    OpWorkload::Gemm { m, n, k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attn_workloads_valid() {
        let mut rng = Pcg64::new(3);
        for _ in 0..200 {
            match attn_workload(&mut rng) {
                OpWorkload::Attention { q_lens, ctx_lens, n_heads, n_kv_heads, .. } => {
                    assert_eq!(q_lens.len(), ctx_lens.len());
                    assert!(!q_lens.is_empty() && q_lens.len() <= 129);
                    assert!(n_kv_heads <= n_heads);
                }
                _ => panic!("wrong op"),
            }
        }
    }

    #[test]
    fn gg_loads_sum_to_total() {
        let mut rng = Pcg64::new(4);
        for _ in 0..100 {
            if let OpWorkload::GroupedGemm { tokens_per_expert, .. } =
                grouped_gemm_workload(&mut rng)
            {
                assert!(tokens_per_expert.iter().map(|&x| x as u64).sum::<u64>() >= 16);
            }
        }
    }

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(9);
        let mut b = Pcg64::new(9);
        assert_eq!(attn_workload(&mut a), attn_workload(&mut b));
    }
}
