//! Feature extraction for the learned predictors.
//!
//! Bit-for-bit mirror of `python/compile/features.py`; any change here
//! must be reflected there (enforced by `rust/tests/oracle_parity.rs`
//! against `artifacts/oracle_golden.json`). Features combine length /
//! load distribution statistics with tiling-derived quantities from the
//! oracle's tile model (§3.2 of the paper).

use crate::hardware::GpuSpec;
use crate::oracle;

pub const ATTN_N_FEATURES: usize = 16;
pub const GG_N_FEATURES: usize = 12;
pub const GEMM_N_FEATURES: usize = 6;

const US: f64 = 1e6; // seconds -> microseconds for log-scaled features

/// (sum, mean, max, population std); empty slice -> zeros.
fn stats(xs: &[u32]) -> (f64, f64, f64, f64) {
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0, 0.0, 0.0);
    }
    let s: f64 = xs.iter().map(|&x| x as f64).sum();
    let mean = s / n as f64;
    let mx = xs.iter().copied().max().unwrap() as f64;
    let var: f64 = xs.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>()
        / n as f64;
    (s, mean, mx, var.sqrt())
}

/// (waves, fraction of SMs busy in the last wave).
fn wave_features(n_tiles: u64, sms: u32) -> (f64, f64) {
    if n_tiles == 0 {
        return (0.0, 0.0);
    }
    let waves = n_tiles.div_ceil(sms as u64);
    let frac_last = (n_tiles - (waves - 1) * sms as u64) as f64 / sms as f64;
    (waves as f64, frac_last)
}

#[allow(clippy::too_many_arguments)]
pub fn attn_features(
    is_prefill: bool,
    q_lens: &[u32],
    ctx_lens: &[u32],
    n_heads: u32,
    n_kv_heads: u32,
    head_dim: u32,
    gpu: &GpuSpec,
) -> [f64; ATTN_N_FEATURES] {
    let b = q_lens.len() as f64;
    let (sum_l, mean_l, _max_l, std_l) = stats(q_lens);
    let (sum_c, mean_c, max_c, std_c) = stats(ctx_lens);
    let cv_l = if mean_l > 0.0 { std_l / mean_l } else { 0.0 };
    let cv_c = if mean_c > 0.0 { std_c / mean_c } else { 0.0 };
    let (tile, max_kv) = if is_prefill {
        let s = oracle::attn_prefill_stats(q_lens, ctx_lens, n_heads, n_kv_heads, head_dim, 2, gpu);
        let max_kv = q_lens
            .iter()
            .zip(ctx_lens)
            .filter(|(&l, _)| l > 0)
            .map(|(&l, &c)| (c + l) as u64)
            .max()
            .unwrap_or(0);
        (s, max_kv as f64)
    } else {
        let (s, _split) =
            oracle::attn_decode_stats(ctx_lens, n_heads, n_kv_heads, head_dim, 2, gpu);
        (s, max_c)
    };
    let (waves, frac_last) = wave_features(tile.n_tiles, gpu.sms);
    let mean_tile = if tile.n_tiles > 0 { tile.work / tile.n_tiles as f64 } else { 0.0 };
    [
        if is_prefill { 1.0 } else { 0.0 },
        b.ln_1p(),
        (n_heads as f64).ln_1p(),
        (n_kv_heads as f64).ln_1p(),
        (head_dim as f64).ln_1p(),
        sum_l.ln_1p(),
        cv_l,
        sum_c.ln_1p(),
        cv_c,
        (tile.n_tiles as f64).ln_1p(),
        frac_last,
        (tile.work * US).ln_1p(),
        (mean_tile * US).ln_1p(),
        (tile.max_tile * US).ln_1p(),
        waves.ln_1p(),
        max_kv.ln_1p(),
    ]
}

pub fn grouped_gemm_features(
    tokens_per_expert: &[u32],
    n: u64,
    k: u64,
    gpu: &GpuSpec,
) -> [f64; GG_N_FEATURES] {
    let e = tokens_per_expert.len() as f64;
    let (total, mean_m, max_m, std_m) = stats(tokens_per_expert);
    let cv_m = if mean_m > 0.0 { std_m / mean_m } else { 0.0 };
    let imbalance = if total > 0.0 { max_m * e / total } else { 0.0 };
    let (tiles, t_tile, active) = oracle::grouped_gemm_stats(tokens_per_expert, n, k, 2, gpu);
    let (waves, frac_last) = wave_features(tiles, gpu.sms);
    [
        e.ln_1p(),
        total.ln_1p(),
        (n as f64).ln_1p(),
        (k as f64).ln_1p(),
        cv_m,
        if e > 0.0 { active as f64 / e } else { 0.0 },
        imbalance,
        (tiles as f64).ln_1p(),
        frac_last,
        (t_tile * US).ln_1p(),
        (tiles as f64 * t_tile * US).ln_1p(),
        waves.ln_1p(),
    ]
}

pub fn gemm_features(m: u64, n: u64, k: u64, gpu: &GpuSpec) -> [f64; GEMM_N_FEATURES] {
    let (tiles, t_tile) = oracle::gemm_stats(m, n, k, 2, gpu);
    let (waves, _frac_last) = wave_features(tiles, gpu.sms);
    [
        (m as f64).ln_1p(),
        (n as f64).ln_1p(),
        (k as f64).ln_1p(),
        (tiles as f64).ln_1p(),
        (t_tile * US).ln_1p(),
        waves.ln_1p(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attn_feature_shape_and_finiteness() {
        let g = GpuSpec::a800();
        let f = attn_features(true, &[128, 256], &[0, 0], 28, 4, 128, &g);
        assert_eq!(f.len(), ATTN_N_FEATURES);
        assert!(f.iter().all(|x| x.is_finite()));
        assert_eq!(f[0], 1.0);
    }

    #[test]
    fn homogeneous_batch_has_zero_cv() {
        let g = GpuSpec::a800();
        let f = attn_features(false, &[1; 8], &[512; 8], 28, 4, 128, &g);
        assert_eq!(f[6], 0.0);
        assert_eq!(f[8], 0.0);
    }

    #[test]
    fn gg_features_capture_imbalance() {
        let g = GpuSpec::a800();
        let bal = grouped_gemm_features(&[100; 8], 4096, 2048, &g);
        let imb = grouped_gemm_features(&[10, 10, 10, 10, 10, 10, 10, 730], 4096, 2048, &g);
        // imbalance metric (index 6) strictly larger for the skewed load
        assert!(imb[6] > bal[6]);
    }

    #[test]
    fn gemm_features_monotone_in_m() {
        let g = GpuSpec::a800();
        let a = gemm_features(64, 4096, 2048, &g);
        let b = gemm_features(4096, 4096, 2048, &g);
        assert!(b[0] > a[0]);
        assert!(b[3] >= a[3]);
    }

    #[test]
    fn stats_empty() {
        assert_eq!(stats(&[]), (0.0, 0.0, 0.0, 0.0));
    }
}
