//! Execution predictors: pluggable operator-runtime models.
//!
//! * [`OraclePredictor`] — the analytical ground truth (and the
//!   "profiled" stand-in, see DESIGN.md §Substitutions).
//! * [`LearnedPredictor`] — Frontier's contribution: the trained MLP
//!   executed through PJRT from the AOT artifacts, with memoization.
//! * [`VidurPredictor`] — the replica-centric baseline's proxy-length
//!   operator model (single sqrt proxy, no wave/straggler terms).
//! * [`RooflinePredictor`] — the "intra-framework simulator" baseline
//!   (§2.2): pure roofline, no scheduling effects at all.

mod learned;
mod vidur;

pub use learned::LearnedPredictor;
pub use vidur::VidurPredictor;

use crate::hardware::{GpuSpec, LinkSpec};
use crate::operators::OpWorkload;
use crate::oracle;

/// A model that prices one operator invocation, in seconds.
pub trait ExecutionPredictor {
    fn predict(&mut self, op: &OpWorkload) -> f64;
    fn name(&self) -> &'static str;
    /// Number of underlying model evaluations (cache misses) — perf metric.
    fn evals(&self) -> u64 {
        0
    }
    /// Hint that all of `ops` are about to be priced: batched backends
    /// (the PJRT-learned predictor) warm their caches in grouped
    /// executable launches. Analytical predictors ignore it. Takes
    /// borrowed ops through an iterator so hot callers can chain their
    /// op lists (attention + FFN plan) without cloning a single op —
    /// the pre-refactor signature forced a `.cloned().collect()` of the
    /// entire iteration per call.
    fn prefetch(&mut self, _ops: &mut dyn Iterator<Item = &OpWorkload>) {}
}

/// Which predictor drives a simulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictorKind {
    /// Analytical oracle (ground truth).
    Oracle,
    /// Learned MLP via PJRT artifacts (Frontier).
    Learned,
    /// Vidur-style proxy-length model.
    Vidur,
    /// Naive roofline.
    Roofline,
}

impl PredictorKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "oracle" => Some(Self::Oracle),
            "learned" => Some(Self::Learned),
            "vidur" => Some(Self::Vidur),
            "roofline" => Some(Self::Roofline),
            _ => None,
        }
    }
}

/// Collective/transfer pricing shared by all predictors (the paper's
/// learned models cover compute operators; communication uses the
/// alpha-beta model).
pub fn comm_time(op: &OpWorkload, link: &LinkSpec) -> Option<f64> {
    match op {
        OpWorkload::AllReduce { bytes, n_ranks } => {
            Some(oracle::allreduce_time(*bytes, *n_ranks, link))
        }
        OpWorkload::AllToAll { bytes, n_ranks } => {
            Some(oracle::all2all_time(*bytes, *n_ranks, link))
        }
        OpWorkload::P2p { bytes } => Some(oracle::p2p_time(*bytes, link)),
        _ => None,
    }
}

/// Ground-truth analytical predictor.
pub struct OraclePredictor {
    pub gpu: GpuSpec,
    pub link: LinkSpec,
    evals: u64,
}

impl OraclePredictor {
    pub fn new(gpu: GpuSpec, link: LinkSpec) -> Self {
        OraclePredictor { gpu, link, evals: 0 }
    }

    pub fn a800() -> Self {
        Self::new(GpuSpec::a800(), LinkSpec::nvlink_a800())
    }
}

impl ExecutionPredictor for OraclePredictor {
    fn predict(&mut self, op: &OpWorkload) -> f64 {
        self.evals += 1;
        if let Some(t) = comm_time(op, &self.link) {
            return t;
        }
        match op {
            OpWorkload::Gemm { m, n, k } => oracle::gemm_time(*m, *n, *k, 2, &self.gpu),
            OpWorkload::Attention { is_prefill, q_lens, ctx_lens, n_heads, n_kv_heads, head_dim } => {
                if *is_prefill {
                    oracle::attn_prefill_time(q_lens, ctx_lens, *n_heads, *n_kv_heads, *head_dim, 2, &self.gpu)
                } else {
                    oracle::attn_decode_time(ctx_lens, *n_heads, *n_kv_heads, *head_dim, 2, &self.gpu)
                }
            }
            OpWorkload::GroupedGemm { tokens_per_expert, n, k } => {
                oracle::grouped_gemm_time(tokens_per_expert, *n, *k, 2, &self.gpu)
            }
            _ => unreachable!(),
        }
    }

    fn name(&self) -> &'static str {
        "oracle"
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// Naive roofline predictor: `max(flops/peak, bytes/bw) + launch`,
/// no tile scheduling, no wave quantization, no stragglers.
pub struct RooflinePredictor {
    pub gpu: GpuSpec,
    pub link: LinkSpec,
    evals: u64,
}

impl RooflinePredictor {
    pub fn new(gpu: GpuSpec, link: LinkSpec) -> Self {
        RooflinePredictor { gpu, link, evals: 0 }
    }

    pub fn a800() -> Self {
        Self::new(GpuSpec::a800(), LinkSpec::nvlink_a800())
    }

    fn mem_bytes(op: &OpWorkload, dtype: f64) -> f64 {
        match op {
            OpWorkload::Gemm { m, n, k } => {
                ((*m * *k + *k * *n + *m * *n) as f64) * dtype
            }
            OpWorkload::Attention { q_lens, ctx_lens, n_kv_heads, head_dim, .. } => {
                let kv: f64 = ctx_lens
                    .iter()
                    .zip(q_lens)
                    .map(|(&c, &l)| (c as f64 + l as f64) * 2.0)
                    .sum();
                kv * *n_kv_heads as f64 * *head_dim as f64 * dtype
            }
            OpWorkload::GroupedGemm { tokens_per_expert, n, k } => {
                let total: f64 = tokens_per_expert.iter().map(|&m| m as f64).sum();
                let active = tokens_per_expert.iter().filter(|&&m| m > 0).count() as f64;
                (total * *k as f64 + active * (*k * *n) as f64 + total * *n as f64) * dtype
            }
            _ => 0.0,
        }
    }
}

impl ExecutionPredictor for RooflinePredictor {
    fn predict(&mut self, op: &OpWorkload) -> f64 {
        self.evals += 1;
        if let Some(t) = comm_time(op, &self.link) {
            return t;
        }
        let flops = op.flops();
        let bytes = Self::mem_bytes(op, 2.0);
        let t_comp = flops / (self.gpu.peak_flops * 0.8);
        let t_mem = bytes / (self.gpu.hbm_bw * self.gpu.mem_eff);
        self.gpu.launch_overhead + t_comp.max(t_mem)
    }

    fn name(&self) -> &'static str {
        "roofline"
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

/// Build a predictor by kind. `Learned` loads the PJRT artifacts from
/// [`crate::runtime::PredictorRuntime::default_dir`] unless a dir is given.
pub fn build(
    kind: PredictorKind,
    artifacts_dir: Option<&std::path::Path>,
) -> anyhow::Result<Box<dyn ExecutionPredictor>> {
    build_for(kind, GpuSpec::a800(), LinkSpec::nvlink_a800(), artifacts_dir)
}

/// Build a predictor for a specific GPU model and interconnect — the
/// per-stage form for heterogeneous deployments. The learned predictor
/// executes GPU-specific trained artifacts, so it ignores the `gpu`
/// argument (its artifacts already encode the hardware).
pub fn build_for(
    kind: PredictorKind,
    gpu: GpuSpec,
    link: LinkSpec,
    artifacts_dir: Option<&std::path::Path>,
) -> anyhow::Result<Box<dyn ExecutionPredictor>> {
    Ok(match kind {
        PredictorKind::Oracle => Box::new(OraclePredictor::new(gpu, link)),
        PredictorKind::Vidur => Box::new(VidurPredictor::new(gpu, link)),
        PredictorKind::Roofline => Box::new(RooflinePredictor::new(gpu, link)),
        PredictorKind::Learned => {
            let dir = artifacts_dir
                .map(|p| p.to_path_buf())
                .unwrap_or_else(crate::runtime::PredictorRuntime::default_dir);
            Box::new(LearnedPredictor::load(&dir)?)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decode_op(ctx: Vec<u32>) -> OpWorkload {
        OpWorkload::Attention {
            is_prefill: false,
            q_lens: vec![1; ctx.len()],
            ctx_lens: ctx,
            n_heads: 28,
            n_kv_heads: 4,
            head_dim: 128,
        }
    }

    #[test]
    fn oracle_matches_oracle_module() {
        let mut p = OraclePredictor::a800();
        let op = OpWorkload::Gemm { m: 512, n: 4096, k: 4096 };
        let direct = oracle::gemm_time(512, 4096, 4096, 2, &GpuSpec::a800());
        assert_eq!(p.predict(&op), direct);
        assert_eq!(p.evals(), 1);
    }

    #[test]
    fn roofline_underestimates_skewed_decode() {
        let mut oracle_p = OraclePredictor::a800();
        let mut roof = RooflinePredictor::a800();
        let mut ctx = vec![256u32; 71];
        ctx.push(65536);
        let op = decode_op(ctx);
        // roofline ignores the straggler: it must be faster than truth
        assert!(roof.predict(&op) < oracle_p.predict(&op));
    }

    #[test]
    fn comm_identical_across_predictors() {
        let mut a = OraclePredictor::a800();
        let mut b = RooflinePredictor::a800();
        let op = OpWorkload::AllReduce { bytes: 1e8, n_ranks: 8 };
        assert_eq!(a.predict(&op), b.predict(&op));
    }

    #[test]
    fn kind_parsing() {
        assert_eq!(PredictorKind::parse("learned"), Some(PredictorKind::Learned));
        assert_eq!(PredictorKind::parse("bogus"), None);
    }
}
