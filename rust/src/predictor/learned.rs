//! The learned predictor: Frontier's high-fidelity operator model.
//!
//! Features are extracted in Rust (`operators::features`, mirroring the
//! Python training pipeline) and priced by the AOT-compiled MLP through
//! PJRT. A memoization cache keyed on the feature bits keeps the
//! simulation hot path off the executable for repeated workload shapes —
//! decode iterations re-price nearly identical batches layer after
//! layer, so hit rates in steady state exceed 90%.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::Result;

use crate::hardware::{GpuSpec, LinkSpec};
use crate::operators::features;
use crate::operators::OpWorkload;
use crate::runtime::PredictorRuntime;

use super::{comm_time, ExecutionPredictor};

/// Cache key: operator class + the raw bits of the f32-rounded features.
/// f32 rounding matches what the executable actually sees, so two keys
/// are equal exactly when PJRT would compute identical outputs.
#[derive(Clone, PartialEq, Eq, Hash)]
struct FeatKey(u8, Vec<u32>);

fn key(class: u8, feats: &[f64]) -> FeatKey {
    FeatKey(class, feats.iter().map(|&x| (x as f32).to_bits()).collect())
}

type SharedCache = Rc<RefCell<HashMap<FeatKey, f64>>>;

pub struct LearnedPredictor {
    rt: Rc<PredictorRuntime>,
    gpu: GpuSpec,
    link: LinkSpec,
    /// Memo cache shared across simulations using the same artifacts
    /// (per thread): sweeps re-price mostly the same workload shapes.
    cache: SharedCache,
    evals: u64,
    hits: u64,
    /// Quantize features before prediction (~3% log-space rounding).
    /// Decode contexts advance every iteration, so exact memoization
    /// almost never hits; rounding trades <=3% input error (below the
    /// predictor's own noise) for >90% cache hit rates on the hot path.
    /// Disable for operator-fidelity studies (Fig. 2).
    quantize: bool,
}

thread_local! {
    static SHARED_CACHES: RefCell<HashMap<std::path::PathBuf, SharedCache>> =
        RefCell::new(HashMap::new());
}

impl LearnedPredictor {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let rt = PredictorRuntime::load_cached(artifacts_dir)?;
        let cache = SHARED_CACHES.with(|c| {
            Rc::clone(
                c.borrow_mut()
                    .entry(artifacts_dir.to_path_buf())
                    .or_insert_with(|| Rc::new(RefCell::new(HashMap::with_capacity(4096)))),
            )
        });
        Ok(LearnedPredictor {
            rt,
            gpu: GpuSpec::a800(),
            link: LinkSpec::nvlink_a800(),
            cache,
            evals: 0,
            hits: 0,
            quantize: true,
        })
    }

    /// Exact mode: no feature quantization and a private cache
    /// (operator-fidelity studies).
    pub fn load_exact(artifacts_dir: &Path) -> Result<Self> {
        Ok(LearnedPredictor {
            quantize: false,
            cache: Rc::new(RefCell::new(HashMap::with_capacity(4096))),
            ..Self::load(artifacts_dir)?
        })
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        (self.hits, self.evals)
    }

    /// Feature-level quantization: the predictor's features are
    /// log-scaled, so rounding to 1/32 bounds the induced workload error
    /// at ~3% — below the model's own validation error — while making
    /// near-identical batches (decode contexts advance one token per
    /// iteration) share cache entries.
    fn round_feats(&self, feats: &mut [f64]) {
        if !self.quantize {
            return;
        }
        for f in feats {
            *f = (*f * 32.0).round() / 32.0;
        }
    }

    fn query(&mut self, class: u8, feats: Vec<f64>) -> f64 {
        let k = key(class, &feats);
        if let Some(&t) = self.cache.borrow().get(&k) {
            self.hits += 1;
            return t;
        }
        self.evals += 1;
        let exe = match class {
            0 => &self.rt.attn,
            1 => &self.rt.grouped_gemm,
            _ => &self.rt.gemm,
        };
        let us = exe
            .predict_us(std::slice::from_ref(&feats))
            .expect("predictor execution failed")[0];
        let secs = us * 1e-6;
        self.cache.borrow_mut().insert(k, secs);
        secs
    }

    fn featurize(&self, op: &OpWorkload) -> Option<(u8, Vec<f64>)> {
        match op {
            OpWorkload::Attention { is_prefill, q_lens, ctx_lens, n_heads, n_kv_heads, head_dim } => {
                Some((
                    0,
                    features::attn_features(
                        *is_prefill, q_lens, ctx_lens, *n_heads, *n_kv_heads, *head_dim,
                        &self.gpu,
                    )
                    .to_vec(),
                ))
            }
            OpWorkload::GroupedGemm { tokens_per_expert, n, k } => Some((
                1,
                features::grouped_gemm_features(tokens_per_expert, *n, *k, &self.gpu).to_vec(),
            )),
            OpWorkload::Gemm { m, n, k } => {
                Some((2, features::gemm_features(*m, *n, *k, &self.gpu).to_vec()))
            }
            _ => None,
        }
    }
}

impl ExecutionPredictor for LearnedPredictor {
    fn predict(&mut self, op: &OpWorkload) -> f64 {
        if let Some(t) = comm_time(op, &self.link) {
            return t;
        }
        let (class, mut feats) = self.featurize(op).expect("compute op");
        self.round_feats(&mut feats);
        self.query(class, feats)
    }

    /// Batched cache warm-up: group pending (uncached) queries by
    /// operator class and execute each group in as few PJRT launches as
    /// the fixed artifact batch allows. One iteration's whole op list
    /// costs <= 3 launches instead of one per op.
    fn prefetch(&mut self, ops: &mut dyn Iterator<Item = &OpWorkload>) {
        let mut pending: [Vec<(FeatKey, Vec<f64>)>; 3] = Default::default();
        for op in ops {
            if comm_time(op, &self.link).is_some() {
                continue;
            }
            let Some((class, mut feats)) = self.featurize(op) else { continue };
            self.round_feats(&mut feats);
            let k = key(class, &feats);
            if self.cache.borrow().contains_key(&k) {
                continue;
            }
            let bucket = &mut pending[class as usize];
            if !bucket.iter().any(|(existing, _)| *existing == k) {
                bucket.push((k, feats));
            }
        }
        for (class, bucket) in pending.into_iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let exe = match class {
                0 => &self.rt.attn,
                1 => &self.rt.grouped_gemm,
                _ => &self.rt.gemm,
            };
            for chunk in bucket.chunks(exe.batch) {
                let rows: Vec<Vec<f64>> = chunk.iter().map(|(_, f)| f.clone()).collect();
                let out = exe.predict_us(&rows).expect("predictor execution failed");
                self.evals += 1;
                let mut cache = self.cache.borrow_mut();
                for ((k, _), us) in chunk.iter().zip(out) {
                    cache.insert(k.clone(), us * 1e-6);
                }
            }
        }
    }

    fn name(&self) -> &'static str {
        "learned"
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}
