//! Vidur-style baseline predictor: proxy-length operator models.
//!
//! Vidur [4] estimates attention runtime by collapsing a heterogeneous
//! batch into a single proxy length ("typically the square root of batch
//! sequence lengths", §3.2) and ignores kernel partitioning effects
//! (wave quantization, stragglers). This reproduces the §1 failure mode:
//! >55% error on a skewed 72-request FlashAttention batch. GroupedGEMM is
//! not supported by Vidur (Table 1); the fallback treats it as one dense
//! GEMM of the total token count.

use crate::hardware::{GpuSpec, LinkSpec};
use crate::operators::OpWorkload;
use crate::oracle;

use super::{comm_time, ExecutionPredictor};

pub struct VidurPredictor {
    pub gpu: GpuSpec,
    pub link: LinkSpec,
    evals: u64,
}

impl VidurPredictor {
    pub fn new(gpu: GpuSpec, link: LinkSpec) -> Self {
        VidurPredictor { gpu, link, evals: 0 }
    }

    pub fn a800() -> Self {
        Self::new(GpuSpec::a800(), LinkSpec::nvlink_a800())
    }

    /// Root-mean-square proxy: sqrt(mean(x^2)) — attention work scales
    /// quadratically in length, so Vidur's calibration uses the sqrt of
    /// the summed squared lengths.
    fn rms(xs: &[u32]) -> u32 {
        if xs.is_empty() {
            return 0;
        }
        let ms: f64 =
            xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len() as f64;
        ms.sqrt().round() as u32
    }

    fn mean(xs: &[u32]) -> u32 {
        if xs.is_empty() {
            return 0;
        }
        (xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64).round() as u32
    }

    /// Smooth makespan: total work spread perfectly over the SMs, no
    /// wave quantization, no straggler serialization. Below one wave the
    /// tiles run fully in parallel (mean tile time); above, perfect
    /// packing at `work / sms`.
    fn smooth(&self, work: f64, n_tiles: u64) -> f64 {
        if n_tiles == 0 {
            return 0.0;
        }
        let cap = (n_tiles as f64).min(self.gpu.sms as f64);
        self.gpu.launch_overhead + work / cap
    }
}

impl ExecutionPredictor for VidurPredictor {
    fn predict(&mut self, op: &OpWorkload) -> f64 {
        self.evals += 1;
        if let Some(t) = comm_time(op, &self.link) {
            return t;
        }
        match op {
            OpWorkload::Gemm { m, n, k } => {
                // dense GEMM is Vidur's strong suit: keep the tiled model
                // but drop quantization (smooth interpolation between
                // profiled grid points)
                let (tiles, t_tile) = oracle::gemm_stats(*m, *n, *k, 2, &self.gpu);
                self.smooth(tiles as f64 * t_tile, tiles)
            }
            OpWorkload::Attention { is_prefill, q_lens, ctx_lens, n_heads, n_kv_heads, head_dim } => {
                let b = q_lens.len();
                if *is_prefill {
                    let proxy_l = Self::rms(q_lens).max(1);
                    let proxy_c = Self::mean(ctx_lens);
                    let s = oracle::attn_prefill_stats(
                        &vec![proxy_l; b],
                        &vec![proxy_c; b],
                        *n_heads,
                        *n_kv_heads,
                        *head_dim,
                        2,
                        &self.gpu,
                    );
                    self.smooth(s.work, s.n_tiles)
                } else {
                    let proxy_c = Self::mean(ctx_lens).max(1);
                    let (s, _split) = oracle::attn_decode_stats(
                        &vec![proxy_c; b],
                        *n_heads,
                        *n_kv_heads,
                        *head_dim,
                        2,
                        &self.gpu,
                    );
                    self.smooth(s.work, s.n_tiles)
                }
            }
            OpWorkload::GroupedGemm { tokens_per_expert, n, k } => {
                // unsupported by Vidur: closest fallback is one dense GEMM
                // over the total tokens (perfect balance assumption)
                let total: u64 = tokens_per_expert.iter().map(|&m| m as u64).sum();
                let (tiles, t_tile) = oracle::gemm_stats(total, *n, *k, 2, &self.gpu);
                self.smooth(tiles as f64 * t_tile, tiles)
            }
            _ => unreachable!(),
        }
    }

    fn name(&self) -> &'static str {
        "vidur"
    }

    fn evals(&self) -> u64 {
        self.evals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::predictor::OraclePredictor;

    fn decode_op(ctx: Vec<u32>) -> OpWorkload {
        OpWorkload::Attention {
            is_prefill: false,
            q_lens: vec![1; ctx.len()],
            ctx_lens: ctx,
            n_heads: 28,
            n_kv_heads: 4,
            head_dim: 128,
        }
    }

    #[test]
    fn accurate_on_homogeneous_decode() {
        let mut vidur = VidurPredictor::a800();
        let mut truth = OraclePredictor::a800();
        let op = decode_op(vec![1024; 64]);
        let v = vidur.predict(&op);
        let t = truth.predict(&op);
        let err = (v - t).abs() / t;
        assert!(err < 0.35, "homogeneous error {err}");
    }

    #[test]
    fn severely_underestimates_skewed_decode() {
        // the §1 anecdote: 72 requests with one very long context
        let mut vidur = VidurPredictor::a800();
        let mut truth = OraclePredictor::a800();
        let mut ctx = vec![200u32; 71];
        ctx.push(49152);
        let op = decode_op(ctx);
        let v = vidur.predict(&op);
        let t = truth.predict(&op);
        assert!(v < 0.6 * t, "vidur {v} vs truth {t} should underestimate by >40%");
    }

    #[test]
    fn rms_proxy() {
        assert_eq!(VidurPredictor::rms(&[3, 4]), 4); // sqrt(12.5)=3.54 -> 4
        assert_eq!(VidurPredictor::rms(&[]), 0);
        assert_eq!(VidurPredictor::mean(&[1, 3]), 2);
    }
}
