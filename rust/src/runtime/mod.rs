//! PJRT runtime: load and execute the AOT-compiled predictor artifacts.
//!
//! The build-time Python side (`python/compile/aot.py`) lowers each
//! trained predictor to HLO *text* (the interchange format that survives
//! the jax>=0.5 / xla_extension 0.5.1 proto-id mismatch — see
//! /opt/xla-example/README.md). This module compiles those modules on the
//! PJRT CPU client once at startup and serves batched feature->runtime
//! queries on the simulation hot path. Python is never invoked here.
//!
//! The PJRT path needs the external `xla` crate, which is unavailable in
//! fully-offline builds; it is gated behind the non-default `pjrt` cargo
//! feature. Without it this module keeps the same API but fails cleanly
//! at load time, and every consumer (the learned predictor, the
//! `validate` CLI subcommand, the artifact-gated tests) already skips or
//! errors gracefully when artifacts cannot be loaded.

#[cfg(feature = "pjrt")]
mod imp {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    use anyhow::{anyhow, bail, Context, Result};

    use crate::config::json::Json;

    /// One compiled predictor executable plus its I/O contract.
    pub struct PredictorExecutable {
        exe: xla::PjRtLoadedExecutable,
        /// Fixed batch dimension the module was lowered with.
        pub batch: usize,
        pub n_features: usize,
        /// Validation metrics recorded at training time (from the manifest).
        pub val_mape: f64,
    }

    impl PredictorExecutable {
        /// Predict runtimes (microseconds) for up to `batch` feature rows.
        /// Rows are padded to the fixed batch; outputs beyond `rows.len()`
        /// are discarded.
        pub fn predict_us(&self, rows: &[Vec<f64>]) -> Result<Vec<f64>> {
            if rows.is_empty() {
                return Ok(Vec::new());
            }
            if rows.len() > self.batch {
                bail!("{} rows exceeds lowered batch {}", rows.len(), self.batch);
            }
            let mut flat = vec![0f32; self.batch * self.n_features];
            for (i, row) in rows.iter().enumerate() {
                if row.len() != self.n_features {
                    bail!("feature row has {} dims, expected {}", row.len(), self.n_features);
                }
                for (j, &x) in row.iter().enumerate() {
                    flat[i * self.n_features + j] = x as f32;
                }
            }
            let lit = xla::Literal::vec1(&flat)
                .reshape(&[self.batch as i64, self.n_features as i64])?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = result.to_tuple1()?;
            let log_us = out.to_vec::<f32>()?;
            Ok(rows
                .iter()
                .enumerate()
                .map(|(i, _)| (log_us[i] as f64).exp())
                .collect())
        }
    }

    /// The full set of predictor executables, loaded from `artifacts/`.
    pub struct PredictorRuntime {
        pub attn: PredictorExecutable,
        pub grouped_gemm: PredictorExecutable,
        pub gemm: PredictorExecutable,
        pub artifacts_dir: PathBuf,
    }

    impl PredictorRuntime {
        /// Compile all predictor artifacts on a fresh PJRT CPU client.
        pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let dir = artifacts_dir.as_ref();
            let manifest_path = dir.join("manifest.json");
            let text = std::fs::read_to_string(&manifest_path)
                .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
            let manifest = Json::parse(&text).context("parsing manifest.json")?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
            let preds = manifest.req("predictors")?;
            let load_one = |name: &str| -> Result<PredictorExecutable> {
                let meta = preds.req(name)?;
                let hlo = dir.join(meta.req("hlo")?.as_str()?);
                let proto = xla::HloModuleProto::from_text_file(
                    hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("loading {hlo:?}: {e}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?;
                Ok(PredictorExecutable {
                    exe,
                    batch: meta.req("batch")?.as_usize()?,
                    n_features: meta.req("n_features")?.as_usize()?,
                    val_mape: meta.req("metrics")?.req("val_mape")?.as_f64()?,
                })
            };
            Ok(PredictorRuntime {
                attn: load_one("attn")?,
                grouped_gemm: load_one("grouped_gemm")?,
                gemm: load_one("gemm")?,
                artifacts_dir: dir.to_path_buf(),
            })
        }

        /// Locate the artifacts directory: `$FRONTIER_ARTIFACTS` or
        /// `./artifacts` relative to the workspace root.
        pub fn default_dir() -> PathBuf {
            if let Ok(p) = std::env::var("FRONTIER_ARTIFACTS") {
                return PathBuf::from(p);
            }
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        }

        /// Load with per-thread memoization: PJRT client construction plus
        /// compiling the three predictor modules costs ~100 ms, which would
        /// otherwise be paid by *every* simulation in a sweep (§Perf). The
        /// registry also carries a shared prediction memo cache so repeated
        /// simulations against the same artifacts reuse learned-predictor
        /// query results.
        pub fn load_cached(artifacts_dir: impl AsRef<Path>) -> Result<Rc<PredictorRuntime>> {
            RUNTIME_REGISTRY.with(|reg| {
                let mut reg = reg.borrow_mut();
                if let Some(rt) = reg.get(artifacts_dir.as_ref()) {
                    return Ok(Rc::clone(rt));
                }
                let rt = Rc::new(Self::load(artifacts_dir.as_ref())?);
                reg.insert(artifacts_dir.as_ref().to_path_buf(), Rc::clone(&rt));
                Ok(rt)
            })
        }
    }

    thread_local! {
        static RUNTIME_REGISTRY: RefCell<HashMap<PathBuf, Rc<PredictorRuntime>>> =
            RefCell::new(HashMap::new());
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::{Path, PathBuf};
    use std::rc::Rc;

    use anyhow::{bail, Result};

    /// Stub of the PJRT executable (built without the `pjrt` feature).
    pub struct PredictorExecutable {
        /// Fixed batch dimension the module was lowered with.
        pub batch: usize,
        pub n_features: usize,
        /// Validation metrics recorded at training time (from the manifest).
        pub val_mape: f64,
    }

    impl PredictorExecutable {
        pub fn predict_us(&self, _rows: &[Vec<f64>]) -> Result<Vec<f64>> {
            bail!("frontier was built without the `pjrt` feature")
        }
    }

    /// Stub of the artifact runtime (built without the `pjrt` feature).
    pub struct PredictorRuntime {
        pub attn: PredictorExecutable,
        pub grouped_gemm: PredictorExecutable,
        pub gemm: PredictorExecutable,
        pub artifacts_dir: PathBuf,
    }

    impl PredictorRuntime {
        pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "cannot load artifacts from {:?}: frontier was built without \
                 the `pjrt` feature. Enabling it requires adding the `xla` \
                 crate (xla-rs) to Cargo.toml's dependencies first, then \
                 building with `--features pjrt`",
                artifacts_dir.as_ref()
            )
        }

        /// Locate the artifacts directory: `$FRONTIER_ARTIFACTS` or
        /// `./artifacts` relative to the workspace root.
        pub fn default_dir() -> PathBuf {
            if let Ok(p) = std::env::var("FRONTIER_ARTIFACTS") {
                return PathBuf::from(p);
            }
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        }

        pub fn load_cached(artifacts_dir: impl AsRef<Path>) -> Result<Rc<PredictorRuntime>> {
            Self::load(artifacts_dir).map(Rc::new)
        }
    }
}

pub use imp::{PredictorExecutable, PredictorRuntime};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_points_at_workspace_artifacts() {
        let d = PredictorRuntime::default_dir();
        assert!(d.ends_with("artifacts") || std::env::var("FRONTIER_ARTIFACTS").is_ok());
    }
}
