//! Online expert-load tracking and dynamic expert migration.
//!
//! Static placement goes stale the moment expert popularity drifts
//! (the regime [`crate::moe::RoutingPolicy::Drifting`] models, and the
//! one MegaScale-Infer-style disaggregated EP serving is built around).
//! This module turns placement into a simulated control loop:
//!
//! 1. **Track** — a [`LoadEstimator`] keeps a windowed EWMA of the
//!    per-expert token loads observed on every routing draw (fed from
//!    the cost model's EP pricing path).
//! 2. **Plan** — between iterations, [`plan_migration`] compares the
//!    current placement's predicted rank imbalance under the estimated
//!    loads against a load-aware rebalanced placement (capped LPT
//!    greedy; for
//!    [`PlacementPolicy::ReplicatedHot`] the replica set is re-targeted
//!    at the *estimated* hot experts). When the current placement is
//!    worse by more than a threshold ratio, it emits a
//!    [`MigrationPlan`] listing the expert weight moves.
//! 3. **Charge** — [`charge_migration`] prices the plan's weight
//!    transfers through the same 3-tier contended EP fabric the
//!    dispatch/combine traffic rides (NVLink within a node, IB NICs
//!    between nodes, the WAN trunk between clusters), so migration is a
//!    modeled latency/bandwidth trade-off, not free: the coordinator
//!    stalls the stage's replicas for the transfer makespan and meters
//!    the moved bytes.
//!
//! The planner is deterministic in its inputs and *stable*: re-planning
//! immediately after adopting a plan proposes nothing (the rebalanced
//! placement is a fixed point), so a threshold ratio >= 1 cannot
//! thrash under stationary load. Migration can only ever be adopted
//! when it strictly lowers predicted imbalance — pinned by property
//! test (`prop_migration_plan_never_worsens_predicted_imbalance`).

use super::placement::{
    rank_imbalance, replicate_hot, A2aPhase, EpSpec, EpTopology, ExpertPlacement,
    PlacementPolicy,
};

/// When the coordinator re-places experts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MigrationPolicy {
    /// Never migrate: placement stays exactly as built (bit-reproduces
    /// the static-placement simulator).
    Off,
    /// Re-place when the current placement's predicted rank imbalance
    /// exceeds the rebalanced placement's by the configured threshold
    /// ratio (checked once per load window).
    Threshold,
}

impl MigrationPolicy {
    /// Parse `off` or `threshold` (the CLI `--migration` grammar).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "off" => Some(Self::Off),
            "threshold" => Some(Self::Threshold),
            _ => None,
        }
    }

    /// Stable lowercase name (reports, CSV columns).
    pub fn name(&self) -> &'static str {
        match self {
            MigrationPolicy::Off => "off",
            MigrationPolicy::Threshold => "threshold",
        }
    }
}

/// Windowed online estimator of per-expert load: an EWMA over the
/// per-expert token counts of each observed routing draw, with gain
/// `2 / (window + 1)` (so `window` draws carry ~2/3 of the weight —
/// the classic N-period EWMA correspondence).
#[derive(Clone, Debug)]
pub struct LoadEstimator {
    /// Estimated tokens per draw for each expert (fractional tokens).
    ewma: Vec<f64>,
    /// Per-observation smoothing gain (dimensionless, in (0, 1]).
    gain: f64,
    /// Routing draws observed so far.
    draws: u64,
}

impl LoadEstimator {
    /// Estimator over `n_experts` experts smoothing over roughly
    /// `window` routing draws (`window >= 1`).
    pub fn new(n_experts: u32, window: u32) -> Self {
        LoadEstimator {
            ewma: vec![0.0; n_experts as usize],
            gain: 2.0 / (window.max(1) as f64 + 1.0),
            draws: 0,
        }
    }

    /// Fold one routing draw's per-expert token loads into the
    /// estimate. The first observation seeds the EWMA directly so early
    /// estimates are not biased toward zero.
    pub fn observe(&mut self, loads: &[u32]) {
        debug_assert_eq!(loads.len(), self.ewma.len());
        if self.draws == 0 {
            for (m, &x) in self.ewma.iter_mut().zip(loads) {
                *m = x as f64;
            }
        } else {
            for (m, &x) in self.ewma.iter_mut().zip(loads) {
                *m += self.gain * (x as f64 - *m);
            }
        }
        self.draws += 1;
    }

    /// Routing draws observed so far.
    pub fn draws(&self) -> u64 {
        self.draws
    }

    /// The current per-expert load estimate (fractional tokens per
    /// routing draw).
    pub fn estimate(&self) -> &[f64] {
        &self.ewma
    }

    /// Fixed-point snapshot of the estimate (1/256-token units),
    /// suitable as planner input or as a `loads_hint` for
    /// [`ExpertPlacement::build`].
    pub fn snapshot(&self) -> Vec<u32> {
        self.ewma.iter().map(|&m| (m * 256.0).round().max(0.0) as u32).collect()
    }
}

/// One expert weight transfer of a [`MigrationPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpertMove {
    /// Expert being copied.
    pub expert: u32,
    /// EP rank the weights are read from (the expert's current home).
    pub from: u32,
    /// EP rank gaining a copy of the weights.
    pub to: u32,
}

/// A planned re-placement: the target placement, the weight moves that
/// realize it, and the predicted imbalance before/after (under the
/// estimated loads the plan was computed from).
#[derive(Clone, Debug)]
pub struct MigrationPlan {
    /// The placement to adopt.
    pub placement: ExpertPlacement,
    /// Expert weight copies required (hosts gained vs. the current
    /// placement; dropping a stale replica is free).
    pub moves: Vec<ExpertMove>,
    /// Predicted max-over-mean rank load of the *current* placement
    /// under the estimated loads (1.0 = perfectly balanced).
    pub pre_imbalance: f64,
    /// Predicted max-over-mean rank load of [`MigrationPlan::placement`]
    /// under the same estimated loads.
    pub post_imbalance: f64,
}

/// Load-aware placement over `topo` for the estimated per-expert loads
/// `est` (any consistent unit; the planner uses
/// [`LoadEstimator::snapshot`]'s 1/256-token fixed point): capped LPT
/// greedy — experts in decreasing load order, each assigned to the
/// least-loaded rank that still has a free expert slot (ties to the
/// lowest rank index, so the result is deterministic). Every rank holds
/// at most `ceil(n_experts / n_ranks)` home experts: ranks have a fixed
/// weight-memory budget, and an uncapped rebalance would pile every
/// near-idle expert onto one rank — bad for HBM *and* for the per-rank
/// GroupedGEMM, whose cost grows with resident active experts. For
/// [`PlacementPolicy::ReplicatedHot`] the `hot` highest-estimated
/// experts are additionally replicated onto one rank of every other
/// cluster, exactly as [`ExpertPlacement::build`] does — this is the
/// *load-aware replication* upgrade: the replica set follows the
/// observed hot set instead of a warmup draw.
pub fn rebalanced_placement(
    policy: PlacementPolicy,
    est: &[u32],
    topo: EpTopology,
) -> ExpertPlacement {
    let n = topo.n_ranks as usize;
    let cap = est.len().div_ceil(n.max(1));
    let mut order: Vec<usize> = (0..est.len()).collect();
    order.sort_by(|&a, &b| est[b].cmp(&est[a]).then(a.cmp(&b)));
    let mut totals = vec![0u64; n];
    let mut counts = vec![0usize; n];
    let mut expert_ranks: Vec<Vec<u32>> = vec![Vec::new(); est.len()];
    for &e in &order {
        let r = (0..n)
            .filter(|&r| counts[r] < cap)
            .min_by_key(|&r| (totals[r], r))
            .expect("cap * n_ranks >= n_experts");
        totals[r] += est[e] as u64;
        counts[r] += 1;
        expert_ranks[e] = vec![r as u32];
    }
    if let PlacementPolicy::ReplicatedHot { hot } = policy {
        let k = (hot as usize).min(est.len());
        replicate_hot(&mut expert_ranks, &order[..k], topo);
    }
    ExpertPlacement { topo, expert_ranks }
}

/// Decide whether to re-place experts. Returns a plan iff the current
/// placement's predicted rank imbalance under `est` exceeds the
/// rebalanced placement's by more than the `threshold` ratio
/// (`threshold >= 1`; e.g. 1.25 = migrate only for a >=25% predicted
/// improvement) *and* at least one expert actually moves. Returns
/// `None` when the estimate is empty/zero, the topology is trivial, or
/// the improvement does not clear the threshold — in particular a
/// single mega-hot expert that no placement can balance never triggers
/// churn (that regime is what hot-expert *replication* is for).
pub fn plan_migration(
    current: &ExpertPlacement,
    policy: PlacementPolicy,
    est: &[u32],
    threshold: f64,
) -> Option<MigrationPlan> {
    let topo = current.topo;
    if topo.n_ranks <= 1
        || est.len() != current.expert_ranks.len()
        || est.iter().all(|&x| x == 0)
    {
        return None;
    }
    let candidate = rebalanced_placement(policy, est, topo);
    let pre = rank_imbalance(&current.rank_totals(est));
    let post = rank_imbalance(&candidate.rank_totals(est));
    if post <= 0.0 || pre <= threshold * post {
        return None;
    }
    let mut moves = Vec::new();
    for (e, hosts) in candidate.expert_ranks.iter().enumerate() {
        let old = &current.expert_ranks[e];
        let from = old[0];
        for &to in hosts {
            if !old.contains(&to) {
                moves.push(ExpertMove { expert: e as u32, from, to });
            }
        }
    }
    if moves.is_empty() {
        return None;
    }
    Some(MigrationPlan {
        placement: candidate,
        moves,
        pre_imbalance: pre,
        post_imbalance: post,
    })
}

/// Price a plan's weight transfers through the EP fabric:
/// `expert_bytes` is the per-expert weight footprint a move must copy
/// (bytes). Because one placement is shared by every resident layer,
/// that is [`crate::model::ModelConfig::expert_weight_bytes`] (one
/// layer) times the stage's layer count — the coordinator scales it.
/// Every move contributes `expert_bytes` from its source to its
/// destination rank; the transfers contend exactly like an all-to-all
/// phase (per-rank NVLink ports / NICs, shared WAN trunks), so
/// cross-cluster re-placement pays the trunk. Returns the phase
/// accounting; `A2aPhase::secs` is the stall the coordinator charges
/// the migrating stage.
pub fn charge_migration(spec: &EpSpec, plan: &MigrationPlan, expert_bytes: f64) -> A2aPhase {
    charge_migration_degraded(spec, plan, expert_bytes, crate::network::LinkHealth::HEALTHY)
}

/// [`charge_migration`] through a degraded cross-cluster trunk (fabric
/// epochs): weight moves launched during a brownout pay the slowed
/// trunk — migrating *away* from a browned-out cluster is itself more
/// expensive, which is the tension the link-fault scenarios probe.
/// Healthy `trunk` is bit-identical to [`charge_migration`].
pub fn charge_migration_degraded(
    spec: &EpSpec,
    plan: &MigrationPlan,
    expert_bytes: f64,
    trunk: crate::network::LinkHealth,
) -> A2aPhase {
    let n = spec.n_ranks() as usize;
    let mut matrix = vec![0.0f64; n * n];
    for m in &plan.moves {
        matrix[m.from as usize * n + m.to as usize] += expert_bytes;
    }
    spec.a2a_time_degraded(trunk, &matrix)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hardware::LinkSpec;

    #[test]
    fn estimator_tracks_and_adapts() {
        let mut est = LoadEstimator::new(4, 8);
        assert_eq!(est.draws(), 0);
        est.observe(&[8, 0, 0, 0]);
        // first draw seeds directly
        assert_eq!(est.estimate().to_vec(), vec![8.0, 0.0, 0.0, 0.0]);
        for _ in 0..64 {
            est.observe(&[0, 8, 0, 0]);
        }
        // after many draws the estimate follows the new hot expert
        assert!(est.estimate()[1] > 7.0, "{:?}", est.estimate());
        assert!(est.estimate()[0] < 1.0, "{:?}", est.estimate());
        assert_eq!(est.draws(), 65);
        let snap = est.snapshot();
        assert_eq!(snap.len(), 4);
        assert!(snap[1] > snap[0]);
    }

    #[test]
    fn rebalance_beats_contiguous_on_separable_skew() {
        // two hot experts co-resident under contiguous placement: LPT
        // must separate them
        let topo = EpTopology::new(4, 1);
        let est = [100u32, 90, 1, 1, 1, 1, 1, 1];
        let contiguous =
            ExpertPlacement::build(PlacementPolicy::Contiguous, 8, topo, None);
        let cand = rebalanced_placement(PlacementPolicy::Contiguous, &est, topo);
        let pre = rank_imbalance(&contiguous.rank_totals(&est));
        let post = rank_imbalance(&cand.rank_totals(&est));
        assert!(post < pre, "LPT {post} must beat contiguous {pre}");
        // the two hot experts end up on different ranks
        assert_ne!(cand.expert_ranks[0], cand.expert_ranks[1]);
        // every expert is placed on a valid rank, and no rank exceeds
        // its expert-slot budget of ceil(8/4) = 2
        let mut counts = [0u32; 4];
        for hosts in &cand.expert_ranks {
            assert_eq!(hosts.len(), 1);
            assert!(hosts[0] < 4);
            counts[hosts[0] as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c <= 2), "slot cap violated: {counts:?}");
    }

    #[test]
    fn rebalance_replicates_estimated_hot_set() {
        let topo = EpTopology::new(4, 2);
        let mut est = [1u32; 8];
        est[5] = 200; // estimated-hot expert, not the lowest index
        let cand = rebalanced_placement(
            PlacementPolicy::ReplicatedHot { hot: 1 },
            &est,
            topo,
        );
        assert_eq!(cand.expert_ranks[5].len(), 2, "hot expert spans both clusters");
        let clusters: Vec<u32> =
            cand.expert_ranks[5].iter().map(|&r| topo.cluster_of(r)).collect();
        assert!(clusters.contains(&0) && clusters.contains(&1));
        assert!(cand.expert_ranks.iter().enumerate().all(|(e, h)| e == 5 || h.len() == 1));
    }

    #[test]
    fn plan_triggers_and_is_stable() {
        let topo = EpTopology::new(4, 1);
        let current = ExpertPlacement::build(PlacementPolicy::Contiguous, 8, topo, None);
        let est = [100u32, 90, 1, 1, 1, 1, 1, 1];
        let plan = plan_migration(&current, PlacementPolicy::Contiguous, &est, 1.1)
            .expect("separable skew must trigger");
        assert!(plan.post_imbalance < plan.pre_imbalance);
        assert!(!plan.moves.is_empty());
        // every move is a real move onto the planned host set
        for m in &plan.moves {
            assert_ne!(m.from, m.to);
            assert!(plan.placement.expert_ranks[m.expert as usize].contains(&m.to));
            assert_eq!(current.expert_ranks[m.expert as usize][0], m.from);
        }
        // stability: re-planning right after adoption proposes nothing
        assert!(
            plan_migration(&plan.placement, PlacementPolicy::Contiguous, &est, 1.1)
                .is_none(),
            "adopted placement must be a fixed point"
        );
    }

    #[test]
    fn plan_declines_unfixable_and_degenerate_cases() {
        let topo = EpTopology::new(4, 1);
        let current = ExpertPlacement::build(PlacementPolicy::Contiguous, 8, topo, None);
        // one mega-hot expert: no placement helps, so no churn
        let mega = [1000u32, 1, 1, 1, 1, 1, 1, 1];
        assert!(plan_migration(&current, PlacementPolicy::Contiguous, &mega, 1.1).is_none());
        // zero estimate
        assert!(plan_migration(&current, PlacementPolicy::Contiguous, &[0; 8], 1.1).is_none());
        // length mismatch
        assert!(plan_migration(&current, PlacementPolicy::Contiguous, &[1; 4], 1.1).is_none());
        // single rank
        let one = ExpertPlacement::build(
            PlacementPolicy::Contiguous,
            8,
            EpTopology::new(1, 1),
            None,
        );
        assert!(plan_migration(&one, PlacementPolicy::Contiguous, &[5; 8], 1.1).is_none());
    }

    #[test]
    fn migration_charge_pays_the_fabric() {
        let topo = EpTopology::new(4, 2);
        let current = ExpertPlacement::build(PlacementPolicy::Contiguous, 8, topo, None);
        // hot experts 0 and 1 share rank 0 (cluster 0): rebalancing
        // pushes one of them across the cluster boundary
        let est = [100u32, 90, 1, 1, 1, 1, 1, 1];
        let plan = plan_migration(&current, PlacementPolicy::Contiguous, &est, 1.1)
            .expect("must trigger");
        let spec = EpSpec::flat(
            current,
            LinkSpec::nvlink_a800(),
            LinkSpec::cross_cluster(),
        );
        let phase = charge_migration(&spec, &plan, 1e6);
        assert!(phase.secs > 0.0, "weight moves take time");
        assert!(
            (phase.total_bytes - plan.moves.len() as f64 * 1e6).abs() < 1e-6,
            "every move is metered"
        );
        assert_eq!(phase.local_bytes, 0.0, "a move is never rank-local");
        let crosses = plan.moves.iter().any(|m| {
            spec.placement.topo.cluster_of(m.from) != spec.placement.topo.cluster_of(m.to)
        });
        assert_eq!(crosses, phase.cross_bytes > 0.0);
    }

    #[test]
    fn migration_policy_parse() {
        assert_eq!(MigrationPolicy::parse("off"), Some(MigrationPolicy::Off));
        assert_eq!(MigrationPolicy::parse("threshold"), Some(MigrationPolicy::Threshold));
        assert_eq!(MigrationPolicy::parse("sometimes"), None);
        assert_eq!(MigrationPolicy::Off.name(), "off");
        assert_eq!(MigrationPolicy::Threshold.name(), "threshold");
    }
}
