//! MoE routing simulation: token-to-expert assignment and EP sharding.
//!
//! The paper models the MoE layer as a data-dependent micro-workflow
//! (§3.3): gate GEMM -> pluggable routing -> heterogeneous per-expert
//! GroupedGEMM -> synchronization barrier (`max` over expert tasks).
//! This module provides the pluggable routing policies that generate the
//! token-to-expert assignment map, plus load-balance metrics.

pub mod placement;

pub use placement::{
    rank_imbalance, A2aPhase, EpNetwork, EpSpec, EpTopology, ExpertPlacement, PlacementPolicy,
};

use crate::core::Pcg64;

/// How tokens pick experts — the pluggable routing module of §3.3.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoutingPolicy {
    /// Perfectly balanced round-robin (the idealized upper bound;
    /// what balance-oblivious simulators implicitly assume).
    Balanced,
    /// Uniform random choice per token (multinomial load noise).
    UniformRandom,
    /// Skewed popularity: expert weights drawn once from a symmetric
    /// Dirichlet with concentration `alpha` — small alpha = hot experts.
    Skewed { alpha: f64 },
}

impl RoutingPolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "balanced" => Some(Self::Balanced),
            "uniform" => Some(Self::UniformRandom),
            _ => s.strip_prefix("skewed:").and_then(|a| {
                a.parse::<f64>().ok().map(|alpha| Self::Skewed { alpha })
            }),
        }
    }
}

/// Stable expert-popularity weights for [`RoutingPolicy::Skewed`]:
/// drawn from a dedicated deterministic stream keyed on `(alpha, n)`,
/// so the *same* experts stay hot across layers, steps, and runs — the
/// semi-stable popularity real MoE serving exhibits, and the property
/// hot-expert replication placement relies on. Token sampling still
/// flows through the caller's rng.
pub fn expert_popularity(alpha: f64, n_experts: u32) -> Vec<f64> {
    let mut wrng = Pcg64::new(0xE5_9EED ^ alpha.to_bits() ^ ((n_experts as u64) << 40));
    wrng.dirichlet_sym(alpha, n_experts as usize)
}

/// Generate the token-to-expert assignment map: per-expert token counts
/// for `tokens` tokens each selecting `top_k` distinct experts.
pub fn assign_tokens(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    rng: &mut Pcg64,
) -> Vec<u32> {
    let e = n_experts as usize;
    let k = (top_k as usize).min(e);
    let mut loads = vec![0u32; e];
    match policy {
        RoutingPolicy::Balanced => {
            let total = tokens as u64 * k as u64;
            let base = (total / e as u64) as u32;
            let rem = (total % e as u64) as usize;
            for (i, l) in loads.iter_mut().enumerate() {
                *l = base + u32::from(i < rem);
            }
        }
        RoutingPolicy::UniformRandom | RoutingPolicy::Skewed { .. } => {
            let weights: Vec<f64> = match policy {
                RoutingPolicy::Skewed { alpha } => expert_popularity(alpha, n_experts),
                _ => vec![1.0 / e as f64; e],
            };
            let mut w = weights.clone();
            for _ in 0..tokens {
                // top-k without replacement per token
                w.copy_from_slice(&weights);
                for _ in 0..k {
                    let idx = rng.weighted_index(&w);
                    loads[idx] += 1;
                    w[idx] = 0.0;
                }
            }
        }
    }
    loads
}

/// Load-balance metrics over an assignment map (predictor features and
/// reporting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BalanceMetrics {
    /// max load / mean load (1.0 = perfect).
    pub imbalance: f64,
    /// Coefficient of variation of loads.
    pub cv: f64,
    /// Fraction of experts with nonzero load.
    pub active_frac: f64,
}

pub fn balance_metrics(loads: &[u32]) -> BalanceMetrics {
    let e = loads.len() as f64;
    if e == 0.0 {
        return BalanceMetrics { imbalance: 0.0, cv: 0.0, active_frac: 0.0 };
    }
    let total: f64 = loads.iter().map(|&x| x as f64).sum();
    let mean = total / e;
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let var: f64 =
        loads.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>() / e;
    BalanceMetrics {
        imbalance: if mean > 0.0 { max / mean } else { 0.0 },
        cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        active_frac: loads.iter().filter(|&&x| x > 0).count() as f64 / e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_is_exactly_balanced() {
        let mut rng = Pcg64::new(1);
        let loads = assign_tokens(RoutingPolicy::Balanced, 100, 8, 2, &mut rng);
        assert_eq!(loads.iter().sum::<u32>(), 200);
        assert_eq!(loads.iter().max(), loads.iter().min());
        let m = balance_metrics(&loads);
        assert!((m.imbalance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn token_conservation() {
        let mut rng = Pcg64::new(2);
        for policy in [
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.1 },
            RoutingPolicy::Balanced,
        ] {
            let loads = assign_tokens(policy, 333, 16, 4, &mut rng);
            assert_eq!(loads.iter().sum::<u32>(), 333 * 4, "{policy:?}");
        }
    }

    #[test]
    fn top_k_capped_by_expert_count() {
        let mut rng = Pcg64::new(3);
        let loads = assign_tokens(RoutingPolicy::UniformRandom, 10, 4, 8, &mut rng);
        assert_eq!(loads.iter().sum::<u32>(), 40); // k clamped to 4
        // without replacement: no expert can exceed token count
        assert!(loads.iter().all(|&l| l <= 10));
    }

    #[test]
    fn skew_increases_imbalance() {
        let mut rng = Pcg64::new(4);
        let uni = assign_tokens(RoutingPolicy::UniformRandom, 4096, 16, 2, &mut rng);
        let skew = assign_tokens(RoutingPolicy::Skewed { alpha: 0.05 }, 4096, 16, 2, &mut rng);
        assert!(balance_metrics(&skew).imbalance > balance_metrics(&uni).imbalance);
    }

    #[test]
    fn routing_policy_parse() {
        assert_eq!(RoutingPolicy::parse("balanced"), Some(RoutingPolicy::Balanced));
        assert_eq!(
            RoutingPolicy::parse("skewed:0.25"),
            Some(RoutingPolicy::Skewed { alpha: 0.25 })
        );
        assert_eq!(RoutingPolicy::parse("nope"), None);
    }

    #[test]
    fn skewed_popularity_is_stable() {
        // hot experts persist across draws (and rng streams): the
        // argmax of the loads matches the stable popularity argmax
        let w = expert_popularity(0.05, 16);
        assert_eq!(w.len(), 16);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let w_max = w.iter().cloned().fold(0.0, f64::max);
        for seed in [1u64, 2, 3] {
            let mut rng = Pcg64::new(seed);
            let loads =
                assign_tokens(RoutingPolicy::Skewed { alpha: 0.05 }, 4096, 16, 2, &mut rng);
            let loads_hot =
                loads.iter().enumerate().max_by_key(|&(_, &l)| l).unwrap().0;
            // the busiest expert must be one of the stably-popular ones
            // (tie-tolerant: within 2x of the top weight)
            assert!(
                w[loads_hot] >= 0.5 * w_max,
                "seed {seed}: expert {loads_hot} won with weight {} vs max {w_max}",
                w[loads_hot]
            );
        }
    }

    #[test]
    fn metrics_empty_and_zero() {
        let m = balance_metrics(&[]);
        assert_eq!(m.active_frac, 0.0);
        let m = balance_metrics(&[0, 0]);
        assert_eq!(m.imbalance, 0.0);
    }
}
