//! MoE routing simulation: token-to-expert assignment and EP sharding.
//!
//! The paper models the MoE layer as a data-dependent micro-workflow
//! (§3.3): gate GEMM -> pluggable routing -> heterogeneous per-expert
//! GroupedGEMM -> synchronization barrier (`max` over expert tasks).
//! This module provides the pluggable routing policies that generate the
//! token-to-expert assignment map, plus load-balance metrics, an online
//! (windowed EWMA) expert-load estimator, and the dynamic expert
//! [`migration`] planner that re-places experts when popularity drifts.
//!
//! Routing draws are the hottest loop in the whole simulator (one draw
//! per iteration, or per `(layer, micro-batch)` cell on the AF path),
//! so the assignment sampler comes in two production fidelities
//! ([`RoutingFidelity`]) — O(1)-per-pick token sampling through a
//! cached Walker alias table, and O(E·k) aggregate count sampling for
//! huge-batch scale runs — with the original O(tokens·k·E) linear-scan
//! sampler preserved as the in-tree distribution oracle
//! ([`assign_tokens_oracle`]).
#![warn(missing_docs)]

pub mod migration;
pub mod placement;

pub use migration::{
    charge_migration, charge_migration_degraded, plan_migration, rebalanced_placement,
    ExpertMove, LoadEstimator,
    MigrationPlan, MigrationPolicy,
};
pub use placement::{
    rank_imbalance, A2aPhase, EpFabric, EpNetwork, EpSpec, EpTopology, ExpertPlacement,
    PlacementPolicy,
};

use crate::core::Pcg64;

/// How tokens pick experts — the pluggable routing module of §3.3.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoutingPolicy {
    /// Perfectly balanced round-robin (the idealized upper bound;
    /// what balance-oblivious simulators implicitly assume).
    Balanced,
    /// Uniform random choice per token (multinomial load noise).
    UniformRandom,
    /// Skewed popularity: expert weights drawn once from a symmetric
    /// Dirichlet with concentration `alpha` — small alpha = hot experts.
    Skewed {
        /// Dirichlet concentration (dimensionless; smaller = hotter).
        alpha: f64,
    },
    /// Skewed popularity whose hot set *drifts*: every `period` routing
    /// draws the popularity vector is redrawn from a fresh deterministic
    /// stream (epoch 0 is identical to [`RoutingPolicy::Skewed`]). This
    /// is the regime dynamic expert migration exists for: a placement
    /// tuned at construction goes stale as the hot experts move.
    Drifting {
        /// Dirichlet concentration per epoch (dimensionless).
        alpha: f64,
        /// Routing draws per popularity epoch (draws, not seconds; on
        /// the AF path one draw is one `(layer, micro-batch)` cell).
        period: u64,
    },
}

impl RoutingPolicy {
    /// Parse `balanced`, `uniform`, `skewed:ALPHA`, or
    /// `drift:ALPHA:PERIOD` (the CLI `--routing` grammar).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "balanced" => Some(Self::Balanced),
            "uniform" => Some(Self::UniformRandom),
            _ => {
                if let Some(a) = s.strip_prefix("skewed:") {
                    return a.parse::<f64>().ok().map(|alpha| Self::Skewed { alpha });
                }
                let spec = s.strip_prefix("drift:")?;
                let (a, p) = spec.split_once(':')?;
                match (a.parse::<f64>(), p.parse::<u64>()) {
                    (Ok(alpha), Ok(period)) if period > 0 => Some(Self::Drifting { alpha, period }),
                    _ => None,
                }
            }
        }
    }
}

/// Stable expert-popularity weights for [`RoutingPolicy::Skewed`]:
/// drawn from a dedicated deterministic stream keyed on `(alpha, n)`,
/// so the *same* experts stay hot across layers, steps, and runs — the
/// semi-stable popularity real MoE serving exhibits, and the property
/// hot-expert replication placement relies on. Token sampling still
/// flows through the caller's rng.
pub fn expert_popularity(alpha: f64, n_experts: u32) -> Vec<f64> {
    expert_popularity_phase(alpha, n_experts, 0)
}

/// Popularity weights of one drift epoch ([`RoutingPolicy::Drifting`]):
/// epoch 0 reproduces [`expert_popularity`] exactly; every later epoch
/// draws an independent Dirichlet from its own deterministic stream, so
/// the hot set jumps at epoch boundaries while staying reproducible
/// across runs. Returns probabilities summing to 1.
pub fn expert_popularity_phase(alpha: f64, n_experts: u32, epoch: u64) -> Vec<f64> {
    let seed = 0xE5_9EED
        ^ alpha.to_bits()
        ^ ((n_experts as u64) << 40)
        ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut wrng = Pcg64::new(seed);
    wrng.dirichlet_sym(alpha, n_experts as usize)
}

/// Per-expert token capacity for a capacity factor `cf`
/// (GShard/MegaScale style): `ceil(cf * tokens * top_k / n_experts)`,
/// floored at one slot so a positive factor never starves an expert.
pub fn expert_capacity(tokens: u32, n_experts: u32, top_k: u32, cf: f64) -> u32 {
    let k = top_k.min(n_experts).max(1);
    let fair_share = tokens as f64 * k as f64 / n_experts.max(1) as f64;
    (fair_share * cf).ceil().max(1.0) as u32
}

/// Generate the token-to-expert assignment map: per-expert token counts
/// for `tokens` tokens each selecting `top_k` distinct experts.
pub fn assign_tokens(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    rng: &mut Pcg64,
) -> Vec<u32> {
    assign_tokens_capped(policy, tokens, n_experts, top_k, None, rng).0
}

/// [`assign_tokens`] with an optional per-expert capacity cap: a token
/// routed to a full expert is *dropped* (the GShard capacity-factor
/// policy) rather than rerouted. Returns `(per-expert loads, dropped
/// token-slots)`. The RNG stream is identical to the uncapped path, so
/// `capacity = None` reproduces [`assign_tokens`] bit-for-bit.
/// Equivalent to [`assign_tokens_at`] at draw index 0.
pub fn assign_tokens_capped(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    assign_tokens_at(policy, tokens, n_experts, top_k, capacity, 0, rng)
}

/// [`assign_tokens_capped`] at a known routing-draw index `draw` (a
/// running count of assignment draws, maintained by the caller). Only
/// [`RoutingPolicy::Drifting`] reads it — the popularity epoch is
/// `draw / period` — so for every other policy any `draw` value is
/// bit-identical to [`assign_tokens_capped`] (pinned by property test).
pub fn assign_tokens_at(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    draw: u64,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    let mut cache = PopularityCache::default();
    assign_tokens_cached(policy, tokens, n_experts, top_k, capacity, draw, &mut cache, rng)
}

/// How the token-to-expert assignment of one routing draw is sampled.
///
/// Both fidelities share the same popularity model and epoch clock;
/// they differ in the *sampling process* and its cost:
///
/// * [`RoutingFidelity::Token`] — every token draws its own top-k
///   expert set (O(1) per pick via the cached Walker alias table), so
///   per-draw load variance matches real per-token routing. This is
///   the default and is distributionally identical to the in-tree
///   oracle sampler [`assign_tokens_oracle`].
/// * [`RoutingFidelity::Aggregate`] — the per-expert token *counts*
///   are sampled directly: `k` binomial-split multinomial rounds of
///   `tokens` slots each (O(E·k) total, independent of the batch
///   size), with each round's expert mass depleted by the fraction of
///   tokens that already picked it — the within-token distinctness
///   constraint at the population level. For huge-batch scale runs
///   this removes the per-token loop entirely; per-expert shares track
///   the token sampler to a few percent worst-case (pinned with
///   tolerances by `rust/tests/routing_dist.rs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RoutingFidelity {
    /// Per-token top-k sampling through the alias table (default).
    #[default]
    Token,
    /// O(E·k) direct per-expert count sampling (huge-batch scale mode).
    Aggregate,
}

impl RoutingFidelity {
    /// Parse `token` or `aggregate` (the CLI `--routing-fidelity`
    /// grammar).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "token" => Some(Self::Token),
            "aggregate" => Some(Self::Aggregate),
            _ => None,
        }
    }

    /// Stable lowercase name (reports, sweep tables).
    pub fn name(&self) -> &'static str {
        match self {
            RoutingFidelity::Token => "token",
            RoutingFidelity::Aggregate => "aggregate",
        }
    }
}

/// Per-`(policy, n_experts, epoch)` sampling state for the hot routing
/// path: the popularity vector, its Walker alias table (O(1) weighted
/// picks), and reusable scratch buffers, rebuilt only at epoch
/// boundaries. A caller pricing many draws (the cost model — one draw
/// per `(layer, micro-batch)` cell on the AF path) pays the O(E)
/// Dirichlet + table build once per epoch and nothing per draw.
#[derive(Clone, Debug, Default)]
pub struct PopularityCache {
    key: Option<(RoutingPolicy, u32, u64)>,
    weights: Vec<f64>,
    /// Walker alias table over `weights`: accept `i` with probability
    /// `prob[i]`, else take `alias[i]`.
    prob: Vec<f64>,
    alias: Vec<u32>,
    /// Within-token distinctness scratch (the current token's picks).
    picked: Vec<u32>,
    /// Residual-weight scratch for the exact rejection fallback.
    resid: Vec<f64>,
    /// Tokens that already picked each expert (aggregate fidelity).
    agg: Vec<u64>,
    /// Current aggregate round's per-expert counts.
    agg_round: Vec<u64>,
    /// Current aggregate round's usable-mass weights.
    agg_v: Vec<f64>,
}

/// Rejection attempts per pick before falling back to the exact O(E)
/// renormalized draw. Failing all tries has probability `q^32` where
/// `q` is the already-picked mass, so the fallback only engages when
/// one expert holds nearly all popularity.
const ALIAS_REJECT_TRIES: u32 = 32;

impl PopularityCache {
    /// (Re)build the cached weights + alias table for
    /// `(policy, n_experts, epoch)` if the key changed. Scratch buffers
    /// are pre-sized here so steady-state draws never allocate.
    fn ensure(&mut self, policy: RoutingPolicy, n_experts: u32, epoch: u64) {
        if self.key == Some((policy, n_experts, epoch)) {
            return;
        }
        self.weights = match policy {
            RoutingPolicy::Skewed { alpha } => expert_popularity(alpha, n_experts),
            RoutingPolicy::Drifting { alpha, .. } => {
                expert_popularity_phase(alpha, n_experts, epoch)
            }
            _ => vec![1.0 / n_experts.max(1) as f64; n_experts as usize],
        };
        self.build_alias();
        let e = self.weights.len();
        self.resid.clear();
        self.resid.resize(e, 0.0);
        self.agg.clear();
        self.agg.resize(e, 0);
        self.agg_round.clear();
        self.agg_round.resize(e, 0);
        self.agg_v.clear();
        self.agg_v.resize(e, 0.0);
        self.key = Some((policy, n_experts, epoch));
    }

    /// Vose's O(E) alias-table construction: every entry gets an
    /// acceptance probability and (for the rejected mass) an alias
    /// partner, so one uniform deviate samples the full weighted
    /// distribution.
    fn build_alias(&mut self) {
        let n = self.weights.len();
        self.prob.clear();
        self.prob.resize(n, 1.0);
        self.alias.clear();
        self.alias.extend(0..n as u32);
        let total: f64 = self.weights.iter().sum();
        if n == 0 || total <= 0.0 {
            return;
        }
        // epoch-boundary build: transient worklists may allocate (the
        // per-draw path never reaches here on a warm key)
        let mut small: Vec<u32> = Vec::with_capacity(n);
        let mut large: Vec<u32> = Vec::with_capacity(n);
        for (i, &w) in self.weights.iter().enumerate() {
            let scaled = w * n as f64 / total;
            self.prob[i] = scaled;
            if scaled < 1.0 {
                small.push(i as u32);
            } else {
                large.push(i as u32);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            self.alias[s as usize] = l;
            // the large entry donates the small one's deficit
            self.prob[l as usize] += self.prob[s as usize] - 1.0;
            if self.prob[l as usize] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // leftovers are 1.0 up to rounding: self-aliased full columns
        for i in large.into_iter().chain(small) {
            self.prob[i as usize] = 1.0;
            self.alias[i as usize] = i;
        }
    }

    /// One O(1) weighted pick from the alias table.
    #[inline]
    fn alias_draw(&self, rng: &mut Pcg64) -> usize {
        let n = self.prob.len();
        let u = rng.next_f64() * n as f64;
        let i = (u as usize).min(n - 1);
        if u - i as f64 < self.prob[i] {
            i
        } else {
            self.alias[i] as usize
        }
    }

    /// Exact conditional pick for the rare rejection-fallback case:
    /// renormalize the weights with the current token's picks removed
    /// (the same distribution the rejection loop targets).
    fn fallback_draw(&mut self, rng: &mut Pcg64) -> usize {
        self.resid.copy_from_slice(&self.weights);
        for &p in &self.picked {
            self.resid[p as usize] = 0.0;
        }
        if self.resid.iter().sum::<f64>() <= 0.0 {
            // all residual mass zero (degenerate weights): fall back to
            // uniform over the unpicked experts
            for (i, r) in self.resid.iter_mut().enumerate() {
                *r = if self.picked.contains(&(i as u32)) { 0.0 } else { 1.0 };
            }
        }
        rng.weighted_index(&self.resid)
    }

    /// Token-fidelity draw: every token picks `k` *distinct* experts,
    /// each pick O(1) through the alias table with rejection on
    /// within-token repeats (expected tries `1/(1-q)` for picked mass
    /// `q`; k << E keeps q small). Distributionally identical to
    /// [`assign_tokens_oracle`] — rejection targets exactly the
    /// renormalized without-replacement conditional — but consumes the
    /// RNG stream differently.
    fn sample_token_topk(
        &mut self,
        tokens: u32,
        k: usize,
        cap: u32,
        rng: &mut Pcg64,
        loads: &mut [u32],
    ) -> u64 {
        let mut dropped = 0u64;
        for _ in 0..tokens {
            self.picked.clear();
            for _ in 0..k {
                let mut idx = usize::MAX;
                for _ in 0..ALIAS_REJECT_TRIES {
                    let cand = self.alias_draw(rng);
                    if !self.picked.contains(&(cand as u32)) {
                        idx = cand;
                        break;
                    }
                }
                if idx == usize::MAX {
                    idx = self.fallback_draw(rng);
                }
                self.picked.push(idx as u32);
                if loads[idx] < cap {
                    loads[idx] += 1;
                } else {
                    dropped += 1;
                }
            }
        }
        dropped
    }

    /// Aggregate-fidelity draw: sample the per-expert slot counts
    /// directly, one binomial-split multinomial round of `tokens` slots
    /// per top-k pick (O(E·k) total, independent of the batch size).
    /// Each round weights expert `i` by `w_i * avail_i / tokens` where
    /// `avail_i` counts the tokens that have not picked `i` yet — the
    /// population-level form of top-k *without replacement* (an expert
    /// a token already took is unavailable to it), which keeps the
    /// per-expert shares within a few percent of the exact token
    /// sampler even under heavy skew. Counts are clamped at `avail_i`
    /// (no expert exceeds one slot per token) with clamped-off slots
    /// re-split over experts with headroom, then the capacity cap is
    /// applied as drops. Conserves slots exactly:
    /// `sum(loads) + dropped == tokens * k`.
    fn sample_aggregate(
        &mut self,
        tokens: u32,
        k: usize,
        cap: u32,
        rng: &mut Pcg64,
        loads: &mut [u32],
    ) -> u64 {
        let n = self.weights.len();
        let t = tokens as u64;
        for a in self.agg.iter_mut() {
            *a = 0;
        }
        for _round in 0..k {
            for i in 0..n {
                self.agg_v[i] = self.weights[i] * (t - self.agg[i]) as f64;
            }
            let mut remaining = t;
            let mut vsum: f64 = self.agg_v.iter().sum();
            for c in self.agg_round.iter_mut() {
                *c = 0;
            }
            for i in 0..n {
                let avail = t - self.agg[i];
                let c = if i + 1 == n {
                    remaining.min(avail)
                } else if remaining == 0 || vsum <= 0.0 {
                    0
                } else {
                    rng.binomial(remaining, (self.agg_v[i] / vsum).clamp(0.0, 1.0))
                        .min(remaining)
                        .min(avail)
                };
                self.agg_round[i] = c;
                remaining -= c;
                vsum -= self.agg_v[i];
            }
            // slots clamped off a full expert: re-split over experts
            // with headroom (every pass fills at least one candidate,
            // so this terminates in <= E passes; headroom always
            // suffices because round r leaves (E - r) * tokens slots)
            while remaining > 0 {
                let mut vs = 0.0;
                let mut last = usize::MAX;
                for i in 0..n {
                    if self.agg[i] + self.agg_round[i] < t {
                        vs += self.agg_v[i];
                        last = i;
                    }
                }
                if last == usize::MAX {
                    break;
                }
                if vs <= 0.0 {
                    // zero-mass leftovers: spread deterministically
                    for i in 0..n {
                        if remaining == 0 {
                            break;
                        }
                        let room = t - self.agg[i] - self.agg_round[i];
                        let take = room.min(remaining);
                        self.agg_round[i] += take;
                        remaining -= take;
                    }
                    break;
                }
                for i in 0..n {
                    let used = self.agg[i] + self.agg_round[i];
                    if used >= t {
                        continue;
                    }
                    let avail = t - used;
                    let c = if i == last {
                        remaining.min(avail)
                    } else if remaining == 0 || vs <= 0.0 {
                        0
                    } else {
                        rng.binomial(remaining, (self.agg_v[i] / vs).clamp(0.0, 1.0))
                            .min(remaining)
                            .min(avail)
                    };
                    self.agg_round[i] += c;
                    remaining -= c;
                    vs -= self.agg_v[i];
                }
            }
            for i in 0..n {
                self.agg[i] += self.agg_round[i];
            }
        }
        let mut dropped = 0u64;
        for (l, &c) in loads.iter_mut().zip(self.agg.iter()) {
            let kept = c.min(cap as u64);
            dropped += c - kept;
            *l = kept as u32;
        }
        dropped
    }
}

/// [`assign_tokens_at`] with a caller-held [`PopularityCache`] — the
/// reusable-state form for hot pricing paths, at token fidelity.
/// Bit-identical to the uncached call for every policy (the cache only
/// memoizes deterministic per-epoch state).
#[allow(clippy::too_many_arguments)]
pub fn assign_tokens_cached(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    draw: u64,
    cache: &mut PopularityCache,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    let mut loads = Vec::new();
    let dropped = assign_tokens_into(
        policy,
        RoutingFidelity::Token,
        tokens,
        n_experts,
        top_k,
        capacity,
        draw,
        cache,
        rng,
        &mut loads,
    );
    (loads, dropped)
}

/// The allocation-free hot-path entry point: write the per-expert loads
/// of one routing draw into `out` (cleared and resized; capacity
/// reused) under the chosen [`RoutingFidelity`], returning the dropped
/// token-slots. All the `assign_tokens*` convenience wrappers lower
/// onto this.
#[allow(clippy::too_many_arguments)]
pub fn assign_tokens_into(
    policy: RoutingPolicy,
    fidelity: RoutingFidelity,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    draw: u64,
    cache: &mut PopularityCache,
    rng: &mut Pcg64,
    out: &mut Vec<u32>,
) -> u64 {
    let e = n_experts as usize;
    let k = (top_k as usize).min(e);
    let cap = capacity.unwrap_or(u32::MAX);
    out.clear();
    out.resize(e, 0);
    if e == 0 {
        return 0;
    }
    match policy {
        RoutingPolicy::Balanced => {
            let total = tokens as u64 * k as u64;
            let base = (total / e as u64) as u32;
            let rem = (total % e as u64) as usize;
            let mut dropped = 0u64;
            for (i, l) in out.iter_mut().enumerate() {
                let want = base + u32::from(i < rem);
                *l = want.min(cap);
                dropped += (want - *l) as u64;
            }
            dropped
        }
        RoutingPolicy::UniformRandom
        | RoutingPolicy::Skewed { .. }
        | RoutingPolicy::Drifting { .. } => {
            let epoch = match policy {
                RoutingPolicy::Drifting { period, .. } => draw / period.max(1),
                _ => 0,
            };
            cache.ensure(policy, n_experts, epoch);
            match fidelity {
                RoutingFidelity::Token => cache.sample_token_topk(tokens, k, cap, rng, out),
                RoutingFidelity::Aggregate => cache.sample_aggregate(tokens, k, cap, rng, out),
            }
        }
    }
}

/// The frozen linear-scan reference sampler: per token, `k` picks
/// without replacement via a full-vector weighted scan with the picked
/// entries zeroed — O(tokens * k * E) per draw and one fresh weight
/// copy per token. This was the production sampler before the alias
/// table; it is kept (unchanged RNG consumption) as the in-tree test
/// oracle that `rust/tests/routing_dist.rs` checks both production
/// samplers against. Not for hot paths.
pub fn assign_tokens_oracle(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    draw: u64,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    let e = n_experts as usize;
    let k = (top_k as usize).min(e);
    let cap = capacity.unwrap_or(u32::MAX);
    let mut loads = vec![0u32; e];
    let mut dropped = 0u64;
    match policy {
        RoutingPolicy::Balanced => {
            let total = tokens as u64 * k as u64;
            let base = (total / e.max(1) as u64) as u32;
            let rem = (total % e.max(1) as u64) as usize;
            for (i, l) in loads.iter_mut().enumerate() {
                let want = base + u32::from(i < rem);
                *l = want.min(cap);
                dropped += (want - *l) as u64;
            }
        }
        RoutingPolicy::UniformRandom
        | RoutingPolicy::Skewed { .. }
        | RoutingPolicy::Drifting { .. } => {
            let epoch = match policy {
                RoutingPolicy::Drifting { period, .. } => draw / period.max(1),
                _ => 0,
            };
            let weights = match policy {
                RoutingPolicy::Skewed { alpha } => expert_popularity(alpha, n_experts),
                RoutingPolicy::Drifting { alpha, .. } => {
                    expert_popularity_phase(alpha, n_experts, epoch)
                }
                _ => vec![1.0 / n_experts.max(1) as f64; e],
            };
            let mut w = weights.clone();
            for _ in 0..tokens {
                // top-k without replacement per token
                w.copy_from_slice(&weights);
                for _ in 0..k {
                    let idx = rng.weighted_index(&w);
                    if loads[idx] < cap {
                        loads[idx] += 1;
                    } else {
                        dropped += 1;
                    }
                    w[idx] = 0.0;
                }
            }
        }
    }
    (loads, dropped)
}

/// Load-balance metrics over an assignment map (predictor features and
/// reporting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BalanceMetrics {
    /// max load / mean load (1.0 = perfect).
    pub imbalance: f64,
    /// Coefficient of variation of loads.
    pub cv: f64,
    /// Fraction of experts with nonzero load.
    pub active_frac: f64,
}

/// Compute [`BalanceMetrics`] over per-expert token loads.
pub fn balance_metrics(loads: &[u32]) -> BalanceMetrics {
    let e = loads.len() as f64;
    if e == 0.0 {
        return BalanceMetrics { imbalance: 0.0, cv: 0.0, active_frac: 0.0 };
    }
    let total: f64 = loads.iter().map(|&x| x as f64).sum();
    let mean = total / e;
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let var: f64 =
        loads.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>() / e;
    BalanceMetrics {
        imbalance: if mean > 0.0 { max / mean } else { 0.0 },
        cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        active_frac: loads.iter().filter(|&&x| x > 0).count() as f64 / e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_is_exactly_balanced() {
        let mut rng = Pcg64::new(1);
        let loads = assign_tokens(RoutingPolicy::Balanced, 100, 8, 2, &mut rng);
        assert_eq!(loads.iter().sum::<u32>(), 200);
        assert_eq!(loads.iter().max(), loads.iter().min());
        let m = balance_metrics(&loads);
        assert!((m.imbalance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn token_conservation() {
        let mut rng = Pcg64::new(2);
        for policy in [
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.1 },
            RoutingPolicy::Balanced,
        ] {
            let loads = assign_tokens(policy, 333, 16, 4, &mut rng);
            assert_eq!(loads.iter().sum::<u32>(), 333 * 4, "{policy:?}");
        }
    }

    #[test]
    fn top_k_capped_by_expert_count() {
        let mut rng = Pcg64::new(3);
        let loads = assign_tokens(RoutingPolicy::UniformRandom, 10, 4, 8, &mut rng);
        assert_eq!(loads.iter().sum::<u32>(), 40); // k clamped to 4
        // without replacement: no expert can exceed token count
        assert!(loads.iter().all(|&l| l <= 10));
    }

    #[test]
    fn skew_increases_imbalance() {
        let mut rng = Pcg64::new(4);
        let uni = assign_tokens(RoutingPolicy::UniformRandom, 4096, 16, 2, &mut rng);
        let skew = assign_tokens(RoutingPolicy::Skewed { alpha: 0.05 }, 4096, 16, 2, &mut rng);
        assert!(balance_metrics(&skew).imbalance > balance_metrics(&uni).imbalance);
    }

    #[test]
    fn routing_policy_parse() {
        assert_eq!(RoutingPolicy::parse("balanced"), Some(RoutingPolicy::Balanced));
        assert_eq!(
            RoutingPolicy::parse("skewed:0.25"),
            Some(RoutingPolicy::Skewed { alpha: 0.25 })
        );
        assert_eq!(
            RoutingPolicy::parse("drift:0.1:512"),
            Some(RoutingPolicy::Drifting { alpha: 0.1, period: 512 })
        );
        assert_eq!(RoutingPolicy::parse("drift:0.1:0"), None);
        assert_eq!(RoutingPolicy::parse("drift:0.1"), None);
        assert_eq!(RoutingPolicy::parse("nope"), None);
    }

    #[test]
    fn drift_epoch_zero_matches_skewed() {
        // epoch 0 weights == the stable skewed weights, so draws inside
        // the first epoch are bit-identical to the Skewed policy
        assert_eq!(expert_popularity_phase(0.1, 8, 0), expert_popularity(0.1, 8));
        let drifting = RoutingPolicy::Drifting { alpha: 0.1, period: 24 };
        let skewed = RoutingPolicy::Skewed { alpha: 0.1 };
        for draw in [0u64, 7, 23] {
            let mut a = Pcg64::new(5);
            let mut b = Pcg64::new(5);
            let da = assign_tokens_at(drifting, 64, 8, 2, None, draw, &mut a);
            let db = assign_tokens_at(skewed, 64, 8, 2, None, draw, &mut b);
            assert_eq!(da, db, "draw {draw} inside epoch 0 must match skewed");
        }
    }

    #[test]
    fn drift_epochs_move_the_hot_set() {
        // later epochs draw fresh popularity vectors: at least one of the
        // first few epochs must crown a different hottest expert
        let argmax = |w: &[f64]| {
            w.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        let base = argmax(&expert_popularity_phase(0.1, 8, 0));
        let moved = (1..4)
            .any(|p| argmax(&expert_popularity_phase(0.1, 8, p)) != base);
        assert!(moved, "drift epochs never moved the hot expert");
        // every epoch is still a probability vector
        for p in 0..4 {
            let w = expert_popularity_phase(0.1, 8, p);
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn skewed_popularity_is_stable() {
        // hot experts persist across draws (and rng streams): the
        // argmax of the loads matches the stable popularity argmax
        let w = expert_popularity(0.05, 16);
        assert_eq!(w.len(), 16);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let w_max = w.iter().cloned().fold(0.0, f64::max);
        for seed in [1u64, 2, 3] {
            let mut rng = Pcg64::new(seed);
            let loads =
                assign_tokens(RoutingPolicy::Skewed { alpha: 0.05 }, 4096, 16, 2, &mut rng);
            let loads_hot =
                loads.iter().enumerate().max_by_key(|&(_, &l)| l).unwrap().0;
            // the busiest expert must be one of the stably-popular ones
            // (tie-tolerant: within 2x of the top weight)
            assert!(
                w[loads_hot] >= 0.5 * w_max,
                "seed {seed}: expert {loads_hot} won with weight {} vs max {w_max}",
                w[loads_hot]
            );
        }
    }

    #[test]
    fn capacity_cap_drops_and_conserves() {
        let mut rng = Pcg64::new(9);
        // skewed routing overflows a tight cap
        let cap = expert_capacity(512, 8, 2, 1.0);
        let (loads, dropped) = assign_tokens_capped(
            RoutingPolicy::Skewed { alpha: 0.05 },
            512,
            8,
            2,
            Some(cap),
            &mut rng,
        );
        assert!(loads.iter().all(|&l| l <= cap));
        assert!(dropped > 0, "tight cap under heavy skew must drop");
        // routed + dropped conserves the token-slot total
        assert_eq!(
            loads.iter().map(|&x| x as u64).sum::<u64>() + dropped,
            512 * 2
        );
        // uncapped path is bit-identical to assign_tokens
        let mut a = Pcg64::new(4);
        let mut b = Pcg64::new(4);
        let plain = assign_tokens(RoutingPolicy::UniformRandom, 100, 8, 2, &mut a);
        let (capped, d) =
            assign_tokens_capped(RoutingPolicy::UniformRandom, 100, 8, 2, None, &mut b);
        assert_eq!(plain, capped);
        assert_eq!(d, 0);
    }

    #[test]
    fn capacity_formula() {
        // fair share = 512 * 2 / 8 = 128
        assert_eq!(expert_capacity(512, 8, 2, 1.0), 128);
        assert_eq!(expert_capacity(512, 8, 2, 1.25), 160);
        // floor at one slot
        assert_eq!(expert_capacity(1, 64, 1, 0.5), 1);
        // balanced routing never drops at cf >= 1
        let mut rng = Pcg64::new(1);
        let cap = expert_capacity(100, 8, 2, 1.0);
        let (_, dropped) =
            assign_tokens_capped(RoutingPolicy::Balanced, 100, 8, 2, Some(cap), &mut rng);
        assert_eq!(dropped, 0);
    }

    #[test]
    fn metrics_empty_and_zero() {
        let m = balance_metrics(&[]);
        assert_eq!(m.active_frac, 0.0);
        let m = balance_metrics(&[0, 0]);
        assert_eq!(m.imbalance, 0.0);
    }

    #[test]
    fn routing_fidelity_parse() {
        assert_eq!(RoutingFidelity::parse("token"), Some(RoutingFidelity::Token));
        assert_eq!(RoutingFidelity::parse("aggregate"), Some(RoutingFidelity::Aggregate));
        assert_eq!(RoutingFidelity::parse("exact"), None);
        assert_eq!(RoutingFidelity::default(), RoutingFidelity::Token);
        assert_eq!(RoutingFidelity::Aggregate.name(), "aggregate");
    }

    #[test]
    fn alias_table_reproduces_the_weights() {
        // the alias table is an exact O(1) sampler: empirical pick
        // frequencies converge to the cached popularity vector
        let mut cache = PopularityCache::default();
        cache.ensure(RoutingPolicy::Skewed { alpha: 0.3 }, 16, 0);
        let want = cache.weights.clone();
        let mut rng = Pcg64::new(31);
        let draws = 200_000;
        let mut counts = vec![0u64; 16];
        for _ in 0..draws {
            counts[cache.alias_draw(&mut rng)] += 1;
        }
        for (i, &w) in want.iter().enumerate() {
            let got = counts[i] as f64 / draws as f64;
            let tol = 6.0 * (w * (1.0 - w) / draws as f64).sqrt() + 1e-4;
            assert!((got - w).abs() < tol, "expert {i}: {got} vs weight {w}");
        }
        // every column is a valid (prob, alias) pair
        assert!(cache.prob.iter().all(|&p| (0.0..=1.0 + 1e-9).contains(&p)));
        assert!(cache.alias.iter().all(|&a| a < 16));
    }

    #[test]
    fn alias_sampler_conserves_and_respects_distinctness() {
        let mut cache = PopularityCache::default();
        let mut rng = Pcg64::new(5);
        let mut loads = Vec::new();
        for policy in [
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.05 },
            RoutingPolicy::Drifting { alpha: 0.1, period: 3 },
        ] {
            for draw in [0u64, 7] {
                let dropped = assign_tokens_into(
                    policy,
                    RoutingFidelity::Token,
                    100,
                    8,
                    3,
                    None,
                    draw,
                    &mut cache,
                    &mut rng,
                    &mut loads,
                );
                assert_eq!(dropped, 0);
                assert_eq!(loads.iter().map(|&x| u64::from(x)).sum::<u64>(), 300);
                // top-k without replacement: no expert exceeds the
                // token count
                assert!(loads.iter().all(|&l| l <= 100), "{policy:?}: {loads:?}");
            }
        }
    }

    #[test]
    fn alias_rejection_survives_a_mega_hot_expert() {
        // one expert holding ~all popularity forces the rejection
        // fallback on the second distinct pick: the draw must still
        // conserve slots and stay distinct
        let mut cache = PopularityCache::default();
        cache.ensure(RoutingPolicy::Skewed { alpha: 0.01 }, 4, 0);
        // overwrite with an adversarial popularity vector and rebuild
        cache.weights = vec![1.0 - 3e-9, 1e-9, 1e-9, 1e-9];
        cache.build_alias();
        let mut rng = Pcg64::new(77);
        let mut loads = vec![0u32; 4];
        let dropped = cache.sample_token_topk(50, 2, u32::MAX, &mut rng, &mut loads);
        assert_eq!(dropped, 0);
        assert_eq!(loads.iter().map(|&x| u64::from(x)).sum::<u64>(), 100);
        assert_eq!(loads[0], 50, "the hot expert is picked by every token");
        assert!(loads.iter().all(|&l| l <= 50));
    }

    #[test]
    fn aggregate_sampler_conserves_clamps_and_drops() {
        let mut cache = PopularityCache::default();
        let mut rng = Pcg64::new(13);
        let mut loads = Vec::new();
        // heavy skew, k=3: uncapped counts conserve and respect the
        // per-token distinctness bound
        let dropped = assign_tokens_into(
            RoutingPolicy::Skewed { alpha: 0.05 },
            RoutingFidelity::Aggregate,
            200,
            8,
            3,
            None,
            0,
            &mut cache,
            &mut rng,
            &mut loads,
        );
        assert_eq!(dropped, 0);
        assert_eq!(loads.iter().map(|&x| u64::from(x)).sum::<u64>(), 600);
        assert!(loads.iter().all(|&l| l <= 200), "{loads:?}");
        // a tight cap drops, conserving routed + dropped
        let cap = expert_capacity(200, 8, 3, 1.0);
        let dropped = assign_tokens_into(
            RoutingPolicy::Skewed { alpha: 0.05 },
            RoutingFidelity::Aggregate,
            200,
            8,
            3,
            Some(cap),
            0,
            &mut cache,
            &mut rng,
            &mut loads,
        );
        assert!(dropped > 0, "tight cap under heavy skew must drop");
        assert!(loads.iter().all(|&l| l <= cap));
        assert_eq!(loads.iter().map(|&x| u64::from(x)).sum::<u64>() + dropped, 600);
        // k == E saturates every expert exactly
        let d = assign_tokens_into(
            RoutingPolicy::UniformRandom,
            RoutingFidelity::Aggregate,
            64,
            4,
            4,
            None,
            0,
            &mut cache,
            &mut rng,
            &mut loads,
        );
        assert_eq!(d, 0);
        assert_eq!(loads, vec![64; 4]);
        // balanced policy is fidelity-independent
        let mut a = Vec::new();
        let mut b = Vec::new();
        for (fid, out) in
            [(RoutingFidelity::Token, &mut a), (RoutingFidelity::Aggregate, &mut b)]
        {
            assign_tokens_into(
                RoutingPolicy::Balanced,
                fid,
                100,
                8,
                2,
                None,
                0,
                &mut cache,
                &mut rng,
                out,
            );
        }
        assert_eq!(a, b);
    }

    #[test]
    fn oracle_sampler_matches_its_frozen_stream() {
        // the oracle's RNG consumption is frozen: one weighted_index
        // deviate per pick. Reproduce it by hand for a tiny case.
        let policy = RoutingPolicy::Skewed { alpha: 0.2 };
        let weights = expert_popularity(0.2, 4);
        let mut by_hand = Pcg64::new(9);
        let mut want = vec![0u32; 4];
        let mut w = weights.clone();
        for _ in 0..10 {
            w.copy_from_slice(&weights);
            for _ in 0..2 {
                let idx = by_hand.weighted_index(&w);
                want[idx] += 1;
                w[idx] = 0.0;
            }
        }
        let (got, dropped) =
            assign_tokens_oracle(policy, 10, 4, 2, None, 0, &mut Pcg64::new(9));
        assert_eq!(got, want);
        assert_eq!(dropped, 0);
    }
}
