//! MoE routing simulation: token-to-expert assignment and EP sharding.
//!
//! The paper models the MoE layer as a data-dependent micro-workflow
//! (§3.3): gate GEMM -> pluggable routing -> heterogeneous per-expert
//! GroupedGEMM -> synchronization barrier (`max` over expert tasks).
//! This module provides the pluggable routing policies that generate the
//! token-to-expert assignment map, plus load-balance metrics, an online
//! (windowed EWMA) expert-load estimator, and the dynamic expert
//! [`migration`] planner that re-places experts when popularity drifts.
#![warn(missing_docs)]

pub mod migration;
pub mod placement;

pub use migration::{
    charge_migration, plan_migration, rebalanced_placement, ExpertMove, LoadEstimator,
    MigrationPlan, MigrationPolicy,
};
pub use placement::{
    rank_imbalance, A2aPhase, EpFabric, EpNetwork, EpSpec, EpTopology, ExpertPlacement,
    PlacementPolicy,
};

use crate::core::Pcg64;

/// How tokens pick experts — the pluggable routing module of §3.3.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoutingPolicy {
    /// Perfectly balanced round-robin (the idealized upper bound;
    /// what balance-oblivious simulators implicitly assume).
    Balanced,
    /// Uniform random choice per token (multinomial load noise).
    UniformRandom,
    /// Skewed popularity: expert weights drawn once from a symmetric
    /// Dirichlet with concentration `alpha` — small alpha = hot experts.
    Skewed {
        /// Dirichlet concentration (dimensionless; smaller = hotter).
        alpha: f64,
    },
    /// Skewed popularity whose hot set *drifts*: every `period` routing
    /// draws the popularity vector is redrawn from a fresh deterministic
    /// stream (epoch 0 is identical to [`RoutingPolicy::Skewed`]). This
    /// is the regime dynamic expert migration exists for: a placement
    /// tuned at construction goes stale as the hot experts move.
    Drifting {
        /// Dirichlet concentration per epoch (dimensionless).
        alpha: f64,
        /// Routing draws per popularity epoch (draws, not seconds; on
        /// the AF path one draw is one `(layer, micro-batch)` cell).
        period: u64,
    },
}

impl RoutingPolicy {
    /// Parse `balanced`, `uniform`, `skewed:ALPHA`, or
    /// `drift:ALPHA:PERIOD` (the CLI `--routing` grammar).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "balanced" => Some(Self::Balanced),
            "uniform" => Some(Self::UniformRandom),
            _ => {
                if let Some(a) = s.strip_prefix("skewed:") {
                    return a.parse::<f64>().ok().map(|alpha| Self::Skewed { alpha });
                }
                let spec = s.strip_prefix("drift:")?;
                let (a, p) = spec.split_once(':')?;
                match (a.parse::<f64>(), p.parse::<u64>()) {
                    (Ok(alpha), Ok(period)) if period > 0 => Some(Self::Drifting { alpha, period }),
                    _ => None,
                }
            }
        }
    }
}

/// Stable expert-popularity weights for [`RoutingPolicy::Skewed`]:
/// drawn from a dedicated deterministic stream keyed on `(alpha, n)`,
/// so the *same* experts stay hot across layers, steps, and runs — the
/// semi-stable popularity real MoE serving exhibits, and the property
/// hot-expert replication placement relies on. Token sampling still
/// flows through the caller's rng.
pub fn expert_popularity(alpha: f64, n_experts: u32) -> Vec<f64> {
    expert_popularity_phase(alpha, n_experts, 0)
}

/// Popularity weights of one drift epoch ([`RoutingPolicy::Drifting`]):
/// epoch 0 reproduces [`expert_popularity`] exactly; every later epoch
/// draws an independent Dirichlet from its own deterministic stream, so
/// the hot set jumps at epoch boundaries while staying reproducible
/// across runs. Returns probabilities summing to 1.
pub fn expert_popularity_phase(alpha: f64, n_experts: u32, epoch: u64) -> Vec<f64> {
    let seed = 0xE5_9EED
        ^ alpha.to_bits()
        ^ ((n_experts as u64) << 40)
        ^ epoch.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut wrng = Pcg64::new(seed);
    wrng.dirichlet_sym(alpha, n_experts as usize)
}

/// Per-expert token capacity for a capacity factor `cf`
/// (GShard/MegaScale style): `ceil(cf * tokens * top_k / n_experts)`,
/// floored at one slot so a positive factor never starves an expert.
pub fn expert_capacity(tokens: u32, n_experts: u32, top_k: u32, cf: f64) -> u32 {
    let k = top_k.min(n_experts).max(1);
    let fair_share = tokens as f64 * k as f64 / n_experts.max(1) as f64;
    (fair_share * cf).ceil().max(1.0) as u32
}

/// Generate the token-to-expert assignment map: per-expert token counts
/// for `tokens` tokens each selecting `top_k` distinct experts.
pub fn assign_tokens(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    rng: &mut Pcg64,
) -> Vec<u32> {
    assign_tokens_capped(policy, tokens, n_experts, top_k, None, rng).0
}

/// [`assign_tokens`] with an optional per-expert capacity cap: a token
/// routed to a full expert is *dropped* (the GShard capacity-factor
/// policy) rather than rerouted. Returns `(per-expert loads, dropped
/// token-slots)`. The RNG stream is identical to the uncapped path, so
/// `capacity = None` reproduces [`assign_tokens`] bit-for-bit.
/// Equivalent to [`assign_tokens_at`] at draw index 0.
pub fn assign_tokens_capped(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    assign_tokens_at(policy, tokens, n_experts, top_k, capacity, 0, rng)
}

/// [`assign_tokens_capped`] at a known routing-draw index `draw` (a
/// running count of assignment draws, maintained by the caller). Only
/// [`RoutingPolicy::Drifting`] reads it — the popularity epoch is
/// `draw / period` — so for every other policy any `draw` value is
/// bit-identical to [`assign_tokens_capped`] (pinned by property test).
pub fn assign_tokens_at(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    draw: u64,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    let mut cache = PopularityCache::default();
    assign_tokens_cached(policy, tokens, n_experts, top_k, capacity, draw, &mut cache, rng)
}

/// Reusable popularity-vector cache for [`assign_tokens_cached`]: the
/// Dirichlet draw behind [`RoutingPolicy::Skewed`] /
/// [`RoutingPolicy::Drifting`] is deterministic per `(policy, epoch)`,
/// so a caller pricing many draws (the cost model's hot path — one
/// draw per `(layer, micro-batch)` cell on the AF path) re-derives it
/// only at epoch boundaries instead of every draw. Using a cache never
/// changes results, only saves the recomputation.
#[derive(Clone, Debug, Default)]
pub struct PopularityCache {
    key: Option<(RoutingPolicy, u32, u64)>,
    weights: Vec<f64>,
}

impl PopularityCache {
    /// The popularity vector (probabilities summing to 1) for `policy`
    /// over `n_experts` experts at `epoch`, recomputed only when the
    /// key changes.
    fn weights(&mut self, policy: RoutingPolicy, n_experts: u32, epoch: u64) -> &[f64] {
        if self.key != Some((policy, n_experts, epoch)) {
            self.weights = match policy {
                RoutingPolicy::Skewed { alpha } => expert_popularity(alpha, n_experts),
                RoutingPolicy::Drifting { alpha, .. } => {
                    expert_popularity_phase(alpha, n_experts, epoch)
                }
                _ => vec![1.0 / n_experts.max(1) as f64; n_experts as usize],
            };
            self.key = Some((policy, n_experts, epoch));
        }
        &self.weights
    }
}

/// [`assign_tokens_at`] with a caller-held [`PopularityCache`] — the
/// allocation-free-at-steady-state form for hot pricing paths.
/// Bit-identical to the uncached call for every policy.
#[allow(clippy::too_many_arguments)]
pub fn assign_tokens_cached(
    policy: RoutingPolicy,
    tokens: u32,
    n_experts: u32,
    top_k: u32,
    capacity: Option<u32>,
    draw: u64,
    cache: &mut PopularityCache,
    rng: &mut Pcg64,
) -> (Vec<u32>, u64) {
    let e = n_experts as usize;
    let k = (top_k as usize).min(e);
    let cap = capacity.unwrap_or(u32::MAX);
    let mut loads = vec![0u32; e];
    let mut dropped = 0u64;
    match policy {
        RoutingPolicy::Balanced => {
            let total = tokens as u64 * k as u64;
            let base = (total / e as u64) as u32;
            let rem = (total % e as u64) as usize;
            for (i, l) in loads.iter_mut().enumerate() {
                let want = base + u32::from(i < rem);
                *l = want.min(cap);
                dropped += (want - *l) as u64;
            }
        }
        RoutingPolicy::UniformRandom
        | RoutingPolicy::Skewed { .. }
        | RoutingPolicy::Drifting { .. } => {
            let epoch = match policy {
                RoutingPolicy::Drifting { period, .. } => draw / period.max(1),
                _ => 0,
            };
            let weights = cache.weights(policy, n_experts, epoch);
            let mut w = weights.to_vec();
            for _ in 0..tokens {
                // top-k without replacement per token
                w.copy_from_slice(weights);
                for _ in 0..k {
                    let idx = rng.weighted_index(&w);
                    if loads[idx] < cap {
                        loads[idx] += 1;
                    } else {
                        dropped += 1;
                    }
                    w[idx] = 0.0;
                }
            }
        }
    }
    (loads, dropped)
}

/// Load-balance metrics over an assignment map (predictor features and
/// reporting).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BalanceMetrics {
    /// max load / mean load (1.0 = perfect).
    pub imbalance: f64,
    /// Coefficient of variation of loads.
    pub cv: f64,
    /// Fraction of experts with nonzero load.
    pub active_frac: f64,
}

/// Compute [`BalanceMetrics`] over per-expert token loads.
pub fn balance_metrics(loads: &[u32]) -> BalanceMetrics {
    let e = loads.len() as f64;
    if e == 0.0 {
        return BalanceMetrics { imbalance: 0.0, cv: 0.0, active_frac: 0.0 };
    }
    let total: f64 = loads.iter().map(|&x| x as f64).sum();
    let mean = total / e;
    let max = loads.iter().copied().max().unwrap_or(0) as f64;
    let var: f64 =
        loads.iter().map(|&x| (x as f64 - mean) * (x as f64 - mean)).sum::<f64>() / e;
    BalanceMetrics {
        imbalance: if mean > 0.0 { max / mean } else { 0.0 },
        cv: if mean > 0.0 { var.sqrt() / mean } else { 0.0 },
        active_frac: loads.iter().filter(|&&x| x > 0).count() as f64 / e,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_is_exactly_balanced() {
        let mut rng = Pcg64::new(1);
        let loads = assign_tokens(RoutingPolicy::Balanced, 100, 8, 2, &mut rng);
        assert_eq!(loads.iter().sum::<u32>(), 200);
        assert_eq!(loads.iter().max(), loads.iter().min());
        let m = balance_metrics(&loads);
        assert!((m.imbalance - 1.0).abs() < 1e-12);
    }

    #[test]
    fn token_conservation() {
        let mut rng = Pcg64::new(2);
        for policy in [
            RoutingPolicy::UniformRandom,
            RoutingPolicy::Skewed { alpha: 0.1 },
            RoutingPolicy::Balanced,
        ] {
            let loads = assign_tokens(policy, 333, 16, 4, &mut rng);
            assert_eq!(loads.iter().sum::<u32>(), 333 * 4, "{policy:?}");
        }
    }

    #[test]
    fn top_k_capped_by_expert_count() {
        let mut rng = Pcg64::new(3);
        let loads = assign_tokens(RoutingPolicy::UniformRandom, 10, 4, 8, &mut rng);
        assert_eq!(loads.iter().sum::<u32>(), 40); // k clamped to 4
        // without replacement: no expert can exceed token count
        assert!(loads.iter().all(|&l| l <= 10));
    }

    #[test]
    fn skew_increases_imbalance() {
        let mut rng = Pcg64::new(4);
        let uni = assign_tokens(RoutingPolicy::UniformRandom, 4096, 16, 2, &mut rng);
        let skew = assign_tokens(RoutingPolicy::Skewed { alpha: 0.05 }, 4096, 16, 2, &mut rng);
        assert!(balance_metrics(&skew).imbalance > balance_metrics(&uni).imbalance);
    }

    #[test]
    fn routing_policy_parse() {
        assert_eq!(RoutingPolicy::parse("balanced"), Some(RoutingPolicy::Balanced));
        assert_eq!(
            RoutingPolicy::parse("skewed:0.25"),
            Some(RoutingPolicy::Skewed { alpha: 0.25 })
        );
        assert_eq!(
            RoutingPolicy::parse("drift:0.1:512"),
            Some(RoutingPolicy::Drifting { alpha: 0.1, period: 512 })
        );
        assert_eq!(RoutingPolicy::parse("drift:0.1:0"), None);
        assert_eq!(RoutingPolicy::parse("drift:0.1"), None);
        assert_eq!(RoutingPolicy::parse("nope"), None);
    }

    #[test]
    fn drift_epoch_zero_matches_skewed() {
        // epoch 0 weights == the stable skewed weights, so draws inside
        // the first epoch are bit-identical to the Skewed policy
        assert_eq!(expert_popularity_phase(0.1, 8, 0), expert_popularity(0.1, 8));
        let drifting = RoutingPolicy::Drifting { alpha: 0.1, period: 24 };
        let skewed = RoutingPolicy::Skewed { alpha: 0.1 };
        for draw in [0u64, 7, 23] {
            let mut a = Pcg64::new(5);
            let mut b = Pcg64::new(5);
            let da = assign_tokens_at(drifting, 64, 8, 2, None, draw, &mut a);
            let db = assign_tokens_at(skewed, 64, 8, 2, None, draw, &mut b);
            assert_eq!(da, db, "draw {draw} inside epoch 0 must match skewed");
        }
    }

    #[test]
    fn drift_epochs_move_the_hot_set() {
        // later epochs draw fresh popularity vectors: at least one of the
        // first few epochs must crown a different hottest expert
        let argmax = |w: &[f64]| {
            w.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
        };
        let base = argmax(&expert_popularity_phase(0.1, 8, 0));
        let moved = (1..4)
            .any(|p| argmax(&expert_popularity_phase(0.1, 8, p)) != base);
        assert!(moved, "drift epochs never moved the hot expert");
        // every epoch is still a probability vector
        for p in 0..4 {
            let w = expert_popularity_phase(0.1, 8, p);
            assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn skewed_popularity_is_stable() {
        // hot experts persist across draws (and rng streams): the
        // argmax of the loads matches the stable popularity argmax
        let w = expert_popularity(0.05, 16);
        assert_eq!(w.len(), 16);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let w_max = w.iter().cloned().fold(0.0, f64::max);
        for seed in [1u64, 2, 3] {
            let mut rng = Pcg64::new(seed);
            let loads =
                assign_tokens(RoutingPolicy::Skewed { alpha: 0.05 }, 4096, 16, 2, &mut rng);
            let loads_hot =
                loads.iter().enumerate().max_by_key(|&(_, &l)| l).unwrap().0;
            // the busiest expert must be one of the stably-popular ones
            // (tie-tolerant: within 2x of the top weight)
            assert!(
                w[loads_hot] >= 0.5 * w_max,
                "seed {seed}: expert {loads_hot} won with weight {} vs max {w_max}",
                w[loads_hot]
            );
        }
    }

    #[test]
    fn capacity_cap_drops_and_conserves() {
        let mut rng = Pcg64::new(9);
        // skewed routing overflows a tight cap
        let cap = expert_capacity(512, 8, 2, 1.0);
        let (loads, dropped) = assign_tokens_capped(
            RoutingPolicy::Skewed { alpha: 0.05 },
            512,
            8,
            2,
            Some(cap),
            &mut rng,
        );
        assert!(loads.iter().all(|&l| l <= cap));
        assert!(dropped > 0, "tight cap under heavy skew must drop");
        // routed + dropped conserves the token-slot total
        assert_eq!(
            loads.iter().map(|&x| x as u64).sum::<u64>() + dropped,
            512 * 2
        );
        // uncapped path is bit-identical to assign_tokens
        let mut a = Pcg64::new(4);
        let mut b = Pcg64::new(4);
        let plain = assign_tokens(RoutingPolicy::UniformRandom, 100, 8, 2, &mut a);
        let (capped, d) =
            assign_tokens_capped(RoutingPolicy::UniformRandom, 100, 8, 2, None, &mut b);
        assert_eq!(plain, capped);
        assert_eq!(d, 0);
    }

    #[test]
    fn capacity_formula() {
        // fair share = 512 * 2 / 8 = 128
        assert_eq!(expert_capacity(512, 8, 2, 1.0), 128);
        assert_eq!(expert_capacity(512, 8, 2, 1.25), 160);
        // floor at one slot
        assert_eq!(expert_capacity(1, 64, 1, 0.5), 1);
        // balanced routing never drops at cf >= 1
        let mut rng = Pcg64::new(1);
        let cap = expert_capacity(100, 8, 2, 1.0);
        let (_, dropped) =
            assign_tokens_capped(RoutingPolicy::Balanced, 100, 8, 2, Some(cap), &mut rng);
        assert_eq!(dropped, 0);
    }

    #[test]
    fn metrics_empty_and_zero() {
        let m = balance_metrics(&[]);
        assert_eq!(m.active_frac, 0.0);
        let m = balance_metrics(&[0, 0]);
        assert_eq!(m.imbalance, 0.0);
    }
}
