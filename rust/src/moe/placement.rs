//! Expert-parallel placement and cross-cluster routing traffic (§3.3).
//!
//! The paper's headline MoE claim is *cross-cluster expert routing*: the
//! EP domain spans hardware clusters, so the dispatch/combine all-to-all
//! pays heterogeneous link costs and contends on shared trunks. This
//! module provides the three pieces the rest of the stack threads
//! through:
//!
//! 1. **Placement** — [`ExpertPlacement`] maps experts to EP ranks and
//!    ranks to clusters ([`EpTopology`]) under a [`PlacementPolicy`]:
//!    contiguous blocks, strided (round-robin) assignment, or contiguous
//!    with the hottest experts replicated onto every cluster
//!    (MegaScale-Infer-style hot-expert replication).
//! 2. **Traffic** — [`ExpertPlacement::dispatch_matrix`] converts a
//!    routing assignment (per-expert token loads from
//!    [`crate::moe::assign_tokens`]) into per-`(src, dst)`-rank byte
//!    volumes, assuming tokens enter uniformly across EP ranks. The
//!    combine phase is the transpose.
//! 3. **Charging** — [`EpNetwork`] prices one all-to-all phase through
//!    FIFO-contended [`crate::network::Link`]s over the 3-tier
//!    hierarchical fabric ([`EpFabric`]): ranks sharing a node exchange
//!    over per-rank NVLink ports, ranks on different nodes over per-rank
//!    (possibly asymmetric egress/ingress) IB NICs, and each directed
//!    cluster pair shares a WAN trunk ([`crate::network::Fabric`]). A
//!    message occupies all the links on its path simultaneously; skewed
//!    routing therefore serializes on the hot expert's ingress NIC and
//!    cross-cluster hops on the trunk — the contention the closed-form
//!    `oracle::all2all_time` cannot see. In the uncontended, uniform,
//!    single-cluster case the charge reduces *exactly* to the closed
//!    form (pinned by `rust/tests/oracle_parity.rs`).
//!
//! [`EpSpec`] bundles a placement with the [`EpFabric`] it rides on and
//! is what [`crate::workflows::CostModel`] carries on the MoE pricing
//! path.

use crate::core::SimTime;
use crate::hardware::LinkSpec;
use crate::network::{Fabric, HierSpec, Link, LinkHealth, NetLoc, Tier};

/// How experts are assigned to EP ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Contiguous blocks: rank r hosts experts `[r*E/N, (r+1)*E/N)`.
    Contiguous,
    /// Strided round-robin: expert e lives on rank `e % N`.
    Strided,
    /// Contiguous base plus the `hot` highest-load experts replicated
    /// onto one rank of every cluster; sources route hot-expert traffic
    /// to their own cluster's replica, trading memory for cross-cluster
    /// bytes and rank balance.
    ReplicatedHot {
        /// How many of the highest-load experts to replicate (count).
        hot: u32,
    },
}

impl PlacementPolicy {
    /// Parse `contiguous`, `strided`, `replicated`, or `replicated:K`
    /// (the CLI `--ep-placement` grammar).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "contiguous" => Some(Self::Contiguous),
            "strided" => Some(Self::Strided),
            "replicated" => Some(Self::ReplicatedHot { hot: 1 }),
            _ => s.strip_prefix("replicated:").and_then(|k| {
                k.parse::<u32>().ok().map(|hot| Self::ReplicatedHot { hot })
            }),
        }
    }

    /// Stable lowercase name (reports, sweep tables).
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Contiguous => "contiguous",
            PlacementPolicy::Strided => "strided",
            PlacementPolicy::ReplicatedHot { .. } => "replicated-hot",
        }
    }
}

/// EP ranks grouped into hardware clusters (contiguous rank blocks; the
/// first `n_ranks % n_clusters` clusters take one extra rank).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EpTopology {
    /// Total EP ranks (GPUs in the expert-parallel domain; count).
    pub n_ranks: u32,
    /// Hardware clusters the ranks span (count; 1 = co-located).
    pub n_clusters: u32,
}

impl EpTopology {
    /// Topology of `n_ranks` EP ranks over `n_clusters` clusters
    /// (clamped so every cluster holds at least one rank).
    pub fn new(n_ranks: u32, n_clusters: u32) -> Self {
        let n_ranks = n_ranks.max(1);
        EpTopology { n_ranks, n_clusters: n_clusters.clamp(1, n_ranks) }
    }

    /// Half-open rank range `[start, end)` of cluster `c`.
    pub fn cluster_ranks(&self, c: u32) -> (u32, u32) {
        let per = self.n_ranks / self.n_clusters;
        let rem = self.n_ranks % self.n_clusters;
        let start = c * per + c.min(rem);
        (start, start + per + u32::from(c < rem))
    }

    /// Cluster index hosting `rank`.
    pub fn cluster_of(&self, rank: u32) -> u32 {
        for c in 0..self.n_clusters {
            let (s, e) = self.cluster_ranks(c);
            if rank >= s && rank < e {
                return c;
            }
        }
        self.n_clusters - 1
    }

    /// The `i`-th rank (mod cluster size) of cluster `c`.
    pub fn rank_in_cluster(&self, c: u32, i: u32) -> u32 {
        let (s, e) = self.cluster_ranks(c);
        s + i % (e - s)
    }
}

/// A concrete expert-to-rank assignment over an [`EpTopology`].
#[derive(Clone, Debug)]
pub struct ExpertPlacement {
    /// The rank/cluster topology the experts are placed over.
    pub topo: EpTopology,
    /// `expert_ranks[e]` = ranks hosting expert `e` (length 1 unless the
    /// expert is replicated; the home rank comes first).
    pub expert_ranks: Vec<Vec<u32>>,
}

impl ExpertPlacement {
    /// Build a placement. `loads_hint` (e.g. historical per-expert loads)
    /// selects which experts [`PlacementPolicy::ReplicatedHot`]
    /// replicates; without a hint the lowest-index experts are chosen.
    pub fn build(
        policy: PlacementPolicy,
        n_experts: u32,
        topo: EpTopology,
        loads_hint: Option<&[u32]>,
    ) -> Self {
        let n = topo.n_ranks;
        let home = |e: u32| -> u32 {
            match policy {
                PlacementPolicy::Strided => e % n,
                // balanced contiguous blocks (first `rem` ranks take one
                // extra expert when n does not divide n_experts)
                _ => {
                    let per = n_experts / n;
                    let rem = n_experts % n;
                    let cut = rem * (per + 1);
                    if e < cut {
                        e / (per + 1).max(1)
                    } else {
                        rem + (e - cut) / per.max(1)
                    }
                }
            }
        };
        let mut expert_ranks: Vec<Vec<u32>> =
            (0..n_experts).map(|e| vec![home(e).min(n - 1)]).collect();
        if let PlacementPolicy::ReplicatedHot { hot } = policy {
            let k = hot.min(n_experts) as usize;
            let hot_experts: Vec<usize> = match loads_hint {
                Some(loads) if loads.len() == n_experts as usize => {
                    let mut idx: Vec<usize> = (0..loads.len()).collect();
                    idx.sort_by(|&a, &b| loads[b].cmp(&loads[a]).then(a.cmp(&b)));
                    idx.truncate(k);
                    idx
                }
                _ => (0..k).collect(),
            };
            replicate_hot(&mut expert_ranks, &hot_experts, topo);
        }
        ExpertPlacement { topo, expert_ranks }
    }

    /// Number of experts placed (count).
    pub fn n_experts(&self) -> u32 {
        self.expert_ranks.len() as u32
    }

    /// Which replica of expert `e` a token entering on rank `src` is
    /// dispatched to: the replica in `src`'s own cluster when one
    /// exists, else a deterministic spread over the replicas.
    fn replica_index(&self, e: usize, src: u32) -> usize {
        let hosts = &self.expert_ranks[e];
        if hosts.len() == 1 {
            return 0;
        }
        let sc = self.topo.cluster_of(src);
        if let Some(i) = hosts.iter().position(|&h| self.topo.cluster_of(h) == sc) {
            return i;
        }
        (src as usize + e) % hosts.len()
    }

    /// Per-rank token loads for the resident experts, splitting each
    /// replicated expert's load across its replicas exactly as the
    /// dispatch does (tokens uniform over source ranks, each routed to
    /// its preferred replica; largest-remainder rounding keeps the total
    /// token count exact).
    pub fn rank_expert_loads(&self, loads: &[u32]) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        self.rank_expert_loads_into(loads, &mut out);
        out
    }

    /// Allocation-free variant of [`ExpertPlacement::rank_expert_loads`]
    /// for the per-draw pricing path: reuses `out`'s outer and inner
    /// vector capacities (steady-state draws on a non-replicated
    /// placement perform zero allocations).
    pub fn rank_expert_loads_into(&self, loads: &[u32], out: &mut Vec<Vec<u32>>) {
        let n = self.topo.n_ranks as usize;
        out.truncate(n);
        for rank in out.iter_mut() {
            rank.clear();
        }
        while out.len() < n {
            out.push(Vec::new());
        }
        for (e, &load) in loads.iter().enumerate() {
            let hosts = &self.expert_ranks[e];
            if hosts.len() == 1 {
                out[hosts[0] as usize].push(load);
                continue;
            }
            // how many of the n source ranks prefer each replica
            let mut srcs = vec![0u64; hosts.len()];
            for s in 0..n {
                srcs[self.replica_index(e, s as u32)] += 1;
            }
            // split `load` proportionally, largest remainder first
            let load = load as u64;
            let mut share: Vec<u64> = srcs.iter().map(|&c| load * c / n as u64).collect();
            let mut order: Vec<usize> = (0..hosts.len()).collect();
            order.sort_by_key(|&i| (std::cmp::Reverse((load * srcs[i]) % n as u64), i));
            let deficit = load - share.iter().sum::<u64>();
            for &i in order.iter().take(deficit as usize) {
                share[i] += 1;
            }
            for (i, &h) in hosts.iter().enumerate() {
                out[h as usize].push(share[i] as u32);
            }
        }
    }

    /// Total tokens computed per rank.
    pub fn rank_totals(&self, loads: &[u32]) -> Vec<u64> {
        self.rank_expert_loads(loads)
            .iter()
            .map(|per| per.iter().map(|&x| x as u64).sum())
            .collect()
    }

    /// Dispatch byte volumes per `(src, dst)` rank pair (row-major
    /// `n_ranks * n_ranks`), for `bytes_per_token` activation bytes per
    /// routed token. Tokens enter uniformly across ranks, so source `s`
    /// owes expert `e` exactly `loads[e] / n` tokens. The matrix total
    /// (including the local diagonal) equals
    /// `sum(loads) * bytes_per_token`.
    pub fn dispatch_matrix(&self, loads: &[u32], bytes_per_token: f64) -> Vec<f64> {
        let mut mat = Vec::new();
        self.dispatch_matrix_into(loads, bytes_per_token, &mut mat);
        mat
    }

    /// Allocation-free variant of [`ExpertPlacement::dispatch_matrix`]:
    /// writes into `out` (cleared and resized), reusing its capacity —
    /// the hot-path form for per-draw pricing.
    pub fn dispatch_matrix_into(&self, loads: &[u32], bytes_per_token: f64, out: &mut Vec<f64>) {
        let n = self.topo.n_ranks as usize;
        out.clear();
        out.resize(n * n, 0.0);
        for (e, &load) in loads.iter().enumerate() {
            if load == 0 {
                continue;
            }
            let per_src = load as f64 * bytes_per_token / n as f64;
            for s in 0..n {
                let d = self.expert_ranks[e][self.replica_index(e, s as u32)] as usize;
                out[s * n + d] += per_src;
            }
        }
    }

    /// Transpose of a `(src, dst)` byte matrix over this placement's
    /// ranks — the combine phase of a dispatch matrix already in hand.
    pub fn transposed(&self, matrix: &[f64]) -> Vec<f64> {
        let mut t = Vec::new();
        self.transpose_into(matrix, &mut t);
        t
    }

    /// Allocation-free transpose into a reusable buffer.
    pub fn transpose_into(&self, matrix: &[f64], out: &mut Vec<f64>) {
        let n = self.topo.n_ranks as usize;
        out.clear();
        out.resize(n * n, 0.0);
        for s in 0..n {
            for d in 0..n {
                out[d * n + s] = matrix[s * n + d];
            }
        }
    }

    /// Combine byte volumes: the transpose of the dispatch (every routed
    /// token's output travels the reverse path).
    pub fn combine_matrix(&self, loads: &[u32], bytes_per_token: f64) -> Vec<f64> {
        self.transposed(&self.dispatch_matrix(loads, bytes_per_token))
    }
}

/// Replicate each of `hot_experts` (in priority order) onto one rank
/// of every cluster other than its home's: replica `j` of the priority
/// list lands on `rank_in_cluster(c, j)`. Shared by constructor-time
/// placement ([`ExpertPlacement::build`]) and the migration planner
/// ([`crate::moe::migration::rebalanced_placement`]) so both produce
/// identical replica sets — the dispatch replica-routing assumes it.
pub(crate) fn replicate_hot(
    expert_ranks: &mut [Vec<u32>],
    hot_experts: &[usize],
    topo: EpTopology,
) {
    for (j, &e) in hot_experts.iter().enumerate() {
        let home_cluster = topo.cluster_of(expert_ranks[e][0]);
        for c in 0..topo.n_clusters {
            if c == home_cluster {
                continue;
            }
            let r = topo.rank_in_cluster(c, j as u32);
            if !expert_ranks[e].contains(&r) {
                expert_ranks[e].push(r);
            }
        }
    }
}

/// Max-over-mean rank load (1.0 = perfectly balanced, 0.0 = no load).
pub fn rank_imbalance(totals: &[u64]) -> f64 {
    if totals.is_empty() {
        return 0.0;
    }
    let sum: u64 = totals.iter().sum();
    if sum == 0 {
        return 0.0;
    }
    let mean = sum as f64 / totals.len() as f64;
    *totals.iter().max().unwrap() as f64 / mean
}

/// Outcome of charging one all-to-all phase through the fabric.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct A2aPhase {
    /// Phase makespan, seconds.
    pub secs: f64,
    /// All bytes in the matrix (including rank-local, which is free).
    pub total_bytes: f64,
    /// Bytes that crossed a cluster boundary.
    pub cross_bytes: f64,
    /// Rank-local bytes (the diagonal; never touch the network).
    pub local_bytes: f64,
}

/// How the EP rank set maps onto the 3-tier hierarchical fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpFabric {
    /// Per-tier link specs (NVLink / IB / WAN).
    pub hier: HierSpec,
    /// EP ranks sharing one node *within their cluster*. `u32::MAX`
    /// puts a whole cluster on one node — the legacy flat intra+cross
    /// model.
    pub ranks_per_node: u32,
    /// Ingress NIC bandwidth as a multiple of egress (per-rank NIC
    /// asymmetry; 1.0 = symmetric full-duplex).
    pub ingress_scale: f64,
}

impl EpFabric {
    /// Legacy flat fabric: one node per cluster, symmetric NICs.
    ///
    /// Single-cluster charging is bit-identical to the pre-hierarchy
    /// model (pinned by the closed-form parity test). Multi-cluster
    /// charging differs deliberately: cross-cluster messages now ride
    /// dedicated NICs instead of contending with intra-cluster traffic
    /// on the same per-rank links — the physically faithful model.
    pub fn flat(intra: LinkSpec, cross: LinkSpec) -> Self {
        EpFabric {
            hier: HierSpec::flat(intra, cross),
            ranks_per_node: u32::MAX,
            ingress_scale: 1.0,
        }
    }

    /// Full 3-tier hierarchy with `ranks_per_node` GPUs per node and an
    /// ingress/egress NIC bandwidth ratio.
    pub fn hierarchical(hier: HierSpec, ranks_per_node: u32, ingress_scale: f64) -> Self {
        EpFabric { hier, ranks_per_node: ranks_per_node.max(1), ingress_scale }
    }

    /// Hierarchy coordinate of a rank: its cluster, and its node index
    /// within that cluster.
    pub fn loc(&self, topo: &EpTopology, rank: u32) -> NetLoc {
        let c = topo.cluster_of(rank);
        let (start, _) = topo.cluster_ranks(c);
        NetLoc::new(c, (rank - start) / self.ranks_per_node.max(1))
    }
}

/// The EP fabric instance: per-rank NVLink ports (intra-node), per-rank
/// egress/ingress NICs (inter-node, possibly asymmetric), and one FIFO
/// trunk per directed cluster pair (WAN).
#[derive(Clone, Debug)]
pub struct EpNetwork {
    topo: EpTopology,
    fabric: EpFabric,
    /// Intra-node NVLink ports (one egress + ingress pair per rank).
    nv_egress: Vec<Link>,
    nv_ingress: Vec<Link>,
    /// Inter-node NICs; ingress bandwidth scaled by the asymmetry knob.
    nic_egress: Vec<Link>,
    nic_ingress: Vec<Link>,
    trunks: Fabric,
    /// Occupancy generation: [`EpNetwork::reset`] bumps this counter
    /// and links lazily clear themselves on first touch, making reset
    /// O(1) instead of O(links) per pricing draw.
    gen: u64,
    /// Effective health of the cross-cluster trunk for this pricing
    /// draw (fabric epochs: piecewise-constant per window, set by the
    /// engine before pricing). Healthy is exactly inert: `bw * 1.0`
    /// and `alpha + 0.0` are bit-exact no-ops. A dead trunk floors
    /// bandwidth at [`LinkHealth::OUTAGE_EP_BW_FRAC`] — MoE tokens
    /// routed to a remote expert can't re-route mid-layer, they stall.
    trunk_health: LinkHealth,
}

impl EpNetwork {
    /// Legacy flat constructor: intra-cluster NICs + cross-cluster trunk.
    pub fn new(topo: EpTopology, intra: LinkSpec, cross: LinkSpec) -> Self {
        Self::with_fabric(topo, EpFabric::flat(intra, cross))
    }

    /// Build the fabric instance for `topo` over `fabric`'s 3-tier link
    /// hierarchy (per-rank NVLink ports, per-rank possibly-asymmetric
    /// NICs, per directed cluster pair WAN trunks).
    pub fn with_fabric(topo: EpTopology, fabric: EpFabric) -> Self {
        let n = topo.n_ranks as usize;
        let nic_in = LinkSpec {
            bandwidth: fabric.hier.inter_node.bandwidth * fabric.ingress_scale.max(1e-9),
            alpha: fabric.hier.inter_node.alpha,
        };
        EpNetwork {
            topo,
            fabric,
            nv_egress: (0..n).map(|_| Link::new(fabric.hier.intra_node)).collect(),
            nv_ingress: (0..n).map(|_| Link::new(fabric.hier.intra_node)).collect(),
            nic_egress: (0..n).map(|_| Link::new(fabric.hier.inter_node)).collect(),
            nic_ingress: (0..n).map(|_| Link::new(nic_in)).collect(),
            trunks: Fabric::new(fabric.hier.wan),
            gen: 0,
            trunk_health: LinkHealth::HEALTHY,
        }
    }

    /// Set the effective cross-cluster trunk health for subsequent
    /// pricing draws. Survives [`EpNetwork::reset`] (reset clears
    /// occupancy, not fabric state); the engine re-applies it at every
    /// fabric-epoch boundary.
    pub fn set_trunk_health(&mut self, h: LinkHealth) {
        self.trunk_health = h;
    }

    /// Current effective trunk health.
    pub fn trunk_health(&self) -> LinkHealth {
        self.trunk_health
    }

    /// EP ranks this network connects (count).
    pub fn n_ranks(&self) -> u32 {
        self.topo.n_ranks
    }

    /// Whether this network instance was built for `spec`'s topology and
    /// fabric (scratch-reuse validity check).
    pub fn matches(&self, spec: &EpSpec) -> bool {
        self.topo == spec.placement.topo && self.fabric == spec.fabric
    }

    /// Make the network read as idle for the next independent pricing
    /// draw (the per-CostModel scratch buffer). O(1): bumps the
    /// occupancy generation instead of walking every NIC/port/trunk
    /// link — each link lazily clears itself the first time the next
    /// draw touches it ([`Link::touch`]).
    pub fn reset(&mut self) {
        self.gen += 1;
    }

    /// Charge one all-to-all phase described by a row-major `(src, dst)`
    /// byte matrix, starting no earlier than `now`. Messages follow the
    /// canonical rotation schedule (step p: rank s -> rank (s+p) mod n)
    /// and each occupies every link on its tier path simultaneously:
    /// intra-node messages the two NVLink ports, inter-node messages the
    /// two NICs, cross-cluster messages the NICs *and* the directed WAN
    /// trunk — for `alpha_sum + bytes / bottleneck_bw`. Returns the
    /// delivery time of the last message and the phase accounting.
    pub fn all_to_all(&mut self, now: SimTime, bytes: &[f64]) -> (SimTime, A2aPhase) {
        let n = self.topo.n_ranks as usize;
        assert_eq!(bytes.len(), n * n, "byte matrix must be n_ranks^2");
        let mut phase = A2aPhase::default();
        let mut finish = now;
        for (i, &b) in bytes.iter().enumerate() {
            phase.total_bytes += b;
            if i / n == i % n {
                phase.local_bytes += b;
            }
        }
        let hier = self.fabric.hier;
        for p in 1..n {
            for s in 0..n {
                let d = (s + p) % n;
                let b = bytes[s * n + d];
                if b <= 0.0 {
                    continue;
                }
                let sl = self.fabric.loc(&self.topo, s as u32);
                let dl = self.fabric.loc(&self.topo, d as u32);
                let tier = HierSpec::tier_of(sl, dl);
                let gen = self.gen;
                // resolve the links on the path (lazily clearing stale
                // occupancy generations) and the path alpha/beta
                let (start, alpha, bw) = match tier {
                    Tier::IntraNode => {
                        self.nv_egress[s].touch(gen);
                        self.nv_ingress[d].touch(gen);
                        let start = self.nv_egress[s]
                            .earliest_start(now)
                            .max(self.nv_ingress[d].earliest_start(now));
                        (start, hier.intra_node.alpha, hier.intra_node.bandwidth)
                    }
                    Tier::InterNode => {
                        self.nic_egress[s].touch(gen);
                        self.nic_ingress[d].touch(gen);
                        let start = self.nic_egress[s]
                            .earliest_start(now)
                            .max(self.nic_ingress[d].earliest_start(now));
                        let bw = self.nic_egress[s]
                            .spec
                            .bandwidth
                            .min(self.nic_ingress[d].spec.bandwidth);
                        (start, hier.inter_node.alpha, bw)
                    }
                    Tier::CrossCluster => {
                        self.nic_egress[s].touch(gen);
                        self.nic_ingress[d].touch(gen);
                        let trunk_link = self.trunks.link_mut(sl.cluster, dl.cluster);
                        trunk_link.touch(gen);
                        let trunk = trunk_link.earliest_start(now);
                        let start = self.nic_egress[s]
                            .earliest_start(now)
                            .max(self.nic_ingress[d].earliest_start(now))
                            .max(trunk);
                        // the trunk-health overlay only narrows the WAN
                        // leg: a brownout scales its bandwidth, a dead
                        // trunk floors it (tokens can't re-route
                        // mid-layer), and added latency rides the alpha
                        let th = self.trunk_health;
                        let bw = self.nic_egress[s]
                            .spec
                            .bandwidth
                            .min(self.nic_ingress[d].spec.bandwidth)
                            .min(hier.wan.bandwidth * th.ep_bw_frac());
                        (
                            start,
                            hier.inter_node.alpha + hier.wan.alpha + th.alpha_add_s,
                            bw,
                        )
                    }
                };
                let done = start + SimTime::from_secs_f64(alpha + b / bw);
                match tier {
                    Tier::IntraNode => {
                        self.nv_egress[s].occupy(done, b);
                        self.nv_ingress[d].occupy(done, b);
                    }
                    Tier::InterNode => {
                        self.nic_egress[s].occupy(done, b);
                        self.nic_ingress[d].occupy(done, b);
                    }
                    Tier::CrossCluster => {
                        self.nic_egress[s].occupy(done, b);
                        self.nic_ingress[d].occupy(done, b);
                        self.trunks.link_mut(sl.cluster, dl.cluster).occupy(done, b);
                        phase.cross_bytes += b;
                    }
                }
                if done > finish {
                    finish = done;
                }
            }
        }
        phase.secs = (finish - now).as_secs_f64();
        (finish, phase)
    }
}

/// Everything the cost model needs to price EP dispatch/combine: the
/// placement plus the hierarchical fabric it rides on.
#[derive(Clone, Debug)]
pub struct EpSpec {
    /// Expert-to-rank placement (mutable at runtime: the migration
    /// control loop re-writes it between iterations).
    pub placement: ExpertPlacement,
    /// The 3-tier fabric the EP traffic rides.
    pub fabric: EpFabric,
}

impl EpSpec {
    /// Legacy flat construction from an intra-cluster NIC spec and a
    /// cross-cluster trunk spec.
    pub fn flat(placement: ExpertPlacement, intra: LinkSpec, cross: LinkSpec) -> Self {
        EpSpec { placement, fabric: EpFabric::flat(intra, cross) }
    }

    /// EP ranks in the placement (count).
    pub fn n_ranks(&self) -> u32 {
        self.placement.topo.n_ranks
    }

    /// A fresh (idle) network instance over this spec's fabric.
    pub fn make_network(&self) -> EpNetwork {
        EpNetwork::with_fabric(self.placement.topo, self.fabric)
    }

    /// Makespan and accounting of one all-to-all phase over a fresh
    /// (uncontended) fabric. Cross-phase contention is modeled by the
    /// pipeline executor serializing the transfer resources, so each
    /// phase is priced from an idle network. Allocates a network per
    /// call — hot paths should hold an [`EpNetwork`] and use
    /// [`EpNetwork::reset`] + [`EpNetwork::all_to_all`] instead.
    pub fn a2a_time(&self, matrix: &[f64]) -> A2aPhase {
        self.make_network().all_to_all(SimTime::ZERO, matrix).1
    }

    /// [`EpSpec::a2a_time`] through a degraded cross-cluster trunk
    /// (fabric epochs): migration weight moves priced during a
    /// brownout pay the slowed trunk. Healthy `trunk` is bit-identical
    /// to [`EpSpec::a2a_time`].
    pub fn a2a_time_degraded(&self, trunk: LinkHealth, matrix: &[f64]) -> A2aPhase {
        let mut net = self.make_network();
        net.set_trunk_health(trunk);
        net.all_to_all(SimTime::ZERO, matrix).1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> LinkSpec {
        LinkSpec { bandwidth: 100e9, alpha: 5e-6 }
    }

    fn slow() -> LinkSpec {
        LinkSpec { bandwidth: 10e9, alpha: 30e-6 }
    }

    #[test]
    fn topology_partitions_ranks() {
        let t = EpTopology::new(10, 4);
        let mut seen = Vec::new();
        for c in 0..4 {
            let (s, e) = t.cluster_ranks(c);
            assert!(e > s);
            for r in s..e {
                assert_eq!(t.cluster_of(r), c);
                seen.push(r);
            }
        }
        assert_eq!(seen, (0..10).collect::<Vec<u32>>());
        // clamping
        assert_eq!(EpTopology::new(2, 8).n_clusters, 2);
        assert_eq!(EpTopology::new(0, 0).n_ranks, 1);
    }

    #[test]
    fn placement_policies_parse() {
        assert_eq!(PlacementPolicy::parse("contiguous"), Some(PlacementPolicy::Contiguous));
        assert_eq!(PlacementPolicy::parse("strided"), Some(PlacementPolicy::Strided));
        assert_eq!(
            PlacementPolicy::parse("replicated:3"),
            Some(PlacementPolicy::ReplicatedHot { hot: 3 })
        );
        assert_eq!(PlacementPolicy::parse("nope"), None);
    }

    #[test]
    fn contiguous_and_strided_are_partitions() {
        for policy in [PlacementPolicy::Contiguous, PlacementPolicy::Strided] {
            let p = ExpertPlacement::build(policy, 9, EpTopology::new(4, 2), None);
            assert_eq!(p.expert_ranks.len(), 9);
            let mut per_rank = vec![0u32; 4];
            for hosts in &p.expert_ranks {
                assert_eq!(hosts.len(), 1, "{policy:?}");
                per_rank[hosts[0] as usize] += 1;
            }
            // balanced: no rank more than one expert above any other
            assert!(per_rank.iter().max().unwrap() - per_rank.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn replicated_hot_spans_clusters() {
        let loads = [5u32, 100, 1, 2, 3, 4, 6, 7];
        let p = ExpertPlacement::build(
            PlacementPolicy::ReplicatedHot { hot: 1 },
            8,
            EpTopology::new(4, 2),
            Some(&loads),
        );
        // expert 1 is the hottest: one replica per cluster
        assert_eq!(p.expert_ranks[1].len(), 2);
        let clusters: Vec<u32> =
            p.expert_ranks[1].iter().map(|&r| p.topo.cluster_of(r)).collect();
        assert!(clusters.contains(&0) && clusters.contains(&1));
        // everyone else stays single-homed
        assert!(p.expert_ranks.iter().enumerate().all(|(e, h)| e == 1 || h.len() == 1));
    }

    #[test]
    fn dispatch_conserves_bytes_and_loads() {
        let topo = EpTopology::new(4, 2);
        for policy in [
            PlacementPolicy::Contiguous,
            PlacementPolicy::Strided,
            PlacementPolicy::ReplicatedHot { hot: 2 },
        ] {
            let loads = [40u32, 13, 0, 7, 21, 9, 5, 2];
            let p = ExpertPlacement::build(policy, 8, topo, Some(&loads));
            let bpt = 512.0;
            let m = p.dispatch_matrix(&loads, bpt);
            let total: f64 = m.iter().sum();
            let want = loads.iter().map(|&x| x as f64).sum::<f64>() * bpt;
            assert!((total - want).abs() < 1e-6 * want, "{policy:?}: {total} vs {want}");
            // rank loads conserve tokens exactly
            let totals = p.rank_totals(&loads);
            assert_eq!(totals.iter().sum::<u64>(), loads.iter().map(|&x| x as u64).sum());
            // combine is the transpose: same total
            let c = p.combine_matrix(&loads, bpt);
            assert!((c.iter().sum::<f64>() - want).abs() < 1e-6 * want);
        }
    }

    #[test]
    fn replication_cuts_cross_cluster_bytes() {
        let topo = EpTopology::new(4, 2);
        let mut loads = [1u32; 8];
        loads[0] = 400; // expert 0 is hot and homed in cluster 0
        let base = ExpertPlacement::build(PlacementPolicy::Contiguous, 8, topo, None);
        let repl = ExpertPlacement::build(
            PlacementPolicy::ReplicatedHot { hot: 1 },
            8,
            topo,
            Some(&loads),
        );
        let spec = EpSpec::flat(base, spec(), slow());
        let spec_r = EpSpec::flat(repl, spec.fabric.hier.intra_node, slow());
        let a = spec.a2a_time(&spec.placement.dispatch_matrix(&loads, 1024.0));
        let b = spec_r.a2a_time(&spec_r.placement.dispatch_matrix(&loads, 1024.0));
        assert!(b.cross_bytes < a.cross_bytes, "{} vs {}", b.cross_bytes, a.cross_bytes);
    }

    // NOTE: closed-form parity of the uncontended uniform all-to-all is
    // covered (across rank counts and link specs) by
    // `ep_fabric_all2all_reduces_to_closed_form_uncontended` in
    // rust/tests/oracle_parity.rs.

    #[test]
    fn skewed_ingress_serializes() {
        // all traffic to one rank: its ingress NIC is the bottleneck and
        // the phase degenerates to a serial chain of n-1 large messages
        let s = spec();
        let n = 4usize;
        let topo = EpTopology::new(n as u32, 1);
        let uniform = {
            let mut net = EpNetwork::new(topo, s, s);
            let mat = vec![1e6; n * n];
            net.all_to_all(SimTime::ZERO, &mat).0
        };
        let skewed = {
            let mut net = EpNetwork::new(topo, s, s);
            let mut mat = vec![0.0; n * n];
            for src in 0..n {
                mat[src * n + 2] = 1e6 * n as f64; // same total volume
            }
            net.all_to_all(SimTime::ZERO, &mat).0
        };
        assert!(skewed > uniform, "{skewed:?} vs {uniform:?}");
    }

    #[test]
    fn cross_cluster_pays_the_trunk() {
        let loads = [32u32; 8];
        let one = ExpertPlacement::build(
            PlacementPolicy::Contiguous,
            8,
            EpTopology::new(4, 1),
            None,
        );
        let two = ExpertPlacement::build(
            PlacementPolicy::Contiguous,
            8,
            EpTopology::new(4, 2),
            None,
        );
        let e1 = EpSpec::flat(one, spec(), slow());
        let e2 = EpSpec::flat(two, spec(), slow());
        let bpt = 2048.0;
        let t1 = e1.a2a_time(&e1.placement.dispatch_matrix(&loads, bpt));
        let t2 = e2.a2a_time(&e2.placement.dispatch_matrix(&loads, bpt));
        assert_eq!(t1.cross_bytes, 0.0);
        assert!(t2.cross_bytes > 0.0);
        assert!(t2.secs > t1.secs, "{} vs {}", t2.secs, t1.secs);
    }

    #[test]
    fn degraded_trunk_slows_only_cross_cluster() {
        let loads = [32u32; 8];
        let two = ExpertPlacement::build(
            PlacementPolicy::Contiguous,
            8,
            EpTopology::new(4, 2),
            None,
        );
        let e2 = EpSpec::flat(two, spec(), slow());
        let mat = e2.placement.dispatch_matrix(&loads, 2048.0);
        let healthy = e2.a2a_time(&mat);
        // healthy overlay is bit-identical to no overlay
        let inert = e2.a2a_time_degraded(LinkHealth::HEALTHY, &mat);
        assert_eq!(healthy.secs.to_bits(), inert.secs.to_bits());
        // brownout: same bytes, longer phase
        let brown = e2.a2a_time_degraded(
            LinkHealth { up: true, bw_frac: 0.25, alpha_add_s: 0.0 },
            &mat,
        );
        assert_eq!(brown.cross_bytes, healthy.cross_bytes);
        assert!(brown.secs > healthy.secs, "{} vs {}", brown.secs, healthy.secs);
        // dead trunk: floored, far slower still
        let dead = e2.a2a_time_degraded(
            LinkHealth { up: false, bw_frac: 1.0, alpha_add_s: 0.0 },
            &mat,
        );
        assert!(dead.secs > brown.secs, "{} vs {}", dead.secs, brown.secs);
        // intra-cluster-only traffic is untouched by trunk health
        let one = ExpertPlacement::build(
            PlacementPolicy::Contiguous,
            8,
            EpTopology::new(4, 1),
            None,
        );
        let e1 = EpSpec::flat(one, spec(), slow());
        let m1 = e1.placement.dispatch_matrix(&loads, 2048.0);
        let a = e1.a2a_time(&m1);
        let b = e1.a2a_time_degraded(
            LinkHealth { up: true, bw_frac: 0.1, alpha_add_s: 1.0 },
            &m1,
        );
        assert_eq!(a.secs.to_bits(), b.secs.to_bits());
    }

    #[test]
    fn hierarchical_tiers_order_the_phase() {
        // same uniform matrix: finer node granularity pushes more
        // traffic off NVLink onto IB, lengthening the phase; a WAN span
        // lengthens it further
        let hier = HierSpec {
            intra_node: spec(),                              // 100 GB/s
            inter_node: LinkSpec { bandwidth: 25e9, alpha: 10e-6 },
            wan: slow(),                                     // 10 GB/s
        };
        let n = 8u32;
        let mat = vec![2e6; (n * n) as usize];
        let run = |clusters: u32, rpn: u32| {
            let topo = EpTopology::new(n, clusters);
            let mut net =
                EpNetwork::with_fabric(topo, EpFabric::hierarchical(hier, rpn, 1.0));
            net.all_to_all(SimTime::ZERO, &mat).1
        };
        let one_node = run(1, 8);
        let two_nodes = run(1, 4);
        let two_clusters = run(2, 4);
        assert!(two_nodes.secs > one_node.secs, "{} vs {}", two_nodes.secs, one_node.secs);
        assert!(
            two_clusters.secs > two_nodes.secs,
            "{} vs {}",
            two_clusters.secs,
            two_nodes.secs
        );
        assert_eq!(one_node.cross_bytes, 0.0);
        assert_eq!(two_nodes.cross_bytes, 0.0);
        assert!(two_clusters.cross_bytes > 0.0);
    }

    #[test]
    fn ingress_asymmetry_slows_inter_node_traffic() {
        let hier = HierSpec {
            intra_node: spec(),
            inter_node: LinkSpec { bandwidth: 25e9, alpha: 10e-6 },
            wan: slow(),
        };
        let topo = EpTopology::new(4, 1);
        let mat = vec![4e6; 16];
        let run = |scale: f64| {
            let mut net =
                EpNetwork::with_fabric(topo, EpFabric::hierarchical(hier, 2, scale));
            net.all_to_all(SimTime::ZERO, &mat).1.secs
        };
        // half-rate ingress NICs bottleneck every inter-node message
        assert!(run(0.5) > run(1.0));
    }

    #[test]
    fn reset_reproduces_fresh_network() {
        // scratch reuse: reset() must make a used network
        // indistinguishable from a fresh one for any subsequent phase
        let topo = EpTopology::new(6, 2);
        let fabric = EpFabric::hierarchical(
            HierSpec { intra_node: spec(), inter_node: spec(), wan: slow() },
            2,
            0.8,
        );
        let mat_a: Vec<f64> = (0..36).map(|i| (i % 7) as f64 * 1e6).collect();
        let mat_b: Vec<f64> = (0..36).map(|i| (i % 5) as f64 * 2e6).collect();
        let mut reused = EpNetwork::with_fabric(topo, fabric);
        let first = reused.all_to_all(SimTime::ZERO, &mat_a).1;
        reused.reset();
        let second = reused.all_to_all(SimTime::ZERO, &mat_b).1;
        let fresh_a = EpNetwork::with_fabric(topo, fabric).all_to_all(SimTime::ZERO, &mat_a).1;
        let fresh_b = EpNetwork::with_fabric(topo, fabric).all_to_all(SimTime::ZERO, &mat_b).1;
        assert_eq!(first, fresh_a);
        assert_eq!(second, fresh_b);
    }

    #[test]
    fn matrix_into_matches_allocating_variants() {
        let loads = [40u32, 13, 0, 7, 21, 9, 5, 2];
        let p = ExpertPlacement::build(
            PlacementPolicy::ReplicatedHot { hot: 2 },
            8,
            EpTopology::new(4, 2),
            Some(&loads),
        );
        let mut buf = vec![999.0; 3]; // wrong size + stale data: must be overwritten
        p.dispatch_matrix_into(&loads, 640.0, &mut buf);
        assert_eq!(buf, p.dispatch_matrix(&loads, 640.0));
        let mut t = Vec::new();
        p.transpose_into(&buf, &mut t);
        assert_eq!(t, p.transposed(&buf));
    }

    #[test]
    fn rank_imbalance_metric() {
        assert_eq!(rank_imbalance(&[]), 0.0);
        assert_eq!(rank_imbalance(&[0, 0]), 0.0);
        assert!((rank_imbalance(&[10, 10]) - 1.0).abs() < 1e-12);
        assert!((rank_imbalance(&[30, 10]) - 1.5).abs() < 1e-12);
    }
}
