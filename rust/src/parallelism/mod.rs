//! Parallelism configuration: TP / PP / DP / EP sharding math.
//!
//! Frontier models the virtual sharding of §3.3: each replica is a group
//! of GPUs running one model copy under `tp * pp` partitioning; MoE
//! layers additionally shard experts under `ep` with the topological
//! constraint `attn_dp * attn_tp == moe_tp * moe_ep` (checked by
//! [`Parallelism::validate_moe_topology`]).

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Parallelism {
    /// Tensor parallel degree (within a replica).
    pub tp: u32,
    /// Pipeline parallel degree.
    pub pp: u32,
    /// Expert parallel degree (MoE; 1 for dense).
    pub ep: u32,
}

impl Default for Parallelism {
    fn default() -> Self {
        Parallelism { tp: 1, pp: 1, ep: 1 }
    }
}

impl Parallelism {
    pub fn tp(tp: u32) -> Self {
        Parallelism { tp, ..Default::default() }
    }

    pub fn new(tp: u32, pp: u32, ep: u32) -> Self {
        Parallelism { tp, pp, ep }
    }

    /// GPUs per model replica.
    pub fn gpus_per_replica(&self) -> u32 {
        self.tp * self.pp * self.ep.max(1) / 1
    }

    pub fn validate(&self) -> Result<()> {
        if self.tp == 0 || self.pp == 0 || self.ep == 0 {
            bail!("parallel degrees must be >= 1: {self:?}");
        }
        Ok(())
    }

    /// The MoE topological constraint from §3.3:
    /// `attn_dp * attn_tp == moe_tp * moe_ep`.
    pub fn validate_moe_topology(
        attn_dp: u32,
        attn_tp: u32,
        moe_tp: u32,
        moe_ep: u32,
    ) -> Result<()> {
        if attn_dp * attn_tp != moe_tp * moe_ep {
            bail!(
                "MoE topology violated: attn_dp({attn_dp}) * attn_tp({attn_tp}) \
                 != moe_tp({moe_tp}) * moe_ep({moe_ep})"
            );
        }
        Ok(())
    }

    /// Experts resident on each EP rank (n_experts must divide evenly).
    pub fn experts_per_rank(&self, n_experts: u32) -> Result<u32> {
        if n_experts % self.ep != 0 {
            bail!("{} experts do not divide across ep={}", n_experts, self.ep);
        }
        Ok(n_experts / self.ep)
    }

    /// Per-rank slice of a global per-expert load vector under the
    /// contiguous expert sharding (`experts_per_rank` experts each;
    /// `n_experts % ep == 0` is enforced by config validation). The
    /// single source of the chunking rule — the allocation-free pricing
    /// path indexes rank by rank instead of materializing a Vec.
    pub fn expert_shard<'a>(&self, loads: &'a [u32], rank: usize) -> &'a [u32] {
        let per = loads.len() / self.ep.max(1) as usize;
        &loads[rank * per..(rank + 1) * per]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpus_per_replica() {
        assert_eq!(Parallelism::new(4, 2, 1).gpus_per_replica(), 8);
        assert_eq!(Parallelism::tp(2).gpus_per_replica(), 2);
    }

    #[test]
    fn moe_topology_constraint() {
        // attn: dp=4, tp=2 (8 gpus) == moe: tp=2, ep=4
        assert!(Parallelism::validate_moe_topology(4, 2, 2, 4).is_ok());
        assert!(Parallelism::validate_moe_topology(4, 2, 2, 3).is_err());
    }

    #[test]
    fn expert_sharding() {
        let p = Parallelism::new(1, 1, 4);
        assert_eq!(p.experts_per_rank(64).unwrap(), 16);
        assert!(p.experts_per_rank(63).is_err());
        let loads: Vec<u32> = (0..8).collect();
        let p2 = Parallelism::new(1, 1, 2);
        assert_eq!(p2.expert_shard(&loads, 0), &[0, 1, 2, 3]);
        assert_eq!(p2.expert_shard(&loads, 1), &[4, 5, 6, 7]);
    }

    #[test]
    fn zero_degree_rejected() {
        assert!(Parallelism::new(0, 1, 1).validate().is_err());
        assert!(Parallelism::new(1, 1, 1).validate().is_ok());
    }
}
