//! Paged KV-cache block manager (PagedAttention-style).
//!
//! Each decode/colocated replica owns a [`BlockManager`]: a pool of
//! fixed-size KV blocks. Requests reserve blocks for their full lifetime
//! footprint on admission; completion frees them. The manager's free
//! count is the *memory availability signal* the decode
//! `ClusterScheduler` reports to the `GlobalController` for PD
//! backpressure (§3.3 step 2): KV transfers are initiated only when the
//! consumer has room.

use anyhow::{bail, Result};

/// Tokens per KV block (vLLM default).
pub const BLOCK_TOKENS: u32 = 16;

#[derive(Clone, Debug)]
pub struct BlockManager {
    /// Total blocks in the pool.
    total: u64,
    /// Currently free blocks.
    free: u64,
    /// Per-request allocation (request id -> blocks held).
    held: std::collections::HashMap<u64, u64>,
    /// High-water mark (metrics).
    pub peak_used: u64,
    /// Admissions rejected for lack of memory (metrics).
    pub alloc_failures: u64,
}

/// Blocks needed to hold `tokens` KV entries.
pub fn blocks_for_tokens(tokens: u32) -> u64 {
    (tokens as u64).div_ceil(BLOCK_TOKENS as u64)
}

impl BlockManager {
    /// Build from a GPU memory budget: capacity left after weights and
    /// activations is divided into KV blocks.
    pub fn from_budget(
        hbm_capacity: u64,
        weight_bytes: u64,
        kv_bytes_per_token: u64,
        reserve_frac: f64,
    ) -> Self {
        let usable = (hbm_capacity.saturating_sub(weight_bytes)) as f64 * (1.0 - reserve_frac);
        let block_bytes = kv_bytes_per_token * BLOCK_TOKENS as u64;
        let total = (usable as u64) / block_bytes.max(1);
        Self::with_blocks(total)
    }

    pub fn with_blocks(total: u64) -> Self {
        BlockManager {
            total,
            free: total,
            held: Default::default(),
            peak_used: 0,
            alloc_failures: 0,
        }
    }

    pub fn total_blocks(&self) -> u64 {
        self.total
    }

    pub fn free_blocks(&self) -> u64 {
        self.free
    }

    pub fn used_blocks(&self) -> u64 {
        self.total - self.free
    }

    /// Fraction of the pool in use.
    pub fn utilization(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.used_blocks() as f64 / self.total as f64
    }

    pub fn can_allocate(&self, blocks: u64) -> bool {
        blocks <= self.free
    }

    /// Reserve `blocks` for `req`. Fails without side effects (beyond the
    /// failure counter) if the pool is short.
    pub fn allocate(&mut self, req: u64, blocks: u64) -> Result<()> {
        if blocks > self.free {
            self.alloc_failures += 1;
            bail!("out of KV blocks: want {blocks}, free {}", self.free);
        }
        self.free -= blocks;
        *self.held.entry(req).or_insert(0) += blocks;
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// Grow an existing allocation (decode appending past a block edge).
    pub fn grow(&mut self, req: u64, blocks: u64) -> Result<()> {
        self.allocate(req, blocks)
    }

    /// Release everything held by `req`; returns the blocks freed.
    pub fn free_request(&mut self, req: u64) -> u64 {
        let blocks = self.held.remove(&req).unwrap_or(0);
        self.free += blocks;
        debug_assert!(self.free <= self.total, "double free");
        blocks
    }

    pub fn held_by(&self, req: u64) -> u64 {
        self.held.get(&req).copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_for_tokens_rounds_up() {
        assert_eq!(blocks_for_tokens(1), 1);
        assert_eq!(blocks_for_tokens(16), 1);
        assert_eq!(blocks_for_tokens(17), 2);
        assert_eq!(blocks_for_tokens(0), 0);
    }

    #[test]
    fn allocate_free_round_trip() {
        let mut bm = BlockManager::with_blocks(100);
        bm.allocate(1, 30).unwrap();
        bm.allocate(2, 30).unwrap();
        assert_eq!(bm.free_blocks(), 40);
        assert_eq!(bm.held_by(1), 30);
        assert_eq!(bm.free_request(1), 30);
        assert_eq!(bm.free_blocks(), 70);
        assert_eq!(bm.held_by(1), 0);
    }

    #[test]
    fn allocation_failure_is_clean() {
        let mut bm = BlockManager::with_blocks(10);
        bm.allocate(1, 8).unwrap();
        assert!(bm.allocate(2, 5).is_err());
        assert_eq!(bm.free_blocks(), 2);
        assert_eq!(bm.alloc_failures, 1);
        bm.allocate(2, 2).unwrap();
        assert_eq!(bm.free_blocks(), 0);
    }

    #[test]
    fn grow_accumulates() {
        let mut bm = BlockManager::with_blocks(10);
        bm.allocate(1, 2).unwrap();
        bm.grow(1, 3).unwrap();
        assert_eq!(bm.held_by(1), 5);
        assert_eq!(bm.free_request(1), 5);
    }

    #[test]
    fn peak_tracking() {
        let mut bm = BlockManager::with_blocks(10);
        bm.allocate(1, 7).unwrap();
        bm.free_request(1);
        bm.allocate(2, 3).unwrap();
        assert_eq!(bm.peak_used, 7);
    }

    #[test]
    fn from_budget_sizes_pool() {
        // Qwen2-7B on A800: 80GB - ~15GB weights, 57344 B/token kv
        let bm = BlockManager::from_budget(
            80 * (1 << 30),
            15 * (1 << 30),
            57344,
            0.1,
        );
        // ~62.8 GB usable / (57344 * 16) ~= 68k blocks ~= 1.1M tokens
        assert!(bm.total_blocks() > 50_000 && bm.total_blocks() < 90_000);
    }

    #[test]
    fn utilization() {
        let mut bm = BlockManager::with_blocks(100);
        assert_eq!(bm.utilization(), 0.0);
        bm.allocate(1, 50).unwrap();
        assert!((bm.utilization() - 0.5).abs() < 1e-12);
    }
}
