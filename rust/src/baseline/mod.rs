//! Replica-centric baseline simulator (Vidur-style).
//!
//! The abstraction the paper argues against (§1): the system is a pool
//! of homogeneous, self-contained replicas and simulation reduces to
//! load-balancing requests among them. Operator runtimes come from the
//! proxy-length [`crate::predictor::VidurPredictor`]; MoE layers use the
//! balance-oblivious `mean` (no straggler barrier); there are no
//! primitives for inter-cluster routing, KV transfer, or backpressure —
//! [`ReplicaCentricSim::simulate`] rejects disaggregated modes by
//! construction (Table 1's ✗ cells).

use anyhow::{bail, Result};

use crate::config::{DeploymentMode, ExperimentConfig, OverheadConfig};
use crate::core::{EventQueue, Pcg64, SimTime};
use crate::metrics::{MetricsCollector, SimReport};
use crate::moe::RoutingPolicy;
use crate::predictor::VidurPredictor;
use crate::workflows::{BatchShape, CostCtx, CostModel};

#[derive(Clone, Copy, Debug)]
enum Ev {
    Arrival(u64),
    IterEnd { r: usize },
}

struct Replica {
    waiting: std::collections::VecDeque<u64>,
    running: Vec<u64>,
    busy: bool,
}

struct BReq {
    arrival: SimTime,
    input_len: u32,
    output_len: u32,
    class: u16,
    prefilled: bool,
    decoded: u32,
    first_token: Option<SimTime>,
    last_token: SimTime,
}

/// The replica-centric simulator.
pub struct ReplicaCentricSim {
    cfg: ExperimentConfig,
    max_batch: usize,
}

impl ReplicaCentricSim {
    pub fn new(cfg: ExperimentConfig) -> Self {
        let max_batch = cfg.policy.budget.max_batch;
        ReplicaCentricSim { cfg, max_batch }
    }

    /// Run the workload. Disaggregated deployments cannot be expressed
    /// in the replica-centric abstraction.
    pub fn simulate(&self) -> Result<SimReport> {
        let n_replicas = match self.cfg.mode {
            DeploymentMode::Colocated { replicas } => replicas as usize,
            _ => bail!(
                "replica-centric abstraction cannot express {:?} (no \
                 inter-cluster primitives)",
                self.cfg.mode.name()
            ),
        };
        let host_start = std::time::Instant::now();
        let mut pred = VidurPredictor::a800();
        let mut cost = CostModel::new(self.cfg.model.clone(), self.cfg.parallel, self.cfg.link);
        // balance-oblivious: no straggler modeling, idealized routing
        cost.straggler_max = false;
        cost.moe_routing = RoutingPolicy::Balanced;
        cost.overhead = OverheadConfig::zero();
        let mut rng = Pcg64::new(self.cfg.seed);
        let mut metrics = MetricsCollector::default();
        metrics.slo = self.cfg.slo;
        metrics.class_names = self.cfg.workload.class_names();
        if self.cfg.keep_raw_samples {
            metrics.raw = Some(Box::default());
        }

        let trace = self.cfg.workload.materialize()?;
        let mut reqs: Vec<BReq> = trace
            .iter()
            .map(|s| BReq {
                arrival: s.arrival,
                input_len: s.input_len,
                output_len: s.output_len,
                class: s.class,
                prefilled: false,
                decoded: 0,
                first_token: None,
                last_token: SimTime::ZERO,
            })
            .collect();
        let mut replicas: Vec<Replica> = (0..n_replicas)
            .map(|_| Replica { waiting: Default::default(), running: vec![], busy: false })
            .collect();
        let mut queue: EventQueue<Ev> = EventQueue::new();
        for (i, r) in trace.iter().enumerate() {
            queue.schedule_at(r.arrival, Ev::Arrival(i as u64));
        }
        let mut rr = 0usize;
        while let Some(ev) = queue.pop() {
            match ev.kind {
                Ev::Arrival(rid) => {
                    metrics.record_arrival(queue.now().as_secs_f64());
                    // pure round-robin load balancing across the pool
                    let r = rr % n_replicas;
                    rr += 1;
                    replicas[r].waiting.push_back(rid);
                    Self::maybe_start(
                        r, &mut replicas, &mut reqs, &mut queue, &cost, &mut pred, &mut rng,
                        &mut metrics, self.max_batch,
                    );
                }
                Ev::IterEnd { r } => {
                    let now = queue.now();
                    metrics.iterations += 1;
                    let running = replicas[r].running.clone();
                    let mut done = Vec::new();
                    for &rid in &running {
                        let rq = &mut reqs[rid as usize];
                        if !rq.prefilled {
                            rq.prefilled = true;
                            rq.decoded = 1;
                            rq.first_token = Some(now);
                            rq.last_token = now;
                            metrics.prefill_tokens += rq.input_len as u64;
                            metrics.output_tokens += 1;
                            let (class, ttft) = (rq.class, (now - rq.arrival).as_secs_f64());
                            metrics.record_ttft(class, ttft, now.as_secs_f64());
                        } else {
                            rq.decoded += 1;
                            metrics.output_tokens += 1;
                            let (class, tbt) = (rq.class, (now - rq.last_token).as_secs_f64());
                            metrics.record_tbt(class, tbt, now.as_secs_f64());
                            let rq = &mut reqs[rid as usize];
                            rq.last_token = now;
                        }
                        let rq = &reqs[rid as usize];
                        if rq.decoded >= rq.output_len {
                            done.push(rid);
                        }
                    }
                    for rid in done {
                        let rq = &reqs[rid as usize];
                        let e2e = (now - rq.arrival).as_secs_f64();
                        let ttft =
                            rq.first_token.map_or(e2e, |ft| (ft - rq.arrival).as_secs_f64());
                        let tbt_mean = match (rq.first_token, rq.decoded) {
                            (Some(ft), d) if d > 1 => (now - ft).as_secs_f64() / (d - 1) as f64,
                            _ => 0.0,
                        };
                        metrics.record_completion(
                            rq.class,
                            ttft,
                            tbt_mean,
                            e2e,
                            rq.output_len,
                            now.as_secs_f64(),
                        );
                        replicas[r].running.retain(|&x| x != rid);
                    }
                    replicas[r].busy = false;
                    Self::maybe_start(
                        r, &mut replicas, &mut reqs, &mut queue, &cost, &mut pred, &mut rng,
                        &mut metrics, self.max_batch,
                    );
                }
            }
        }
        Ok(SimReport {
            mode: "replica-centric".into(),
            predictor: "vidur".into(),
            sim_duration: queue.now().as_secs_f64(),
            host_duration: host_start.elapsed().as_secs_f64(),
            events_processed: queue.processed(),
            n_gpus: self.cfg.n_gpus(),
            metrics,
            // the replica-centric abstraction has no stage pools
            stages: Vec::new(),
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn maybe_start(
        r: usize,
        replicas: &mut [Replica],
        reqs: &mut [BReq],
        queue: &mut EventQueue<Ev>,
        cost: &CostModel,
        pred: &mut VidurPredictor,
        rng: &mut Pcg64,
        metrics: &mut MetricsCollector,
        max_batch: usize,
    ) {
        let repl = &mut replicas[r];
        if repl.busy {
            return;
        }
        while repl.running.len() < max_batch {
            match repl.waiting.pop_front() {
                Some(rid) => repl.running.push(rid),
                None => break,
            }
        }
        if repl.running.is_empty() {
            return;
        }
        // monolithic batch model: full prefills (no chunking), then decode
        let mut shape = BatchShape::default();
        for &rid in &repl.running {
            let rq = &reqs[rid as usize];
            if !rq.prefilled {
                shape.prefill.push((rq.input_len, 0));
                shape.lm_head_rows += 1;
            } else {
                shape.decode_ctx.push(rq.input_len + rq.decoded);
                shape.lm_head_rows += 1;
            }
        }
        let dt = {
            let mut ctx = CostCtx { pred, rng, metrics: Some(metrics) };
            cost.iteration_time(&mut ctx, &shape)
        };
        repl.busy = true;
        queue.schedule_in(SimTime::from_secs_f64(dt), Ev::IterEnd { r });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::workload::WorkloadSpec;

    #[test]
    fn completes_colocated_workload() {
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 2)
            .with_workload(WorkloadSpec::table2(16, 64, 8));
        let report = ReplicaCentricSim::new(cfg).simulate().unwrap();
        assert_eq!(report.metrics.completed_requests, 16);
        assert_eq!(report.metrics.output_tokens, 16 * 8);
    }

    #[test]
    fn rejects_disaggregated_modes() {
        let pd = ExperimentConfig::pd(ModelConfig::tiny(), 1, 1);
        assert!(ReplicaCentricSim::new(pd).simulate().is_err());
        let af = ExperimentConfig::af(ModelConfig::tiny(), 1, 1, 1, 2);
        assert!(ReplicaCentricSim::new(af).simulate().is_err());
    }

    #[test]
    fn deterministic() {
        let cfg = ExperimentConfig::colocated(ModelConfig::tiny(), 1)
            .with_workload(WorkloadSpec::table2(8, 64, 4));
        let a = ReplicaCentricSim::new(cfg.clone()).simulate().unwrap();
        let b = ReplicaCentricSim::new(cfg).simulate().unwrap();
        assert_eq!(a.sim_duration, b.sim_duration);
    }
}
