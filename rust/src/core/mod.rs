//! Discrete-event simulation core: time, RNG, event queue, engine.
//!
//! Everything in the simulator is driven by [`EventQueue`]: a binary heap
//! of `(time, seq)`-ordered events. The `seq` tie-break makes simulation
//! runs fully deterministic for a fixed seed, which the property tests
//! rely on.

mod engine;
mod rng;
mod time;

pub use engine::{Event, EventQueue};
pub use rng::Pcg64;
pub use time::{SimTime, MS, NS_PER_SEC, S, US};
