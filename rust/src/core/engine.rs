//! Event queue: the heart of the discrete-event engine.
//!
//! Generic over the event payload so the same queue drives both the
//! full coordinator simulation and the standalone AF dependency-graph
//! executor. Ordering is `(time, seq)` — FIFO among simultaneous events —
//! making every run deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::SimTime;

#[derive(Clone, Debug)]
pub struct Event<K> {
    pub time: SimTime,
    pub seq: u64,
    pub kind: K,
}

impl<K> PartialEq for Event<K> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<K> Eq for Event<K> {}
impl<K> PartialOrd for Event<K> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<K> Ord for Event<K> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Priority queue of events, earliest `(time, seq)` first.
pub struct EventQueue<K> {
    heap: BinaryHeap<Event<K>>,
    next_seq: u64,
    now: SimTime,
    processed: u64,
}

impl<K> Default for EventQueue<K> {
    fn default() -> Self {
        Self::new()
    }
}

impl<K> EventQueue<K> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(1024),
            next_seq: 0,
            now: SimTime::ZERO,
            processed: 0,
        }
    }

    /// Current simulation time (the timestamp of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events popped so far (the engine-perf metric).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `kind` at absolute time `at`. Panics (debug) on scheduling
    /// into the past — causality violations are always bugs.
    pub fn schedule_at(&mut self, at: SimTime, kind: K) {
        debug_assert!(at >= self.now, "event scheduled in the past");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time: at, seq, kind });
    }

    /// Schedule `kind` after a delay from now.
    pub fn schedule_in(&mut self, delay: SimTime, kind: K) {
        self.schedule_at(self.now + delay, kind);
    }

    /// Pop the next event, advancing `now`.
    pub fn pop(&mut self) -> Option<Event<K>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now, "time went backwards");
        self.now = ev.time;
        self.processed += 1;
        Some(ev)
    }

    /// Peek at the next event time without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(30), "c");
        q.schedule_at(SimTime(10), "a");
        q.schedule_at(SimTime(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_among_simultaneous() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(SimTime(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.kind)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_with_pop() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(42), ());
        assert_eq!(q.now(), SimTime::ZERO);
        q.pop();
        assert_eq!(q.now(), SimTime(42));
        assert_eq!(q.processed(), 1);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(100), 0);
        q.pop();
        q.schedule_in(SimTime(50), 1);
        assert_eq!(q.pop().unwrap().time, SimTime(150));
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn past_scheduling_panics() {
        let mut q = EventQueue::new();
        q.schedule_at(SimTime(100), ());
        q.pop();
        q.schedule_at(SimTime(50), ());
    }
}
