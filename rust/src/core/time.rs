//! Simulation time: integer nanoseconds since simulation start.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// Nanoseconds-resolution simulation timestamp.
///
/// Integer time keeps event ordering exact and runs reproducible; all
/// oracle/predictor outputs (f64 seconds) are rounded on conversion.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

pub const NS_PER_SEC: u64 = 1_000_000_000;
/// One microsecond in SimTime ticks.
pub const US: u64 = 1_000;
/// One millisecond in SimTime ticks.
pub const MS: u64 = 1_000_000;
/// One second in SimTime ticks.
pub const S: u64 = NS_PER_SEC;

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);
    pub const MAX: SimTime = SimTime(u64::MAX);

    /// Convert seconds (as produced by the oracle / predictors) to ticks.
    pub fn from_secs_f64(s: f64) -> Self {
        debug_assert!(s >= 0.0 && s.is_finite(), "bad duration: {s}");
        SimTime((s * NS_PER_SEC as f64).round() as u64)
    }

    pub fn from_us_f64(us: f64) -> Self {
        Self::from_secs_f64(us * 1e-6)
    }

    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / NS_PER_SEC as f64
    }

    pub fn as_us_f64(self) -> f64 {
        self.0 as f64 / US as f64
    }

    pub fn as_ms_f64(self) -> f64 {
        self.0 as f64 / MS as f64
    }

    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}ms", self.as_ms_f64())
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}ms", self.as_ms_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        let t = SimTime::from_secs_f64(1.5);
        assert_eq!(t.0, 1_500_000_000);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-12);
        assert_eq!(SimTime::from_us_f64(2.0).0, 2_000);
    }

    #[test]
    fn arithmetic() {
        let a = SimTime(100) + SimTime(50);
        assert_eq!(a, SimTime(150));
        assert_eq!(a - SimTime(150), SimTime::ZERO);
        assert_eq!(SimTime(10).saturating_sub(SimTime(20)), SimTime::ZERO);
    }

    #[test]
    fn ordering() {
        assert!(SimTime(1) < SimTime(2));
        assert_eq!(SimTime::ZERO, SimTime::default());
    }
}
