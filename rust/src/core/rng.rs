//! Deterministic PCG64 RNG (no external crates in this offline build).
//!
//! PCG-XSL-RR 128/64. One instance per simulation; all stochastic choices
//! (arrivals, lengths, MoE routing) flow through it so a fixed seed
//! reproduces the run exactly.

#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((seed as u128).wrapping_mul(0x9E37_79B9_7F4A_7C15) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — hi exclusive, hi > lo.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + (self.next_f64() * (hi - lo) as f64) as u64
    }

    /// Exponential with the given rate (inter-arrival sampling).
    pub fn exp(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.next_f64()).ln() / rate
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Lognormal with underlying N(mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang (shape >= 0.01).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.next_f64().max(1e-12);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64().max(1e-300);
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v;
            }
        }
    }

    /// Binomial(n, p). Three regimes: exact Bernoulli summation for
    /// small `n`, CDF inversion (expected O(np) steps) for small means,
    /// and a rounded normal approximation (exact mean/variance) for the
    /// rest. The approximation tail is what the aggregate routing
    /// sampler's tolerance-based property tests budget for.
    pub fn binomial(&mut self, n: u64, p: f64) -> u64 {
        if n == 0 || p <= 0.0 {
            return 0;
        }
        if p >= 1.0 {
            return n;
        }
        if p > 0.5 {
            return n - self.binomial(n, 1.0 - p);
        }
        let mean = n as f64 * p;
        if n <= 64 {
            (0..n).filter(|_| self.next_f64() < p).count() as u64
        } else if mean < 12.0 {
            // inversion: walk the pmf from 0 (no underflow: the branch
            // implies (1-p)^n >= exp(-mean/(1-p)) >= e^-24)
            let q = 1.0 - p;
            let s = p / q;
            let mut pmf = q.powf(n as f64);
            let mut cdf = pmf;
            let u = self.next_f64();
            let mut i = 0u64;
            while cdf < u && i < n {
                i += 1;
                pmf *= s * (n - i + 1) as f64 / i as f64;
                cdf += pmf;
            }
            i
        } else {
            let sd = (mean * (1.0 - p)).sqrt();
            (mean + sd * self.normal()).round().clamp(0.0, n as f64) as u64
        }
    }

    /// Dirichlet(alpha,...,alpha) of length n.
    pub fn dirichlet_sym(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut xs: Vec<f64> = (0..n).map(|_| self.gamma(alpha).max(1e-300)).collect();
        let s: f64 = xs.iter().sum();
        for x in &mut xs {
            *x /= s;
        }
        xs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Pcg64::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut rng = Pcg64::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(11);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut rng = Pcg64::new(13);
        for &alpha in &[0.05, 0.5, 5.0] {
            let xs = rng.dirichlet_sym(alpha, 16);
            assert_eq!(xs.len(), 16);
            assert!((xs.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(xs.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut rng = Pcg64::new(17);
        let w = [0.0, 0.0, 1.0];
        for _ in 0..50 {
            assert_eq!(rng.weighted_index(&w), 2);
        }
    }

    #[test]
    fn binomial_moments_and_edges() {
        let mut rng = Pcg64::new(23);
        // degenerate cases
        assert_eq!(rng.binomial(0, 0.5), 0);
        assert_eq!(rng.binomial(10, 0.0), 0);
        assert_eq!(rng.binomial(10, 1.0), 10);
        // all three regimes: exact small-n, inversion, normal approx
        for &(n, p) in &[(40u64, 0.3f64), (10_000, 0.0005), (5_000, 0.2), (5_000, 0.9)] {
            let draws = 4_000;
            let mut sum = 0.0;
            let mut sum2 = 0.0;
            for _ in 0..draws {
                let x = rng.binomial(n, p) as f64;
                assert!(x <= n as f64);
                sum += x;
                sum2 += x * x;
            }
            let mean = sum / draws as f64;
            let var = sum2 / draws as f64 - mean * mean;
            let want_mean = n as f64 * p;
            let want_var = want_mean * (1.0 - p);
            let mean_tol = 6.0 * (want_var / draws as f64).sqrt() + 0.5;
            assert!(
                (mean - want_mean).abs() < mean_tol,
                "n={n} p={p}: mean {mean} vs {want_mean}"
            );
            assert!(
                (var - want_var).abs() < 0.15 * want_var + 1.0,
                "n={n} p={p}: var {var} vs {want_var}"
            );
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Pcg64::new(19);
        for _ in 0..1000 {
            let x = rng.gen_range(5, 10);
            assert!((5..10).contains(&x));
        }
    }
}
