//! Workload generation: arrival processes and length distributions.
//!
//! Covers the paper's evaluation workloads (Table 2's fixed
//! batch/in/out grids) plus the dynamic mixes used for Fig. 2-style
//! operator studies: Poisson/gamma arrivals and
//! fixed/uniform/lognormal/zipf-skew length distributions. A generated
//! trace is just `Vec<RequestSpec>`, so real traces can be loaded from
//! JSON with the same downstream path.

use crate::core::{Pcg64, SimTime};

/// One request to serve.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestSpec {
    pub arrival: SimTime,
    pub input_len: u32,
    pub output_len: u32,
}

/// Arrival process.
#[derive(Clone, Debug, PartialEq)]
pub enum Arrival {
    /// All requests present at t=0 (throughput / closed-batch runs).
    Batch,
    /// Poisson with `rate` requests/second.
    Poisson { rate: f64 },
    /// Gamma inter-arrivals (burstiness via cv != 1).
    Gamma { rate: f64, cv: f64 },
    /// Fixed inter-arrival interval.
    Uniform { rate: f64 },
}

/// Length distribution.
#[derive(Clone, Debug, PartialEq)]
pub enum LenDist {
    Fixed(u32),
    Uniform { lo: u32, hi: u32 },
    /// Lognormal targeting the given mean with shape sigma.
    LogNormal { mean: f64, sigma: f64 },
    /// Mostly-short with a heavy tail: `frac_long` of requests are
    /// uniform in `[long_lo, long_hi]`, the rest in `[lo, hi]`.
    ZipfMix { lo: u32, hi: u32, long_lo: u32, long_hi: u32, frac_long: f64 },
}

impl LenDist {
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        match *self {
            LenDist::Fixed(v) => v,
            LenDist::Uniform { lo, hi } => rng.gen_range(lo as u64, hi as u64 + 1) as u32,
            LenDist::LogNormal { mean, sigma } => {
                // E[lognormal(mu, sigma)] = exp(mu + sigma^2/2)
                let mu = mean.ln() - sigma * sigma / 2.0;
                (rng.lognormal(mu, sigma).round() as u32).max(1)
            }
            LenDist::ZipfMix { lo, hi, long_lo, long_hi, frac_long } => {
                if rng.next_f64() < frac_long {
                    rng.gen_range(long_lo as u64, long_hi as u64 + 1) as u32
                } else {
                    rng.gen_range(lo as u64, hi as u64 + 1) as u32
                }
            }
        }
    }

    /// Mean of the distribution (for rate-matching calculations).
    pub fn mean(&self) -> f64 {
        match *self {
            LenDist::Fixed(v) => v as f64,
            LenDist::Uniform { lo, hi } => (lo + hi) as f64 / 2.0,
            LenDist::LogNormal { mean, .. } => mean,
            LenDist::ZipfMix { lo, hi, long_lo, long_hi, frac_long } => {
                (1.0 - frac_long) * (lo + hi) as f64 / 2.0
                    + frac_long * (long_lo + long_hi) as f64 / 2.0
            }
        }
    }
}

/// Complete workload specification.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub arrival: Arrival,
    pub input: LenDist,
    pub output: LenDist,
    pub n_requests: u32,
    pub seed: u64,
}

impl WorkloadSpec {
    /// Table-2 style: all requests at t=0, inputs uniform around the
    /// target mean (the paper reports "Avg Input"), fixed outputs.
    pub fn table2(n_requests: u32, avg_input: u32, output: u32) -> Self {
        let lo = (avg_input / 2).max(1);
        let hi = avg_input + avg_input / 2;
        WorkloadSpec {
            arrival: Arrival::Batch,
            input: LenDist::Uniform { lo, hi },
            output: LenDist::Fixed(output),
            n_requests,
            seed: 0xF05,
        }
    }

    pub fn poisson(rate: f64, n_requests: u32, input: u32, output: u32) -> Self {
        WorkloadSpec {
            arrival: Arrival::Poisson { rate },
            input: LenDist::LogNormal { mean: input as f64, sigma: 0.6 },
            output: LenDist::LogNormal { mean: output as f64, sigma: 0.4 },
            n_requests,
            seed: 0xF05,
        }
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Materialize the trace.
    pub fn generate(&self) -> Vec<RequestSpec> {
        let mut rng = Pcg64::new(self.seed);
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|_| {
                let arrival = match self.arrival {
                    Arrival::Batch => SimTime::ZERO,
                    Arrival::Poisson { rate } => {
                        t += rng.exp(rate);
                        SimTime::from_secs_f64(t)
                    }
                    Arrival::Gamma { rate, cv } => {
                        let shape = 1.0 / (cv * cv);
                        let scale = 1.0 / (rate * shape);
                        t += rng.gamma(shape) * scale;
                        SimTime::from_secs_f64(t)
                    }
                    Arrival::Uniform { rate } => {
                        t += 1.0 / rate;
                        SimTime::from_secs_f64(t)
                    }
                };
                RequestSpec {
                    arrival,
                    input_len: self.input.sample(&mut rng).max(1),
                    output_len: self.output.sample(&mut rng).max(1),
                }
            })
            .collect()
    }
}

/// Serialize a trace to JSON (workload interchange with external tools).
pub fn trace_to_json(trace: &[RequestSpec]) -> crate::config::json::Json {
    use crate::config::json::Json;
    Json::Arr(
        trace
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("arrival_s", Json::Num(r.arrival.as_secs_f64())),
                    ("input_len", Json::Num(r.input_len as f64)),
                    ("output_len", Json::Num(r.output_len as f64)),
                ])
            })
            .collect(),
    )
}

/// Load a trace from the JSON produced by [`trace_to_json`].
pub fn trace_from_json(v: &crate::config::json::Json) -> anyhow::Result<Vec<RequestSpec>> {
    v.as_arr()?
        .iter()
        .map(|r| {
            Ok(RequestSpec {
                arrival: SimTime::from_secs_f64(r.req("arrival_s")?.as_f64()?),
                input_len: r.req("input_len")?.as_u64()? as u32,
                output_len: r.req("output_len")?.as_u64()? as u32,
            })
        })
        .collect()
}

/// Load a trace file (JSON array of `{arrival_s, input_len, output_len}`).
pub fn trace_from_file(path: &std::path::Path) -> anyhow::Result<Vec<RequestSpec>> {
    let text = std::fs::read_to_string(path)?;
    trace_from_json(&crate::config::json::Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_arrivals_at_zero() {
        let trace = WorkloadSpec::table2(16, 128, 64).generate();
        assert_eq!(trace.len(), 16);
        assert!(trace.iter().all(|r| r.arrival == SimTime::ZERO));
        assert!(trace.iter().all(|r| r.output_len == 64));
    }

    #[test]
    fn table2_input_mean_close_to_target() {
        let trace = WorkloadSpec::table2(2000, 256, 1).generate();
        let mean: f64 =
            trace.iter().map(|r| r.input_len as f64).sum::<f64>() / trace.len() as f64;
        assert!((mean - 256.0).abs() < 15.0, "mean={mean}");
    }

    #[test]
    fn poisson_rate_matches() {
        let spec = WorkloadSpec::poisson(10.0, 5000, 128, 64);
        let trace = spec.generate();
        let span = trace.last().unwrap().arrival.as_secs_f64();
        let rate = trace.len() as f64 / span;
        assert!((rate - 10.0).abs() < 0.8, "rate={rate}");
    }

    #[test]
    fn arrivals_are_sorted() {
        let trace = WorkloadSpec::poisson(50.0, 1000, 64, 64).generate();
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn deterministic_under_seed() {
        let a = WorkloadSpec::poisson(5.0, 100, 64, 64).generate();
        let b = WorkloadSpec::poisson(5.0, 100, 64, 64).generate();
        assert_eq!(a, b);
        let c = WorkloadSpec::poisson(5.0, 100, 64, 64).with_seed(9).generate();
        assert_ne!(a, c);
    }

    #[test]
    fn lognormal_mean_targets() {
        let mut rng = Pcg64::new(5);
        let d = LenDist::LogNormal { mean: 500.0, sigma: 0.6 };
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - 500.0).abs() < 20.0, "mean={mean}");
    }

    #[test]
    fn zipf_mix_has_heavy_tail() {
        let mut rng = Pcg64::new(6);
        let d = LenDist::ZipfMix { lo: 16, hi: 256, long_lo: 8192, long_hi: 16384, frac_long: 0.05 };
        let xs: Vec<u32> = (0..10_000).map(|_| d.sample(&mut rng)).collect();
        let long = xs.iter().filter(|&&x| x >= 8192).count() as f64 / xs.len() as f64;
        assert!((long - 0.05).abs() < 0.01, "frac_long={long}");
    }

    #[test]
    fn trace_json_round_trip() {
        let trace = WorkloadSpec::poisson(5.0, 50, 64, 64).generate();
        let j = trace_to_json(&trace);
        let back = trace_from_json(&j).unwrap();
        assert_eq!(trace.len(), back.len());
        assert_eq!(trace[7].input_len, back[7].input_len);
    }
}
