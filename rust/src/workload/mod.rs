//! Workload generation: request classes, arrival processes, and length
//! distributions.
//!
//! Covers the paper's evaluation workloads (Table 2's fixed
//! batch/in/out grids) plus open-loop production mixes: named request
//! *classes* (chat, long-context RAG, agentic multi-turn with think
//! time, offline batch) with per-class arrival processes
//! (Poisson/gamma/MMPP bursts/diurnal rate curve), per-class length
//! distributions, and multi-tenant rate shares. A materialized workload
//! is just `Vec<RequestSpec>`, so real traces replay through the same
//! downstream path; [`trace_to_text`]/[`trace_from_file`] give a
//! compact deterministic on-disk form.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::core::{Pcg64, SimTime};

/// One request to serve.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestSpec {
    pub arrival: SimTime,
    pub input_len: u32,
    pub output_len: u32,
    /// Index into the workload's class list (0 for single-class specs).
    pub class: u16,
}

/// Arrival process.
#[derive(Clone, Debug, PartialEq)]
pub enum Arrival {
    /// All requests present at t=0 (throughput / closed-batch runs).
    Batch,
    /// Poisson with `rate` requests/second.
    Poisson { rate: f64 },
    /// Gamma inter-arrivals (burstiness via cv != 1).
    Gamma { rate: f64, cv: f64 },
    /// Fixed inter-arrival interval.
    Uniform { rate: f64 },
    /// 2-state Markov-modulated Poisson process: `rate` in the calm
    /// state, `burst_rate` during bursts, with exponentially
    /// distributed dwell times (means `calm_s` / `burst_s`).
    Mmpp { rate: f64, burst_rate: f64, calm_s: f64, burst_s: f64 },
    /// Diurnal rate curve, sampled by thinning a Poisson process at the
    /// peak rate: `rate(t) = rate * (1 + amplitude * sin(2πt/period))`.
    /// Over a full period the mean rate is `rate`.
    Diurnal { rate: f64, amplitude: f64, period_s: f64 },
}

impl Arrival {
    /// Reject parameters that produce NaN timestamps or diverge:
    /// non-positive rates, `cv <= 0` (`shape = 1/cv²` overflows to
    /// inf), non-finite values, out-of-range diurnal amplitude.
    pub fn validate(&self) -> Result<()> {
        let pos = |name: &str, v: f64| -> Result<()> {
            if !v.is_finite() || v <= 0.0 {
                bail!("arrival {name} must be finite and > 0, got {v}");
            }
            Ok(())
        };
        match *self {
            Arrival::Batch => Ok(()),
            Arrival::Poisson { rate } | Arrival::Uniform { rate } => pos("rate", rate),
            Arrival::Gamma { rate, cv } => {
                pos("rate", rate)?;
                pos("cv", cv)
            }
            Arrival::Mmpp { rate, burst_rate, calm_s, burst_s } => {
                pos("rate", rate)?;
                pos("burst_rate", burst_rate)?;
                pos("calm_s", calm_s)?;
                pos("burst_s", burst_s)
            }
            Arrival::Diurnal { rate, amplitude, period_s } => {
                pos("rate", rate)?;
                pos("period_s", period_s)?;
                if !amplitude.is_finite() || !(0.0..=1.0).contains(&amplitude) {
                    bail!("diurnal amplitude must be in [0, 1], got {amplitude}");
                }
                Ok(())
            }
        }
    }
}

/// Stateful sampler for one arrival stream (MMPP needs state beyond the
/// clock). Draw order per request is arrival-then-lengths, which keeps
/// the single-class RNG stream identical to earlier releases.
struct ArrivalGen<'a> {
    arrival: &'a Arrival,
    t: f64,
    burst: bool,
    dwell_end: f64,
}

impl<'a> ArrivalGen<'a> {
    fn new(arrival: &'a Arrival, rng: &mut Pcg64) -> Self {
        let dwell_end = match *arrival {
            Arrival::Mmpp { calm_s, .. } => rng.exp(1.0 / calm_s),
            _ => 0.0,
        };
        ArrivalGen { arrival, t: 0.0, burst: false, dwell_end }
    }

    /// Absolute arrival time of the next request, seconds.
    fn next(&mut self, rng: &mut Pcg64) -> f64 {
        match *self.arrival {
            Arrival::Batch => {}
            Arrival::Poisson { rate } => self.t += rng.exp(rate),
            Arrival::Gamma { rate, cv } => {
                let shape = 1.0 / (cv * cv);
                let scale = 1.0 / (rate * shape);
                self.t += rng.gamma(shape) * scale;
            }
            Arrival::Uniform { rate } => self.t += 1.0 / rate,
            Arrival::Mmpp { rate, burst_rate, calm_s, burst_s } => loop {
                let r = if self.burst { burst_rate } else { rate };
                let dt = rng.exp(r);
                if self.t + dt <= self.dwell_end {
                    self.t += dt;
                    break;
                }
                // dwell expired before the next arrival: flip state and
                // re-draw from the new state's rate
                self.t = self.dwell_end;
                self.burst = !self.burst;
                let dwell = if self.burst { burst_s } else { calm_s };
                self.dwell_end = self.t + rng.exp(1.0 / dwell);
            },
            Arrival::Diurnal { rate, amplitude, period_s } => {
                // thinning: candidates at the peak rate, accepted with
                // probability rate(t)/peak — exact for amplitude <= 1
                let peak = rate * (1.0 + amplitude);
                loop {
                    self.t += rng.exp(peak);
                    let r = rate
                        * (1.0 + amplitude * (std::f64::consts::TAU * self.t / period_s).sin());
                    if rng.next_f64() * peak <= r {
                        break;
                    }
                }
            }
        }
        self.t
    }
}

/// Length distribution.
#[derive(Clone, Debug, PartialEq)]
pub enum LenDist {
    Fixed(u32),
    Uniform { lo: u32, hi: u32 },
    /// Lognormal targeting the given mean with shape sigma.
    LogNormal { mean: f64, sigma: f64 },
    /// Mostly-short with a heavy tail: `frac_long` of requests are
    /// uniform in `[long_lo, long_hi]`, the rest in `[lo, hi]`.
    ZipfMix { lo: u32, hi: u32, long_lo: u32, long_hi: u32, frac_long: f64 },
}

impl LenDist {
    pub fn sample(&self, rng: &mut Pcg64) -> u32 {
        match *self {
            LenDist::Fixed(v) => v,
            LenDist::Uniform { lo, hi } => rng.gen_range(lo as u64, hi as u64 + 1) as u32,
            LenDist::LogNormal { mean, sigma } => {
                // E[lognormal(mu, sigma)] = exp(mu + sigma^2/2)
                let mu = mean.ln() - sigma * sigma / 2.0;
                (rng.lognormal(mu, sigma).round() as u32).max(1)
            }
            LenDist::ZipfMix { lo, hi, long_lo, long_hi, frac_long } => {
                if rng.next_f64() < frac_long {
                    rng.gen_range(long_lo as u64, long_hi as u64 + 1) as u32
                } else {
                    rng.gen_range(lo as u64, hi as u64 + 1) as u32
                }
            }
        }
    }

    /// Mean of the distribution (for rate-matching calculations).
    /// Bounds are widened to f64 before adding: `(lo + hi)` overflows
    /// u32 for long-context bounds.
    pub fn mean(&self) -> f64 {
        let mid = |lo: u32, hi: u32| (lo as f64 + hi as f64) / 2.0;
        match *self {
            LenDist::Fixed(v) => v as f64,
            LenDist::Uniform { lo, hi } => mid(lo, hi),
            LenDist::LogNormal { mean, .. } => mean,
            LenDist::ZipfMix { lo, hi, long_lo, long_hi, frac_long } => {
                (1.0 - frac_long) * mid(lo, hi) + frac_long * mid(long_lo, long_hi)
            }
        }
    }

    /// Reject ranges `gen_range` would panic on (or silently invert)
    /// and parameters that yield zero/NaN lengths.
    pub fn validate(&self) -> Result<()> {
        let range = |name: &str, lo: u32, hi: u32| -> Result<()> {
            if lo == 0 {
                bail!("{name} length bound lo must be >= 1 (zero-length requests)");
            }
            if lo > hi {
                bail!("{name} length bounds inverted: lo {lo} > hi {hi}");
            }
            Ok(())
        };
        match *self {
            LenDist::Fixed(v) => {
                if v == 0 {
                    bail!("fixed length must be >= 1");
                }
                Ok(())
            }
            LenDist::Uniform { lo, hi } => range("uniform", lo, hi),
            LenDist::LogNormal { mean, sigma } => {
                if !mean.is_finite() || mean < 1.0 {
                    bail!("lognormal mean must be finite and >= 1, got {mean}");
                }
                if !sigma.is_finite() || sigma < 0.0 {
                    bail!("lognormal sigma must be finite and >= 0, got {sigma}");
                }
                Ok(())
            }
            LenDist::ZipfMix { lo, hi, long_lo, long_hi, frac_long } => {
                range("zipf short", lo, hi)?;
                range("zipf long", long_lo, long_hi)?;
                if !frac_long.is_finite() || !(0.0..=1.0).contains(&frac_long) {
                    bail!("zipf frac_long must be in [0, 1], got {frac_long}");
                }
                Ok(())
            }
        }
    }
}

/// One request class of an open-loop mix: a tenant/workload family with
/// its own arrival process and length distributions.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassSpec {
    pub name: String,
    /// Rate share of the mix: this class generates
    /// `weight / Σweights` of the workload's requests.
    pub weight: f64,
    pub arrival: Arrival,
    pub input: LenDist,
    pub output: LenDist,
    /// Requests per session (agentic multi-turn; 1 = single-shot). The
    /// arrival process spawns *sessions*; follow-up turns arrive after
    /// exponential think-time gaps.
    pub turns: u32,
    /// Mean think time between turns, seconds.
    pub think_s: f64,
}

impl ClassSpec {
    pub fn new(name: &str, weight: f64, arrival: Arrival, input: LenDist, output: LenDist) -> Self {
        ClassSpec { name: name.into(), weight, arrival, input, output, turns: 1, think_s: 0.0 }
    }

    /// Agentic multi-turn sessions: `turns` requests per session with
    /// mean `think_s` seconds between consecutive turns.
    pub fn with_turns(mut self, turns: u32, think_s: f64) -> Self {
        self.turns = turns;
        self.think_s = think_s;
        self
    }

    pub fn validate(&self) -> Result<()> {
        let ctx = |e: anyhow::Error| anyhow::anyhow!("class '{}': {e}", self.name);
        if !self.weight.is_finite() || self.weight <= 0.0 {
            bail!("class '{}': weight must be finite and > 0, got {}", self.name, self.weight);
        }
        if self.turns == 0 {
            bail!("class '{}': turns must be >= 1", self.name);
        }
        if !self.think_s.is_finite() || self.think_s < 0.0 {
            bail!("class '{}': think_s must be finite and >= 0, got {}", self.name, self.think_s);
        }
        self.arrival.validate().map_err(ctx)?;
        self.input.validate().map_err(ctx)?;
        self.output.validate().map_err(ctx)
    }
}

/// Complete workload specification. Single-class workloads use the flat
/// `arrival`/`input`/`output` fields (with `classes` empty); open-loop
/// mixes populate `classes` (the flat fields are then ignored); setting
/// `trace` replays a file instead of generating anything.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub arrival: Arrival,
    pub input: LenDist,
    pub output: LenDist,
    pub n_requests: u32,
    pub seed: u64,
    /// Open-loop request classes; empty = single-class flat spec.
    pub classes: Vec<ClassSpec>,
    /// Replay this trace file instead of generating (see
    /// [`trace_from_file`] for the accepted formats).
    pub trace: Option<PathBuf>,
}

impl WorkloadSpec {
    /// Table-2 style: all requests at t=0, inputs uniform around the
    /// target mean (the paper reports "Avg Input"), fixed outputs.
    pub fn table2(n_requests: u32, avg_input: u32, output: u32) -> Self {
        let lo = (avg_input / 2).max(1);
        let hi = avg_input + avg_input / 2;
        WorkloadSpec {
            arrival: Arrival::Batch,
            input: LenDist::Uniform { lo, hi },
            output: LenDist::Fixed(output),
            n_requests,
            seed: 0xF05,
            classes: Vec::new(),
            trace: None,
        }
    }

    pub fn poisson(rate: f64, n_requests: u32, input: u32, output: u32) -> Self {
        WorkloadSpec {
            arrival: Arrival::Poisson { rate },
            input: LenDist::LogNormal { mean: input as f64, sigma: 0.6 },
            output: LenDist::LogNormal { mean: output as f64, sigma: 0.4 },
            n_requests,
            seed: 0xF05,
            classes: Vec::new(),
            trace: None,
        }
    }

    /// Multi-class open-loop workload from explicit classes.
    pub fn classes(classes: Vec<ClassSpec>, n_requests: u32) -> Self {
        WorkloadSpec {
            arrival: Arrival::Batch,
            input: LenDist::Fixed(1),
            output: LenDist::Fixed(1),
            n_requests,
            seed: 0xF05,
            classes,
            trace: None,
        }
    }

    /// Replay a trace file.
    pub fn from_trace(path: PathBuf) -> Self {
        let mut w = WorkloadSpec::table2(1, 1, 1);
        w.trace = Some(path);
        w
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// One simulated traffic day at `rate` mean requests/second total:
    /// diurnal interactive classes (chat + RAG), MMPP-bursty agentic
    /// sessions, and a constant offline-batch trickle. The diurnal
    /// period spans the whole run (one "day" = one period).
    pub fn traffic_day(rate: f64, n_requests: u32) -> Self {
        let period_s = (n_requests as f64 / rate).max(1.0);
        let day = |share: f64| Arrival::Diurnal {
            rate: share * rate,
            amplitude: 0.6,
            period_s,
        };
        let agentic_turns = 6u32;
        // MMPP session rate targeting share*rate *requests*/s: sessions
        // carry `turns` requests and the calm/burst dwell mix has mean
        // rate 1.5x the calm rate (calm 300s at x + burst 60s at 4x)
        let agentic_share = 0.15;
        let calm = agentic_share * rate / (agentic_turns as f64 * 1.5);
        let classes = vec![
            ClassSpec::new(
                "chat",
                0.55,
                day(0.55),
                LenDist::LogNormal { mean: 512.0, sigma: 0.8 },
                LenDist::LogNormal { mean: 192.0, sigma: 0.6 },
            ),
            ClassSpec::new(
                "rag",
                0.20,
                day(0.20),
                LenDist::ZipfMix {
                    lo: 1024,
                    hi: 4096,
                    long_lo: 8192,
                    long_hi: 16384,
                    frac_long: 0.08,
                },
                LenDist::LogNormal { mean: 256.0, sigma: 0.5 },
            ),
            ClassSpec::new(
                "agentic",
                agentic_share,
                Arrival::Mmpp {
                    rate: calm,
                    burst_rate: 4.0 * calm,
                    calm_s: 300.0,
                    burst_s: 60.0,
                },
                LenDist::LogNormal { mean: 768.0, sigma: 0.6 },
                LenDist::LogNormal { mean: 256.0, sigma: 0.6 },
            )
            .with_turns(agentic_turns, 4.0),
            ClassSpec::new(
                "batch",
                0.10,
                Arrival::Uniform { rate: 0.10 * rate },
                LenDist::LogNormal { mean: 2048.0, sigma: 0.4 },
                LenDist::LogNormal { mean: 64.0, sigma: 0.4 },
            ),
        ];
        WorkloadSpec::classes(classes, n_requests)
    }

    /// Named single-class presets (`chat`, `rag`, `agentic`, `batch`)
    /// or the mixed `day`; `rate` overrides each preset's default mean
    /// request rate.
    pub fn preset(name: &str, rate: Option<f64>, n_requests: u32) -> Result<Self> {
        if let Some(r) = rate {
            if !r.is_finite() || r <= 0.0 {
                bail!("workload rate must be finite and > 0, got {r}");
            }
        }
        let day = Self::traffic_day(rate.unwrap_or(30.0), n_requests);
        let single = |i: usize, default_rate: f64| {
            let mut c = day.classes[i].clone();
            c.weight = 1.0;
            // re-target the class's own arrival process at the
            // requested rate (presets default to the day-mix shape)
            let r = rate.unwrap_or(default_rate);
            c.arrival = match c.arrival {
                Arrival::Diurnal { amplitude, period_s, .. } => {
                    Arrival::Diurnal { rate: r, amplitude, period_s }
                }
                Arrival::Mmpp { calm_s, burst_s, .. } => {
                    let calm = r / (c.turns as f64 * 1.5);
                    Arrival::Mmpp { rate: calm, burst_rate: 4.0 * calm, calm_s, burst_s }
                }
                _ => Arrival::Poisson { rate: r },
            };
            Ok(WorkloadSpec::classes(vec![c], n_requests))
        };
        match name {
            "day" => Ok(day),
            "chat" => single(0, 20.0),
            "rag" => single(1, 5.0),
            "agentic" => single(2, 5.0),
            "batch" => single(3, 2.0),
            other => bail!(
                "unknown workload preset '{other}' (expected chat|rag|agentic|batch|day, \
                 optionally ':<rate>', or trace:<file>)"
            ),
        }
    }

    /// Parse a `--workload` value: `<preset>[:<rate>]` or
    /// `trace:<file>`. The grammar is comma-free on purpose so
    /// `--axis workload=chat:20,day:50` sweeps cleanly.
    pub fn parse_spec(spec: &str, n_requests: u32) -> Result<Self> {
        match spec.split_once(':') {
            Some(("trace", path)) if !path.is_empty() => {
                Ok(WorkloadSpec::from_trace(PathBuf::from(path)))
            }
            Some(("trace", _)) => bail!("trace: needs a file path (trace:<file>)"),
            Some((name, rate)) => {
                let r: f64 = rate
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad workload rate '{rate}' in '{spec}'"))?;
                Self::preset(name, Some(r), n_requests)
            }
            None => Self::preset(spec, None, n_requests),
        }
    }

    /// Class names for per-class reporting (empty for flat specs).
    pub fn class_names(&self) -> Vec<String> {
        self.classes.iter().map(|c| c.name.clone()).collect()
    }

    /// Reject parameter combinations that panic, hang, or silently
    /// produce NaN timestamps. Called from
    /// [`ExperimentConfig::validate`](crate::config::ExperimentConfig::validate)
    /// so bad workloads fail loudly at config-build time.
    pub fn validate(&self) -> Result<()> {
        if self.trace.is_some() {
            return Ok(()); // trace contents are validated on load
        }
        if self.n_requests == 0 {
            bail!("empty workload");
        }
        if self.classes.is_empty() {
            self.arrival.validate()?;
            self.input.validate()?;
            self.output.validate()
        } else {
            for c in &self.classes {
                c.validate()?;
            }
            Ok(())
        }
    }

    /// Materialize the request list: load + validate the trace file if
    /// one is set, otherwise generate synthetically.
    pub fn materialize(&self) -> Result<Vec<RequestSpec>> {
        match &self.trace {
            Some(path) => trace_from_file(path),
            None => Ok(self.generate()),
        }
    }

    /// Materialize a synthetic trace. Trace-replay specs go through
    /// [`WorkloadSpec::materialize`] instead.
    pub fn generate(&self) -> Vec<RequestSpec> {
        debug_assert!(self.trace.is_none(), "trace replay goes through materialize()");
        if self.classes.is_empty() {
            return self.generate_flat();
        }
        let total_w: f64 = self.classes.iter().map(|c| c.weight).sum();
        let mut out: Vec<RequestSpec> = Vec::with_capacity(self.n_requests as usize);
        let mut remaining = self.n_requests;
        for (ci, class) in self.classes.iter().enumerate() {
            // rate share -> request count; the last class absorbs
            // rounding so the total is exact
            let n = if ci + 1 == self.classes.len() {
                remaining
            } else {
                let share = (self.n_requests as f64 * class.weight / total_w).round() as u32;
                share.min(remaining)
            };
            remaining -= n;
            // independent per-class RNG stream: adding or re-weighting
            // one class never perturbs another class's draws
            let mut rng =
                Pcg64::new(self.seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(ci as u64 + 1));
            let mut arrivals = ArrivalGen::new(&class.arrival, &mut rng);
            let mut made = 0u32;
            while made < n {
                let mut t = arrivals.next(&mut rng);
                for turn in 0..class.turns {
                    if made >= n {
                        break;
                    }
                    if turn > 0 && class.think_s > 0.0 {
                        t += rng.exp(1.0 / class.think_s);
                    }
                    out.push(RequestSpec {
                        arrival: SimTime::from_secs_f64(t),
                        input_len: class.input.sample(&mut rng).max(1),
                        output_len: class.output.sample(&mut rng).max(1),
                        class: ci as u16,
                    });
                    made += 1;
                }
            }
        }
        // stable by arrival: ties keep class order, so the merged trace
        // is deterministic
        out.sort_by_key(|r| r.arrival);
        out
    }

    fn generate_flat(&self) -> Vec<RequestSpec> {
        let mut rng = Pcg64::new(self.seed);
        let mut arrivals = ArrivalGen::new(&self.arrival, &mut rng);
        (0..self.n_requests)
            .map(|_| {
                let t = arrivals.next(&mut rng);
                RequestSpec {
                    arrival: if matches!(self.arrival, Arrival::Batch) {
                        SimTime::ZERO
                    } else {
                        SimTime::from_secs_f64(t)
                    },
                    input_len: self.input.sample(&mut rng).max(1),
                    output_len: self.output.sample(&mut rng).max(1),
                    class: 0,
                }
            })
            .collect()
    }
}

/// Serialize a trace to JSON (workload interchange with external tools).
pub fn trace_to_json(trace: &[RequestSpec]) -> crate::config::json::Json {
    use crate::config::json::Json;
    Json::Arr(
        trace
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("arrival_s", Json::Num(r.arrival.as_secs_f64())),
                    ("input_len", Json::Num(r.input_len as f64)),
                    ("output_len", Json::Num(r.output_len as f64)),
                    ("class", Json::Num(r.class as f64)),
                ])
            })
            .collect(),
    )
}

/// Serialize a trace in the compact text form: a header comment, then
/// one `arrival_s input_len output_len class` line per request.
pub fn trace_to_text(trace: &[RequestSpec]) -> String {
    let mut s = String::with_capacity(trace.len() * 24 + 64);
    s.push_str("# frontier trace v1: arrival_s input_len output_len class\n");
    for r in trace {
        s.push_str(&format!(
            "{:.6} {} {} {}\n",
            r.arrival.as_secs_f64(),
            r.input_len,
            r.output_len,
            r.class
        ));
    }
    s
}

/// Validate raw trace rows and build the request list: arrivals must be
/// finite, non-negative, and non-decreasing; lengths in `1..=u32::MAX`.
/// The coordinator schedules whatever it is given, so garbage rows must
/// die here, not "succeed" with nonsense timestamps.
fn build_trace(rows: Vec<(f64, u64, u64, u64)>) -> Result<Vec<RequestSpec>> {
    if rows.is_empty() {
        bail!("empty trace");
    }
    let mut prev = 0.0f64;
    let mut out = Vec::with_capacity(rows.len());
    for (i, (arrival_s, input, output, class)) in rows.into_iter().enumerate() {
        if !arrival_s.is_finite() || arrival_s < 0.0 {
            bail!("trace row {i}: arrival_s must be finite and >= 0, got {arrival_s}");
        }
        if arrival_s < prev {
            bail!("trace row {i}: arrivals not sorted ({arrival_s} after {prev})");
        }
        prev = arrival_s;
        let len = |name: &str, v: u64| -> Result<u32> {
            if v == 0 || v > u32::MAX as u64 {
                bail!("trace row {i}: {name} must be in 1..=u32::MAX, got {v}");
            }
            Ok(v as u32)
        };
        if class > u16::MAX as u64 {
            bail!("trace row {i}: class must fit in u16, got {class}");
        }
        out.push(RequestSpec {
            arrival: SimTime::from_secs_f64(arrival_s),
            input_len: len("input_len", input)?,
            output_len: len("output_len", output)?,
            class: class as u16,
        });
    }
    Ok(out)
}

/// Load a trace from the JSON produced by [`trace_to_json`] (the
/// `class` field is optional and defaults to 0). Rows are validated —
/// see [`trace_from_file`].
pub fn trace_from_json(v: &crate::config::json::Json) -> Result<Vec<RequestSpec>> {
    let rows = v
        .as_arr()?
        .iter()
        .map(|r| {
            let class = match r.get("class") {
                Some(c) => c.as_u64()?,
                None => 0,
            };
            Ok((
                r.req("arrival_s")?.as_f64()?,
                r.req("input_len")?.as_u64()?,
                r.req("output_len")?.as_u64()?,
                class,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    build_trace(rows)
}

/// Parse the compact text trace form written by [`trace_to_text`]:
/// `#`-comment and blank lines are skipped, data lines carry
/// whitespace-separated `arrival_s input_len output_len [class]`.
pub fn trace_from_text(text: &str) -> Result<Vec<RequestSpec>> {
    let mut rows = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut f = line.split_whitespace();
        let mut field = |name: &str| {
            f.next().ok_or_else(|| {
                anyhow::anyhow!("trace line {}: missing {name}", lineno + 1)
            })
        };
        let arrival: f64 = field("arrival_s")?
            .parse()
            .map_err(|_| anyhow::anyhow!("trace line {}: bad arrival_s", lineno + 1))?;
        let nums = |s: &str| -> Result<u64> {
            s.parse()
                .map_err(|_| anyhow::anyhow!("trace line {}: bad integer '{s}'", lineno + 1))
        };
        let input = nums(field("input_len")?)?;
        let output = nums(field("output_len")?)?;
        let class = match f.next() {
            Some(c) => nums(c)?,
            None => 0,
        };
        rows.push((arrival, input, output, class));
    }
    build_trace(rows)
}

/// Load a trace file: JSON (`[{arrival_s, input_len, output_len,
/// class?}, ...]`) or the compact text form, sniffed by the leading
/// character. Arrivals are validated monotonic non-negative on load.
pub fn trace_from_file(path: &std::path::Path) -> Result<Vec<RequestSpec>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read trace {path:?}: {e}"))?;
    if text.trim_start().starts_with('[') {
        trace_from_json(&crate::config::json::Json::parse(&text)?)
    } else {
        trace_from_text(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_arrivals_at_zero() {
        let trace = WorkloadSpec::table2(16, 128, 64).generate();
        assert_eq!(trace.len(), 16);
        assert!(trace.iter().all(|r| r.arrival == SimTime::ZERO));
        assert!(trace.iter().all(|r| r.output_len == 64));
    }

    #[test]
    fn table2_input_mean_close_to_target() {
        let trace = WorkloadSpec::table2(2000, 256, 1).generate();
        let mean: f64 =
            trace.iter().map(|r| r.input_len as f64).sum::<f64>() / trace.len() as f64;
        assert!((mean - 256.0).abs() < 15.0, "mean={mean}");
    }

    #[test]
    fn poisson_rate_matches() {
        let spec = WorkloadSpec::poisson(10.0, 5000, 128, 64);
        let trace = spec.generate();
        let span = trace.last().unwrap().arrival.as_secs_f64();
        let rate = trace.len() as f64 / span;
        assert!((rate - 10.0).abs() < 0.8, "rate={rate}");
    }

    #[test]
    fn arrivals_are_sorted() {
        let trace = WorkloadSpec::poisson(50.0, 1000, 64, 64).generate();
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
    }

    #[test]
    fn deterministic_under_seed() {
        let a = WorkloadSpec::poisson(5.0, 100, 64, 64).generate();
        let b = WorkloadSpec::poisson(5.0, 100, 64, 64).generate();
        assert_eq!(a, b);
        let c = WorkloadSpec::poisson(5.0, 100, 64, 64).with_seed(9).generate();
        assert_ne!(a, c);
    }

    #[test]
    fn lognormal_mean_targets() {
        let mut rng = Pcg64::new(5);
        let d = LenDist::LogNormal { mean: 500.0, sigma: 0.6 };
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| d.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!((mean - 500.0).abs() < 20.0, "mean={mean}");
    }

    #[test]
    fn zipf_mix_has_heavy_tail() {
        let mut rng = Pcg64::new(6);
        let d = LenDist::ZipfMix { lo: 16, hi: 256, long_lo: 8192, long_hi: 16384, frac_long: 0.05 };
        let xs: Vec<u32> = (0..10_000).map(|_| d.sample(&mut rng)).collect();
        let long = xs.iter().filter(|&&x| x >= 8192).count() as f64 / xs.len() as f64;
        assert!((long - 0.05).abs() < 0.01, "frac_long={long}");
    }

    #[test]
    fn trace_json_round_trip() {
        let trace = WorkloadSpec::poisson(5.0, 50, 64, 64).generate();
        let j = trace_to_json(&trace);
        let back = trace_from_json(&j).unwrap();
        assert_eq!(trace.len(), back.len());
        assert_eq!(trace[7].input_len, back[7].input_len);
    }

    #[test]
    fn trace_text_round_trip_keeps_classes() {
        let trace = WorkloadSpec::traffic_day(50.0, 200).generate();
        let text = trace_to_text(&trace);
        let back = trace_from_text(&text).unwrap();
        assert_eq!(trace.len(), back.len());
        for (a, b) in trace.iter().zip(&back) {
            assert_eq!(a.class, b.class);
            assert_eq!(a.input_len, b.input_len);
            assert_eq!(a.output_len, b.output_len);
            // text form rounds arrivals to 1µs
            assert!((a.arrival.as_secs_f64() - b.arrival.as_secs_f64()).abs() < 1e-5);
        }
    }

    #[test]
    fn trace_rejects_garbage_rows() {
        // unsorted
        let t = "1.0 10 10 0\n0.5 10 10 0\n";
        assert!(trace_from_text(t).unwrap_err().to_string().contains("not sorted"));
        // negative
        let t = "-1.0 10 10 0\n";
        assert!(trace_from_text(t).unwrap_err().to_string().contains(">= 0"));
        // NaN
        let t = "NaN 10 10 0\n";
        assert!(trace_from_text(t).is_err());
        // zero-length request
        let t = "0.0 0 10 0\n";
        assert!(trace_from_text(t).unwrap_err().to_string().contains("input_len"));
        // empty
        assert!(trace_from_text("# nothing\n").unwrap_err().to_string().contains("empty"));
        // JSON path hits the same validator
        use crate::config::json::Json;
        let j = Json::parse(
            r#"[{"arrival_s": 2.0, "input_len": 4, "output_len": 4},
                {"arrival_s": 1.0, "input_len": 4, "output_len": 4}]"#,
        )
        .unwrap();
        assert!(trace_from_json(&j).unwrap_err().to_string().contains("not sorted"));
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        // satellite regressions: each of these previously panicked,
        // diverged, or produced NaN timestamps deep inside generate()
        assert!(LenDist::Uniform { lo: 9, hi: 3 }.validate().is_err());
        assert!(LenDist::Fixed(0).validate().is_err());
        assert!(Arrival::Gamma { rate: 1.0, cv: 0.0 }.validate().is_err());
        assert!(Arrival::Poisson { rate: 0.0 }.validate().is_err());
        assert!(Arrival::Poisson { rate: -2.0 }.validate().is_err());
        assert!(Arrival::Uniform { rate: f64::NAN }.validate().is_err());
        assert!(Arrival::Diurnal { rate: 1.0, amplitude: 1.5, period_s: 60.0 }
            .validate()
            .is_err());
        assert!(Arrival::Mmpp { rate: 1.0, burst_rate: 0.0, calm_s: 10.0, burst_s: 1.0 }
            .validate()
            .is_err());
        let mut w = WorkloadSpec::table2(16, 128, 64);
        assert!(w.validate().is_ok());
        w.input = LenDist::Uniform { lo: 100, hi: 10 };
        assert!(w.validate().is_err());
        w = WorkloadSpec::table2(0, 128, 64);
        assert!(w.validate().unwrap_err().to_string().contains("empty workload"));
        let mut day = WorkloadSpec::traffic_day(30.0, 100);
        assert!(day.validate().is_ok());
        day.classes[0].weight = -1.0;
        assert!(day.validate().is_err());
    }

    #[test]
    fn lendist_mean_survives_long_context_bounds() {
        // (lo + hi) as u32 used to overflow for long-context bounds
        let d = LenDist::Uniform { lo: 3_000_000_000, hi: 3_000_000_002 };
        assert_eq!(d.mean(), 3_000_000_001.0);
    }

    #[test]
    fn traffic_day_mix_matches_shares() {
        let trace = WorkloadSpec::traffic_day(100.0, 4000).generate();
        assert_eq!(trace.len(), 4000);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival), "sorted");
        let count = |c: u16| trace.iter().filter(|r| r.class == c).count() as f64 / 4000.0;
        assert!((count(0) - 0.55).abs() < 0.01, "chat share {}", count(0));
        assert!((count(1) - 0.20).abs() < 0.01, "rag share {}", count(1));
        assert!((count(2) - 0.15).abs() < 0.01, "agentic share {}", count(2));
        assert!((count(3) - 0.10).abs() < 0.01, "batch share {}", count(3));
        // deterministic
        assert_eq!(trace, WorkloadSpec::traffic_day(100.0, 4000).generate());
    }

    #[test]
    fn diurnal_rate_and_mmpp_rate_roughly_match_targets() {
        let w = WorkloadSpec::classes(
            vec![ClassSpec::new(
                "d",
                1.0,
                Arrival::Diurnal { rate: 10.0, amplitude: 0.6, period_s: 500.0 },
                LenDist::Fixed(8),
                LenDist::Fixed(8),
            )],
            10_000,
        );
        let trace = w.generate();
        let span = trace.last().unwrap().arrival.as_secs_f64();
        let rate = trace.len() as f64 / span;
        assert!((rate - 10.0).abs() < 1.0, "diurnal mean rate {rate}");
        // peak phase (sin > 0) should see visibly more arrivals than trough
        let phase = |t: f64| (t / 500.0).fract();
        let hi = trace.iter().filter(|r| phase(r.arrival.as_secs_f64()) < 0.5).count();
        let lo = trace.len() - hi;
        assert!(hi as f64 > 1.3 * lo as f64, "diurnal modulation visible: {hi} vs {lo}");

        let w = WorkloadSpec::classes(
            vec![ClassSpec::new(
                "m",
                1.0,
                Arrival::Mmpp { rate: 2.0, burst_rate: 8.0, calm_s: 30.0, burst_s: 6.0 },
                LenDist::Fixed(8),
                LenDist::Fixed(8),
            )],
            10_000,
        );
        let trace = w.generate();
        let span = trace.last().unwrap().arrival.as_secs_f64();
        let rate = trace.len() as f64 / span;
        // stationary mean = (2*30 + 8*6)/36 = 3.0
        assert!((rate - 3.0).abs() < 0.5, "mmpp mean rate {rate}");
    }

    #[test]
    fn agentic_sessions_space_turns_by_think_time() {
        let w = WorkloadSpec::classes(
            vec![ClassSpec::new(
                "agent",
                1.0,
                Arrival::Poisson { rate: 0.5 },
                LenDist::Fixed(64),
                LenDist::Fixed(16),
            )
            .with_turns(4, 10.0)],
            400,
        );
        let trace = w.generate();
        assert_eq!(trace.len(), 400);
        assert!(trace.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        // 100 sessions of 4 turns at ~10s think time stretch the span
        // well past the session-arrival span alone (~200s)
        let span = trace.last().unwrap().arrival.as_secs_f64();
        assert!(span > 200.0, "think time extends the span: {span}");
    }

    #[test]
    fn preset_grammar_parses_and_rejects() {
        let w = WorkloadSpec::parse_spec("chat", 100).unwrap();
        assert_eq!(w.classes.len(), 1);
        assert_eq!(w.classes[0].name, "chat");
        let w = WorkloadSpec::parse_spec("day:80", 100).unwrap();
        assert_eq!(w.classes.len(), 4);
        let w = WorkloadSpec::parse_spec("trace:/tmp/x.trace", 100).unwrap();
        assert!(w.trace.is_some());
        assert!(WorkloadSpec::parse_spec("nope", 100).is_err());
        assert!(WorkloadSpec::parse_spec("chat:zero", 100).is_err());
        assert!(WorkloadSpec::parse_spec("chat:-4", 100).is_err());
        assert!(WorkloadSpec::parse_spec("trace:", 100).is_err());
    }
}
